"""Lane-padded rank rows + the tiled policy-step kernel.

Proves the padding invariants the refactor rests on: ``find`` / ``promote``
/ ``demote`` / ``rank_step`` are equivalent on padded and tight rows, and
the tiled Pallas kernel (forced down to 128-lane tiles so multi-tile
carries actually fire) is bit-identical to the jnp oracle at awkward K —
non-multiples of 128, single-element rows, K larger than one tile —
including ``wipe_from`` boundaries and fully-``EMPTY`` rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.policy import (EMPTY, LANE, demote, find, lane_pad,
                               padded_row, promote, rank_step)
from repro.kernels.policy_step import fused_policy_step

K_GRID = [1, 7, 127, 128, 129, 1000]


def climb_plan(hit, i, scalars):
    """CLIMB with a traced length scalar — the simplest full-contract plan
    (promote-by-one on hit, replace bottom on miss, wipe = none)."""
    (n,) = scalars
    src = jnp.where(hit, i, n - 1)
    t = jnp.where(hit, jnp.maximum(i - 1, 0), n - 1)
    return src, t, n, (n,)


def _tight_row(K, k, rng):
    """A tight [K] row with k distinct resident keys, rest EMPTY."""
    row = np.full(K, -1, np.int32)
    row[:k] = rng.choice(5 * K + 8, size=k, replace=False).astype(np.int32)
    return jnp.asarray(row)


# --- padded vs tight primitive equivalence ----------------------------------

@pytest.mark.parametrize("K", [1, 7, 127, 128, 129])
def test_find_promote_demote_padded_equivalence(K):
    rng = np.random.default_rng(K + 1)
    k = int(rng.integers(1, K + 1))
    tight = _tight_row(K, k, rng)
    W = lane_pad(K)
    padded = jnp.concatenate([tight, jnp.full((W - K,), EMPTY, jnp.int32)])

    present = tight[int(rng.integers(0, k))]
    absent = jnp.int32(5 * K + 9)
    for key in (present, absent):
        ht, it = find(tight, key)
        hp, ip = find(padded, key)
        assert bool(ht) == bool(hp)
        if bool(ht):
            assert int(it) == int(ip)

    i = int(rng.integers(0, k))
    t = int(rng.integers(0, i + 1))
    np.testing.assert_array_equal(
        np.asarray(promote(padded, i, t, jnp.int32(777))[:K]),
        np.asarray(promote(tight, i, t, jnp.int32(777))))
    d = int(rng.integers(i, k))
    np.testing.assert_array_equal(
        np.asarray(demote(padded, i, d, tight[i])[:K]),
        np.asarray(demote(tight, i, d, tight[i])))
    # padding untouched by either primitive
    assert np.all(np.asarray(promote(padded, i, t, jnp.int32(777))[K:]) == -1)
    assert np.all(np.asarray(demote(padded, i, d, tight[i])[K:]) == -1)


# --- tiled kernel vs jnp oracle at edge K -----------------------------------

@pytest.mark.parametrize("K", K_GRID)
def test_fused_step_parity_edge_K(K):
    """128-lane forced tiles: K=1000 runs 8 tiles, so the cross-tile argmin,
    boundary carry, and evicted-extraction paths all fire."""
    rng = np.random.default_rng(K)
    n = jnp.int32(K)
    cache_j = cache_p = padded_row(K)

    @jax.jit
    def jstep(c, key):
        return rank_step(c, key, (n,), climb_plan)

    @jax.jit
    def pstep(c, key):
        return fused_policy_step(c, key, (n,), climb_plan,
                                 interpret=True, tile=LANE)

    for step in range(100):
        key = jnp.int32(rng.integers(0, max(2 * K, 4)))
        cache_j, _, hit_j, ev_j = jstep(cache_j, key)
        cache_p, _, hit_p, ev_p = pstep(cache_p, key)
        assert bool(hit_j) == bool(hit_p), step
        assert int(ev_j) == int(ev_p), step
        np.testing.assert_array_equal(np.asarray(cache_j),
                                      np.asarray(cache_p))
    # padding invariant held throughout
    assert np.all(np.asarray(cache_p)[K:] == -1)


@pytest.mark.parametrize("K", [1, 7, 127, 129])
def test_fused_step_pads_tight_rows_internally(K):
    """Direct calls with tight (non-padded) rows — e.g. the rank_step
    doctest — pad internally and slice back, bit-identical to the oracle."""
    rng = np.random.default_rng(K + 7)
    n = jnp.int32(K)
    cache_j = cache_p = jnp.full((K,), EMPTY, jnp.int32)
    for step in range(60):
        key = jnp.int32(rng.integers(0, max(2 * K, 4)))
        cache_j, _, hit_j, _ = rank_step(cache_j, key, (n,), climb_plan)
        cache_p, _, hit_p, _ = fused_policy_step(
            cache_p, key, (n,), climb_plan, interpret=True, tile=LANE)
        assert cache_p.shape == (K,)
        assert bool(hit_j) == bool(hit_p), step
        np.testing.assert_array_equal(np.asarray(cache_j),
                                      np.asarray(cache_p))


# --- wipe_from boundaries and empty rows ------------------------------------

@pytest.mark.parametrize("wipe", [0, 1, 64, 127, 128, 200, 255, 256])
def test_wipe_from_boundaries(wipe):
    """Wipes landing on/off tile edges of a 2-tile row (W=256, tile=128),
    including wipe=0 (clears the freshly inserted key too) and wipe=W."""
    W = 256
    cache = jnp.arange(W, dtype=jnp.int32)

    def plan(hit, i, scalars):
        return jnp.int32(W - 1), jnp.int32(0), jnp.int32(wipe), ()

    ref = rank_step(cache, jnp.int32(999), (), plan)
    got = fused_policy_step(cache, jnp.int32(999), (), plan,
                            interpret=True, tile=LANE)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    assert bool(got[2]) == bool(ref[2]) and int(got[3]) == int(ref[3])
    assert np.all(np.asarray(got[0])[wipe:] == -1)


@pytest.mark.parametrize("K", [4, 128, 300])
@pytest.mark.parametrize("key", [5, -1])
def test_full_empty_row(K, key):
    """A fully-EMPTY row: a miss inserts at the bottom rank; searching for
    EMPTY itself (-1) 'hits' at rank 0 in both lowerings alike."""
    cache = padded_row(K)
    n = jnp.int32(K)
    ref = rank_step(cache, jnp.int32(key), (n,), climb_plan)
    got = fused_policy_step(cache, jnp.int32(key), (n,), climb_plan,
                            interpret=True, tile=LANE)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    assert bool(got[2]) == bool(ref[2]) and int(got[3]) == int(ref[3])


# --- composition: scan + vmap over multi-tile rows --------------------------

def test_scan_vmap_multitile_parity():
    K = 300                                  # W = 384 -> 3 forced tiles
    B, T = 3, 120
    rng = np.random.default_rng(42)
    keys = jnp.asarray(rng.integers(0, 2 * K, size=(B, T)).astype(np.int32))
    n = jnp.int32(K)

    def run(step_fn):
        def one(lane_keys):
            def body(c, key):
                c, _, hit, _ = step_fn(c, key)
                return c, hit
            return jax.lax.scan(body, padded_row(K), lane_keys)
        return jax.jit(jax.vmap(one))(keys)

    cj, hj = run(lambda c, key: rank_step(c, key, (n,), climb_plan))
    cp, hp = run(lambda c, key: fused_policy_step(
        c, key, (n,), climb_plan, interpret=True, tile=LANE))
    np.testing.assert_array_equal(np.asarray(hp), np.asarray(hj))
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(cj))


def test_compiled_config_lowers_for_tpu():
    """The interpret=False (Mosaic) configuration cannot execute on CPU,
    but it must *lower*: cross-platform export for TPU proves the kernel
    is Mosaic-legal, scan+vmap included (tools/check_lowering.py runs the
    fuller sweep; this is the in-suite smoke)."""
    jexport = pytest.importorskip("jax.export")
    K = 300
    n = jnp.int32(K)

    def f(cache, keys):
        def body(c, key):
            c, _, hit, _ = fused_policy_step(c, key, (n,), climb_plan,
                                             interpret=False, tile=LANE)
            return c, hit
        return jax.lax.scan(body, cache, keys)

    exp = jexport.export(jax.jit(f), platforms=["tpu"])(
        jax.ShapeDtypeStruct((lane_pad(K),), jnp.int32),
        jax.ShapeDtypeStruct((16,), jnp.int32))
    assert "tpu" in [p.lower() for p in exp.platforms]


# --- property: random promote/wipe plans on padded rows ---------------------

@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_random_plan_sequences_parity(data):
    """Arbitrary valid plans (any t <= src < W, any wipe boundary, keys
    including EMPTY) keep the tiled kernel bit-identical to the oracle —
    stronger than policy-shaped sequences."""
    K = data.draw(st.integers(min_value=1, max_value=200))
    W = lane_pad(K)
    cache_j = cache_p = padded_row(K)
    for _ in range(data.draw(st.integers(min_value=1, max_value=6))):
        key = jnp.int32(data.draw(st.integers(min_value=-1,
                                              max_value=2 * K)))
        src = data.draw(st.integers(min_value=0, max_value=K - 1))
        t = data.draw(st.integers(min_value=0, max_value=src))
        wipe = data.draw(st.integers(min_value=0, max_value=W))

        def plan(hit, i, scalars, src=src, t=t, wipe=wipe):
            return jnp.int32(src), jnp.int32(t), jnp.int32(wipe), ()

        rj = rank_step(cache_j, key, (), plan)
        rp = fused_policy_step(cache_p, key, (), plan,
                               interpret=True, tile=LANE)
        np.testing.assert_array_equal(np.asarray(rp[0]), np.asarray(rj[0]))
        assert bool(rp[2]) == bool(rj[2]) and int(rp[3]) == int(rj[3])
        cache_j, cache_p = rj[0], rp[0]
