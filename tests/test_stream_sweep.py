"""Streaming replay/sweep parity: ``replay_stream(observe=True)`` matches
``replay(observe=True)`` (the occupancy-observation gap), iterator-chunk
input matches dense input, and a Sweep run through the streaming path
emits records bit-identical to the materialized path — for both Pallas
settings, synthetic and file-backed scenarios alike."""
import pathlib

import numpy as np
import pytest

from repro.bench import (Scenario, Sweep, results, run_sweep, should_stream,
                         stream_chunks)
from repro.core import Engine, Request
from repro.data.traces import make_trace, zipf_trace

ENGINE = Engine()
CORPUS = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" \
    / "corpus"


# --- replay_stream observe= (the satellite bugfix) -------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_replay_stream_observe_matches_replay(use_pallas):
    """A streamed DAC run reports the same time-mean observables as the
    stacked-obs replay — exactly, since integer observables sum without
    rounding in 64 bits on both paths."""
    trace = zipf_trace(N=256, T=5000, alpha=1.0, seed=9)
    full = ENGINE.replay("dac", trace, 24, observe=True,
                         use_pallas=use_pallas)
    stream = ENGINE.replay_stream("dac", trace, 24, chunk=1024,
                                  observe=True, use_pallas=use_pallas)
    assert int(stream.metrics.hits) == int(full.metrics.hits)
    for name in ("k", "jump"):
        want = np.asarray(full.obs[name], np.float64).mean()
        assert stream.obs[name] == want, (name, stream.obs[name], want)


def test_replay_stream_observe_batched():
    traces = np.stack([zipf_trace(N=96, T=2300, alpha=a, seed=s)
                       for s, a in enumerate((0.8, 1.1))])
    full = ENGINE.replay("dac", traces, 16, observe=True,
                         collect_info=False)
    stream = ENGINE.replay_stream("dac", traces, 16, chunk=512,
                                  observe=True)
    np.testing.assert_array_equal(
        stream.obs["k"],
        np.asarray(full.obs["k"], np.float64).mean(axis=-1))


def test_replay_stream_observe_none_without_observables():
    trace = zipf_trace(N=64, T=800, alpha=1.0, seed=1)
    assert ENGINE.replay_stream("lru", trace, 8, observe=True,
                                chunk=300).obs is None
    assert ENGINE.replay_stream("dac", trace, 8, chunk=300).obs is None


# --- iterator-chunk input --------------------------------------------------

def test_replay_stream_iterator_matches_dense():
    trace = zipf_trace(N=256, T=5000, alpha=1.0, seed=4)
    sizes = (1 + (trace % 11)).astype(np.int32)
    dense = ENGINE.replay_stream("arc", trace, 24, sizes=sizes, chunk=777)
    it = ENGINE.replay_stream(
        "arc", (Request.of(trace[lo:lo + 777], sizes=sizes[lo:lo + 777])
                for lo in range(0, 5000, 777)), 24)
    for field in dense.metrics._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(it.metrics, field)),
            np.asarray(getattr(dense.metrics, field)), err_msg=field)


def test_replay_stream_accepts_ingest_chunks_directly():
    """The advertised pairing — replay_stream over iter_chunks output —
    unwraps TraceChunk records (keys AND sizes/costs) instead of
    stacking the three columns into a bogus [3, T] key batch."""
    from repro.data import ingest
    path = str(CORPUS / "kv.csv.gz")
    tr = ingest.load_trace(path)
    full = ENGINE.replay("lru", Request.of(tr.keys, sizes=tr.sizes,
                                           costs=tr.costs), 49,
                         collect_info=False)
    got = ENGINE.replay_stream("lru", ingest.iter_chunks(path, chunk=777),
                               49)
    assert int(got.metrics.requests) == len(tr.keys)      # one lane, not 3
    assert int(got.metrics.hits) == int(full.metrics.hits)
    np.testing.assert_allclose(np.asarray(got.metrics.bytes_missed),
                               np.asarray(full.metrics.bytes_missed),
                               rtol=1e-6)
    # a plain (keys, sizes, costs) tuple unwraps the same way
    plain = ENGINE.replay_stream(
        "lru", iter([(tr.keys, tr.sizes, tr.costs)]), 49)
    assert int(plain.metrics.requests) == len(tr.keys)
    assert int(plain.metrics.hits) == int(full.metrics.hits)


def test_replay_stream_iterator_contract():
    trace = zipf_trace(N=64, T=400, alpha=1.0, seed=2)
    empty = ENGINE.replay_stream("lru", iter(()), 8)
    assert int(empty.metrics.requests) == 0 and empty.obs is None
    with pytest.raises(ValueError, match="inside each chunk"):
        ENGINE.replay_stream("lru", iter((Request.of(trace),)), 8, sizes=2)
    with pytest.raises(ValueError, match="owns its chunking"):
        ENGINE.replay_stream("lru", iter((Request.of(trace),)), 8,
                             chunk=128)
    with pytest.raises(ValueError, match="lane shape"):
        ENGINE.replay_stream(
            "lru", iter((Request.of(trace),
                         Request.of(np.stack([trace, trace])))), 8)


# --- streaming path selection ----------------------------------------------

def test_should_stream_rules():
    syn = Scenario("z", trace="zipf(N=64,alpha=1.0)", T=50, K=(8,))
    real = Scenario("r", trace=f"file(path={CORPUS / 'scan.keys.txt'})",
                    T=1000)
    assert not should_stream(syn)
    assert should_stream(syn, True) and not should_stream(real, False)
    assert should_stream(syn, threshold=10)    # T beyond the threshold
    assert should_stream(real)                 # file-backed always streams
    # strings other than "auto" are an error, not a truthy surprise
    for bad in ("false", "no", "Auto", 1):
        with pytest.raises(ValueError, match="stream must be"):
            should_stream(syn, bad)
    # a bad chunk errors instead of emitting zero-request "perfect" cells
    for bad_chunk in (0, -7):
        with pytest.raises(ValueError, match="chunk"):
            list(stream_chunks(syn, seeds=(0,), chunk=bad_chunk))


def test_stream_chunks_match_materialized_requests():
    """The streamed chunks concatenate to exactly the materialized batch
    — keys, sizes and costs — for synthetic and file-backed scenarios."""
    from repro.bench import materialize
    for sc in (Scenario("syn", trace="zipf(N=128,alpha=1.0)", T=500,
                        K=(8,), size_model="lognormal", cost_model="fetch"),
               Scenario("real", trace=f"file(path={CORPUS / 'kv.csv.gz'})",
                        T=900)):
        whole = materialize(sc, seeds=(0, 1))
        parts = list(stream_chunks(sc, seeds=(0, 1), chunk=256))
        for field in ("key", "size", "cost"):
            np.testing.assert_array_equal(
                np.concatenate([np.asarray(getattr(c, field))
                                for c in parts], axis=-1),
                np.asarray(getattr(whole, field)),
                err_msg=f"{sc.name}.{field}")


# --- sweep-level bit-parity (the satellite guarantee) ----------------------

def _parity_sweep():
    # corpus sizes < 256 B and dyadic costs: every float32 running total
    # stays exact, so the two paths' records must match *bitwise*
    return Sweep(
        "stream_parity",
        policies=("lru", "dac"),
        scenarios=(
            Scenario("syn", trace="zipf(N=256,alpha=1.0)", T=2000,
                     K=("S", 16)),
            Scenario("real",
                     trace=f"file(path={CORPUS / 'mix.oracleGeneral.bin.gz'})",
                     T=5000, K=("L",)),
        ),
        seeds=(0, 1), observe=True)


def _strip_wall(record):
    return {k: v for k, v in record.items() if k != "wall_s"}


@pytest.mark.parametrize("use_pallas", [False, True])
def test_sweep_records_identical_across_paths(use_pallas):
    sweep = _parity_sweep()
    mat = run_sweep(sweep, stream=False, use_pallas=use_pallas)
    stm = run_sweep(sweep, stream=True, use_pallas=use_pallas)
    assert len(mat.records) == len(stm.records) == 6
    for a, b in zip(mat.records, stm.records):
        assert _strip_wall(a) == _strip_wall(b), (a["policy"], a["scenario"])
    # adaptive cells carry avg_k on both paths, from real per-step obs
    for res in (mat, stm):
        avg_k = res.metric("avg_k", policy="dac", scenario="real")
        assert avg_k.shape == (2,) and (avg_k > 0).all()


def test_sweep_payloads_identical_and_valid(tmp_path):
    """The full serialized payloads (v2, as benchmarks/real_traces.py
    emits) agree modulo wall-time and creation provenance."""
    sweep = _parity_sweep()
    pa = run_sweep(sweep, stream=False).payload(schema=results.SCHEMA_V2)
    pb = run_sweep(sweep, stream=True).payload(schema=results.SCHEMA_V2)
    for p in (pa, pb):
        results.validate(p)
        assert p["schema"] == results.SCHEMA_V2
    assert [_strip_wall(r) for r in pa["records"]] == \
        [_strip_wall(r) for r in pb["records"]]
    assert pa["config"] == pb["config"]
    results.save(pb, results_dir=str(tmp_path))
    assert results.load(str(tmp_path / "stream_parity.json"))["records"]


def test_auto_stream_is_default_and_equivalent():
    """stream="auto" streams the file-backed scenario and materializes
    the small synthetic one — with records identical to both forced
    paths."""
    sweep = _parity_sweep()
    auto = run_sweep(sweep)                     # default stream="auto"
    forced = run_sweep(sweep, stream=True)
    for a, b in zip(auto.records, forced.records):
        assert _strip_wall(a) == _strip_wall(b)
