"""Distributed behaviors that need >1 device: run in subprocesses with
--xla_force_host_platform_device_count (the main pytest process keeps the
default 1-device view, per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_lowers_and_runs():
    """Tiny model on a (2 data x 2 model) mesh: one real sharded train step
    executes; loss finite; params stay sharded."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import SMOKE_ARCHS
        from repro.launch.mesh import make_test_mesh, shard_ctx
        from repro.models import init_params, shardings
        from repro.optim import AdamWConfig, adamw
        from repro.train import make_train_step

        cfg = SMOKE_ARCHS["mixtral-8x22b"]
        mesh = make_test_mesh(data=2, model=2)
        sctx = shard_ctx(mesh)
        params = init_params(cfg, jax.random.PRNGKey(0))
        sh = shardings(params, cfg, sctx)
        params = jax.tree.map(jax.device_put, params, sh)
        ocfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=0)
        opt = adamw.init(params, ocfg)
        step = jax.jit(make_train_step(cfg, ocfg, sctx=sctx,
                                       n_microbatches=2))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        params, opt, m = step(params, opt, batch)
        assert bool(jnp.isfinite(m["loss"])), m
        print("LOSS", float(m["loss"]))
    """, n_devices=4)
    assert "LOSS" in out


def test_compressed_grad_sync_tracks_uncompressed():
    """Pure pod mesh (2 devices): the int8+EF compressed cross-pod train
    step tracks the uncompressed step to ~1e-4 over 8 steps.

    NOTE: the partial-manual form (pod manual + data/model auto inside one
    shard_map) currently crashes XLA:CPU's SPMD partitioner
    (spmd_partitioner_util.cc check on collective device groups) — a
    toolchain limitation recorded in EXPERIMENTS.md §Fault-tolerance; the
    compression numerics and int8 wire format are exactly those of the
    multi-pod deployment."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import SMOKE_ARCHS
        from repro.models import init_params
        from repro.optim import AdamWConfig, adamw
        from repro.train import (init_ef_state, make_compressed_train_step,
                                 make_train_step)
        from repro.data.tokens import TokenPipeline

        cfg = SMOKE_ARCHS["deepseek-7b"]
        mesh = jax.make_mesh((2,), ("pod",), devices=jax.devices()[:2])
        params = init_params(cfg, jax.random.PRNGKey(0))
        ocfg = AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=0,
                           weight_decay=0.0)
        pipe = TokenPipeline(cfg.vocab, 8, 32)

        plain = jax.jit(make_train_step(cfg, ocfg))
        comp = jax.jit(make_compressed_train_step(cfg, ocfg, mesh))
        p1, o1 = params, adamw.init(params, ocfg)
        p2, o2 = params, adamw.init(params, ocfg)
        ef = init_ef_state(params, 2)
        for t in range(8):
            b = {k: jnp.asarray(v) for k, v in pipe.batch(t).items()}
            p1, o1, m1 = plain(p1, o1, b)
            p2, o2, ef, m2 = comp(p2, o2, ef, b)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        print("PLAIN", float(m1["loss"]), "COMP", float(m2["loss"]),
              "DIFF", d)
        assert float(m2["loss"]) < 7.0
        assert d < 0.01, (float(m1["loss"]), float(m2["loss"]))
    """, n_devices=8)
    assert "PLAIN" in out


def test_elastic_restart_8_to_4_devices():
    """Checkpoint on an 8-device (4 data x 2 model) mesh, restore + continue
    on a 4-device (2 x 2) mesh; loss keeps decreasing."""
    ckpt = "/tmp/repro_elastic_test"
    run_py(f"""
        import shutil, jax, jax.numpy as jnp
        shutil.rmtree({ckpt!r}, ignore_errors=True)
        from repro.configs import SMOKE_ARCHS
        from repro.launch.mesh import make_test_mesh, shard_ctx
        from repro.optim import AdamWConfig
        from repro.train import TrainConfig, Trainer

        cfg = SMOKE_ARCHS["deepseek-7b"]
        sctx = shard_ctx(make_test_mesh(data=4, model=2))
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=24)
        tc = TrainConfig(steps=24, ckpt_dir={ckpt!r}, ckpt_every=8,
                         global_batch=8, seq_len=32, async_ckpt=False)
        tr = Trainer(cfg, opt, tc, sctx=sctx)
        tr.run(steps=12)
        print("PHASE1", tr.history[-1]["loss"])
    """, n_devices=8)
    out = run_py(f"""
        import jax, jax.numpy as jnp
        from repro.configs import SMOKE_ARCHS
        from repro.launch.mesh import make_test_mesh, shard_ctx
        from repro.optim import AdamWConfig
        from repro.train import TrainConfig, Trainer

        cfg = SMOKE_ARCHS["deepseek-7b"]
        sctx = shard_ctx(make_test_mesh(data=2, model=2))   # half the fleet
        opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=24)
        tc = TrainConfig(steps=24, ckpt_dir={ckpt!r}, ckpt_every=8,
                         global_batch=8, seq_len=32, async_ckpt=False)
        tr = Trainer(cfg, opt, tc, sctx=sctx)
        tr.run()
        assert tr.history[0]["step"] == 12, tr.history[0]
        import numpy as np
        head = np.mean([h["loss"] for h in tr.history[:3]])
        tail = np.mean([h["loss"] for h in tr.history[-3:]])
        print("RESUMED", head, "END", tail)
        assert tail < head + 0.05, (head, tail)
    """, n_devices=4)
    assert "RESUMED" in out


def test_serve_decode_sharded():
    """Sharded bounded-KV decode on a (2, 2) mesh."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import SMOKE_ARCHS
        from repro.launch.mesh import make_test_mesh, shard_ctx
        from repro.models import init_params, shardings
        from repro.serving import init_serve_state
        from repro.serving.serve_step import decode_step, \\
            serve_state_shardings
        cfg = SMOKE_ARCHS["deepseek-7b"]
        mesh = make_test_mesh(data=2, model=2)
        sctx = shard_ctx(mesh)
        params = init_params(cfg, jax.random.PRNGKey(0))
        params = jax.tree.map(jax.device_put, params,
                              shardings(params, cfg, sctx))
        state = init_serve_state(cfg, 4, max_len=64, budget=32)
        state = jax.tree.map(jax.device_put, state,
                             serve_state_shardings(cfg, sctx, state))
        step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t,
                                                   sctx=sctx))
        tok = jnp.zeros((4,), jnp.int32)
        for _ in range(6):
            state, logits = step(params, state, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert bool(jnp.isfinite(logits).all())
        print("DECODE_OK")
    """, n_devices=4)
    assert "DECODE_OK" in out
