"""Sweep API: the seeds-vmapped grid runner vs a per-seed Python loop
(bit-identical, both Pallas settings), Scenario/Sweep config round-trips,
and the canonical result schema's validation contract."""
import numpy as np
import pytest

from repro.bench import (Scenario, Sweep, materialize, report, results,
                         run_sweep)
from repro.core import Engine
from repro.core.policy import Request
from repro.data.traces import make_trace

ENGINE = Engine()

SEEDS = (0, 1, 2)


def _scenario(**kw):
    base = dict(name="cell", trace="zipf(N=256,alpha=1.0)", T=2000,
                K=(16,))
    base.update(kw)
    return Scenario(**base)


# --- the satellite guarantee: vmapped seeds == per-seed loop ---------------

@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("policy", ["dac", "lru"])
def test_vmapped_cell_bit_identical_to_seed_loop(policy, use_pallas):
    """One vmapped [S, T] replay of a grid cell produces exactly the
    per-lane Metrics of S independent single-trace replays — for the jnp
    and the fused-Pallas lowerings alike."""
    sc = _scenario()
    K = sc.capacities()[0]
    reqs = materialize(sc, SEEDS)
    batched = ENGINE.replay(policy, reqs, K, collect_info=False,
                            use_pallas=use_pallas)
    spec = make_trace(sc.trace)
    for i, seed in enumerate(SEEDS):
        single = ENGINE.replay(policy, spec.generate(sc.T, seed=seed), K,
                               collect_info=False, use_pallas=use_pallas)
        for field in batched.metrics._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(batched.metrics, field))[i],
                np.asarray(getattr(single.metrics, field)),
                err_msg=f"{policy} seed={seed} {field} "
                        f"(use_pallas={use_pallas})")


@pytest.mark.parametrize("use_pallas", [False, True])
def test_runner_records_match_seed_loop(use_pallas):
    """run_sweep's per-seed metric lists equal the looped single-lane
    ratios, including size/cost-weighted ones."""
    sc = _scenario(size_model="lognormal(median_kb=4,sigma=1.0)",
                   cost_model="fetch")
    sweep = Sweep("loop_eq", policies=("dac",), scenarios=(sc,),
                  seeds=SEEDS)
    res = run_sweep(sweep, use_pallas=use_pallas)
    (rec,) = res.records
    K = sc.capacities()[0]
    spec = make_trace(sc.trace)
    sizes = sc.size_table()
    costs = sc.cost_table(sizes)
    for i, seed in enumerate(SEEDS):
        keys = spec.generate(sc.T, seed=seed)
        single = ENGINE.replay(
            "dac", Request.of(keys, sizes=sizes[keys], costs=costs[keys]),
            K, collect_info=False, use_pallas=use_pallas)
        assert rec["metrics"]["miss_ratio"][i] == single.miss_ratio
        assert rec["metrics"]["byte_miss_ratio"][i] == single.byte_miss_ratio
        assert rec["metrics"]["penalty_ratio"][i] == single.penalty_ratio


def test_observe_collects_avg_k():
    sweep = Sweep("obs", policies=("dac",), scenarios=(_scenario(),),
                  seeds=SEEDS, observe=True)
    res = run_sweep(sweep)
    avg_k = res.metric("avg_k", policy="dac")
    assert avg_k.shape == (len(SEEDS),)
    assert (avg_k > 0).all()


# --- Scenario / Sweep ------------------------------------------------------

def test_capacity_regimes_resolve_against_footprint():
    sc = _scenario(K=("S", "L", 33))
    # zipf N=256: S = max(4, 0.1% of 256) = 4, L = 10% = 25
    assert sc.capacities() == (4, 25, 33)
    assert [sc.k_label(k) for k in sc.K] == ["S", "L", "33"]
    scan = _scenario(trace="scan_mix(N=256,alpha=1.0,scan_frac=0.2,"
                     "scan_len=32)", K=("L",))
    assert scan.capacities() == (51,)   # 10% of the 2N id footprint
    with pytest.raises(ValueError, match="regime"):
        _scenario(K=("M",)).capacities()


def test_scenario_validates_eagerly():
    with pytest.raises(ValueError, match="unknown trace family"):
        _scenario(trace="nope(N=3)")
    with pytest.raises(ValueError, match="cost_model requires"):
        _scenario(cost_model="fetch")
    with pytest.raises(ValueError, match="unknown size model"):
        _scenario(size_model="gaussian")
    with pytest.raises(ValueError, match="unknown parameter"):
        _scenario(size_model="lognormal(mu=3)")
    with pytest.raises(ValueError, match="unknown parameter"):
        _scenario(size_model="lognormal", cost_model="fetch(typo=1)")
    with pytest.raises(ValueError, match="unknown cost model"):
        _scenario(size_model="lognormal", cost_model="quadratic")


def test_sweep_rejects_duplicate_scenario_names():
    with pytest.raises(ValueError, match="unique"):
        Sweep("x", policies=("lru",),
              scenarios=(_scenario(), _scenario(K=(8,))))


def test_sweep_config_roundtrip():
    sweep = Sweep("rt", policies=("lru", "dac(eps=0.5)"),
                  scenarios=(_scenario(K=("S", 8)),
                             _scenario(name="sized",
                                       size_model="lognormal")),
                  seeds=(3, 4), observe=True)
    assert Sweep.from_config(sweep.to_config()) == sweep


def test_sweep_rejects_empty_axes():
    with pytest.raises(ValueError):
        Sweep("x", policies=(), scenarios=(_scenario(),))
    with pytest.raises(ValueError):
        Sweep("x", policies=("lru",), scenarios=())
    with pytest.raises(ValueError):
        Sweep("x", policies=("lru",), scenarios=(_scenario(),), seeds=())


def test_materialize_shapes_and_models():
    sc = _scenario(size_model="lognormal", cost_model="fetch")
    reqs = materialize(sc, SEEDS)
    assert reqs.key.shape == (len(SEEDS), sc.T)
    assert reqs.size.shape == reqs.key.shape
    sizes = sc.size_table()
    np.testing.assert_array_equal(np.asarray(reqs.size)[0],
                                  sizes[np.asarray(reqs.key)[0]])


# --- canonical results schema ----------------------------------------------

def _payload():
    sweep = Sweep("schema", policies=("fifo", "lru"),
                  scenarios=(_scenario(),), seeds=SEEDS)
    return run_sweep(sweep).payload(extras={"note": "test"})


def test_payload_validates_and_roundtrips(tmp_path):
    p = _payload()
    results.validate(p)
    assert p["schema"] == results.SCHEMA_VERSION
    for key in ("git_sha", "jax", "x64", "backend", "device_count"):
        assert key in p["provenance"]
    path = results.save(p, results_dir=str(tmp_path))
    q = results.load(path)
    assert q["bench"] == "schema"
    assert len(q["records"]) == 2
    # the embedded config reconstructs the sweep that produced the file
    assert Sweep.from_config(q["config"]).cells


@pytest.mark.parametrize("mutate, match", [
    (lambda p: p.pop("provenance"), "provenance"),
    (lambda p: p.update(schema="v0"), "schema"),
    (lambda p: p["records"][0].pop("metrics"), "metrics"),
    (lambda p: p["records"][0]["metrics"].update(bad="x"), "number"),
    (lambda p: p["records"][0]["metrics"]["miss_ratio"].append(0.5),
     "len\\(seeds\\)"),
    (lambda p: p["provenance"].pop("git_sha"), "git_sha"),
    (lambda p: p["records"][0].update(K="big"), "K"),
])
def test_validation_rejects_malformed_payloads(mutate, match):
    p = _payload()
    mutate(p)
    with pytest.raises(ValueError, match=match):
        results.validate(p)


def test_save_refuses_invalid(tmp_path):
    p = _payload()
    del p["records"][0]["metrics"]
    with pytest.raises(ValueError):
        results.save(p, results_dir=str(tmp_path))
    assert not list(tmp_path.iterdir())


# --- reporting -------------------------------------------------------------

def test_mrr_matrix_and_winners():
    sweep = Sweep("rep", policies=("fifo", "lru", "dac"),
                  scenarios=(_scenario(K=("S", 16)),), seeds=SEEDS)
    res = run_sweep(sweep)
    table = report.mrr_matrix(res.records, ["fifo", "lru", "dac"])
    assert set(table) == {"cell(S)", "cell(16)"}
    for col in table.values():
        assert col["fifo"] == 0.0          # baseline vs itself
        assert all(-1.0 <= v <= 1.0 for v in col.values())
    wins = report.winners(res.records, ["fifo", "lru", "dac"])
    for col in wins.values():
        assert abs(sum(col.values()) - 1.0) < 1e-9
