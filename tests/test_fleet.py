"""Fleet subsystem invariants: conservation under churn, lifecycle slot
return, auction determinism and its proportional degeneration, sharded
replay, and the serve-path cap wiring."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.bench import FleetScenario, FleetSweep, Scenario, TierScenario
from repro.core import Engine
from repro.data.traces import fleet_trace, make_trace
from repro.fleet import (FleetTier, jain_index, penalty_quantile,
                         replay_fleet)
from repro.tier import AuctionArbiter, ProportionalArbiter

from test_distributed import run_py


def _trace(T=3000, n_lanes=8, seed=0, **kw):
    kw.setdefault("rate", 0.02)
    kw.setdefault("mean_session", 500)
    kw.setdefault("lo", 8)
    return fleet_trace(N=128, T=T, n_lanes=n_lanes, seed=seed, **kw)


# ---------------------------------------------------------------------------
# conservation + lifecycle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arbiter", ["auction", "greedy", "proportional"])
def test_conservation_under_churn(arbiter):
    """sum(k) never exceeds the budget at any step, through arrivals,
    departures, growth and shrink."""
    keys = _trace()
    fl = FleetTier("dac(k_min=4)", n_lanes=8, budget=96, arbiter=arbiter)
    res = replay_fleet(fl, keys, observe=True)
    ks = np.asarray(res.obs["k"])
    assert ks.sum(axis=1).max() <= 96
    # every alive lane floors at k_min
    alive = np.asarray(res.obs["alive"])
    assert ks[alive].min() >= 4


def test_departed_lane_returns_slots():
    """A departed tenant's lane drops to k = 0 (its capacity is back in
    the pool), and the lane serves nothing while idle."""
    keys = _trace()
    fl = FleetTier("dac(k_min=4)", n_lanes=8, budget=96)
    res = replay_fleet(fl, keys, observe=True)
    ks = np.asarray(res.obs["k"])
    alive = np.asarray(res.obs["alive"])
    assert (ks[~alive] == 0).all()
    # the trace actually exercises churn (sessions ended mid-stream)
    departs = (~alive[1:] & alive[:-1]).sum()
    assert departs > 0
    # requests count only served steps
    assert np.asarray(res.metrics.requests).sum() == alive.sum()


def test_freed_capacity_is_regranted():
    """After a mass departure the survivors can grow into the freed
    capacity: one lane alone with the whole pool exceeds its even
    share."""
    n, budget, T = 4, 64, 4000
    keys = np.full((T, n), -1, np.int32)
    rng = np.random.default_rng(0)
    wide = rng.integers(0, 128, size=T).astype(np.int32)
    # all four lanes busy for the first quarter, then only lane 0
    keys[: T // 4] = wide[: T // 4, None]
    keys[T // 4:, 0] = wide[T // 4:]
    fl = FleetTier("dac(k_min=4)", n_lanes=n, budget=budget,
                   arbiter="auction")
    res = replay_fleet(fl, keys, observe=True)
    ks = np.asarray(res.obs["k"])
    assert ks.sum(axis=1).max() <= budget
    assert ks[-1, 0] > budget // n          # grew past the even split
    assert (ks[-1, 1:] == 0).all()


def test_fleet_deterministic():
    """Two replays of the same stream are bit-identical (auction included:
    pricing is pure arithmetic on the carry)."""
    keys = _trace(T=2000)
    fl = FleetTier("dac(k_min=4)", n_lanes=8, budget=96, arbiter="auction")
    a = replay_fleet(fl, keys, observe=True)
    b = replay_fleet(fl, keys, observe=True)
    assert np.array_equal(np.asarray(a.obs["k"]), np.asarray(b.obs["k"]))
    for x, y in zip(a.metrics, b.metrics):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert np.array_equal(np.asarray(a.hist), np.asarray(b.hist))


def test_auction_uniform_utility_matches_proportional():
    """With no utility signal the auction degenerates to the proportional
    split, bit-exactly (uniform weights, same floor arithmetic)."""
    auction, prop = AuctionArbiter(), ProportionalArbiter()
    rng = np.random.default_rng(1)
    for _ in range(50):
        n = int(rng.integers(2, 9))
        k = jnp.asarray(rng.integers(0, 32, n), jnp.int32)
        demanding = jnp.asarray(rng.integers(0, 2, n).astype(bool))
        budget = int(rng.integers(int(k.sum()), int(k.sum()) + 64))
        got = auction(k, demanding, budget, n)
        want = prop(k, demanding, budget, n)
        assert np.array_equal(np.asarray(got), np.asarray(want))


def test_auction_prices_by_utility():
    """Higher-utility demanders get the larger grant; total grants stay
    within the free pool."""
    k = jnp.asarray([4, 4, 4, 4], jnp.int32)
    demanding = jnp.asarray([True, True, True, False])
    caps = np.asarray(AuctionArbiter()(
        k, demanding, 28, 4, utility=jnp.asarray([9.0, 3.0, 0.0, 5.0])))
    assert caps[0] - 4 >= caps[1] - 4 >= caps[2] - 4
    assert caps[3] == 4                     # not demanding: no grant
    assert (caps - 4).sum() <= 28 - 12      # grants <= free pool


# ---------------------------------------------------------------------------
# replay surfaces
# ---------------------------------------------------------------------------

def test_batched_seed_axis_matches_single():
    keys = np.stack([_trace(T=1000, seed=s) for s in (0, 1)])
    fl = FleetTier("dac(k_min=4)", n_lanes=8, budget=96)
    batched = replay_fleet(fl, keys)
    for s in range(2):
        single = replay_fleet(fl, keys[s])
        for bx, sx in zip(batched.metrics, single.metrics):
            assert np.array_equal(np.asarray(bx)[s], np.asarray(sx))
        assert np.array_equal(np.asarray(batched.hist)[s],
                              np.asarray(single.hist))


def test_non_resizable_requires_static_and_holds_share():
    with pytest.raises(ValueError, match="static"):
        FleetTier("lru", n_lanes=4, budget=64, arbiter="greedy")
    keys = _trace(n_lanes=4)
    fl = FleetTier("lru", n_lanes=4, budget=64, arbiter="static")
    res = replay_fleet(fl, keys, observe=True)
    ks = np.asarray(res.obs["k"])
    alive = np.asarray(res.obs["alive"])
    assert (ks[alive] == 16).all() and (ks[~alive] == 0).all()


def test_fleet_tier_validation():
    with pytest.raises(ValueError, match="k_min"):
        FleetTier("dac(k_min=16)", n_lanes=8, budget=64)   # share < k_min
    with pytest.raises(ValueError, match="n_lanes"):
        FleetTier("dac", n_lanes=0, budget=64)
    with pytest.raises(TypeError, match="FleetTier"):
        Engine().replay_fleet("dac", _trace())
    with pytest.raises(ValueError, match="n_lanes"):
        replay_fleet(FleetTier("dac(k_min=4)", n_lanes=4, budget=64),
                     _trace(n_lanes=8))


def test_scenario_family_routing():
    """Fleet traces are rejected by the single-cache and tier scenario
    types and accepted by FleetScenario; and vice versa."""
    with pytest.raises(ValueError, match="FleetScenario"):
        Scenario("x", trace="fleet(N=64,n_lanes=2)", T=100)
    with pytest.raises(ValueError, match="multi-tenant"):
        TierScenario("x", trace="fleet(N=64,n_lanes=2)", T=100)
    with pytest.raises(ValueError, match="dynamic-fleet"):
        FleetScenario("x", trace="zipf(N=64,alpha=1.0)", T=100)
    sc = FleetScenario("x", trace="fleet(N=64,n_lanes=2)", T=100)
    assert sc.n_lanes == 2
    assert FleetScenario.from_config(sc.to_config()) == sc
    sw = FleetSweep("w", entries=(("dac", "auction"),), scenarios=(sc,))
    assert FleetSweep.from_config(sw.to_config()) == sw


def test_fleet_trace_has_dead_gap_between_sessions():
    """The generator guarantees >= 1 idle step between a lane's sessions,
    so alive-mask edges always mark real arrivals/departures."""
    keys = _trace(T=5000, rate=0.05, mean_session=200)
    for lane in range(keys.shape[1]):
        col = keys[:, lane]
        # a departure step is idle; the next session starts strictly later
        starts = np.flatnonzero((col[1:] >= 0) & (col[:-1] < 0)) + 1
        ends = np.flatnonzero((col[1:] < 0) & (col[:-1] >= 0)) + 1
        for e in ends:
            nxt = starts[starts >= e]
            if nxt.size:
                assert nxt[0] > e


def test_telemetry_quantiles_and_jain():
    hist = np.zeros((32,))
    hist[0] = 98
    hist[10] = 2
    assert penalty_quantile(hist, 0.5) == 0.0
    assert penalty_quantile(hist, 0.99) == pytest.approx(2.0 ** 6)
    assert jain_index(np.array([3.0, 3.0, 3.0])) == pytest.approx(1.0)
    assert jain_index(np.array([6.0, 0.0, 0.0])) == pytest.approx(1 / 3)
    # mask: lanes that never hosted a tenant don't dilute the index
    assert jain_index(np.array([5.0, 5.0, 0.0]),
                      mask=np.array([True, True, False])) == pytest.approx(1.0)


def test_fleet_histogram_counts_served_steps():
    keys = _trace(T=1500)
    fl = FleetTier("dac(k_min=4)", n_lanes=8, budget=96)
    res = replay_fleet(fl, keys, observe=True)
    alive = np.asarray(res.obs["alive"])
    assert np.asarray(res.hist).sum() == alive.sum()


# ---------------------------------------------------------------------------
# serve-path cap wiring
# ---------------------------------------------------------------------------

def test_kv_cache_resize_respects_caps():
    """serve-side: a [B] cap vector gates each sequence's doubling."""
    from repro.serving import kv_cache as kvc
    B, Bmax = 3, 64
    ctrl = kvc.control_init(B, Bmax, k0=8)
    # drive pure misses until every lane's jump saturates at 2k
    for pos in range(16):
        ctrl, _ = kvc.insert(ctrl, jnp.full((B,), pos, jnp.int32))
        ctrl = kvc.resize(ctrl, k_min=4,
                          cap=jnp.asarray([8, 12, 64], jnp.int32))
    k = np.asarray(ctrl["k_active"])
    assert k[0] == 8                  # cap == k: the doubling is denied
    assert k[1] == 12                 # partial grant: grows to the cap
    assert k[2] == 16                 # full headroom: the doubling lands


# ---------------------------------------------------------------------------
# sharded replay (subprocess: forced multi-device CPU)
# ---------------------------------------------------------------------------

def test_sharded_fleet_conserves_and_rebalances():
    """4-shard mesh over 8 lanes: conservation holds under the psum
    budget re-deal, outputs gather to full-fleet shapes, and the sharded
    aggregate tracks the unsharded replay."""
    out = run_py("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.data.traces import fleet_trace
        from repro.fleet import FleetTier, replay_fleet

        keys = fleet_trace(N=128, T=2500, n_lanes=8, rate=0.02,
                           mean_session=500, lo=8, seed=0)
        mesh = Mesh(np.array(jax.devices()), ("data",))
        fl = FleetTier("dac(k_min=4)", n_lanes=8, budget=96,
                       arbiter="auction")
        res = replay_fleet(fl, keys, observe=True, mesh=mesh,
                           rebalance=200)
        ks = np.asarray(res.obs["k"])
        assert ks.shape == (2500, 8) and np.asarray(res.hist).shape == (8, 32)
        assert ks.sum(axis=1).max() <= 96, ks.sum(axis=1).max()
        ref = replay_fleet(fl, keys)
        agg = lambda r: (np.asarray(r.metrics.bytes_missed).sum()
                         / np.asarray(r.metrics.bytes_total).sum())
        d = abs(agg(res) - agg(ref))
        assert d < 0.05, (agg(res), agg(ref))
        # per-shard budget guard
        try:
            replay_fleet(FleetTier("dac(k_min=16)", n_lanes=8, budget=128),
                         keys, mesh=mesh)
        except ValueError as e:
            assert "per-shard" in str(e)
        print("SHARDED_OK", ks.sum(axis=1).max())
    """, n_devices=4)
    assert "SHARDED_OK" in out


def test_fleet_matches_tier_on_always_alive_stream():
    """A fleet stream with every lane alive at every step is exactly the
    tier's regime: both replays see the same per-lane miss counts when
    arbitration never binds (budget ample, static arbiter)."""
    from repro.data.traces import tenants_trace
    from repro.tier import CacheTier, replay_tier
    keys = tenants_trace(N=64, T=1500, n_tenants=4, lo=8, seed=2)
    budget = 128
    ft = FleetTier("dac(k_min=4)", n_lanes=4, budget=budget,
                   arbiter="static", k0=budget // 4)
    tt = CacheTier("dac(k_min=4)", n_tenants=4, budget=budget,
                   arbiter="static", k0=budget // 4)
    fres = replay_fleet(ft, keys)
    tres = replay_tier(tt, keys)
    assert np.array_equal(np.asarray(fres.metrics.hits),
                          np.asarray(tres.metrics.hits))
    assert np.array_equal(np.asarray(fres.metrics.requests),
                          np.asarray(tres.metrics.requests))
