"""Tests for the Request/StepInfo contract, the unified Engine, the
make_policy spec parser, and the mrr metric guards.  Hypothesis-free so the
whole file runs in minimal environments."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EMPTY, Engine, POLICIES, Request, StepInfo,
                        DynamicAdaptiveClimb, make_policy, mrr)
from repro.data.traces import object_sizes, zipf_trace

ENGINE = Engine()


# --- Request / StepInfo ------------------------------------------------------

def test_request_defaults_unit_size_and_cost():
    keys = np.array([3, 1, 2], np.int64)
    req = Request.of(keys)
    assert req.key.dtype == jnp.int32
    assert req.size.dtype == jnp.int32 and (np.asarray(req.size) == 1).all()
    assert req.cost.dtype == jnp.float32 and (np.asarray(req.cost) == 1.0).all()
    assert req.key.shape == req.size.shape == req.cost.shape == (3,)


def test_request_broadcasts_scalars_and_arrays():
    keys = np.arange(5, dtype=np.int32)
    req = Request.of(keys, sizes=7, costs=np.arange(5) * 0.5)
    assert (np.asarray(req.size) == 7).all()
    np.testing.assert_allclose(np.asarray(req.cost), np.arange(5) * 0.5)
    # an existing Request passes through untouched
    assert Request.of(req) is req
    with pytest.raises(ValueError):
        Request.of(req, sizes=3)
    # int32-wrapping sizes are rejected, not silently corrupted —
    # whether they arrive as numpy, python scalars, or device arrays
    with pytest.raises(ValueError, match="int32"):
        Request.of(keys, sizes=np.int64(3) << 30)
    with pytest.raises(ValueError, match="int32"):
        Request.of(keys, sizes=3.0e9)
    with pytest.raises(ValueError, match="int32"):
        Request.of(keys, sizes=jnp.full((5,), 3.0e9))


def test_step_info_charges_size_and_cost_on_miss_only():
    pol = make_policy("lru")
    state = pol.init(4)
    step = jax.jit(pol.step)
    state, miss = step(state, Request.of(jnp.int32(9), sizes=100, costs=2.5))
    assert isinstance(miss, StepInfo)
    assert not bool(miss.hit)
    assert int(miss.bytes_missed) == 100
    assert float(miss.penalty) == 2.5
    state, hit = step(state, Request.of(jnp.int32(9), sizes=100, costs=2.5))
    assert bool(hit.hit)
    assert int(hit.bytes_missed) == 0
    assert float(hit.penalty) == 0.0
    assert int(hit.evicted_key) == int(EMPTY)


# --- evicted_key semantics ---------------------------------------------------

def _resident_set(name, state):
    """Keys currently resident (occupying cache capacity) for any policy."""
    if name == "twoq":
        arrs = [state["in_keys"], state["am_keys"]]
    elif name == "arc":
        arrs = [state["t1k"], state["t2k"]]
    elif name == "lirs":
        from repro.core.lirs_lhd import HIR, LIR
        st = np.asarray(state["state"])
        keys = np.asarray(state["keys"])
        return set(keys[(st == LIR) | (st == HIR)].tolist())
    else:
        for f in ("cache", "keys"):
            if f in state:
                arrs = [state[f]]
                break
    out = set()
    for a in arrs:
        out |= set(np.asarray(a).tolist())
    return out - {int(EMPTY)}


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_evicted_key_tracks_residency(name):
    """Per step: hits evict nothing; a reported eviction was resident before
    and (for non-resizing policies) is exactly the residency loss; nothing
    but the requested key enters."""
    K = 8
    trace = zipf_trace(N=40, T=400, alpha=0.8, seed=13)
    pol = POLICIES[name]()
    state = pol.init(K)
    step = jax.jit(pol.step)
    resizing = isinstance(pol, DynamicAdaptiveClimb)
    for k in trace:
        pre = _resident_set(name, state)
        state, info = step(state, Request.of(jnp.int32(int(k))))
        post = _resident_set(name, state)
        ev = int(info.evicted_key)
        if bool(info.hit):
            assert ev == int(EMPTY), (name, k)
            continue
        gained = post - pre
        assert gained <= {int(k)}, (name, k, gained)
        if ev != int(EMPTY):
            assert ev in pre, (name, k, ev)
            assert ev not in post, (name, k, ev)
        if not resizing:
            # exact conservation: what left residency is what was reported
            lost = pre - post
            assert lost == ({ev} - {int(EMPTY)}), (name, k, lost, ev)


# --- Engine ------------------------------------------------------------------

def test_engine_accepts_specs_and_bare_keys():
    trace = zipf_trace(N=64, T=2000, alpha=1.0, seed=0)
    res = ENGINE.replay("lru", trace, 16)
    assert res.info.hit.shape == (2000,)
    assert 0.0 < res.miss_ratio < 1.0
    # unit sizes/costs: all three ratios coincide
    assert res.byte_miss_ratio == pytest.approx(res.miss_ratio, abs=1e-6)
    assert res.penalty_ratio == pytest.approx(res.miss_ratio, abs=1e-6)
    # metrics agree with the per-step info they were reduced from
    hits = np.asarray(res.info.hit)
    assert int(res.metrics.hits) == hits.sum()
    assert int(res.metrics.requests) == 2000


def test_engine_byte_metrics_match_posthoc():
    trace = zipf_trace(N=64, T=2000, alpha=1.0, seed=1)
    sizes = object_sizes(64, seed=1)[trace]
    res = ENGINE.replay("arc", trace, 16, sizes=sizes)
    hits = np.asarray(res.info.hit)
    manual = ((~hits) * sizes).sum() / sizes.sum()
    assert res.byte_miss_ratio == pytest.approx(float(manual), rel=1e-5)


def test_engine_batched_matches_single():
    t0 = zipf_trace(N=64, T=1000, alpha=1.0, seed=2)
    t1 = zipf_trace(N=64, T=1000, alpha=0.7, seed=3)
    batched = ENGINE.replay("sieve", np.stack([t0, t1]), 16)
    assert batched.info.hit.shape == (2, 1000)
    for i, tr in enumerate((t0, t1)):
        single = ENGINE.replay("sieve", tr, 16)
        np.testing.assert_array_equal(np.asarray(batched.info.hit[i]),
                                      np.asarray(single.info.hit))
        assert batched.miss_ratio[i] == pytest.approx(single.miss_ratio)


def test_engine_observe_collects_dac_trajectory():
    trace = zipf_trace(N=512, T=3000, alpha=0.3, seed=4)
    res = ENGINE.replay("dac(growth=4)", trace, 16, observe=True)
    ks = np.asarray(res.obs["k"])
    assert ks.shape == (3000,)
    assert ks.max() <= 16 * 4 and ks.min() >= 2


def test_engine_rejects_bad_rank():
    with pytest.raises(ValueError):
        ENGINE.replay("lru", np.zeros((2, 3, 4), np.int32), 4)


# --- make_policy -------------------------------------------------------------

def test_make_policy_plain_and_aliases():
    assert type(make_policy("lru")) is POLICIES["lru"]
    assert type(make_policy("dac")) is POLICIES["dynamicadaptiveclimb"]
    assert type(make_policy("ac")) is POLICIES["adaptiveclimb"]
    assert type(make_policy("2q")) is POLICIES["twoq"]
    pol = make_policy("lru")
    assert make_policy(pol) is pol


def test_make_policy_kwargs():
    pol = make_policy("dac(eps=0.25, growth=2, k_min=4)")
    assert isinstance(pol, DynamicAdaptiveClimb)
    assert pol.eps == 0.25 and pol.growth == 2 and pol.k_min == 4
    pol2 = make_policy("tinylfu(rows=2)")
    assert pol2.rows == 2


def test_make_policy_errors():
    with pytest.raises(ValueError):
        make_policy("nosuchpolicy")
    with pytest.raises(ValueError):
        make_policy("lru(3)")  # positional args not allowed


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_make_policy_roundtrip_every_registry_entry(name):
    """Spec round-trip: serializing a policy's constructor state back into
    a spec string reproduces an equal policy (same class, same params)."""
    pol = POLICIES[name]()
    if pol.__dict__:
        args = ",".join(f"{k}={v}" for k, v in sorted(pol.__dict__.items()))
        spec = f"{name}({args})"
    else:
        spec = name
    pol2 = make_policy(spec)
    assert type(pol2) is type(pol)
    assert pol2 == pol and hash(pol2) == hash(pol)


def test_make_policy_coerces_numeric_types_to_signature():
    """Integer knobs accept "4" and "4.0" identically; float knobs accept
    ints — the parsed value always lands with the declared type."""
    a = make_policy("dac(growth=4)")
    b = make_policy("dac(growth=4.0)")
    assert a == b
    assert isinstance(b.growth, int) and b.growth == 4
    c = make_policy("dac(eps=1)")
    assert isinstance(c.eps, float) and c.eps == 1.0
    d = make_policy("lirs(ghost_factor=3.0, hir_frac=1)")
    assert isinstance(d.ghost_factor, int) and d.ghost_factor == 3
    assert isinstance(d.hir_frac, float) and d.hir_frac == 1.0
    with pytest.raises(ValueError, match="integer"):
        make_policy("dac(growth=4.5)")
    with pytest.raises(ValueError, match="unknown parameter"):
        make_policy("dac(jump=3)")


# --- mrr guards (satellite: explicit both-zero branch) -----------------------

def test_mrr_both_zero_is_zero():
    assert mrr(0.0, 0.0) == 0.0


def test_mrr_signed_branches():
    # improvement: normalized by FIFO's miss ratio
    assert mrr(0.2, 0.4) == pytest.approx(0.5)
    # regression: normalized by the algorithm's own miss ratio
    assert mrr(0.4, 0.2) == pytest.approx(-0.5)
    # degenerate one-sided zeros
    assert mrr(0.0, 0.5) == pytest.approx(1.0)
    assert mrr(0.5, 0.0) == pytest.approx(-1.0)
