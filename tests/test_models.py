"""Model-substrate unit tests: attention oracle sweep, mLSTM chunked vs
sequential, mamba chunked vs stepwise, MoE conservation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import SMOKE_ARCHS
from repro.models import ssm
from repro.models.layers import attention_dense, chunked_attention
from repro.models.moe import capacity, moe_apply, moe_init, route

KEY = jax.random.PRNGKey(11)


@pytest.mark.parametrize("H,Hkv,win,cap,cq,ck", [
    (8, 2, None, 0.0, 16, 8), (4, 4, 16, 0.0, 8, 8),
    (8, 4, None, 50.0, 32, 16), (6, 2, 24, 30.0, 16, 16),
    (8, 1, None, 0.0, 64, 64),
])
def test_chunked_attention_matches_dense(H, Hkv, win, cap, cq, ck):
    S = 64
    q = jax.random.normal(KEY, (2, S, H, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, Hkv, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, Hkv, 16))
    ref = attention_dense(q, k, v, window=win, softcap=cap)
    got = chunked_attention(q, k, v, window=win, softcap=cap,
                            chunk_q=cq, chunk_k=ck)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), chunk=st.sampled_from([4, 8, 16]))
def test_mlstm_chunked_equals_sequential(seed, chunk):
    B, S, H, dh = 2, 32, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    ip = jax.random.normal(ks[3], (B, S, H))
    fp = jax.random.normal(ks[4], (B, S, H)) + 2.0
    C0 = jnp.zeros((B, H, dh, dh))
    n0 = jnp.zeros((B, H, dh))
    m0 = jnp.zeros((B, H))
    h1, C1, nn1, m1 = ssm.mlstm_seq(q, k, v, ip, fp, C0, n0, m0)
    h2, C2, nn2, m2 = ssm.mlstm_cell_chunked(q, k, v, ip, fp, C0, n0, m0,
                                             chunk)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C2),
                               rtol=2e-4, atol=2e-4)


def test_mamba_chunked_equals_stepwise():
    cfg = SMOKE_ARCHS["jamba-1.5-large-398b"]
    p = ssm.mamba_init(KEY, cfg, jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1
    y_par, st_par = ssm.mamba_apply(x, p, cfg, return_state=True)
    st = ssm.mamba_state_init(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        o, st = ssm.mamba_decode_step(x[:, t], p, cfg, st)
        ys.append(o)
    y_seq = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_par["h"]), np.asarray(st["h"]),
                               rtol=1e-4, atol=1e-5)


def test_moe_routes_topk_and_capacity():
    cfg = SMOKE_ARCHS["mixtral-8x22b"]
    m = cfg.moe
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    p = moe_init(KEY, cfg, jnp.float32)
    idx, gates, probs = route(x, p["router"], cfg)
    assert idx.shape == (2, 16, m.top_k)
    # gates renormalized over top-k
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    # distinct experts per token
    assert (np.asarray(idx[..., 0]) != np.asarray(idx[..., 1])).all()
    C = capacity(16, cfg)
    assert C >= 16 * m.top_k / m.n_experts


def test_moe_identity_when_experts_zero():
    """Zero expert weights => MoE contributes ~nothing (residual sanity)."""
    cfg = SMOKE_ARCHS["mixtral-8x22b"]
    p = moe_init(KEY, cfg, jnp.float32)
    p = dict(p, w_down=jnp.zeros_like(p["w_down"]))
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    out = moe_apply(x, p, cfg)
    assert float(jnp.abs(out).max()) < 1e-6


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50))
def test_causality(seed):
    """Changing a future token never changes past logits (every arch kind
    with cheap smoke configs)."""
    from repro.models import forward, init_params
    for name in ("deepseek-7b", "jamba-1.5-large-398b", "xlstm-125m"):
        cfg = SMOKE_ARCHS[name]
        params = init_params(cfg, jax.random.PRNGKey(seed))
        toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (1, 16), 0,
                                  cfg.vocab)
        t2 = toks.at[0, 10].set((toks[0, 10] + 1) % cfg.vocab)
        l1 = forward(params, cfg, tokens=toks)
        l2 = forward(params, cfg, tokens=t2)
        np.testing.assert_allclose(np.asarray(l1[0, :10]),
                                   np.asarray(l2[0, :10]),
                                   atol=2e-2, rtol=0)
