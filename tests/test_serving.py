"""Serving-layer tests: decode == forward, bounded-pool semantics, the DAC
KV controller's invariants (hypothesis) and control behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import SMOKE_ARCHS
from repro.models import forward, init_params
from repro.serving import decode_step, init_serve_state, kv_cache, prefill

KEY = jax.random.PRNGKey(3)


def _nodrop(cfg):
    if cfg.moe:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("name,tol,fp32", [
    ("deepseek-7b", 1e-4, False), ("gemma2-27b", 1e-4, False),
    ("qwen1.5-110b", 1e-4, False), ("codeqwen1.5-7b", 1e-4, False),
    ("mixtral-8x22b", 1e-3, False), ("musicgen-medium", 1e-4, False),
    ("llava-next-mistral-7b", 1e-4, False),
    ("deepseek-v2-236b", 1e-4, True), ("jamba-1.5-large-398b", 1e-4, True),
    ("xlstm-125m", 0.05, False),
])
def test_prefill_decode_matches_forward(name, tol, fp32):
    """Decode continuation reproduces full-forward logits.

    The deep MoE hybrids run in fp32: in bf16 the decode-vs-batched
    rounding difference can flip a top-k routing decision, which is a
    discontinuous (and hardware/version-dependent) output jump no fixed
    logit tolerance survives.  This test validates cache/state plumbing,
    so fp32 — where decode == forward to ~1e-5 — is the right regime."""
    cfg = _nodrop(SMOKE_ARCHS[name])
    if fp32:
        cfg = dataclasses.replace(cfg, param_dtype="float32")
    params = init_params(cfg, KEY)
    B, S, G = 2, 24, 4
    toks = jax.random.randint(KEY, (B, S + G), 0, cfg.vocab)
    emb = jax.random.normal(KEY, (B, S + G, cfg.d_model), jnp.float32) * .05
    kw = dict(embeds=emb[:, :S]) if cfg.embeds_input else \
        dict(tokens=toks[:, :S])
    state, last = prefill(params, cfg, max_len=S + G + 2, **kw)
    fkw = dict(embeds=emb) if cfg.embeds_input else dict(tokens=toks)
    ref = forward(params, cfg, **fkw)
    np.testing.assert_allclose(np.asarray(last), np.asarray(ref[:, S - 1]),
                               atol=max(tol, 1e-2), rtol=0)
    if cfg.embeds_input:
        step = jax.jit(lambda p, s, e: decode_step(p, cfg, s, embed=e))
    else:
        step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t))
    for t in range(S, S + G):
        inp = emb[:, t] if cfg.embeds_input else toks[:, t]
        state, logits = step(params, state, inp)
        err = float(jnp.max(jnp.abs(logits - ref[:, t])))
        assert err < tol + 5e-3, (name, t, err)


def test_bounded_equals_unbounded_when_no_eviction():
    """budget >= context and k_active pinned => bit-identical decode."""
    cfg = SMOKE_ARCHS["deepseek-7b"]
    params = init_params(cfg, KEY)
    B, S = 2, 20
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    su = init_serve_state(cfg, B, max_len=S, budget=0)
    sb = init_serve_state(cfg, B, max_len=S, budget=32)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t))
    for t in range(S):
        su, lu = step(params, su, toks[:, t])
        sb, lb = step(params, sb, toks[:, t])
        np.testing.assert_array_equal(np.asarray(lu), np.asarray(lb))


def test_bounded_budget_respected_under_long_decode():
    """Decoding far past the budget: occupied slots never exceed k_active,
    and the length/free bitmaps stay consistent."""
    cfg = SMOKE_ARCHS["deepseek-7b"]
    params = init_params(cfg, KEY)
    B, budget, steps = 2, 16, 40
    state = init_serve_state(cfg, B, max_len=steps + 2, budget=budget)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t))
    tok = jnp.zeros((B,), jnp.int32)
    for t in range(steps):
        state, logits = step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert bool(jnp.isfinite(logits).all()), t
    for st in state["layers"].values():
        if not (isinstance(st, dict) and "ctrl" in st):
            continue
        ctrl = st["ctrl"]
        occupied = (~np.asarray(ctrl["free"])).sum(-1)
        length = np.asarray(ctrl["length"])
        k_act = np.asarray(ctrl["k_active"])
        assert (occupied == length).all()
        assert (length <= k_act).all()


# --- DAC slot-pool controller: property tests ------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), budget=st.sampled_from([8, 16, 32]),
       steps=st.integers(5, 60))
def test_kv_ctrl_invariants(seed, budget, steps):
    """rank2slot entries unique & consistent with free bitmap; jump/jump'
    within Alg. 2 bounds; k_active within [k_min, budget]."""
    rng = np.random.default_rng(seed)
    B = 3
    ctrl = kv_cache.control_init(B, budget)
    for t in range(steps):
        ctrl, slot = kv_cache.insert(ctrl, jnp.full((B,), t, jnp.int32))
        if rng.random() < 0.7:      # random hit on an occupied slot
            valid = np.asarray(kv_cache.valid_slots(ctrl))
            hits = []
            for b in range(B):
                occ = np.nonzero(valid[b])[0]
                hits.append(rng.choice(occ) if occ.size else -1)
            ctrl = kv_cache.hit(ctrl, jnp.asarray(hits, jnp.int32))
        ctrl = kv_cache.resize(ctrl, eps=0.5, k_min=2)

        r2s = np.asarray(ctrl["rank2slot"])
        free = np.asarray(ctrl["free"])
        length = np.asarray(ctrl["length"])
        k_act = np.asarray(ctrl["k_active"])
        jump = np.asarray(ctrl["jump"])
        jump2 = np.asarray(ctrl["jump2"])
        for b in range(B):
            live = r2s[b, :length[b]]
            assert (live >= 0).all()
            assert len(np.unique(live)) == len(live)
            assert (~free[b][live]).all()
            assert (~free[b]).sum() == length[b]
            assert (r2s[b, length[b]:] == -1).all()
            assert 2 <= k_act[b] <= budget
            assert length[b] <= k_act[b]
            assert -(k_act[b] // 2) <= jump[b] <= 2 * k_act[b]
            assert -(k_act[b] // 2) <= jump2[b] <= 0


def test_kv_ctrl_grows_when_thrashing_shrinks_when_concentrated():
    B, budget = 1, 64
    ctrl = kv_cache.control_init(B, budget, k0=8)
    # all misses, no hits -> jump saturates -> budget doubles toward 64
    for t in range(200):
        ctrl, _ = kv_cache.insert(ctrl, jnp.full((B,), t, jnp.int32))
        ctrl = kv_cache.resize(ctrl)
    assert int(ctrl["k_active"][0]) == budget

    # hammer the top slot with hits -> shrink
    for t in range(300):
        top = ctrl["rank2slot"][:, 0]
        ctrl = kv_cache.hit(ctrl, top)
        ctrl = kv_cache.resize(ctrl, eps=0.5, k_min=2)
    assert int(ctrl["k_active"][0]) < budget
