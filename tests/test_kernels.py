"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _qkv(B, S, H, Hkv, D, Dv, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    return (jax.random.normal(k1, (B, S, H, D), dtype),
            jax.random.normal(k2, (B, S, Hkv, D), dtype),
            jax.random.normal(k3, (B, S, Hkv, Dv), dtype))


FLASH_CASES = [
    # B, S, H, Hkv, D, Dv, window, softcap, dtype, bq, bk
    (2, 128, 8, 2, 64, 64, None, 0.0, jnp.float32, 32, 32),
    (1, 256, 4, 4, 32, 32, 64, 0.0, jnp.float32, 64, 32),
    (2, 64, 8, 4, 64, 64, None, 50.0, jnp.bfloat16, 16, 16),
    (1, 96, 6, 2, 48, 48, 32, 30.0, jnp.float32, 32, 16),
    (1, 128, 4, 1, 64, 32, None, 0.0, jnp.float32, 32, 64),  # MLA-ish Dv!=D
    (3, 80, 2, 2, 16, 16, None, 0.0, jnp.float32, 16, 16),   # ragged S
]


@pytest.mark.parametrize(
    "B,S,H,Hkv,D,Dv,win,cap,dtype,bq,bk", FLASH_CASES)
def test_flash_attention_matches_ref(B, S, H, Hkv, D, Dv, win, cap, dtype,
                                     bq, bk):
    q, k, v = _qkv(B, S, H, Hkv, D, Dv, dtype)
    got = ops.flash_attention(q, k, v, window=win, softcap=cap,
                              block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=win, softcap=cap)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


DECODE_CASES = [
    (2, 256, 8, 2, 64, 64, 0.0, 64),
    (3, 128, 4, 4, 32, 32, 50.0, 32),
    (1, 512, 8, 1, 64, 64, 0.0, 128),
    (2, 96, 4, 2, 32, 16, 0.0, 32),      # Dv != D
]


@pytest.mark.parametrize("B,S,H,Hkv,D,Dv,cap,bs", DECODE_CASES)
def test_decode_attention_matches_ref(B, S, H, Hkv, D, Dv, cap, bs):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, Hkv, D))
    v = jax.random.normal(k3, (B, S, Hkv, Dv))
    valid = jax.random.bernoulli(k4, 0.7, (B, S)).at[:, 0].set(True)
    got_o, got_m = ops.decode_attention(q, k, v, valid, softcap=cap,
                                        block_s=bs, interpret=True)
    want_o, want_m = ref.decode_attention_ref(q, k, v, valid, softcap=cap)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_mass_is_hit_signal():
    """Masked slots get zero mass; mass sums to ~1 over valid slots."""
    B, S, H, Hkv, D = 2, 128, 4, 2, 32
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, D))
    k = jax.random.normal(k2, (B, S, Hkv, D))
    v = jax.random.normal(k3, (B, S, Hkv, D))
    valid = jnp.zeros((B, S), bool).at[:, :40].set(True)
    _, mass = ops.decode_attention(q, k, v, valid, block_s=32,
                                   interpret=True)
    assert float(jnp.abs(mass[:, 40:]).max()) < 1e-6
    np.testing.assert_allclose(np.asarray(mass.sum(-1)), 1.0, rtol=1e-4)


@pytest.mark.parametrize("K", [16, 64, 129])
@pytest.mark.parametrize("B", [4, 10])
def test_batched_policy_step_matches_policy(K, B):
    """Successor of the retired cache_update kernel test: a batch of
    AdaptiveClimb lanes stepped through the tiled policy-step kernel
    (vmap -> native lane grid) stays bit-identical to the jnp oracle."""
    from repro.core import AdaptiveClimb, Request
    from repro.core.policy import pallas_mode

    pol = AdaptiveClimb()
    rng = np.random.default_rng(0)
    state = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (B,) + x.shape), pol.init(K))
    state_r = state

    @jax.jit
    def step_pallas(st, keys):
        with pallas_mode("interpret"):
            return jax.vmap(lambda s, k: pol.step(s, Request.of(k)))(
                st, keys)

    @jax.jit
    def step_jnp(st, keys):
        return jax.vmap(lambda s, k: pol.step(s, Request.of(k)))(st, keys)

    for t in range(300):
        keys = jnp.asarray(rng.integers(0, 2 * K, B).astype(np.int32))
        state, info = step_pallas(state, keys)
        state_r, info_r = step_jnp(state_r, keys)
        assert bool((info.hit == info_r.hit).all()), t
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(state_r)):
        assert bool((a == b).all())
