"""Property suite for the hostile trace families (flood / scanstorm /
diurnal / thrash): the structural guarantees each family's docstring
promises, checked over hypothesis-sampled parameter grids, plus the
canonical round-trip contract every registered family carries.
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.traces import (COLD_RANGE_FAMILIES, TRACES, make_trace)

HOSTILE = ("flood", "scanstorm", "diurnal", "thrash")


def test_families_registered():
    assert set(HOSTILE) <= set(TRACES)
    assert {"flood", "scanstorm"} <= COLD_RANGE_FAMILIES


@settings(max_examples=20, deadline=None)
@given(N=st.sampled_from([64, 128, 256]),
       frac=st.sampled_from([0.1, 0.25, 0.3, 0.5]),
       phases=st.integers(min_value=1, max_value=4),
       seed=st.integers(min_value=0, max_value=5))
def test_flood_fraction_per_phase(N, frac, phases, seed):
    """Each phase carries exactly ``int(phase_len * flood_frac)`` flood
    requests, all in the cold id range [N, 2N)."""
    T = 2048
    spec = make_trace(f"flood(N={N},alpha=1.0,flood_frac={frac},"
                      f"burst_len=16,phases={phases})")
    keys = spec.generate(T=T, seed=seed)
    assert spec.n_keys == 2 * N
    bounds = np.linspace(0, T, phases + 1).astype(int)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        phase = keys[lo:hi]
        n_cold = int((phase >= N).sum())
        assert n_cold == int((hi - lo) * frac), (lo, hi)


@settings(max_examples=20, deadline=None)
@given(N=st.sampled_from([128, 256]), seed=st.integers(0, 5))
def test_flood_cold_ids_are_one_hit(N, seed):
    """While the cold counter hasn't wrapped (total flood requests <= N),
    every flood id appears exactly once — true one-hit wonders."""
    frac, T = 0.1, 1024        # T*frac = 102 <= N
    spec = make_trace(f"flood(N={N},alpha=1.0,flood_frac={frac},"
                      "burst_len=16,phases=2)")
    keys = spec.generate(T=T, seed=seed)
    cold = keys[keys >= N]
    assert len(cold) == int(T / 2 * frac) * 2
    _, counts = np.unique(cold, return_counts=True)
    assert counts.max() == 1


@settings(max_examples=20, deadline=None)
@given(N=st.sampled_from([64, 128, 256]),
       loop_frac=st.sampled_from([4, 8]),
       K=st.sampled_from([4, 8, 12]),
       seed=st.integers(0, 5))
def test_thrash_reuse_distance_exceeds_K(N, loop_frac, K, seed):
    """The realized reuse distance of every repeat access is exactly
    ``loop - 1`` distinct keys — strictly larger than any cache smaller
    than the loop, by construction."""
    loop = N // loop_frac
    if loop <= K:
        loop = K + 1            # the property under test needs loop > K
    spec = make_trace(f"thrash(N={N},loop={loop})")
    keys = spec.generate(T=4 * loop, seed=seed)
    last = {}
    dists = []
    for t, k in enumerate(keys):
        if k in last:
            dists.append(len(set(keys[last[k] + 1:t])))
        last[k] = t
    assert dists and set(dists) == {loop - 1}
    assert all(d >= K for d in dists)


@settings(max_examples=20, deadline=None)
@given(N=st.sampled_from([128, 256]),
       lo=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 5))
def test_diurnal_narrow_phase_working_set(N, lo, seed):
    """Off-duty windows address at most ``lo`` distinct keys; the wide
    windows address more than ``lo`` (the swing is real)."""
    period, duty = 64, 0.5
    spec = make_trace(f"diurnal(N={N},period={period},duty={duty},lo={lo})")
    keys = spec.generate(T=1024, seed=seed)
    on = int(period * duty)
    phase = np.arange(1024) % period
    narrow = keys[phase >= on]
    wide = keys[phase < on]
    assert len(np.unique(narrow)) <= lo
    assert len(np.unique(wide)) > lo


@settings(max_examples=20, deadline=None)
@given(N=st.sampled_from([128, 256]),
       storm_frac=st.sampled_from([0.1, 0.25]),
       seed=st.integers(0, 5))
def test_scanstorm_scans_hit_cold_range(N, storm_frac, seed):
    """Scan overlays land in the cold range [N, 2N) while the churn base
    stays in [0, N).  Scans may overlap or clip at the trace end, so the
    cold volume is bounded by (not pinned to) ``n_scans * scan_len`` and
    overlapped runs merge — but a cold stretch always steps sequentially
    (+1, wrapping by N at the range edge or at a scan junction)."""
    scan_len = 32
    spec = make_trace(f"scanstorm(N={N},alpha=1.0,mean_phase=200,"
                      f"drift=0.1,storm_frac={storm_frac},"
                      f"scan_len={scan_len})")
    keys = spec.generate(T=2048, seed=seed)
    assert spec.n_keys == 2 * N
    cold = keys >= N
    n_scans = max(1, int(2048 * storm_frac / scan_len))
    assert 0 < cold.sum() <= n_scans * scan_len
    assert (keys[~cold] < N).all()
    # overlaps only merge runs, never mint new ones
    idx = np.flatnonzero(cold)
    runs = np.split(idx, np.flatnonzero(np.diff(idx) != 1) + 1)
    assert 1 <= len(runs) <= n_scans


@pytest.mark.parametrize("family", HOSTILE)
def test_roundtrip_and_determinism(family):
    """Same contract as tests/test_trace_registry.py: canonical string
    is a fixed point and generation is seed-deterministic."""
    example = {
        "flood": "flood(N=128,alpha=1.0,flood_frac=0.3,burst_len=16,"
                 "phases=2)",
        "scanstorm": "scanstorm(N=128,alpha=1.0,mean_phase=100,drift=0.1,"
                     "storm_frac=0.25,scan_len=16)",
        "diurnal": "diurnal(N=128,period=64,lo=16)",
        "thrash": "thrash(N=128,loop=32)",
    }[family]
    spec = make_trace(example)
    assert spec.family == family
    again = make_trace(str(spec))
    assert again == spec and str(again) == str(spec)
    a = spec.generate(T=512, seed=1)
    np.testing.assert_array_equal(a, spec.generate(T=512, seed=1))
    assert not np.array_equal(a, spec.generate(T=512, seed=2))
    assert a.dtype == np.int32 and a.min() >= 0 and a.max() < spec.n_keys


@pytest.mark.parametrize("spec, match", [
    ("flood(N=64,flood_frac=1.5)", "flood_frac"),
    ("diurnal(N=64,duty=0.0)", "duty"),
    ("diurnal(N=64,lo=100)", "lo"),
    ("thrash(N=64,loop=100)", "loop"),
    ("thrash(N=64,loop=0)", "loop"),
])
def test_parameter_validation(spec, match):
    with pytest.raises(ValueError, match=match):
        make_trace(spec).generate(T=64, seed=0)
