"""Doctest runner for the public API surface.

Every symbol exported from ``repro.core``, ``repro.bench``, ``repro.data``,
``repro.tier``, ``repro.fleet``, ``repro.campaign`` and ``repro.analysis``
carries a docstring
with an executable example; this
suite runs them all (the scoped equivalent of ``pytest --doctest-modules``)
so the examples in the docs can't rot.  ``tools/check_docs.py`` relies on
the same modules importing cleanly for its anchor checks.
"""
import doctest
import importlib

import pytest

# the documented public surface: repro.core / repro.bench / repro.data /
# repro.tier and the modules their __init__ re-exports from
MODULES = [
    "repro.core",
    "repro.core.policy",
    "repro.core.simulator",
    "repro.core.adaptiveclimb",
    "repro.core.admission",
    "repro.core.dynamicadaptiveclimb",
    "repro.core.baselines",
    "repro.core.lirs_lhd",
    "repro.kernels.policy_step",
    "repro.launch.roofline",
    "repro.data.traces",
    "repro.data.ingest",
    "repro.bench.scenario",
    "repro.bench.runner",
    "repro.bench.results",
    "repro.bench.report",
    "repro.campaign.manifest",
    "repro.campaign.store",
    "repro.campaign.executor",
    "repro.campaign.report",
    "repro.specs",
    "repro.analysis",
    "repro.analysis.findings",
    "repro.analysis.lint",
    "repro.analysis.contracts",
    "repro.analysis.retrace",
    "repro.tier",
    "repro.tier.arbiter",
    "repro.tier.tier",
    "repro.core.control",
    "repro.fleet",
    "repro.fleet.fleet",
    "repro.fleet.telemetry",
]


@pytest.mark.parametrize("module", MODULES)
def test_doctests(module):
    mod = importlib.import_module(module)
    result = doctest.testmod(mod, verbose=False,
                             optionflags=doctest.NORMALIZE_WHITESPACE)
    assert result.failed == 0, (
        f"{result.failed} doctest failure(s) in {module}")
    # the public surface must actually carry examples (a module whose
    # docstrings all lost their examples silently passes otherwise)
    if module not in ("repro.specs",):
        assert result.attempted > 0, f"{module} has no doctest examples"


def test_public_exports_have_docstrings():
    """Every public export of the public packages is documented."""
    for pkg_name in ("repro.core", "repro.bench", "repro.data", "repro.tier",
                     "repro.fleet", "repro.campaign", "repro.analysis"):
        pkg = importlib.import_module(pkg_name)
        exports = getattr(pkg, "__all__", None) or [
            n for n in vars(pkg) if not n.startswith("_")]
        for name in exports:
            obj = getattr(pkg, name)
            if not (callable(obj) or isinstance(obj, type)):
                continue   # data constants (POLICIES, EMPTY, ...) can't
                           # carry docstrings
            assert getattr(obj, "__doc__", None), (
                f"{pkg_name}.{name} has no docstring")
