"""Unit tests for the report layer on hand-built record stores.

Two halves: :mod:`repro.bench.report` (winners / metric_cdf /
robustness_frontier) on dense and deliberately *partial* grids — the
dropped-cell counts must be surfaced, ties must resolve
lexicographically regardless of caller ordering — and the campaign
aggregation path (:mod:`repro.campaign.report`), whose cross-policy
tables shrink to complete cells instead of crashing on partial
coverage.
"""
import numpy as np
import pytest

from repro.bench import report
from repro.campaign import report as campaign_report


def _rec(policy, scenario, k_label, values, metric="miss_ratio", **extra):
    vals = list(np.atleast_1d(values))
    return dict({"policy": policy, "scenario": scenario, "K_label": k_label,
                 "metrics": {metric: vals}}, **extra)


# --- winners ----------------------------------------------------------------


def test_winners_fraction_and_margin():
    recs = [_rec("fifo", "z", "S", [0.5, 0.5]),
            _rec("lru", "z", "S", [0.3, 0.6])]
    w = report.winners(recs, ["fifo", "lru"], margin=True)["z(S)"]
    assert w["winners"] == {"fifo": 0.5, "lru": 0.5}
    # margin is the seed-mean runner-up gap: |0.5-0.3| and |0.6-0.5|
    assert w["margin"] == pytest.approx(0.15)


def test_winners_tie_is_lexicographic_not_caller_order():
    recs = [_rec(p, "z", "S", [0.3]) for p in ("lru", "arc", "fifo")]
    for order in (["lru", "arc", "fifo"], ["fifo", "lru", "arc"],
                  ["arc", "fifo", "lru"]):
        assert report.winners(recs, order) == {"z(S)": {"arc": 1.0}}


def test_winners_margin_zero_on_exact_tie():
    recs = [_rec(p, "z", "S", [0.3]) for p in ("lru", "arc")]
    w = report.winners(recs, ["lru", "arc"], margin=True)["z(S)"]
    assert w == {"winners": {"arc": 1.0}, "margin": 0.0}


# --- metric_cdf -------------------------------------------------------------


def test_metric_cdf_sorted_with_unit_tail():
    recs = [_rec("lru", s, "S", [v], metric="hit_ratio")
            for s, v in [("a", 0.8), ("b", 0.2), ("c", 0.5)]]
    cdf = report.metric_cdf(recs, ["lru"])["lru"]
    assert cdf["values"] == sorted(cdf["values"]) == [0.2, 0.5, 0.8]
    assert cdf["cdf"] == [pytest.approx((i + 1) / 3) for i in range(3)]
    assert cdf["cdf"][-1] == 1.0


def test_metric_cdf_uses_seed_means():
    recs = [_rec("lru", "a", "S", [0.2, 0.6], metric="hit_ratio")]
    assert report.metric_cdf(recs, ["lru"])["lru"]["values"] == [0.4]


# --- robustness_frontier ----------------------------------------------------


def _grid():
    """fifo baseline everywhere; dac covered everywhere; lru missing the
    scan cell entirely (partial coverage)."""
    return [
        _rec("fifo", "flood", "S", [0.8]), _rec("fifo", "scan", "S", [0.5]),
        _rec("fifo", "loop", "S", [0.4]),
        _rec("dac", "flood", "S", [0.4]), _rec("dac", "scan", "S", [0.6]),
        _rec("dac", "loop", "S", [0.4]),
        _rec("lru", "flood", "S", [0.6]), _rec("lru", "loop", "S", [0.2]),
    ]


def test_frontier_worst_mean_and_dropped():
    f = report.robustness_frontier(_grid(), ["dac", "lru"],
                                   metric="miss_ratio")
    dac = f["dac"]
    assert dac["cells"] == 3 and dac["dropped"] == 0
    assert dac["worst_cell"] == "scan(S)"
    # signed MRR: (0.5 - 0.6) / max(0.5, 0.6)
    assert dac["worst"] == pytest.approx(-1 / 6)
    assert dac["mean"] == pytest.approx(np.mean([0.5, -1 / 6, 0.0]))
    lru = f["lru"]
    assert lru["cells"] == 2 and lru["dropped"] == 1
    assert "scan(S)" not in lru["per_cell"]
    assert lru["worst_cell"] == "flood(S)"          # +0.25 < +0.5


def test_frontier_missing_baseline_cell_counts_as_dropped():
    recs = [_rec("fifo", "flood", "S", [0.8]),
            _rec("dac", "flood", "S", [0.4]),
            _rec("dac", "scan", "S", [0.6])]   # no fifo record for scan
    f = report.robustness_frontier(recs, ["dac"], metric="miss_ratio")
    assert f["dac"]["cells"] == 1 and f["dac"]["dropped"] == 1


def test_frontier_empty_coverage_reports_none():
    recs = [_rec("fifo", "flood", "S", [0.8])]
    f = report.robustness_frontier(recs, ["lirs"], metric="miss_ratio")
    assert f["lirs"] == {"worst": None, "worst_cell": None, "mean": None,
                         "cells": 0, "dropped": 1, "per_cell": {}}


def test_frontier_worst_cell_tie_is_lexicographic():
    recs = []
    for sc in ("zeta", "alpha", "mid"):
        recs.append(_rec("fifo", sc, "S", [0.5]))
        recs.append(_rec("dac", sc, "S", [0.6]))   # identical MRR everywhere
    f = report.robustness_frontier(recs, ["dac"], metric="miss_ratio")
    assert f["dac"]["worst_cell"] == "alpha(S)"


def test_frontier_default_metric_is_byte_weighted():
    recs = [dict(_rec("fifo", "flood", "S", [0.5]),
                 metrics={"byte_miss_ratio": [0.5]}),
            dict(_rec("dac", "flood", "S", [0.25]),
                 metrics={"byte_miss_ratio": [0.25]})]
    f = report.robustness_frontier(recs, ["dac"])
    assert f["dac"]["worst"] == pytest.approx(0.5)


# --- campaign report path ---------------------------------------------------


def _camp(policy, scenario, m, dataset="ds", k_label="S"):
    return {"policy": policy, "scenario": scenario, "K_label": k_label,
            "dataset": dataset, "seeds": [0],
            "metrics": {"miss_ratio": [m], "hit_ratio": [1 - m],
                        "byte_miss_ratio": [m], "penalty_ratio": [m]}}


def test_complete_cells_keeps_only_fully_covered():
    recs = [_camp("fifo", "a", 0.5), _camp("lru", "a", 0.3),
            _camp("fifo", "b", 0.4)]             # lru missing from cell b
    kept, dropped = campaign_report.complete_cells(recs, ["fifo", "lru"])
    assert dropped == 1
    assert {(r["scenario"], r["policy"]) for r in kept} == \
        {("a", "fifo"), ("a", "lru")}


def test_complete_cells_filters_uncompared_policies():
    recs = [_camp("fifo", "a", 0.5), _camp("lru", "a", 0.3),
            _camp("arc", "a", 0.2)]
    kept, dropped = campaign_report.complete_cells(recs, ["fifo", "lru"])
    assert dropped == 0
    assert all(r["policy"] in ("fifo", "lru") for r in kept)


def test_dataset_winners_surfaces_dropped_and_shrinks():
    recs = [_camp("fifo", "a", 0.5), _camp("lru", "a", 0.3),
            _camp("fifo", "b", 0.4),             # incomplete cell -> dropped
            _camp("fifo", "c", 0.2, dataset="other"),
            _camp("lru", "c", 0.4, dataset="other")]
    table = campaign_report.dataset_winners(recs, ["fifo", "lru"])
    assert table["ds"]["cells"] == 1 and table["ds"]["dropped"] == 1
    assert table["ds"]["winner"] == "lru"
    assert table["ds"]["wins"] == {"fifo": 0.0, "lru": 1.0}
    assert table["other"]["winner"] == "fifo" and \
        table["other"]["dropped"] == 0


def test_dataset_winners_skips_dataset_with_no_complete_cells():
    recs = [_camp("fifo", "a", 0.5),
            _camp("lru", "b", 0.3)]              # no cell has both
    assert campaign_report.dataset_winners(recs, ["fifo", "lru"]) == {}


def test_dataset_winners_tie_is_lexicographic():
    recs = [_camp("fifo", "a", 0.3), _camp("lru", "a", 0.3)]
    table = campaign_report.dataset_winners(recs, ["fifo", "lru"])
    assert table["ds"]["winner"] == "fifo"
    assert table["ds"]["margin"] == 0.0


def test_mrr_vs_baseline_over_complete_cells():
    recs = [_camp("fifo", "a", 0.5), _camp("lru", "a", 0.25),
            _camp("fifo", "b", 0.4)]             # b incomplete -> excluded
    out = campaign_report.mrr_vs_baseline(recs, ["fifo", "lru"],
                                          baseline="fifo")
    assert out["ds"]["lru"] == pytest.approx(0.5)
    assert out["ds"]["fifo"] == pytest.approx(0.0)
