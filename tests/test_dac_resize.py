"""DAC resize invariants under the Request/StepInfo API, on plain random
traces (no hypothesis): the active size k stays in [k_min, K_max], ranks
>= k are EMPTY after every step (in particular after a shrink), and the
jump/jump' controllers stay in their documented ranges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EMPTY, DynamicAdaptiveClimb, Engine, Request

ENGINE = Engine()


def _mixed_trace(rng, T=1200):
    """Alternating thrash / concentration segments to exercise both the
    grow and shrink paths."""
    segs = []
    while sum(len(s) for s in segs) < T:
        if rng.random() < 0.5:
            segs.append(rng.integers(0, 400, 150))      # wide: thrash
        else:
            segs.append(rng.integers(0, 3, 150))        # narrow: concentrate
    return np.concatenate(segs)[:T].astype(np.int32)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("K,eps,growth,k_min", [
    (8, 0.5, 4, 2), (16, 0.25, 2, 2), (16, 1.0, 8, 4), (32, 0.5, 1, 2),
])
def test_resize_invariants_stepwise(seed, K, eps, growth, k_min):
    pol = DynamicAdaptiveClimb(eps=eps, growth=growth, k_min=k_min)
    K_max = K * growth
    state = pol.init(K)
    step = jax.jit(pol.step)
    rng = np.random.default_rng(seed)
    prev_k = K
    saw_shrink = saw_grow = False
    for key in _mixed_trace(rng):
        state, _ = step(state, Request.of(jnp.int32(int(key))))
        k = int(state["k"])
        jump, jump2 = int(state["jump"]), int(state["jump2"])
        assert k_min <= k <= K_max
        assert k in (prev_k, 2 * prev_k, prev_k // 2), (prev_k, k)
        saw_grow |= k == 2 * prev_k
        saw_shrink |= k == prev_k // 2
        # every rank past the active size is EMPTY — the shrink wipe leaves
        # no stale keys that could fake a hit later
        cache = np.asarray(state["cache"])
        assert (cache[k:] == int(EMPTY)).all(), (k, cache)
        # controller ranges documented in dynamicadaptiveclimb.py
        assert -(k // 2) <= jump <= 2 * k
        assert -(k // 2) <= jump2 <= 0
        prev_k = k
    if growth > 1:
        assert saw_grow, "mixed trace should trigger at least one grow"
    assert saw_shrink, "mixed trace should trigger at least one shrink"


@pytest.mark.parametrize("growth", [1, 4])
def test_resize_trajectory_via_engine(growth):
    """The same invariants hold for the k/jump observables the engine
    collects, over a longer trace."""
    rng = np.random.default_rng(7)
    trace = _mixed_trace(rng, T=6000)
    K = 16
    res = ENGINE.replay(f"dac(growth={growth})", trace, K, observe=True)
    ks = np.asarray(res.obs["k"])
    jumps = np.asarray(res.obs["jump"])
    assert ks.min() >= 2 and ks.max() <= K * growth
    assert (jumps <= 2 * ks).all()
    assert (jumps >= -(ks // 2)).all()
    # k moves by exact doubling/halving only
    steps = ks[1:] / ks[:-1]
    assert set(np.unique(steps)).issubset({0.5, 1.0, 2.0})


def test_shrink_never_below_k_min():
    pol = DynamicAdaptiveClimb(eps=1.0, growth=2, k_min=8)
    state = pol.init(16)
    step = jax.jit(pol.step)
    for key in np.tile(np.arange(2, dtype=np.int32), 500):  # max concentration
        state, _ = step(state, Request.of(jnp.int32(int(key))))
        assert int(state["k"]) >= 8
    assert int(state["k"]) == 8  # it did shrink, and stopped at the floor
