"""Trace ingestion: per-format write->read round-trips, dense-remap
determinism and chunked/full equivalence, characterization stats, and the
``file(path=...)`` registry family's contract (spec round-trip, footprint
resolution, scenario validation, corpus freshness)."""
import importlib.util
import pathlib

import numpy as np
import pytest

from repro.bench import Scenario
from repro.data import ingest
from repro.data.traces import make_trace

ROOT = pathlib.Path(__file__).resolve().parent.parent
CORPUS = ROOT / "benchmarks" / "corpus"


@pytest.fixture
def trace_arrays():
    rng = np.random.default_rng(42)
    keys = (rng.integers(0, 60, 500) * 997 + 13).astype(np.int64)
    sizes = rng.integers(1, 200, 500).astype(np.int64)
    costs = (sizes / 64 + 1).astype(np.float32)   # dyadic: exact in text
    return keys, sizes, costs


FORMAT_CASES = [
    ("t.oracleGeneral.bin", "oracle"),
    ("t.oracleGeneral.bin.gz", "oracle"),
    ("t.csv", "csv"),
    ("t.csv.gz", "csv"),
    ("t.keys.txt", "txt"),
    ("t.keys.txt.gz", "txt"),
]


def _write(path, fmt, keys, sizes, costs):
    if fmt == "oracle":
        ingest.write_oracle_general(path, keys, sizes)
    elif fmt == "csv":
        ingest.write_csv(path, keys, sizes, costs)
    else:
        ingest.write_keys(path, keys)


# --- write -> read round-trips ---------------------------------------------

@pytest.mark.parametrize("name,fmt", FORMAT_CASES)
def test_roundtrip_preserves_columns(tmp_path, trace_arrays, name, fmt):
    """Each format preserves exactly the columns it carries: keys always
    (via the order-isomorphic dense remap), sizes for oracle/csv, costs
    for csv."""
    keys, sizes, costs = trace_arrays
    path = str(tmp_path / name)
    _write(path, fmt, keys, sizes, costs)
    tr = ingest.load_trace(path)
    # dense ids are first-appearance-ordered: remapping the original keys
    # the same way must reproduce them exactly
    np.testing.assert_array_equal(tr.keys, ingest.DenseRemap()(keys))
    assert tr.keys.dtype == np.int32
    assert tr.n_objects == len(np.unique(keys))
    if fmt in ("oracle", "csv"):
        np.testing.assert_array_equal(tr.sizes, sizes)
    else:
        assert tr.sizes is None
    if fmt == "csv":
        np.testing.assert_array_equal(tr.costs, costs)
    else:
        assert tr.costs is None


def test_oracle_record_layout(tmp_path, trace_arrays):
    """The oracleGeneral writer emits libCacheSim's packed 24-byte
    little-endian records — raw obj ids and sizes survive unremapped."""
    keys, sizes, _ = trace_arrays
    path = str(tmp_path / "t.oracleGeneral.bin")
    ingest.write_oracle_general(path, keys, sizes)
    rec = np.fromfile(path, dtype=ingest.ORACLE_DTYPE)
    assert ingest.ORACLE_DTYPE.itemsize == 24
    np.testing.assert_array_equal(rec["obj"], keys.astype(np.uint64))
    np.testing.assert_array_equal(rec["size"], sizes.astype(np.uint32))
    # next_access_vtime: position of the key's next occurrence, or -1
    i = int(np.argmax(rec["next"] >= 0))
    nxt = int(rec["next"][i])
    assert keys[nxt] == keys[i] and not np.any(keys[i + 1:nxt] == keys[i])


def test_truncated_oracle_raises(tmp_path):
    path = str(tmp_path / "t.oracleGeneral.bin")
    ingest.write_oracle_general(path, [1, 2, 3])
    with open(path, "ab") as f:
        f.write(b"\x00" * 7)
    with pytest.raises(ValueError, match="24-byte"):
        ingest.load_trace(path)


def test_csv_header_reorder_and_extra_columns(tmp_path):
    path = str(tmp_path / "t.csv")
    with open(path, "w") as f:
        f.write("op,size,key,cost\nGET,10,7,1.5\nGET,20,9,2.5\n")
    tr = ingest.load_trace(path)
    assert tr.keys.tolist() == [0, 1]
    assert tr.sizes.tolist() == [10, 20]
    assert tr.costs.tolist() == [1.5, 2.5]
    with open(path, "w") as f:
        f.write("op,size\nGET,10\n")
    ingest._load_full.cache_clear()
    with pytest.raises(ValueError, match="no 'key'"):
        ingest.load_trace(path)


def test_csv_headerless_positional(tmp_path):
    path = str(tmp_path / "t.csv")
    with open(path, "w") as f:
        f.write("5,10\n5,10\n8,30\n")
    tr = ingest.load_trace(path)
    assert tr.keys.tolist() == [0, 0, 1]
    assert tr.sizes.tolist() == [10, 10, 30]
    assert tr.costs is None


def test_csv_headerless_string_keys(tmp_path):
    """A first data row with a textual key (hash-keyed traces) must not
    be swallowed by header sniffing — only a row naming `key` (or an
    all-textual multi-column foreign header, refused) is special."""
    path = str(tmp_path / "t.csv")
    with open(path, "w") as f:
        f.write("deadbeef,10\ncafe,20\ndeadbeef,10\n")
    tr = ingest.load_trace(path)
    assert tr.keys.tolist() == [0, 1, 0]
    assert tr.sizes.tolist() == [10, 20, 10]


def test_mixed_token_keys_chunk_invariant(tmp_path):
    """Regression: keys are compared as raw text, so a chunk's token mix
    cannot change identities across chunk boundaries ('1234' stays
    '1234' whether its chunk also contains 'abcd' or not), and '007' is
    a different object than '7'."""
    path = str(tmp_path / "t.keys.txt")
    ingest.write_keys(path, np.array(
        ["1234", "abcd", "1234", "5678", "abcd", "5678", "007", "7"]))
    full = ingest.load_trace(path)
    assert full.keys.tolist() == [0, 1, 0, 2, 1, 2, 3, 4]
    for chunk in (1, 2, 3):
        got = np.concatenate(
            [c.keys for c in ingest.iter_chunks(path, chunk=chunk)])
        np.testing.assert_array_equal(got, full.keys,
                                      err_msg=f"chunk={chunk}")


def test_csv_single_textual_column_is_ambiguous(tmp_path):
    """'obj_id\\nA\\nB\\n' is undecidable (header? bare string keys?) —
    refuse with guidance instead of ingesting a phantom object."""
    path = str(tmp_path / "t.csv")
    with open(path, "w") as f:
        f.write("obj_id\nA\nB\nA\n")
    with pytest.raises(ValueError, match="no 'key' column"):
        ingest.load_trace(path)


def test_count_requests_cheap_path(tmp_path, trace_arrays):
    """count_requests agrees with characterize on every format, and the
    uncompressed-oracle fast path is pure arithmetic on the file size."""
    keys, sizes, costs = trace_arrays
    for name, fmt in FORMAT_CASES:
        path = str(tmp_path / name)
        _write(path, fmt, keys, sizes, costs)
        assert ingest.count_requests(path) == 500
        assert ingest.characterize(path).n_requests == 500
    bad = str(tmp_path / "bad.oracleGeneral.bin")
    with open(bad, "wb") as f:
        f.write(b"\x00" * 25)
    with pytest.raises(ValueError, match="24-byte"):
        ingest.count_requests(bad)


def test_txt_string_keys(tmp_path):
    path = str(tmp_path / "t.keys.txt")
    with open(path, "w") as f:
        f.write("alpha\nbeta\nalpha\n42\n")
    tr = ingest.load_trace(path)
    assert tr.keys.tolist() == [0, 1, 0, 2]


# --- dense remap -----------------------------------------------------------

def test_dense_remap_first_appearance_order():
    out = ingest.DenseRemap()(np.array([50, 20, 50, 90, 20]))
    assert out.tolist() == [0, 1, 0, 2, 1]


def test_dense_remap_deterministic_and_chunk_invariant(tmp_path,
                                                       trace_arrays):
    """The remap depends only on the key sequence: loading twice is
    identical, and chunked iteration (any chunk size) reproduces the
    full load bit for bit."""
    keys, sizes, costs = trace_arrays
    path = str(tmp_path / "t.csv")
    ingest.write_csv(path, keys, sizes, costs)
    full = ingest.load_trace(path)
    np.testing.assert_array_equal(full.keys, ingest.load_trace(path).keys)
    for chunk in (1, 7, 64, 10_000):
        got = np.concatenate(
            [c.keys for c in ingest.iter_chunks(path, chunk=chunk)])
        np.testing.assert_array_equal(got, full.keys, err_msg=f"chunk={chunk}")


def test_limit_is_a_prefix(tmp_path, trace_arrays):
    keys, sizes, costs = trace_arrays
    path = str(tmp_path / "t.csv")
    ingest.write_csv(path, keys, sizes, costs)
    full = ingest.load_trace(path)
    part = ingest.load_trace(path, limit=123)
    np.testing.assert_array_equal(part.keys, full.keys[:123])
    np.testing.assert_array_equal(part.sizes, full.sizes[:123])
    chunks = list(ingest.iter_chunks(path, chunk=50, limit=123))
    assert sum(len(c.keys) for c in chunks) == 123
    np.testing.assert_array_equal(
        np.concatenate([c.keys for c in chunks]), part.keys)


# --- format detection ------------------------------------------------------

def test_detect_format():
    assert ingest.detect_format("x/mix.oracleGeneral.bin.gz") == "oracle"
    assert ingest.detect_format("kv.csv") == "csv"
    assert ingest.detect_format("a.keys") == "txt"
    with pytest.raises(ValueError, match="pass format="):
        ingest.detect_format("trace.dat")
    with pytest.raises(ValueError, match="unknown trace format"):
        ingest.load_trace("whatever.csv", format="parquet")


# --- characterization ------------------------------------------------------

def test_characterize_counts_and_footprint(tmp_path):
    path = str(tmp_path / "t.csv")
    ingest.write_csv(path, [1, 1, 1, 2], sizes=[100, 100, 100, 50])
    st = ingest.characterize(path)
    assert (st.n_requests, st.n_objects) == (4, 2)
    assert st.total_bytes == 350          # traffic volume
    assert st.footprint_bytes == 150      # storage demand (first-seen)
    assert st.unique_frac == 0.5


def test_characterize_skew_orders_zipf_exponents(tmp_path):
    from repro.data.traces import zipf_trace
    skews = []
    for alpha in (0.2, 1.4):
        path = str(tmp_path / f"z{alpha}.keys.txt")
        ingest.write_keys(path, zipf_trace(N=512, T=20_000, alpha=alpha,
                                           seed=0))
        skews.append(ingest.characterize(path).skew)
    assert skews[1] > skews[0] > 0


# --- the file(...) registry family -----------------------------------------

def _file_spec(tmp_path, **kw):
    path = str(tmp_path / "t.csv")
    keys = np.array([5, 2, 5, 9, 2, 5])
    ingest.write_csv(path, keys, sizes=[10, 20, 10, 30, 20, 10], **kw)
    return make_trace(f"file(path={path})")


def test_file_spec_roundtrips_like_every_family(tmp_path):
    spec = _file_spec(tmp_path)
    assert spec.family == "file" and spec.is_file
    assert make_trace(str(spec)) == spec
    assert str(make_trace(str(spec))) == str(spec)
    assert spec.n_keys == 3               # dense footprint from the file
    assert spec.stats().n_requests == 6


def test_file_spec_generate_ignores_seed_and_bounds_T(tmp_path):
    spec = _file_spec(tmp_path)
    np.testing.assert_array_equal(spec.generate(T=4, seed=0),
                                  spec.generate(T=4, seed=99))
    assert spec.generate(T=4).tolist() == [0, 1, 0, 2]
    with pytest.raises(ValueError, match="wrap-around"):
        spec.generate(T=7)


def test_file_spec_requires_path():
    with pytest.raises(ValueError, match="missing required"):
        make_trace("file")
    with pytest.raises(ValueError, match="unknown parameter"):
        make_trace("file(path=x.csv,N=4)")


def test_scenario_file_backed_validation(tmp_path):
    spec = _file_spec(tmp_path)
    sc = Scenario("real", trace=str(spec), T=6, K=("L", 2))
    assert sc.capacities() == (4, 2)      # "L" floored at 4 of footprint 3
    with pytest.raises(ValueError, match="size_model"):
        Scenario("real", trace=str(spec), T=6, size_model="lognormal")
    with pytest.raises(ValueError, match="exceeds"):
        Scenario("real", trace=str(spec), T=1000)
    with pytest.raises(FileNotFoundError):
        Scenario("real", trace=f"file(path={tmp_path}/missing.csv)", T=5)


# --- the committed corpus --------------------------------------------------

def _load_make_corpus():
    spec = importlib.util.spec_from_file_location(
        "make_corpus", ROOT / "tools" / "make_corpus.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_committed_corpus_is_fresh(tmp_path):
    """tools/make_corpus.py regenerates the committed corpus byte for
    byte (gzip mtime pinned to 0) — CI diffs on this same property."""
    mod = _load_make_corpus()
    paths = mod.build(str(tmp_path))
    committed = sorted(p.name for p in CORPUS.iterdir())
    assert sorted(pathlib.Path(p).name for p in paths) == committed
    for path in paths:
        fresh = pathlib.Path(path).read_bytes()
        assert fresh == (CORPUS / pathlib.Path(path).name).read_bytes(), \
            f"{path} drifted from the committed corpus"


@pytest.mark.parametrize("name", ["mix.oracleGeneral.bin.gz", "kv.csv.gz",
                                  "scan.keys.txt"])
def test_corpus_files_replay_through_registry(name):
    spec = make_trace(f"file(path={CORPUS / name})")
    keys = spec.generate(T=1000)
    assert keys.dtype == np.int32 and keys.min() >= 0
    assert keys.max() < spec.n_keys


def test_corpus_gz_pair_is_same_trace():
    a = ingest.load_trace(str(CORPUS / "mix.oracleGeneral.bin"))
    b = ingest.load_trace(str(CORPUS / "mix.oracleGeneral.bin.gz"))
    np.testing.assert_array_equal(a.keys, b.keys)
    np.testing.assert_array_equal(a.sizes, b.sizes)
