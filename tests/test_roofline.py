"""The loop-aware HLO analyzer behind the roofline deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import roofline as R


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_matches_xla_cost_analysis_on_scanfree_graph():
    f = lambda x, w: (jnp.tanh(x @ w) @ w).sum()  # noqa: E731
    c = _compile(f, jax.ShapeDtypeStruct((256, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    ours = R.analyze_hlo(c.as_text())["flops"]
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax returns [dict], newer dict
        ca = ca[0]
    xla = ca["flops"]
    assert abs(ours - xla) / xla < 0.01, (ours, xla)


@pytest.mark.parametrize("n", [2, 8, 32])
def test_scales_with_scan_trip_count(n):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=n)
        return out.sum()
    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    got = R.analyze_hlo(c.as_text())["flops"]
    expect = 2 * 128 ** 3 * n
    assert abs(got - expect) / expect < 0.05, (got, expect, n)


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out.sum()
    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    got = R.analyze_hlo(c.as_text())["flops"]
    expect = 2 * 64 ** 3 * 12
    assert abs(got - expect) / expect < 0.05, (got, expect)


def test_scan_sliced_reads_not_charged_full_buffer():
    """A scan that dynamic-slices one row per step from a big stacked input
    must charge ~row bytes per step, not the whole buffer."""
    T, D = 512, 256

    def f(xs, w):
        def body(c, i):
            row = jax.lax.dynamic_slice_in_dim(xs, i * D // D, 1, 0)
            return c + (row[0] * w), None
        c, _ = jax.lax.scan(body, jnp.zeros((D,), jnp.float32),
                            jnp.arange(T))
        return c.sum()
    c = _compile(f, jax.ShapeDtypeStruct((T, D), jnp.float32),
                 jax.ShapeDtypeStruct((D,), jnp.float32))
    hbm = R.analyze_hlo(c.as_text())["hbm_bytes"]
    full_buffer_everystep = T * (T * D * 4)
    assert hbm < full_buffer_everystep / 20, hbm


def test_hardware_constants():
    assert R.PEAK_FLOPS == 197e12
    assert R.HBM_BW == 819e9
    assert R.ICI_BW == 50e9
    t = {"compute_s": 1.0, "memory_s": 2.0, "collective_s": 0.5}
    assert R.dominant_term(t) == "memory_s"


def test_parse_replica_groups():
    assert R._group_size("replica_groups=[2,8]<=[16]", 99) == 8
    assert R._group_size("replica_groups={{0,1,2,3}}", 99) == 4
    assert R._group_size("no groups here", 7) == 7
