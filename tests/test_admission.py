"""Differential harness for the size-aware admission layer.

The wrapper's core contract is *conservative extension*: ``admit(<p>,
filter=off)`` must be bit-identical to bare ``<p>`` — not
metrics-close, byte-for-byte equal per step — for every registry
policy, under both ``use_pallas`` settings, on single-lane scans and
vmapped lane batches.  On top of that: gating decisions are
deterministic, hits are never re-accounted, rejected misses still
charge their bytes, and the spec grammar composes (``admit(dac(...),
...)`` keeps the nested base spec intact).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EMPTY, AdmissionPolicy, Engine, POLICIES,
                        make_policy)
from repro.core.admission import FILTERS
from repro.core.policy import Request

ENGINE = Engine()
PALLAS = (False, True)

_rng = np.random.default_rng(7)
KEYS = _rng.integers(0, 48, size=(2, 320)).astype(np.int32)
SIZES = _rng.integers(1, 9000, size=(2, 320)).astype(np.float64)


def _info_equal(a, b, label):
    assert (a is None) == (b is None)
    for f in a._fields:
        x, y = getattr(a, f), getattr(b, f)
        if x is None and y is None:
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{label}: StepInfo.{f}")


def _metrics_equal(a, b, label):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{label}: Metrics.{f}")


@pytest.mark.parametrize("use_pallas", PALLAS)
@pytest.mark.parametrize("name", sorted(POLICIES))
def test_filter_off_bit_identical_scan(name, use_pallas):
    """Single-lane scan: the pass-through wrapper is invisible."""
    wrapped = make_policy(f"admit({name},filter=off)")
    ref = ENGINE.replay(name, KEYS[0], 8, sizes=SIZES[0],
                        use_pallas=use_pallas)
    got = ENGINE.replay(wrapped, KEYS[0], 8, sizes=SIZES[0],
                        use_pallas=use_pallas)
    _info_equal(got.info, ref.info, f"{name}/pallas={use_pallas}")
    _metrics_equal(got.metrics, ref.metrics, f"{name}/pallas={use_pallas}")


@pytest.mark.parametrize("use_pallas", PALLAS)
@pytest.mark.parametrize("name", sorted(POLICIES))
def test_filter_off_bit_identical_vmapped(name, use_pallas):
    """Vmapped lane batch: identical per lane, not just in aggregate."""
    wrapped = make_policy(f"admit({name},filter=off)")
    ref = ENGINE.replay(name, KEYS, 8, sizes=SIZES, use_pallas=use_pallas)
    got = ENGINE.replay(wrapped, KEYS, 8, sizes=SIZES,
                        use_pallas=use_pallas)
    _info_equal(got.info, ref.info, f"{name}/vmap/pallas={use_pallas}")
    _metrics_equal(got.metrics, ref.metrics,
                   f"{name}/vmap/pallas={use_pallas}")


@pytest.mark.parametrize("filter", [f for f in FILTERS if f != "off"])
def test_gated_replay_deterministic(filter):
    """Same trace, same wrapper -> the same decisions, step for step;
    and the vmapped batch reproduces each single-lane scan exactly."""
    pol = make_policy(f"admit(dac,filter={filter})")
    a = ENGINE.replay(pol, KEYS, 8, sizes=SIZES)
    b = ENGINE.replay(pol, KEYS, 8, sizes=SIZES)
    _info_equal(a.info, b.info, f"repeat/{filter}")
    for lane in range(KEYS.shape[0]):
        single = ENGINE.replay(pol, KEYS[lane], 8, sizes=SIZES[lane])
        for f in a.info._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a.info, f))[lane],
                np.asarray(getattr(single.info, f)),
                err_msg=f"lane {lane}/{filter}: StepInfo.{f}")


@pytest.mark.parametrize("filter", FILTERS)
def test_hits_never_gated(filter):
    """When everything fits (no evictions, victim always EMPTY) the
    gate can never fire: any filter replays bit-identically to the bare
    base, and hit accounting is untouched."""
    keys = _rng.integers(0, 6, size=400).astype(np.int32)
    ref = ENGINE.replay("lru", keys, 8)
    got = ENGINE.replay(make_policy(f"admit(lru,filter={filter})"), keys, 8)
    _info_equal(got.info, ref.info, f"fits/{filter}")
    _metrics_equal(got.metrics, ref.metrics, f"fits/{filter}")


def test_hit_steps_commit_unchanged():
    """On hit steps the gate is a no-op: the hit flag and the zero
    eviction/bytes accounting come straight from the base."""
    res = ENGINE.replay(make_policy("admit(dac)"), KEYS[0], 8,
                        sizes=SIZES[0])
    hit = np.asarray(res.info.hit)
    assert hit.any()
    assert (np.asarray(res.info.evicted_key)[hit] == EMPTY).all()
    assert (np.asarray(res.info.bytes_missed)[hit] == 0).all()


def test_rejected_miss_still_charges_bytes():
    """A gated miss reports no eviction but still pays the fetch: every
    miss charges its request size whether or not it was admitted."""
    res = ENGINE.replay(make_policy("admit(lru,filter=tinylfu)"), KEYS[0],
                        8, sizes=SIZES[0])
    miss = ~np.asarray(res.info.hit)
    np.testing.assert_array_equal(
        np.asarray(res.info.bytes_missed)[miss], SIZES[0][miss])
    # the wrapper must actually have rejected something on this trace,
    # or the test above is vacuous for the gated path
    bare = ENGINE.replay("lru", KEYS[0], 8, sizes=SIZES[0])
    n_evict = (np.asarray(res.info.evicted_key) != EMPTY).sum()
    n_bare = (np.asarray(bare.info.evicted_key) != EMPTY).sum()
    assert n_evict < n_bare


def test_gating_changes_behaviour():
    """The non-off filters are not accidental pass-throughs."""
    bare = ENGINE.replay("lru", KEYS[0], 8, sizes=SIZES[0])
    gated = ENGINE.replay(make_policy("admit(lru)"), KEYS[0], 8,
                          sizes=SIZES[0])
    assert not np.array_equal(np.asarray(bare.info.evicted_key),
                              np.asarray(gated.info.evicted_key))


# --- budgeted / observables delegation ---------------------------------


def test_hasattr_mirrors_base():
    """The engine and the tier feature-detect with hasattr: the wrapper
    must expose ``step_budgeted``/``observables`` exactly when its base
    does."""
    for name in sorted(POLICIES):
        base = make_policy(name)
        wrapped = make_policy(f"admit({name})")
        for attr in ("step_budgeted", "observables"):
            assert hasattr(wrapped, attr) == hasattr(base, attr), \
                f"{name}.{attr}"


def test_step_budgeted_off_parity():
    """filter=off budgeted stepping matches the bare base with the same
    cap threaded through ``state['base']['cap']``."""
    wrapped = make_policy("admit(dac,filter=off)")
    bare = make_policy("dac")
    sw, sb = wrapped.init(8), bare.init(8)
    sw = {"base": dict(sw["base"], cap=jnp.int32(12))}
    sb = dict(sb, cap=jnp.int32(12))
    for k in KEYS[0][:120]:
        r = Request.of(jnp.int32(int(k)))
        sw, iw = wrapped.step_budgeted(sw, r)
        sb, ib = bare.step_budgeted(sb, r)
        assert bool(iw.hit) == bool(ib.hit)
        assert int(iw.evicted_key) == int(ib.evicted_key)
    np.testing.assert_array_equal(np.asarray(sw["base"]["cache"]),
                                  np.asarray(sb["cache"]))


def test_step_budgeted_gated_runs_and_observes():
    """The gated budgeted path steps, and observables delegate to the
    base's view of the nested state."""
    wrapped = make_policy("admit(dac)")
    st = wrapped.init(8)
    st = {"base": dict(st["base"], cap=jnp.int32(12)), "adm": st["adm"]}
    for k in KEYS[0][:80]:
        st, _ = wrapped.step_budgeted(st, Request.of(jnp.int32(int(k))))
    obs = wrapped.observables(st)
    assert set(obs) == {"k", "jump"}
    assert int(obs["k"]) >= 2


def test_adapt_keys_keep_controller_live():
    """DAC's resize controller observes rejected misses (ADAPT_KEYS):
    a flood of oversized one-hit wonders must not freeze ``k`` at its
    minimum the way a wholesale revert would."""
    N = 256
    base = _rng.zipf(1.2, size=2000) % N
    flood = N + np.arange(2000) % N
    mask = _rng.random(2000) < 0.4
    keys = np.where(mask, flood, base).astype(np.int32)
    sizes = np.where(keys >= N, 65536.0, 4096.0)
    res = ENGINE.replay(make_policy("admit(dac)"), keys, 32, sizes=sizes,
                        observe=True)
    assert int(np.asarray(res.obs["k"]).max()) > 32


# --- spec grammar ------------------------------------------------------


def test_nested_base_spec_survives():
    pol = make_policy("admit(dac(eps=0.25,growth=2),filter=tinylfu,"
                      "size_norm=false)")
    assert isinstance(pol, AdmissionPolicy)
    assert pol.base.eps == 0.25 and pol.base.growth == 2
    assert pol.filter == "tinylfu" and pol.size_norm is False


def test_admit_specs_equal_and_hash():
    a = make_policy("admit(dac(eps=0.25),filter=ghost)")
    b = make_policy("admit(dac(eps=0.25))")
    assert a == b and hash(a) == hash(b)
    assert a != make_policy("admit(dac(eps=0.5))")


@pytest.mark.parametrize("spec, match", [
    ("admit()", "needs a base policy spec"),
    ("admit(filter=tinylfu)", "needs a base policy spec"),
    ("admit(lru,filter=sometimes)", "admit filter must be one of"),
    ("admit(lru,rows=9)", "rows must lie in"),
    ("admit(lru,nope=1)", "unknown parameter"),
    ("admit(nosuchpolicy)", "unknown policy"),
])
def test_spec_errors(spec, match):
    with pytest.raises(ValueError, match=match):
        make_policy(spec)


def test_estimator_state_shapes_fixed():
    """Sketch width is the pow2 ceiling of K*width_factor; ghost ring is
    ghost_factor*K and starts all-EMPTY — fixed shapes, derived from K."""
    pol = make_policy("admit(lru,width_factor=3,ghost_factor=2)")
    st = pol.init(10)
    assert st["adm"]["sketch"].shape == (4, 32)
    assert st["adm"]["bytes"].shape == (4, 32)
    assert st["adm"]["ghost"].shape == (20,)
    assert bool((st["adm"]["ghost"] == EMPTY).all())
    off = make_policy("admit(lru,filter=off)")
    assert set(off.init(10)) == {"base"}
