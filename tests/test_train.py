"""Training substrate: optimizer numerics, checkpoint fault tolerance,
resume determinism, straggler watchdog, data-pipeline statelessness."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import SMOKE_ARCHS
from repro.data.tokens import TokenPipeline
from repro.optim import AdamWConfig, adamw
from repro.train import StragglerWatchdog, TrainConfig, Trainer


def test_adamw_int8_moments_track_f32(tmp_path):
    cfg = SMOKE_ARCHS["deepseek-7b"]
    from repro.models import init_params, lm_loss
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg.vocab, 4, 64)
    losses = {}
    for md in ("float32", "int8"):
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20,
                           moment_dtype=md)
        p, o = params, adamw.init(params, ocfg)

        @jax.jit
        def step(p, o, b, ocfg=ocfg):
            loss, g = jax.value_and_grad(lm_loss)(p, cfg, b)
            p, o, _ = adamw.update(g, o, p, ocfg)
            return p, o, loss

        ls = []
        for t in range(12):
            b = {k: jnp.asarray(v) for k, v in pipe.batch(t).items()}
            p, o, loss = step(p, o, b)
            ls.append(float(loss))
        losses[md] = ls
    f32, i8 = np.array(losses["float32"]), np.array(losses["int8"])
    assert i8[-1] < i8[0]
    assert abs(f32[-1] - i8[-1]) < 0.15, (f32[-1], i8[-1])


def test_checkpoint_atomic_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": {"w": jnp.arange(6, dtype=jnp.bfloat16)},
             "s": jnp.int32(3)}
    for step in (5, 10, 15, 20):
        mgr.save(step, state)
    assert mgr.steps() == [15, 20]
    step, restored = mgr.restore()
    assert step == 20
    assert restored["a"]["w"].dtype == np.dtype("bfloat16")
    np.testing.assert_array_equal(restored["a"]["w"],
                                  np.arange(6, dtype=np.float32))
    # a stale .tmp dir never shadows a complete checkpoint
    (tmp_path / "step_0000000025.tmp").mkdir()
    assert mgr.latest_step() == 20


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"x": jnp.ones((128, 128))}, blocking=False)
    mgr.wait()
    step, st = mgr.restore()
    assert step == 1 and st["x"].shape == (128, 128)


def test_trainer_kill_and_resume_is_deterministic(tmp_path):
    """A crash mid-run resumes from the last snapshot and replays the exact
    same data stream (stateless pipeline) => same final loss as uninterrupted."""
    cfg = SMOKE_ARCHS["deepseek-7b"]
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=16,
                      weight_decay=0.0)

    def tc(d):
        return TrainConfig(steps=16, ckpt_dir=str(d), ckpt_every=8,
                           global_batch=4, seq_len=32, async_ckpt=False)

    d1, d2 = tmp_path / "a", tmp_path / "b"
    # uninterrupted
    t_full = Trainer(cfg, opt, tc(d1))
    t_full.run()
    # interrupted at step 8, then resumed
    t_int = Trainer(cfg, opt, tc(d2))
    t_int.run(steps=8)
    t_res = Trainer(cfg, opt, tc(d2))
    t_res.run()
    assert t_res.history[0]["step"] == 8
    np.testing.assert_allclose(t_full.history[-1]["loss"],
                               t_res.history[-1]["loss"], rtol=1e-5)


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(factor=3.0)
    flags = [wd.record(dt) for dt in
             [1.0, 1.1, 0.9, 1.0, 5.0, 1.0, 1.05, 9.0]]
    assert flags == [False, False, False, False, True, False, False, True]
    assert wd.flagged == 2
    assert wd.ema < 1.5          # outliers must not poison the EMA


def test_token_pipeline_stateless_and_host_sharded():
    pipe = TokenPipeline(vocab=100, global_batch=8, seq_len=16, seed=1)
    b1 = pipe.batch(7)
    b2 = pipe.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host shards partition the global batch
    parts = [pipe.batch(7, host_id=h, n_hosts=4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    assert not np.array_equal(pipe.batch(8)["tokens"], b1["tokens"])
    # labels are the next-token shift
    full = pipe.batch(3)
    assert full["tokens"].shape == full["labels"].shape == (8, 16)


def test_quantize_roundtrip_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * \
        jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (1000,)))
    q = adamw.quantize(x)
    err = jnp.abs(adamw.dequantize(q, x.shape) - x)
    # blockwise int8: error bounded by block_max/254
    assert float(err.max()) <= float(jnp.abs(x).max()) / 127.0
