"""Trace registry: make_trace spec parsing, canonical-string round-trips,
same-seed determinism, and the make_policy-parity coercion/error contract."""
import pathlib

import numpy as np
import pytest

from repro.data.traces import (DATASET_FAMILIES, TRACE_ALIASES, TRACES,
                               TraceSpec, dataset_family, make_trace)

_CORPUS = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" \
    / "corpus"

# one concrete, cheap spec per registered family
EXAMPLE_SPECS = {
    "zipf": "zipf(N=128,alpha=1.0)",
    "shifting_zipf": "shifting_zipf(N=128,alpha=1.0,phases=3)",
    "scan_mix": "scan_mix(N=128,alpha=1.0,scan_frac=0.2,scan_len=32)",
    "churn": "churn(N=128,alpha=1.0,mean_phase=500,drift=0.1)",
    "tenants": "tenants(N=128,n_tenants=4,period=512,lo=16)",
    "fleet": "fleet(N=128,n_lanes=4,rate=0.05,mean_session=200,lo=16)",
    "file": f"file(path={_CORPUS / 'kv.csv.gz'})",
    "flood": "flood(N=128,alpha=1.0,flood_frac=0.3,burst_len=16,phases=2)",
    "scanstorm": "scanstorm(N=128,alpha=1.0,mean_phase=100,drift=0.1,"
                 "storm_frac=0.25,scan_len=16)",
    "diurnal": "diurnal(N=128,period=64,lo=16)",
    "thrash": "thrash(N=128,loop=32)",
}


def test_every_family_has_an_example_spec():
    assert set(EXAMPLE_SPECS) == set(TRACES)


@pytest.mark.parametrize("family", sorted(TRACES))
def test_roundtrip_every_family(family):
    """str(make_trace(s)) is canonical: parsing it back yields an equal
    spec, and the canonical form is a fixed point."""
    spec = make_trace(EXAMPLE_SPECS[family])
    assert spec.family == family
    again = make_trace(str(spec))
    assert again == spec
    assert str(again) == str(spec)
    assert hash(again) == hash(spec)


@pytest.mark.parametrize("alias", sorted(TRACE_ALIASES))
def test_roundtrip_every_dataset_alias(alias):
    """Dataset aliases resolve to a registered family whose canonical
    string round-trips; their parameters match DATASET_FAMILIES."""
    spec = make_trace(alias)
    assert spec.family in TRACES
    assert make_trace(str(spec)) == spec
    cfg = {k: v for k, v in DATASET_FAMILIES[alias].items() if k != "kind"}
    assert spec.kwargs == cfg


@pytest.mark.parametrize("family", sorted(TRACES))
def test_same_seed_determinism(family):
    spec = make_trace(EXAMPLE_SPECS[family])
    a = spec.generate(T=4000, seed=3)
    b = spec.generate(T=4000, seed=3)
    np.testing.assert_array_equal(a, b)
    # tier/fleet families emit [T, n_tenants] interleaved streams
    want = ((4000, spec.n_tenants) if spec.is_tier or spec.is_fleet
            else (4000,))
    assert a.shape == want and a.dtype == np.int32
    # fleet streams mark idle lanes with -1; every live key stays in range
    floor = -1 if spec.is_fleet else 0
    assert a.min() >= floor and a.max() < spec.n_keys
    if spec.is_file:
        # real data has no seed axis: every seed is the same trace
        np.testing.assert_array_equal(a, spec.generate(T=4000, seed=4))
    else:
        # a different seed produces a different trace
        assert not np.array_equal(a, spec.generate(T=4000, seed=4))


def test_generate_batch_stacks_per_seed_traces():
    spec = make_trace("zipf(N=64,alpha=1.0)")
    batch = spec.generate_batch(T=1000, seeds=[5, 9])
    assert batch.shape == (2, 1000)
    np.testing.assert_array_equal(batch[0], spec.generate(1000, seed=5))
    np.testing.assert_array_equal(batch[1], spec.generate(1000, seed=9))


def test_dataset_family_wrapper_bit_identical():
    """The back-compat wrapper reproduces its historical seeding exactly
    through the registry path."""
    got = dataset_family("wiki", T=3000, n_traces=2, seed=2)
    spec = make_trace("wiki")
    np.testing.assert_array_equal(
        got, spec.generate_batch(3000, seeds=[2000, 2001]))


def test_alias_accepts_parameter_overrides():
    spec = make_trace("alibaba(alpha=1.3)")
    assert spec.kwargs["alpha"] == 1.3
    base = make_trace("alibaba")
    assert {k: v for k, v in spec.kwargs.items() if k != "alpha"} == \
        {k: v for k, v in base.kwargs.items() if k != "alpha"}


def test_scan_mix_footprint_is_2N():
    assert make_trace("scan_mix(N=64,alpha=1.0,scan_frac=0.2,"
                      "scan_len=16)").n_keys == 128
    assert make_trace("zipf(N=64,alpha=1.0)").n_keys == 64


def test_trace_spec_passthrough():
    spec = make_trace("zipf(N=64,alpha=1.0)")
    assert make_trace(spec) is spec


# --- make_policy-parity coercion & error contract --------------------------

def test_coercion_to_declared_types():
    """Integer knobs accept "128" and "128.0" identically; float knobs
    accept ints — same contract as make_policy."""
    a = make_trace("zipf(N=128,alpha=1)")
    b = make_trace("zipf(N=128.0,alpha=1.0)")
    assert a == b
    assert isinstance(a.kwargs["N"], int)
    assert isinstance(a.kwargs["alpha"], float)
    c = make_trace("scan_mix(N=64,alpha=1,scan_frac=1,scan_len=8.0)")
    assert isinstance(c.kwargs["scan_frac"], float)
    assert isinstance(c.kwargs["scan_len"], int)


def test_non_integral_float_for_int_param_raises():
    with pytest.raises(ValueError, match="integer"):
        make_trace("zipf(N=64.5,alpha=1.0)")


def test_unknown_param_raises():
    with pytest.raises(ValueError, match="unknown parameter"):
        make_trace("zipf(N=64,alpha=1.0,beta=2)")


def test_unknown_family_raises():
    with pytest.raises(ValueError, match="unknown trace family"):
        make_trace("nosuchfamily")


def test_missing_required_param_raises():
    with pytest.raises(ValueError, match="missing required"):
        make_trace("zipf(N=64)")


def test_positional_args_raise():
    with pytest.raises(ValueError, match="k=v"):
        make_trace("zipf(64)")


def test_runtime_axes_not_spec_settable():
    """T and seed are runtime arguments of generate(), not spec params."""
    with pytest.raises(ValueError, match="unknown parameter"):
        make_trace("zipf(N=64,alpha=1.0,T=100)")
    with pytest.raises(ValueError, match="unknown parameter"):
        make_trace("zipf(N=64,alpha=1.0,seed=1)")
