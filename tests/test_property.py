"""Property-based tests (hypothesis) on system invariants of the policies,
driven through the Request/StepInfo step contract."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import (POLICIES, AdaptiveClimb, DynamicAdaptiveClimb,
                        EMPTY, Request)

SMALL_TRACE = st.lists(st.integers(min_value=0, max_value=40),
                       min_size=1, max_size=300)


def _req(k):
    return Request.of(jnp.int32(k))


def _cache_key_field(state):
    for f in ("cache", "keys"):
        if f in state:
            return np.asarray(state[f])
    return None


@settings(max_examples=15, deadline=None)
@given(trace=SMALL_TRACE, K=st.sampled_from([2, 5, 8]))
def test_no_duplicates_and_hit_is_membership(trace, K):
    """For every policy: cached keys stay unique; hit <=> pre-step membership;
    unit-request StepInfo charges exactly one byte / one cost unit per miss."""
    for name, ctor in POLICIES.items():
        if name in ("twoq", "arc", "lirs"):
            continue  # multi-list/ghost-keeping policies checked below
        pol = ctor()
        st_ = pol.init(K)
        step = jax.jit(pol.step)
        for k in trace:
            pre = _cache_key_field(st_)
            member = bool((pre == k).any())
            st_, info = step(st_, _req(k))
            assert bool(info.hit) == member, (name, k)
            assert int(info.bytes_missed) == (0 if member else 1), (name, k)
            assert float(info.penalty) == (0.0 if member else 1.0), (name, k)
            post = _cache_key_field(st_)
            real = post[post != int(EMPTY)]
            assert len(np.unique(real)) == len(real), (name, post)


@settings(max_examples=15, deadline=None)
@given(trace=SMALL_TRACE, K=st.sampled_from([4, 8]))
def test_multilist_invariants(trace, K):
    """TwoQ/ARC: resident lists are disjoint; ARC |T1|+|T2| <= K, 0<=p<=K."""
    for name in ("twoq", "arc"):
        pol = POLICIES[name]()
        st_ = pol.init(K)
        step = jax.jit(pol.step)
        for k in trace:
            st_, _ = step(st_, _req(k))
            if name == "arc":
                t1 = set(np.asarray(st_["t1k"])) - {int(EMPTY)}
                t2 = set(np.asarray(st_["t2k"])) - {int(EMPTY)}
                b1 = set(np.asarray(st_["b1k"])) - {int(EMPTY)}
                b2 = set(np.asarray(st_["b2k"])) - {int(EMPTY)}
                assert not (t1 & t2) and not (b1 & b2)
                assert not ((t1 | t2) & (b1 | b2))
                assert len(t1) + len(t2) <= K
                assert 0 <= int(st_["p"]) <= K
            else:
                a1 = set(np.asarray(st_["in_keys"])) - {int(EMPTY)}
                am = set(np.asarray(st_["am_keys"])) - {int(EMPTY)}
                assert not (a1 & am)


@settings(max_examples=15, deadline=None)
@given(trace=SMALL_TRACE, K=st.sampled_from([2, 6, 16]))
def test_adaptiveclimb_jump_bounds(trace, K):
    pol = AdaptiveClimb()
    st_ = pol.init(K)
    step = jax.jit(pol.step)
    for k in trace:
        st_, _ = step(st_, _req(k))
        assert 1 <= int(st_["jump"]) <= K


@settings(max_examples=15, deadline=None)
@given(trace=SMALL_TRACE, K=st.sampled_from([4, 8, 16]),
       eps=st.sampled_from([0.25, 0.5, 1.0]))
def test_dac_invariants(trace, K, eps):
    """DAC: k stays in [k_min, K_max] and is K*2^j; jump in [-k/2, 2k];
    jump' in [-k/2, 0]; inactive ranks are EMPTY."""
    pol = DynamicAdaptiveClimb(eps=eps)
    st_ = pol.init(K)
    K_max = K * pol.growth
    step = jax.jit(pol.step)
    valid_ks = {K * 2**j for j in range(-10, 10)
                if 1 <= K * 2**j <= K_max and (K * 2**j) % 1 == 0}
    for k in trace:
        st_, _ = step(st_, _req(k))
        kk = int(st_["k"])
        jump, jump2 = int(st_["jump"]), int(st_["jump2"])
        assert kk in valid_ks
        assert -(kk // 2) <= jump <= 2 * kk
        assert -(kk // 2) <= jump2 <= 0
        cache = np.asarray(st_["cache"])
        assert (cache[kk:] == int(EMPTY)).all()


def test_dac_grows_under_thrash_and_shrinks_under_concentration():
    """End-to-end behavioural check of the resizing control law."""
    pol = DynamicAdaptiveClimb(eps=1.0, growth=8)
    K = 16
    # thrash: cyclic scan over 10*K distinct keys -> all misses -> jump rises
    scan = np.tile(np.arange(10 * K, dtype=np.int32), 20)
    st_ = pol.init(K)
    step = jax.jit(pol.step)
    for k in scan[:600]:
        st_, _ = step(st_, _req(k))
    assert int(st_["k"]) > K, "cache should grow under thrashing"

    # concentration: two hot keys only -> hits at the very top -> shrink
    hot = np.tile(np.arange(2, dtype=np.int32), 400)
    st_ = pol.init(K)
    for k in hot:
        st_, _ = step(st_, _req(k))
    assert int(st_["k"]) < K, "cache should shrink when top half owns all hits"


@settings(max_examples=10, deadline=None)
@given(trace=SMALL_TRACE, K=st.sampled_from([4, 8, 16]))
def test_lirs_invariants(trace, K):
    """LIRS: residents <= K; LIR count <= K - K_hir; ghosts bounded; a hit
    implies pre-step LIR/HIR residency (ghost hits are misses)."""
    from repro.core.lirs_lhd import FREE, GHOST, HIR, LIR
    pol = POLICIES["lirs"]()
    st_ = pol.init(K)
    step = jax.jit(pol.step)
    k_hir = max(1, int(K * pol.hir_frac))
    for k in trace:
        pre_state = np.asarray(st_["state"])
        pre_keys = np.asarray(st_["keys"])
        resident_pre = bool(
            ((pre_keys == k) & ((pre_state == LIR)
                                | (pre_state == HIR))).any())
        st_, info = step(st_, _req(k))
        assert bool(info.hit) == resident_pre
        s = np.asarray(st_["state"])
        keys = np.asarray(st_["keys"])
        assert ((s == LIR) | (s == HIR)).sum() <= K
        assert (s == LIR).sum() <= K - k_hir
        assert (s == GHOST).sum() <= pol.ghost_factor * K
        tracked = keys[s != FREE]
        assert len(np.unique(tracked)) == len(tracked)
