"""Bit-parity lock for the deduplicated Alg. 2 control law.

``repro.core.control`` is the single source of truth for the jump/jump'
/resize scalar arithmetic; both ``repro.core.dynamicadaptiveclimb`` (rank
rows) and ``repro.serving.kv_cache`` (KV slot pools) are thin data-plane
wrappers around it.  These tests drive each wrapper and a straight
control-function mirror through *matched event streams* and require the
scalar trajectories to be bit-identical — any future fork of the
constants (thresholds, saturation bounds, post-resize resets) between
the replay path and the serving path fails here, not in a benchmark.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Engine
from repro.core.control import hit_update, miss_update, resize_update
from repro.serving import kv_cache as kvc


def _kv_scalars(ctrl):
    return (int(ctrl["jump"][0]), int(ctrl["jump2"][0]),
            int(ctrl["k_active"][0]))


@pytest.mark.parametrize("use_caps", [False, True])
def test_kv_pool_matches_control_mirror(use_caps):
    """Drive a 1-sequence KV slot pool through a randomized
    insert/hit/resize event stream; mirror the scalars through the shared
    control functions; require bit-identical (jump, jump', k) at every
    event boundary."""
    rng = np.random.default_rng(0)
    Bmax, k0, eps, k_min = 64, 8, 0.5, 2
    ctrl = kvc.control_init(1, Bmax, k0=k0)
    jump = jnp.int32(k0)
    jump2 = jnp.int32(0)
    k = jnp.int32(k0)

    for t in range(400):
        # --- miss event (every decoded token inserts) ------------------
        ctrl, _ = kvc.insert(ctrl, jnp.full((1,), t, jnp.int32))
        jump, jump2, _ = miss_update(jump, jump2, k)
        assert _kv_scalars(ctrl) == (int(jump), int(jump2), int(k)), t

        # --- optional hit event at a known rank ------------------------
        length = int(ctrl["length"][0])
        if rng.random() < 0.7 and length > 0:
            r = int(rng.integers(0, length))
            slot = ctrl["rank2slot"][0, r]
            ctrl = kvc.hit(ctrl, slot[None])
            jump, jump2, _ = hit_update(jump, jump2, jnp.int32(r), k)
            assert _kv_scalars(ctrl) == (int(jump), int(jump2), int(k)), t

        # --- resize check (after every request) ------------------------
        if use_caps:
            cap = jnp.int32(int(rng.integers(k_min, Bmax + 1)))
            ctrl = kvc.resize(ctrl, eps=eps, k_min=k_min, cap=cap[None])
            k, jump, jump2, _, _ = resize_update(
                jump, jump2, k, eps=eps, k_min=k_min, kmax=Bmax, cap=cap)
        else:
            ctrl = kvc.resize(ctrl, eps=eps, k_min=k_min)
            k, jump, jump2, _, _ = resize_update(
                jump, jump2, k, eps=eps, k_min=k_min, kmax=Bmax)
        assert _kv_scalars(ctrl) == (int(jump), int(jump2), int(k)), t


def test_dac_replay_matches_control_mirror_on_misses():
    """An all-distinct-keys trace never hits, so the core DAC replay's
    (jump, k) trajectory is fully determined by miss_update +
    resize_update — mirror it step by step."""
    T, K, growth = 200, 4, 4
    keys = np.arange(T, dtype=np.int32)        # all cold: pure miss path
    res = Engine().replay("dac(eps=0.5,growth=4)", keys, K=K, observe=True)
    jump = jnp.int32(K)
    jump2 = jnp.int32(0)
    k = jnp.int32(K)
    kmax = K * growth
    for t in range(T):
        jump, jump2, _ = miss_update(jump, jump2, k)
        k, jump, jump2, _, _ = resize_update(
            jump, jump2, k, eps=0.5, k_min=2, kmax=kmax)
        assert int(res.obs["k"][t]) == int(k), t
        assert int(res.obs["jump"][t]) == int(jump), t


def test_resize_update_cap_semantics():
    """cap <= k denies, k < cap < 2k partially grants, cap >= 2k matches
    the un-arbitrated law bit-for-bit."""
    j, j2, k = jnp.int32(16), jnp.int32(0), jnp.int32(8)   # jump == 2k
    deny = resize_update(j, j2, k, eps=0.5, k_min=2, kmax=64,
                         cap=jnp.int32(8))
    assert int(deny[0]) == 8 and not bool(deny[3])
    part = resize_update(j, j2, k, eps=0.5, k_min=2, kmax=64,
                         cap=jnp.int32(11))
    assert int(part[0]) == 11 and bool(part[3])
    full = resize_update(j, j2, k, eps=0.5, k_min=2, kmax=64,
                         cap=jnp.int32(16))
    vanilla = resize_update(j, j2, k, eps=0.5, k_min=2, kmax=64)
    assert [int(x) for x in full[:3]] == [int(x) for x in vanilla[:3]]
