"""Tier conservation laws (hypothesis-free, like test_dac_resize):
under any arbiter the summed active sizes never exceed the global budget,
grants never exceed the free pool, and the static arbiter reproduces N
independent single-cache replays bit-identically."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench import Scenario, TierScenario, TierSweep, results, run_tier_sweep
from repro.core import Engine, make_policy
from repro.data.traces import make_trace, tenants_trace
from repro.tier import (ARBITERS, CacheTier, make_arbiter, replay_tier)

ENGINE = Engine()

N_TENANTS, K0, GROWTH = 4, 8, 4
BUDGET = N_TENANTS * K0 * GROWTH          # static share == K0 * GROWTH


def _mixed_streams(n=N_TENANTS, T=2500, seed=0):
    """[T, n] independent thrash/concentrate streams (grow + shrink both
    fire for every tenant)."""
    def one(rng):
        segs = []
        while sum(len(s) for s in segs) < T:
            wide = rng.random() < 0.5
            segs.append(rng.integers(0, 400 if wide else 3, 150))
        return np.concatenate(segs)[:T].astype(np.int32)
    return np.stack([one(np.random.default_rng(seed * 100 + t))
                     for t in range(n)], axis=1)


# --- law 1: the static arbiter is exact hard partitioning ------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_static_tier_bit_identical_to_independent_replays(use_pallas):
    """arbiter('static') tier replay == N independent Engine.replay calls,
    field-for-field, for both step lowerings."""
    streams = _mixed_streams()
    tier = CacheTier("dac", n_tenants=N_TENANTS, budget=BUDGET,
                     arbiter="static", k0=K0)
    res = replay_tier(tier, streams, use_pallas=use_pallas)
    for t in range(N_TENANTS):
        single = ENGINE.replay(make_policy("dac"), streams[:, t], K0,
                               collect_info=False, use_pallas=use_pallas)
        for field in single.metrics._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res.metrics, field))[t],
                np.asarray(getattr(single.metrics, field)),
                err_msg=f"tenant {t} {field} (use_pallas={use_pallas})")


def test_budgeted_step_with_pinned_cap_matches_step():
    """step_budgeted degenerates to step when cap is pinned at K_max."""
    from repro.core.policy import Request
    pol = make_policy("dac(growth=2)")
    st_a = pol.init(8)
    st_b = dict(pol.init(8), cap=jnp.int32(16))
    rng = np.random.default_rng(3)
    for key in rng.integers(0, 40, 600):
        req = Request.of(jnp.int32(int(key)))
        st_a, info_a = pol.step(st_a, req)
        st_b, info_b = pol.step_budgeted(st_b, req)
        assert int(st_a["k"]) == int(st_b["k"])
        assert int(st_a["jump"]) == int(st_b["jump"])
        assert bool(info_a.hit) == bool(info_b.hit)
        np.testing.assert_array_equal(np.asarray(st_a["cache"]),
                                      np.asarray(st_b["cache"]))


# --- law 2: sum(k) <= budget at every step, under every arbiter ------------

@pytest.mark.parametrize("arbiter", sorted(ARBITERS))
def test_sum_k_never_exceeds_budget(arbiter):
    streams = _mixed_streams(T=3000)
    # a tight budget so grants actually contend
    budget = N_TENANTS * K0 * 2
    if make_arbiter(arbiter).needs_utility:
        # utility-priced arbiters are fleet-only (the fixed-population
        # tier carries no byte-miss-cost signal); their conservation law
        # is enforced in tests/test_fleet.py
        with pytest.raises(ValueError, match="utility"):
            CacheTier("dac", n_tenants=N_TENANTS, budget=budget,
                      arbiter=arbiter, k0=K0)
        return
    tier = CacheTier("dac", n_tenants=N_TENANTS, budget=budget,
                     arbiter=arbiter, k0=K0)
    res = replay_tier(tier, streams, observe=True)
    ks = np.asarray(res.obs["k"])                 # [T, N]
    assert ks.shape == (streams.shape[0], N_TENANTS)
    assert (ks >= tier.policy.k_min).all()
    assert (ks.sum(axis=1) <= budget).all(), (
        f"{arbiter}: sum k peaked at {ks.sum(axis=1).max()} > {budget}")
    # shrinks really do return capacity: the pool was drawn on at least once
    if arbiter != "static":
        assert (ks.max(axis=0) > budget // N_TENANTS).any(), (
            f"{arbiter}: no tenant ever outgrew its static share")


# --- law 3: grants never exceed the free pool ------------------------------

@pytest.mark.parametrize("arbiter", ["greedy", "proportional"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_grants_never_exceed_free_pool(arbiter, seed):
    """Direct arbiter contract on random tier states: caps >= k, granted
    headroom sums to at most budget - sum(k)."""
    arb = make_arbiter(arbiter)
    rng = np.random.default_rng(seed)
    n = 8
    budget = 512
    k = rng.integers(2, budget // n + 1, n).astype(np.int32)
    demanding = rng.random(n) < 0.6
    caps = np.asarray(arb(jnp.asarray(k), jnp.asarray(demanding),
                          budget, n))
    free = budget - k.sum()
    assert (caps >= k).all()
    assert (caps - k).sum() <= max(free, 0)
    assert (caps[~demanding] == k[~demanding]).all()


def test_static_arbiter_caps_bounded_by_share():
    arb = make_arbiter("static")
    k = jnp.asarray(np.array([2, 8, 16, 5], np.int32))
    caps = np.asarray(arb(k, jnp.ones(4, bool), budget=64, n_tenants=4))
    assert (caps <= 16).all()          # share = 64 // 4
    assert (caps >= np.asarray(k)).all()


def test_over_budget_static_share_rejected():
    """An explicit static share above budget // n_tenants would let the
    tenants jointly exceed the budget — CacheTier refuses it."""
    with pytest.raises(ValueError, match="exceeds the budget"):
        CacheTier("dac", n_tenants=2, budget=32, arbiter="static(share=32)")
    # a fair-or-smaller share is fine
    CacheTier("dac", n_tenants=2, budget=32, arbiter="static(share=8)")


def test_tier_budget_regime_letters_are_usable():
    """'S'/'L' budgets resolve to something every tier policy can start
    at (regression: the 'S' floor used to be below DAC's footprint)."""
    sc = TierScenario("f", trace="tenants(N=256,n_tenants=4)", T=100,
                      budget=("S", "L"))
    for B in sc.budgets():
        CacheTier("dac", n_tenants=4, budget=B)   # must not raise


def test_non_resizable_policy_requires_static_arbiter():
    with pytest.raises(ValueError, match="static"):
        CacheTier("lru", n_tenants=2, budget=32, arbiter="greedy")
    tier = CacheTier("lru", n_tenants=2, budget=32, arbiter="static")
    streams = _mixed_streams(n=2, T=500)
    res = replay_tier(tier, streams)
    for t in range(2):
        single = ENGINE.replay("lru", streams[:, t], 16, collect_info=False)
        assert float(np.asarray(res.metrics.hits)[t]) == float(
            np.asarray(single.metrics.hits))


# --- the tenants(...) trace family -----------------------------------------

def test_tenants_trace_registry_round_trip():
    spec = make_trace("tenants(N=128,n_tenants=4)")
    assert spec.is_tier and spec.n_tenants == 4 and spec.n_keys == 128
    assert make_trace(str(spec)) == spec
    keys = spec.generate(T=200, seed=1)
    assert keys.shape == (200, 4) and keys.dtype == np.int32
    np.testing.assert_array_equal(keys, spec.generate(T=200, seed=1))
    batch = spec.generate_batch(T=100, seeds=(0, 1))
    assert batch.shape == (2, 100, 4)
    assert (keys >= 0).all() and (keys < 128).all()


def test_tenants_phase_shift_rotates_wide_phase():
    """Phase shifting staggers the wide phases: per window, the tenant with
    the largest distinct-key count rotates."""
    keys = tenants_trace(N=256, T=4000, n_tenants=4, alpha=0.5,
                         period=4000, duty=0.25, lo=8, seed=0)
    widest = [int(np.argmax([len(np.unique(keys[lo:lo + 1000, t]))
                             for t in range(4)]))
              for lo in range(0, 4000, 1000)]
    assert sorted(widest) == [0, 1, 2, 3], widest


def test_scenario_rejects_tier_family_and_vice_versa():
    with pytest.raises(ValueError, match="TierScenario"):
        Scenario("x", trace="tenants(N=64,n_tenants=2)", T=100)
    with pytest.raises(ValueError, match="multi-tenant"):
        TierScenario("x", trace="zipf(N=64,alpha=1.0)", T=100)


def test_replay_tier_shape_validation():
    tier = CacheTier("dac", n_tenants=4, budget=64)
    with pytest.raises(ValueError, match="n_tenants"):
        replay_tier(tier, np.zeros((100, 3), np.int32))
    with pytest.raises(ValueError, match="T, N"):
        replay_tier(tier, np.zeros((100,), np.int32))


# --- tier sweep machinery ---------------------------------------------------

def _tiny_sweep(seeds=(0, 1)):
    sc = TierScenario(
        "flux", trace="tenants(N=64,n_tenants=2,period=512,lo=8)",
        T=600, budget=(32,))
    return TierSweep("tiny", entries=(("dac", "greedy"), ("lru", "static")),
                     scenarios=(sc,), seeds=seeds)


def test_tier_sweep_config_round_trip():
    sw = _tiny_sweep()
    assert TierSweep.from_config(sw.to_config()) == sw


def test_run_tier_sweep_records_and_v2_schema():
    res = run_tier_sweep(_tiny_sweep())
    assert len(res.records) == 2
    payload = res.payload()
    assert payload["schema"] == results.SCHEMA_V2
    results.validate(payload)
    rec = res.select(policy="dac", arbiter="greedy")[0]
    assert rec["n_tenants"] == 2 and rec["budget"] == 32
    assert len(rec["tenants"]) == 2
    for ten in rec["tenants"]:
        assert len(ten["metrics"]["miss_ratio"]) == 2   # per-seed lists
        assert len(ten["metrics"]["avg_k"]) == 2


def test_run_tier_sweep_matches_per_seed_loop():
    """Seed-vmapped tier cells == per-seed replay_tier loop."""
    sw = _tiny_sweep(seeds=(0, 1, 2))
    res = run_tier_sweep(sw)
    rec = res.select(policy="dac", arbiter="greedy")[0]
    sc = sw.scenarios[0]
    spec = make_trace(sc.trace)
    tier = CacheTier("dac", n_tenants=2, budget=32, arbiter="greedy")
    for i, seed in enumerate(sw.seeds):
        single = replay_tier(tier, spec.generate(sc.T, seed=seed))
        assert rec["metrics"]["miss_ratio"][i] == float(
            np.asarray(single.agg_miss_ratio))
        for ten in rec["tenants"]:
            assert ten["metrics"]["miss_ratio"][i] == float(
                np.asarray(single.miss_ratio)[ten["tenant"]])


def test_v1_schema_rejects_tenant_records():
    payload = results.build_payload(
        "x", config={}, records=[
            {"metrics": {"miss_ratio": [0.1]}, "seeds": [0],
             "tenants": [{"tenant": 0, "metrics": {"miss_ratio": [0.1]}}]}])
    with pytest.raises(ValueError, match="v2"):
        results.validate(payload)


def test_v2_schema_rejects_malformed_tenants():
    def v2(records):
        return results.build_payload("x", config={}, records=records,
                                     schema=results.SCHEMA_V2)
    good = {"metrics": {"m": [0.1]}, "seeds": [0],
            "tenants": [{"tenant": 0, "metrics": {"m": [0.1]}}]}
    results.validate(v2([good]))
    bad_missing = {"metrics": {"m": [0.1]},
                   "tenants": [{"metrics": {"m": [0.1]}}]}
    with pytest.raises(ValueError, match="tenant"):
        results.validate(v2([bad_missing]))
    bad_len = {"metrics": {"m": [0.1]}, "seeds": [0],
               "tenants": [{"tenant": 0, "metrics": {"m": [0.1, 0.2]}}]}
    with pytest.raises(ValueError, match="len"):
        results.validate(v2([bad_len]))
