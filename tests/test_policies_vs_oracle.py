"""Every vectorized policy must reproduce the literal-pseudocode oracle
hit-for-hit on adversarial traces."""
import numpy as np
import pytest

from repro.core import POLICIES
from repro.core.oracle import ORACLES
from repro.core.simulator import replay
from repro.data.traces import scan_mix_trace, shifting_zipf_trace, zipf_trace

POLICY_NAMES = sorted(POLICIES.keys())


def _traces():
    out = {
        "zipf_small_universe": zipf_trace(N=32, T=1500, alpha=0.9, seed=1),
        "zipf_big_universe": zipf_trace(N=4096, T=1500, alpha=0.8, seed=2),
        "shifting": shifting_zipf_trace(N=256, T=1500, alpha=1.1, phases=5,
                                        seed=3),
        "scans": scan_mix_trace(N=128, T=1500, alpha=1.0, scan_frac=0.3,
                                scan_len=64, seed=4),
        "uniform": np.random.default_rng(5).integers(
            0, 64, size=1500).astype(np.int32),
        "repeat_heavy": np.tile(np.arange(7, dtype=np.int32), 200),
    }
    return out


@pytest.mark.parametrize("policy_name", POLICY_NAMES)
@pytest.mark.parametrize("K", [4, 16, 33])
def test_matches_oracle(policy_name, K):
    policy = POLICIES[policy_name]()
    oracle_cls = ORACLES[policy_name]
    for tname, trace in _traces().items():
        oracle = oracle_cls(K)
        expected = np.array([oracle.step(int(k)) for k in trace])
        got = np.asarray(replay(policy, trace, K))
        mism = np.nonzero(expected != got)[0]
        assert mism.size == 0, (
            f"{policy_name} K={K} trace={tname}: first mismatch at "
            f"t={mism[0] if mism.size else None} "
            f"(oracle={expected[mism[:5]]}, jax={got[mism[:5]]})")


@pytest.mark.parametrize("eps", [0.25, 0.5, 1.0])
def test_dac_eps_matches_oracle(eps):
    from repro.core import DynamicAdaptiveClimb
    from repro.core.oracle import OracleDynamicAdaptiveClimb
    K = 16
    trace = shifting_zipf_trace(N=200, T=3000, alpha=1.2, phases=6, seed=7)
    oracle = OracleDynamicAdaptiveClimb(K, eps=eps)
    expected = np.array([oracle.step(int(k)) for k in trace])
    got = np.asarray(replay(DynamicAdaptiveClimb(eps=eps), trace, K))
    assert (expected == got).all()
