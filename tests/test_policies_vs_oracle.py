"""Every vectorized policy must reproduce the literal-pseudocode oracle
hit-for-hit on adversarial traces (replayed through the unified Engine)."""
import numpy as np
import pytest

from repro.core import Engine, POLICIES, make_policy
from repro.core.oracle import ORACLES, oracle_replay
from repro.data.traces import (object_sizes, scan_mix_trace,
                               shifting_zipf_trace, zipf_trace)

POLICY_NAMES = sorted(POLICIES.keys())
ENGINE = Engine()


def _traces():
    out = {
        "zipf_small_universe": zipf_trace(N=32, T=1500, alpha=0.9, seed=1),
        "zipf_big_universe": zipf_trace(N=4096, T=1500, alpha=0.8, seed=2),
        "shifting": shifting_zipf_trace(N=256, T=1500, alpha=1.1, phases=5,
                                        seed=3),
        "scans": scan_mix_trace(N=128, T=1500, alpha=1.0, scan_frac=0.3,
                                scan_len=64, seed=4),
        "uniform": np.random.default_rng(5).integers(
            0, 64, size=1500).astype(np.int32),
        "repeat_heavy": np.tile(np.arange(7, dtype=np.int32), 200),
    }
    return out


@pytest.mark.parametrize("policy_name", POLICY_NAMES)
@pytest.mark.parametrize("K", [4, 16, 33])
def test_matches_oracle(policy_name, K):
    policy = make_policy(policy_name)
    oracle_cls = ORACLES[policy_name]
    for tname, trace in _traces().items():
        oracle = oracle_cls(K)
        expected = np.array([oracle.step(int(k)) for k in trace])
        got = np.asarray(ENGINE.replay(policy, trace, K).hits)
        mism = np.nonzero(expected != got)[0]
        assert mism.size == 0, (
            f"{policy_name} K={K} trace={tname}: first mismatch at "
            f"t={mism[0] if mism.size else None} "
            f"(oracle={expected[mism[:5]]}, jax={got[mism[:5]]})")


@pytest.mark.parametrize("eps", [0.25, 0.5, 1.0])
def test_dac_eps_matches_oracle(eps):
    from repro.core.oracle import OracleDynamicAdaptiveClimb
    K = 16
    trace = shifting_zipf_trace(N=200, T=3000, alpha=1.2, phases=6, seed=7)
    oracle = OracleDynamicAdaptiveClimb(K, eps=eps)
    expected = np.array([oracle.step(int(k)) for k in trace])
    got = np.asarray(ENGINE.replay(f"dac(eps={eps})", trace, K).hits)
    assert (expected == got).all()


@pytest.mark.parametrize("policy_name", ["lru", "arc",
                                         "dynamicadaptiveclimb"])
def test_sized_metrics_match_oracle(policy_name):
    """Engine-native byte-miss/penalty aggregates == the plain-Python
    oracle replay weighted by the same per-object sizes."""
    K = 16
    trace = shifting_zipf_trace(N=128, T=2000, alpha=1.0, phases=4, seed=9)
    sizes = object_sizes(128, seed=9)[trace]
    res = ENGINE.replay(policy_name, trace, K, sizes=sizes, costs=sizes)
    ref = oracle_replay(policy_name, trace, K, sizes=sizes, costs=sizes)
    np.testing.assert_array_equal(np.asarray(res.hits), ref["hits"])
    assert res.miss_ratio == pytest.approx(ref["miss_ratio"], rel=1e-6)
    assert res.byte_miss_ratio == pytest.approx(ref["byte_miss_ratio"],
                                                rel=1e-5)
    assert res.total_penalty == pytest.approx(ref["penalty"], rel=1e-5)
