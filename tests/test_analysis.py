"""The static-analysis subsystem, proven on itself and on fixtures.

Three layers of coverage:

* every AST lint rule demonstrated on a small synthetic bad/good fixture
  pair, plus the waiver machinery (same-line, line-above, stale-waiver
  audit, docstring inertness);
* the jaxpr contract pass over the full policy registry x both Pallas
  modes — and deliberately-broken toy policies that each trip exactly
  the check built to catch them (carry drift, debug callback, unpadded
  row, missing ADAPT_KEYS);
* the retrace auditor caught red-handed by a weak-typed toy step, and
  clean on the real engine; ``tools/repolint.py --lint-only`` exits 0 on
  the repo itself.
"""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (Finding, audit_engine, audit_jit, check_fleet,
                            check_policy, check_tier, lint_source,
                            lint_tree, registry_specs, verify_contracts)
from repro.analysis.contracts import FORBIDDEN_PRIMITIVES
from repro.bench import results
from repro.core import POLICIES
from repro.core.policy import EMPTY, LANE, Policy, Request, padded_row

ROOT = Path(__file__).resolve().parents[1]


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# level 2: AST lint rules on fixtures
# ---------------------------------------------------------------------------

class TestLintRules:
    def test_wallclock_bad(self):
        src = "import time\ndef f():\n    return time.time()\n"
        assert rules_of(lint_source(src, path="m.py")) == ["wallclock"]

    def test_wallclock_datetime(self):
        src = ("import datetime\n"
               "stamp = datetime.datetime.now()\n")
        assert rules_of(lint_source(src, path="m.py")) == ["wallclock"]

    def test_wallclock_good_perf_counter(self):
        src = "import time\nt0 = time.perf_counter()\n"
        assert lint_source(src, path="m.py") == []

    def test_unseeded_rng_legacy_numpy(self):
        src = "import numpy as np\nx = np.random.rand(3)\n"
        assert rules_of(lint_source(src, path="m.py")) == ["unseeded-rng"]

    def test_unseeded_rng_stdlib(self):
        src = "import random\nx = random.choice([1, 2])\n"
        assert rules_of(lint_source(src, path="m.py")) == ["unseeded-rng"]

    def test_unseeded_default_rng(self):
        bad = "import numpy as np\nrng = np.random.default_rng()\n"
        good = "import numpy as np\nrng = np.random.default_rng(17)\n"
        assert rules_of(lint_source(bad, path="m.py")) == ["unseeded-rng"]
        assert lint_source(good, path="m.py") == []

    def test_seeded_generator_draws_pass(self):
        src = ("import numpy as np\nrng = np.random.default_rng(0)\n"
               "x = rng.normal(size=3)\ny = rng.choice([1, 2])\n")
        assert lint_source(src, path="m.py") == []

    def test_jax_random_is_seeded_by_key(self):
        src = ("import jax\n"
               "x = jax.random.normal(jax.random.PRNGKey(0), (3,))\n")
        assert lint_source(src, path="m.py") == []

    def test_schema_literal_bad(self):
        src = f'schema = "{results.SCHEMA_V1}"\n'
        assert rules_of(lint_source(src, path="m.py")) == ["schema-literal"]

    def test_schema_literal_docstring_exempt(self):
        src = f'"""Payloads use ``{results.SCHEMA_V2}`` records."""\n'
        assert lint_source(src, path="m.py") == []

    def test_schema_literal_defining_module_exempt(self):
        src = f'SCHEMA_V1 = "{results.SCHEMA_V1}"\n'
        assert lint_source(src, path="src/repro/bench/results.py") == []
        assert rules_of(lint_source(src, path="other.py")) == [
            "schema-literal"]

    def test_empty_sentinel_bad(self):
        src = "import jax.numpy as jnp\nx = jnp.int32(-1)\n"
        assert rules_of(lint_source(src, path="m.py")) == ["empty-sentinel"]

    def test_empty_sentinel_other_values_pass(self):
        src = ("import jax.numpy as jnp\n"
               "a = jnp.int32(-2)\nb = jnp.int32(0)\nc = jnp.float32(-1)\n")
        assert lint_source(src, path="m.py") == []

    def test_atomic_json_bad(self):
        src = ("import json\ndef save(p, d):\n"
               "    with open(p, 'w') as f:\n        json.dump(d, f)\n")
        assert rules_of(lint_source(src, path="m.py")) == ["atomic-json"]

    def test_atomic_json_writer_body_exempt(self):
        src = ("import json\ndef atomic_write_json(p, d):\n"
               "    with open(p, 'w') as f:\n        json.dump(d, f)\n")
        assert lint_source(src, path="m.py") == []

    def test_json_dumps_passes(self):
        src = "import json\ns = json.dumps({'a': 1})\n"
        assert lint_source(src, path="m.py") == []

    def test_traced_branch_bad(self):
        src = ("import jax.numpy as jnp\ndef f(x):\n"
               "    if jnp.any(x > 0):\n        return 1\n    return 0\n")
        assert rules_of(lint_source(src, path="m.py")) == ["traced-branch"]

    def test_traced_branch_while(self):
        src = ("import jax.numpy as jnp\ndef f(x):\n"
               "    while jnp.sum(x) > 0:\n        x = x - 1\n")
        assert rules_of(lint_source(src, path="m.py")) == ["traced-branch"]

    def test_traced_branch_metadata_ok(self):
        src = ("import jax.numpy as jnp\ndef f(x):\n"
               "    if jnp.dtype(x.dtype) == jnp.dtype(jnp.int32):\n"
               "        return 1\n    return 0\n")
        assert lint_source(src, path="m.py") == []


class TestWaivers:
    BAD = "import time\nt = time.time()"

    def test_same_line_waiver(self):
        src = ("import time\n"
               "t = time.time()  # repolint: waive[wallclock] -- stamp\n")
        assert lint_source(src, path="m.py") == []

    def test_line_above_waiver(self):
        src = ("import time\n"
               "# repolint: waive[wallclock] -- provenance stamp\n"
               "t = time.time()\n")
        assert lint_source(src, path="m.py") == []

    def test_waiver_is_rule_specific(self):
        src = ("import time\n"
               "t = time.time()  # repolint: waive[atomic-json] -- wrong\n")
        assert rules_of(lint_source(src, path="m.py")) == [
            "unused-waiver", "wallclock"]

    def test_stale_waiver_reported(self):
        src = "x = 1  # repolint: waive[wallclock] -- nothing here\n"
        fs = lint_source(src, path="m.py")
        assert rules_of(fs) == ["unused-waiver"]
        assert fs[0].where == "m.py:1"

    def test_waiver_in_docstring_is_inert(self):
        src = ('"""Docs show `# repolint: waive[wallclock]` syntax."""\n'
               "import time\nt = time.time()\n")
        assert rules_of(lint_source(src, path="m.py")) == ["wallclock"]

    def test_multi_rule_waiver(self):
        src = ("import time, json\n"
               "# repolint: waive[wallclock,atomic-json] -- demo\n"
               "t = json.dump({'t': time.time()}, open('x', 'w'))\n")
        assert lint_source(src, path="m.py") == []


def test_repo_lint_is_clean():
    """The repo itself carries no unwaived findings (the CI gate)."""
    assert lint_tree(ROOT) == []


def test_finding_renders():
    f = Finding("wallclock", "a.py:3", "boom")
    assert str(f) == "a.py:3: [wallclock] boom"


# ---------------------------------------------------------------------------
# level 1: jaxpr contracts over the registry
# ---------------------------------------------------------------------------

def test_registry_specs_cover_everything():
    specs = registry_specs()
    assert len(specs) == 2 * len(POLICIES) == 30
    assert all(f"admit({n})" in specs for n in POLICIES)


@pytest.mark.parametrize("use_pallas", [False, "interpret"])
@pytest.mark.parametrize("spec", registry_specs())
def test_policy_contracts(spec, use_pallas):
    assert check_policy(spec, use_pallas=use_pallas) == []


@pytest.mark.parametrize("use_pallas", [False, "interpret"])
@pytest.mark.parametrize("spec", ["dynamicadaptiveclimb",
                                  "admit(dynamicadaptiveclimb)"])
def test_budgeted_contracts(spec, use_pallas):
    assert check_policy(spec, use_pallas=use_pallas, budgeted=True) == []


@pytest.mark.parametrize("use_pallas", [False, "interpret"])
def test_tier_and_fleet_contracts(use_pallas):
    assert check_tier(use_pallas=use_pallas) == []
    assert check_fleet(use_pallas=use_pallas) == []


def test_x64_subpass_is_clean():
    from jax.experimental import enable_x64
    with enable_x64():
        for spec in ("dynamicadaptiveclimb", "lru", "hyperbolic", "lhd"):
            assert check_policy(spec) == []


# --- toy policies that each violate exactly one contract -------------------

class _ToyRank(Policy):
    """Minimal well-formed rank-row policy (the control fixture)."""

    name = "toyrank"

    def init(self, K):
        return {"cache": padded_row(K)}

    def step(self, state, req):
        from repro.core.policy import step_info
        cache = state["cache"]
        hit = jnp.any(cache == req.key)
        cache = jnp.where(hit, cache, cache.at[0].set(req.key))
        return {"cache": cache}, step_info(hit, req)


class _CarryDrift(_ToyRank):
    name = "carrydrift"

    def step(self, state, req):
        new, info = super().step(state, req)
        return {"cache": new["cache"].astype(jnp.float32)}, info


class _StructureDrift(_ToyRank):
    name = "structuredrift"

    def step(self, state, req):
        new, info = super().step(state, req)
        return dict(new, extra=jnp.int32(0)), info


class _DebugCallback(_ToyRank):
    name = "debugcallback"

    def step(self, state, req):
        jax.debug.print("key={k}", k=req.key)
        return super().step(state, req)


class _UnpaddedRow(_ToyRank):
    name = "unpaddedrow"

    def init(self, K):
        return {"cache": jnp.full((K,), EMPTY, jnp.int32)}


class _MissingAdaptKeys(_ToyRank):
    name = "missingadapt"
    ADAPT_KEYS = ("jump",)


def test_toy_control_fixture_is_clean():
    assert check_policy(_ToyRank()) == []


def test_carry_aval_drift_caught():
    fs = check_policy(_CarryDrift())
    assert "carry-aval" in rules_of(fs)
    assert any("float32" in f.message for f in fs)


def test_carry_structure_drift_caught():
    assert "carry-structure" in rules_of(check_policy(_StructureDrift()))


def test_forbidden_primitive_caught():
    fs = check_policy(_DebugCallback())
    assert "forbidden-primitive" in rules_of(fs)
    assert any(p in f.message for f in fs for p in FORBIDDEN_PRIMITIVES)


def test_unpadded_row_caught():
    K = 5
    assert LANE % K  # K itself must not be lane-aligned for this fixture
    fs = check_policy(_UnpaddedRow(), K=K)
    assert "row-width" in rules_of(fs)


def test_missing_adapt_keys_caught():
    assert "adapt-keys" in rules_of(check_policy(_MissingAdaptKeys()))


def test_full_verify_contracts_is_clean():
    """The whole CI contract pass (registry x modes, budgeted paths,
    tier/fleet, x64 sub-pass) on the real repo."""
    assert verify_contracts() == []


# ---------------------------------------------------------------------------
# retrace auditor
# ---------------------------------------------------------------------------

def test_audit_jit_clean_on_stable_keys():
    f = jax.jit(lambda x: x * 2)
    fs = audit_jit(f, "toy",
                   prime=[("i32", lambda: f(jnp.int32(1)))],
                   variants=[("same-aval", lambda: f(jnp.int32(9)))],
                   expected=1)
    assert fs == []


def test_audit_jit_catches_weak_typed_call():
    """The classic cache-key bug: a Python scalar where an int32 array
    primed the cache retraces silently — the auditor must see it."""
    f = jax.jit(lambda x: x + 1)
    fs = audit_jit(f, "toy",
                   prime=[("i32", lambda: f(jnp.int32(1)))],
                   variants=[("weak-python-int", lambda: f(1))])
    assert rules_of(fs) == ["retrace"]


def test_audit_jit_expected_count_mismatch():
    f = jax.jit(lambda x: x + 1)
    fs = audit_jit(f, "toy",
                   prime=[("i32", lambda: f(jnp.int32(1)))],
                   variants=[], expected=2)
    assert rules_of(fs) == ["retrace-count"]


def test_engine_retrace_audit_is_clean():
    findings, report = audit_engine()
    assert findings == []
    assert report == {"_replay_single": 4, "_replay_batched": 3,
                      "_replay_chunk": 2}


def test_engine_audit_catches_unstable_policy_key():
    """A policy whose instances compare by identity (no value __eq__)
    retraces on every equal-but-fresh instance — exactly what the
    variant sweep exists to catch."""

    class IdentityPolicy(_ToyRank):
        name = "identitytoy"
        __hash__ = object.__hash__
        __eq__ = object.__eq__

    from repro.core.simulator import Engine, _replay_single
    eng = Engine()
    keys = jnp.arange(8, dtype=jnp.int32) % 3
    fs = audit_jit(
        _replay_single, "engine._replay_single",
        prime=[("a", lambda: eng.replay(IdentityPolicy(), keys, 4))],
        variants=[("fresh equal instance",
                   lambda: eng.replay(IdentityPolicy(), keys, 4))])
    assert rules_of(fs) == ["retrace"]


# ---------------------------------------------------------------------------
# the CLI gate and the schema constants satellite
# ---------------------------------------------------------------------------

def test_repolint_lint_only_exits_clean():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "repolint.py"),
         "--lint-only"], capture_output=True, text=True, cwd=ROOT)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "lint: 0 finding(s)" in proc.stdout


def test_schema_constants_are_canonical():
    assert results.SCHEMA_VERSION == results.SCHEMA_V1
    assert results.SCHEMA_VERSIONS == (results.SCHEMA_V1,
                                       results.SCHEMA_V2)
    assert results.SCHEMA_V1.endswith("/v1")
    assert results.SCHEMA_V2.endswith("/v2")
    # the validator accepts exactly the canonical pair
    with pytest.raises(ValueError):
        results.build_payload("x", config={}, records=[],
                              schema=results.SCHEMA_V1 + "x")
