"""Hypothesis import guard shared by the test modules.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When
it is absent, property-based tests must *skip* — not kill collection of the
whole module (the seed repo hard-imported hypothesis and tier-1 died at
collection).  Import ``given``/``settings``/``st`` from here: with
hypothesis installed they are the real thing; without it, ``given`` marks
the test skipped and ``st``/``settings`` are inert decoration-time stubs.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def wrap(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return wrap

    def settings(*_a, **_k):
        def wrap(fn):
            return fn
        return wrap

    class _StrategyStub:
        """Accepts any strategy-building call chain at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
