"""Campaign orchestrator: manifest grammar, crash-safe store semantics,
resume/shard partitioning, quarantine isolation, and the store-only
report layer.  The centerpiece is the crash-restart drill: a campaign
killed after N cells and resumed must produce a ``cells/`` tree
bit-identical to an uninterrupted run, with no cell executed twice."""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.bench import report as bench_report
from repro.bench import results
from repro.campaign import (CampaignStore, Cell, Dataset, Grid, Manifest,
                            cell_key, dataset_winners, load_manifest,
                            pending_cells, plan_cells, render_report,
                            run_campaign, scan_corpus, shard_cells)
from repro.campaign.report import campaign_records, format_report
from repro.data import ingest

ROOT = pathlib.Path(__file__).resolve().parent.parent
CORPUS = ROOT / "benchmarks" / "corpus"

GRID = Grid(policies=("fifo", "lru"), K=(64,), seeds=(0,), T=2000)


@pytest.fixture(scope="module")
def corpus_manifest():
    return scan_corpus(str(CORPUS), name="mini", grid=GRID)


@pytest.fixture(scope="module")
def full_store(tmp_path_factory, corpus_manifest):
    """One uninterrupted run over the committed corpus — the reference
    store for the bit-identity drill and the report tests."""
    store = CampaignStore(str(tmp_path_factory.mktemp("full") / "store"))
    summary = run_campaign(corpus_manifest, store)
    assert summary.counts["quarantined"] == 0
    assert summary.counts["remaining"] == 0
    return store


# --- manifest grammar -------------------------------------------------------

def test_grid_validation():
    with pytest.raises(ValueError, match="at least one policy"):
        Grid(policies=())
    with pytest.raises(ValueError, match="regime letters"):
        Grid(policies=("lru",), K=("M",))
    with pytest.raises(ValueError, match="positive cap"):
        Grid(policies=("lru",), T=0)
    # ints and regime letters coexist, coerced
    g = Grid(policies=("lru",), K=("S", "64"), seeds=("3",))
    assert g.K == ("S", 64) and g.seeds == (3,)


def test_manifest_roundtrip_and_validation():
    m = Manifest(name="demo", root=".", grid=Grid(policies=("lru",)),
                 datasets=(Dataset(name="d", glob="*.csv"),))
    assert Manifest.from_dict(m.to_dict()) == m
    with pytest.raises(ValueError, match="schema"):
        Manifest.from_dict(dict(m.to_dict(), schema="nope/v9"))
    with pytest.raises(ValueError, match="unique"):
        Manifest(name="demo", root=".", grid=Grid(policies=("lru",)),
                 datasets=(Dataset(name="d", glob="*.csv"),
                           Dataset(name="d", glob="*.txt")))
    with pytest.raises(ValueError, match="glob.*or.*traces"):
        Dataset(name="empty")


def test_manifest_empty_glob_is_an_error(tmp_path):
    m = Manifest(name="demo", root=str(tmp_path),
                 grid=Grid(policies=("lru",)),
                 datasets=(Dataset(name="d", glob="*.nothere"),))
    with pytest.raises(ValueError, match="matched no trace files"):
        m.traces()


def test_load_manifest_reanchors_relative_root(tmp_path):
    traces = tmp_path / "traces"
    traces.mkdir()
    ingest.write_csv(str(traces / "a.csv"), [1, 2, 1], [10, 20, 10])
    m = Manifest(name="demo", root="traces",
                 grid=Grid(policies=("lru",)),
                 datasets=(Dataset(name="d", glob="*.csv"),))
    m.save(str(tmp_path / "campaign.json"))
    loaded = load_manifest(str(tmp_path / "campaign.json"))
    assert os.path.isabs(loaded.root)
    [(ds, path, fmt)] = loaded.traces()
    assert (ds, os.path.basename(path)) == ("d", "a.csv")


def test_scan_corpus_groups_and_freezes_stats(corpus_manifest):
    names = {d.name for d in corpus_manifest.datasets}
    assert names == {"csv", "oracle", "txt"}       # grouped by format
    all_traces = [p for d in corpus_manifest.datasets for p, _ in d.traces]
    # the plain .bin with a committed .gz twin is skipped, not duplicated
    assert not any(p.endswith(".oracleGeneral.bin") for p in all_traces)
    for d in corpus_manifest.datasets:
        for rel, _ in d.traces:
            assert d.stats[rel]["n_requests"] > 0   # frozen characterization


# --- store ------------------------------------------------------------------

def _tiny_payload(wall=1.5):
    return results.build_payload(
        "cell", config={}, wall_s=wall, schema=results.SCHEMA_V2,
        records=[{"metrics": {"miss_ratio": [0.5]}, "seeds": [0],
                  "wall_s": 0.7}])


def test_store_put_normalizes_and_get_revalidates(tmp_path):
    store = CampaignStore(str(tmp_path / "s"))
    path = store.put("aaaa", _tiny_payload())
    on_disk = json.load(open(path))
    assert on_disk["created_unix"] == 0.0 and on_disk["wall_s"] == 0.0
    assert on_disk["records"][0]["wall_s"] == 0.0
    assert store.get("aaaa")["schema"] == results.SCHEMA_V2
    assert store.completed() == ["aaaa"]
    # volatile fields zeroed identically regardless of actual timings
    store.put("bbbb", _tiny_payload(wall=99.0))
    a, b = (open(store.path_for(k)).read() for k in ("aaaa", "bbbb"))
    assert a == b
    assert not [f for f in os.listdir(store.cells_dir) if ".tmp." in f]


def test_store_rejects_invalid_payloads(tmp_path):
    store = CampaignStore(str(tmp_path / "s"))
    with pytest.raises(ValueError, match="schema"):
        store.put("aaaa", {"schema": "bogus"})
    assert not store.has("aaaa")                   # nothing landed


def test_store_pins_manifest_and_rejects_mismatch(tmp_path):
    store = CampaignStore(str(tmp_path / "s"))
    m1 = Manifest(name="a", root=".", grid=Grid(policies=("lru",)),
                  datasets=(Dataset(name="d", glob="*.csv"),))
    store.init_manifest(m1)
    store.init_manifest(m1)                        # idempotent
    m2 = dataclasses.replace(m1, grid=Grid(policies=("fifo",)))
    with pytest.raises(ValueError, match="different.*manifest"):
        store.init_manifest(m2)


# --- planning, sharding, resume --------------------------------------------

def test_shards_partition_the_plan(corpus_manifest):
    cells = plan_cells(corpus_manifest)
    assert len(cells) == len({cell_key(c) for c in cells}) == 6
    shards = [shard_cells(cells, f"{i}/3") for i in range(3)]
    keys = [{cell_key(c) for c in s} for s in shards]
    assert set.union(*keys) == {cell_key(c) for c in cells}
    for i in range(3):
        for j in range(i + 1, 3):
            assert keys[i].isdisjoint(keys[j])


def test_crash_restart_is_bit_identical(tmp_path, corpus_manifest,
                                        full_store):
    """The satellite drill: kill a campaign after 2 cells (the cell-budget
    hook), restart it, and the final store is byte-for-byte the store of
    an uninterrupted run — and no cell ran twice."""
    store = CampaignStore(str(tmp_path / "store"))
    first = run_campaign(corpus_manifest, store, max_cells=2)
    assert first.counts == {"total": 6, "skipped": 0, "executed": 2,
                            "quarantined": 0, "remaining": 4}
    # "restart": a fresh handle on the same directory, no carried state
    resumed = run_campaign(corpus_manifest, CampaignStore(store.root))
    assert resumed.counts["skipped"] == 2
    assert resumed.counts["remaining"] == 0
    e1, e2 = set(first.executed), set(resumed.executed)
    assert e1.isdisjoint(e2) and e1 | e2 == set(full_store.completed())
    # the journal agrees nothing executed twice across both invocations
    done = [json.loads(l)["key"]
            for l in open(os.path.join(store.root, store.JOURNAL))
            if json.loads(l)["event"] == "done"]
    assert len(done) == len(set(done)) == 6
    # bit-identity of the cells/ tree vs the uninterrupted reference
    fa = sorted(os.listdir(os.path.join(full_store.root, "cells")))
    fb = sorted(os.listdir(os.path.join(store.root, "cells")))
    assert fa == fb
    for fn in fa:
        ref = open(os.path.join(full_store.root, "cells", fn), "rb").read()
        got = open(os.path.join(store.root, "cells", fn), "rb").read()
        assert ref == got, f"cell file {fn} differs after crash-restart"


def test_quarantine_keeps_campaign_alive_and_sticks(tmp_path):
    corpus = tmp_path / "corpus"
    corpus.mkdir()
    ingest.write_csv(str(corpus / "good.csv"), [1, 2, 1, 3], [8, 8, 8, 8])
    # 10 bytes is not a whole number of 24-byte oracle records
    (corpus / "bad.oracleGeneral.bin").write_bytes(b"\x00" * 10)
    m = scan_corpus(str(corpus), name="q",
                    grid=Grid(policies=("lru",), K=(4,), seeds=(0,)),
                    dataset="d", characterize=False)
    store = CampaignStore(str(tmp_path / "store"))
    summary = run_campaign(m, store)
    assert len(summary.executed) == 1 and len(summary.quarantined) == 1
    q = store.get_quarantined(summary.quarantined[0])
    assert "Traceback" in q["error"]
    assert q["cell"]["trace"].endswith("bad.oracleGeneral.bin")
    # resume: the quarantined cell is not retried, nothing is pending
    assert pending_cells(plan_cells(m), store) == []
    again = run_campaign(m, store)
    assert again.counts["executed"] == 0 and again.counts["skipped"] == 2


def test_workers_spawn_pool(tmp_path, corpus_manifest):
    """A 2-worker process pool completes the same cells as inline runs
    (spawn context; results land via the shared store directory)."""
    grid = Grid(policies=("fifo", "lru"), K=(32,), seeds=(0,), T=800)
    m = dataclasses.replace(
        corpus_manifest, grid=grid,
        datasets=tuple(d for d in corpus_manifest.datasets
                       if d.name == "txt"))
    store = CampaignStore(str(tmp_path / "store"))
    summary = run_campaign(m, store, workers=2)
    assert summary.counts["executed"] == 2
    assert summary.counts["quarantined"] == 0
    assert len(store.completed()) == 2


# --- winners tie-break / margin + CDF (bench.report satellites) ------------

def _rec(policy, scenario, miss):
    return {"policy": policy, "scenario": scenario, "K_label": "S",
            "seeds": [0], "dataset": "d",
            "metrics": {"miss_ratio": [miss], "hit_ratio": [1 - miss],
                        "byte_miss_ratio": [miss], "penalty_ratio": [miss]}}


def test_winners_tie_breaks_lexicographically_with_margin():
    recs = [_rec("zpol", "t", 0.4), _rec("apol", "t", 0.4),
            _rec("mpol", "t", 0.6)]
    pols = ["zpol", "apol", "mpol"]
    plain = bench_report.winners(recs, pols)
    assert plain["t(S)"] == {"apol": 1.0}          # tie -> first by name
    assert sum(plain["t(S)"].values()) == 1.0      # shape unchanged
    rich = bench_report.winners(recs, pols, margin=True)
    assert rich["t(S)"]["winners"] == {"apol": 1.0}
    assert rich["t(S)"]["margin"] == pytest.approx(0.0)  # runner-up tied
    solo = bench_report.winners([_rec("a", "t", 0.3), _rec("b", "t", 0.5)],
                                ["a", "b"], margin=True)
    assert solo["t(S)"]["margin"] == pytest.approx(0.2)


def test_metric_cdf_is_a_cdf():
    recs = [_rec("a", f"t{i}", m) for i, m in enumerate([0.2, 0.6, 0.4])]
    cdf = bench_report.metric_cdf(recs, ["a"], "miss_ratio")["a"]
    assert cdf["values"] == sorted(cdf["values"])
    assert cdf["cdf"][-1] == pytest.approx(1.0)
    assert all(x <= y for x, y in zip(cdf["cdf"], cdf["cdf"][1:]))


# --- report layer, from the store alone ------------------------------------

def test_report_renders_from_store_alone(full_store):
    report = render_report(full_store, baseline="fifo")
    assert report["n_cells"] == 6 and report["n_quarantined"] == 0
    assert report["policies"] == ["fifo", "lru"]
    assert set(report["winners"]) == {"csv", "oracle", "txt"}
    for row in report["winners"].values():
        assert row["winner"] in ("fifo", "lru")
        assert row["margin"] >= 0.0
        assert sum(row["wins"].values()) == pytest.approx(1.0)
    # reduction tables: fifo vs itself is exactly zero
    for col in report["mrr_vs_fifo"].values():
        assert col["fifo"] == pytest.approx(0.0)
    cdf = report["hit_ratio_cdf"]
    assert set(cdf) == {"fifo", "lru"} and len(cdf["lru"]["values"]) == 3
    text = format_report(report)
    assert "winners (miss ratio)" in text and "oracle" in text


def test_incomplete_cells_shrink_tables_not_crash(full_store):
    recs = campaign_records(full_store)
    # drop one policy's record from one cell -> that cell leaves the table
    recs = [r for r in recs
            if not (r["policy"] == "lru" and r["dataset"] == "txt")]
    table = dataset_winners(recs, ["fifo", "lru"])
    assert "txt" not in table and set(table) == {"csv", "oracle"}
    assert all(row["dropped"] == 0 for row in table.values())


# --- results --out-dir plumbing + ingest cache key (satellites) ------------

def test_set_results_dir_redirects_save(tmp_path, monkeypatch):
    monkeypatch.setattr(results, "RESULTS_DIR", results.RESULTS_DIR)
    out = str(tmp_path / "elsewhere")
    assert results.set_results_dir(out) == out
    path = results.save(_tiny_payload())
    assert os.path.dirname(path) == out
    assert not [f for f in os.listdir(out) if ".tmp." in f]   # atomic


def test_characterize_cache_keys_on_size(tmp_path):
    """A rewrite that lands in the same mtime tick must not serve stale
    stats to make_manifest: file size is part of the cache key."""
    p = str(tmp_path / "t.csv")
    ingest.write_csv(p, [1, 2], [8, 8])
    mtime_ns = os.stat(p).st_mtime_ns
    assert ingest.characterize(p).n_requests == 2
    ingest.write_csv(p, [1, 2, 3, 4], [8, 8, 8, 8])
    os.utime(p, ns=(mtime_ns, mtime_ns))           # force same-mtime rewrite
    assert ingest.characterize(p).n_requests == 4
    assert ingest.count_requests(p) == 4


# --- CLI --------------------------------------------------------------------

def _cli(args, **kw):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run([sys.executable, "-m", "benchmarks.campaign",
                           *args], capture_output=True, text=True,
                          cwd=str(ROOT), env=env, **kw)


def test_cli_status_and_report_from_store_only(full_store):
    out = _cli(["--store", full_store.root, "--status"])
    assert out.returncode == 0, out.stderr
    assert "completed   6" in out.stdout
    out = _cli(["--store", full_store.root, "--report"])
    assert out.returncode == 0, out.stderr
    assert "winners (miss ratio)" in out.stdout
    report = json.load(open(os.path.join(full_store.root, "report.json")))
    assert report["schema"] == "repro.campaign.report/v1"
    assert report["n_cells"] == 6


def test_cli_fresh_store_requires_manifest(tmp_path):
    out = _cli(["--store", str(tmp_path / "fresh")])
    assert out.returncode == 2
    assert "--manifest is required" in out.stderr


def test_run_out_dir_flag_redirects_results(tmp_path):
    """`benchmarks.run --out-dir` repoints the live results directory."""
    out = subprocess.run(
        [sys.executable, "-c",
         "import sys; from benchmarks import run\n"
         "run.main(['--out-dir', sys.argv[1], '--list'])\n"
         "from repro.bench import results\n"
         "print(results.RESULTS_DIR)",
         str(tmp_path / "out")],
        capture_output=True, text=True, cwd=str(ROOT),
        env=dict(os.environ, PYTHONPATH="src"))
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip().splitlines()[-1] == str(tmp_path / "out")
