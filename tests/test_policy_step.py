"""Fused Pallas policy-step kernel + streaming metrics-only replay.

Oracle parity: every rank-based policy must produce bit-identical hit
sequences with the fused kernel on and off (the kernel runs under the
Pallas interpreter on CPU — the same body Mosaic compiles on TPU), and
metrics-only / streaming replays must reproduce the stacked-info totals.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LRU, BLRU, Engine, Request, make_policy
from repro.data.traces import scan_mix_trace, zipf_trace

ENGINE = Engine()

RANK_SPECS = ["climb", "adaptiveclimb", "dynamicadaptiveclimb",
              "dac(eps=0.25,growth=2)"]


def _traces():
    return {
        "zipf": zipf_trace(N=256, T=2500, alpha=0.9, seed=11),
        "scan": scan_mix_trace(N=128, T=2500, alpha=1.0, scan_frac=0.3,
                               scan_len=96, seed=5),
    }


# --- Pallas oracle parity ----------------------------------------------------

@pytest.mark.parametrize("spec", RANK_SPECS)
@pytest.mark.parametrize("kind", ["zipf", "scan"])
def test_pallas_hits_bit_identical(spec, kind):
    trace = _traces()[kind]
    ref = ENGINE.replay(spec, trace, 24, use_pallas=False)
    got = ENGINE.replay(spec, trace, 24, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(got.info.hit),
                                  np.asarray(ref.info.hit))
    assert int(got.metrics.hits) == int(ref.metrics.hits)


@pytest.mark.parametrize("spec", ["adaptiveclimb", "dynamicadaptiveclimb"])
def test_pallas_batched_bit_identical(spec):
    traces = np.stack([zipf_trace(N=96, T=900, alpha=a, seed=s)
                       for s, a in enumerate((0.7, 1.0, 1.2))])
    ref = ENGINE.replay(spec, traces, 16, use_pallas=False)
    got = ENGINE.replay(spec, traces, 16, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(got.info.hit),
                                  np.asarray(ref.info.hit))


def test_engine_level_use_pallas_default():
    trace = zipf_trace(N=128, T=1200, alpha=1.0, seed=2)
    eng = Engine(use_pallas=True)
    ref = ENGINE.replay("dac", trace, 16)
    got = eng.replay("dac", trace, 16)              # engine-level default
    np.testing.assert_array_equal(np.asarray(got.info.hit),
                                  np.asarray(ref.info.hit))
    # per-call override wins over the engine default
    got_off = eng.replay("dac", trace, 16, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got_off.info.hit),
                                  np.asarray(ref.info.hit))


@pytest.mark.parametrize("spec", ["climb", "dynamicadaptiveclimb"])
def test_pallas_interpret_mode_string(spec):
    """The explicit "interpret" mode matches the jnp lowering bit-for-bit
    (True also resolves to interpret on this CPU container, but the string
    pins it regardless of backend)."""
    trace = zipf_trace(N=128, T=1200, alpha=1.0, seed=8)
    ref = ENGINE.replay(spec, trace, 16, use_pallas=False)
    got = ENGINE.replay(spec, trace, 16, use_pallas="interpret")
    np.testing.assert_array_equal(np.asarray(got.info.hit),
                                  np.asarray(ref.info.hit))
    assert int(got.metrics.hits) == int(ref.metrics.hits)


def test_pallas_mode_validation():
    trace = zipf_trace(N=32, T=100, alpha=1.0, seed=0)
    with pytest.raises(ValueError, match="use_pallas"):
        Engine(use_pallas="fast")
    with pytest.raises(ValueError, match="use_pallas"):
        ENGINE.replay("dac", trace, 8, use_pallas="fast")
    with pytest.raises(ValueError, match="use_pallas"):
        ENGINE.replay_stream("dac", trace, 8, use_pallas="maybe")


def test_resolve_interpret_env_override(monkeypatch):
    from repro.kernels.policy_step import INTERPRET_ENV, resolve_interpret
    monkeypatch.setenv(INTERPRET_ENV, "interpret")
    assert resolve_interpret(False) is True       # forced, beats the arg
    monkeypatch.setenv(INTERPRET_ENV, "compiled")
    assert resolve_interpret(True) is False
    monkeypatch.setenv(INTERPRET_ENV, "auto")
    assert resolve_interpret(True) is True        # defers to the arg
    assert resolve_interpret(False) is False
    monkeypatch.setenv(INTERPRET_ENV, "fast")
    with pytest.raises(ValueError, match=INTERPRET_ENV):
        resolve_interpret()
    monkeypatch.delenv(INTERPRET_ENV)
    expect = jax.default_backend() not in ("tpu", "gpu")
    assert resolve_interpret(None) is expect      # per-backend default


def test_pallas_flag_is_noop_for_slot_policies():
    trace = zipf_trace(N=128, T=1200, alpha=1.0, seed=6)
    ref = ENGINE.replay("lru", trace, 16)
    got = ENGINE.replay("lru", trace, 16, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(got.info.hit),
                                  np.asarray(ref.info.hit))


# --- metrics-only mode -------------------------------------------------------

@pytest.mark.parametrize("spec", ["lru", "arc", "dynamicadaptiveclimb"])
def test_collect_info_false_matches_stacked_totals(spec):
    trace = zipf_trace(N=256, T=2000, alpha=1.0, seed=7)
    sizes = (1 + (trace % 11)).astype(np.int32)
    full = ENGINE.replay(spec, trace, 24, sizes=sizes)
    lean = ENGINE.replay(spec, trace, 24, sizes=sizes, collect_info=False)
    assert lean.info is None
    assert int(lean.metrics.requests) == int(full.metrics.requests)
    assert int(lean.metrics.hits) == int(full.metrics.hits)
    for f, l in zip(full.metrics, lean.metrics):
        np.testing.assert_allclose(np.asarray(l), np.asarray(f), rtol=1e-6)
    assert lean.miss_ratio == pytest.approx(full.miss_ratio)
    assert lean.byte_miss_ratio == pytest.approx(full.byte_miss_ratio)


def test_collect_info_false_allocates_no_stepinfo():
    """The jitted metrics-only program's output avals contain nothing
    [T]-shaped — the StepInfo stack is truly gone, not just hidden."""
    T = 4096
    out = jax.eval_shape(
        lambda r: ENGINE.replay("lru", r, 16, collect_info=False),
        jax.ShapeDtypeStruct((T,), jnp.int32))
    assert out.info is None
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves and all(T not in leaf.shape for leaf in leaves), \
        [leaf.shape for leaf in leaves]
    # batched: per-lane metrics only, no [B, T] stack
    out = jax.eval_shape(
        lambda r: ENGINE.replay("dac", r, 16, collect_info=False),
        jax.ShapeDtypeStruct((3, T), jnp.int32))
    assert all(T not in leaf.shape for leaf in jax.tree_util.tree_leaves(out))


def test_collect_info_false_still_collects_observables():
    trace = zipf_trace(N=512, T=1500, alpha=0.3, seed=4)
    res = ENGINE.replay("dac(growth=4)", trace, 16, observe=True,
                        collect_info=False)
    assert res.info is None
    ks = np.asarray(res.obs["k"])
    assert ks.shape == (1500,) and ks.min() >= 2


def test_hits_property_errors_without_info():
    trace = zipf_trace(N=64, T=500, alpha=1.0, seed=1)
    res = ENGINE.replay("lru", trace, 8, collect_info=False)
    with pytest.raises(ValueError, match="collect_info"):
        res.hits


# --- streaming replay --------------------------------------------------------

@pytest.mark.parametrize("spec,pallas", [("lru", False), ("sieve", False),
                                         ("dynamicadaptiveclimb", True)])
def test_replay_stream_matches_replay(spec, pallas):
    trace = zipf_trace(N=256, T=5000, alpha=1.0, seed=9)
    full = ENGINE.replay(spec, trace, 24)
    # chunk does not divide T: exercises the remainder program
    stream = ENGINE.replay_stream(spec, trace, 24, chunk=1024,
                                  use_pallas=pallas)
    assert stream.info is None
    assert int(stream.metrics.requests) == 5000
    assert int(stream.metrics.hits) == int(full.metrics.hits)
    assert stream.miss_ratio == pytest.approx(full.miss_ratio)


def test_replay_stream_batched_with_sizes():
    traces = np.stack([zipf_trace(N=96, T=2300, alpha=a, seed=s)
                       for s, a in enumerate((0.8, 1.1))])
    sizes = (1 + (traces % 7)).astype(np.int32)
    full = ENGINE.replay("arc", traces, 16, sizes=sizes)
    stream = ENGINE.replay_stream("arc", traces, 16, sizes=sizes, chunk=512)
    np.testing.assert_array_equal(np.asarray(stream.metrics.hits),
                                  np.asarray(full.metrics.hits))
    np.testing.assert_allclose(stream.byte_miss_ratio, full.byte_miss_ratio,
                               rtol=1e-5)


def test_replay_stream_accepts_request_and_rejects_extras():
    trace = zipf_trace(N=64, T=1000, alpha=1.0, seed=3)
    req = Request.of(trace, sizes=2)
    full = ENGINE.replay("lru", req, 8)
    stream = ENGINE.replay_stream("lru", req, 8, chunk=300)
    assert int(stream.metrics.hits) == int(full.metrics.hits)
    assert stream.metrics.bytes_total == pytest.approx(2000.0)
    with pytest.raises(ValueError, match="inside the Request"):
        ENGINE.replay_stream("lru", req, 8, sizes=3)
    with pytest.raises(ValueError, match="chunk"):
        ENGINE.replay_stream("lru", trace, 8, chunk=0)


# --- counter / timestamp widening -------------------------------------------

def test_lru_timestamps_widen_under_x64():
    st32 = LRU().init(4)
    assert st32["t"].dtype == jnp.int32
    with jax.experimental.enable_x64():
        st64 = LRU().init(4)
        assert st64["t"].dtype == jnp.int64
        assert st64["last"].dtype == jnp.int64
        assert BLRU().init(4)["t"].dtype == jnp.int64
        # keys stay int32 (they are ids, not counters)
        assert st64["keys"].dtype == jnp.int32
