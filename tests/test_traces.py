"""Trace-generator regressions: scan/Zipf key-range disjointness (the
scan_mix_trace wraparound bug aliased "cold" scan keys back into the hot
Zipf range) and the public surface of the traces module."""
import numpy as np
import pytest

from repro.data import traces
from repro.data.traces import (DATASET_FAMILIES, churn_trace, dataset_family,
                               scan_mix_trace, zipf_trace)

SCAN_FAMILIES = {name: cfg for name, cfg in DATASET_FAMILIES.items()
                 if cfg["kind"] == "scan"}


def _split_ranges(N, T, alpha, scan_frac, scan_len, seed):
    """Return (zipf_keys, scan_keys) of one scan_mix_trace: positions that
    differ from the underlying Zipf draw were overwritten by a scan."""
    out = scan_mix_trace(N, T, alpha, scan_frac, scan_len, seed=seed)
    base = zipf_trace(N, T, alpha, seed=seed + 1)
    scan_pos = out != base
    return out[~scan_pos], out[scan_pos]


@pytest.mark.parametrize("name", sorted(SCAN_FAMILIES))
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_scan_mix_family_ranges_disjoint(name, seed):
    """Regression for the wraparound bug: for every scan-family parameter
    set used by DATASET_FAMILIES, scan keys stay in [N, 2N) and Zipf keys
    in [0, N) — the id ranges never alias."""
    cfg = dict(SCAN_FAMILIES[name])
    cfg.pop("kind")
    N = cfg["N"]
    zipf_keys, scan_keys = _split_ranges(T=50_000, seed=seed, **cfg)
    assert zipf_keys.min() >= 0 and zipf_keys.max() < N
    assert scan_keys.size > 0
    assert scan_keys.min() >= N, \
        f"scan keys aliased into the hot range: min={scan_keys.min()}"
    assert scan_keys.max() < 2 * N


def test_scan_mix_wraps_within_cold_range():
    """Adversarial shape: scan_len close to N makes nearly every scan run
    cross the 2N-1 boundary; with the old `% 2N` wraparound these keys
    landed in [0, N)."""
    N, T = 64, 20_000
    zipf_keys, scan_keys = _split_ranges(N=N, T=T, alpha=1.0, scan_frac=0.5,
                                         scan_len=48, seed=3)
    assert scan_keys.size > 0
    assert scan_keys.min() >= N and scan_keys.max() < 2 * N
    # the wrap keeps scans sequential *within* the cold range: every run
    # still touches scan_len distinct cold keys
    assert len(np.unique(scan_keys)) <= N


def test_scan_mix_deterministic_and_int32():
    a = scan_mix_trace(128, 5000, 1.0, 0.2, 64, seed=9)
    b = scan_mix_trace(128, 5000, 1.0, 0.2, 64, seed=9)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32


def test_churn_trace_exported_and_reachable():
    """churn_trace is used by dataset_family and documented in the module
    header — it must be part of the public surface."""
    assert "churn_trace" in traces.__all__
    tr = churn_trace(N=256, T=5000, alpha=1.0, mean_phase=1000, drift=0.1,
                     seed=0)
    assert tr.shape == (5000,) and tr.dtype == np.int32
    assert tr.min() >= 0 and tr.max() < 256


@pytest.mark.parametrize("name", sorted(DATASET_FAMILIES))
def test_dataset_family_key_ranges(name):
    """Every family stays inside its documented id budget: [0, N) for
    churn/zipfshift, [0, 2N) for scan mixes."""
    cfg = DATASET_FAMILIES[name]
    hi = 2 * cfg["N"] if cfg["kind"] == "scan" else cfg["N"]
    tr = dataset_family(name, T=20_000, n_traces=2, seed=1)
    assert tr.min() >= 0 and tr.max() < hi
