"""Trace-generator regressions: scan/Zipf key-range disjointness (the
scan_mix_trace wraparound bug aliased "cold" scan keys back into the hot
Zipf range), churn_trace's realized hot-set turnover (the old
uniform-over-N rotation delivered the docstring's `drift` only in
expectation — lumpily, with zero-turnover typical phases on skewed
parameters), and the public surface of the traces module."""
import numpy as np
import pytest

from repro.data import traces
from repro.data.traces import (DATASET_FAMILIES, _churn_phases, churn_trace,
                               dataset_family, scan_mix_trace, zipf_trace)

SCAN_FAMILIES = {name: cfg for name, cfg in DATASET_FAMILIES.items()
                 if cfg["kind"] == "scan"}


def _split_ranges(N, T, alpha, scan_frac, scan_len, seed):
    """Return (zipf_keys, scan_keys) of one scan_mix_trace: positions that
    differ from the underlying Zipf draw were overwritten by a scan."""
    out = scan_mix_trace(N, T, alpha, scan_frac, scan_len, seed=seed)
    base = zipf_trace(N, T, alpha, seed=seed + 1)
    scan_pos = out != base
    return out[~scan_pos], out[scan_pos]


@pytest.mark.parametrize("name", sorted(SCAN_FAMILIES))
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_scan_mix_family_ranges_disjoint(name, seed):
    """Regression for the wraparound bug: for every scan-family parameter
    set used by DATASET_FAMILIES, scan keys stay in [N, 2N) and Zipf keys
    in [0, N) — the id ranges never alias."""
    cfg = dict(SCAN_FAMILIES[name])
    cfg.pop("kind")
    N = cfg["N"]
    zipf_keys, scan_keys = _split_ranges(T=50_000, seed=seed, **cfg)
    assert zipf_keys.min() >= 0 and zipf_keys.max() < N
    assert scan_keys.size > 0
    assert scan_keys.min() >= N, \
        f"scan keys aliased into the hot range: min={scan_keys.min()}"
    assert scan_keys.max() < 2 * N


def test_scan_mix_wraps_within_cold_range():
    """Adversarial shape: scan_len close to N makes nearly every scan run
    cross the 2N-1 boundary; with the old `% 2N` wraparound these keys
    landed in [0, N)."""
    N, T = 64, 20_000
    zipf_keys, scan_keys = _split_ranges(N=N, T=T, alpha=1.0, scan_frac=0.5,
                                         scan_len=48, seed=3)
    assert scan_keys.size > 0
    assert scan_keys.min() >= N and scan_keys.max() < 2 * N
    # the wrap keeps scans sequential *within* the cold range: every run
    # still touches scan_len distinct cold keys
    assert len(np.unique(scan_keys)) <= N


def test_scan_mix_deterministic_and_int32():
    a = scan_mix_trace(128, 5000, 1.0, 0.2, 64, seed=9)
    b = scan_mix_trace(128, 5000, 1.0, 0.2, 64, seed=9)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32


@pytest.mark.parametrize("drift,hot_frac", [(0.2, 0.1), (0.05, 0.1),
                                            (0.25, 0.01)])
def test_churn_hot_set_turnover_is_exactly_drift(drift, hot_frac):
    """Regression for the drift-semantics bug: every phase rotates exactly
    ``round(H * drift)`` ids out of the hot ranks (swapped against cold
    ids), so the realized per-phase hot-set turnover *is* the documented
    drift fraction — including the skewed small-hot-set regimes where the
    old uniform rotation left the typical phase with no turnover at all."""
    N = 4096
    H = max(1, int(N * hot_frac))
    n_rot = min(int(round(H * drift)), N - H)
    assert n_rot > 0, "parameter set must demand turnover"
    prev = None
    n_phases = 0
    for start, stop, perm in _churn_phases(N, 60_000, 2500, drift,
                                           hot_frac, seed=3):
        hot = set(perm[:H].tolist())
        assert len(hot) == H
        if prev is not None:
            survivors = len(hot & prev)
            assert survivors == H - n_rot, \
                f"turnover {1 - survivors / H:.3f} != drift {n_rot / H:.3f}"
            n_phases += 1
        prev = hot
    assert n_phases >= 3


def test_churn_tiny_drift_still_rotates():
    """Regression: H * drift < 1/2 must not round the rotation away — a
    positive drift rotates at least one id per phase (turnover floored
    at 1/H); drift=0 rotates none."""
    N, hot_frac = 1000, 0.01          # H = 10; 10 * 0.04 rounds to 0
    H = 10
    prev = None
    for _, _, perm in _churn_phases(N, 20_000, 2000, 0.04, hot_frac,
                                    seed=1):
        hot = set(perm[:H].tolist())
        if prev is not None:
            assert len(hot & prev) == H - 1
        prev = hot
    frozen = [perm[:H].tolist()
              for _, _, perm in _churn_phases(N, 20_000, 2000, 0.0,
                                              hot_frac, seed=1)]
    assert all(h == frozen[0] for h in frozen)


def test_churn_rejects_degenerate_parameters():
    """Parameter sets that cannot deliver the promised turnover raise
    instead of silently clamping to less drift (or none at all)."""
    for bad in (0.0, 1.0, 1.5, -0.1):
        with pytest.raises(ValueError, match="hot_frac"):
            churn_trace(N=100, T=200, alpha=1.0, mean_phase=50, drift=0.1,
                        hot_frac=bad)
    for bad in (-0.2, 1.2):
        with pytest.raises(ValueError, match="drift"):
            churn_trace(N=100, T=200, alpha=1.0, mean_phase=50, drift=bad)
    # feasibility: rotating 50% of an 80% hot set needs more cold ids
    # than exist — refuse rather than deliver half the drift
    with pytest.raises(ValueError, match="cold ids"):
        churn_trace(N=100, T=200, alpha=1.0, mean_phase=50, drift=0.5,
                    hot_frac=0.8)


def test_churn_trace_seed_stays_sixth_positional():
    """hot_frac is keyword-only, so pre-existing positional callers
    (seed as the 6th argument) keep their meaning."""
    a = churn_trace(64, 500, 1.0, 100, 0.1, 7)
    b = churn_trace(N=64, T=500, alpha=1.0, mean_phase=100, drift=0.1,
                    seed=7)
    np.testing.assert_array_equal(a, b)


def test_churn_phases_tile_the_trace():
    phases = list(_churn_phases(512, 10_000, 900, 0.1, 0.1, seed=0))
    assert phases[0][0] == 0 and phases[-1][1] == 10_000
    for (a, b, _), (c, d, _) in zip(phases, phases[1:]):
        assert b == c and a < b
    ids = np.sort(phases[0][2])
    np.testing.assert_array_equal(ids, np.arange(512))   # perm stays a perm


def test_churn_trace_draws_through_phase_perms():
    """churn_trace is exactly `perm[zipf draws]` phase by phase — the
    generator the turnover test measures is the one the trace uses."""
    kw = dict(N=256, T=8000, alpha=1.0, mean_phase=1000, drift=0.2)
    tr = churn_trace(**kw, seed=5)
    draw = np.random.default_rng(np.random.SeedSequence([5, 1]))
    pmf = traces._zipf_pmf(256, 1.0)
    for start, stop, perm in _churn_phases(256, 8000, 1000, 0.2, 0.1,
                                           seed=5):
        want = perm[draw.choice(256, size=stop - start, p=pmf)]
        np.testing.assert_array_equal(tr[start:stop], want)


def test_churn_trace_exported_and_reachable():
    """churn_trace is used by dataset_family and documented in the module
    header — it must be part of the public surface."""
    assert "churn_trace" in traces.__all__
    tr = churn_trace(N=256, T=5000, alpha=1.0, mean_phase=1000, drift=0.1,
                     seed=0)
    assert tr.shape == (5000,) and tr.dtype == np.int32
    assert tr.min() >= 0 and tr.max() < 256


@pytest.mark.parametrize("name", sorted(DATASET_FAMILIES))
def test_dataset_family_key_ranges(name):
    """Every family stays inside its documented id budget: [0, N) for
    churn/zipfshift, [0, 2N) for scan mixes."""
    cfg = DATASET_FAMILIES[name]
    hi = 2 * cfg["N"] if cfg["kind"] == "scan" else cfg["N"]
    tr = dataset_family(name, T=20_000, n_traces=2, seed=1)
    assert tr.min() >= 0 and tr.max() < hi
