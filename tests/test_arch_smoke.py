"""Per-architecture smoke tests: reduced configs of every assigned arch run
one forward + one train step on CPU; output shapes and finiteness hold."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_ARCHS, SHAPES, input_specs
from repro.models import forward, init_params, lm_loss, param_count
from repro.optim import AdamWConfig, adamw

ARCH_NAMES = sorted(SMOKE_ARCHS)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, key, B=2, S=24):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"labels": toks}
    if cfg.embeds_input:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32) * 0.05
    else:
        batch["tokens"] = toks
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_finite(name, key):
    cfg = SMOKE_ARCHS[name]
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits = forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"))
    assert logits.shape == (2, 24, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_reduces_loss(name, key):
    cfg = SMOKE_ARCHS[name]
    params = init_params(cfg, key)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                          weight_decay=0.0)
    opt = adamw.init(params, opt_cfg)
    batch = _batch(cfg, key)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(lm_loss)(p, cfg, b)
        p, o, _ = adamw.update(g, o, p, opt_cfg)
        return p, o, loss

    losses = []
    for _ in range(4):
        params, opt, loss = step(params, opt, batch)
        assert bool(jnp.isfinite(loss)), name
        losses.append(float(loss))
    assert losses[-1] < losses[0], (name, losses)


def test_full_configs_match_published_sizes():
    expect = {  # published total parameter counts (tolerance: embeddings)
        "deepseek-v2-236b": 236e9, "mixtral-8x22b": 141e9,
        "qwen1.5-110b": 111e9, "deepseek-7b": 6.9e9, "gemma2-27b": 27.2e9,
        "codeqwen1.5-7b": 7.25e9, "llava-next-mistral-7b": 7.24e9,
        "jamba-1.5-large-398b": 398e9, "xlstm-125m": 0.125e9,
        "musicgen-medium": 1.5e9,
    }
    for name, target in expect.items():
        n = param_count(ARCHS[name])
        assert abs(n - target) / target < 0.25, (name, n, target)


def test_input_specs_cover_all_cells():
    for name, cfg in ARCHS.items():
        for shape in SHAPES:
            spec = input_specs(cfg, shape)
            assert spec, (name, shape)
            for leaf in jax.tree.leaves(spec):
                assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("name", ["gemma2-27b", "mixtral-8x22b"])
def test_window_masking_differs_from_full(name, key):
    """SWA archs: a distant-past token must not influence the last logit."""
    cfg = SMOKE_ARCHS[name]
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    win = min(s.window for s in cfg.period if s.window)
    S = win * 3
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    l1 = forward(params, cfg, tokens=toks)
    l2 = forward(params, cfg, tokens=toks2)
    if all(s.window for s in cfg.period):   # pure-SWA (mixtral)
        np.testing.assert_allclose(np.asarray(l1[0, -1]),
                                   np.asarray(l2[0, -1]), atol=1e-3)
    else:                                   # gemma2 has global layers
        assert float(jnp.max(jnp.abs(l1[0, -1] - l2[0, -1]))) > 0
