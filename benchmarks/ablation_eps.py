"""Ablation (beyond-paper): DAC's sensitivity threshold eps and growth
headroom vs miss ratio AND memory actually used.

Quantifies the central §Repro finding: under stationary skew, Alg. 2
trades miss ratio for memory (shrink fires whenever hits concentrate);
eps tunes *how readily*, growth bounds how far it can expand under churn.
Reported per config: miss ratio, average adapted size / nominal K — the
DAC variants are just policy spec strings on the sweep's policy axis.
"""
from __future__ import annotations

import numpy as np

from repro.bench import Scenario, Sweep, report, run_sweep

EPS_GRID = (0.25, 0.5, 1.0)
GROWTH_GRID = (1, 4)
POLS = [f"dac(eps={e},growth={g})" for e in EPS_GRID for g in GROWTH_GRID]


def sweep(N: int = 4096, T: int = 60_000, K: int = 256,
          seed: int = 0) -> Sweep:
    return Sweep(
        "ablation_eps",
        policies=tuple(POLS),
        scenarios=(
            Scenario("zipf(1.0)", trace=f"zipf(N={N},alpha=1.0)", T=T,
                     K=(K,)),
            Scenario("shifting", trace=f"shifting_zipf(N={N},alpha=1.1,"
                     "phases=6)", T=T, K=(K,)),
        ),
        seeds=(seed,),
        observe=True,
    )


def run(N: int = 4096, T: int = 60_000, K: int = 256, seed: int = 0,
        quiet: bool = False):
    res = run_sweep(sweep(N=N, T=T, K=K, seed=seed))
    rows = {}
    for sc in res.sweep.scenarios:
        for pol, e, g in ((f"dac(eps={e},growth={g})", e, g)
                          for e in EPS_GRID for g in GROWTH_GRID):
            rows[f"{sc.name}|eps={e}|growth={g}"] = {
                "miss": float(np.mean(res.metric(
                    "miss_ratio", policy=pol, scenario=sc.name))),
                "avg_k_frac": float(np.mean(res.metric(
                    "avg_k", policy=pol, scenario=sc.name)) / K),
            }
    if not quiet:
        print(report.fmt_row(["config", "miss", "avg_k/K"], [36, 10, 10]))
        for k, v in rows.items():
            print(report.fmt_row(
                [k, f"{v['miss']:.3f}", f"{v['avg_k_frac']:.2f}"],
                [36, 10, 10]))
    return res.save(extras={"rows": rows})


if __name__ == "__main__":
    run()
