"""Ablation (beyond-paper): DAC's sensitivity threshold eps and growth
headroom vs miss ratio AND memory actually used.

Quantifies the central §Repro finding: under stationary skew, Alg. 2
trades miss ratio for memory (shrink fires whenever hits concentrate);
eps tunes *how readily*, growth bounds how far it can expand under churn.
Reported per config: miss ratio, average adapted size / nominal K.
"""
from __future__ import annotations

import numpy as np

from repro.core import Engine, make_policy
from repro.data.traces import shifting_zipf_trace, zipf_trace
from .common import fmt_row, save


def run(N: int = 4096, T: int = 60_000, K: int = 256, seed: int = 0,
        quiet: bool = False):
    engine = Engine()
    traces = {
        "zipf(1.0)": zipf_trace(N, T, 1.0, seed=seed),
        "shifting": shifting_zipf_trace(N, T, 1.1, phases=6, seed=seed),
    }
    rows = {}
    for tname, trace in traces.items():
        for eps in (0.25, 0.5, 1.0):
            for growth in (1, 4):
                pol = make_policy(f"dac(eps={eps},growth={growth})")
                res = engine.replay(pol, trace, K, observe=True)
                rows[f"{tname}|eps={eps}|growth={growth}"] = {
                    "miss": res.miss_ratio,
                    "avg_k_frac": float(np.asarray(res.obs["k"]).mean() / K),
                }
    if not quiet:
        print(fmt_row(["config", "miss", "avg_k/K"], [36, 10, 10]))
        for k, v in rows.items():
            print(fmt_row([k, f"{v['miss']:.3f}", f"{v['avg_k_frac']:.2f}"],
                          [36, 10, 10]))
    return save("ablation_eps", {"K": K, "T": T, "rows": rows})


if __name__ == "__main__":
    run()
