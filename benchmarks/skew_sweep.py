"""Fig. 11: miss ratio vs Zipf skewness alpha for DAC / AC / LFU / LRU —
one scenario per alpha, the whole figure a single declarative Sweep."""
from __future__ import annotations

import numpy as np

from repro.bench import Scenario, Sweep, report, run_sweep

POLS = ["lru", "lfu", "adaptiveclimb", "dynamicadaptiveclimb"]
ALPHAS = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4]


def sweep(N: int = 4096, T: int = 60_000, K: int = 256,
          seed: int = 0) -> Sweep:
    return Sweep(
        "skew_sweep",
        policies=tuple(POLS),
        scenarios=tuple(
            Scenario(f"alpha={a}", trace=f"zipf(N={N},alpha={a})", T=T,
                     K=(K,))
            for a in ALPHAS),
        seeds=(seed,),
    )


def run(N: int = 4096, T: int = 60_000, K: int = 256, seed: int = 0,
        quiet: bool = False):
    res = run_sweep(sweep(N=N, T=T, K=K, seed=seed))
    rows = {
        a: {p: float(np.mean(res.metric("miss_ratio", policy=p,
                                        scenario=f"alpha={a}")))
            for p in POLS}
        for a in ALPHAS}
    if not quiet:
        print(report.fmt_row(["alpha"] + POLS, [8] + [22] * len(POLS)))
        for a, row in rows.items():
            print(report.fmt_row([a] + [f"{row[p]:.3f}" for p in POLS],
                                 [8] + [22] * len(POLS)))
    return res.save(extras={"rows": {str(k): v for k, v in rows.items()}})


if __name__ == "__main__":
    run()
