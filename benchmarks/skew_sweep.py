"""Fig. 11: miss ratio vs Zipf skewness alpha for DAC / AC / LFU / LRU."""
from __future__ import annotations

from repro.core import Engine
from repro.data.traces import zipf_trace
from .common import fmt_row, save

POLS = ["lru", "lfu", "adaptiveclimb", "dynamicadaptiveclimb"]


def run(N: int = 4096, T: int = 60_000, K: int = 256, seed: int = 0,
        quiet: bool = False):
    engine = Engine()
    alphas = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.4]
    rows = {}
    for a in alphas:
        trace = zipf_trace(N=N, T=T, alpha=a, seed=seed)
        rows[a] = {p: engine.replay(p, trace, K).miss_ratio for p in POLS}
    if not quiet:
        print(fmt_row(["alpha"] + POLS, [8] + [22] * len(POLS)))
        for a, row in rows.items():
            print(fmt_row([a] + [f"{row[p]:.3f}" for p in POLS],
                          [8] + [22] * len(POLS)))
    return save("skew_sweep", {"N": N, "T": T, "K": K,
                               "rows": {str(k): v for k, v in rows.items()}})


if __name__ == "__main__":
    run()
