"""Beyond-paper: multi-tenant shared-budget tier — DAC-arbitrated vs
statically-partitioned baselines.

Two ``tenants(...)`` fluctuating-working-set grids (phase-shifted wide /
narrow phases per tenant, §5's regime but *across* tenants):

* ``flux``       4 tenants, one wide at a time (uncontended trading)
* ``contended``  8 tenants, half wide at once (grants compete for the pool)

Entries pair a policy with an arbiter: ``dac+greedy`` / ``dac+proportional``
trade capacity through the free pool, ``dac+static`` and the LRU / Climb /
AdaptiveClimb / FIFO rows are hard-partitioned at ``budget // n_tenants``.
The headline number is the aggregate byte-weighted MRR vs ``fifo+static``
(``repro.bench.report.tier_mrr_matrix``); results land in the v2 schema
with per-tenant records (``repro.bench.results.SCHEMA_V2``).
"""
from __future__ import annotations

import numpy as np

from repro.bench import (TierScenario, TierSweep, report, results,
                         run_tier_sweep)

DAC = "dac(k_min=16)"   # floor the shrink at the narrow-phase working set
ENTRIES = (
    (DAC, "greedy"),
    (DAC, "proportional"),
    (DAC, "static"),
    ("lru", "static"),
    ("climb", "static"),
    ("adaptiveclimb", "static"),
    ("fifo", "static"),
)


def _trace(n: int, duty: float) -> str:
    return (f"tenants(N=256,n_tenants={n},alpha=0.5,period=6000,"
            f"duty={duty},lo=16,alpha_lo=1.6)")


def sweep(T: int = 60_000, seeds=(0, 1, 2)) -> TierSweep:
    return TierSweep(
        "tenant_sweep",
        entries=ENTRIES,
        scenarios=(
            TierScenario("flux", trace=_trace(4, 0.25), T=T, budget=(320,),
                         size_model="lognormal(median_kb=16,sigma=1.5)"),
            TierScenario("contended", trace=_trace(8, 0.5), T=T,
                         budget=(512,),
                         size_model="lognormal(median_kb=16,sigma=1.5)"),
        ),
        seeds=seeds,
    )


def _occupancy_timelines(sw, windows: int = 8) -> dict:
    """One observed greedy replay per scenario (first seed): the
    per-tenant occupancy-over-time table for the report."""
    from repro.core import Engine
    from repro.data.traces import make_trace
    from repro.tier import CacheTier

    out = {}
    for sc in sw.scenarios:
        tier = CacheTier(DAC, n_tenants=sc.n_tenants,
                         budget=sc.budgets()[0], arbiter="greedy")
        stream = make_trace(sc.trace).generate(sc.T, seed=sw.seeds[0])
        res = Engine().replay_tier(tier, stream, observe=True)
        out[sc.name] = report.occupancy_timeline(res.obs["k"], windows)
    return out


def run(T: int = 60_000, seeds=(0, 1, 2), quiet: bool = False):
    sw = sweep(T=T, seeds=seeds)
    res = run_tier_sweep(sw, progress=None if quiet else print)
    mrr = report.tier_mrr_matrix(res.records, ENTRIES)
    wins = report.tier_winners(res.records, ENTRIES)
    timelines = _occupancy_timelines(sw)
    if not quiet:
        labels = [f"{p}+{a}" for p, a in ENTRIES]
        print("\naggregate byte-weighted MRR vs fifo+static")
        report.print_table(mrr, labels, name_w=30)
        for rec in res.select(arbiter="greedy"):
            occ = report.tenant_occupancy(rec)
            ks = ", ".join(f"{t}:{v['avg_k']:.0f}" for t, v in occ.items())
            print(f"[{rec['scenario']}] {rec['policy']}+greedy avg_k  {ks}")
        print("\n[flux] dac+greedy occupancy over time (window means)")
        for w, row in enumerate(timelines["flux"]):
            print(f"  t{w}: " + " ".join(f"{k:6.1f}" for k in row))
    # the tier thesis, asserted on every run: trading capacity beats
    # hard partitioning on the fluctuating grid
    for cell in mrr.values():
        arbitrated = cell[f"{DAC}+greedy"]
        static_best = max(v for k, v in cell.items() if k.endswith("+static"))
        if not np.isfinite(arbitrated) or arbitrated <= static_best:
            print(f"WARNING: DAC-arbitrated ({arbitrated:.3f}) did not beat "
                  f"static partitioning ({static_best:.3f})")
    payload = res.save(extras={"mrr_vs_fifo_static": mrr, "winners": wins,
                               "occupancy_timeline_greedy": timelines})
    assert payload["schema"] == results.SCHEMA_V2, payload["schema"]
    return payload


if __name__ == "__main__":
    run()
