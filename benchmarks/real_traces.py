"""Real-trace replay: the miniature corpus through the streaming path.

The paper's headline grid is 1067 *real* traces from 6 datasets; this
benchmark is that pipeline end to end on the committed miniature corpus
(`tools/make_corpus.py`): `file(path=...)` scenarios resolve their id
footprint from the files, sizes/costs come *from the traces* (byte- and
cost-weighted miss ratios over real object sizes — where size-aware
caching earns its keep), and every cell replays out-of-core through
`run_sweep`'s streaming path (`Engine.replay_stream`, device memory
O(K + chunk)).  Point `--corpus` at a directory of real oracleGeneral /
CSV / txt traces to run the same grid on actual datasets.

The emitted payload uses schema v2 and carries each trace's ingest
characterization stats in `extras["traces"]`.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

import numpy as np

from repro.bench import Scenario, Sweep, report, results, run_sweep
from repro.data import characterize, detect_format, make_trace

CORPUS = os.path.join("benchmarks", "corpus")
POLS = ["fifo", "lru", "arc", "adaptiveclimb", "dynamicadaptiveclimb"]


def _corpus_files(corpus: str) -> list[str]:
    names = sorted(os.listdir(corpus)) if os.path.isdir(corpus) else []
    files = []
    for name in names:
        path = os.path.join(corpus, name)
        try:
            detect_format(path)
        except ValueError:
            continue
        # the .bin/.bin.gz pair is intentionally identical content; keep
        # the gzipped one so the compressed read path stays exercised
        if name.endswith(".oracleGeneral.bin") and \
                os.path.exists(path + ".gz"):
            continue
        files.append(path)
    if not files:
        raise FileNotFoundError(
            f"no trace files under {corpus!r} — run "
            "`PYTHONPATH=src python tools/make_corpus.py` first")
    return files


def sweep(corpus: str = CORPUS, T: int | None = None,
          seed: int = 0) -> Sweep:
    scenarios = []
    used = set()
    for path in _corpus_files(corpus):
        st = characterize(path)
        # short stem when unambiguous, full basename when corpora share
        # one (web.train.csv / web.test.csv must not collide)
        name = os.path.basename(path).split(".")[0]
        if name in used:
            name = os.path.basename(path)
        used.add(name)
        scenarios.append(Scenario(
            name, trace=f"file(path={path})",
            T=min(T, st.n_requests) if T else st.n_requests,
            K=("S", "L")))
    return Sweep("real_traces", policies=tuple(POLS),
                 scenarios=tuple(scenarios), seeds=(seed,), observe=True)


def run(corpus: str = CORPUS, T: int | None = None, seed: int = 0,
        quiet: bool = False):
    sw = sweep(corpus=corpus, T=T, seed=seed)
    res = run_sweep(sw, stream=True,
                    progress=None if quiet else print)
    stats = {sc.name: dataclasses.asdict(
        make_trace(sc.trace).stats()) for sc in sw.scenarios}
    rows = {
        f"{sc.name}({lab})": {
            p: float(np.mean(res.metric("byte_miss_ratio", policy=p,
                                        scenario=sc.name, K_label=lab)))
            for p in POLS}
        for sc in sw.scenarios for lab in ("S", "L")}
    if not quiet:
        print(report.fmt_row(["trace(K)"] + POLS, [14] + [22] * len(POLS)))
        for cell, row in rows.items():
            print(report.fmt_row(
                [cell] + [f"{row[p]:.3f}" for p in POLS],
                [14] + [22] * len(POLS)))
    return res.save(extras={"traces": stats, "byte_miss_rows": rows},
                    schema=results.SCHEMA_V2)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--corpus", default=CORPUS,
                    help="directory of oracleGeneral/CSV/txt traces "
                         f"(default: {CORPUS})")
    ap.add_argument("--T", type=int, default=None,
                    help="cap requests per trace (default: full trace)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    run(corpus=args.corpus, T=args.T, seed=args.seed, quiet=args.quiet)
    return 0


if __name__ == "__main__":
    sys.exit(main())
