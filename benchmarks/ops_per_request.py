"""Fig. 9: per-request policy cost — the paper measures CPU instructions per
request; the honest TPU-dry-run equivalent is HLO flops + HBM bytes per
request of the *compiled policy step*, extracted with the loop-aware
analyzer from a lowered trace replay.

Compares AdaptiveClimb / DynamicAdaptiveClimb / LRU at small & large cache
sizes (the paper's small/large x alpha grid).  No traces are generated —
the replay lowers over abstract shapes — so this table bypasses the sweep
runner but still emits the canonical schema-validated result payload.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.bench import report, results
from repro.core import Engine, Request, make_policy
from repro.launch import roofline

POLS = ["lru", "adaptiveclimb", "dynamicadaptiveclimb"]

ENGINE = Engine()


def _per_request(policy, K: int, T: int = 1024):
    # metrics-only replay: the lowered program carries no [T] StepInfo
    # stack, so flops/bytes measure the policy-step hot loop itself
    fn = jax.jit(
        lambda reqs: ENGINE.replay(policy, reqs, K, collect_info=False))
    reqs = Request(key=jax.ShapeDtypeStruct((T,), jnp.int32),
                   size=jax.ShapeDtypeStruct((T,), jnp.int32),
                   cost=jax.ShapeDtypeStruct((T,), jnp.float32))
    lowered = fn.lower(reqs)
    ana = roofline.analyze_hlo(lowered.compile().as_text())
    return ana["flops"] / T, ana["hbm_bytes"] / T


def run(quiet: bool = False):
    t_start = time.perf_counter()
    rows = {}
    records = []
    for regime, K in (("small", 64), ("large", 1024)):
        for p in POLS:
            fl, by = _per_request(make_policy(p), K)
            rows[f"{p}({regime})"] = {"flops_per_req": fl,
                                      "bytes_per_req": by}
            records.append({
                "policy": p, "K": K, "K_label": regime,
                "metrics": {"flops_per_req": fl, "bytes_per_req": by}})
    if not quiet:
        print(report.fmt_row(["policy(K)", "flops/req", "bytes/req"],
                             [34, 14, 14]))
        for k, v in rows.items():
            print(report.fmt_row([k, f"{v['flops_per_req']:.0f}",
                                  f"{v['bytes_per_req']:.0f}"],
                                 [34, 14, 14]))
        ac = rows["adaptiveclimb(large)"]["bytes_per_req"]
        lru = rows["lru(large)"]["bytes_per_req"]
        print(f"\nAC/LRU bytes ratio (large): {ac/lru:.2f} "
              "(paper Fig. 9: climb policies ~0.5-0.75x of LRU)")
    payload = results.build_payload(
        "ops_per_request", config={"policies": POLS},
        records=records, extras={"rows": rows},
        wall_s=time.perf_counter() - t_start)
    results.save(payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quiet", action="store_true",
                    help="no table; still writes the JSON result")
    run(quiet=ap.parse_args().quiet)
