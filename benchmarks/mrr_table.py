"""Table III / Fig. 5 / Fig. 6: miss-ratio reduction relative to FIFO across
the six dataset families x {small, large} cache regimes x 15 policies, plus
the best-performing-policy-per-dataset breakdown (Fig. 6).

The paper's six public trace sets are not redistributable offline; each
family here is a synthetic generator matched to the published workload
character (see repro.data.traces).  The validated claim is the paper's
*qualitative* one: the climb policies lead or co-lead MRR, with the gap
widening under working-set churn.

Declarative: the whole table is one ``Sweep`` — dataset aliases from the
trace registry, regime letters for K, the trace/seed axis vmapped per cell.
"""
from __future__ import annotations

from repro.bench import Scenario, Sweep, report, run_sweep
from repro.data.traces import DATASET_FAMILIES

POLICY_ORDER = [
    "dynamicadaptiveclimb", "adaptiveclimb", "sieve", "arc", "tinylfu",
    "twoq", "lirs", "lhd", "lfu", "hyperbolic", "clock", "climb", "lru",
    "blru", "fifo",
]


def sweep(T: int = 60_000, n_traces: int = 3, seed: int = 0) -> Sweep:
    return Sweep(
        "mrr_table",
        policies=tuple(POLICY_ORDER),
        scenarios=tuple(Scenario(ds, trace=ds, T=T, K=("L", "S"))
                        for ds in DATASET_FAMILIES),
        seeds=tuple(seed * 1000 + i for i in range(n_traces)),
    )


def run(T: int = 60_000, n_traces: int = 3, seed: int = 0,
        quiet: bool = False):
    res = run_sweep(sweep(T=T, n_traces=n_traces, seed=seed))
    table = report.mrr_matrix(res.records, POLICY_ORDER, baseline="fifo")
    wins = report.winners(res.records, POLICY_ORDER)

    if not quiet:
        report.print_table(table, POLICY_ORDER)
        print("\nFig.6 winners (fraction of traces with lowest miss ratio):")
        for c, w in wins.items():
            best = max(w, key=w.get)
            print(f"  {c:16s} {best} ({w[best]:.0%})")

    climb_best = sum(
        max(w, key=w.get) in ("adaptiveclimb", "dynamicadaptiveclimb")
        for w in wins.values())
    return res.save(extras={
        "table": table, "winners": wins,
        "climb_best_cells": climb_best, "total_cells": len(wins)})


if __name__ == "__main__":
    run()
