"""Table III / Fig. 5 / Fig. 6: miss-ratio reduction relative to FIFO across
the six dataset families x {small, large} cache regimes x 13 policies, plus
the best-performing-policy-per-dataset breakdown (Fig. 6).

The paper's six public trace sets are not redistributable offline; each
family here is a synthetic generator matched to the published workload
character (see repro.data.traces).  The validated claim is the paper's
*qualitative* one: the climb policies lead or co-lead MRR, with the gap
widening under working-set churn.
"""
from __future__ import annotations

import numpy as np

from repro.core import Engine, mrr
from repro.data.traces import DATASET_FAMILIES, dataset_family
from .common import fmt_row, k_for, save

POLICY_ORDER = [
    "dynamicadaptiveclimb", "adaptiveclimb", "sieve", "arc", "tinylfu",
    "twoq", "lirs", "lhd", "lfu", "hyperbolic", "clock", "climb", "lru",
    "blru", "fifo",
]


def run(T: int = 60_000, n_traces: int = 3, seed: int = 0,
        quiet: bool = False):
    engine = Engine()
    datasets = list(DATASET_FAMILIES)
    table = {}
    wins = {}
    for ds in datasets:
        cfg_N = DATASET_FAMILIES[ds]["N"]
        traces = dataset_family(ds, T=T, n_traces=n_traces, seed=seed)
        for regime in ("L", "S"):
            K = k_for(cfg_N * 2, regime)   # x2: scan families use 2N ids
            col = f"{ds}({regime})"
            mrs = {}
            for name in POLICY_ORDER:
                res = engine.replay(name, np.asarray(traces), K)
                mrs[name] = np.atleast_1d(res.miss_ratio)  # [n_traces]
            fifo = mrs["fifo"]
            table[col] = {
                name: float(np.mean([mrr(m, f) for m, f in
                                     zip(mrs[name], fifo)]))
                for name in POLICY_ORDER}
            # Fig. 6: winner fraction per trace
            stack = np.stack([mrs[n] for n in POLICY_ORDER])
            winners = np.argmin(stack, axis=0)
            wins[col] = {POLICY_ORDER[i]: float((winners == i).mean())
                         for i in set(winners.tolist())}

    if not quiet:
        cols = list(table)
        print(fmt_row(["policy"] + cols, [22] + [14] * len(cols)))
        for name in POLICY_ORDER:
            print(fmt_row([name] + [f"{table[c][name]:+.3f}" for c in cols],
                          [22] + [14] * len(cols)))
        print("\nFig.6 winners (fraction of traces with lowest miss ratio):")
        for c, w in wins.items():
            best = max(w, key=w.get)
            print(f"  {c:16s} {best} ({w[best]:.0%})")

    climb_best = sum(
        max(w, key=w.get) in ("adaptiveclimb", "dynamicadaptiveclimb")
        for w in wins.values())
    return save("mrr_table", {
        "T": T, "n_traces": n_traces, "table": table, "winners": wins,
        "climb_best_cells": climb_best, "total_cells": len(wins)})


if __name__ == "__main__":
    run()
