"""Adversarial robustness grid: every policy — with and without the
size-aware admission layer — against the hostile trace families.

The paper's headline claim is adaptivity under *fluctuating working set
sizes*; the standard grid only exercises DAC where that fluctuation is
friendly.  This sweep is the hostile counterpart (the regimes where
lightweight ranked policies are known to break — Einziger et al.'s
size-aware admission line, Yang et al.'s scan/churn failure modes):

* ``flood``     one-hit-wonder bursts whose cold ids carry *large*
                bimodal sizes — the byte-weighted worst case admission
                exists for
* ``scanstorm`` sequential scans erupting mid-churn, same correlated
                oversized cold range
* ``diurnal``   square-wave load swings between a wide and a narrow
                working set — the resize controller's stress test
* ``thrash``    a cyclic loop strictly wider than the cache, reuse
                distance > K by construction: the LRU worst case

Policies run bare and under ``admit(...)`` (ghost filter, size-norm on).
The headline table is :func:`repro.bench.report.robustness_frontier`:
per policy, the worst-case and mean byte-weighted MRR vs FIFO across the
grid — robustness is the *min*, not the mean.  The payload asserts the
tentpole claim as data: ``extras["flood_check"]`` records whether
admission improved DAC's worst flood cell (CI gates on it).

Run via ``python -m benchmarks.run --only robustness``; invoking the
module directly (or ``run(commit=...)``) additionally refreshes the
committed ``experiments/bench/BENCH_robustness.json`` artifact.
"""
from __future__ import annotations

import numpy as np

from repro.bench import Scenario, Sweep, report, results, run_sweep
from repro.bench.results import atomic_write_json

ADMIT = "admit({})"        # ghost filter + size_norm defaults
BASES = ("fifo", "lru", "arc", "sieve", "dac")
POLS = BASES + tuple(ADMIT.format(p) for p in ("lru", "dac"))

COMMIT_PATH = "experiments/bench/BENCH_robustness.json"

_SIZED = dict(cost_model="fetch")


def _scenarios(N: int, T: int) -> tuple:
    """The hostile grid at footprint ``N``: flood/scanstorm address a 2N
    id range (cold ids >= N), and the bimodal size model's ``split=N``
    pins exactly those cold ids at the large mode — one-hit wonders are
    *oversized*, so the byte metrics feel them.  ``thrash`` loops over
    ``N // 4`` keys, strictly wider than the large-regime cache
    (``K_L = N // 10``), so its reuse distance defeats recency by
    construction at every grid capacity."""
    bimodal = f"bimodal(split={N},small_kb=4,large_kb=64)"
    return (
        Scenario("flood",
                 trace=f"flood(N={N},alpha=0.9,flood_frac=0.35,"
                       "burst_len=128,phases=4)",
                 T=T, K=("S", "L"), size_model=bimodal, **_SIZED),
        Scenario("scanstorm",
                 trace=f"scanstorm(N={N},alpha=0.9,mean_phase=2000,"
                       "drift=0.1,storm_frac=0.25,scan_len=256)",
                 T=T, K=("S", "L"), size_model=bimodal, **_SIZED),
        Scenario("diurnal",
                 trace=f"diurnal(N={N},period={N},lo=64)",
                 T=T, K=("S", "L"),
                 size_model="lognormal(median_kb=16,sigma=1.5)", **_SIZED),
        Scenario("thrash",
                 trace=f"thrash(N={N},loop={N // 4})",
                 T=T, K=("S", "L"),
                 size_model="lognormal(median_kb=16,sigma=1.5)", **_SIZED),
    )


def sweep(N: int = 4096, T: int = 40_000, seeds=(0, 1)) -> Sweep:
    return Sweep("robustness", policies=POLS,
                 scenarios=_scenarios(N, T), seeds=seeds)


def _flood_check(frontier: dict) -> dict:
    """The tentpole claim as data: admission must improve DAC's *worst*
    flood cell (and not cost it the flood mean)."""
    def flood_worst(pol):
        cells = {c: v for c, v in frontier[pol]["per_cell"].items()
                 if c.startswith("flood(")}
        return (min(cells.values()) if cells else None,
                float(np.mean(list(cells.values()))) if cells else None)

    dac_worst, dac_mean = flood_worst("dac")
    adm_worst, adm_mean = flood_worst(ADMIT.format("dac"))
    ok = (None not in (dac_worst, adm_worst)
          and adm_worst >= dac_worst)
    return {"dac_worst": dac_worst, "dac_mean": dac_mean,
            "admit_dac_worst": adm_worst, "admit_dac_mean": adm_mean,
            "ok": bool(ok)}


def run(N: int = 4096, T: int = 40_000, seeds=(0, 1), quiet: bool = False,
        commit: str | None = None):
    sw = sweep(N=N, T=T, seeds=seeds)
    res = run_sweep(sw, progress=None if quiet else print)
    frontier = report.robustness_frontier(res.records, POLS)
    check = _flood_check(frontier)
    if not quiet:
        print("\nbyte-weighted MRR vs fifo — worst cell / grid mean")
        for pol in POLS:
            f = frontier[pol]
            print(report.fmt_row(
                [pol, f"{f['worst']:+.3f}", f['worst_cell'],
                 f"{f['mean']:+.3f}"], [14, 8, 16, 8]))
        print(f"\nflood check (admission vs bare dac, worst cell): "
              f"{'OK' if check['ok'] else 'FAILED'} "
              f"(admit {check['admit_dac_worst']:+.3f} vs "
              f"dac {check['dac_worst']:+.3f})")
    if not check["ok"]:
        print("WARNING: size-aware admission did not improve DAC's worst "
              "flood cell — the robustness claim does not hold on this run")
    payload = res.save(extras={"frontier": frontier, "flood_check": check},
                       schema=results.SCHEMA_V2)
    if commit is not None:
        atomic_write_json(commit, payload)
        if not quiet:
            print(f"committed artifact refreshed: {commit}")
    return payload


if __name__ == "__main__":
    run(commit=COMMIT_PATH)
