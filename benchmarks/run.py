"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--list] [--only NAME ...]

Every table runs through the declarative Sweep API (repro.bench) and
writes a schema-validated JSON result to experiments/bench/ (or
``--out-dir DIR``, so ad-hoc runs and campaign stores never interleave
JSONs into the same directory).  ``--only`` takes *exact* job names
(repeatable, comma-separable; see ``--list``) and exits non-zero when a
requested name doesn't exist — no silent no-op runs.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.bench import results


def _jobs():
    from . import (ablation_eps, byte_miss, curve_cachesize, fleet_sweep,
                   kv_bounded, mrr_table, ops_per_request, real_traces,
                   robustness, skew_sweep, tenant_sweep, throughput)

    # name -> (description, fn(fast) -> validated payload)
    return {
        "mrr_table": (
            "Table III / Fig 5-6",
            lambda fast: mrr_table.run(T=20_000 if fast else 60_000,
                                       n_traces=2 if fast else 3)),
        "curve_cachesize": (
            "Fig 8",
            lambda fast: curve_cachesize.run(T=30_000 if fast else 80_000)),
        "skew_sweep": (
            "Fig 11",
            lambda fast: skew_sweep.run(T=20_000 if fast else 60_000)),
        "byte_miss": (
            "Fig 10",
            lambda fast: byte_miss.run(T=20_000 if fast else 60_000)),
        "ops_per_request": (
            "Fig 9", lambda fast: ops_per_request.run()),
        "throughput": (
            "Tables IV/V, Fig 7",
            lambda fast: throughput.run(T=10_000 if fast else 30_000)),
        "kv_bounded": (
            "beyond-paper",
            lambda fast: kv_bounded.run(gen=16 if fast else 32)),
        "real_traces": (
            "paper's real-trace grid (miniature corpus, streaming path, "
            "v2 schema)",
            lambda fast: real_traces.run(T=2000 if fast else None)),
        "tenant_sweep": (
            "beyond-paper (multi-tenant tier, v2 schema)",
            lambda fast: tenant_sweep.run(
                T=24_000 if fast else 60_000,
                seeds=(0, 1) if fast else (0, 1, 2))),
        "fleet_sweep": (
            "beyond-paper (dynamic fleet + SLO telemetry, v2 schema)",
            lambda fast: fleet_sweep.run(
                T=16_000 if fast else 40_000,
                seeds=(0, 1) if fast else (0, 1, 2))),
        "ablation_eps": (
            "beyond-paper",
            lambda fast: ablation_eps.run(T=20_000 if fast else 60_000)),
        "robustness": (
            "beyond-paper (size-aware admission vs hostile grid, "
            "v2 schema)",
            lambda fast: robustness.run(
                N=1024 if fast else 4096,
                T=6000 if fast else 40_000,
                seeds=(0,) if fast else (0, 1))),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--fast", action="store_true",
                    help="smaller T for a quick pass")
    ap.add_argument("--list", action="store_true",
                    help="print the exact job names and exit")
    ap.add_argument("--only", action="append", default=[], metavar="NAME",
                    help="run only these jobs (exact names; repeatable or "
                         "comma-separated)")
    ap.add_argument("--out-dir", default=None, metavar="DIR",
                    help="write result JSONs here instead of "
                         f"{results.RESULTS_DIR} (BENCH_OUT)")
    args = ap.parse_args(argv)
    if args.out_dir:
        results.set_results_dir(args.out_dir)

    jobs = _jobs()
    if args.list:
        for name, (desc, _) in jobs.items():
            print(f"{name:18s} {desc}")
        return 0

    selected = [n.strip() for arg in args.only for n in arg.split(",")
                if n.strip()]
    unknown = [n for n in selected if n not in jobs]
    if unknown:
        print(f"error: unknown job name(s) {unknown}; "
              f"known: {list(jobs)}", file=sys.stderr)
        return 2
    if args.only and not selected:
        print("error: --only matched nothing", file=sys.stderr)
        return 2
    to_run = selected or list(jobs)

    for name in to_run:
        desc, fn = jobs[name]
        print(f"\n{'='*72}\n{name} ({desc})\n{'='*72}")
        t0 = time.perf_counter()
        payload = fn(args.fast)
        results.validate(payload)
        print(f"[{name}] {time.perf_counter()-t0:.1f}s "
              f"(schema {payload['schema']} OK, "
              f"{len(payload['records'])} records)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
