"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Writes JSON results to experiments/bench/ and prints each table.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller T for a quick pass")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from . import (ablation_eps, byte_miss, curve_cachesize, kv_bounded,
                   mrr_table, ops_per_request, skew_sweep, throughput)

    fast = args.fast
    jobs = [
        ("mrr_table (Table III / Fig 5-6)",
         lambda: mrr_table.run(T=20_000 if fast else 60_000,
                               n_traces=2 if fast else 3)),
        ("curve_cachesize (Fig 8)",
         lambda: curve_cachesize.run(T=30_000 if fast else 80_000)),
        ("skew_sweep (Fig 11)",
         lambda: skew_sweep.run(T=20_000 if fast else 60_000)),
        ("byte_miss (Fig 10)",
         lambda: byte_miss.run(T=20_000 if fast else 60_000)),
        ("ops_per_request (Fig 9)", ops_per_request.run),
        ("throughput (Tables IV/V, Fig 7)",
         lambda: throughput.run(T=10_000 if fast else 30_000)),
        ("kv_bounded (beyond-paper)",
         lambda: kv_bounded.run(gen=16 if fast else 32)),
        ("ablation_eps (beyond-paper)",
         lambda: ablation_eps.run(T=20_000 if fast else 60_000)),
    ]
    for name, fn in jobs:
        if args.only and args.only not in name:
            continue
        print(f"\n{'='*72}\n{name}\n{'='*72}")
        t0 = time.time()
        fn()
        print(f"[{name}] {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
