"""Shared benchmark plumbing: result sink + trace/size regimes."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

# cache-size regimes, as fractions of the trace footprint (paper §V-B:
# small = 0.1%, large = 10%); the synthetic families use N=8192 objects
SMALL_FRAC = 0.001
LARGE_FRAC = 0.10


def k_for(N: int, regime: str) -> int:
    frac = SMALL_FRAC if regime == "S" else LARGE_FRAC
    return max(4, int(N * frac))


def save(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    payload = {"bench": name, "time": time.time(), **payload}
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return payload


def fmt_row(cells, widths):
    return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths))
