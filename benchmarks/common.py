"""Shared benchmark plumbing, now thin shims over ``repro.bench``.

``k_for`` / the regime fractions / ``fmt_row`` re-export from the bench
package; ``save`` wraps a legacy free-form payload in the canonical
versioned envelope (git SHA, jax version, x64 flag — see
``repro.bench.results``) so even non-sweep payloads are attributable and
schema-valid.
"""
from __future__ import annotations

from repro.bench import results
from repro.bench.report import fmt_row                          # noqa: F401
from repro.bench.scenario import (LARGE_FRAC, SMALL_FRAC,       # noqa: F401
                                  k_for)

RESULTS_DIR = results.RESULTS_DIR


def save(name: str, payload: dict, *, config: dict | None = None,
         records: list | None = None,
         schema: str = results.SCHEMA_V2,
         wall_s: float | None = None,
         results_dir: str | None = None) -> dict:
    """Wrap a free-form payload as the ``extras`` of a canonical result
    envelope, validate it, and write ``<results_dir>/<name>.json``
    (default: the live ``repro.bench.results`` directory, which
    ``benchmarks.run --out-dir`` redirects).  New payloads default to
    ``repro.bench.result/v2`` (a strict superset of v1); pass
    ``schema=results.SCHEMA_V1`` to pin v1."""
    out = results.build_payload(name, config=config or {},
                                records=records or [], extras=payload,
                                schema=schema, wall_s=wall_s)
    results.save(out, results_dir=results_dir)
    return out
