"""Fig. 10: byte miss ratio at different cache sizes on a wiki-like trace
(log-normal object sizes, shifting-Zipf popularity).

The first real size- and cost-aware workload: requests carry per-object
sizes (``repro.data.traces.object_sizes``) and a latency cost model
(``fetch_costs``), and the byte-miss / penalty metrics come straight off
``Engine.replay`` — the engine reduces them per lane inside the jitted
program, nothing is recomputed post-hoc from hit masks.

DynamicAdaptiveClimb vs LRU vs ARC (the paper additionally compares LRB, a
*learned* policy needing offline training — out of scope offline; noted).
Byte miss ratio = sum(size_t * miss_t) / sum(size_t); penalty ratio is the
same weighting by fetch latency.
"""
from __future__ import annotations

from repro.core import Engine, Request
from repro.data.traces import fetch_costs, object_sizes, shifting_zipf_trace
from .common import fmt_row, save

POLS = ["lru", "arc", "dynamicadaptiveclimb"]


def run(N: int = 4096, T: int = 60_000, seed: int = 0, quiet: bool = False):
    engine = Engine()
    trace = shifting_zipf_trace(N=N, T=T, alpha=0.9, phases=4, seed=seed)
    sizes = object_sizes(N, seed=seed)
    costs = fetch_costs(sizes)
    reqs = Request.of(trace, sizes=sizes[trace], costs=costs[trace])
    fracs = [0.01, 0.02, 0.05, 0.10, 0.20, 0.40]
    rows = {}
    for frac in fracs:
        K = max(4, int(N * frac))
        row = {}
        for p in POLS:
            res = engine.replay(p, reqs, K)
            row[p] = res.byte_miss_ratio
            row[f"{p}_penalty"] = res.penalty_ratio
        rows[frac] = row
    if not quiet:
        print(fmt_row(["K/N"] + [f"{p} byte|pen" for p in POLS],
                      [8] + [22] * len(POLS)))
        for frac, row in rows.items():
            print(fmt_row(
                [f"{frac:.0%}"]
                + [f"{row[p]:.3f}|{row[f'{p}_penalty']:.3f}" for p in POLS],
                [8] + [22] * len(POLS)))
    return save("byte_miss", {"N": N, "T": T,
                              "rows": {str(k): v for k, v in rows.items()}})


if __name__ == "__main__":
    run()
