"""Fig. 10: byte miss ratio at different cache sizes on a wiki-like trace
(log-normal object sizes, shifting-Zipf popularity).

DynamicAdaptiveClimb vs LRU vs ARC (the paper additionally compares LRB, a
*learned* policy needing offline training — out of scope offline; noted).
Byte miss ratio = sum(size_t * miss_t) / sum(size_t).
"""
from __future__ import annotations

import numpy as np

from repro.core import POLICIES, replay
from repro.data.traces import object_sizes, shifting_zipf_trace
from .common import fmt_row, save

POLS = ["lru", "arc", "dynamicadaptiveclimb"]


def run(N: int = 4096, T: int = 60_000, seed: int = 0, quiet: bool = False):
    trace = shifting_zipf_trace(N=N, T=T, alpha=0.9, phases=4, seed=seed)
    sizes = object_sizes(N, seed=seed)
    req_bytes = sizes[trace]
    fracs = [0.01, 0.02, 0.05, 0.10, 0.20, 0.40]
    rows = {}
    for frac in fracs:
        K = max(4, int(N * frac))
        row = {}
        for p in POLS:
            hits = np.asarray(replay(POLICIES[p](), trace, K))
            row[p] = float(((~hits) * req_bytes).sum() / req_bytes.sum())
        rows[frac] = row
    if not quiet:
        print(fmt_row(["K/N"] + POLS, [8] + [22] * len(POLS)))
        for frac, row in rows.items():
            print(fmt_row([f"{frac:.0%}"] + [f"{row[p]:.3f}" for p in POLS],
                          [8] + [22] * len(POLS)))
    return save("byte_miss", {"N": N, "T": T,
                              "rows": {str(k): v for k, v in rows.items()}})


if __name__ == "__main__":
    run()
