"""Fig. 10: byte miss ratio at different cache sizes on a wiki-like trace
(log-normal object sizes, shifting-Zipf popularity).

The size- and cost-aware workload as pure data: the scenario declares a
``lognormal`` object-size model and a ``fetch`` latency-cost model next to
its trace spec, and the byte-miss / penalty metrics come straight off the
engine (reduced per lane inside the jitted program, nothing recomputed
post-hoc from hit masks).

DynamicAdaptiveClimb vs LRU vs ARC (the paper additionally compares LRB, a
*learned* policy needing offline training — out of scope offline; noted).
Byte miss ratio = sum(size_t * miss_t) / sum(size_t); penalty ratio is the
same weighting by fetch latency.
"""
from __future__ import annotations

import numpy as np

from repro.bench import Scenario, Sweep, report, run_sweep

POLS = ["lru", "arc", "dynamicadaptiveclimb"]
FRACS = [0.01, 0.02, 0.05, 0.10, 0.20, 0.40]


def sweep(N: int = 4096, T: int = 60_000, seed: int = 0) -> Sweep:
    return Sweep(
        "byte_miss",
        policies=tuple(POLS),
        scenarios=(Scenario(
            "wiki_sized",
            trace=f"shifting_zipf(N={N},alpha=0.9,phases=4)", T=T,
            K=tuple(max(4, int(N * f)) for f in FRACS),
            size_model=f"lognormal(seed={seed})",
            cost_model="fetch"),),
        seeds=(seed,),
    )


def run(N: int = 4096, T: int = 60_000, seed: int = 0, quiet: bool = False):
    res = run_sweep(sweep(N=N, T=T, seed=seed))
    rows = {}
    for frac, K in zip(FRACS, res.sweep.scenarios[0].capacities()):
        row = {}
        for p in POLS:
            row[p] = float(np.mean(res.metric("byte_miss_ratio",
                                              policy=p, K=K)))
            row[f"{p}_penalty"] = float(np.mean(res.metric(
                "penalty_ratio", policy=p, K=K)))
        rows[frac] = row
    if not quiet:
        print(report.fmt_row(["K/N"] + [f"{p} byte|pen" for p in POLS],
                             [8] + [22] * len(POLS)))
        for frac, row in rows.items():
            print(report.fmt_row(
                [f"{frac:.0%}"]
                + [f"{row[p]:.3f}|{row[f'{p}_penalty']:.3f}" for p in POLS],
                [8] + [22] * len(POLS)))
    return res.save(extras={"rows": {str(k): v for k, v in rows.items()}})


if __name__ == "__main__":
    run()
