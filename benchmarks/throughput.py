"""Tables IV/V + Fig. 7: throughput scaling — plus the policy-step
performance trajectory.

The paper replays disjoint traces on 1..16 threads; the SPMD-native
equivalent replays 1..16 *parallel cache lanes* (vmap) per step — same
embarrassingly-parallel structure, measured in Mops on this host.  On a
real pod the lanes additionally spread over the data axis via
``Engine.replay(..., mesh=...)`` (examples/trace_study.py).

The lanes are the seed axis of one declarative Scenario, materialized by
the sweep runner (no hand-stacked traces); the timing harness itself stays
wall-clock, so the replay runs here rather than through ``run_sweep``.
Replays run in metrics-only mode (``collect_info=False``) — the honest
throughput number excludes materializing a [lanes, T] StepInfo stack that
production replay never needs.  Rank-based policies are additionally
measured through the fused Pallas policy-step kernel in every *executable*
lowering — ``"interpret"`` anywhere, ``"compiled"`` (Mosaic/Triton) on
tpu/gpu — and reported side by side with the jnp lowering.

``--policy-step`` runs the second bench: the committed performance
trajectory (``experiments/bench/BENCH_policy_step.json``) — jnp vs
interpret vs compiled Mops for each rank policy × K in the parity grid,
stamped with the memory-bound roofline targets from
``repro.launch.roofline.policy_step_targets``.  On hosts that cannot
execute compiled Pallas (CPU) the compiled cells are skipped and replaced
with lowering evidence: the compiled configuration is cross-platform
exported for TPU (Mosaic-legal or the bench fails), recorded under
``extras["compiled"]``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import Scenario, materialize, report, results
from repro.core import Engine, make_policy
from repro.core.policy import lane_pad
from repro.launch.roofline import policy_step_targets

from benchmarks.common import save

POLS = ["climb", "adaptiveclimb", "dynamicadaptiveclimb", "tinylfu",
        "clock", "sieve", "twoq", "arc", "lru", "blru"]
# policies with a fused Pallas policy-step lowering (rank-array family)
RANK_POLS = {"climb", "adaptiveclimb", "dynamicadaptiveclimb"}
# the committed perf-trajectory grid (ISSUE: parity K grid)
K_GRID = (128, 1024, 8192, 65536)


def _compiled_executable() -> bool:
    """Compiled Pallas runs only on tpu (Mosaic) / gpu (Triton)."""
    return jax.default_backend() in ("tpu", "gpu")


def _pallas_modes() -> list:
    return ["interpret"] + (["compiled"] if _compiled_executable() else [])


def scenario(T: int, K: int) -> Scenario:
    return Scenario("zipf_hot", trace="zipf(N=8192,alpha=1.1)", T=T, K=(K,))


def _measure(engine, pol, reqs, K, use_pallas):
    res = engine.replay(pol, reqs, K, collect_info=False,
                        use_pallas=use_pallas)
    jax.block_until_ready(res.metrics.hits)        # compile + warm
    t0 = time.perf_counter()
    res = engine.replay(pol, reqs, K, collect_info=False,
                        use_pallas=use_pallas)
    jax.block_until_ready(res.metrics.hits)
    return time.perf_counter() - t0


def run(K: int = 256, T: int = 30_000, lanes_list=(1, 2, 4, 8, 16),
        quiet: bool = False):
    engine = Engine()
    lanes_list = list(lanes_list)
    sc = scenario(T, K)
    lane_reqs = {lanes: materialize(sc, seeds=range(lanes))
                 for lanes in lanes_list}
    t_start = time.perf_counter()
    table = {}
    records = []
    for p in POLS:
        pol = make_policy(p)
        modes = ["jnp"] + (_pallas_modes() if p in RANK_POLS else [])
        for mode in modes:
            row = {}
            for lanes in lanes_list:
                dt = _measure(engine, pol, lane_reqs[lanes], K,
                              use_pallas=False if mode == "jnp" else mode)
                row[lanes] = lanes * T / dt / 1e6       # Mops
                records.append({
                    "policy": p, "scenario": sc.name, "trace": sc.trace,
                    "T": T, "K": K, "K_label": str(K), "mode": mode,
                    "lanes": lanes,
                    "metrics": {"mops": row[lanes], "wall_s": dt}})
            table[f"{p}[{mode}]" if len(modes) > 1 else p] = row
    if not quiet:
        print(report.fmt_row(["policy"] + [f"{n} lanes" for n in lanes_list]
                             + ["avg"], [30] + [10] * (len(lanes_list) + 1)))
        for p, row in table.items():
            vals = [row[n] for n in lanes_list]
            print(report.fmt_row([p] + [f"{v:.2f}" for v in vals]
                                 + [f"{np.mean(vals):.2f}"],
                                 [30] + [10] * (len(lanes_list) + 1)))
    return save(
        "throughput",
        {"table": {p: {str(k): v for k, v in r.items()}
                   for p, r in table.items()}},
        config={"K": K, "T": T, "lanes": lanes_list,
                "scenario": sc.to_config()},
        records=records,
        wall_s=time.perf_counter() - t_start)


# ---------------------------------------------------------------------------
# policy-step performance trajectory (BENCH_policy_step.json)
# ---------------------------------------------------------------------------

def _padded_width(spec: str, K: int) -> int:
    """The rank-row width policy ``spec`` allocates at capacity K (DAC
    over-allocates its growth headroom)."""
    pol = make_policy(spec)
    return int(pol.init(K)["cache"].shape[0])


def _export_compiled_lowering(spec: str, K: int) -> bool:
    """Lowering evidence where compiled Pallas cannot execute: export the
    scanned compiled-mode replay program for TPU (runs the full Mosaic
    pass pipeline); any illegal kernel raises here."""
    import jax.export
    from repro.core.policy import pallas_mode

    pol = make_policy(spec)

    def f(keys):
        with pallas_mode("compiled"):
            def body(st, key):
                from repro.core import Request
                st, info = pol.step(st, Request.of(key))
                return st, info.hit
            return jax.lax.scan(body, pol.init(K), keys)[1]

    jax.export.export(jax.jit(f), platforms=["tpu"])(
        jax.ShapeDtypeStruct((16,), jnp.int32))
    return True


def run_policy_step(K_grid=K_GRID, T: int = 2000, quiet: bool = False):
    """The committed perf trajectory: scanned single-lane replay Mops per
    rank policy × K × lowering.  T is a cap — each K runs
    ``min(T, 2^21 / K)`` requests (>= 128) so the largest rows stay
    tractable on CPU while small-K cells get stable timings."""
    engine = Engine()
    t_start = time.perf_counter()
    compiled_ok = _compiled_executable()
    modes = ["jnp"] + _pallas_modes()
    records = []
    table = {}
    targets = {}
    for p in sorted(RANK_POLS, key=POLS.index):
        for K in K_grid:
            W = _padded_width(p, K)
            target = policy_step_targets([W])[W]
            targets[f"{p}/K{K}"] = target
            T_eff = int(max(128, min(T, (1 << 21) // K)))
            sc = Scenario("policy_step", T=T_eff, K=(K,),
                          trace=f"zipf(N={max(4096, 2 * K)},alpha=1.1)")
            reqs = materialize(sc, seeds=range(1))
            for mode in modes:
                dt = _measure(engine, p, reqs, K,
                              use_pallas=False if mode == "jnp" else mode)
                mops = T_eff / dt / 1e6
                metrics = {"mops": mops, "wall_s": dt,
                           "target_mops": target}
                if mode == "compiled":
                    # roofline validation: achieved fraction of the
                    # memory-bound HBM roof for this row width
                    metrics["roofline_frac"] = mops / target
                records.append({
                    "policy": p, "scenario": sc.name, "trace": sc.trace,
                    "T": T_eff, "K": K, "K_label": str(K), "mode": mode,
                    "W": W, "metrics": metrics})
                table[f"{p}[{mode}]/K{K}"] = mops
    compiled_extras = {"status": "executed" if compiled_ok else
                       "skipped: this backend cannot execute compiled "
                       "Pallas (see lowering_ok for Mosaic evidence)",
                       "backend": jax.default_backend()}
    if not compiled_ok:
        compiled_extras["lowering_ok"] = {
            p: _export_compiled_lowering(p, min(K_grid))
            for p in sorted(RANK_POLS, key=POLS.index)}
    if not quiet:
        print(report.fmt_row(["policy[mode]/K", "Mops"], [40, 12]))
        for k, v in table.items():
            print(report.fmt_row([k, f"{v:.3f}"], [40, 12]))
    return save(
        "BENCH_policy_step",
        {"table": table, "roofline_target_mops": targets,
         "compiled": compiled_extras},
        config={"K_grid": list(K_grid), "T_cap": T, "modes": modes},
        records=records,
        wall_s=time.perf_counter() - t_start)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--K", type=int, default=256)
    ap.add_argument("--T", type=int, default=30_000)
    ap.add_argument("--lanes", type=int, nargs="+", default=[1, 2, 4, 8, 16])
    ap.add_argument("--quiet", action="store_true",
                    help="no table; still writes the JSON result")
    ap.add_argument("--policy-step", action="store_true",
                    help="run the policy-step perf trajectory "
                         "(BENCH_policy_step.json) instead of the "
                         "lane-scaling table")
    ap.add_argument("--K-grid", type=int, nargs="+", default=list(K_GRID),
                    help="--policy-step: capacities to measure")
    args = ap.parse_args()
    if args.policy_step:
        run_policy_step(K_grid=tuple(args.K_grid),
                        T=min(args.T, 30_000) if args.T else 2000,
                        quiet=args.quiet)
    else:
        run(K=args.K, T=args.T, lanes_list=args.lanes, quiet=args.quiet)


if __name__ == "__main__":
    main()
