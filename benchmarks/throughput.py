"""Tables IV/V + Fig. 7: throughput scaling.

The paper replays disjoint traces on 1..16 threads; the SPMD-native
equivalent replays 1..16 *parallel cache lanes* (vmap) per step — same
embarrassingly-parallel structure, measured in Mops on this host.  On a
real pod the lanes additionally spread over the data axis via
``Engine.replay(..., mesh=...)`` (examples/trace_study.py).

The lanes are the seed axis of one declarative Scenario, materialized by
the sweep runner (no hand-stacked traces); the timing harness itself stays
wall-clock, so the replay runs here rather than through ``run_sweep``.
Replays run in metrics-only mode (``collect_info=False``) — the honest
throughput number excludes materializing a [lanes, T] StepInfo stack that
production replay never needs.  Rank-based policies are additionally
measured through the fused Pallas policy-step kernel (``use_pallas=True``,
interpret-mode off-TPU) and reported side by side with the jnp lowering.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.bench import Scenario, materialize, report, results
from repro.core import Engine, make_policy

POLS = ["climb", "adaptiveclimb", "dynamicadaptiveclimb", "tinylfu",
        "clock", "sieve", "twoq", "arc", "lru", "blru"]
# policies with a fused Pallas policy-step lowering (rank-array family)
RANK_POLS = {"climb", "adaptiveclimb", "dynamicadaptiveclimb"}


def scenario(T: int, K: int) -> Scenario:
    return Scenario("zipf_hot", trace="zipf(N=8192,alpha=1.1)", T=T, K=(K,))


def _measure(engine, pol, reqs, K, use_pallas):
    res = engine.replay(pol, reqs, K, collect_info=False,
                        use_pallas=use_pallas)
    jax.block_until_ready(res.metrics.hits)        # compile + warm
    t0 = time.perf_counter()
    res = engine.replay(pol, reqs, K, collect_info=False,
                        use_pallas=use_pallas)
    jax.block_until_ready(res.metrics.hits)
    return time.perf_counter() - t0


def run(K: int = 256, T: int = 30_000, lanes_list=(1, 2, 4, 8, 16),
        quiet: bool = False):
    engine = Engine()
    lanes_list = list(lanes_list)
    sc = scenario(T, K)
    lane_reqs = {lanes: materialize(sc, seeds=range(lanes))
                 for lanes in lanes_list}
    t_start = time.perf_counter()
    table = {}
    records = []
    for p in POLS:
        pol = make_policy(p)
        modes = ["jnp"] + (["pallas"] if p in RANK_POLS else [])
        for mode in modes:
            row = {}
            for lanes in lanes_list:
                dt = _measure(engine, pol, lane_reqs[lanes], K,
                              use_pallas=(mode == "pallas"))
                row[lanes] = lanes * T / dt / 1e6       # Mops
                records.append({
                    "policy": p, "scenario": sc.name, "trace": sc.trace,
                    "T": T, "K": K, "K_label": str(K), "mode": mode,
                    "lanes": lanes,
                    "metrics": {"mops": row[lanes], "wall_s": dt}})
            table[f"{p}[{mode}]" if len(modes) > 1 else p] = row
    if not quiet:
        print(report.fmt_row(["policy"] + [f"{n} lanes" for n in lanes_list]
                             + ["avg"], [30] + [10] * (len(lanes_list) + 1)))
        for p, row in table.items():
            vals = [row[n] for n in lanes_list]
            print(report.fmt_row([p] + [f"{v:.2f}" for v in vals]
                                 + [f"{np.mean(vals):.2f}"],
                                 [30] + [10] * (len(lanes_list) + 1)))
    payload = results.build_payload(
        "throughput",
        config={"K": K, "T": T, "lanes": lanes_list,
                "scenario": sc.to_config()},
        records=records,
        extras={"table": {p: {str(k): v for k, v in r.items()}
                          for p, r in table.items()}},
        wall_s=time.perf_counter() - t_start)
    results.save(payload)
    return payload


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--K", type=int, default=256)
    ap.add_argument("--T", type=int, default=30_000)
    ap.add_argument("--lanes", type=int, nargs="+", default=[1, 2, 4, 8, 16])
    ap.add_argument("--quiet", action="store_true",
                    help="no table; still writes the JSON result")
    args = ap.parse_args()
    run(K=args.K, T=args.T, lanes_list=args.lanes, quiet=args.quiet)


if __name__ == "__main__":
    main()
