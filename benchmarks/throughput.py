"""Tables IV/V + Fig. 7: throughput scaling.

The paper replays disjoint traces on 1..16 threads; the SPMD-native
equivalent replays 1..16 *parallel cache lanes* (vmap) per step — same
embarrassingly-parallel structure, measured in Mops on this host.  On a
real pod the lanes additionally spread over the data axis via
``Engine.replay(..., mesh=...)`` (examples/trace_study.py).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Engine, make_policy
from repro.data.traces import zipf_trace
from .common import fmt_row, save

POLS = ["adaptiveclimb", "dynamicadaptiveclimb", "tinylfu", "clock",
        "sieve", "twoq", "arc", "lru", "blru"]


def run(K: int = 256, T: int = 30_000, quiet: bool = False):
    engine = Engine()
    lanes_list = [1, 2, 4, 8, 16]
    table = {}
    for p in POLS:
        pol = make_policy(p)
        row = {}
        for lanes in lanes_list:
            traces = np.stack([zipf_trace(8192, T, 1.1, seed=s)
                               for s in range(lanes)])
            jax.block_until_ready(
                engine.replay(pol, traces, K).info.hit)   # compile + warm
            t0 = time.perf_counter()
            jax.block_until_ready(engine.replay(pol, traces, K).info.hit)
            dt = time.perf_counter() - t0
            row[lanes] = lanes * T / dt / 1e6       # Mops
        table[p] = row
    if not quiet:
        print(fmt_row(["policy"] + [f"{n} lanes" for n in lanes_list]
                      + ["avg"], [22] + [10] * (len(lanes_list) + 1)))
        for p, row in table.items():
            vals = [row[n] for n in lanes_list]
            print(fmt_row([p] + [f"{v:.2f}" for v in vals]
                          + [f"{np.mean(vals):.2f}"],
                          [22] + [10] * (len(lanes_list) + 1)))
    return save("throughput", {
        "K": K, "T": T,
        "table": {p: {str(k): v for k, v in r.items()}
                  for p, r in table.items()}})


if __name__ == "__main__":
    run()
