"""Campaign driver: corpus-scale trace evaluation over `repro.campaign`.

  # run (or resume — completed cells are skipped) a campaign:
  PYTHONPATH=src python -m benchmarks.campaign \\
      --manifest campaign.json --store runs/corpus [--workers N] \\
      [--shard i/n] [--max-cells N] [--chunk C] [--quiet]

  # render the aggregate report from the store alone (nothing reruns):
  PYTHONPATH=src python -m benchmarks.campaign --store runs/corpus --report

  # coverage counts only:
  PYTHONPATH=src python -m benchmarks.campaign --store runs/corpus --status

The store directory is self-describing (it pins the manifest on first
run), so `--report` / `--status` / resumption need only `--store`.
`--shard i/n` runs the i-th round-robin slice of the full grid — launch
the same command on n hosts with i = 0..n-1 and point them at a shared
store.  `--max-cells` bounds how many cells execute this invocation
(smoke tests, crash-resume drills).  Failing traces are quarantined with
their traceback under `<store>/quarantine/` and reported, never fatal.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.bench import results
from repro.campaign import (CampaignStore, Manifest, format_report,
                            load_manifest, pending_cells, plan_cells,
                            render_report, run_campaign)


def _store_manifest(store: CampaignStore) -> Manifest:
    m = Manifest.from_dict(store.manifest_dict())
    # the pinned copy's root was already re-anchored by load_manifest
    return m


def _status(store: CampaignStore) -> int:
    m = _store_manifest(store)
    cells = plan_cells(m)
    pending = pending_cells(cells, store)
    print(f"campaign {m.name} @ {store.root}")
    print(f"  planned     {len(cells)}")
    print(f"  completed   {len(store.completed())}")
    print(f"  quarantined {len(store.quarantined())}")
    print(f"  pending     {len(pending)}")
    return 0


def _report(store: CampaignStore, out: str | None, baseline: str) -> int:
    report = render_report(store, baseline=baseline)
    path = out or os.path.join(store.root, "report.json")
    results.atomic_write_json(path, report, sort_keys=True)
    print(format_report(report))
    print(f"\nreport written to {path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--store", required=True,
                    help="campaign store directory (created if missing)")
    ap.add_argument("--manifest", default=None,
                    help="manifest file (JSON/TOML); optional when the "
                         "store already pins one")
    ap.add_argument("--workers", type=int, default=0,
                    help="process-pool size; <=1 runs inline (default)")
    ap.add_argument("--shard", default=None, metavar="i/n",
                    help="run only the i-th of n round-robin grid slices")
    ap.add_argument("--max-cells", type=int, default=None,
                    help="execute at most this many cells this run")
    ap.add_argument("--chunk", type=int, default=None,
                    help="streaming chunk size override")
    ap.add_argument("--report", action="store_true",
                    help="render the aggregate report from the store "
                         "and exit (nothing reruns)")
    ap.add_argument("--status", action="store_true",
                    help="print coverage counts and exit")
    ap.add_argument("--baseline", default="fifo",
                    help="baseline policy for the reduction tables "
                         "(default: fifo)")
    ap.add_argument("--out", default=None,
                    help="report JSON path (default: <store>/report.json)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    store = CampaignStore(args.store)
    if args.report:
        return _report(store, args.out, args.baseline)
    if args.status:
        return _status(store)

    if args.manifest:
        manifest = load_manifest(args.manifest)
    else:
        try:
            manifest = _store_manifest(store)
        except OSError:
            print("error: --manifest is required for a fresh store",
                  file=sys.stderr)
            return 2
    summary = run_campaign(
        manifest, store, workers=args.workers, shard=args.shard,
        max_cells=args.max_cells, chunk=args.chunk,
        progress=None if args.quiet else print)
    c = summary.counts
    print(f"[{manifest.name}] {c['executed']} executed, "
          f"{c['skipped']} skipped (already stored), "
          f"{c['quarantined']} quarantined, {c['remaining']} remaining "
          f"[{summary.wall_s:.1f}s]")
    if summary.quarantined and not args.quiet:
        for key in summary.quarantined:
            q = store.get_quarantined(key)
            print(f"  quarantined {key}: {q['cell']['trace']} "
                  f"({q['error'].strip().splitlines()[-1]})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
