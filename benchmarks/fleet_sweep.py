"""Beyond-paper: dynamic-fleet serving — auction-arbitrated DAC vs
statically-partitioned baselines under tenant churn.

Two ``fleet(...)`` grids (Poisson arrivals, exponential sessions, ``-1``
idle-lane encoding — see :func:`repro.data.traces.fleet_trace`):

* ``pool``   12 lanes, long sessions, ~6 concurrent tenants: half the
             static partitions sit idle while live tenants thrash — the
             regime where pooling is the whole game
* ``churn``  8 lanes, short sessions, constant arrivals/departures: the
             lifecycle stress (admission, slot return, mid-stream resets)

Entries pair a policy with an arbiter: ``dac+auction`` prices grants by
each tenant's byte-miss-cost EWMA, ``dac+greedy`` / ``dac+proportional``
trade through the same pool unpriced, and ``dac+static`` / ``lru+static``
/ ``fifo+static`` are hard-partitioned at ``budget // n_lanes``.  The
headline number is the aggregate byte-weighted MRR vs ``fifo+static``;
every record additionally carries the SLO telemetry (penalty p50/p99
from the in-carry histograms, Jain occupancy fairness) plus per-lane
sub-records, landing in the v2 schema (``repro.bench.results.SCHEMA_V2``).

Run via ``python -m benchmarks.run --only fleet_sweep``; invoking this
module directly (or ``run(commit=...)``) additionally refreshes the
committed ``experiments/bench/BENCH_fleet.json`` artifact that CI
validates (every committed BENCH artifact lives under
``experiments/bench/`` — ``tools/check_bench.py`` enforces it).
"""
from __future__ import annotations

import numpy as np

from repro.bench import (FleetScenario, FleetSweep, report, results,
                         run_fleet_sweep)
from repro.bench.results import atomic_write_json

DAC = "dac(k_min=16)"   # floor the shrink at the narrow-phase working set
ENTRIES = (
    (DAC, "auction"),
    (DAC, "greedy"),
    (DAC, "proportional"),
    (DAC, "static"),
    ("lru", "static"),
    ("fifo", "static"),
)

_MODELS = dict(size_model="lognormal(median_kb=16,sigma=1.5)",
               cost_model="fetch(base_ms=2.0,per_mb_ms=8.0)")


def _trace(n_lanes: int, rate: float, mean_session: int) -> str:
    return (f"fleet(N=256,n_lanes={n_lanes},rate={rate},"
            f"mean_session={mean_session},alpha=0.5,period=6000,"
            f"duty=0.25,lo=16,alpha_lo=1.6)")


def sweep(T: int = 40_000, seeds=(0, 1, 2)) -> FleetSweep:
    return FleetSweep(
        "fleet_sweep",
        entries=ENTRIES,
        scenarios=(
            FleetScenario("pool", trace=_trace(12, 0.002, 3000), T=T,
                          budget=(384,), **_MODELS),
            FleetScenario("churn", trace=_trace(8, 0.02, 300), T=T,
                          budget=(256,), **_MODELS),
        ),
        seeds=seeds,
    )


def _fleet_windows(sw, windows: int = 8) -> dict:
    """One observed auction replay per scenario (first seed): per-window
    occupancy / alive-fraction / conservation-peak records for the
    payload extras."""
    from repro.core import Engine
    from repro.data.traces import make_trace
    from repro.fleet import FleetTier, window_records

    out = {}
    for sc in sw.scenarios:
        tier = FleetTier(DAC, n_lanes=sc.n_lanes, budget=sc.budgets()[0],
                         arbiter="auction", util_decay=sc.util_decay)
        stream = make_trace(sc.trace).generate(sc.T, seed=sw.seeds[0])
        res = Engine().replay_fleet(tier, stream, observe=True)
        out[sc.name] = window_records(res.obs, windows)
    return out


def run(T: int = 40_000, seeds=(0, 1, 2), quiet: bool = False,
        commit: str | None = None):
    sw = sweep(T=T, seeds=seeds)
    res = run_fleet_sweep(sw, progress=None if quiet else print)
    mrr = report.tier_mrr_matrix(res.records, ENTRIES)
    wins = report.tier_winners(res.records, ENTRIES)
    windows = _fleet_windows(sw)
    if not quiet:
        labels = [f"{p}+{a}" for p, a in ENTRIES]
        print("\naggregate byte-weighted MRR vs fifo+static")
        report.print_table(mrr, labels, name_w=30)
        for rec in res.select(arbiter="auction"):
            m = rec["metrics"]
            print(f"[{rec['scenario']}] {rec['policy']}+auction  "
                  f"jain={np.mean(m['jain']):.3f}  "
                  f"p50={np.mean(m['penalty_p50']):.2f}ms  "
                  f"p99={np.mean(m['penalty_p99']):.2f}ms  "
                  f"avg_k_total={np.mean(m['avg_k_total']):.1f}")
    # the fleet thesis, asserted on every run: the priced pool beats the
    # best hard partition wherever tenants come and go
    for cell, vals in mrr.items():
        auction = vals[f"{DAC}+auction"]
        static_best = max(v for k, v in vals.items() if k.endswith("+static"))
        if not np.isfinite(auction) or auction <= static_best:
            print(f"WARNING: [{cell}] auction-arbitrated ({auction:.3f}) "
                  f"did not beat static partitioning ({static_best:.3f})")
    payload = res.save(extras={"mrr_vs_fifo_static": mrr, "winners": wins,
                               "fleet_windows_auction": windows})
    assert payload["schema"] == results.SCHEMA_V2, payload["schema"]
    if commit is not None:
        atomic_write_json(commit, payload)
        if not quiet:
            print(f"committed artifact refreshed: {commit}")
    return payload


if __name__ == "__main__":
    run(T=16_000, seeds=(0, 1), commit="experiments/bench/BENCH_fleet.json")
