"""Fig. 8: miss ratio vs cache size under Zipf(alpha=1.0) for LRU, LFU,
AdaptiveClimb, DynamicAdaptiveClimb.

Reproduction note (EXPERIMENTS.md §Repro): under a *stationary* Zipf, Alg. 2
reliably reaches its shrink condition (hits outnumber misses and concentrate
in the top half), so DAC trades miss ratio for memory at large nominal K.
The paper's Fig. 8 curve is reproduced when DAC's x-coordinate is its
*average adapted size* (the resource it actually used) — both plots are
emitted here: miss@nominal-K and (avg_k, miss) pareto points, the adapted
size coming off the sweep's ``observe`` channel.
"""
from __future__ import annotations

import numpy as np

from repro.bench import Scenario, Sweep, report, run_sweep

POLS = ["lru", "lfu", "adaptiveclimb", "dynamicadaptiveclimb"]
FRACS = [0.005, 0.01, 0.02, 0.05, 0.10, 0.20]


def sweep(N: int = 4096, T: int = 80_000, alpha: float = 1.0,
          seed: int = 0) -> Sweep:
    return Sweep(
        "curve_cachesize",
        policies=tuple(POLS),
        scenarios=(Scenario("zipf", trace=f"zipf(N={N},alpha={alpha})", T=T,
                            K=tuple(max(4, int(N * f)) for f in FRACS)),),
        seeds=(seed,),
        observe=True,
    )


def run(N: int = 4096, T: int = 80_000, alpha: float = 1.0, seed: int = 0,
        quiet: bool = False):
    res = run_sweep(sweep(N=N, T=T, alpha=alpha, seed=seed))
    rows, pareto = {}, []
    for frac, K in zip(FRACS, res.sweep.scenarios[0].capacities()):
        row = {p: float(np.mean(res.metric("miss_ratio", policy=p, K=K)))
               for p in POLS}
        avg_k = float(np.mean(res.metric(
            "avg_k", policy="dynamicadaptiveclimb", K=K)))
        row["dac_avg_k"] = avg_k
        pareto.append((avg_k / N, row["dynamicadaptiveclimb"]))
        rows[frac] = row
    if not quiet:
        print(report.fmt_row(["K/N"] + POLS + ["dac_avg_k/N"],
                             [8] + [22] * len(POLS) + [12]))
        for frac, row in rows.items():
            print(report.fmt_row(
                [f"{frac:.1%}"] + [f"{row[p]:.3f}" for p in POLS]
                + [f"{row['dac_avg_k']/N:.1%}"],
                [8] + [22] * len(POLS) + [12]))
        print("DAC pareto (avg_k/N, miss):",
              [(f"{k:.1%}", f"{m:.3f}") for k, m in pareto])
    return res.save(extras={
        "rows": {str(k): v for k, v in rows.items()}, "dac_pareto": pareto})


if __name__ == "__main__":
    run()
