"""Fig. 8: miss ratio vs cache size under Zipf(alpha=1.0) for LRU, LFU,
AdaptiveClimb, DynamicAdaptiveClimb.

Reproduction note (EXPERIMENTS.md §Repro): under a *stationary* Zipf, Alg. 2
reliably reaches its shrink condition (hits outnumber misses and concentrate
in the top half), so DAC trades miss ratio for memory at large nominal K.
The paper's Fig. 8 curve is reproduced when DAC's x-coordinate is its
*average adapted size* (the resource it actually used) — both plots are
emitted here: miss@nominal-K and (avg_k, miss) pareto points.
"""
from __future__ import annotations

import numpy as np

from repro.core import Engine
from repro.data.traces import zipf_trace
from .common import fmt_row, save

POLS = ["lru", "lfu", "adaptiveclimb", "dynamicadaptiveclimb"]


def run(N: int = 4096, T: int = 80_000, alpha: float = 1.0, seed: int = 0,
        quiet: bool = False):
    engine = Engine()
    trace = zipf_trace(N=N, T=T, alpha=alpha, seed=seed)
    fracs = [0.005, 0.01, 0.02, 0.05, 0.10, 0.20]
    rows = {}
    pareto = []
    for frac in fracs:
        K = max(4, int(N * frac))
        row = {}
        for p in POLS:
            if p == "dynamicadaptiveclimb":
                res = engine.replay(p, trace, K, observe=True)
                row[p] = res.miss_ratio
                avg_k = float(np.asarray(res.obs["k"]).mean())
                row["dac_avg_k"] = avg_k
                pareto.append((avg_k / N, row[p]))
            else:
                row[p] = engine.replay(p, trace, K).miss_ratio
        rows[frac] = row
    if not quiet:
        print(fmt_row(["K/N"] + POLS + ["dac_avg_k/N"],
                      [8] + [22] * len(POLS) + [12]))
        for frac, row in rows.items():
            print(fmt_row(
                [f"{frac:.1%}"] + [f"{row[p]:.3f}" for p in POLS]
                + [f"{row['dac_avg_k']/N:.1%}"],
                [8] + [22] * len(POLS) + [12]))
        print("DAC pareto (avg_k/N, miss):",
              [(f"{k:.1%}", f"{m:.3f}") for k, m in pareto])
    return save("curve_cachesize", {
        "N": N, "T": T, "alpha": alpha,
        "rows": {str(k): v for k, v in rows.items()},
        "dac_pareto": pareto})


if __name__ == "__main__":
    run()
