"""Beyond-paper: the paper's policy as a bounded KV-cache manager.

Measures next-token agreement with the exact (unbounded) cache and the KV
memory held, as the DAC slot budget shrinks — the serving-quality analogue
of the paper's miss-ratio tables.  The cell grid is a declarative
:class:`repro.bench.ServeScenario` (arch + decode shape + budget
fractions), the seed axis produces canonical per-seed metric lists, and
the output is the same schema-validated payload as every trace sweep.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import ServeScenario, report, results
from repro.configs import SMOKE_ARCHS
from repro.models import init_params
from repro.serving import decode_step, prefill


def _decode(cfg, params, toks, gen, budget, force=None):
    """Teacher-forced when `force` is given: feeds the reference
    continuation so per-step agreement is measured on identical context
    (no error compounding)."""
    B, S = toks.shape
    state, logits = prefill(params, cfg, tokens=toks, max_len=S + gen,
                            budget=budget)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    for i in range(gen):
        feed = tok if force is None else jnp.asarray(force[i])
        state, logits = step(params, state, feed)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    kv = sum(np.asarray(st[k]).nbytes for st in state["layers"].values()
             if isinstance(st, dict) for k in ("k", "v") if k in st)
    return np.stack(out), kv


def _cell(cfg, params, toks, sc, budget, ref, ref_kv):
    out, kv = _decode(cfg, params, toks, sc.gen, budget=budget,
                      force=ref[:-1])
    return {"agreement": float((out == ref).mean()),
            "kv_bytes": float(kv), "kv_frac": kv / ref_kv}


def run(arch: str = "deepseek-7b", gen: int = 32, seeds=(0,),
        quiet: bool = False):
    t_start = time.perf_counter()
    sc = ServeScenario("kv_bounded", arch=arch, batch=2, prompt=96,
                       gen=gen)
    cfg = SMOKE_ARCHS[sc.arch]
    # one metric-list accumulator per budget cell, per-seed aligned
    cells = {B: [] for B in sc.budgets()}
    for seed in seeds:
        params = init_params(cfg, jax.random.PRNGKey(seed))
        rng = np.random.default_rng(seed)
        toks = jnp.asarray(
            rng.integers(0, 64, (sc.batch, sc.prompt)).astype(np.int32))
        ref, ref_kv = _decode(cfg, params, toks, sc.gen, budget=0)
        for B in sc.budgets():
            cells[B].append(_cell(cfg, params, toks, sc, B, ref, ref_kv))
    records = []
    for frac, B in zip(sc.budget_frac, sc.budgets()):
        metrics = {name: [c[name] for c in cells[B]]
                   for name in ("agreement", "kv_bytes", "kv_frac")}
        records.append({"policy": "dac", "scenario": sc.name,
                        "K": B, "K_label": sc.budget_label(frac),
                        "T": sc.total, "seeds": list(seeds),
                        "metrics": metrics})
    if not quiet:
        print(report.fmt_row(["budget", "agreement", "kv_frac"],
                             [14, 12, 10]))
        for rec in records:
            m = rec["metrics"]
            print(report.fmt_row(
                [f"{rec['K']} ({rec['K_label']})",
                 f"{np.mean(m['agreement']):.1%}",
                 f"{np.mean(m['kv_frac']):.2f}"], [14, 12, 10]))
    payload = results.build_payload(
        "kv_bounded",
        config={"scenario": sc.to_config(), "seeds": list(seeds)},
        records=records,
        wall_s=time.perf_counter() - t_start)
    results.save(payload)
    return payload


if __name__ == "__main__":
    run()
