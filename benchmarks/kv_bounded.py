"""Beyond-paper: the paper's policy as a bounded KV-cache manager.

Measures next-token agreement with the exact (unbounded) cache and the KV
memory held, as the DAC slot budget shrinks — the serving-quality analogue
of the paper's miss-ratio tables.  Not a trace replay, so it bypasses the
sweep runner, but the output is the same canonical schema-validated
payload (one record per budget).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import report, results
from repro.configs import SMOKE_ARCHS
from repro.models import init_params
from repro.serving import decode_step, prefill


def _decode(cfg, params, toks, gen, budget, force=None):
    """Teacher-forced when `force` is given: feeds the reference
    continuation so per-step agreement is measured on identical context
    (no error compounding)."""
    B, S = toks.shape
    state, logits = prefill(params, cfg, tokens=toks, max_len=S + gen,
                            budget=budget)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    for i in range(gen):
        feed = tok if force is None else jnp.asarray(force[i])
        state, logits = step(params, state, feed)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    kv = sum(np.asarray(st[k]).nbytes for st in state["layers"].values()
             if isinstance(st, dict) for k in ("k", "v") if k in st)
    return np.stack(out), kv


def run(arch: str = "deepseek-7b", gen: int = 32, quiet: bool = False):
    t_start = time.perf_counter()
    cfg = SMOKE_ARCHS[arch]
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 2, 96
    toks = jnp.asarray(rng.integers(0, 64, (B, S)).astype(np.int32))
    total = S + gen
    ref, ref_kv = _decode(cfg, params, toks, gen, budget=0)
    rows = {}
    records = []
    for budget in (total, total * 3 // 4, total // 2, total // 4):
        out, kv = _decode(cfg, params, toks, gen, budget=budget,
                          force=ref[:-1])
        rows[budget] = {"agreement": float((out == ref).mean()),
                        "kv_bytes": kv, "kv_frac": kv / ref_kv}
        records.append({"scenario": arch, "K": budget,
                        "metrics": dict(rows[budget])})
    if not quiet:
        print(report.fmt_row(["budget", "agreement", "kv_frac"],
                             [10, 12, 10]))
        for b, r in rows.items():
            print(report.fmt_row([b, f"{r['agreement']:.1%}",
                                  f"{r['kv_frac']:.2f}"], [10, 12, 10]))
    payload = results.build_payload(
        "kv_bounded",
        config={"arch": arch, "gen": gen, "prompt": S},
        records=records,
        extras={"rows": {str(k): v for k, v in rows.items()}},
        wall_s=time.perf_counter() - t_start)
    results.save(payload)
    return payload


if __name__ == "__main__":
    run()
