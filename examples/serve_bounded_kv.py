"""The paper's technique in its serving role: decode with a
DynamicAdaptiveClimb-managed bounded KV pool and compare against the
unbounded cache.

Shows (a) bounded-vs-unbounded next-token agreement as the budget shrinks,
(b) the DAC controller's per-layer active-budget adaptation, (c) memory
held vs the unbounded cache.

  PYTHONPATH=src python examples/serve_bounded_kv.py --gen 48
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import decode_step, prefill


def run(cfg, params, tokens, gen, budget):
    B, S = tokens.shape
    state, logits = prefill(params, cfg, tokens=tokens,
                            max_len=S + gen, budget=budget)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out, ks = [np.asarray(tok)], []
    for _ in range(gen):
        state, logits = step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
        if budget:
            ks.append([np.asarray(st["ctrl"]["k_active"]).mean()
                       for st in state["layers"].values()
                       if isinstance(st, dict) and "ctrl" in st])
    kv_bytes = sum(np.asarray(st[k]).nbytes
                   for st in state["layers"].values()
                   if isinstance(st, dict)
                   for k in ("k", "v", "latent", "krope") if k in st)
    return np.stack(out), (np.asarray(ks) if ks else None), kv_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    rng = np.random.default_rng(args.seed)
    # a prompt with strong recency structure (DAC's favourable regime)
    toks = rng.integers(0, 48, (args.batch, args.prompt_len)).astype(np.int32)
    toks = jnp.asarray(toks)

    ref, _, ref_bytes = run(cfg, params, toks, args.gen, budget=0)
    total = args.prompt_len + args.gen
    print(f"[bounded-kv] unbounded cache: {ref_bytes/1e6:.2f} MB "
          f"({total} slots/layer)")
    for budget in (total, total // 2, total // 4):
        out, ks, nbytes = run(cfg, params, toks, args.gen, budget=budget)
        agree = float((out == ref).mean())
        kmsg = (f" k_active(end)={ks[-1][0]:.0f}" if ks is not None
                and len(ks) else "")
        print(f"  budget={budget:4d} slots: next-token agreement "
              f"{agree:5.1%}  kv={nbytes/1e6:.2f} MB{kmsg}")
    print("  (exactness at budget >= context; graceful degradation below —\n"
          "   the DAC policy keeps top-attended entries as budget shrinks)")


if __name__ == "__main__":
    main()
