"""Fleet-arbitrated bounded-KV decode: B sequences share one global HBM
slot budget smaller than ``B x Bmax``, priced per step by the auction
arbiter.

Each sequence runs the paper's DAC-managed bounded KV pool
(``repro.serving.kv_cache``); on top, a fleet loop plays capacity
market-maker every decoded token:

  1. read each sequence's controller — active budget (max over layers),
     growth pressure (``clip(jump, 0) / 2k``, EWMA-smoothed into the
     auction's utility signal), and whether any layer demands a doubling;
  2. ask :class:`repro.tier.AuctionArbiter` for per-sequence caps against
     the *global* budget G;
  3. decode one token with ``decode_step(..., kv_caps=caps)`` — a layer's
     doubling only lands if the grown size stays within its sequence's
     granted cap.

Mid-decode one lane is restarted (a departed tenant's lane handed to a
fresh session): its controllers re-initialize at the admission share and
every physical slot provably returns to the free pool.  The invariant
printed at the end is the fleet conservation law — ``sum_b max_layer
k_active <= G`` at every step, through growth, shrink and the restart —
plus next-token agreement vs the same sequences decoded un-arbitrated.

  PYTHONPATH=src python examples/fleet_decode.py --gen 48
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import init_params
from repro.serving import decode_step, prefill
from repro.serving import kv_cache as kvc
from repro.tier import AuctionArbiter


def _ctrl_layers(state):
    """The period-stacked layer states that carry a DAC controller."""
    return [st for st in state["layers"].values()
            if isinstance(st, dict) and "ctrl" in st]


def fleet_signals(state):
    """Per-sequence (k, demanding, pressure) off the stacked controllers:
    k = max over layers of the active budget (the HBM driver), demanding
    = any layer's jump saturated at 2k, pressure in [0, 1]."""
    ks, dem, press = [], [], []
    for st in _ctrl_layers(state):
        c = st["ctrl"]                      # leaves [P, B, ...]
        k = c["k_active"].astype(jnp.int32)
        ks.append(k.max(axis=0))
        dem.append(jnp.any(c["jump"] >= 2 * k, axis=0))
        press.append(jnp.mean(
            jnp.clip(c["jump"], 0, None) / (2.0 * k), axis=0))
    return (jnp.stack(ks).max(axis=0),
            jnp.stack(dem).any(axis=0),
            jnp.stack(press).mean(axis=0))


def restart_lane(state, b: int, budget: int, k0: int):
    """Hand lane ``b`` to a fresh session: every layer's controller row
    re-initializes at the admission share ``k0`` — all of the lane's
    physical slots return to the free pool (the KV payload becomes
    unreachable; ``valid_slots`` masks it out)."""
    fresh = kvc.control_init(1, budget, k0=k0)
    layers = dict(state["layers"])
    for name, st in layers.items():
        if not (isinstance(st, dict) and "ctrl" in st):
            continue
        ctrl = {key: leaf.at[:, b].set(fresh[key][0])
                for key, leaf in st["ctrl"].items()}
        layers[name] = dict(st, ctrl=ctrl)
    return dict(state, layers=layers)


def run(cfg, params, tokens, gen, budget, G=None, restart_at=None,
        decay=0.9):
    """Teacher-free greedy decode; with ``G`` the auction arbiter caps
    per-sequence growth against the global budget.  Returns (tokens,
    per-step ``sum_b k`` trace, restart free-pool check)."""
    B, S = tokens.shape
    k0 = None if G is None else max(16, G // B)
    state, logits = prefill(params, cfg, tokens=tokens, max_len=S + gen,
                            budget=budget, k0=k0)
    arbiter = AuctionArbiter()
    step = jax.jit(lambda p, s, t, c: decode_step(p, cfg, s, token=t,
                                                  kv_caps=c))
    step_free = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    util = jnp.zeros((B,), jnp.float32)
    out, ksum_trace, restart_ok = [np.asarray(tok)], [], None
    for t in range(gen):
        if G is None:
            state, logits = step_free(params, state, tok)
        else:
            if t == restart_at:
                # admission: the freed lane re-enters at its share only if
                # the pool still covers it (other lanes may hold grants);
                # sum_others <= G - k_min always, so at least the floor fits
                k_pre, _, _ = fleet_signals(state)
                headroom = G - int(np.asarray(k_pre.sum())
                                   - np.asarray(k_pre[0]))
                admit = max(16, min(k0, headroom))
                state = restart_lane(state, 0, budget, admit)
                c0 = _ctrl_layers(state)[0]["ctrl"]
                restart_ok = (bool(np.asarray(c0["free"][:, 0]).all())
                              and int(np.asarray(c0["length"][:, 0]).max())
                              == 0)
            k, demanding, pressure = fleet_signals(state)
            util = decay * util + (1.0 - decay) * pressure
            caps = arbiter(k, demanding, G, B, utility=util)
            state, logits = step(params, state, tok, caps)
            k_now, _, _ = fleet_signals(state)
            ksum_trace.append(int(np.asarray(k_now.sum())))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    return np.stack(out), ksum_trace, restart_ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--bmax", type=int, default=128,
                    help="per-sequence slot pool (per layer)")
    ap.add_argument("--global-budget", type=int, default=256,
                    help="fleet HBM budget G (< batch * bmax)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    toks = jnp.asarray(rng.integers(0, 48, (args.batch, args.prompt_len))
                       .astype(np.int32))

    B, G = args.batch, args.global_budget
    assert G < B * args.bmax, "G must undercut the per-sequence pools"
    ref, _, _ = run(cfg, params, toks, args.gen, budget=args.bmax)
    got, ksum, restart_ok = run(cfg, params, toks, args.gen,
                                budget=args.bmax, G=G,
                                restart_at=args.gen // 2)
    agree = float((got[:, 1:] == ref[:, 1:]).mean())   # lane 0 restarted
    print(f"[fleet-decode] {B} sequences x Bmax={args.bmax} slots/layer, "
          f"global budget G={G} (= {G / (B * args.bmax):.0%} of the "
          f"un-arbitrated pools)")
    print(f"  conservation: max_t sum_b k_active = {max(ksum)} <= {G}  "
          f"({'OK' if max(ksum) <= G else 'VIOLATED'})")
    print(f"  lane-0 restart at t={args.gen // 2}: slots returned to the "
          f"free pool: {'OK' if restart_ok else 'FAILED'}")
    print(f"  next-token agreement vs un-arbitrated bounded decode: "
          f"{agree:5.1%}")
    if max(ksum) > G or not restart_ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
