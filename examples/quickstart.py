"""Quickstart: the paper's policies on synthetic traces.

Replays Zipf / shifting-Zipf traces through AdaptiveClimb,
DynamicAdaptiveClimb and the strongest baselines via the unified
``Engine.replay`` entrypoint, printing miss-ratio reduction vs FIFO (the
paper's headline metric) and DAC's cache-size trajectory under working-set
shifts.  Policies come from ``make_policy`` spec strings — no hand
construction.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Engine, mrr
from repro.data.traces import shifting_zipf_trace, zipf_trace


def main():
    engine = Engine()
    K = 64
    T = 60_000
    traces = {
        "zipf(a=1.0)": zipf_trace(N=2048, T=T, alpha=1.0, seed=0),
        "shifting-zipf": shifting_zipf_trace(N=2048, T=T, alpha=1.1,
                                             phases=6, seed=0),
    }
    contenders = ["fifo", "lru", "climb", "sieve", "arc",
                  "adaptiveclimb", "dynamicadaptiveclimb"]

    for tname, trace in traces.items():
        mr_fifo = engine.replay("fifo", trace, K).miss_ratio
        print(f"\n=== {tname}  (K={K}, T={T}, fifo miss={mr_fifo:.3f}) ===")
        for name in contenders:
            mr = engine.replay(name, trace, K).miss_ratio
            print(f"  {name:22s} miss={mr:.3f}  MRR={mrr(mr, mr_fifo):+.3f}")

    # DAC resizing trajectory under a working-set expansion
    print("\n=== DynamicAdaptiveClimb cache-size trajectory ===")
    small = zipf_trace(N=64, T=20_000, alpha=1.2, seed=1)      # fits easily
    big = zipf_trace(N=8192, T=20_000, alpha=0.4, seed=2)      # thrashes
    trace = np.concatenate([small, big, small])
    res = engine.replay("dac(growth=8)", trace, K, observe=True)
    hits = np.asarray(res.info.hit)
    ks = np.asarray(res.obs["k"])
    for t in range(0, len(trace), 6000):
        seg = slice(max(0, t - 3000), t + 3000)
        print(f"  t={t:6d}  k_active={ks[t]:5d}  "
              f"hit_rate~{hits[seg].mean():.2f}")
    print(f"  (cache grew to {ks.max()} under thrash, "
          f"returned to {ks[-1]} on the stable tail)")


if __name__ == "__main__":
    main()
