"""End-to-end training driver: train a small LM for a few hundred steps on
CPU with checkpointing/auto-resume, then kill-and-resume to demonstrate
fault tolerance.

Default is a ~25M-param llama-style config (CPU-friendly); ``--arch`` +
``--full`` selects any registered architecture (e.g. the full xlstm-125m,
~130M params — the assignment's "~100M model" — budget a few hours on CPU).

  PYTHONPATH=src python examples/train_small.py --steps 300
"""
import argparse
import shutil

from repro.models import ArchConfig, LayerSpec
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer

SMALL = ArchConfig(
    name="llama-25m", family="dense", n_layers=4, d_model=384, n_heads=6,
    n_kv_heads=2, d_ff=1024, vocab=8192, period=(LayerSpec("attn"),),
    tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--arch", default="")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--simulate-crash", action="store_true",
                    help="stop at 60%% of steps, then auto-resume")
    args = ap.parse_args()

    if args.arch:
        from repro.configs import get_arch
        cfg = get_arch(args.arch, smoke=not args.full)
    else:
        cfg = SMALL
    if args.fresh:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    opt = AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                      total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(args.steps // 6, 10),
                       global_batch=args.batch, seq_len=args.seq,
                       n_microbatches=2)

    if args.simulate_crash:
        Trainer(cfg, opt, tcfg).run(steps=int(args.steps * 0.6))
        print("[train_small] --- simulated crash; restarting ---")

    trainer = Trainer(cfg, opt, tcfg)
    trainer.run()
    hist = trainer.history
    print(f"[train_small] {cfg.name}: steps {hist[0]['step']}.."
          f"{hist[-1]['step']}  loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}")
    assert hist[-1]["loss"] < hist[0]["loss"] + 1e-6


if __name__ == "__main__":
    main()
