"""Fleet-scale trace study: the paper's 1067-trace evaluation pattern as a
single declarative Sweep — thousands of independent caches replayed in
parallel vmapped lanes, one jitted replay per (policy, dataset) cell.

On this CPU container it runs on 1 device; on a pod the same sweep spreads
the seed axis over the data mesh axis (``run_sweep(..., mesh=...)`` — the
TPU-native version of the paper's multi-threaded libCacheSim replay,
Tables IV/V).

  PYTHONPATH=src python examples/trace_study.py --n-traces 64
"""
import argparse

import jax
import numpy as np

from repro.bench import Scenario, Sweep, report, run_sweep
from repro.core import mrr
from repro.data.traces import DATASET_FAMILIES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-traces", type=int, default=64)
    ap.add_argument("--T", type=int, default=20_000)
    ap.add_argument("--K", type=int, default=128)
    ap.add_argument("--policies", default="fifo,lru,sieve,adaptiveclimb,"
                    "dynamicadaptiveclimb")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route rank policies through the fused kernel")
    args = ap.parse_args()

    names = args.policies.split(",")
    mesh = (jax.make_mesh((jax.device_count(),), ("data",))
            if jax.device_count() > 1 else None)

    sweep = Sweep(
        "trace_study",
        policies=tuple(names),
        scenarios=tuple(Scenario(ds, trace=ds, T=args.T, K=(args.K,))
                        for ds in DATASET_FAMILIES),
        seeds=tuple(7000 + i for i in range(args.n_traces)),
    )
    print(f"[trace_study] {len(sweep.scenarios)} dataset families x "
          f"{len(sweep.seeds)} traces x {len(names)} policies "
          f"(T={args.T}, K={args.K}, devices={jax.device_count()})")

    res = run_sweep(sweep, mesh=mesh,
                    use_pallas=args.use_pallas or None)
    for sc in sweep.scenarios:
        means = {n: float(np.mean(report.seed_values(
            res.records, "miss_ratio", policy=n, scenario=sc.name)))
            for n in names}
        # baseline: fifo when swept, else the worst policy in the row
        base = means.get("fifo", max(means.values()))
        wall = sum(r["wall_s"] for r in res.records
                   if r["scenario"] == sc.name)
        reqs = len(names) * len(sweep.seeds) * args.T
        pretty = "  ".join(f"{n}={mrr(v, base):+.3f}"
                           for n, v in means.items() if n != "fifo")
        print(f"  {sc.name:10s} base_miss={base:.3f}  MRR: {pretty}   "
              f"[{reqs/wall/1e6:.2f} Mreq/s]")


if __name__ == "__main__":
    main()
