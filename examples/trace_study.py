"""Fleet-scale trace study: the paper's 1067-trace evaluation pattern as a
single SPMD program — thousands of independent caches replayed in parallel
lanes (vmap) across the device mesh.

On this CPU container it runs on 1 device; on a pod the same
``Engine.replay(..., mesh=...)`` call spreads the trace batch over the data
axis (the TPU-native version of the paper's multi-threaded libCacheSim
replay, Tables IV/V).

  PYTHONPATH=src python examples/trace_study.py --n-traces 64
"""
import argparse
import time

import jax
import numpy as np

from repro.core import Engine, mrr
from repro.data.traces import DATASET_FAMILIES, dataset_family


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-traces", type=int, default=64)
    ap.add_argument("--T", type=int, default=20_000)
    ap.add_argument("--K", type=int, default=128)
    ap.add_argument("--policies", default="fifo,lru,sieve,adaptiveclimb,"
                    "dynamicadaptiveclimb")
    args = ap.parse_args()

    names = args.policies.split(",")
    datasets = list(DATASET_FAMILIES)
    mesh = (jax.make_mesh((jax.device_count(),), ("data",))
            if jax.device_count() > 1 else None)
    engine = Engine(mesh=mesh)

    print(f"[trace_study] {len(datasets)} dataset families x "
          f"{args.n_traces} traces x {len(names)} policies "
          f"(T={args.T}, K={args.K}, devices={jax.device_count()})")
    for ds in datasets:
        traces = dataset_family(ds, T=args.T, n_traces=args.n_traces, seed=7)
        row = {}
        t0 = time.perf_counter()
        for name in names:
            res = engine.replay(name, np.asarray(traces), args.K)
            row[name] = float(np.mean(res.miss_ratio))
        dt = time.perf_counter() - t0
        reqs = len(names) * traces.size
        base = row.get("fifo", max(row.values()))
        pretty = "  ".join(f"{n}={mrr(v, base):+.3f}" for n, v in row.items()
                           if n != "fifo")
        print(f"  {ds:10s} fifo_miss={base:.3f}  MRR: {pretty}   "
              f"[{reqs/dt/1e6:.2f} Mreq/s]")


if __name__ == "__main__":
    main()
