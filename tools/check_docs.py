#!/usr/bin/env python
"""Docs checker: every internal link and code anchor in docs/ + README
must resolve.  No Sphinx — three plain rules over the markdown sources:

1. markdown links ``[text](target)`` with relative targets -> the file
   must exist (``#fragment``-only links and http(s) URLs are skipped);
2. inline code spans that look like repo paths (``src/...``, ``docs/...``,
   ``tools/...``, ``tests/...``, ``benchmarks/...``) -> the file must
   exist;
3. inline code spans that look like dotted code anchors (``repro.x.y`` or
   ``repro.x.y.Symbol.attr``) -> the module must import and the symbol
   chain must resolve via getattr.

Exit code 0 iff everything resolves; each failure prints one
``file: problem`` line.  Run from the repo root (CI does), or pass the
root as argv[1].
"""
from __future__ import annotations

import importlib
import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
PATH_RE = re.compile(r"^(src|docs|tools|tests|benchmarks|examples)/[\w/-]+(\.\w+)?$")
ANCHOR_RE = re.compile(r"^repro(\.\w+)+$")
FENCE_RE = re.compile(r"^```.*?^```", re.M | re.S)


def _prose(md: pathlib.Path) -> str:
    """Markdown source with fenced code blocks stripped — anchors are a
    prose convention; example code inside fences is illustrative."""
    return FENCE_RE.sub("", md.read_text())


def check_file(md: pathlib.Path, root: pathlib.Path) -> list[str]:
    errors = []
    text = _prose(md)
    # links are scanned with inline code spans blanked out — backticked
    # code like `arr[i](x)` is not a markdown link
    for target in LINK_RE.findall(CODE_RE.sub("", text)):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        path = (md.parent / target.split("#", 1)[0]).resolve()
        if not path.exists():
            errors.append(f"{md}: broken link -> {target}")
    for span in CODE_RE.findall(text):
        span = span.strip()
        if PATH_RE.match(span):
            if not (root / span).exists():
                errors.append(f"{md}: missing path anchor -> {span}")
        elif ANCHOR_RE.match(span):
            err = _check_import(span)
            if err:
                errors.append(f"{md}: {err}")
    return errors


def _check_import(anchor: str) -> str | None:
    """Import the longest importable module prefix of ``anchor``, then
    getattr the rest of the chain.  Returns an error string or None."""
    parts = anchor.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
            break
        except ImportError:
            continue
    else:
        return f"unimportable anchor -> {anchor}"
    for attr in parts[cut:]:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"unresolvable anchor -> {anchor} (no attribute {attr!r})"
    return None


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    sys.path.insert(0, str(root / "src"))
    sources = sorted((root / "docs").glob("**/*.md")) + [root / "README.md"]
    errors = []
    n_anchors = 0
    for md in sources:
        if not md.exists():
            errors.append(f"{md}: missing")
            continue
        n_anchors += len([s for s in CODE_RE.findall(_prose(md))
                          if PATH_RE.match(s.strip())
                          or ANCHOR_RE.match(s.strip())])
        errors.extend(check_file(md, root))
    for err in errors:
        print(err, file=sys.stderr)
    print(f"checked {len(sources)} docs, {n_anchors} code anchors: "
          f"{'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
