#!/usr/bin/env python
"""Committed-benchmark checker: every ``BENCH_*.json`` artifact lives
under ``experiments/bench/`` and loads as a schema-valid result payload.

PR 7 committed ``experiments/bench/BENCH_policy_step.json`` while PR 8
dropped ``BENCH_fleet.json`` at the repo root; this tool pins the layout
so the committed artifacts can't drift apart again.  Three rules:

1. no ``BENCH_*.json`` anywhere outside ``experiments/bench/``
   (git-tracked or not — a stray artifact in the working tree is a
   refresh that forgot the path);
2. every ``experiments/bench/BENCH_*.json`` loads through
   ``repro.bench.results.load`` (envelope + schema validation);
3. at least one artifact exists — an empty directory means the checker
   is checking nothing.

Exit code 0 iff all rules hold; each failure prints one line.  Run from
the repo root (CI does), or pass the root as argv[1].
"""
from __future__ import annotations

import pathlib
import sys

BENCH_DIR = "experiments/bench"
# trees that legitimately contain json or are not ours to police
SKIP_PARTS = {".git", "__pycache__", ".pytest_cache", ".hypothesis",
              "node_modules"}


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    sys.path.insert(0, str(root / "src"))
    from repro.bench import results

    bench_dir = root / BENCH_DIR
    errors = []

    strays = [p for p in root.glob("**/BENCH_*.json")
              if not SKIP_PARTS.intersection(p.parts)
              and p.parent != bench_dir]
    for p in strays:
        errors.append(f"{p.relative_to(root)}: committed benchmark "
                      f"artifacts belong under {BENCH_DIR}/")

    artifacts = sorted(bench_dir.glob("BENCH_*.json"))
    if not artifacts:
        errors.append(f"{BENCH_DIR}: no BENCH_*.json artifacts found")
    for p in artifacts:
        try:
            payload = results.load(str(p))
        except Exception as e:  # noqa: BLE001 — report, don't crash
            errors.append(f"{p.relative_to(root)}: failed to load/"
                          f"validate: {e}")
            continue
        print(f"{p.relative_to(root)}: schema {payload['schema']} OK "
              f"({len(payload['records'])} records)")

    for err in errors:
        print(err, file=sys.stderr)
    print(f"checked {len(artifacts)} artifacts, {len(strays)} strays: "
          f"{'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
