#!/usr/bin/env python
"""Repo-wide static-analysis gate: AST lint + jaxpr contracts + retrace
audit.

Runs the three layers of ``repro.analysis`` and exits nonzero on any
unwaived finding, so CI (the ``static-analysis`` job) and pre-commit
runs share one verdict:

1. **lint** — repo-specific AST rules over ``src/``, ``benchmarks/``,
   ``tools/`` (wallclock, unseeded RNG, schema literals, inline ``-1``
   sentinels, non-atomic JSON writes, traced-value branching).  Waivers
   are per-line ``# repolint: waive[rule] -- reason`` comments and are
   themselves audited: a stale waiver is a finding.
2. **contracts** — every registry policy (and ``admit(...)`` wrapper,
   and the tier/fleet budgeted paths) abstractly traced under both
   Pallas settings: scan-carry law, lane-padded int32 rows,
   ``ADAPT_KEYS``, no 64-bit widening, no host-callback primitives.
3. **retrace** — the nine canonical engine program shapes compile to
   exactly nine programs, and equivalence variants never recompile.

Usage::

    python tools/repolint.py                 # the full gate
    python tools/repolint.py --lint-only     # AST pass only (fast)
    python tools/repolint.py --contracts-only
    python tools/repolint.py --no-retrace    # skip the compile audit
"""
from __future__ import annotations

import argparse
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--lint-only", action="store_true",
                    help="run only the AST lint pass (no jax import)")
    ap.add_argument("--contracts-only", action="store_true",
                    help="run only the jaxpr contract + retrace passes")
    ap.add_argument("--no-retrace", action="store_true",
                    help="skip the compilation-cache audit")
    ap.add_argument("--root", default=ROOT,
                    help="repository root to lint (default: this repo)")
    args = ap.parse_args(argv)
    if args.lint_only and args.contracts_only:
        ap.error("--lint-only and --contracts-only are mutually exclusive")

    findings = []
    t0 = time.perf_counter()

    if not args.contracts_only:
        from repro.analysis import lint
        lint_findings = lint.lint_tree(args.root)
        findings += lint_findings
        print(f"[repolint] lint: {len(lint_findings)} finding(s)")

    if not args.lint_only:
        from repro.analysis import contracts, retrace
        contract_findings = contracts.verify_contracts()
        findings += contract_findings
        n_targets = (len(contracts.registry_specs()) + 4)  # + budgeted/
        print(f"[repolint] contracts: {len(contract_findings)} finding(s) "
              f"over {n_targets} targets x 2 pallas modes (+ x64 pass)")
        if not args.no_retrace:
            retrace_findings, report = retrace.audit_engine()
            findings += retrace_findings
            print(f"[repolint] retrace: {len(retrace_findings)} finding(s),"
                  f" compiled programs {report}")

    for f in findings:
        print(f"  {f}")
    status = "clean" if not findings else f"{len(findings)} finding(s)"
    print(f"[repolint] {status} in {time.perf_counter() - t0:.1f}s")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
