#!/usr/bin/env python
"""Regenerate the committed miniature real-trace corpus.

The corpus under ``benchmarks/corpus/`` exercises every ingest format
(oracleGeneral binary, the same bytes gzipped, CSV-with-costs gzipped,
key-per-line text) end to end: ``tools/make_corpus.py`` -> ``repro.data.ingest``
-> the ``file(path=...)`` trace family -> ``run_sweep``'s streaming path
(``benchmarks/real_traces.py``).  Everything is deterministic — fixed
seeds, gzip ``mtime=0`` — so CI regenerates the corpus and ``git diff``s
it against the committed files.

Sizes are small integers (< 256 bytes) and costs are dyadic rationals:
their float32 running sums stay exact at corpus scale, which is what
lets the streaming/materialized parity tests assert *bit-identical*
records rather than tolerances.

Usage::

    PYTHONPATH=src python tools/make_corpus.py [--out benchmarks/corpus] [--T 5000]
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.data import ingest  # noqa: E402
from repro.data.traces import (churn_trace, scan_mix_trace,  # noqa: E402
                               zipf_trace)

DEFAULT_OUT = os.path.join("benchmarks", "corpus")


def _sizes(keys: np.ndarray, seed: int) -> np.ndarray:
    """Per-object sizes in [1, 256): a deterministic table indexed by key,
    so every request for an object carries the same size (as in a real
    trace) and float32 byte totals stay exact at corpus scale."""
    n = int(keys.max()) + 1
    table = np.random.default_rng(seed).integers(1, 256, n)
    return table[keys]


def build(out_dir: str = DEFAULT_OUT, T: int = 5000) -> list[str]:
    """Write the four corpus files; returns their paths."""
    os.makedirs(out_dir, exist_ok=True)
    paths = []

    def emit(name, writer, *args, **kw):
        path = os.path.join(out_dir, name)
        writer(path, *args, **kw)
        paths.append(path)
        return path

    # churn workload with sizes — the oracleGeneral pair (plain + gzip
    # share content: the gzip reader must see identical requests)
    mix = churn_trace(N=600, T=T, alpha=1.1, mean_phase=T // 5, drift=0.2,
                      seed=7)
    mix_sizes = _sizes(mix, seed=70)
    emit("mix.oracleGeneral.bin", ingest.write_oracle_general, mix,
         mix_sizes)
    emit("mix.oracleGeneral.bin.gz", ingest.write_oracle_general, mix,
         mix_sizes)

    # skewed KV workload with sizes *and* costs — CSV, gzipped.  Costs are
    # dyadic (size/64 + 1): exact in float32 and in decimal text.
    kv = zipf_trace(N=800, T=T, alpha=1.2, seed=11)
    kv_sizes = _sizes(kv, seed=110)
    kv_costs = (kv_sizes / 64 + 1).astype(np.float32)
    emit("kv.csv.gz", ingest.write_csv, kv, kv_sizes, kv_costs)

    # scan-heavy workload, keys only — plain text, unit sizes downstream
    scan = scan_mix_trace(N=500, T=T, alpha=0.9, scan_frac=0.3,
                          scan_len=64, seed=13)
    emit("scan.keys.txt", ingest.write_keys, scan)
    return paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output directory (default {DEFAULT_OUT})")
    ap.add_argument("--T", type=int, default=5000,
                    help="requests per trace (default 5000)")
    args = ap.parse_args(argv)
    for path in build(args.out, args.T):
        st = ingest.characterize(path)
        print(f"{path}: {st.n_requests} reqs, {st.n_objects} objects, "
              f"{st.footprint_bytes} B footprint, skew~{st.skew:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
