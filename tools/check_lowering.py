#!/usr/bin/env python
"""Compiled-configuration lowering smoke (runs anywhere, TPU not needed).

CPU cannot *execute* compiled Pallas, but it can run the full Mosaic pass
pipeline via cross-platform export: ``jax.export.export(jit(f),
platforms=["tpu"])`` fails loudly on any kernel Mosaic would reject.  This
script exports every program shape the engine actually runs with
``use_pallas="compiled"``:

  * single-lane scanned replay, per rank policy (Climb / AdaptiveClimb /
    DynamicAdaptiveClimb);
  * vmapped [B, T] batched replay (the custom_vmap lane-grid kernel);
  * the multi-tenant tier step, [T, N] and seed-vmapped [S, T, N] (the
    nested-vmap path through the standard pallas batching rule).

CI runs this in the ``kernels-compiled`` job so a kernel edit that breaks
the real lowering cannot land behind a green interpret-only suite.

Usage: PYTHONPATH=src python tools/check_lowering.py
"""
from __future__ import annotations

import sys

import jax
import jax.export
import jax.numpy as jnp

from repro.core import Engine, Request, make_policy
from repro.core.policy import pallas_mode
from repro.tier import CacheTier, replay_tier

RANK_SPECS = ("climb", "adaptiveclimb", "dynamicadaptiveclimb")
T, B, S, N = 16, 3, 2, 3


def _export(label: str, fn, *avals) -> bool:
    try:
        exp = jax.export.export(jax.jit(fn), platforms=["tpu"])(*avals)
        assert "tpu" in [p.lower() for p in exp.platforms]
        print(f"  OK  {label}")
        return True
    except Exception as e:  # noqa: BLE001 - report every failure mode
        print(f"FAIL  {label}: {type(e).__name__}: {e}")
        return False


def check_policy(spec: str) -> bool:
    pol = make_policy(spec)
    K = 300                                  # W = 384: multi-tile with a
                                             # forced 128-lane tile

    def scanned(keys):
        with pallas_mode("compiled"):
            def body(st, key):
                st, info = pol.step(st, Request.of(key))
                return st, info.hit
            return jax.lax.scan(body, pol.init(K), keys)[1]

    def batched(keys):
        with pallas_mode("compiled"):
            def one(lane):
                def body(st, key):
                    st, info = pol.step(st, Request.of(key))
                    return st, info.hit
                return jax.lax.scan(body, pol.init(K), lane)[1]
            return jax.vmap(one)(keys)

    ok = _export(f"{spec}: scan [T]", scanned,
                 jax.ShapeDtypeStruct((T,), jnp.int32))
    ok &= _export(f"{spec}: vmap+scan [B, T]", batched,
                  jax.ShapeDtypeStruct((B, T), jnp.int32))
    return ok


def check_tier() -> bool:
    tier = CacheTier(n_tenants=N, budget=96, k0=16)

    def tier_flat(keys):
        return replay_tier(tier, keys, use_pallas="compiled").metrics.hits

    def tier_seeded(keys):
        return replay_tier(tier, keys, use_pallas="compiled").metrics.hits

    ok = _export("tier: [T, N]", tier_flat,
                 jax.ShapeDtypeStruct((T, N), jnp.int32))
    ok &= _export("tier: seed-vmapped [S, T, N]", tier_seeded,
                  jax.ShapeDtypeStruct((S, T, N), jnp.int32))
    return ok


def check_engine() -> bool:
    eng = Engine()

    def replay(keys):
        return eng.replay("dynamicadaptiveclimb", keys, 300,
                          collect_info=False,
                          use_pallas="compiled").metrics.hits

    return _export("engine: replay [B, T] compiled", replay,
                   jax.ShapeDtypeStruct((B, T), jnp.int32))


def main() -> int:
    print("Mosaic lowering smoke (cross-platform TPU export):")
    ok = all([*(check_policy(s) for s in RANK_SPECS),
              check_tier(), check_engine()])
    print("all lowerings OK" if ok else "LOWERING FAILURES", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
