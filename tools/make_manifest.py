#!/usr/bin/env python
"""Scan a trace directory and emit a campaign manifest.

Walks ``CORPUS`` one level deep for trace files in any
``repro.data.ingest`` format (oracleGeneral binary / CSV / key-per-line,
gzip-transparent), groups them into datasets (subdirectory name; trace
format for flat files; ``--dataset`` forces one group), characterizes
each trace (request/object counts, byte footprint, skew — frozen into
the manifest), and writes a pinned ``repro.campaign.manifest/v1`` JSON
ready for ``python -m benchmarks.campaign``.

Usage::

    PYTHONPATH=src python tools/make_manifest.py benchmarks/corpus \\
        --out campaign.json --policies fifo lru dac --K S L --seeds 0
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.campaign import Grid, scan_corpus  # noqa: E402

DEFAULT_POLICIES = ("fifo", "lru", "arc", "adaptiveclimb",
                    "dynamicadaptiveclimb")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("corpus", help="directory of trace files to scan")
    ap.add_argument("--out", default=None,
                    help="manifest path (default: <corpus>/campaign.json)")
    ap.add_argument("--name", default=None,
                    help="campaign name (default: the corpus dir name)")
    ap.add_argument("--policies", nargs="+", default=list(DEFAULT_POLICIES),
                    metavar="SPEC", help="make_policy spec strings")
    ap.add_argument("--K", nargs="+", default=["S", "L"], metavar="K",
                    help="capacities: ints and/or S/L regime letters")
    ap.add_argument("--seeds", nargs="+", type=int, default=[0])
    ap.add_argument("--T", type=int, default=None,
                    help="cap requests per trace (default: full trace)")
    ap.add_argument("--dataset", default=None,
                    help="force all traces into one named dataset")
    ap.add_argument("--no-stats", action="store_true",
                    help="skip per-trace characterization (faster scan)")
    args = ap.parse_args(argv)

    K = tuple(k if k in ("S", "L") else int(k) for k in args.K)
    grid = Grid(policies=tuple(args.policies), K=K,
                seeds=tuple(args.seeds), T=args.T)
    manifest = scan_corpus(args.corpus, name=args.name, grid=grid,
                           dataset=args.dataset,
                           characterize=not args.no_stats)
    n_traces = sum(len(d.traces) for d in manifest.datasets)
    out = args.out or os.path.join(args.corpus, "campaign.json")
    # a relative manifest root re-anchors at the manifest file's directory
    # on load, so record the corpus relative to where the manifest lands —
    # the pair stays relocatable together
    root = os.path.relpath(os.path.abspath(args.corpus),
                           os.path.dirname(os.path.abspath(out)))
    manifest = dataclasses.replace(manifest, root=root)
    manifest.save(out)
    cells = (n_traces * len(grid.policies) * len(grid.K)
             * len(grid.seeds))
    print(f"{out}: {len(manifest.datasets)} dataset(s), "
          f"{n_traces} trace(s), {cells} grid cells")
    for ds in manifest.datasets:
        reqs = (sum(s["n_requests"] for s in ds.stats.values())
                if ds.stats else "?")
        print(f"  {ds.name}: {len(ds.traces)} trace(s), {reqs} requests")
    return 0


if __name__ == "__main__":
    sys.exit(main())
