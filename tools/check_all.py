#!/usr/bin/env python
"""One command that reproduces the full CI gate locally.

Chains the repo's checkers in the order CI runs them and reports one
pass/fail table::

    python tools/check_all.py            # everything
    python tools/check_all.py --fast     # lint-only repolint (no compiles)
    python tools/check_all.py --skip bench --skip lowering

Each step is a subprocess with ``PYTHONPATH=src`` (and CPU-pinned JAX),
so a locally-importable-but-broken module fails here exactly like it
fails in CI.  Exit status is nonzero if any step fails.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

STEPS = [
    ("repolint", ["tools/repolint.py"]),
    ("docs", ["tools/check_docs.py"]),
    ("bench", ["tools/check_bench.py"]),
    ("lowering", ["tools/check_lowering.py"]),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="repolint runs --lint-only (skip jaxpr/compile "
                         "passes)")
    ap.add_argument("--skip", action="append", default=[],
                    choices=[name for name, _ in STEPS],
                    help="skip a step (repeatable)")
    args = ap.parse_args(argv)

    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")

    outcomes = []
    for name, cmd in STEPS:
        if name in args.skip:
            outcomes.append((name, "SKIP", 0.0))
            continue
        full = [sys.executable] + cmd
        if name == "repolint" and args.fast:
            full.append("--lint-only")
        print(f"\n=== {name}: {' '.join(cmd)} ===", flush=True)
        t0 = time.perf_counter()
        rc = subprocess.run(full, cwd=ROOT, env=env).returncode
        outcomes.append((name, "OK" if rc == 0 else f"FAIL({rc})",
                         time.perf_counter() - t0))

    print("\n" + "=" * 46)
    failed = 0
    for name, status, dt in outcomes:
        print(f"{name:<10} {status:<9} {dt:6.1f}s")
        failed += status.startswith("FAIL")
    print("=" * 46)
    if failed:
        print(f"{failed} step(s) failed")
        return 1
    print("all steps passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
