"""Trace data layer: synthetic generators + the spec-string trace registry
(:mod:`repro.data.traces`) and real-trace file ingestion
(:mod:`repro.data.ingest`)."""
from . import ingest
from .ingest import (DenseRemap, Trace, TraceChunk, TraceStats, characterize,
                     count_requests, detect_format, iter_chunks, load_trace,
                     write_csv, write_keys, write_oracle_general)
from .traces import (DATASET_FAMILIES, TIER_FAMILIES, TRACE_ALIASES, TRACES,
                     TraceSpec, churn_trace, dataset_family, fetch_costs,
                     file_trace, make_trace, object_sizes, scan_mix_trace,
                     shifting_zipf_trace, tenants_trace, zipf_trace)
