from .traces import (DATASET_FAMILIES, dataset_family, fetch_costs,
                     object_sizes, scan_mix_trace, shifting_zipf_trace,
                     zipf_trace, churn_trace)
