"""Trace data layer: synthetic generators + the spec-string trace registry
(see :mod:`repro.data.traces`)."""
from .traces import (DATASET_FAMILIES, TIER_FAMILIES, TRACE_ALIASES, TRACES,
                     TraceSpec, churn_trace, dataset_family, fetch_costs,
                     make_trace, object_sizes, scan_mix_trace,
                     shifting_zipf_trace, tenants_trace, zipf_trace)
