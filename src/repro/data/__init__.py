from .traces import (DATASET_FAMILIES, dataset_family, object_sizes,
                     scan_mix_trace, shifting_zipf_trace, zipf_trace,
                     churn_trace)
