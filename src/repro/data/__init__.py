from .traces import (DATASET_FAMILIES, TRACE_ALIASES, TRACES, TraceSpec,
                     churn_trace, dataset_family, fetch_costs, make_trace,
                     object_sizes, scan_mix_trace, shifting_zipf_trace,
                     zipf_trace)
