"""Deterministic, stateless-resumable LM token pipeline.

``batch(step)`` derives every byte from (seed, step, host) counters — no
iterator state, so a restarted / rescheduled / elastically-resized job
regenerates exactly the stream it would have seen (the fault-tolerance tests
rely on this).  Token draws follow a Zipf marginal with a light Markov
repetition structure so losses are non-trivial.

For stub-frontend archs (audio/vlm) the pipeline emits precomputed
embeddings [B, S, d] (the assignment's modality frontend stub) plus labels.
"""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab: int, global_batch: int, seq_len: int,
                 seed: int = 0, embed_dim: int = 0, repeat_p: float = 0.3):
        self.vocab = vocab
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.embed_dim = embed_dim
        self.repeat_p = repeat_p
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        w = ranks ** -1.1
        self._pmf = w / w.sum()

    def _rng(self, step: int):
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        """Per-host slice of the global batch for `step`."""
        B, S = self.global_batch, self.seq_len
        assert B % n_hosts == 0
        rng = self._rng(step)
        toks = rng.choice(self.vocab, size=(B, S + 1), p=self._pmf)
        rep = rng.random((B, S)) < self.repeat_p
        for t in range(1, S + 1):                    # light Markov structure
            toks[:, t] = np.where(rep[:, t - 1], toks[:, t - 1], toks[:, t])
        toks = toks.astype(np.int32)
        lo = host_id * (B // n_hosts)
        hi = lo + B // n_hosts
        out = {"labels": toks[lo:hi, 1:]}
        if self.embed_dim:
            emb = rng.standard_normal(
                (B, S, self.embed_dim)).astype(np.float32) * 0.05
            out["embeds"] = emb[lo:hi]
        else:
            out["tokens"] = toks[lo:hi, :-1]
        return out
