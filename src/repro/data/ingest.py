"""Real-trace ingestion: file readers, dense key remapping, streaming.

The paper's headline evaluation runs over 1067 *real* traces; this module
is the bridge between trace files on disk and the replay engine.  Three
formats are supported, all gzip-transparent (``.gz`` suffix or magic
bytes):

* ``oracle`` — libCacheSim's ``oracleGeneral`` binary: packed
  little-endian 24-byte records
  ``(u32 clock_time, u64 obj_id, u32 obj_size, i64 next_access_vtime)``.
* ``csv`` — textual ``key[,size[,cost]]`` rows.  A first row naming a
  ``key`` column is treated as a header (columns may be reordered;
  ``size``/``cost`` optional; extras ignored); any other first row is
  data, except an all-textual multi-column row — a foreign header —
  which is refused rather than ingested as a request.
* ``txt`` — one key per line.

Raw keys — 64-bit ids for ``oracle``, textual tokens for ``csv``/``txt``
(compared as strings: ``"007"`` and ``"7"`` are distinct objects) — are
densely remapped to ``int32`` ids in **first-appearance order**:
deterministic, order-stable, and identical whether a trace is loaded at
once (:func:`load_trace`) or iterated in chunks of any size
(:func:`iter_chunks`), so streamed and materialized replays see
bit-identical request streams.  Uncompressed ``oracle`` files
are memory-mapped and sliced per chunk — a multi-gigabyte trace never
loads into host memory on the streaming path.

:func:`characterize` computes per-trace stats (request/object counts,
byte footprint, a Zipf skew estimate) in one streaming pass; the trace
registry's ``file(path=...)`` family (:mod:`repro.data.traces`) resolves
its id footprint through it.  Writers for every format round-trip what
the format carries and power ``tools/make_corpus.py`` plus the ingest
test suite.

>>> import os, tempfile
>>> p = os.path.join(tempfile.mkdtemp(), "t.keys.txt")
>>> write_keys(p, [7, 7, 3, 7])
>>> load_trace(p).keys.tolist()          # dense first-appearance ids
[0, 0, 1, 0]
"""
from __future__ import annotations

import csv
import dataclasses
import functools
import gzip
import io
import os
from typing import Iterator, NamedTuple

import numpy as np

__all__ = [
    "FORMATS", "ORACLE_DTYPE", "DEFAULT_CHUNK",
    "DenseRemap", "TraceChunk", "Trace", "TraceStats",
    "detect_format", "iter_chunks", "load_trace", "characterize",
    "count_requests", "write_oracle_general", "write_csv", "write_keys",
]

FORMATS = ("oracle", "csv", "txt")

# libCacheSim oracleGeneral record: packed little-endian, 24 bytes
ORACLE_DTYPE = np.dtype([("time", "<u4"), ("obj", "<u8"),
                         ("size", "<u4"), ("next", "<i8")])
assert ORACLE_DTYPE.itemsize == 24

DEFAULT_CHUNK = 1 << 18

_SUFFIX_TO_FORMAT = {
    ".bin": "oracle", ".oracle": "oracle", ".oraclegeneral": "oracle",
    ".csv": "csv", ".txt": "txt", ".keys": "txt",
}


def detect_format(path) -> str:
    """Infer the trace format from the file suffix (a trailing ``.gz`` is
    stripped first): ``.bin``/``.oracleGeneral`` -> ``oracle``, ``.csv``
    -> ``csv``, ``.txt``/``.keys`` -> ``txt``.

    >>> detect_format("a/mix.oracleGeneral.bin.gz")
    'oracle'
    >>> detect_format("kv.csv")
    'csv'
    """
    name = os.path.basename(str(path)).lower()
    if name.endswith(".gz"):
        name = name[:-3]
    _, suffix = os.path.splitext(name)
    fmt = _SUFFIX_TO_FORMAT.get(suffix)
    if fmt is None:
        raise ValueError(
            f"cannot infer trace format from {path!r} (suffix {suffix!r}); "
            f"pass format= explicitly, one of {list(FORMATS)}")
    return fmt


def _resolve_format(path, format: str) -> str:
    if format == "auto":
        return detect_format(path)
    if format not in FORMATS:
        raise ValueError(
            f"unknown trace format {format!r}; known: {list(FORMATS)} "
            "(or 'auto')")
    return format


def _is_gzip(path) -> bool:
    with open(path, "rb") as f:
        return f.read(2) == b"\x1f\x8b"


def _open_binary(path):
    """Binary stream over ``path``, transparently gunzipping."""
    if _is_gzip(path):
        return gzip.open(path, "rb")
    return open(path, "rb")


def _open_text(path):
    return io.TextIOWrapper(_open_binary(path), encoding="utf-8",
                            newline="")


# ---------------------------------------------------------------------------
# dense key remapping
# ---------------------------------------------------------------------------

class DenseRemap:
    """Raw keys -> dense ``int32`` ids in first-appearance order.

    Deterministic and order-stable: the i-th *distinct* raw key ever seen
    gets id ``i``, so the mapping depends only on the key sequence — the
    same trace remaps identically whether it is consumed whole or in
    chunks of any size.

    >>> remap = DenseRemap()
    >>> remap(np.array([9, 4, 9, 7])).tolist()
    [0, 1, 0, 2]
    >>> remap(np.array([7, 1])).tolist()      # state persists across calls
    [2, 3]
    >>> remap.n_objects
    4
    """

    def __init__(self):
        self._ids: dict = {}

    @property
    def n_objects(self) -> int:
        """Number of distinct raw keys assigned so far."""
        return len(self._ids)

    def __call__(self, raw) -> np.ndarray:
        raw = np.asarray(raw)
        ids = self._ids
        if raw.dtype.kind in "iuU":
            # vectorized: one dict op per *distinct* key in the chunk,
            # visited in first-appearance order (argsort of first index)
            uniq, first, inv = np.unique(raw, return_index=True,
                                         return_inverse=True)
            lut = np.empty(len(uniq), dtype=np.int64)
            for j in np.argsort(first, kind="stable"):
                lut[j] = ids.setdefault(uniq[j].item(), len(ids))
            out = lut[inv]
        else:
            out = np.empty(raw.shape, dtype=np.int64)
            for i, k in enumerate(raw.tolist()):
                out[i] = ids.setdefault(k, len(ids))
        if ids and len(ids) > np.iinfo(np.int32).max:
            raise ValueError("trace exceeds int32 distinct-key budget")
        return out.astype(np.int32)


# ---------------------------------------------------------------------------
# raw per-format readers (chunked; keys NOT yet remapped)
# ---------------------------------------------------------------------------

def _iter_oracle_raw(path, chunk):
    if _is_gzip(path):
        want = chunk * ORACLE_DTYPE.itemsize
        with _open_binary(path) as f:
            while True:
                buf = f.read(want)
                if not buf:
                    return
                # gzip streams may return short reads mid-file
                while len(buf) % ORACLE_DTYPE.itemsize or len(buf) < want:
                    more = f.read(want - len(buf))
                    if not more:
                        break
                    buf += more
                if len(buf) % ORACLE_DTYPE.itemsize:
                    raise ValueError(
                        f"{path}: truncated oracleGeneral stream "
                        f"({len(buf) % ORACLE_DTYPE.itemsize} trailing bytes)")
                rec = np.frombuffer(buf, dtype=ORACLE_DTYPE)
                yield rec["obj"], rec["size"].astype(np.int64), None
        return
    n_bytes = os.path.getsize(path)
    n_rec, trailing = divmod(n_bytes, ORACLE_DTYPE.itemsize)
    if trailing:
        raise ValueError(
            f"{path}: size {n_bytes} is not a multiple of the 24-byte "
            "oracleGeneral record (truncated or wrong format?)")
    if n_rec == 0:
        return
    # memory-mapped: a chunk slice is the only thing that touches RAM
    mm = np.memmap(path, dtype=ORACLE_DTYPE, mode="r", shape=(n_rec,))
    for lo in range(0, n_rec, chunk):
        rec = mm[lo:lo + chunk]
        yield np.asarray(rec["obj"]), rec["size"].astype(np.int64), None


def _iter_csv_raw(path, chunk):
    with _open_text(path) as f:
        reader = csv.reader(f)
        first = next(reader, None)
        if first is None:
            return
        cols = {"key": 0, "size": 1, "cost": 2}
        rows = []

        def numeric(tok):
            try:
                float(tok)
                return True
            except ValueError:
                return False

        names = [tok.strip().lower() for tok in first]
        if "key" in names:
            # header row: named columns, any order, extras ignored
            cols = {name: i for i, name in enumerate(names)
                    if name in ("key", "size", "cost")}
        elif all(not numeric(tok) for tok in first):
            # every column textual but none named 'key': a header from
            # another tool, or an undecidably ambiguous first row —
            # refuse rather than ingest column names as requests (multi-
            # column string *keys* are fine: their size column is
            # numeric, so such data rows don't trip this; single-column
            # string keys belong in the txt format or under a 'key'
            # header)
            raise ValueError(
                f"{path}: first CSV row {names} looks like a header but "
                "has no 'key' column; name one (size/cost optional), use "
                "headerless key[,size[,cost]] rows, or the txt format "
                "for bare string keys")
        else:
            cols = {name: i for name, i in cols.items() if i < len(first)}
            rows.append(first)

        def flush(rows):
            keys = np.asarray([r[cols["key"]].strip() for r in rows])
            sizes = costs = None
            if "size" in cols:
                # int(float(...)): tolerate float-formatted byte counts
                # ("1024.0") from pandas-style exporters
                sizes = np.asarray(
                    [int(float(r[cols["size"]])) for r in rows],
                    dtype=np.int64)
            if "cost" in cols:
                costs = np.asarray([float(r[cols["cost"]]) for r in rows],
                                   dtype=np.float32)
            return keys, sizes, costs

        for row in reader:
            if not row:
                continue
            rows.append(row)
            if len(rows) >= chunk:
                yield flush(rows)
                rows = []
        if rows:
            yield flush(rows)


def _iter_txt_raw(path, chunk):
    with _open_text(path) as f:
        toks = []
        for line in f:
            line = line.strip()
            if not line:
                continue
            toks.append(line)
            if len(toks) >= chunk:
                yield np.asarray(toks), None, None
                toks = []
        if toks:
            yield np.asarray(toks), None, None


_RAW_READERS = {"oracle": _iter_oracle_raw, "csv": _iter_csv_raw,
                "txt": _iter_txt_raw}


# ---------------------------------------------------------------------------
# public loading surface
# ---------------------------------------------------------------------------

class TraceChunk(NamedTuple):
    """One streamed slice of a trace: dense int32 ``keys`` plus the
    per-request ``sizes`` (int64 bytes) / ``costs`` (float32) the file
    carries — ``None`` where the format has no such column (the engine's
    unit default applies)."""

    keys: np.ndarray
    sizes: np.ndarray | None
    costs: np.ndarray | None


class Trace(NamedTuple):
    """A fully-loaded trace (see :func:`load_trace`): the same fields as
    :class:`TraceChunk` for the whole request sequence, plus the dense id
    footprint ``n_objects`` (keys lie in ``[0, n_objects)``)."""

    keys: np.ndarray
    sizes: np.ndarray | None
    costs: np.ndarray | None
    n_objects: int


def iter_chunks(path, format: str = "auto", *, chunk: int = DEFAULT_CHUNK,
                limit: int = 0) -> Iterator[TraceChunk]:
    """Stream a trace file as :class:`TraceChunk` slices of ``chunk``
    requests (the last one shorter), keys densely remapped on the fly —
    bit-identical to :func:`load_trace` of the same file.  ``limit > 0``
    stops after that many requests.  Uncompressed ``oracle`` files are
    memory-mapped; nothing larger than one chunk is ever resident.

    >>> import os, tempfile
    >>> p = os.path.join(tempfile.mkdtemp(), "t.keys.txt")
    >>> write_keys(p, [5, 2, 5, 9])
    >>> [c.keys.tolist() for c in iter_chunks(p, chunk=3)]
    [[0, 1, 0], [2]]
    """
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    fmt = _resolve_format(path, format)
    remap = DenseRemap()
    seen = 0
    for raw, sizes, costs in _RAW_READERS[fmt](path, chunk):
        if limit > 0 and seen + len(raw) > limit:
            take = limit - seen
            raw = raw[:take]
            sizes = None if sizes is None else sizes[:take]
            costs = None if costs is None else costs[:take]
        if len(raw) == 0:
            break
        yield TraceChunk(keys=remap(raw), sizes=sizes, costs=costs)
        seen += len(raw)
        if limit > 0 and seen >= limit:
            return


def _cache_key(path):
    """Cache identity of a trace file: realpath + mtime_ns + size.  Size
    is part of the key so a same-second rewrite (mtime unchanged at
    coarse resolution) still invalidates — ``tools/make_manifest.py``
    freezes these stats into manifests and must never see stale ones.
    The resolved format is a separate ``lru_cache`` argument."""
    st = os.stat(path)
    return os.path.realpath(path), st.st_mtime_ns, st.st_size


@functools.lru_cache(maxsize=64)
def _count_requests(cache_key, format: str) -> int:
    path = cache_key[0]
    if format == "oracle" and not _is_gzip(path):
        n_rec, trailing = divmod(os.path.getsize(path),
                                 ORACLE_DTYPE.itemsize)
        if trailing:
            raise ValueError(
                f"{path}: size is not a multiple of the 24-byte "
                "oracleGeneral record (truncated or wrong format?)")
        return int(n_rec)
    return sum(len(raw)
               for raw, _, _ in _RAW_READERS[format](path, DEFAULT_CHUNK))


def count_requests(path, format: str = "auto") -> int:
    """Number of requests in a trace file — O(1) for uncompressed
    ``oracle`` files (size / 24, no decode), a parse-only pass (no remap,
    no popularity stats) otherwise; cached by path + mtime + size +
    format (see :func:`_cache_key`).  This is the
    cheap length check ``repro.bench.Scenario`` validates ``T`` against.

    >>> import os, tempfile
    >>> p = os.path.join(tempfile.mkdtemp(), "t.oracleGeneral.bin")
    >>> write_oracle_general(p, [1, 2, 1])
    >>> count_requests(p)
    3
    """
    return _count_requests(_cache_key(path), _resolve_format(path, format))


@functools.lru_cache(maxsize=4)
def _load_full(cache_key, format: str, limit: int = 0) -> Trace:
    path = cache_key[0]
    keys, sizes, costs = [], [], []
    for ch in iter_chunks(path, format, limit=limit):
        keys.append(ch.keys)
        sizes.append(ch.sizes)
        costs.append(ch.costs)

    def seal(arr):
        # cached arrays are shared across callers: hand out read-only
        # views so an in-place edit fails loudly instead of corrupting
        # every later replay of the same file
        if arr is not None:
            arr.setflags(write=False)
        return arr

    if not keys:
        return Trace(seal(np.empty(0, np.int32)), None, None, 0)
    cat = lambda parts: (None if parts[0] is None
                         else np.concatenate(parts))
    all_keys = np.concatenate(keys)
    n_objects = int(all_keys.max()) + 1 if len(all_keys) else 0
    return Trace(keys=seal(all_keys), sizes=seal(cat(sizes)),
                 costs=seal(cat(costs)), n_objects=n_objects)


def load_trace(path, format: str = "auto", *, limit: int = 0) -> Trace:
    """Load a trace into memory as a :class:`Trace` (the materialized
    counterpart of :func:`iter_chunks`; loads are cached by
    path + mtime + size + format + limit, see :func:`_cache_key`).
    ``limit > 0`` reads only the first ``limit``
    requests — a bounded prefix scan, never a full-file pass, and the
    dense remap of a truncated load matches the full load's prefix.

    >>> import os, tempfile
    >>> p = os.path.join(tempfile.mkdtemp(), "t.csv")
    >>> write_csv(p, [8, 8, 2], sizes=[10, 10, 30])
    >>> tr = load_trace(p)
    >>> tr.keys.tolist(), tr.sizes.tolist(), tr.n_objects
    ([0, 0, 1], [10, 10, 30], 2)
    """
    fmt = _resolve_format(path, format)
    return _load_full(_cache_key(path), fmt, max(0, limit))


# ---------------------------------------------------------------------------
# characterization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceStats:
    """One streaming pass worth of per-trace characterization.

    ``footprint_bytes`` sums each object's first-seen size (the working
    set's storage demand); ``total_bytes`` sums request sizes (traffic
    volume); formats without sizes count unit objects for both, matching
    the engine's unit-size default.  ``skew`` is a least-squares Zipf
    exponent estimate over the log rank-frequency curve (0 means
    uniform)."""

    path: str
    format: str
    n_requests: int
    n_objects: int
    total_bytes: int
    footprint_bytes: int
    skew: float

    @property
    def unique_frac(self) -> float:
        """Distinct keys per request — 1.0 is a pure scan."""
        return self.n_objects / self.n_requests if self.n_requests else 0.0


def _fit_skew(counts: np.ndarray) -> float:
    counts = np.sort(counts[counts > 0])[::-1].astype(np.float64)
    if len(counts) < 2 or counts[0] == counts[-1]:
        return 0.0
    ranks = np.arange(1, len(counts) + 1, dtype=np.float64)
    slope = np.polyfit(np.log(ranks), np.log(counts), 1)[0]
    return float(max(0.0, -slope))


@functools.lru_cache(maxsize=16)
def _characterize(cache_key, format: str) -> TraceStats:
    path = cache_key[0]
    counts = np.zeros(0, dtype=np.int64)
    first_size = np.zeros(0, dtype=np.int64)
    seen = np.zeros(0, dtype=bool)
    n_requests = 0
    total_bytes = 0
    for ch in iter_chunks(path, format):
        hi = int(ch.keys.max()) + 1
        if hi > len(counts):
            grow = max(hi, 2 * len(counts))
            pad = lambda a, fill: np.concatenate(
                [a, np.full(grow - len(a), fill, a.dtype)])
            counts = pad(counts, 0)
            first_size = pad(first_size, 0)
            seen = pad(seen, False)
        np.add.at(counts, ch.keys, 1)
        sizes = (np.ones(len(ch.keys), np.int64) if ch.sizes is None
                 else ch.sizes)
        total_bytes += int(sizes.sum())
        # first-seen size per object: np.unique's return_index is the
        # first in-chunk occurrence of each distinct id
        uniq, first = np.unique(ch.keys, return_index=True)
        new = ~seen[uniq]
        first_size[uniq[new]] = sizes[first[new]]
        seen[uniq[new]] = True
        n_requests += len(ch.keys)
    n_objects = int(seen.sum())
    return TraceStats(
        path=str(path), format=format, n_requests=n_requests,
        n_objects=n_objects, total_bytes=total_bytes,
        footprint_bytes=int(first_size.sum()), skew=_fit_skew(counts))


def characterize(path, format: str = "auto") -> TraceStats:
    """Compute (and cache, by path + mtime + size + format — see
    :func:`_cache_key`) a trace's :class:`TraceStats` in one streaming
    pass.

    >>> import os, tempfile
    >>> p = os.path.join(tempfile.mkdtemp(), "t.csv")
    >>> write_csv(p, [1, 1, 1, 2], sizes=[100, 100, 100, 50])
    >>> st = characterize(p)
    >>> st.n_requests, st.n_objects, st.total_bytes, st.footprint_bytes
    (4, 2, 350, 150)
    """
    return _characterize(_cache_key(path), _resolve_format(path, format))


# ---------------------------------------------------------------------------
# writers (corpus generation + round-trip tests)
# ---------------------------------------------------------------------------

def _open_write(path):
    """Binary sink; ``.gz`` paths gzip with ``mtime=0`` so regenerated
    corpora are byte-identical (CI diffs them against the committed
    files)."""
    if str(path).endswith(".gz"):
        return gzip.GzipFile(path, "wb", mtime=0)
    return open(path, "wb")


def _next_access(keys: np.ndarray) -> np.ndarray:
    """oracleGeneral's ``next_access_vtime``: for each position, the index
    of the key's next occurrence, or -1 (libCacheSim's 'never again')."""
    nxt = np.full(len(keys), -1, dtype=np.int64)
    last: dict = {}
    for i in range(len(keys) - 1, -1, -1):
        k = keys[i].item()
        nxt[i] = last.get(k, -1)
        last[k] = i
    return nxt


def write_oracle_general(path, keys, sizes=None, *, times=None) -> None:
    """Write an ``oracleGeneral`` binary trace (gzip if ``path`` ends in
    ``.gz``); ``next_access_vtime`` is computed from the key sequence.

    >>> import os, tempfile
    >>> p = os.path.join(tempfile.mkdtemp(), "t.oracleGeneral.bin")
    >>> write_oracle_general(p, [11, 5, 11], sizes=[64, 32, 64])
    >>> tr = load_trace(p)
    >>> tr.keys.tolist(), tr.sizes.tolist()
    ([0, 1, 0], [64, 32, 64])
    """
    keys = np.asarray(keys)
    rec = np.empty(len(keys), dtype=ORACLE_DTYPE)
    rec["time"] = (np.arange(len(keys), dtype=np.uint32) if times is None
                   else np.asarray(times, dtype=np.uint32))
    rec["obj"] = keys.astype(np.uint64)
    rec["size"] = (np.ones(len(keys), np.uint32) if sizes is None
                   else np.asarray(sizes, dtype=np.uint32))
    rec["next"] = _next_access(keys)
    with _open_write(path) as f:
        f.write(rec.tobytes())


def write_csv(path, keys, sizes=None, costs=None, *, header=True) -> None:
    """Write a ``key[,size[,cost]]`` CSV trace (gzip-aware); ``header``
    emits the column-name row the reader understands.

    >>> import os, tempfile
    >>> p = os.path.join(tempfile.mkdtemp(), "t.csv.gz")
    >>> write_csv(p, [3, 9], sizes=[2, 4], costs=[0.5, 1.25])
    >>> load_trace(p).costs.tolist()
    [0.5, 1.25]
    """
    if costs is not None and sizes is None:
        raise ValueError("csv column order is key,size,cost — costs "
                         "require sizes")
    cols = ["key"] + (["size"] if sizes is not None else []) \
        + (["cost"] if costs is not None else [])
    keys = np.asarray(keys)
    lines = []
    if header:
        lines.append(",".join(cols))
    for i in range(len(keys)):
        row = [str(keys[i].item() if keys.dtype.kind in "iu" else keys[i])]
        if sizes is not None:
            row.append(str(int(sizes[i])))
        if costs is not None:
            row.append(repr(float(costs[i])))
        lines.append(",".join(row))
    with _open_write(path) as f:
        f.write(("\n".join(lines) + "\n").encode("utf-8"))


def write_keys(path, keys) -> None:
    """Write a key-per-line text trace (gzip-aware).

    >>> import os, tempfile
    >>> p = os.path.join(tempfile.mkdtemp(), "t.keys.txt.gz")
    >>> write_keys(p, [4, 4, 1])
    >>> load_trace(p).keys.tolist()
    [0, 0, 1]
    """
    keys = np.asarray(keys)
    text = "\n".join(str(k.item() if keys.dtype.kind in "iu" else k)
                     for k in keys) + "\n"
    with _open_write(path) as f:
        f.write(text.encode("utf-8"))
