"""Synthetic trace generators + the trace registry.

The paper's six public datasets cannot be redistributed or fetched offline;
each generator below produces a family of traces matched to the published
qualitative characteristics of one dataset (skew, working-set churn, scan
fraction, object-size distribution).  Every generator is deterministic in
its seed.  Keys are int32 >= 0.

Traces are addressed by spec strings, mirroring ``repro.core.make_policy``::

    spec = make_trace("zipf(N=8192,alpha=0.9)")     # -> TraceSpec
    spec = make_trace("alibaba")                    # dataset-family alias
    keys = spec.generate(T=200_000, seed=0)         # [T] int32
    batch = spec.generate_batch(T=200_000, seeds=range(8))   # [8, T]

``str(spec)`` round-trips to the canonical spec string, so experiment
configs and result JSONs carry traces as data, not code.
"""
from __future__ import annotations

import dataclasses
import inspect

import numpy as np

from ..specs import build_kwargs, coerce_value, format_spec, parse_spec
from . import ingest

__all__ = [
    "zipf_trace", "shifting_zipf_trace", "scan_mix_trace", "churn_trace",
    "tenants_trace", "fleet_trace", "file_trace", "flood_trace",
    "scanstorm_trace", "diurnal_trace", "thrash_trace", "dataset_family",
    "DATASET_FAMILIES", "object_sizes", "bimodal_sizes", "fetch_costs",
    "TraceSpec", "make_trace", "TRACES", "TRACE_ALIASES", "TIER_FAMILIES",
    "FLEET_FAMILIES", "COLD_RANGE_FAMILIES",
]


def _zipf_pmf(N: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, N + 1, dtype=np.float64)
    w = ranks ** -alpha
    return w / w.sum()


def zipf_trace(N: int, T: int, alpha: float, seed: int = 0) -> np.ndarray:
    """IID Zipf(alpha) requests over N objects.

    >>> keys = zipf_trace(N=64, T=100, alpha=1.0, seed=0)
    >>> keys.shape, keys.dtype.name, bool((keys < 64).all())
    ((100,), 'int32', True)
    >>> bool((keys == zipf_trace(N=64, T=100, alpha=1.0, seed=0)).all())
    True
    """
    rng = np.random.default_rng(seed)
    pmf = _zipf_pmf(N, alpha)
    return rng.choice(N, size=T, p=pmf).astype(np.int32)


def shifting_zipf_trace(N: int, T: int, alpha: float, phases: int,
                        seed: int = 0) -> np.ndarray:
    """Zipf requests whose item->rank mapping is re-permuted each phase.

    Models working-set churn: popular objects change identity abruptly.
    This is the regime where the paper claims DynamicAdaptiveClimb shines
    ("fluctuating working set sizes").

    >>> shifting_zipf_trace(N=64, T=50, alpha=0.9, phases=2).shape
    (50,)
    """
    rng = np.random.default_rng(seed)
    pmf = _zipf_pmf(N, alpha)
    out = np.empty(T, dtype=np.int32)
    bounds = np.linspace(0, T, phases + 1).astype(int)
    for ph in range(phases):
        perm = rng.permutation(N).astype(np.int32)
        draws = rng.choice(N, size=bounds[ph + 1] - bounds[ph], p=pmf)
        out[bounds[ph]:bounds[ph + 1]] = perm[draws]
    return out


def scan_mix_trace(N: int, T: int, alpha: float, scan_frac: float,
                   scan_len: int, seed: int = 0) -> np.ndarray:
    """Zipf traffic interleaved with sequential scans over cold keys.

    Scans are the classic LRU-killer (they flush the cache with
    never-reused objects); CDN / block-storage traces contain many.
    Scan keys live in a disjoint id range [N, 2N): a scan run that would
    pass 2N-1 wraps around *within* the cold range (modulo N on the
    offset), never back into the hot Zipf range [0, N).

    >>> keys = scan_mix_trace(N=64, T=200, alpha=1.0, scan_frac=0.3,
    ...                       scan_len=16)
    >>> bool((keys < 128).all())       # ids span [0, 2N)
    True
    """
    rng = np.random.default_rng(seed)
    out = zipf_trace(N, T, alpha, seed=seed + 1).astype(np.int64)
    n_scans = max(1, int(T * scan_frac / scan_len))
    for s in range(n_scans):
        start = rng.integers(0, max(1, T - scan_len))
        base = rng.integers(0, N)
        length = min(scan_len, T - start)
        out[start:start + length] = N + (base + np.arange(length)) % N
    return out.astype(np.int32)


def _phase_sizes(rng, T, mean_phase):
    sizes = []
    total = 0
    while total < T:
        s = int(rng.exponential(mean_phase)) + mean_phase // 4 + 1
        sizes.append(min(s, T - total))
        total += s
    return sizes


def _churn_phases(N: int, T: int, mean_phase: int, drift: float,
                  hot_frac: float, seed: int):
    """Yield ``(start, stop, perm)`` per churn phase, where ``perm[r]`` is
    the object id occupying popularity rank ``r`` during that phase.

    Each phase swaps ``round(H * drift)`` ids out of the hot ranks
    ``[0, H)`` (``H = max(1, int(N * hot_frac))``) against ids drawn from
    the cold ranks ``[H, N)`` — so the realized hot-set turnover is
    *exactly* ``round(H * drift) / H`` every phase, not a lumpy binomial
    whose typical value is far below ``drift`` for skewed traces (the old
    uniform-over-all-``N`` rotation touched the hot ranks only in
    expectation).  Any positive ``drift`` rotates at least one id, so the
    turnover is floored at ``1/H`` when ``H * drift < 1/2`` rather than
    silently rounding to a drift-free trace.  The per-phase test in
    ``tests/test_traces.py`` measures turnover through this generator."""
    if not 0 < hot_frac < 1:
        raise ValueError(
            f"hot_frac must lie in (0, 1), got {hot_frac} — with no cold "
            "ranks there is nothing to rotate against")
    if not 0 <= drift <= 1:
        raise ValueError(
            f"drift must lie in [0, 1], got {drift} — it is the fraction "
            "of the hot set rotated per phase")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0]))
    perm = rng.permutation(N).astype(np.int32)
    H = max(1, int(N * hot_frac))
    n_rot = max(1, int(round(H * drift))) if drift > 0 else 0
    if n_rot > N - H:
        # clamping would silently deliver less turnover than promised
        raise ValueError(
            f"drift={drift} with hot_frac={hot_frac} needs {n_rot} cold "
            f"ids per phase but only {N - H} exist; shrink hot_frac or "
            "drift")
    pos = 0
    for size in _phase_sizes(rng, T, mean_phase):
        if n_rot > 0:
            hot = rng.choice(H, size=n_rot, replace=False)
            cold = H + rng.choice(N - H, size=n_rot, replace=False)
            swap_in, swap_out = perm[cold].copy(), perm[hot].copy()
            perm[hot], perm[cold] = swap_in, swap_out
        yield pos, pos + size, perm.copy()
        pos += size


def churn_trace(N: int, T: int, alpha: float, mean_phase: int,
                drift: float, seed: int = 0, *,
                hot_frac: float = 0.1) -> np.ndarray:
    """Zipf with gradual popularity drift: each phase, a ``drift`` fraction
    of the hot set — the ids on the top ``hot_frac * N`` popularity ranks —
    is rotated out against previously-cold ids; the rest persists.  Closer
    to production KV churn than full re-permutation.

    The rotation swaps exactly ``round(H * drift)`` hot-ranked ids
    (at least one while ``drift > 0``) with cold-ranked ones per phase
    (``H = hot_frac * N``), so the realized hot-set turnover *is* the
    ``drift`` parameter, deterministically —
    rather than a drift-in-expectation-only shuffle spread uniformly over
    all ``N`` ids, which left the typical phase of a skewed trace with no
    hot turnover at all.

    >>> churn_trace(N=64, T=50, alpha=1.0, mean_phase=20, drift=0.1).shape
    (50,)
    """
    pmf = _zipf_pmf(N, alpha)
    draw = np.random.default_rng(np.random.SeedSequence([seed, 1]))
    out = np.empty(T, dtype=np.int32)
    for start, stop, perm in _churn_phases(N, T, mean_phase, drift,
                                           hot_frac, seed):
        out[start:stop] = perm[draw.choice(N, size=stop - start, p=pmf)]
    return out


def tenants_trace(N: int, T: int, n_tenants: int, alpha: float = 0.9,
                  period: int = 8192, duty: float = 0.25, lo: int = 64,
                  alpha_lo: float = 1.6, seed: int = 0) -> np.ndarray:
    """``[T, n_tenants]`` interleaved multi-tenant streams with
    phase-shifted working-set fluctuation.

    Each tenant alternates between a *wide* phase (working set = all ``N``
    keys — the cache thrashes, DAC's ``jump`` saturates and demands
    capacity) and a *narrow* phase (working set = ``lo`` keys — hits
    concentrate, DAC shrinks and returns capacity).  Tenant ``t``'s phase
    is shifted by ``t * period / n_tenants``, so at any instant roughly
    ``duty * n_tenants`` tenants are wide while the rest are narrow: the
    paper's §5 "fluctuating working set" regime, but *across* tenants —
    total demand stays near-constant while its owner rotates, which is
    exactly the workload where a shared budget beats static partitioning.

    Wide-phase draws are Zipf(``alpha``) over all ``N`` keys (broad, weak
    locality — capacity is what earns hits); narrow-phase draws are
    Zipf(``alpha_lo``) over the ``lo``-key hot set (tight, strong locality
    — a small cache suffices and the concentrated hits are exactly the
    signal DAC's shrink rule keys on).  Both go through a private
    per-tenant key permutation (all tenants address ``[0, N)`` but their
    hot sets differ).  Deterministic in ``seed``.

    >>> tenants_trace(N=64, T=10, n_tenants=4, seed=0).shape
    (10, 4)
    """
    rng = np.random.default_rng(seed)
    out = np.empty((T, n_tenants), np.int32)
    i = np.arange(T)
    wide_len = max(1, int(period * duty))
    for t in range(n_tenants):
        perm = rng.permutation(N).astype(np.int32)
        wide = rng.choice(N, size=T, p=_zipf_pmf(N, alpha))
        narrow = rng.choice(lo, size=T, p=_zipf_pmf(lo, alpha_lo))
        phase = (i + (t * period) // n_tenants) % period
        out[:, t] = perm[np.where(phase < wide_len, wide, narrow)]
    return out


def fleet_trace(N: int, T: int, n_lanes: int, rate: float = 0.005,
                mean_session: int = 2000, alpha: float = 0.9,
                period: int = 2048, duty: float = 0.25, lo: int = 64,
                alpha_lo: float = 1.6, seed: int = 0) -> np.ndarray:
    """``[T, n_lanes]`` dynamic-fleet request streams: tenants *arrive*
    (Poisson, ``rate`` arrivals per global step), serve one ``tenants``-
    style session (exponential length, mean ``mean_session`` steps), and
    *depart* — the entry is ``-1`` wherever a lane has no active tenant.

    This extends :func:`tenants_trace` with the lifecycle the fleet layer
    (:mod:`repro.fleet`) schedules inside its scanned program: a lane's
    key turning ``>= 0`` is an admission event (a fresh tenant takes over
    the lane's cache), turning ``-1`` a departure (the lane's slots fall
    back to the arbiter's free pool).  Each session gets a private hot-set
    permutation and a random phase offset into the same wide/narrow
    working-set fluctuation as ``tenants(...)`` — so concurrent sessions
    demand capacity at different times, the regime where arbitration
    matters.  An arrival is dropped (not queued) when every lane is busy;
    consecutive sessions on one lane are separated by at least one ``-1``
    step, so alive-mask transitions detect *every* arrival and departure.
    Deterministic in ``seed``.

    >>> keys = fleet_trace(N=64, T=400, n_lanes=4, rate=0.05,
    ...                    mean_session=100, seed=0)
    >>> keys.shape, keys.dtype.name
    ((400, 4), 'int32')
    >>> bool((keys == -1).any()), bool(keys.max() < 64)
    (True, True)
    >>> same = fleet_trace(N=64, T=400, n_lanes=4, rate=0.05,
    ...                    mean_session=100, seed=0)
    >>> bool((keys == same).all())
    True
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    out = np.full((T, n_lanes), -1, np.int32)
    pmf_wide = _zipf_pmf(N, alpha)
    pmf_lo = _zipf_pmf(lo, alpha_lo)
    wide_len = max(1, int(period * duty))
    free_at = np.zeros(n_lanes, np.int64)      # step at which a lane frees
    t = float(rng.exponential(1.0 / rate))     # first arrival time
    while t < T:
        at = int(t)
        lanes = np.flatnonzero(free_at <= at)
        if lanes.size:                         # else: dropped (all busy)
            lane = int(lanes[0])
            length = 1 + int(rng.exponential(mean_session))
            stop = min(at + length, T)
            n = stop - at
            perm = rng.permutation(N).astype(np.int32)
            wide = rng.choice(N, size=n, p=pmf_wide)
            narrow = rng.choice(lo, size=n, p=pmf_lo)
            phase = (np.arange(n) + int(rng.integers(0, period))) % period
            out[at:stop, lane] = perm[np.where(phase < wide_len, wide,
                                               narrow)]
            # ">= stop + 1": at least one dead step between sessions so
            # the alive mask transitions on every arrival/departure
            free_at[lane] = stop + 1
        t += float(rng.exponential(1.0 / rate))
    return out


def file_trace(path: str, format: str = "auto", T: int = 0,
               seed: int = 0) -> np.ndarray:
    """Keys of a *real* trace file (``repro.data.ingest`` formats:
    oracleGeneral binary / CSV / key-per-line, gzip-transparent), densely
    remapped to ``[0, n_objects)`` int32 in first-appearance order.

    Real data has no seed axis: ``seed`` is accepted (the registry's
    runtime contract) and ignored.  ``T > 0`` takes the first ``T``
    requests and raises if the file is shorter — a silent wrap-around
    would distort reuse distances; ``T <= 0`` returns the whole trace.
    Per-request sizes/costs carried by the file are exposed through
    :func:`repro.data.ingest.load_trace`, which the bench layer uses for
    file-backed scenarios.
    """
    del seed  # real traces are data, not a distribution to resample
    tr = ingest.load_trace(path, format=format, limit=max(0, T))
    if T > 0 and len(tr.keys) < T:
        raise ValueError(
            f"file trace {path!r} has only {len(tr.keys)} requests, "
            f"T={T} requested (no implicit wrap-around)")
    return tr.keys


# --- hostile (adversarial) families ----------------------------------------
# The robustness grid: each family targets one known failure mode of
# lightweight replacement/admission policies.  Cold/one-hit ids live in the
# disjoint range [N, 2N) (like scan_mix), so a bimodal size model can give
# them correlated (large) sizes by id.

def flood_trace(N: int, T: int, alpha: float, flood_frac: float = 0.3,
                burst_len: int = 64, phases: int = 4,
                seed: int = 0) -> np.ndarray:
    """One-hit-wonder floods: Zipf(``alpha``) base traffic over ``[0, N)``
    interrupted by bursts of *fresh* cold keys from ``[N, 2N)`` that are
    never requested again (until the cold range wraps after ``N`` flood
    requests).

    Each of the ``phases`` equal time phases carries exactly
    ``int(phase_len * flood_frac)`` flood requests, grouped into runs of
    ``burst_len`` consecutive positions on distinct block boundaries — so
    the realized per-phase flood fraction *is* the parameter (the
    property suite measures it).  Fresh ids advance a global counter
    modulo ``N``; keep total flood traffic below ``N`` requests for
    strictly one-hit wonders.  Pair with the ``bimodal(split=N)`` size
    model to make the flood large-object (the admission layer's hardest
    byte-weighted case).

    >>> keys = flood_trace(N=64, T=400, alpha=1.0, flood_frac=0.25,
    ...                    burst_len=10, phases=2)
    >>> keys.shape, bool((keys < 128).all())
    ((400,), True)
    >>> int((keys >= 64).sum())          # 2 phases x int(200 * 0.25)
    100
    """
    if not 0.0 <= flood_frac < 1.0:
        raise ValueError(f"flood_frac must lie in [0, 1), got {flood_frac}")
    if burst_len < 1 or phases < 1:
        raise ValueError("burst_len and phases must be >= 1")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 3]))
    out = zipf_trace(N, T, alpha, seed=seed + 1).astype(np.int64)
    bounds = np.linspace(0, T, phases + 1).astype(int)
    counter = 0
    for ph in range(phases):
        lo, hi = bounds[ph], bounds[ph + 1]
        L = hi - lo
        n_flood = int(L * flood_frac)
        if n_flood == 0:
            continue
        blocks = L // burst_len
        if n_flood > blocks * burst_len:
            raise ValueError(
                f"flood_frac={flood_frac} with burst_len={burst_len} does "
                f"not fit a phase of {L} requests; shrink burst_len or "
                "flood_frac")
        n_bursts = -(-n_flood // burst_len)
        chosen = rng.choice(blocks, size=n_bursts, replace=False)
        remaining = n_flood
        for j in np.sort(chosen):
            start = lo + int(j) * burst_len
            take = min(burst_len, remaining)
            out[start:start + take] = N + (counter + np.arange(take)) % N
            counter += take
            remaining -= take
    return out.astype(np.int32)


def scanstorm_trace(N: int, T: int, alpha: float, mean_phase: int = 2000,
                    drift: float = 0.1, storm_frac: float = 0.25,
                    scan_len: int = 256, seed: int = 0) -> np.ndarray:
    """Sequential scans landing *mid-churn*: a :func:`churn_trace` base
    (popularity drifting every phase) overwritten by scan runs over the
    cold id range ``[N, 2N)`` — the cache must survive the flush while
    the hot set underneath it is already moving.

    >>> keys = scanstorm_trace(N=64, T=300, alpha=1.0, mean_phase=100,
    ...                        drift=0.1, storm_frac=0.25, scan_len=16)
    >>> keys.shape, bool((keys < 128).all()), bool((keys >= 64).any())
    ((300,), True, True)
    """
    if not 0.0 <= storm_frac < 1.0:
        raise ValueError(f"storm_frac must lie in [0, 1), got {storm_frac}")
    if scan_len < 1:
        raise ValueError(f"scan_len must be >= 1, got {scan_len}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, 4]))
    out = churn_trace(N, T, alpha, mean_phase, drift,
                      seed=seed).astype(np.int64)
    n_scans = max(1, int(T * storm_frac / scan_len))
    for _ in range(n_scans):
        start = rng.integers(0, max(1, T - scan_len))
        base = rng.integers(0, N)
        length = min(scan_len, T - start)
        out[start:start + length] = N + (base + np.arange(length)) % N
    return out.astype(np.int32)


def diurnal_trace(N: int, T: int, alpha: float = 0.9, period: int = 4096,
                  duty: float = 0.5, lo: int = 64, alpha_lo: float = 1.6,
                  seed: int = 0) -> np.ndarray:
    """Diurnal load swings on a single cache: the working set alternates
    between *wide* (Zipf(``alpha``) over all ``N`` keys, ``duty`` of each
    ``period``) and *narrow* (Zipf(``alpha_lo``) over a ``lo``-key hot
    set) — the single-tenant version of :func:`tenants_trace`'s
    fluctuating-working-set regime, which is where the paper claims DAC's
    resizing wins and where admission must not pin the cache to the stale
    wide set.

    >>> keys = diurnal_trace(N=64, T=200, period=40, duty=0.5, lo=8)
    >>> keys.shape, bool((keys < 64).all())
    ((200,), True)
    """
    if not 0.0 < duty < 1.0:
        raise ValueError(f"duty must lie in (0, 1), got {duty}")
    if not 1 <= lo <= N:
        raise ValueError(f"lo must lie in [1, N], got {lo}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(N).astype(np.int32)
    wide = rng.choice(N, size=T, p=_zipf_pmf(N, alpha))
    narrow = rng.choice(lo, size=T, p=_zipf_pmf(lo, alpha_lo))
    phase = np.arange(T) % period
    wide_len = max(1, int(period * duty))
    return perm[np.where(phase < wide_len, wide, narrow)].astype(np.int32)


def thrash_trace(N: int, T: int, loop: int, seed: int = 0) -> np.ndarray:
    """The adversarial eviction-order pattern: a strict cyclic sweep over
    ``loop`` distinct keys (a seeded subset of ``[0, N)``).  Every reuse
    distance is exactly ``loop - 1``, so any policy holding ``K < loop``
    slots with LRU-like eviction order misses *every* request — the
    classic sequential-flooding worst case (FIFO/CLOCK/LRU all degrade;
    frequency-free policies cannot recover).

    >>> keys = thrash_trace(N=64, T=12, loop=4, seed=0)
    >>> sorted(set(keys.tolist())) == sorted(set(keys[:4].tolist()))
    True
    >>> bool((keys[:4] == keys[4:8]).all())
    True
    """
    if not 1 <= loop <= N:
        raise ValueError(f"loop must lie in [1, N], got {loop}")
    rng = np.random.default_rng(seed)
    cycle = rng.permutation(N)[:loop].astype(np.int32)
    return cycle[np.arange(T) % loop]


# --- dataset families ------------------------------------------------------
# Parameters chosen to mimic the published character of each dataset:
#   alibaba   block storage, high skew, heavy churn, large footprint
#   tencent   block storage (CBS), large working set, weak temporal locality
#   twitter   in-memory KV, very high skew, strong temporal locality
#   metacdn   CDN, scans + skew mix
#   metakv    KV, skewed with drift
#   wiki      CDN-like, moderate skew, large objects (used for byte-miss)

DATASET_FAMILIES = {
    "alibaba": dict(kind="churn", N=8192, alpha=1.1, mean_phase=20000,
                    drift=0.2),
    "tencent": dict(kind="scan", N=8192, alpha=0.7, scan_frac=0.3,
                    scan_len=2048),
    "twitter": dict(kind="churn", N=8192, alpha=1.3, mean_phase=50000,
                    drift=0.05),
    "metacdn": dict(kind="scan", N=8192, alpha=1.0, scan_frac=0.15,
                    scan_len=1024),
    "metakv": dict(kind="churn", N=8192, alpha=1.05, mean_phase=30000,
                   drift=0.1),
    "wiki": dict(kind="zipfshift", N=8192, alpha=0.9, phases=4),
}


# --- trace registry --------------------------------------------------------
# Mirrors the policy registry: family name -> generator.  Spec params are
# the generator's parameters minus the runtime axes (T, seed), coerced to
# the declared type exactly like make_policy's constructor kwargs.

TRACES = {
    "zipf": zipf_trace,
    "shifting_zipf": shifting_zipf_trace,
    "scan_mix": scan_mix_trace,
    "churn": churn_trace,
    "tenants": tenants_trace,
    "fleet": fleet_trace,
    "file": file_trace,
    "flood": flood_trace,
    "scanstorm": scanstorm_trace,
    "diurnal": diurnal_trace,
    "thrash": thrash_trace,
}

# families whose cold/one-hit ids live in the disjoint range [N, 2N): the
# id footprint is 2N, and a bimodal(split=N) size model makes cold
# traffic large-object by construction
COLD_RANGE_FAMILIES = frozenset({"scan_mix", "flood", "scanstorm"})

# families whose generators emit [T, n_tenants] interleaved tier streams
# (repro.tier.replay_tier input) rather than a single [T] key trace
TIER_FAMILIES = frozenset({"tenants"})

# families whose [T, n_lanes] streams additionally carry -1 "no active
# tenant" entries — repro.fleet.replay_fleet input ONLY (a -1 key fed to
# replay_tier would spuriously hit the EMPTY rank sentinel)
FLEET_FAMILIES = frozenset({"fleet"})

_RUNTIME_PARAMS = ("T", "seed")

# each DATASET_FAMILIES "kind" is one registered family
_KIND_TO_FAMILY = {"churn": "churn", "scan": "scan_mix",
                   "zipfshift": "shifting_zipf"}

# dataset names resolve like policy aliases: to a (family, params) expansion
TRACE_ALIASES = {
    name: (_KIND_TO_FAMILY[cfg["kind"]],
           {k: v for k, v in cfg.items() if k != "kind"})
    for name, cfg in DATASET_FAMILIES.items()
}


def _family_params(family: str) -> dict:
    fn = TRACES[family]
    return {k: p for k, p in inspect.signature(fn).parameters.items()
            if k not in _RUNTIME_PARAMS}


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """A trace family plus its parameters — data, not code.

    ``params`` is stored as a tuple of ``(name, value)`` pairs in the
    generator's signature order, so specs are hashable and ``str(spec)``
    is canonical (parsing it back yields an equal spec).

    >>> spec = make_trace("zipf(N=128,alpha=1.0)")
    >>> str(spec), spec.n_keys, spec.is_tier
    ('zipf(N=128,alpha=1.0)', 128, False)
    >>> spec.generate(T=50, seed=3).shape
    (50,)
    >>> spec.generate_batch(T=50, seeds=(0, 1)).shape
    (2, 50)
    """

    family: str
    params: tuple = ()

    @property
    def kwargs(self) -> dict:
        return dict(self.params)

    @property
    def n_keys(self) -> int:
        """Id-space footprint: keys lie in ``[0, n_keys)``.  Cold-range
        families (:data:`COLD_RANGE_FAMILIES` — scan mixes, floods, scan
        storms) address ``[0, 2N)`` (cold ids live in ``[N, 2N)``); file
        traces resolve their distinct-key count from the file itself
        (``repro.data.ingest.characterize``, cached by path + mtime)."""
        if self.is_file:
            return self.stats().n_objects
        N = self.kwargs["N"]
        return 2 * N if self.family in COLD_RANGE_FAMILIES else N

    @property
    def is_file(self) -> bool:
        """True for file-backed traces (family ``"file"``): real data —
        ``generate`` ignores the seed, and per-request sizes/costs come
        from the file rather than a synthetic size model."""
        return self.family == "file"

    def stats(self) -> "ingest.TraceStats":
        """File-backed traces only: the underlying file's
        :class:`repro.data.ingest.TraceStats` (request/object counts,
        byte footprint, skew estimate)."""
        if not self.is_file:
            raise ValueError(
                f"stats() is for file-backed traces; {self.family!r} is "
                "synthetic — its footprint is the N parameter")
        return ingest.characterize(self.kwargs["path"],
                                   self.kwargs.get("format", "auto"))

    @property
    def n_requests(self) -> int:
        """File-backed traces only: the trace length, via the cheap
        :func:`repro.data.ingest.count_requests` path (O(1) for
        uncompressed oracle files — no full characterization pass)."""
        if not self.is_file:
            raise ValueError(
                f"n_requests is for file-backed traces; {self.family!r} "
                "is synthetic — any T can be generated")
        return ingest.count_requests(self.kwargs["path"],
                                     self.kwargs.get("format", "auto"))

    @property
    def is_tier(self) -> bool:
        """True for multi-tenant families: ``generate`` returns a
        ``[T, n_tenants]`` interleaved stream (``repro.tier`` input), not
        a single ``[T]`` trace.  Fleet families are *not* tier input —
        their ``-1`` inactive-lane entries only make sense to
        ``repro.fleet.replay_fleet`` (see :data:`FLEET_FAMILIES`)."""
        return self.family in TIER_FAMILIES

    @property
    def is_fleet(self) -> bool:
        """True for dynamic-lifecycle families (``fleet(...)``): a
        ``[T, n_lanes]`` stream with ``-1`` marking lanes with no active
        tenant — ``repro.fleet.replay_fleet`` input."""
        return self.family in FLEET_FAMILIES

    @property
    def n_tenants(self) -> int:
        """Tenant/lane-axis width for tier and fleet families; 1 for
        single-cache ones."""
        if self.is_fleet:
            return self.kwargs["n_lanes"]
        return self.kwargs["n_tenants"] if self.is_tier else 1

    def __str__(self) -> str:
        return format_spec(self.family, self.kwargs)

    def generate(self, T: int, seed: int = 0) -> np.ndarray:
        """One ``[T]`` int32 trace, deterministic in ``seed`` (file-backed
        traces are real data — every seed returns the same keys)."""
        return TRACES[self.family](T=T, seed=seed, **self.kwargs)

    def generate_batch(self, T: int, seeds) -> np.ndarray:
        """``[len(seeds), T]`` independent traces — the seed axis the sweep
        runner vmaps over."""
        return np.stack([self.generate(T, seed=int(s)) for s in seeds])


def make_trace(spec) -> TraceSpec:
    """Build a :class:`TraceSpec` from a spec string: a registered family
    (``"zipf(N=8192,alpha=0.9)"``), a dataset alias (``"alibaba"``,
    optionally with parameter overrides), or a real trace file
    (``"file(path=benchmarks/corpus/kv.csv.gz)"``).  Values are coerced
    to the generator parameter's declared type; unknown families, unknown
    parameters, and missing required parameters raise ``ValueError`` —
    the same contract as ``make_policy``.  ``TraceSpec`` instances pass
    through.

    >>> str(make_trace("wiki"))                 # alias expansion
    'shifting_zipf(N=8192,alpha=0.9,phases=4)'
    >>> str(make_trace("wiki(alpha=1.2)"))      # ... with overrides
    'shifting_zipf(N=8192,alpha=1.2,phases=4)'
    >>> make_trace("tenants(N=64,n_tenants=2)").n_tenants
    2
    """
    if isinstance(spec, TraceSpec):
        return spec
    name, argstr = parse_spec(spec)
    base = {}
    if name in TRACE_ALIASES:
        name, base = TRACE_ALIASES[name]
    if name not in TRACES:
        raise ValueError(
            f"unknown trace family {name!r}; known: {sorted(TRACES)} "
            f"(aliases: {sorted(TRACE_ALIASES)})")
    sig = _family_params(name)
    kwargs = {k: coerce_value("trace family", name, sig, k, v)
              for k, v in base.items()}
    kwargs.update(build_kwargs("trace family", name, TRACES[name], argstr,
                               skip=_RUNTIME_PARAMS))
    missing = [k for k, p in sig.items()
               if p.default is inspect.Parameter.empty and k not in kwargs]
    if missing:
        raise ValueError(
            f"trace family {name!r} missing required parameters {missing}; "
            f"accepts: {sorted(sig)}")
    ordered = tuple((k, kwargs[k]) for k in sig if k in kwargs)
    return TraceSpec(family=name, params=ordered)


def dataset_family(name: str, T: int = 200_000, n_traces: int = 3,
                   seed: int = 0) -> np.ndarray:
    """Return [n_traces, T] synthetic traces for one dataset family.

    Back-compat wrapper over the registry: ``make_trace(name)`` plus the
    historical ``seed * 1000 + i`` per-trace seeding.

    >>> dataset_family("wiki", T=100, n_traces=2).shape
    (2, 100)
    """
    if name not in TRACE_ALIASES:
        raise ValueError(
            f"unknown dataset family {name!r}; known: {sorted(TRACE_ALIASES)}")
    spec = make_trace(name)
    return spec.generate_batch(
        T, seeds=[seed * 1000 + i for i in range(n_traces)])


def object_sizes(n_objects: int, seed: int = 0,
                 median_kb: float = 16.0, sigma: float = 1.5) -> np.ndarray:
    """Log-normal object sizes in bytes (wiki-like heavy tail).

    >>> sizes = object_sizes(1000, seed=0)
    >>> sizes.shape, bool((sizes >= 1).all())
    ((1000,), True)
    """
    rng = np.random.default_rng(seed)
    kb = rng.lognormal(mean=np.log(median_kb), sigma=sigma, size=n_objects)
    return np.maximum(1, (kb * 1024).astype(np.int64))


def bimodal_sizes(n_objects: int, seed: int = 0, split: int = 8192,
                  small_kb: float = 4.0, large_kb: float = 64.0,
                  sigma: float = 0.5) -> np.ndarray:
    """Two-population log-normal size table: ids below ``split`` draw
    around ``small_kb``, ids at or above it around ``large_kb``.  With a
    cold-range trace family (``flood``/``scanstorm``/``scan_mix``) and
    ``split=N``, the hostile cold traffic is large-object *by id* — the
    correlated-size regime where byte-weighted metrics punish size-blind
    admission hardest.

    >>> sizes = bimodal_sizes(100, split=50, small_kb=4, large_kb=64,
    ...                       sigma=0.0)
    >>> [round(s / 1024) for s in sizes[[0, 99]]]
    [4, 64]
    """
    rng = np.random.default_rng(seed)
    small = rng.lognormal(np.log(small_kb), sigma, size=n_objects)
    large = rng.lognormal(np.log(large_kb), sigma, size=n_objects)
    kb = np.where(np.arange(n_objects) < split, small, large)
    return np.maximum(1, (kb * 1024).astype(np.int64))


def fetch_costs(sizes_bytes: np.ndarray, base_ms: float = 2.0,
                per_mb_ms: float = 8.0) -> np.ndarray:
    """Miss penalty (ms) for fetching an object from the backing store:
    a fixed round-trip plus a bandwidth term.  Feeds ``Request.cost`` so
    the engine's ``penalty_ratio`` measures latency-weighted misses, not
    just request- or byte-weighted ones.

    >>> float(fetch_costs(np.array([0.0]), base_ms=2.0)[0])
    2.0
    """
    sizes_bytes = np.asarray(sizes_bytes, dtype=np.float64)
    return (base_ms + per_mb_ms * sizes_bytes / 2**20).astype(np.float32)
