"""deepseek-v2-236b [moe] — MLA + 2 shared + 160 routed top-6
(arXiv:2405.04434).

60L d_model=5120 128H d_ff=1536 (expert width) vocab=102400, MLA
kv_lora=512 (q_lora=1536, decoupled RoPE 64, nope 128, v 128).

Paper-technique applicability: bounded-KV DAC manages the (latent, k_rope)
cache — only (512+64) floats/token, so MLA *compounds* with the paper's
eviction (smallest possible per-slot cost).  long_500k runs under the
bounded budget.
"""
from repro.models import ArchConfig, LayerSpec, MoESpec

FULL = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab=102400,
    period=(LayerSpec("mla", moe=True),),
    moe=MoESpec(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
)

SMOKE = ArchConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=256,
    period=(LayerSpec("mla", moe=True),),
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1),
    kv_lora_rank=32,
    q_lora_rank=24,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
)
