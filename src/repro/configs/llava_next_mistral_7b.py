"""llava-next-mistral-7b [vlm] — mistral backbone, anyres tiling
(hf:llava-hf/llava-v1.6-mistral-7b-hf).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  The vision tower +
anyres patch merger is a STUB per the assignment: ``input_specs`` supplies
the merged sequence of precomputed patch+text embeddings [B, S, d].

Paper-technique applicability: full — standard KV cache, bounded-KV DAC on
decode.
"""
from repro.models import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    period=(LayerSpec("attn"),),
    embeds_input=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    period=(LayerSpec("attn"),),
    embeds_input=True,
    rope_theta=1e6,
)
