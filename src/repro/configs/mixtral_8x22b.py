"""mixtral-8x22b [moe] — 8 experts top-2, SWA (arXiv:2401.04088).

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, sliding window 4096
per the assignment.

Paper-technique applicability: bounded-KV DAC applies to every layer's KV
cache; SWA already bounds the window to 4096 — the DAC budget manages the
*retained* set beyond the window on long_500k (DAC budget > window, so the
policy decides which out-of-window entries survive).
"""
from repro.models import ArchConfig, LayerSpec, MoESpec

FULL = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    period=(LayerSpec("attn", window=4096, moe=True),),
    moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=16384),
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    period=(LayerSpec("attn", window=16, moe=True),),
    moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=128),
    rope_theta=1e6,
)
