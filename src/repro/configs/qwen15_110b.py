"""qwen1.5-110b [dense] — QKV bias (hf:Qwen/Qwen1.5 family).

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.

Paper-technique applicability: full — standard KV cache, bounded-KV DAC on
decode; long_500k runs under the bounded budget (full attention would be
quadratic).
"""
from repro.models import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    period=(LayerSpec("attn"),),
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen1.5-110b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    period=(LayerSpec("attn"),),
    qkv_bias=True,
    rope_theta=1e6,
)
