"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

12L d_model=768 4H d_ff=0 vocab=50304.  Blocks alternate mLSTM (matrix
memory, chunkwise-parallel) and sLSTM (scalar memory, sequential); there is
no separate FFN (d_ff=0): each block carries its own projections.

Paper-technique applicability: NONE for the bounded-KV manager — the state
is O(1) per layer already (nothing to evict).  The trace-simulator form of
DynamicAdaptiveClimb (repro.core) is architecture-independent.  long_500k
runs natively (recurrent decode).
"""
from repro.models import ArchConfig, LayerSpec, XLSTMSpec

FULL = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    period=(LayerSpec("mlstm"), LayerSpec("slstm")),
    xlstm=XLSTMSpec(),
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="xlstm-125m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=256,
    period=(LayerSpec("mlstm"), LayerSpec("slstm")),
    xlstm=XLSTMSpec(m_chunk=8),
    tie_embeddings=True,
)
