"""gemma2-27b [dense] — local+global alternating, logit softcap
(arXiv:2408.00118).

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128
(inner 4096 != d_model), attention softcap 50, final softcap 30, GeGLU,
local window 4096, tied embeddings.

Paper-technique applicability: local layers are already bounded by the 4096
window; the bounded-KV DAC manages the *global* layers on long_500k.
"""
from repro.models import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=36864,
    vocab=256000,
    period=(LayerSpec("attn", window=4096), LayerSpec("attn")),
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="gemma2-27b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=128,
    vocab=256,
    period=(LayerSpec("attn", window=16), LayerSpec("attn")),
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
)
