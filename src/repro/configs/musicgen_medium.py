"""musicgen-medium [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284).

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.  The EnCodec
frontend is a STUB per the assignment: ``input_specs`` supplies precomputed
frame embeddings [B, S, d]; the backbone predicts codebook tokens over the
2048-entry vocab.

Paper-technique applicability: full — standard KV cache, bounded-KV DAC on
decode.
"""
from repro.models import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    period=(LayerSpec("attn"),),
    embeds_input=True,
    act="gelu",
)

SMOKE = ArchConfig(
    name="musicgen-medium-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    period=(LayerSpec("attn"),),
    embeds_input=True,
    act="gelu",
)
