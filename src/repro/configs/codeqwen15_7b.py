"""codeqwen1.5-7b [dense] — qwen1.5-arch (hf:Qwen/CodeQwen1.5-7B).

32L d_model=4096 32H (MHA kv=32) d_ff=13440 vocab=92416, QKV bias.

Paper-technique applicability: full — standard KV cache, bounded-KV DAC on
decode.
"""
from repro.models import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    period=(LayerSpec("attn"),),
    qkv_bias=True,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="codeqwen1.5-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    period=(LayerSpec("attn"),),
    qkv_bias=True,
    rope_theta=1e6,
)
