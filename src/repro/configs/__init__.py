"""Architecture registry: the 10 assigned archs (+ smoke variants) and the
input-shape cells."""
from . import (codeqwen15_7b, deepseek_7b, deepseek_v2_236b, gemma2_27b,
               jamba_15_large_398b, llava_next_mistral_7b, mixtral_8x22b,
               musicgen_medium, qwen15_110b, xlstm_125m)
from .shapes import SHAPES, ShapeCell, input_specs

_MODULES = {
    "xlstm-125m": xlstm_125m,
    "deepseek-v2-236b": deepseek_v2_236b,
    "mixtral-8x22b": mixtral_8x22b,
    "musicgen-medium": musicgen_medium,
    "qwen1.5-110b": qwen15_110b,
    "deepseek-7b": deepseek_7b,
    "gemma2-27b": gemma2_27b,
    "codeqwen1.5-7b": codeqwen15_7b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "jamba-1.5-large-398b": jamba_15_large_398b,
}

ARCHS = {name: mod.FULL for name, mod in _MODULES.items()}
SMOKE_ARCHS = {name: mod.SMOKE for name, mod in _MODULES.items()}


def get_arch(name: str, smoke: bool = False):
    table = SMOKE_ARCHS if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(table)}")
    return table[name]


__all__ = ["ARCHS", "SMOKE_ARCHS", "SHAPES", "ShapeCell", "get_arch",
           "input_specs"]
