"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
(arXiv:2403.19887).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Period of 8 layers: one attention layer (index 4, as in the Jamba paper),
seven Mamba layers; the FFN alternates dense / MoE (MoE on odd layer
indices => 4 MoE layers per period).

Paper-technique applicability: the bounded-KV DAC applies to the attention
layers only (1/8 of layers); Mamba layers carry O(1) conv+ssm state.
long_500k decode is O(1) per mamba layer and O(budget) per attention layer.
"""
from repro.models import ArchConfig, LayerSpec, MambaSpec, MoESpec


def _period():
    out = []
    for i in range(8):
        kind = "attn" if i == 4 else "mamba"
        out.append(LayerSpec(kind, moe=(i % 2 == 1)))
    return tuple(out)


FULL = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    period=_period(),
    moe=MoESpec(n_experts=16, top_k=2, d_ff_expert=24576),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2, chunk=64),
)

SMOKE = ArchConfig(
    name="jamba-1.5-large-398b-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    period=_period(),
    moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=128),
    mamba=MambaSpec(d_state=8, d_conv=4, expand=2, chunk=8),
)
