"""deepseek-7b [dense] — llama-arch (arXiv:2401.02954).

30L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=102400.

Paper-technique applicability: full — standard KV cache, bounded-KV DAC on
decode.
"""
from repro.models import ArchConfig, LayerSpec

FULL = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    period=(LayerSpec("attn"),),
)

SMOKE = ArchConfig(
    name="deepseek-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    period=(LayerSpec("attn"),),
)
