"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Every LM arch is paired with the same four cells:
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (serve)
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of 32k)
  long_500k    seq 524,288 global_batch 1     -> serve_step; sub-quadratic
                                                 required.  SSM/hybrid archs
                                                 run natively; attention
                                                 archs run under the paper's
                                                 bounded-KV DAC manager
                                                 (budget slots << seq).

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — nothing
is allocated; the dry-run lowers against them.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    bounded_budget: int = 0        # decode: DAC bounded-KV slot budget


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode",
                           bounded_budget=65536),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape: "ShapeCell | str"):
    """Model-input ShapeDtypeStructs for one (arch x shape) cell.

    train:   {tokens|embeds, labels}
    prefill: {tokens|embeds}
    decode:  {token [B] int32 | embed [B, d]}  (serve state specs live in
             repro.serving.serve_state_specs — they are step-state, not
             model input)
    """
    cell = SHAPES[shape] if isinstance(shape, str) else shape
    B, S = cell.global_batch, cell.seq_len
    d = cfg.d_model
    if cell.kind == "train":
        spec = {"labels": _sds((B, S), jnp.int32)}
        if cfg.embeds_input:
            spec["embeds"] = _sds((B, S, d), jnp.bfloat16)
        else:
            spec["tokens"] = _sds((B, S), jnp.int32)
        return spec
    if cell.kind == "prefill":
        if cfg.embeds_input:
            return {"embeds": _sds((B, S, d), jnp.bfloat16)}
        return {"tokens": _sds((B, S), jnp.int32)}
    if cell.kind == "decode":
        if cfg.embeds_input:
            return {"embed": _sds((B, d), jnp.bfloat16)}
        return {"token": _sds((B,), jnp.int32)}
    raise ValueError(cell.kind)
