"""Fault-tolerant checkpointing: atomic writes, keep-k pruning, auto-resume,
optional async (double-buffered host copy + writer thread).

Layout:  <dir>/step_<N>/state.npz + meta.json, written to a ``.tmp``
directory first and atomically renamed — a crash mid-save never corrupts the
latest checkpoint, and restore() simply picks the highest complete step.

State pytrees are nested dicts with array leaves (the only structure the
framework uses); leaves are addressed by '/'-joined path.  Scalars and
int8-quantized moment sub-dicts round-trip transparently.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 & friends with numpy)
import numpy as np

_SEP = "/"

# numpy can't serialize ml_dtypes natively; store a bit-view + dtype name
_VIEW_AS = {np.dtype("bfloat16"): np.uint16,
            np.dtype("float8_e4m3fn"): np.uint8,
            np.dtype("float8_e5m2"): np.uint8}


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = tree
    return out


def _unflatten(flat):
    root: dict = {}
    for path, leaf in flat.items():
        parts = path.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


class CheckpointManager:
    """Atomic, keep-k, optionally-async checkpoint manager."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- paths ----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self):
        s = self.steps()
        return s[-1] if s else None

    # -- save -----------------------------------------------------------
    def save(self, step: int, state: dict, blocking: bool = True,
             extra_meta: dict | None = None):
        """Snapshot `state` at `step`.  blocking=False returns immediately
        after the host copy; the serialization runs on a writer thread."""
        self.wait()
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(state).items()}
        dtypes = {}
        for k, v in host.items():
            if v.dtype in _VIEW_AS:
                dtypes[k] = str(v.dtype)
                host[k] = v.view(_VIEW_AS[v.dtype])
        # repolint: waive[wallclock] -- checkpoint provenance stamp
        meta = {"step": step, "time": time.time(), "dtypes": dtypes,
                **(extra_meta or {})}

        def _write():
            final = self._step_dir(step)
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "state.npz"), **host)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                # repolint: waive[atomic-json] -- tmp dir + atomic rename
                json.dump(meta, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._prune()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        for s in self.steps()[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def restore(self, step: int | None = None):
        """Returns (step, state) or (None, None) when nothing to resume.

        Leaves come back as numpy arrays; callers device_put them with
        whatever sharding the *current* mesh wants — this is what makes
        elastic restarts (different device count) work.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(d, "state.npz")) as z:
            flat = {k: z[k] for k in z.files}
        for k, dt in meta.get("dtypes", {}).items():
            flat[k] = flat[k].view(np.dtype(dt))
        return step, _unflatten(flat)
