"""Roofline analysis from the compiled (SPMD-partitioned) HLO.

Why not ``compiled.cost_analysis()`` alone: XLA's HloCostAnalysis counts a
``while`` body ONCE, but every model here scans over layer periods (and the
train step scans over microbatches), so flops / bytes / collective counts
must be multiplied by loop trip counts.  This module parses
``compiled.as_text()`` into computations, recovers each while's trip count
from its condition (scan conditions compare the counter against a constant),
propagates multipliers through fusion/call/while edges from ENTRY, and
accumulates:

  * flops            — dot ops: 2 * prod(out) * contracted_size
                       (+1 flop/output element for fusions; minor)
  * hbm bytes        — operand + result bytes of *top-level* instructions
                       (fusion internals stay in registers/VMEM)
  * collective bytes — per collective kind, with ring wire-cost factors:
                         all-gather      (N-1)/N * result
                         all-reduce    2*(N-1)/N * result
                         reduce-scatter  (N-1)/N * operand
                         all-to-all      (N-1)/N * operand
                         collective-permute      operand
                       N parsed from replica_groups.

Everything is per-device (the compiled module is the per-device program).
Validated against cost_analysis on scan-free graphs (tests/test_roofline).

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_TYPE_RE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_HBM_OPS = {
    "copy", "transpose", "reshape", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "gather", "scatter", "reduce",
    "pad", "select", "convert", "iota", "sort", "reduce-window",
    "bitcast-convert", "dot", "rng-bit-generator", "cumsum",
}


def _span_bytes(span: str) -> int:
    """Sum byte sizes of every dtype[shape] token in `span`."""
    total = 0
    for dt, dims in _TYPE_RE.findall(span):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_shape(span: str):
    """(elems, dims) of the first type token in `span`."""
    m = _TYPE_RE.search(span)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return (math.prod(dims) if dims else 1), dims


class _Instr:
    __slots__ = ("name", "rhs", "op", "result_span", "arg_names")

    def __init__(self, name, rhs):
        self.name = name
        self.rhs = rhs
        mop = re.search(r"\b([a-z][\w\-]*)\(", rhs)
        self.op = mop.group(1) if mop else ""
        self.result_span = rhs[: mop.start()] if mop else rhs
        if mop:
            depth = 0
            end = mop.end() - 1
            for j in range(mop.end() - 1, len(rhs)):
                if rhs[j] == "(":
                    depth += 1
                elif rhs[j] == ")":
                    depth -= 1
                    if depth == 0:
                        end = j
                        break
            args_text = rhs[mop.end():end]
            self.arg_names = re.findall(r"%([\w.\-]+)", args_text)
        else:
            self.arg_names = []


def parse_computations(text: str):
    """Returns ({comp_name: [instr]}, entry_name)."""
    comps: dict[str, list] = {}
    entry = None
    cname = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line.rstrip())
        if mc:
            cname = mc.group(2)
            comps[cname] = []
            if mc.group(1):
                entry = cname
            continue
        s = line.strip()
        if s == "}":
            cname = None
            continue
        if cname is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            comps[cname].append(_Instr(mi.group(1), mi.group(2)))
    return comps, entry


def _trip_count(cond_instrs) -> int:
    best = 1
    for ins in cond_instrs:
        for c in _CONST_RE.findall(ins.rhs):
            best = max(best, int(c))
    return best


def _group_size(rhs: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rhs)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def analyze_hlo(text: str, default_group: int = 1) -> dict:
    """Per-device flops / HBM bytes / collective wire bytes, loop-aware."""
    comps, entry = parse_computations(text)
    if entry is None:                     # single anonymous computation
        entry = next(iter(comps)) if comps else ""

    # name -> byte size and (elems, dims), per computation
    sizes, shapes = {}, {}
    for cn, instrs in comps.items():
        sz, sh = {}, {}
        for ins in instrs:
            sz[ins.name] = _span_bytes(ins.result_span)
            sh[ins.name] = _first_shape(ins.result_span)
        sizes[cn], shapes[cn] = sz, sh

    # Per fused computation: bytes actually READ per parameter index, and
    # the bytes actually WRITTEN by the fusion.
    #   * a parameter whose every use is slice/dynamic-slice/gather touches
    #     only the sliced window;
    #   * a parameter that is the *buffer* operand of a dynamic-update-slice
    #     is updated in place: it reads ~the update window, and the fusion
    #     writes ~the update window (not the whole buffer) — backward-of-
    #     scan gradient accumulations hit this path every iteration.
    fusion_param_reads: dict[str, dict[int, int]] = {}
    fusion_write_bytes: dict[str, int] = {}
    layout_ops = {"bitcast", "reshape", "copy", "transpose", "convert",
                  "bitcast-convert"}
    for cn, instrs in comps.items():
        params = {}
        for ins in instrs:
            mpar = re.search(r"\bparameter\((\d+)\)", ins.rhs)
            if mpar:
                params[ins.name] = int(mpar.group(1))
        if not params:
            continue
        uses = defaultdict(list)
        for ins in instrs:
            for a in set(ins.arg_names):
                uses[a].append(ins)
        reads = {}
        for pname, pidx in params.items():
            full = sizes[cn].get(pname, 0)
            sliced = 0
            ok_sliced = True
            used = bool(uses[pname])
            stack = [pname]
            seen = {pname}
            while stack and ok_sliced:
                nm = stack.pop()
                for ins in uses[nm]:
                    if ins.op in ("slice", "dynamic-slice", "gather"):
                        sliced += _span_bytes(ins.result_span)
                    elif ins.op == "dynamic-update-slice" and \
                            ins.arg_names and ins.arg_names[0] == nm:
                        # in-place RMW of the window only
                        upd = sizes[cn].get(ins.arg_names[1], 0) \
                            if len(ins.arg_names) > 1 else 0
                        sliced += upd
                    elif ins.op in layout_ops:
                        if ins.name not in seen:
                            seen.add(ins.name)
                            stack.append(ins.name)
                    else:
                        ok_sliced = False
                        break
            reads[pidx] = min(sliced, full) if (used and ok_sliced) else \
                (full if used else 0)
        fusion_param_reads[cn] = reads

        # write bytes: dus roots write their update window, not the buffer
        dus_updates = {}
        produced = {}
        for ins in instrs:
            produced[ins.name] = ins
            if ins.op == "dynamic-update-slice" and len(ins.arg_names) > 1:
                dus_updates[ins.name] = sizes[cn].get(ins.arg_names[1], 0)
        root = instrs[-1] if instrs else None
        if root is not None:
            names = [root.name]
            if root.op == "tuple" or root.rhs.lstrip().startswith("("):
                names = root.arg_names or [root.name]
            wb = 0
            shrunk = False
            for nm in names:
                if nm in dus_updates:
                    wb += dus_updates[nm]
                    shrunk = True
                else:
                    src = produced.get(nm)
                    wb += _span_bytes(src.result_span) if src else 0
            if shrunk:
                fusion_write_bytes[cn] = wb

    # computations that are fusion bodies: their instructions live in
    # VMEM/registers — only their dots' flops count, never HBM traffic
    fusion_bodies = set()
    for cn, instrs in comps.items():
        for ins in instrs:
            for cal in re.findall(r"calls=%?([\w.\-]+)", ins.rhs):
                fusion_bodies.add(cal)

    # multipliers via fixpoint over call edges
    mult = defaultdict(float)
    mult[entry] = 1.0
    changed = True
    while changed:
        changed = False
        for cn in list(comps):
            m = mult.get(cn, 0.0)
            if m == 0.0:
                continue
            for ins in comps[cn]:
                if ins.op == "while" or " while(" in ins.rhs:
                    mb = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                    mc = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                    trips = _trip_count(comps.get(mc.group(1), [])) \
                        if mc else 1
                    targets = []
                    if mb:
                        targets.append((mb.group(1), trips))
                    if mc:
                        targets.append((mc.group(1), trips + 1))
                elif ins.op in ("fusion", "call") or "to_apply=" in ins.rhs \
                        or "calls=" in ins.rhs:
                    targets = [(c, 1) for c in re.findall(
                        r"(?:calls=|to_apply=)%?([\w.\-]+)", ins.rhs)]
                elif ins.op == "conditional":
                    targets = [(c, 1) for c in re.findall(
                        r"branch_computations=\{([^}]*)\}", ins.rhs)
                        for c in re.findall(r"%?([\w.\-]+)", c)]
                else:
                    continue
                for callee, factor in targets:
                    if callee in comps and mult[callee] < m * factor:
                        mult[callee] = m * factor
                        changed = True

    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    counts = defaultdict(float)
    hbm_by_op = defaultdict(float)
    hbm_attn_inner = 0.0

    # attention-inner computations: their intermediates (scores, softmax
    # stats, p@v partials) are HBM traffic in the jnp-lowered program but
    # VMEM-resident in the Pallas flash/flash-decode kernels.  Tagged by
    # the attention einsum labels in op_name metadata.
    _ATTN_PAT = re.compile(
        r"op_name=\"[^\"]*(flash_attention_jnp|decode_attention_jnp"
        r"|bqhgk|bqhgd|bhgs,|bhgd,|bhst|bhs,bsr)")
    # Tagging granularity: an instruction is attention-inner if (a) its own
    # op_name carries the scope, or (b) it has no metadata (XLA-synthesized
    # wrappers like wrapped_reduce-window) / is a fusion, and the majority
    # of metadata-carrying instructions in the relevant computation (fusion
    # body, else enclosing computation) are scope-tagged.  This catches the
    # softmax reduce-windows inside the pure-attention kv-scan bodies while
    # leaving mixed layer bodies (MLP + cache writes) untagged.
    comp_tag_frac = {}
    for cn, instrs in comps.items():
        tagged = sum(1 for i in instrs if _ATTN_PAT.search(i.rhs))
        meta = sum(1 for i in instrs if "op_name=" in i.rhs)
        comp_tag_frac[cn] = (tagged / meta) if meta else -1.0

    def _is_attn_instr(ins, cn):
        if _ATTN_PAT.search(ins.rhs):
            return True
        ref = None
        if ins.op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
            if m and comp_tag_frac.get(m.group(1), -1.0) >= 0.0:
                ref = comp_tag_frac[m.group(1)]
        if ref is None and "op_name=" not in ins.rhs:
            ref = comp_tag_frac.get(cn, -1.0)
        return ref is not None and ref >= 0.5

    def op_bytes(cn, ins):
        sz = sizes[cn]
        return sum(sz.get(a, 0) for a in ins.arg_names)

    for cn, instrs in comps.items():
        m = mult.get(cn, 0.0)
        if m == 0.0:
            continue
        in_fusion = cn in fusion_bodies
        for ins in instrs:
            is_attn = _is_attn_instr(ins, cn)
            if in_fusion:
                if ins.op == "dot":       # dots fused via output fusion
                    out_elems, _ = _first_shape(ins.result_span)
                    k = 1
                    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                   ins.rhs)
                    if mc and ins.arg_names:
                        _, lhs_dims = shapes[cn].get(ins.arg_names[0],
                                                     (0, []))
                        if mc.group(1) and lhs_dims:
                            for d in mc.group(1).split(","):
                                if int(d) < len(lhs_dims):
                                    k *= lhs_dims[int(d)]
                    flops += m * 2.0 * out_elems * k
                continue
            hbm_before = hbm
            if ins.op == "dot":
                out_elems, _ = _first_shape(ins.result_span)
                k = 1
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               ins.rhs)
                if mc and ins.arg_names:
                    _, lhs_dims = shapes[cn].get(ins.arg_names[0], (0, []))
                    if mc.group(1) and lhs_dims:
                        for d in mc.group(1).split(","):
                            if int(d) < len(lhs_dims):
                                k *= lhs_dims[int(d)]
                flops += m * 2.0 * out_elems * k
                hbm += m * (_span_bytes(ins.result_span) + op_bytes(cn, ins))
            elif ins.op == "fusion":
                out_elems, _ = _first_shape(ins.result_span)
                flops += m * out_elems
                mcal = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                callee = mcal.group(1) if mcal else None
                reads = fusion_param_reads.get(callee, None)
                if reads is not None:
                    opb = sum(
                        min(sizes[cn].get(a, 0), reads.get(i, 1 << 62))
                        for i, a in enumerate(ins.arg_names))
                else:
                    opb = op_bytes(cn, ins)
                wb = fusion_write_bytes.get(
                    callee, _span_bytes(ins.result_span))
                hbm += m * (wb + opb)
            elif any(ins.op.startswith(c) for c in COLLECTIVES):
                if ins.op.endswith("-done"):
                    continue
                kind = next(c for c in COLLECTIVES if ins.op.startswith(c))
                n = _group_size(ins.rhs, default_group)
                rb = _span_bytes(ins.result_span)
                ob = op_bytes(cn, ins)
                ring = (n - 1) / max(n, 1)
                wire = {"all-gather": rb * ring,
                        "all-reduce": 2 * rb * ring,
                        "reduce-scatter": ob * ring,
                        "all-to-all": ob * ring,
                        "collective-permute": ob}[kind]
                coll[kind] += m * wire
                counts[kind] += m
                hbm += m * (rb + ob)
            elif ins.op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced/gathered window, then writes it
                hbm += m * 2 * _span_bytes(ins.result_span)
            elif ins.op == "dynamic-update-slice":
                # reads + writes the update region only (in-place alias)
                upd = sizes[cn].get(ins.arg_names[1], 0) \
                    if len(ins.arg_names) > 1 else 0
                hbm += m * 2 * upd
            elif ins.op == "scatter":
                upd = sizes[cn].get(ins.arg_names[-1], 0) \
                    if ins.arg_names else 0
                hbm += m * 2 * upd
            elif ins.op in _HBM_OPS:
                hbm += m * (_span_bytes(ins.result_span) + op_bytes(cn, ins))
            hbm_by_op[ins.op] += hbm - hbm_before
            if is_attn:
                hbm_attn_inner += hbm - hbm_before

    wire_total = sum(coll.values())
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": dict(coll),
        "collective_counts": dict(counts),
        "hbm_by_op": dict(hbm_by_op),
        "hbm_attention_inner": hbm_attn_inner,
        "wire_bytes": wire_total,
        "terms": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": hbm / HBM_BW,
            "collective_s": wire_total / ICI_BW,
        },
    }


def dominant_term(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


# ---------------------------------------------------------------------------
# policy-step roofline
# ---------------------------------------------------------------------------

# int32 rank-row element
_ROW_BYTES = 4


def policy_step_traffic_bytes(W: int) -> int:
    """Modeled HBM bytes per fused policy step at padded row width ``W``.

    The tiled kernel makes two passes over the row (phase 0 find, phase 1
    promote) and each pass both reads its input block and writes its
    output block (phase 0 pre-writes the row so every output block is
    defined), so the streamed traffic is ``4 * W * 4`` bytes; the SMEM
    scalar I/O and cross-tile carries are O(1) and ignored.

    >>> policy_step_traffic_bytes(128)
    2048
    """
    return 4 * W * _ROW_BYTES


def policy_step_targets(widths) -> dict:
    """Memory-bound roofline target for the fused policy step, in Mops
    (million requests/s) per padded width: the step does O(W) element ops
    and O(W) bytes of HBM traffic (arithmetic intensity < 1 flop/byte on
    int32 rows), so the HBM roof — not the compute roof — binds::

        steps/s <= HBM_BW / policy_step_traffic_bytes(W)

    ``benchmarks/throughput.py --policy-step`` stamps these targets into
    ``BENCH_policy_step.json`` and reports the compiled kernel's achieved
    fraction on real hardware.

    >>> t = policy_step_targets([1024])
    >>> round(t[1024], 1)                    # 819e9 / 16384 / 1e6
    50.0
    """
    return {int(W): HBM_BW / policy_step_traffic_bytes(int(W)) / 1e6
            for W in widths}
