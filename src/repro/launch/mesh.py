"""Production mesh construction.

Axes:
  * pod   — 2 pods of 256 chips; pure data parallelism over slow DCN links
            (params replicated per pod, gradients synced across — optionally
            int8-compressed, see train/compression.py).
  * data  — 16-way FSDP + batch data parallelism within a pod.
  * model — 16-way tensor / expert / sequence parallelism (fast ICI ring).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models.sharding import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == need:
        return jax.make_mesh(shape, axes)
    if len(devs) > need:            # 512 placeholders, single-pod slice
        return jax.make_mesh(shape, axes, devices=devs[:need])
    raise RuntimeError(
        f"need {need} devices for mesh {shape}, have {len(devs)} — run "
        "under XLA_FLAGS=--xla_force_host_platform_device_count=512")


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for CPU tests (uses however many devices exist)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])


def shard_ctx(mesh: Mesh) -> ShardCtx:
    pod = "pod" if "pod" in mesh.axis_names else None
    return ShardCtx(mesh=mesh, fsdp="data", tp="model", pod=pod)
