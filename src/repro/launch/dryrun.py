import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against ShapeDtypeStruct inputs (no allocation), record
memory_analysis / cost_analysis / loop-aware roofline terms to JSON.

The two lines above MUST precede any other import (jax locks the device
count at first init).

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k \
      --mesh pod --out experiments/dryrun
  python -m repro.launch.dryrun --all            # every remaining cell
  python -m repro.launch.dryrun --report         # summarize JSONs
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, input_specs       # noqa: E402
from repro.launch import roofline                          # noqa: E402
from repro.launch.mesh import make_production_mesh, shard_ctx  # noqa: E402
from repro.models import init_params_shape, param_count, shardings  # noqa: E402
from repro.models.model import REMAT_POLICIES  # noqa: F401,E402
from repro.optim import AdamWConfig, adamw      # noqa: E402
from repro.serving import serve_state_specs     # noqa: E402
from repro.serving.serve_step import decode_step, prefill, \
    serve_state_shardings                       # noqa: E402
from repro.train import make_train_step         # noqa: E402

# per-arch execution knobs (microbatches divide the 256 train batch;
# int8 Adam moments for the >=50B archs so optimizer state fits HBM)
TRAIN_KNOBS = {
    "xlstm-125m": dict(n_micro=1, moments="float32"),
    "musicgen-medium": dict(n_micro=2, moments="float32"),
    "deepseek-7b": dict(n_micro=4, moments="float32"),
    "codeqwen1.5-7b": dict(n_micro=4, moments="float32"),
    "llava-next-mistral-7b": dict(n_micro=4, moments="float32"),
    "gemma2-27b": dict(n_micro=8, moments="float32"),
    "qwen1.5-110b": dict(n_micro=16, moments="int8"),
    "mixtral-8x22b": dict(n_micro=16, moments="int8"),
    "deepseek-v2-236b": dict(n_micro=16, moments="int8"),
    "jamba-1.5-large-398b": dict(n_micro=16, moments="int8"),
}


def batch_shardings(spec_tree, sctx):
    """Batch inputs: dim0 over (pod,)data when divisible."""
    def one(s):
        b = sctx.batch_axes if s.shape[0] % sctx._bsz() == 0 else None
        return NamedSharding(sctx.mesh, P(b, *([None] * (len(s.shape) - 1))))
    return jax.tree.map(one, spec_tree)


def _opt_shardings(opt_shape, param_sh, mesh):
    """Adam moments follow their param's sharding.  int8 block-quantized
    moments are flat [nblocks, 64] — block order is param-agnostic, so they
    shard over every non-pod mesh axis (fully sharded optimizer state)."""
    flat_axes = tuple(a for a in mesh.axis_names if a != "pod")

    def visit(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        if keys[0] in ("m", "v"):
            if keys[-1] in ("q", "scale"):
                n_flat = 1
                for a in flat_axes:
                    n_flat *= mesh.shape[a]
                if leaf.shape[0] % n_flat == 0:
                    rest = [None] * (len(leaf.shape) - 1)
                    return NamedSharding(mesh, P(flat_axes, *rest))
                return NamedSharding(mesh,
                                     P(*([None] * len(leaf.shape))))
            sub = param_sh
            for k in keys[1:]:
                if isinstance(sub, dict) and k in sub:
                    sub = sub[k]
                else:
                    sub = None
                    break
            if sub is not None and not isinstance(sub, dict) \
                    and len(leaf.shape) == len(sub.spec):
                return sub
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
    return jax.tree_util.tree_map_with_path(visit, opt_shape)


def lower_cell(arch: str, shape: str, mesh_kind: str, remat: str = "full",
               extra: dict | None = None):
    """Returns (lowered, n_chips, meta) for one dry-run cell."""
    cfg = ARCHS[arch]
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    sctx = shard_ctx(mesh)
    n_chips = mesh.size
    params_shape = init_params_shape(cfg)
    param_sh = shardings(params_shape, cfg, sctx)
    inputs = input_specs(cfg, shape)
    knobs = dict(TRAIN_KNOBS[arch])
    knobs.update(extra or {})

    meta = dict(arch=arch, shape=shape, mesh=mesh_kind, n_chips=n_chips,
                remat=remat, **{k: str(v) for k, v in knobs.items()})

    if cell.kind == "train":
        # each microbatch must still split over every batch shard, or the
        # partitioner replicates activations across the starved axis
        batch_shards = sctx._bsz()
        knobs["n_micro"] = min(int(knobs["n_micro"]),
                               max(1, cell.global_batch // batch_shards))
        opt_cfg = AdamWConfig(moment_dtype=knobs["moments"],
                              total_steps=10000)
        opt_shape = jax.eval_shape(lambda p: adamw.init(p, opt_cfg),
                                   params_shape)
        opt_sh = _opt_shardings(opt_shape, param_sh, mesh)
        step = make_train_step(cfg, opt_cfg, sctx=sctx,
                               n_microbatches=int(knobs["n_micro"]),
                               remat=remat)
        batch_sh = batch_shardings(inputs, sctx)
        jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_shape, opt_shape, inputs)
        tokens = cell.global_batch * cell.seq_len
        meta["model_flops"] = 6 * param_count(cfg, active_only=True) * tokens
    elif cell.kind == "prefill":
        def prefill_fn(params, batch):
            return prefill(params, cfg,
                           tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"),
                           max_len=cell.seq_len, budget=0, sctx=sctx,
                           remat=remat)
        batch_sh = batch_shardings(inputs, sctx)
        jitted = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
        lowered = jitted.lower(params_shape, inputs)
        tokens = cell.global_batch * cell.seq_len
        meta["model_flops"] = 2 * param_count(cfg, active_only=True) * tokens
    else:  # decode
        import dataclasses as _dc
        if knobs.get("serve_sharding", "resident") == "resident":
            # inference-mode placement: weights resident (no FSDP gather
            # per token); see sharding._serve_rule + EXPERIMENTS.md §Perf
            sctx = _dc.replace(sctx, mode="serve")
            param_sh = shardings(params_shape, cfg, sctx)
            meta["serve_sharding"] = "resident"
        B = cell.global_batch
        state_shape = serve_state_specs(cfg, B, cell.seq_len,
                                        budget=cell.bounded_budget)
        state_sh = serve_state_shardings(cfg, sctx, state_shape)

        def decode_fn(params, state, inp):
            return decode_step(params, cfg, state,
                               token=inp.get("token"),
                               embed=inp.get("embed"), sctx=sctx)
        in_sh = batch_shardings(inputs, sctx)
        jitted = jax.jit(decode_fn, in_shardings=(param_sh, state_sh, in_sh),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_shape, state_shape, inputs)
        meta["model_flops"] = 2 * param_count(cfg, active_only=True) * B
        meta["bounded_budget"] = cell.bounded_budget
    return lowered, n_chips, meta


def kernel_credit_bytes(cfg, cell, n_chips: int, passes: float) -> float:
    """Per-chip HBM bytes of the Pallas flash/flash-decode kernels for every
    attention layer of one step — the analytic substitute for the
    jnp-lowered attention-inner traffic (which materializes score tensors
    that the kernels keep in VMEM).  Model:
      full-seq:  passes x [ nq x (K+V) streamed + Q + O ]
      decode:    2K + V + Q + O  (stats pass re-reads K)
    Head/batch sharding divides per-chip bytes; windowed layers stream a
    band instead of the full prefix.
    """
    tp_n = 16
    bsz = 16 * (2 if n_chips == 512 else 1)
    B, S = cell.global_batch, cell.seq_len
    if cell.kind == "decode" and cell.bounded_budget:
        S = cell.bounded_budget          # the DAC pool bounds the KV read
    B_loc = B / bsz if B % bsz == 0 else B
    H = cfg.n_heads
    Hkv = cfg.n_kv_heads
    hd = cfg.head_dim
    H_loc = H / tp_n if H % tp_n == 0 else H
    Hkv_loc = Hkv / tp_n if Hkv % tp_n == 0 else Hkv
    bq = min(cfg.attn_chunk_q, S)
    total = 0.0
    # slot tables shard over 'model' when kv-heads don't divide it
    # (serve_state_shardings); the kernel streams only the local slots
    slot_div = tp_n if Hkv % tp_n else 1
    for spec in cfg.layer_specs():
        if spec.kind == "mla":
            width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            if cell.kind == "decode":
                total += B_loc * S * width * 2 * 2 / tp_n  # latent, sharded
                total += 2 * B_loc * H_loc * hd * 2
            else:
                nq = max(S // bq, 1)
                total += passes * (nq * B_loc * S * width * 2
                                   + 2 * B_loc * S * H_loc *
                                   (cfg.qk_nope_head_dim
                                    + cfg.qk_rope_head_dim) * 2)
        elif spec.kind == "attn":
            span = min(S, (spec.window or S) + bq)
            if cell.kind == "decode":
                kv = B_loc * min(S, spec.window or S) * Hkv_loc * hd * 2 \
                    / slot_div
                total += 3 * kv + 2 * B_loc * H_loc * hd * 2
            else:
                nq = max(S // bq, 1)
                kv_stream = nq * 2 * B_loc * span * Hkv_loc * hd * 2
                qo = 2 * B_loc * S * H_loc * hd * 2
                total += passes * (kv_stream + qo)
    return total


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             remat: str = "full", tag: str = "", extra: dict | None = None):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape}__{mesh_kind}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, name + ".json")
    t0 = time.perf_counter()
    try:
        lowered, n_chips, meta = lower_cell(arch, shape, mesh_kind,
                                            remat=remat, extra=extra)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        ana = roofline.analyze_hlo(hlo, default_group=n_chips)
        terms = ana["terms"]
        model_flops_chip = meta["model_flops"] / n_chips
        result = {
            **meta,
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "total_nonaliased_gb": round(
                    (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes
                     - mem.alias_size_in_bytes) / 2**30, 3),
            },
            "xla_cost": {k: cost[k] for k in ("flops",)
                         if k in cost},
            "roofline": {
                "flops_per_chip": ana["flops"],
                "hbm_bytes_per_chip": ana["hbm_bytes"],
                "wire_bytes_per_chip": ana["wire_bytes"],
                "collective_bytes": ana["collective_bytes"],
                "collective_counts": ana["collective_counts"],
                "hbm_by_op": ana.get("hbm_by_op", {}),
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "collective_s": terms["collective_s"],
                "dominant": roofline.dominant_term(terms),
                "model_flops_per_chip": model_flops_chip,
                "useful_flops_ratio": (model_flops_chip / ana["flops"])
                if ana["flops"] else 0.0,
                "roofline_fraction": (model_flops_chip / roofline.PEAK_FLOPS)
                / max(max(terms.values()), 1e-30),
            },
        }
        # Pallas-kernel credit: the flash kernels keep attention
        # intermediates in VMEM; the jnp-lowered graph (what CPU XLA can
        # compile) spills them.  Report the kernel-credited memory term
        # alongside the raw one (EXPERIMENTS.md §Roofline method).
        cell = SHAPES[shape]
        cfg = ARCHS[arch]
        passes = {"train": 4.0, "prefill": 1.0, "decode": 1.0}[cell.kind]
        attn_inner = ana.get("hbm_attention_inner", 0.0)
        k_bytes = kernel_credit_bytes(cfg, cell, n_chips, passes)
        mem_credited = (ana["hbm_bytes"] - attn_inner + k_bytes) \
            / roofline.HBM_BW
        terms_k = dict(terms, memory_s=mem_credited)
        result["roofline"]["kernel_credited"] = {
            "attention_inner_bytes": attn_inner,
            "kernel_bytes": k_bytes,
            "memory_s": mem_credited,
            "dominant": roofline.dominant_term(terms_k),
            "roofline_fraction":
                (model_flops_chip / roofline.PEAK_FLOPS)
                / max(max(terms_k.values()), 1e-30),
        }
    except Exception as e:  # noqa: BLE001 — cell failures are data
        result = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                  "ok": False, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    from repro.bench.results import atomic_write_json
    atomic_write_json(path, result)
    dom = result.get("roofline", {}).get("dominant", "-")
    rf = result.get("roofline", {}).get("roofline_fraction", 0)
    print(f"[dryrun] {name}: ok={result['ok']} dominant={dom} "
          f"roofline_frac={rf:.3f} ({time.perf_counter()-t0:.0f}s)")
    return result


def all_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh_kind in ("pod", "multipod"):
                yield arch, shape, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--tag", default="")
    ap.add_argument("--n-micro", type=int, default=0)
    ap.add_argument("--serve-sharding", default="resident",
                    choices=["resident", "fsdp"],
                    help="decode param placement (fsdp = the pre-perf-"
                         "iteration baseline)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    extra = {"n_micro": args.n_micro} if args.n_micro else {}
    extra["serve_sharding"] = args.serve_sharding
    extra = extra or None
    if args.all:
        for arch, shape, mesh_kind in all_cells():
            p = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
            if args.skip_done and os.path.exists(p):
                with open(p) as f:
                    if json.load(f).get("ok"):
                        continue
            run_cell(arch, shape, mesh_kind, args.out, remat=args.remat)
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        run_cell(args.arch, args.shape, args.mesh, args.out,
                 remat=args.remat, tag=args.tag, extra=extra)


if __name__ == "__main__":
    main()
