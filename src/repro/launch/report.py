"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_):
    cells = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        r = json.load(open(f))
        tag = r.get("tag", "")
        key = (r["arch"], r["shape"], r["mesh"])
        if "__" in os.path.basename(f)[:-5].replace(
                f"{r['arch']}__{r['shape']}__{r['mesh']}", ""):
            continue  # tagged perf-iteration files are reported separately
        if os.path.basename(f) == f"{r['arch']}__{r['shape']}__{r['mesh']}.json":
            cells[key] = r
    return cells


def fmt_s(x):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.2f}m"
    return f"{x*1e6:.0f}u"


def table(cells, mesh="pod", out=None):
    lines = []
    lines.append(
        "| arch | shape | compute_s | memory_s | coll_s | dominant | "
        "mem_s (kernel) | frac | frac (kernel) | MODEL/HLO |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh or not r.get("ok"):
            continue
        rf = r["roofline"]
        kc = rf.get("kernel_credited", {})
        lines.append(
            f"| {arch} | {shape} "
            f"| {fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} "
            f"| {fmt_s(rf['collective_s'])} | {rf['dominant'][:-2]} "
            f"| {fmt_s(kc['memory_s']) if kc else '-'} "
            f"| {rf['roofline_fraction']:.4f} "
            f"| {kc.get('roofline_fraction', 0):.4f} "
            f"| {rf['useful_flops_ratio']:.3f} |")
    text = "\n".join(lines)
    if out:
        with open(out, "w") as f:
            f.write(text)
    return text


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    cells = load(args.dir)
    ok = sum(1 for r in cells.values() if r.get("ok"))
    print(f"# {ok}/{len(cells)} cells ok ({args.mesh} mesh shown)\n")
    print(table(cells, args.mesh))


if __name__ == "__main__":
    main()
