"""Serving entrypoint: prefill a batch of prompts, decode with either the
unbounded cache or the paper's DynamicAdaptiveClimb bounded KV pool.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
      --prompt-len 64 --gen 32 --budget 48
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--budget", type=int, default=0,
                    help=">0: bounded DAC KV pool with this many slots")
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch
    from repro.models import init_params
    from repro.serving import decode_step, prefill

    cfg = get_arch(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    max_len = S + args.gen

    kw = {}
    if cfg.embeds_input:
        kw["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)).astype(np.float32))
    else:
        kw["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))

    t0 = time.perf_counter()
    state, logits = prefill(params, cfg, max_len=max_len,
                            budget=args.budget, **kw)
    print(f"[serve] prefill {B}x{S}: {time.perf_counter()-t0:.2f}s "
          f"(budget={args.budget or 'unbounded'})")

    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, token=t,
                                               eps=args.eps))
    step_e = jax.jit(lambda p, s, e: decode_step(p, cfg, s, embed=e,
                                                 eps=args.eps))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen):
        if cfg.embeds_input:
            emb = jnp.asarray(rng.standard_normal(
                (B, cfg.d_model)).astype(np.float32))
            state, logits = step_e(params, state, emb)
        else:
            state, logits = step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    print(f"[serve] decoded {args.gen} tokens x {B} seqs in {dt:.2f}s "
          f"({args.gen*B/dt:.1f} tok/s)")
    if args.budget:
        ctrl_ks = []
        for li, st in state["layers"].items():
            if isinstance(st, dict) and "ctrl" in st:
                ctrl_ks.append(np.asarray(st["ctrl"]["k_active"]))
        if ctrl_ks:
            ks = np.stack(ctrl_ks)
            print(f"[serve] DAC active budgets: min={ks.min()} "
                  f"median={np.median(ks):.0f} max={ks.max()} "
                  f"(pool={args.budget})")
    print("[serve] sample tokens:", np.stack(out)[:8, 0].tolist())


if __name__ == "__main__":
    main()
