"""Training entrypoint.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \
      --steps 200 --batch 8 --seq 128

Full-config multi-pod launches use the same code path with
``--mesh pod|multipod`` (on real hardware each host runs this program;
jax.distributed.initialize is called when JAX_COORDINATOR is set).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moments", default="float32",
                    choices=["float32", "int8"])
    ap.add_argument("--remat", default="full")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "pod", "multipod"])
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()

    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh, shard_ctx
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, Trainer

    cfg = get_arch(args.arch, smoke=args.smoke)
    sctx = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
        sctx = shard_ctx(mesh)

    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps, moment_dtype=args.moments)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every,
                       global_batch=args.batch, seq_len=args.seq,
                       n_microbatches=args.microbatches, remat=args.remat)
    trainer = Trainer(cfg, opt, tcfg, sctx=sctx)
    trainer.run()
    hist = trainer.history
    if hist:
        print(f"[train] {args.arch}: step {hist[0]['step']}..."
              f"{hist[-1]['step']}  loss {hist[0]['loss']:.4f} -> "
              f"{hist[-1]['loss']:.4f}  "
              f"stragglers={trainer.watchdog.flagged}")


if __name__ == "__main__":
    main()
