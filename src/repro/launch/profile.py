"""Dry-run profiler: per-instruction HBM/flop/collective attribution with
JAX op provenance (op_name metadata) — the 'profile' step of the §Perf
hypothesis->change->measure loop (no real hardware, so the lowered HLO is
the profile).

  PYTHONPATH=src python -m repro.launch.profile --arch musicgen-medium \
      --shape prefill_32k --mesh pod --top 15
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import re            # noqa: E402
from collections import defaultdict  # noqa: E402

from repro.launch import roofline as R  # noqa: E402

_METN = re.compile(r'op_name="([^"]+)"')


def _mults(comps, entry):
    mult = defaultdict(float)
    mult[entry] = 1.0
    changed = True
    while changed:
        changed = False
        for cn in list(comps):
            m = mult.get(cn, 0.0)
            if m == 0:
                continue
            for ins in comps[cn]:
                if ins.op == "while" or " while(" in ins.rhs:
                    mb = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                    mc = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                    trips = R._trip_count(comps.get(mc.group(1), [])) \
                        if mc else 1
                    targets = ([(mb.group(1), trips)] if mb else []) + \
                        ([(mc.group(1), trips + 1)] if mc else [])
                elif "calls=" in ins.rhs or "to_apply=" in ins.rhs:
                    targets = [(x, 1) for x in re.findall(
                        r"(?:calls=|to_apply=)%?([\w.\-]+)", ins.rhs)]
                else:
                    continue
                for cal, f in targets:
                    if cal in comps and mult[cal] < m * f:
                        mult[cal] = m * f
                        changed = True
    return mult


def _opname(ins):
    m = _METN.search(ins.rhs)
    if not m:
        return "<?>"
    name = m.group(1)
    return re.sub(r"\[.*?\]", "", name)[-70:]


def profile_text(text, top=15, n_chips=256):
    comps, entry = R.parse_computations(text)
    sizes = {cn: {i.name: R._span_bytes(i.result_span) for i in instrs}
             for cn, instrs in comps.items()}
    mult = _mults(comps, entry)
    fusion_bodies = set()
    for cn, instrs in comps.items():
        for ins in instrs:
            for cal in re.findall(r"calls=%?([\w.\-]+)", ins.rhs):
                fusion_bodies.add(cal)

    hbm_rows, coll_rows = [], []
    for cn, instrs in comps.items():
        m = mult.get(cn, 0)
        if m == 0 or cn in fusion_bodies:
            continue
        for ins in instrs:
            is_coll = any(ins.op.startswith(c) for c in R.COLLECTIVES)
            b = R._span_bytes(ins.result_span) + sum(
                sizes[cn].get(a, 0) for a in ins.arg_names)
            if is_coll and not ins.op.endswith("-done"):
                coll_rows.append((m * b, m, ins.op, _opname(ins)))
            elif ins.op in R._HBM_OPS or ins.op == "fusion":
                hbm_rows.append((m * b, m, ins.op, _opname(ins)))

    out = []
    ana = R.analyze_hlo(text, default_group=n_chips)
    out.append(f"terms: compute={ana['terms']['compute_s']:.3f}s "
               f"memory={ana['terms']['memory_s']:.3f}s "
               f"collective={ana['terms']['collective_s']:.3f}s")
    out.append(f"hbm_by_op: " + ", ".join(
        f"{k}={v/1e9:.0f}GB" for k, v in sorted(
            ana["hbm_by_op"].items(), key=lambda kv: -kv[1])[:6]))
    out.append("\n== top HBM contributors (upper-bound bytes x trips) ==")
    for b, m, op, name in sorted(hbm_rows, reverse=True)[:top]:
        out.append(f"  {b/1e9:9.1f}GB x{m:7.0f} {op:22s} {name}")
    out.append("\n== top collectives ==")
    for b, m, op, name in sorted(coll_rows, reverse=True)[:top]:
        out.append(f"  {b/1e9:9.1f}GB x{m:7.0f} {op:22s} {name}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--n-micro", type=int, default=0)
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    extra = {"n_micro": args.n_micro} if args.n_micro else None
    lowered, n, meta = lower_cell(args.arch, args.shape, args.mesh,
                                  remat=args.remat, extra=extra)
    print(profile_text(lowered.compile().as_text(), top=args.top,
                       n_chips=n))


if __name__ == "__main__":
    main()
