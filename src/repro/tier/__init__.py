"""Multi-tenant shared-budget cache tier driven by DAC resize signals.

The paper's headline contribution — DynamicAdaptiveClimb returns capacity
it doesn't need and claims capacity when it thrashes — only matters when
the capacity has somewhere to go.  This package gives it a marketplace:
N tenant caches share one global slot budget, shrinks feed a free pool,
and saturated ``jump`` controllers draw their doublings from it through a
pluggable arbiter (``static`` / ``greedy`` / ``proportional`` /
``auction`` — the last prices grants by byte-miss cost and pairs with
the dynamic-lifecycle fleet layer, :mod:`repro.fleet`).

>>> import numpy as np
>>> from repro.data.traces import tenants_trace
>>> tier = CacheTier("dac", n_tenants=4, budget=64, arbiter="greedy")
>>> reqs = tenants_trace(N=64, T=500, n_tenants=4, period=128, lo=8)
>>> res = replay_tier(tier, reqs, observe=True)   # [T, N] stream
>>> res.miss_ratio.shape                          # per-tenant ratios
(4,)
>>> bool(np.asarray(res.obs["k"]).sum(axis=1).max() <= 64)   # conservation
True

See ``docs/ARCHITECTURE.md`` (tier section) and the ``tenant_sweep``
benchmark for the DAC-arbitrated vs statically-partitioned comparison.
"""
from .arbiter import (ARBITERS, Arbiter, AuctionArbiter, GreedyArbiter,
                      ProportionalArbiter, StaticArbiter, make_arbiter)
from .tier import CacheTier, TierResult, replay_tier

__all__ = [
    "CacheTier", "TierResult", "replay_tier",
    "Arbiter", "StaticArbiter", "GreedyArbiter", "ProportionalArbiter",
    "AuctionArbiter", "ARBITERS", "make_arbiter",
]
