"""Shared-budget multi-tenant cache tier.

N independent tenant caches — one :class:`~repro.core.DynamicAdaptiveClimb`
instance each, state stacked on a leading tenant axis — share one global
slot budget.  Per global step every tenant serves one request (the
``tenants(...)`` trace family interleaves the per-tenant streams along
time), stepped together with ``vmap`` over the existing fused
``rank_step`` path, and then the **arbiter** closes the loop the paper
leaves open:

* a tenant whose shrink fires *returns* its deactivated slots — they fall
  into the global free pool (``budget - sum(k)``) simply by no longer
  being counted;
* a tenant whose ``jump`` saturates at ``2k`` *demands* a doubling, and
  the arbiter grants / partially grants / denies it out of the free pool
  by setting the tenant's capacity cap for the next step (see
  :mod:`repro.tier.arbiter`).

``arbiter("static")`` is the no-op baseline — hard partitioning into
``budget // n_tenants`` shares, bit-identical to N independent
``Engine.replay`` calls — so every improvement the dynamic arbiters show
is attributable to capacity trading, not to a different policy.

Non-resizing policies (LRU, Climb, ...) are also accepted (with the
static arbiter only): each tenant runs a fixed ``budget // n_tenants``
cache, which is exactly the statically-partitioned baseline the
``tenant_sweep`` benchmark compares against.

>>> import numpy as np
>>> from repro.tier import CacheTier, replay_tier
>>> tier = CacheTier("dac", n_tenants=2, budget=32, arbiter="greedy")
>>> reqs = np.zeros((100, 2), np.int32)           # [T, n_tenants] keys
>>> res = replay_tier(tier, reqs)
>>> [int(h) for h in res.metrics.hits]            # per-tenant totals
[99, 99]
>>> float(res.agg_miss_ratio) == 2 / 200
True
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import make_policy
from ..core.dynamicadaptiveclimb import DynamicAdaptiveClimb
from ..core.policy import (EMPTY, Request, lane_pad, normalize_pallas_mode,
                           pallas_mode)
from ..core.simulator import Metrics, _acc_step, _count_dtype, _ratio
from .arbiter import make_arbiter

__all__ = ["CacheTier", "TierResult", "replay_tier"]


class TierResult(NamedTuple):
    """Per-tenant replay totals plus the tier's occupancy trace.

    ``metrics`` leaves carry a trailing tenant axis (``[N]``, or ``[S, N]``
    for a seed-batched replay); ``avg_k`` is each tenant's time-mean active
    size — the occupancy the arbiter actually granted it; ``obs`` is
    ``{"k": [T, N]}`` under ``observe=True`` (else ``None``).
    """

    metrics: Metrics
    avg_k: jax.Array
    obs: Any

    # -- per-tenant ratios --------------------------------------------------
    @property
    def hit_ratio(self):
        return _ratio(self.metrics.hits, self.metrics.requests)

    @property
    def miss_ratio(self):
        m = self.metrics
        return _ratio(np.asarray(m.requests) - np.asarray(m.hits),
                      m.requests)

    @property
    def byte_miss_ratio(self):
        return _ratio(self.metrics.bytes_missed, self.metrics.bytes_total)

    @property
    def penalty_ratio(self):
        return _ratio(self.metrics.penalty, self.metrics.cost_total)

    # -- tier aggregates (sum over the tenant axis, then the ratio) ---------
    def _agg(self, num, den):
        return _ratio(np.asarray(num, dtype=np.float64).sum(axis=-1),
                      np.asarray(den, dtype=np.float64).sum(axis=-1))

    @property
    def agg_miss_ratio(self):
        """Request-weighted aggregate: total misses / total requests."""
        m = self.metrics
        return self._agg(np.asarray(m.requests) - np.asarray(m.hits),
                         m.requests)

    @property
    def agg_byte_miss_ratio(self):
        """Byte-weighted aggregate: total bytes missed / total bytes."""
        return self._agg(self.metrics.bytes_missed, self.metrics.bytes_total)

    @property
    def agg_penalty_ratio(self):
        """Cost-weighted aggregate: total penalty / total cost."""
        return self._agg(self.metrics.penalty, self.metrics.cost_total)


class CacheTier:
    """Static description of one tier: policy x n_tenants x budget x
    arbiter.  Hashable (a jit static argument, like ``Policy``).

    ``policy`` / ``arbiter`` accept spec strings or instances.  ``k0`` is
    each tenant's initial active size; the default mirrors
    ``DynamicAdaptiveClimb.init`` — the static share divided by the
    policy's ``growth`` headroom — so a tenant starts with the same
    slack a standalone DAC cache would have.

    >>> CacheTier("dac(growth=2)", n_tenants=4, budget=64, arbiter="static")
    CacheTier(dynamicadaptiveclimb, n_tenants=4, budget=64, arbiter=static, k0=8)
    """

    def __init__(self, policy="dac", n_tenants: int = 4, budget: int = 256,
                 arbiter="greedy", k0: int | None = None):
        self.policy = make_policy(policy)
        self.arbiter = make_arbiter(arbiter)
        self.n_tenants = int(n_tenants)
        self.budget = int(budget)
        self.resizable = isinstance(self.policy, DynamicAdaptiveClimb)
        if self.n_tenants < 1:
            raise ValueError("n_tenants must be >= 1")
        share = self.budget // self.n_tenants
        if share < 1:
            raise ValueError(
                f"budget {self.budget} too small for {self.n_tenants} tenants")
        if not self.resizable and self.arbiter.name != "static":
            raise ValueError(
                f"policy {self.policy.name!r} emits no resize signals; only "
                "arbiter('static') is meaningful for it")
        if self.arbiter.needs_utility:
            raise ValueError(
                f"arbiter {self.arbiter.name!r} prices capacity by the "
                "byte-miss-cost utility signal, which only the fleet "
                "replay carries — use repro.fleet.FleetTier")
        # an explicit static share above the fair partition would let the
        # tenants jointly exceed the budget — the conservation law every
        # arbiter must respect (sum(k) <= budget at every step)
        if (self.arbiter.name == "static"
                and getattr(self.arbiter, "share", 0) * self.n_tenants
                > self.budget):
            raise ValueError(
                f"static share {self.arbiter.share} x {self.n_tenants} "
                f"tenants exceeds the budget {self.budget}")
        if k0 is None:
            k0 = (max(self.policy.k_min, share // self.policy.growth)
                  if self.resizable else share)
        self.k0 = int(k0)
        if self.k0 * self.n_tenants > self.budget:
            raise ValueError(
                f"initial sizes exceed the budget: {self.n_tenants} x "
                f"{self.k0} > {self.budget}")

    @property
    def share(self) -> int:
        """The static per-tenant partition, ``budget // n_tenants``."""
        return self.budget // self.n_tenants

    # -- state --------------------------------------------------------------
    def init(self) -> dict:
        """Stacked tenant state (leading axis ``n_tenants``).  Resizable
        tenants get budget-wide rank rows (any single tenant may absorb
        the whole budget) plus the arbiter's initial caps."""
        n = self.n_tenants
        if not self.resizable:
            st = self.policy.init(self.share)
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), st)
        k0 = jnp.full((n,), self.k0, jnp.int32)
        demanding = jnp.zeros((n,), bool)
        return {
            # lane-padded budget-wide rank rows; the allocation bound each
            # tenant's control law sees is the *logical* budget (kmax),
            # not the padded array width
            "cache": jnp.full((n, lane_pad(self.budget)), EMPTY,
                              dtype=jnp.int32),
            "jump": jnp.full((n,), self.k0, jnp.int32),
            "jump2": jnp.zeros((n,), jnp.int32),
            "k": k0,
            "kmax": jnp.full((n,), self.budget, jnp.int32),
            "cap": self.arbiter(k0, demanding, self.budget, n),
        }

    # -- one tier step -------------------------------------------------------
    def step(self, state: dict, req: Request):
        """Advance every tenant one request (``req`` leaves are ``[N]``),
        then re-arbitrate the caps from the post-step resize signals.
        Returns ``(state, info, k)`` with per-tenant ``StepInfo`` and
        active sizes."""
        if not self.resizable:
            state, info = jax.vmap(self.policy.step)(state, req)
            k = jnp.full((self.n_tenants,), self.share, jnp.int32)
            return state, info, k
        state, info = jax.vmap(self.policy.step_budgeted)(state, req)
        k = state["k"]
        demanding = state["jump"] >= 2 * k
        state = dict(state, cap=self.arbiter(k, demanding, self.budget,
                                             self.n_tenants))
        return state, info, k

    # -- hashability for jit static args ------------------------------------
    def _fields(self):
        return (self.policy, self.arbiter, self.n_tenants, self.budget,
                self.k0)

    def __hash__(self):
        return hash((type(self).__name__, self._fields()))

    def __eq__(self, other):
        return type(self) is type(other) and self._fields() == other._fields()

    def __repr__(self):
        return (f"CacheTier({self.policy.name}, n_tenants={self.n_tenants}, "
                f"budget={self.budget}, arbiter={self.arbiter.name}, "
                f"k0={self.k0})")


def _zero_acc_tier(n: int) -> Metrics:
    return Metrics(
        requests=jnp.zeros((n,), _count_dtype()),
        hits=jnp.zeros((n,), _count_dtype()),
        bytes_total=jnp.zeros((n,), jnp.float32),
        bytes_missed=jnp.zeros((n,), jnp.float32),
        cost_total=jnp.zeros((n,), jnp.float32),
        penalty=jnp.zeros((n,), jnp.float32),
    )


def _scan_tier(tier: CacheTier, reqs: Request, observe: bool) -> TierResult:
    """Scan one interleaved ``[T, N]`` stream metrics-only: per-tenant
    ``Metrics`` and the running ``k`` sum ride in the carry (no ``[T]``
    StepInfo is ever stacked), mirroring ``Engine.replay``'s
    ``collect_info=False`` path."""
    n = tier.n_tenants
    T = reqs.key.shape[0]

    def body(carry, req):
        st, acc, ksum = carry
        st, info, k = tier.step(st, req)
        acc = _acc_step(acc, req, info)
        return (st, acc, ksum + k.astype(jnp.float32)), (k if observe
                                                         else None)

    carry0 = (tier.init(), _zero_acc_tier(n), jnp.zeros((n,), jnp.float32))
    (_, acc, ksum), ks = jax.lax.scan(body, carry0, reqs)
    return TierResult(metrics=acc, avg_k=ksum / T,
                      obs={"k": ks} if observe else None)


@partial(jax.jit, static_argnames=("tier", "observe", "use_pallas"))
def _replay_tier_single(tier, reqs, observe, use_pallas):
    with pallas_mode(use_pallas):
        return _scan_tier(tier, reqs, observe)


@partial(jax.jit, static_argnames=("tier", "observe", "use_pallas"))
def _replay_tier_batched(tier, reqs, observe, use_pallas):
    with pallas_mode(use_pallas):
        return jax.vmap(lambda r: _scan_tier(tier, r, observe))(reqs)


def replay_tier(tier: CacheTier, requests, *, sizes=None, costs=None,
                observe: bool = False,
                use_pallas=False) -> TierResult:
    """Replay an interleaved multi-tenant request stream through ``tier``.

    ``requests``: a :class:`~repro.core.Request` (or bare keys, with
    ``sizes``/``costs`` broadcast per ``Request.of``) of shape ``[T, N]``
    — at each of the T global steps, one request per tenant — or
    ``[S, T, N]`` to vmap a seed axis of independent streams.  Metrics are
    reduced in the scan carry (per tenant), and each tenant's time-mean
    active size comes back as ``avg_k``; ``observe=True`` additionally
    stacks the per-step occupancy ``obs["k"]`` (``[T, N]``).

    ``use_pallas`` routes each tenant's fused rank step through the Pallas
    policy-step kernel, exactly as in ``Engine.replay``: ``False`` /
    ``"interpret"`` / ``"compiled"`` (or ``True`` for per-backend auto).
    The tenant vmap hits the kernel's lane-grid batching rule; a seed axis
    on top composes through the standard pallas batching rule.
    """
    use_pallas = normalize_pallas_mode(use_pallas)
    reqs = Request.of(requests, sizes, costs)
    if reqs.key.ndim == 2:
        if reqs.key.shape[1] != tier.n_tenants:
            raise ValueError(
                f"requests [T, N] must have N == n_tenants "
                f"({tier.n_tenants}), got {reqs.key.shape}")
        return _replay_tier_single(tier, reqs, observe, use_pallas)
    if reqs.key.ndim == 3:
        if reqs.key.shape[2] != tier.n_tenants:
            raise ValueError(
                f"requests [S, T, N] must have N == n_tenants "
                f"({tier.n_tenants}), got {reqs.key.shape}")
        return _replay_tier_batched(tier, reqs, observe, use_pallas)
    raise ValueError(
        f"requests must be [T, N] or [S, T, N], got shape {reqs.key.shape}")
