"""Capacity arbiters: who gets the shared budget's free slots.

The tier closes the control loop the paper leaves open.  Each tenant's
DynamicAdaptiveClimb instance *signals* — ``jump`` saturating at ``2k`` is
a grow demand, a shrink returns slots — and the arbiter turns those
signals into per-tenant capacity **caps** for the next step.  A cap is the
largest active size the tenant may reach on its next resize check:
``cap == k`` denies growth, ``cap == 2k`` grants the full doubling,
``k < cap < 2k`` is a partial grant under contention.

Arbiters are pure vectorized functions of the post-step tier state::

    caps = arbiter(k, demanding, budget)     # all int32[N] / bool[N]

and must respect the conservation law the tier tests enforce: granted
headroom never exceeds the free pool ``budget - sum(k)``, so
``sum(k) <= budget`` holds at every step no matter which tenants cash
their caps in.

Arbiters are addressed by spec strings through :func:`make_arbiter`,
mirroring ``make_policy`` / ``make_trace``::

    >>> make_arbiter("greedy")
    GreedyArbiter()
    >>> make_arbiter("static(share=64)")
    StaticArbiter(share=64)
"""
from __future__ import annotations

import jax.numpy as jnp

from ..specs import build_kwargs, parse_spec

__all__ = ["Arbiter", "StaticArbiter", "GreedyArbiter",
           "ProportionalArbiter", "AuctionArbiter", "ARBITERS",
           "make_arbiter"]


class Arbiter:
    """Base class: hashable/static (jit-safe as a static argument), one
    ``__call__(k, demanding, budget, n_tenants, utility=None) -> caps``
    method.  ``utility`` (float32[N], optional) is a per-tenant value
    signal — the fleet layer's byte-miss-cost EWMA — that utility-aware
    arbiters price grants by; slot-counting arbiters ignore it.

    ``pooled`` marks arbiters that allocate out of the *shared* free pool
    (grants depend on every tenant's ``k``); the static partitioner is
    the one non-pooled arbiter — each tenant's cap is a pure function of
    its own state.  ``needs_utility`` marks arbiters meaningless without
    the utility signal (the fleet replay carries it; the plain tier does
    not)."""

    name: str = "base"
    pooled: bool = True
    needs_utility: bool = False

    def __call__(self, k, demanding, budget: int, n_tenants: int,
                 utility=None):
        raise NotImplementedError

    # hashability for jit static args (same scheme as core.policy.Policy)
    def _fields(self):
        return tuple(sorted(self.__dict__.items()))

    def __hash__(self):
        return hash((type(self).__name__, self._fields()))

    def __eq__(self, other):
        return type(self) is type(other) and self._fields() == other._fields()

    def __repr__(self):
        args = ", ".join(f"{k}={v}" for k, v in self.__dict__.items())
        return f"{type(self).__name__}({args})"


def _free_pool(k, budget: int):
    """Unclaimed slots: the global budget minus every tenant's active size."""
    return jnp.maximum(budget - jnp.sum(k), 0)


def _demand(k, demanding, budget: int):
    """Requested extra slots per tenant: a saturated tenant wants to double
    (``+k``), bounded by the budget-wide array width."""
    want = jnp.minimum(k, budget - k)
    return jnp.where(demanding, jnp.maximum(want, 0), 0)


class StaticArbiter(Arbiter):
    """No-op baseline: hard partitioning.  Every tenant owns a fixed
    ``share`` (default ``budget // n_tenants``) and the cap reproduces the
    paper's un-arbitrated law *within* that share — grow iff
    ``2k <= share`` — so a static tier is exactly N independent
    DynamicAdaptiveClimb caches with ``K_max = share``.

    >>> import jax.numpy as jnp
    >>> arb = StaticArbiter()
    >>> k = jnp.array([4, 8], jnp.int32)
    >>> demanding = jnp.array([True, True])
    >>> [int(c) for c in arb(k, demanding, budget=16, n_tenants=2)]
    [8, 8]
    """

    name = "static"
    pooled = False

    def __init__(self, share: int = 0):
        self.share = int(share)   # 0 -> budget // n_tenants

    def __call__(self, k, demanding, budget: int, n_tenants: int,
                 utility=None):
        share = self.share or budget // n_tenants
        return jnp.where(2 * k <= share, 2 * k, k).astype(jnp.int32)


class GreedyArbiter(Arbiter):
    """First-come-first-served over the tenant axis: walk tenants in index
    order, grant each demander as much of its doubling as the remaining
    free pool covers (partial at the boundary), vectorized as a cumulative
    sum — no data-dependent Python control flow.

    >>> import jax.numpy as jnp
    >>> arb = GreedyArbiter()
    >>> k = jnp.array([4, 4, 4], jnp.int32)
    >>> demanding = jnp.array([True, True, True])
    >>> # free pool = 18 - 12 = 6: tenant 0 gets +4, tenant 1 the last +2
    >>> [int(c) for c in arb(k, demanding, budget=18, n_tenants=3)]
    [8, 6, 4]
    """

    name = "greedy"

    def __call__(self, k, demanding, budget: int, n_tenants: int,
                 utility=None):
        free = _free_pool(k, budget)
        demand = _demand(k, demanding, budget)
        before = jnp.cumsum(demand) - demand   # pool already spoken for
        grant = jnp.clip(free - before, 0, demand)
        return (k + grant).astype(jnp.int32)


class ProportionalArbiter(Arbiter):
    """Split the free pool among demanders in proportion to their demand
    (floor division — never over-grants), so contention degrades every
    tenant's grant smoothly instead of starving the tail of the index
    order.

    >>> import jax.numpy as jnp
    >>> arb = ProportionalArbiter()
    >>> k = jnp.array([4, 4, 4], jnp.int32)
    >>> demanding = jnp.array([True, True, False])
    >>> # free pool = 16 - 12 = 4 split over 8 demanded: +2 each
    >>> [int(c) for c in arb(k, demanding, budget=16, n_tenants=3)]
    [6, 6, 4]
    """

    name = "proportional"

    def __call__(self, k, demanding, budget: int, n_tenants: int,
                 utility=None):
        free = _free_pool(k, budget)
        demand = _demand(k, demanding, budget)
        total = jnp.sum(demand)
        prop = jnp.where(total > 0, free * demand // jnp.maximum(total, 1), 0)
        grant = jnp.minimum(demand, prop)
        return (k + grant).astype(jnp.int32)


class AuctionArbiter(Arbiter):
    """Price capacity by *value*, not slot counts: each demander bids its
    recent marginal byte-miss cost (``utility`` — the fleet replay's EWMA
    of per-request miss penalty, i.e. byte-miss x fetch cost; see
    :class:`repro.fleet.FleetTier`), and the free pool is split in
    proportion to **utility-weighted demand** — a first-price share
    auction, the cost-aware framing of Einziger et al.'s size-aware
    cache management.  A tenant thrashing on cheap, tiny objects is
    outbid by one missing on expensive fetches even when both demand the
    same slot count.

    Weights are normalized by the max utility among demanders and the
    grant is floored, so the conservation law (granted headroom <= free
    pool) holds exactly.  Two exact degeneracies, locked by tests:

    * **uniform utilities** (all demanders equal, including the all-zero
      cold start and ``utility=None``): weights collapse to raw demand
      and the grants equal :class:`ProportionalArbiter`'s bit-for-bit
      (the float32 floor-division is exact while ``free * demand``
      stays under 2^24 — pools orders of magnitude beyond any budget
      this repo replays);
    * **single demander**: gets ``min(demand, free)`` like every other
      pooled arbiter.

    >>> import jax.numpy as jnp
    >>> arb = AuctionArbiter()
    >>> k = jnp.array([4, 4, 4], jnp.int32)
    >>> demanding = jnp.array([True, True, False])
    >>> u = jnp.array([3.0, 1.0, 0.0])
    >>> # free pool = 16 - 12 = 4; bids 3:1 -> +3 / +1
    >>> [int(c) for c in arb(k, demanding, 16, 3, utility=u)]
    [7, 5, 4]
    >>> [int(c) for c in arb(k, demanding, 16, 3)]    # no signal: prop.
    [6, 6, 4]
    """

    name = "auction"
    needs_utility = True

    def __call__(self, k, demanding, budget: int, n_tenants: int,
                 utility=None):
        free = _free_pool(k, budget)
        demand = _demand(k, demanding, budget)
        if utility is None:
            u = jnp.ones(jnp.shape(demand), jnp.float32)
        else:
            u = jnp.asarray(utility, jnp.float32)
        # normalize by the max bid among demanders; an all-zero market
        # (cold start) degrades to uniform weights == proportional
        umax = jnp.max(jnp.where(demand > 0, u, 0.0))
        u = jnp.where(umax > 0, u / jnp.maximum(umax, 1e-30),
                      jnp.ones_like(u))
        w = demand.astype(jnp.float32) * u
        total = jnp.sum(w)
        share = jnp.where(
            total > 0,
            jnp.floor(free.astype(jnp.float32) * w
                      / jnp.maximum(total, 1e-30)),
            0.0)
        grant = jnp.minimum(demand, share.astype(jnp.int32))
        return (k + grant).astype(jnp.int32)


ARBITERS = {
    "static": StaticArbiter,
    "greedy": GreedyArbiter,
    "proportional": ProportionalArbiter,
    "auction": AuctionArbiter,
}


def make_arbiter(spec) -> Arbiter:
    """Build an arbiter from a spec string — registry name plus optional
    constructor kwargs, coerced exactly like ``make_policy`` /
    ``make_trace`` (see :mod:`repro.specs`).  Arbiter instances pass
    through.

    >>> make_arbiter("proportional")
    ProportionalArbiter()
    >>> make_arbiter("nope")
    Traceback (most recent call last):
        ...
    ValueError: unknown arbiter 'nope'; known: ['auction', 'greedy', 'proportional', 'static']
    """
    if isinstance(spec, Arbiter):
        return spec
    name, argstr = parse_spec(spec)
    if name not in ARBITERS:
        raise ValueError(
            f"unknown arbiter {name!r}; known: {sorted(ARBITERS)}")
    cls = ARBITERS[name]
    return cls(**build_kwargs("arbiter", name, cls.__init__, argstr))
