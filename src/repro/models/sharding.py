"""Logical sharding rules: parameter/activation PartitionSpecs for the
production mesh.

Mesh axes (see launch/mesh.py):
  * ``data``  — FSDP + batch data parallelism (within a pod, fast ICI)
  * ``model`` — tensor / expert / sequence parallelism
  * ``pod``   — pure data parallelism across pods (slow DCN): parameters are
                replicated per pod, gradients all-reduce over it.

Rules are name-based over the param tree.  Every rule degrades gracefully:
if a dimension is not divisible by its target axis size, that dimension is
replicated instead (``_div`` guard) — so the same model code runs on the
1-device CPU test mesh, the 256-chip pod, and the 512-chip two-pod mesh.

Parameters under the scanned period stack carry a leading ``n_periods`` dim
that is never sharded (prepended ``None``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Static sharding context threaded through the model code.

    mode="train": FSDP over 'data' (weights gathered per layer — amortized
    over a big token batch).  mode="serve": weights fully *resident*,
    model-parallel over BOTH axes where divisible — decode moves ~KB of
    activations per layer instead of re-gathering GBs of weights per token.
    """
    mesh: Mesh
    fsdp: str = "data"
    tp: str = "model"
    pod: Optional[str] = None       # set on the multi-pod mesh
    mode: str = "train"             # train | serve

    @property
    def batch_axes(self):
        return (self.pod, self.fsdp) if self.pod else (self.fsdp,)

    def axis_size(self, name) -> int:
        return self.mesh.shape[name]

    def cons(self, x, *spec):
        """with_sharding_constraint against this mesh."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    # activation constraints used by the model ---------------------------
    def act_btd(self, x, seq_tp=True):
        """[B, S, d] between blocks: batch over (pod, data), seq over model
        (sequence parallelism) when the length divides."""
        sp = self.tp if (seq_tp and x.shape[1] % self.axis_size(self.tp) == 0) \
            else None
        b = self.batch_axes if x.shape[0] % self._bsz() == 0 else None
        return self.cons(x, b, sp, None)

    def act_heads(self, x):
        """[B, S, H, hd] inside attention: heads over model."""
        h = self.tp if x.shape[2] % self.axis_size(self.tp) == 0 else None
        b = self.batch_axes if x.shape[0] % self._bsz() == 0 else None
        return self.cons(x, b, None, h, None)

    def ep(self, x):
        """[B, E, C, *] MoE dispatch buffers: experts over model.

        serve mode: batch replicated — decode activations are tiny, and
        batch-sharding them over 'data' would force the expert weights
        (whose d/ff dims own 'data' in serve mode) to be re-gathered every
        step (the cell-C baseline pathology, EXPERIMENTS.md §Perf)."""
        e = self.tp if x.shape[1] % self.axis_size(self.tp) == 0 else None
        if self.mode == "serve":
            return self.cons(x, None, e, *([None] * (x.ndim - 2)))
        b = self.batch_axes if x.shape[0] % self._bsz() == 0 else None
        return self.cons(x, b, e, *([None] * (x.ndim - 2)))

    def _bsz(self):
        n = self.axis_size(self.fsdp)
        if self.pod:
            n *= self.axis_size(self.pod)
        return n


def _div(n, size):
    return n % size == 0


def _serve_rule(name: str, shape: Tuple[int, ...], cfg, sctx: "ShardCtx"):
    """Inference-mode placement: weights fully *resident* (model-parallel
    over both axes where divisible), never FSDP-gathered — a decode step
    moves KBs of activations per layer instead of GBs of weights.
    Returns None to fall through to the train rule (small/neutral leaves).
    """
    tp, fsdp = sctx.tp, sctx.fsdp
    tp_n = sctx.axis_size(tp)
    flat_n = tp_n * sctx.axis_size(fsdp)
    H, Hkv = cfg.n_heads, cfg.n_kv_heads

    def flat_if(dim):
        if dim % flat_n == 0:
            return (tp, fsdp)
        return tp if dim % tp_n == 0 else None

    if len(shape) == 1 or name in ("bq", "bk", "bv", "router"):
        return P(*([None] * len(shape)))
    if name == "embed":
        return P(flat_if(shape[0]), None)
    if name == "lm_head":
        return P(None, flat_if(shape[1]))
    if name == "wq":
        return P(None, tp if _div(H, tp_n) else None, None)
    if name in ("wk", "wv") and len(shape) == 3:
        return P(None, tp if _div(Hkv, tp_n) else None, None)
    if name == "wo" and len(shape) == 3:
        return P(tp if _div(shape[0], tp_n) else None, None, None)
    if name in ("w_kva", "w_qa"):
        return P(None, None)
    if name in ("w_kvb", "w_qb", "w_q"):
        return P(None, tp if _div(H, tp_n) else None, None)
    if name in ("w_gate", "w_up") and len(shape) == 3:    # [E, d, ff]
        if _div(shape[0], tp_n):
            return P(tp, None, fsdp if _div(shape[2],
                                            sctx.axis_size(fsdp)) else None)
        return P(None, None, flat_if(shape[2]))
    if name == "w_down" and len(shape) == 3:              # [E, ff, d]
        if _div(shape[0], tp_n):
            return P(tp, fsdp if _div(shape[1],
                                      sctx.axis_size(fsdp)) else None, None)
        return P(None, flat_if(shape[1]), None)
    if name in ("w_gate", "w_up"):                        # dense [d, ff]
        return P(None, flat_if(shape[1]))
    if name == "w_down":                                  # [ff, d]
        return P(flat_if(shape[0]), None)
    if name in ("in_proj", "up_proj", "W"):
        return P(None, flat_if(shape[1]))
    if name in ("out_proj", "down_proj"):
        return P(flat_if(shape[0]), None)
    return None


def _rule(name: str, shape: Tuple[int, ...], cfg, tp_size: int,
          fsdp: str, tp: str):
    """PartitionSpec for one (unstacked) param leaf, by name + rank."""
    H, Hkv = cfg.n_heads, cfg.n_kv_heads

    def tp_if(dim_ok):
        return tp if dim_ok else None

    if len(shape) == 1:
        return P(None)                                   # norms, biases: tiny
    if name in ("bq", "bk", "bv"):
        return P(None, None)

    if name == "embed":
        return P(tp_if(_div(shape[0], tp_size)), fsdp)
    if name == "lm_head":
        return P(fsdp, tp_if(_div(shape[1], tp_size)))

    # attention ----------------------------------------------------------
    if name == "wq":
        return P(fsdp, tp_if(_div(H, tp_size)), None)
    if name in ("wk", "wv") and len(shape) == 3:
        return P(fsdp, tp_if(_div(Hkv, tp_size)), None)
    if name == "wo" and len(shape) == 3:
        return P(tp_if(_div(shape[0], tp_size)), None, fsdp)

    # MLA ------------------------------------------------------------------
    if name == "w_kva":
        return P(fsdp, None)
    if name == "w_kvb":
        return P(None, tp_if(_div(H, tp_size)), None)
    if name == "w_qa":
        return P(fsdp, None)
    if name in ("w_qb", "w_q"):
        return P(None if name == "w_qb" else fsdp,
                 tp_if(_div(H, tp_size)), None)

    # MoE --------------------------------------------------------------
    if name == "router":
        return P(fsdp, None)
    if name in ("w_gate", "w_up") and len(shape) == 3:   # [E, d, ff]
        if _div(shape[0], tp_size):
            return P(tp, fsdp, None)
        return P(None, fsdp, tp_if(_div(shape[2], tp_size)))
    if name == "w_down" and len(shape) == 3:             # [E, ff, d]
        if _div(shape[0], tp_size):
            return P(tp, None, fsdp)
        return P(None, tp_if(_div(shape[1], tp_size)), fsdp)

    # dense MLP ---------------------------------------------------------
    if name in ("w_gate", "w_up"):                        # [d, ff]
        return P(fsdp, tp_if(_div(shape[1], tp_size)))
    if name == "w_down":                                  # [ff, d]
        return P(tp_if(_div(shape[0], tp_size)), fsdp)

    # mamba / xlstm ------------------------------------------------------
    if name in ("in_proj", "up_proj", "W"):               # [d, k*di]
        return P(fsdp, tp_if(_div(shape[1], tp_size)))
    if name == "conv_w":                                  # [dc, di]
        return P(None, tp_if(_div(shape[1], tp_size)))
    if name in ("x_proj", "out_proj", "down_proj"):       # [di, *]
        return P(tp_if(_div(shape[0], tp_size)), fsdp
                 if name != "x_proj" else None)
    if name == "dt_w":                                    # [dtr, di]
        return P(None, tp_if(_div(shape[1], tp_size)))
    if name == "A_log":                                   # [di, ds]
        return P(tp_if(_div(shape[0], tp_size)), None)
    if name in ("wq2", "wk2", "wv2"):                     # mlstm [di, di]
        return P(fsdp, tp_if(_div(shape[1], tp_size)))
    if name in ("w_i", "w_f"):                            # [di, H]
        return P(fsdp, None)
    if name == "R":                                       # slstm [H, dh, 4dh]
        return P(None, None, None)

    # default: replicate (correct, never wrong, maybe slow — rules above
    # should cover every large tensor)
    return P(*([None] * len(shape)))


def param_specs(params, cfg, sctx: ShardCtx):
    """Pytree of PartitionSpec matching `params` (period stack handled)."""
    tp_size = sctx.axis_size(sctx.tp)

    def visit(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        keys = [k for k in keys if isinstance(k, str)]
        stacked = "layers" in keys
        # leaf name = last non-structural key ("scale" folds into its norm)
        name = keys[-1] if keys[-1] != "scale" else keys[-2]
        shape = leaf.shape[1:] if stacked else leaf.shape
        # mlstm q/k/v are square [di,di]; disambiguate from attention wq
        if name in ("wq", "wk", "wv") and len(shape) == 2:
            name = name[0:2] + "2"
        spec = None
        if sctx.mode == "serve":
            spec = _serve_rule(name, shape, cfg, sctx)
        if spec is None:
            spec = _rule(name, shape, cfg, tp_size, sctx.fsdp, sctx.tp)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(visit, params)


def shardings(params, cfg, sctx: ShardCtx):
    """NamedShardings for params (device placement / in_shardings)."""
    return jax.tree.map(lambda s: NamedSharding(sctx.mesh, s),
                        param_specs(params, cfg, sctx))
