"""Architecture configuration.

An architecture is a *periodic* stack: ``period`` is a tuple of LayerSpec
describing one repeating group of layers (most archs have period length 1;
gemma2 alternates local/global attention with period 2; jamba repeats an
8-layer mamba/attention group).  The decoder scans over periods with stacked
parameters, so the HLO stays small regardless of depth and remat applies at
period granularity.

All shapes are static; everything in a config must be hashable (configs are
jit static args).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0               # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    router_norm_topk: bool = True   # renormalize gates over the top-k


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model/16)
    chunk: int = 64                 # selective-scan chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    # mLSTM: matrix-memory cell with exponential gating, chunkwise-parallel.
    m_proj_factor: float = 2.0
    m_conv: int = 4
    m_chunk: int = 64
    # sLSTM: scalar-memory cell with hidden-to-gate recurrence (sequential).
    s_proj_factor: float = 1.3333333
    s_conv: int = 4


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period."""
    kind: str                       # attn | mla | mamba | mlstm | slstm
    window: Optional[int] = None    # sliding-window size (attn only)
    moe: bool = False               # FFN is MoE (else dense, unless d_ff==0)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                       # dense-FFN width (0 = block has no FFN)
    vocab: int
    period: Tuple[LayerSpec, ...]

    d_head: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"               # silu | gelu  (gated MLP)
    attn_softcap: float = 0.0       # 0 = off (gemma2: 50)
    final_softcap: float = 0.0      # 0 = off (gemma2: 30)
    tie_embeddings: bool = False

    moe: Optional[MoESpec] = None
    mamba: Optional[MambaSpec] = None
    xlstm: Optional[XLSTMSpec] = None

    # MLA (deepseek-v2) geometry
    kv_lora_rank: int = 0           # >0 enables MLA paths
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # modality frontend stub: model consumes precomputed embeddings
    embeds_input: bool = False

    # numerics / execution
    param_dtype: str = "bfloat16"
    attn_chunk_q: int = 512
    attn_chunk_k: int = 512

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period length {len(self.period)}")
        return self.n_layers // len(self.period)

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def layer_specs(self):
        """All n_layers LayerSpecs in order."""
        return [self.period[i % len(self.period)] for i in range(self.n_layers)]
