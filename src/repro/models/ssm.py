"""Recurrent blocks: Mamba selective SSM (jamba) and xLSTM cells (sLSTM +
mLSTM).

TPU adaptation notes (kernel-layer context in ``docs/ARCHITECTURE.md``):
  * Mamba's CUDA selective-scan kernel fuses the recurrence to avoid
    materializing h[B,S,d_inner,d_state].  The TPU-native equivalent here is
    *chunking*: an outer `lax.scan` over time-chunks carries h[B,di,ds] while
    an inner `associative_scan` parallelizes within the chunk, so the live
    state tensor is [B,chunk,di,ds] and the chunk body is remat-able.
  * mLSTM trains in a chunkwise-parallel form (gated-linear-attention style):
    intra-chunk terms are masked matmuls on the MXU, inter-chunk terms carry
    the (C, n, m) matrix-memory state.  Exponential gating is stabilized in
    log space with a running max `m` exactly as in the xLSTM paper; the
    sequential cell (`mlstm_seq`) is the correctness oracle.
  * sLSTM has hidden-to-gate recurrence, so it is inherently sequential; its
    per-step state is O(d) and the scan body is a few small matmuls.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_apply, mlp_init, rmsnorm, rmsnorm_init


def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x: [B,S,C], w: [dc,C], b: [C]."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(dc))
    return out + b


def conv1d_step(conv_state, x_t, w, b):
    """One decode step.  conv_state: [B,dc-1,C], x_t: [B,C].

    Tap-by-tap sum in the same order as ``causal_conv1d`` so bf16 rounding
    matches the parallel path bit-for-bit (routing decisions downstream are
    rounding-sensitive)."""
    full = jnp.concatenate([conv_state, x_t[:, None]], axis=1)   # [B,dc,C]
    out = sum(full[:, i] * w[i] for i in range(w.shape[0])) + b
    return full[:, 1:], out


# ===========================================================================
# Mamba (selective SSM)
# ===========================================================================

def _mamba_dims(cfg):
    ms = cfg.mamba
    di = ms.expand * cfg.d_model
    dtr = ms.dt_rank or -(-cfg.d_model // 16)
    return ms, di, dtr


def mamba_init(key, cfg, dtype):
    ms, di, dtr = _mamba_dims(cfg)
    d, ds = cfg.d_model, ms.d_state
    ks = jax.random.split(key, 6)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba paper)
    u = jax.random.uniform(ks[0], (di,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))          # inverse softplus
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": dense_init(ks[1], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[2], (ms.d_conv, di), dtype, fan_in=ms.d_conv),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[3], (di, dtr + 2 * ds), dtype, fan_in=di),
        "dt_w": dense_init(ks[4], (dtr, di), dtype, fan_in=dtr),
        "dt_b": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype, fan_in=di),
    }


def _mamba_inner(xc, p, cfg):
    """xc: conv+silu output [B,L,di] -> (dA [B,L,di,ds], dBu, C [B,L,ds])."""
    ms, di, dtr = _mamba_dims(cfg)
    ds = ms.d_state
    dbc = jnp.einsum("bld,de->ble", xc, p["x_proj"]).astype(jnp.float32)
    dt_raw, Bm, Cm = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("blr,rd->bld", dt_raw, p["dt_w"].astype(jnp.float32))
                         + p["dt_b"])               # [B,L,di]
    A = -jnp.exp(p["A_log"])                         # [di,ds]
    dA = jnp.exp(dt[..., None] * A)                  # [B,L,di,ds]
    dBu = (dt * xc.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    return dA, dBu, Cm


def _scan_chunk(h0, dA, dBu):
    """Parallel intra-chunk recurrence h_t = dA_t h_{t-1} + dBu_t.

    h0: [B,di,ds]; dA/dBu: [B,L,di,ds].  Returns (h_all [B,L,di,ds], h_L).
    """
    def op(a, b):
        return (a[0] * b[0], a[1] * b[0] + b[1])
    pA, pB = jax.lax.associative_scan(op, (dA, dBu), axis=1)
    h_all = pA * h0[:, None] + pB
    return h_all, h_all[:, -1]


def mamba_apply(x, p, cfg, return_state=False):
    """Training/prefill pass.  x: [B,S,d] -> [B,S,d] (+ decode state)."""
    B, S, d = x.shape
    ms, di, _ = _mamba_dims(cfg)
    chunk = min(ms.chunk, S)
    while S % chunk:                 # largest divisor <= configured chunk
        chunk -= 1
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(xin, p["conv_w"], p["conv_b"]))

    nck = S // chunk
    xc_c = xc.reshape(B, nck, chunk, di).transpose(1, 0, 2, 3)

    def body(h, xck):
        dA, dBu, Cm = _mamba_inner(xck, p, cfg)
        h_all, h_new = _scan_chunk(h, dA, dBu)
        y = jnp.einsum("blds,bls->bld", h_all, Cm)
        y = y + p["D"] * xck.astype(jnp.float32)
        return h_new, y

    h0 = jnp.zeros((B, di, ms.d_state), jnp.float32)
    h_fin, ys = jax.lax.scan(jax.remat(body), h0, xc_c)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    if return_state:
        tail = xin[:, S - (ms.d_conv - 1):] if S >= ms.d_conv - 1 else \
            jnp.pad(xin, ((0, 0), (ms.d_conv - 1 - S, 0), (0, 0)))
        return out, {"conv": tail, "h": h_fin}
    return out


def mamba_state_init(cfg, B, dtype):
    ms, di, _ = _mamba_dims(cfg)
    return {
        "conv": jnp.zeros((B, ms.d_conv - 1, di), dtype),
        "h": jnp.zeros((B, di, ms.d_state), jnp.float32),
    }


def mamba_decode_step(x_t, p, cfg, state):
    """x_t: [B,d] -> ([B,d], new state)."""
    xz = jnp.einsum("bd,de->be", x_t, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state, xc = conv1d_step(state["conv"], xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dA, dBu, Cm = _mamba_inner(xc[:, None], p, cfg)
    h = state["h"] * dA[:, 0] + dBu[:, 0]
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0]) + p["D"] * xc.astype(jnp.float32)
    y = y.astype(x_t.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bd,de->be", y, p["out_proj"])
    return out, {"conv": conv_state, "h": h}


# ===========================================================================
# mLSTM (xLSTM matrix-memory cell)
# ===========================================================================

def mlstm_init(key, cfg, dtype):
    xs = cfg.xlstm
    d = cfg.d_model
    di = int(xs.m_proj_factor * d)
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (xs.m_conv, di), dtype, fan_in=xs.m_conv),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], (di, di), dtype),
        "wk": dense_init(ks[3], (di, di), dtype),
        "wv": dense_init(ks[4], (di, di), dtype),
        "w_i": dense_init(ks[5], (di, H), jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(ks[6], (di, H), jnp.float32),
        # forget bias init positive => gates start mostly-remember
        "b_f": jnp.full((H,), 3.0, jnp.float32),
        "skip": jnp.ones((di,), dtype),
        "gn": rmsnorm_init(di, dtype),
        "down_proj": dense_init(ks[7], (di, d), dtype, fan_in=di),
    }


def _mlstm_qkvif(xc, xv, p, H):
    """Project conv output / value path to per-head q,k,v and gate preacts."""
    B, L, di = xc.shape
    dh = di // H
    q = jnp.einsum("bld,de->ble", xc, p["wq"]).reshape(B, L, H, dh)
    k = jnp.einsum("bld,de->ble", xc, p["wk"]).reshape(B, L, H, dh)
    v = jnp.einsum("bld,de->ble", xv, p["wv"]).reshape(B, L, H, dh)
    xf = xc.astype(jnp.float32)
    i_pre = jnp.einsum("bld,dh->blh", xf, p["w_i"]) + p["b_i"]   # [B,L,H]
    f_pre = jnp.einsum("bld,dh->blh", xf, p["w_f"]) + p["b_f"]
    return q, k, v, i_pre, f_pre


def mlstm_cell_chunked(q, k, v, i_pre, f_pre, C0, n0, m0, chunk):
    """Chunkwise-parallel stabilized mLSTM cell.

    q,k,v: [B,S,H,dh]; i_pre,f_pre: [B,S,H]; carries C0 [B,H,dh,dh] (kv^T),
    n0 [B,H,dh], m0 [B,H].  Returns (h [B,S,H,dh], C, n, m).
    """
    B, S, H, dh = q.shape
    chunk = min(chunk, S)
    while S % chunk:                 # largest divisor <= configured chunk
        chunk -= 1
    L, N = chunk, S // chunk
    scale = 1.0 / math.sqrt(dh)

    def body(carry, blk):
        C, n, m = carry
        qb, kb, vb, ib, fb = blk                        # [B,L,H,*]/[B,L,H]
        logf = jax.nn.log_sigmoid(fb)                    # [B,L,H]
        b = jnp.cumsum(logf, axis=1)                     # inclusive cumsum
        a = ib - b                                       # [B,L,H]
        M = jax.lax.cummax(a, axis=1)                    # running max of a
        m_i = b + jnp.maximum(m[:, None], M)             # [B,L,H]
        # intra-chunk decay matrix D[i,j] = exp(a_j + b_i - m_i), j <= i
        Dlog = a[:, None, :, :] + b[:, :, None, :] - m_i[:, :, None, :]
        mask = jnp.tril(jnp.ones((L, L), bool))
        Dm = jnp.where(mask[None, :, :, None], jnp.exp(Dlog), 0.0)  # [B,i,j,H]
        qf = q_ = qb.astype(jnp.float32) * scale
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        S_ij = jnp.einsum("bihd,bjhd->bijh", qf, kf) * Dm
        h_intra = jnp.einsum("bijh,bjhd->bihd", S_ij, vf)
        n_intra = jnp.einsum("bijh,bjhd->bihd", Dm, kf)
        # inter-chunk: carry decays by exp(b_i + m_prev - m_i)
        dec = jnp.exp(b + m[:, None] - m_i)              # [B,L,H]
        h_inter = jnp.einsum("bihd,bhde->bihe", qf, C) * dec[..., None]
        n_inter = n[:, None] * dec[..., None]            # [B,L,H,dh]
        n_all = n_intra + n_inter
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bihd,bihd->bih", q_, n_all)),
            jnp.exp(-m_i))
        h = (h_intra + h_inter) / denom[..., None]
        # carry update to chunk end (position L-1)
        G = b[:, -1]                                     # [B,H]
        m_new = m_i[:, -1]
        w_j = jnp.exp(ib + (G[:, None] - b) - m_new[:, None])  # [B,L,H]
        C_new = (C * jnp.exp(G + m - m_new)[..., None, None]
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", w_j, kf, vf))
        n_new = (n * jnp.exp(G + m - m_new)[..., None]
                 + jnp.einsum("bjh,bjhd->bhd", w_j, kf))
        return (C_new, n_new, m_new), h

    blocks = [t.reshape(B, N, L, *t.shape[2:]).transpose(
        1, 0, 2, *range(3, t.ndim + 1)) for t in (q, k, v, i_pre, f_pre)]
    (C, n, m), hs = jax.lax.scan(jax.remat(body), (C0, n0, m0), tuple(blocks))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
    return h.astype(q.dtype), C, n, m


def mlstm_seq(q, k, v, i_pre, f_pre, C0, n0, m0):
    """Sequential oracle for the chunked cell (identical math, step by step)."""
    B, S, H, dh = q.shape
    scale = 1.0 / math.sqrt(dh)

    def body(carry, t):
        C, n, m = carry
        qt = q[:, t].astype(jnp.float32) * scale
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        logf = jax.nn.log_sigmoid(f_pre[:, t])
        m_new = jnp.maximum(logf + m, i_pre[:, t])
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(i_pre[:, t] - m_new)
        C = C * fp[..., None, None] + ip[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = n * fp[..., None] + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), jnp.arange(S))
    return hs.transpose(1, 0, 2, 3).astype(q.dtype), C, n, m


def mlstm_apply(x, p, cfg, return_state=False):
    """mLSTM block (post-up-projection): x [B,S,d] -> [B,S,d] (+ state)."""
    B, S, d = x.shape
    xs = cfg.xlstm
    H = cfg.n_heads
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(xin, p["conv_w"], p["conv_b"]))
    q, k, v, i_pre, f_pre = _mlstm_qkvif(xc, xin, p, H)
    di = xc.shape[-1]
    C0 = jnp.zeros((B, H, di // H, di // H), jnp.float32)
    n0 = jnp.zeros((B, H, di // H), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    h, C, n, m = mlstm_cell_chunked(q, k, v, i_pre, f_pre, C0, n0, m0,
                                    min(xs.m_chunk, S))
    h = h.reshape(B, S, di)
    h = rmsnorm(h, p["gn"], cfg.norm_eps) + p["skip"] * xc
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", h, p["down_proj"])
    if return_state:
        dc = xs.m_conv - 1
        tail = xin[:, S - dc:] if S >= dc else \
            jnp.pad(xin, ((0, 0), (dc - S, 0), (0, 0)))
        return out, {"conv": tail, "C": C, "n": n, "m": m}
    return out


def mlstm_state_init(cfg, B, dtype):
    xs = cfg.xlstm
    di = int(xs.m_proj_factor * cfg.d_model)
    H = cfg.n_heads
    dh = di // H
    return {
        "conv": jnp.zeros((B, xs.m_conv - 1, di), dtype),
        "C": jnp.zeros((B, H, dh, dh), jnp.float32),
        "n": jnp.zeros((B, H, dh), jnp.float32),
        "m": jnp.zeros((B, H), jnp.float32),
    }


def mlstm_decode_step(x_t, p, cfg, state):
    B, d = x_t.shape
    H = cfg.n_heads
    xz = jnp.einsum("bd,de->be", x_t, p["up_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state, xc = conv1d_step(state["conv"], xin, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    q, k, v, i_pre, f_pre = _mlstm_qkvif(xc[:, None], xin[:, None], p, H)
    h, C, n, m = mlstm_seq(q, k, v, i_pre, f_pre,
                           state["C"], state["n"], state["m"])
    di = xc.shape[-1]
    h = h.reshape(B, di)
    h = rmsnorm(h, p["gn"], cfg.norm_eps) + p["skip"] * xc
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bd,de->be", h, p["down_proj"])
    return out, {"conv": conv_state, "C": C, "n": n, "m": m}


# ===========================================================================
# sLSTM (xLSTM scalar-memory cell; sequential by construction)
# ===========================================================================

def slstm_init(key, cfg, dtype):
    xs = cfg.xlstm
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    df = int(xs.s_proj_factor * d)
    df = -(-df // 8) * 8
    ks = jax.random.split(key, 5)
    return {
        "conv_w": dense_init(ks[0], (xs.s_conv, d), dtype, fan_in=xs.s_conv),
        "conv_b": jnp.zeros((d,), dtype),
        # input weights for (z, i, f, o) and block-diag recurrent weights
        "W": dense_init(ks[1], (d, 4 * d), dtype),
        "R": dense_init(ks[2], (H, dh, 4 * dh), jnp.float32, fan_in=dh),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.full((d,), 3.0, jnp.float32),
                              jnp.zeros((d,), jnp.float32)]),
        "gn": rmsnorm_init(d, dtype),
        "ffn": mlp_init(ks[3], d, df, dtype),
        "ffn_norm": rmsnorm_init(d, dtype),
    }


def _slstm_cell(Wx_t, h_prev, c_prev, n_prev, m_prev, R, H):
    """One sLSTM step.  Wx_t: [B,4d] precomputed input part; states [B,d]."""
    B, d4 = Wx_t.shape
    d = d4 // 4
    dh = d // H
    hh = h_prev.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, R).reshape(B, 4 * d)
    pre = Wx_t + rec
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m_prev, i_pre)
    ip = jnp.exp(i_pre - m_new)
    fp = jnp.exp(logf + m_prev - m_new)
    c = fp * c_prev + ip * z
    n = fp * n_prev + ip
    h = o * c / jnp.maximum(n, 1e-6)
    return h, c, n, m_new


def slstm_apply(x, p, cfg, return_state=False):
    """sLSTM block: conv -> cell scan -> groupnorm -> gated FFN."""
    B, S, d = x.shape
    H = cfg.n_heads
    xs = cfg.xlstm
    xc = jax.nn.silu(causal_conv1d(x, p["conv_w"], p["conv_b"]))
    Wx = (jnp.einsum("bsd,de->bse", xc, p["W"]).astype(jnp.float32)
          + p["b"])                                       # [B,S,4d]
    R = p["R"]

    def body(carry, wx_t):
        h, c, n, m = carry
        h, c, n, m = _slstm_cell(wx_t, h, c, n, m, R, H)
        return (h, c, n, m), h

    z0 = jnp.zeros((B, d), jnp.float32)
    (hf, cf, nf, mf), hs = jax.lax.scan(body, (z0, z0, z0, z0),
                                        Wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    h = rmsnorm(h, p["gn"], cfg.norm_eps)
    out = x + h                                           # cell residual
    ff = mlp_apply(rmsnorm(out, p["ffn_norm"], cfg.norm_eps), p["ffn"],
                   act="gelu")
    y = out + ff - x   # block wrapper adds x back (model adds residual)
    if return_state:
        dc = xs.s_conv - 1
        tail = x[:, S - dc:] if S >= dc else \
            jnp.pad(x, ((0, 0), (dc - S, 0), (0, 0)))
        return y, {"conv": tail, "h": hf, "c": cf, "n": nf, "m": mf}
    return y


def slstm_state_init(cfg, B, dtype):
    d = cfg.d_model
    xs = cfg.xlstm
    z = jnp.zeros((B, d), jnp.float32)
    return {"conv": jnp.zeros((B, xs.s_conv - 1, d), dtype),
            "h": z, "c": z, "n": z, "m": z}


def slstm_decode_step(x_t, p, cfg, state):
    B, d = x_t.shape
    H = cfg.n_heads
    conv_state, xc = conv1d_step(state["conv"], x_t, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    Wx = jnp.einsum("bd,de->be", xc, p["W"]).astype(jnp.float32) + p["b"]
    h, c, n, m = _slstm_cell(Wx, state["h"], state["c"], state["n"],
                             state["m"], p["R"], H)
    hn = rmsnorm(h.astype(x_t.dtype), p["gn"], cfg.norm_eps)
    out = x_t + hn
    ff = mlp_apply(rmsnorm(out[:, None], p["ffn_norm"], cfg.norm_eps),
                   p["ffn"], act="gelu")[:, 0]
    new_state = {"conv": conv_state, "h": h, "c": c, "n": n, "m": m}
    return out + ff - x_t, new_state
