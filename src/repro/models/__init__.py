"""LM substrate: composable decoder covering dense / MoE / MLA / SSM /
hybrid architectures, with sharding rules for the production mesh."""
from .config import ArchConfig, LayerSpec, MambaSpec, MoESpec, XLSTMSpec
from .model import (forward, init_params, init_params_shape, lm_loss,
                    param_count)
from .sharding import ShardCtx, param_specs, shardings

__all__ = [
    "ArchConfig", "LayerSpec", "MambaSpec", "MoESpec", "XLSTMSpec",
    "forward", "init_params", "init_params_shape", "lm_loss", "param_count",
    "ShardCtx", "param_specs", "shardings",
]
