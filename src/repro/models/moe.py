"""Mixture-of-Experts FFN with grouped capacity dispatch (GShard-style).

Tokens are grouped per sequence ([B] is the dispatch group dim), and each
group scatters its tokens into a dense per-expert buffer ``[B, E, C, d]``
followed by one *batched* expert matmul.  Keeping the group dim leading means
every dispatch-side op is batched over B — which stays sharded over the data
axis — while the expert dim shards over the mesh 'model' axis (expert
parallelism).  XLA inserts the EP collectives from sharding constraints
alone; the §Perf hillclimb replaces them with an explicit shard_map
all-to-all where the auto-SPMD choice is wasteful.

Tokens beyond an expert's per-group capacity C = ceil(S*k/E * cf) are
dropped (classic capacity-factor dropping); the residual stream carries them
unchanged.  DeepSeek-style shared experts are a dense gated MLP of width
``n_shared * d_ff_expert``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, mlp_apply, mlp_init


def moe_init(key, cfg, dtype):
    d = cfg.d_model
    m = cfg.moe
    E, ff = m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, ff), dtype),
        "w_up": dense_init(ks[2], (E, d, ff), dtype),
        "w_down": dense_init(ks[3], (E, ff, d), dtype, fan_in=ff),
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks[4], d, m.n_shared * ff, dtype)
    return p


def capacity(group_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(group_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 lanes


def route(x, router_w, cfg):
    """Router.  x: [B,S,d] -> (idx [B,S,k], gates [B,S,k], probs [B,S,E])."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    if m.router_norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return idx, gates, probs


def moe_apply(x, p, cfg, ep_constraint=None):
    """x: [B, S, d] -> [B, S, d].

    ep_constraint: optional fn applied to the [B, E, C, *] dispatch buffers
    to pin them to the expert-parallel sharding (supplied by the model
    wrapper; identity when running unsharded).

    Decode (S == 1): the whole batch is one dispatch group — per-sequence
    capacity would allocate E x C_min rows per token (~200x waste for
    top-6-of-160); batch-grouping shrinks the dispatch/combine buffers and
    their collectives by the same factor (§Perf cell C, iteration 2).
    """
    B, S, d = x.shape
    if S == 1 and B > 1:
        out = moe_apply(x.reshape(1, B, d), p, cfg,
                        ep_constraint=ep_constraint)
        return out.reshape(B, 1, d)
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    C = capacity(S, cfg)

    idx, gates, _ = route(x, p["router"], cfg)            # [B,S,k]

    # arrival-order position of each (token, choice) within its expert,
    # computed per group
    oh = jax.nn.one_hot(idx.reshape(B, S * k), E, dtype=jnp.int32)
    pos_excl = jnp.cumsum(oh, axis=1) - oh                # [B, S*k, E]
    pos = (pos_excl * oh).sum(-1)                         # [B, S*k]
    keep = pos < C
    e_flat = idx.reshape(B, S * k)
    slot = e_flat * C + jnp.minimum(pos, C - 1)           # [B, S*k]

    # dispatch: batched scatter-add of (duplicated) tokens into [B, E*C, d]
    src = jnp.repeat(x, k, axis=1) * keep[..., None].astype(x.dtype)

    def scatter_one(src_b, slot_b):
        return jnp.zeros((E * C, d), x.dtype).at[slot_b].add(src_b, mode="drop")

    xe = jax.vmap(scatter_one)(src, slot).reshape(B, E, C, d)
    if ep_constraint is not None:
        xe = ep_constraint(xe)

    # batched expert MLP (B and E are pure batch dims)
    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    a = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
    ye = jnp.einsum("becf,efd->becd", a * u, p["w_down"])
    if ep_constraint is not None:
        ye = ep_constraint(ye)

    # combine: batched gather of each (token, choice) row, weighted
    yf = jax.vmap(lambda ye_b, sl: ye_b.reshape(E * C, d)[sl])(ye, slot)
    w = (gates.reshape(B, S * k) * keep).astype(jnp.float32)
    out = (yf.astype(jnp.float32) * w[..., None]).reshape(B, S, k, d).sum(2)
    out = out.astype(x.dtype)

    if m.n_shared:
        out = out + mlp_apply(x, p["shared"], cfg.act)
    return out


def aux_load_balance_loss(x, router_w, cfg):
    """Switch-style load-balance auxiliary loss (sum_e f_e * P_e * E)."""
    m = cfg.moe
    idx, _, probs = route(x, router_w, cfg)
    frac = jnp.mean(
        jax.nn.one_hot(idx[..., 0], m.n_experts, dtype=jnp.float32),
        axis=(0, 1))
    return jnp.sum(frac * probs.mean((0, 1))) * m.n_experts
