"""Composable decoder: scan-over-periods, remat, sharding constraints.

``init_params(cfg, key)`` builds the parameter pytree; ``forward`` runs the
stack for training (logits) or prefill (logits + per-layer caches).  Layer
parameters are stacked along a leading ``n_periods`` axis and the stack is
applied with ``lax.scan`` so the HLO size is independent of depth; the scan
body is wrapped in ``jax.checkpoint`` with a selectable remat policy.

Single-token decode lives in ``repro.serving.serve_step`` (it scans the same
stacked params with per-period recurrent/KV state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm
from .config import ArchConfig, LayerSpec
from .layers import (attn_apply, attn_init, attn_qkv, dense_init, embed_init,
                     mlp_apply, mlp_init, rmsnorm, rmsnorm_init)

REMAT_POLICIES = {
    "none": None,                                   # no remat
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ArchConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 4)
    p = {}
    if spec.kind == "attn":
        p["attn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["attn"] = attn_init(ks[0], cfg, dtype)
    elif spec.kind == "mla":
        p["attn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype)
    elif spec.kind == "mamba":
        p["attn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["mamba"] = ssm.mamba_init(ks[0], cfg, dtype)
    elif spec.kind == "mlstm":
        p["attn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["mlstm"] = ssm.mlstm_init(ks[0], cfg, dtype)
    elif spec.kind == "slstm":
        p["attn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["slstm"] = ssm.slstm_init(ks[0], cfg, dtype)
    else:  # pragma: no cover
        raise ValueError(spec.kind)

    if spec.kind in ("attn", "mla", "mamba"):
        if spec.moe and cfg.moe:
            p["ffn_norm"] = rmsnorm_init(cfg.d_model, dtype)
            p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
        elif cfg.d_ff:
            p["ffn_norm"] = rmsnorm_init(cfg.d_model, dtype)
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = cfg.dtype
    k_embed, k_layers, k_head = jax.random.split(key, 3)

    def period_init(k):
        pks = jax.random.split(k, len(cfg.period))
        return {f"l{i}": _layer_init(pks[i], cfg, spec, dtype)
                for i, spec in enumerate(cfg.period)}

    layer_keys = jax.random.split(k_layers, cfg.n_periods)
    params = {
        "embed": embed_init(k_embed, (cfg.vocab, cfg.d_model), dtype),
        "layers": jax.vmap(period_init)(layer_keys),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab),
                                       dtype)
    return params


def init_params_shape(cfg: ArchConfig):
    """Shape-only init (eval_shape) — no allocation, for the dry-run."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    """Exact parameter count from shape-only init.

    active_only: subtract the routed-expert parameters a token does NOT
    touch (MoE), giving N_active for the 6·N_active·D roofline bookkeeping.
    """
    import numpy as np
    shapes = init_params_shape(cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        per_layer = 3 * cfg.d_model * m.d_ff_expert * (m.n_experts - m.top_k)
        n_moe = sum(1 for s in cfg.layer_specs() if s.moe)
        n -= per_layer * n_moe
    return n


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_layer(x, p, cfg, spec: LayerSpec, positions, sctx, impl,
                 want_cache):
    """One layer; returns (x, cache_pytree)."""
    cache = {}
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    if spec.kind == "attn":
        if want_cache:
            q, k, v = attn_qkv(h, p["attn"], cfg, positions)
            if sctx is not None:
                q, k, v = sctx.act_heads(q), sctx.act_heads(k), sctx.act_heads(v)
            from .layers import chunked_attention
            o = chunked_attention(q, k, v, causal=True, window=spec.window,
                                  softcap=cfg.attn_softcap,
                                  chunk_q=cfg.attn_chunk_q,
                                  chunk_k=cfg.attn_chunk_k)
            att = jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
            cache = {"k": k, "v": v}
        else:
            att = attn_apply(h, p["attn"], cfg, spec, positions, impl=impl)
        x = x + att
    elif spec.kind == "mla":
        if want_cache:
            latent, krope = mla_mod.mla_latent(h, p["attn"], cfg, positions)
            cache = {"latent": latent, "krope": krope[:, :, 0]}
        x = x + mla_mod.mla_apply(h, p["attn"], cfg, positions)
    elif spec.kind == "mamba":
        out = ssm.mamba_apply(h, p["mamba"], cfg, return_state=want_cache)
        out, cache = out if want_cache else (out, {})
        x = x + out
    elif spec.kind == "mlstm":
        out = ssm.mlstm_apply(h, p["mlstm"], cfg, return_state=want_cache)
        out, cache = out if want_cache else (out, {})
        x = x + out
    elif spec.kind == "slstm":
        out = ssm.slstm_apply(h, p["slstm"], cfg, return_state=want_cache)
        out, cache = out if want_cache else (out, {})
        x = x + out

    if "moe" in p:
        h = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        ep = sctx.ep if sctx is not None else None
        x = x + moe_mod.moe_apply(h, p["moe"], cfg, ep_constraint=ep)
    elif "mlp" in p:
        h = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        x = x + mlp_apply(h, p["mlp"], cfg.act)
    if sctx is not None:
        x = sctx.act_btd(x)
    return x, cache


def forward(params, cfg: ArchConfig, tokens=None, embeds=None, sctx=None,
            impl="jnp", remat="full", want_cache=False, positions=None,
            last_only=False):
    """Run the decoder.

    tokens: [B, S] int32 (or embeds: [B, S, d] for stub-frontend archs).
    Returns logits [B, S, V] (f32) and, if want_cache, the per-period cache
    pytree (leading dim n_periods).  last_only=True computes logits for the
    final position only (prefill: avoids materializing [B, S, V]).
    """
    if cfg.embeds_input:
        assert embeds is not None, f"{cfg.name} takes precomputed embeddings"
        x = embeds.astype(cfg.dtype)
    else:
        x = params["embed"][tokens]
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
    if sctx is not None:
        x = sctx.act_btd(x)

    def body(x, period_params):
        caches = {}
        for i, spec in enumerate(cfg.period):
            x, c = _apply_layer(x, period_params[f"l{i}"], cfg, spec,
                                positions, sctx, impl, want_cache)
            caches[f"l{i}"] = c
        return x, caches

    policy = REMAT_POLICIES[remat]
    if remat != "none":
        body = jax.checkpoint(body, policy=policy)
    x, caches = jax.lax.scan(body, x, params["layers"])

    if last_only:
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    if sctx is not None:
        tp = sctx.tp if cfg.vocab % sctx.axis_size(sctx.tp) == 0 else None
        logits = sctx.cons(logits, sctx.batch_axes, None, tp)
    return (logits, caches) if want_cache else logits


def lm_loss(params, cfg, batch, sctx=None, impl="jnp", remat="full"):
    """Next-token cross-entropy.  batch: {tokens or embeds, labels, mask?}."""
    logits = forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"), sctx=sctx, impl=impl,
                     remat=remat)
    labels = batch["labels"]
    # label log-prob via masked reduction (NOT take_along_axis: gathering
    # along the vocab axis would force an all-gather of the vocab-sharded
    # logits; the iota-compare/select/reduce partitions cleanly)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_logit = jnp.sum(
        jnp.where(v_iota == labels[..., None], logits, 0.0), axis=-1)
    ll = label_logit - lse
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.moe is not None:
        # aux load-balance loss on the first MoE layer's router of each period
        aux = 0.0
        n = 0
        x = (batch["embeds"].astype(cfg.dtype) if cfg.embeds_input
             else params["embed"][batch["tokens"]])
        for i, spec in enumerate(cfg.period):
            if spec.moe:
                router0 = jax.tree.map(lambda a: a[0],
                                       params["layers"][f"l{i}"]["moe"]["router"])
                aux = aux + moe_mod.aux_load_balance_loss(x, router0, cfg)
                n += 1
        if n:
            loss = loss + 0.01 * aux / n
    return loss
