"""Core transformer layers, pure JAX (no flax).

Parameters are plain nested dicts of jnp arrays.  All inits take an explicit
PRNG key and a dtype.  Attention is a chunked (flash-style) online-softmax
implementation: O(chunk_q x chunk_k) live scores instead of O(S^2), which is
what makes the 32k prefill cells compilable and memory-sane; sliding-window
layers restrict the kv range per q-chunk with dynamic slices so banded
attention costs O(S x W) FLOPs, not O(S^2).

The Pallas flash kernel in ``repro.kernels.flash_attention`` is the TPU
drop-in for `chunked_attention` (selected with ``impl='pallas'``); this jnp
path is also its correctness oracle.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(x, p, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x, positions, theta):
    """x: [..., S, H, D] (D even), positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                   # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash-style attention (jnp oracle / CPU path)
# ---------------------------------------------------------------------------

def _softcap(scores, cap):
    if cap:
        return jnp.tanh(scores / cap) * cap
    return scores


def attention_dense(q, k, v, *, causal=True, window=None, softcap=0.0,
                    q_offset=0, scale=None):
    """Reference O(S^2) attention.  q:[B,Sq,H,D] k:[B,Sk,Hkv,D] v:[B,Sk,Hkv,Dv]."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, g, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf * scale, k.astype(jnp.float32))
    s = _softcap(s, softcap)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dv).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                      chunk_q=512, chunk_k=512, scale=None):
    """Flash-style chunked attention with online softmax.

    q: [B, Sq, H, D]; k, v: [B, Sk, Hkv, D]; GQA via H % Hkv == 0.
    Sliding-window layers slice a banded kv range per q-chunk, so the
    compiled FLOPs are O(Sq*W) rather than O(Sq*Sk).
    Assumes self-attention alignment: q token i attends to kv <= i.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    cq = min(chunk_q, Sq)
    while Sq % cq:
        cq -= 1
    nq = Sq // cq

    if window:
        # banded: kv range for q-chunk starting at qs is [qs+cq-band, qs+cq)
        band = min(Sk, ((window + cq + chunk_k - 1) // chunk_k) * chunk_k)
    else:
        band = Sk
    ck = min(chunk_k, band)
    while band % ck:
        ck -= 1
    nk = band // ck

    q = q.reshape(B, nq, cq, H, D).transpose(1, 0, 2, 3, 4)  # [nq, B, ...]

    def q_chunk_body(qi, q_blk):
        qs = qi * cq                                    # chunk start
        base = jnp.maximum(0, qs + cq - band) if window else 0
        acc = jnp.zeros((B, cq, H, Dv), jnp.float32)
        m = jnp.full((B, cq, H), NEG_INF, jnp.float32)
        l = jnp.zeros((B, cq, H), jnp.float32)
        qf = q_blk.astype(jnp.float32) * scale
        qf = qf.reshape(B, cq, Hkv, g, D)

        def kv_body(carry, ki):
            acc, m, l = carry
            ks = base + ki * ck
            k_blk = jax.lax.dynamic_slice_in_dim(k, ks, ck, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ks, ck, 1)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k_blk.astype(jnp.float32))
            s = _softcap(s, softcap)
            qpos = qs + jnp.arange(cq)[:, None]
            kpos = ks + jnp.arange(ck)[None, :]
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, :, None, None], s, NEG_INF)
            s = s.reshape(B, cq, H, ck)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            p = p.reshape(B, cq, Hkv, g, ck)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, v_blk.astype(jnp.float32))
            acc = acc * corr[..., None] + pv.reshape(B, cq, H, Dv)
            return (acc, m_new, l_new), None

        (acc, m, l), _ = jax.lax.scan(kv_body, (acc, m, l), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q_blk.dtype)

    with jax.named_scope("flash_attention_jnp"):
        out = jax.lax.map(lambda args: q_chunk_body(*args),
                          (jnp.arange(nq), q))
        return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dv)


def decode_attention(q, k_cache, v_cache, valid, *, softcap=0.0, scale=None):
    """Single-token decode attention over a KV slot table.

    q: [B, H, D]; k_cache/v_cache: [B, Smax, Hkv, Dv]; valid: [B, Smax]
    bool.  Returns ([B, H, Dv], per-slot attention mass [B, Smax]) — the
    mass is the DAC hit signal, produced in the same pass (no extra HBM
    traffic; the Pallas kernel fuses it the same way).
    """
    B, H, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    with jax.named_scope("decode_attention_jnp"):
        qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
        s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
        s = _softcap(s, softcap)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
        mass = p.reshape(B, H, Smax).mean(axis=1)
        return o.reshape(B, H, Dv).astype(q.dtype), mass


# ---------------------------------------------------------------------------
# attention block (GQA / SWA / softcap)
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, Hkv, hd), dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, Hkv, hd), dtype, fan_in=d),
        "wo": dense_init(ks[3], (H, hd, d), dtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Hkv, hd), dtype)
        p["bv"] = jnp.zeros((Hkv, hd), dtype)
    return p


def attn_qkv(x, p, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(x, p, cfg, spec, positions, impl="jnp"):
    """Full-sequence (training / prefill) attention block body."""
    q, k, v = attn_qkv(x, p, cfg, positions)
    if impl == "pallas":
        from repro.kernels.ops import flash_attention
        o = flash_attention(q, k, v, causal=True, window=spec.window,
                            softcap=cfg.attn_softcap)
    else:
        o = chunked_attention(q, k, v, causal=True, window=spec.window,
                              softcap=cfg.attn_softcap,
                              chunk_q=cfg.attn_chunk_q,
                              chunk_k=cfg.attn_chunk_k)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d, ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d, ff), dtype),
        "w_up": dense_init(ks[1], (d, ff), dtype),
        "w_down": dense_init(ks[2], (ff, d), dtype, fan_in=ff),
    }


def mlp_apply(x, p, act="silu"):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("bsf,fd->bsd", a * u, p["w_down"])
