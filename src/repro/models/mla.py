"""Multi-head Latent Attention (DeepSeek-V2).

KV is compressed into a per-token latent c_kv of rank ``kv_lora_rank`` plus a
single shared rotary key k_rope of dim ``qk_rope_head_dim``; per-head keys and
values are up-projected from the latent.  The decode path caches only
(latent, k_rope) — `(512+64)` floats per token instead of
`2*H*head_dim` — and uses the *absorbed* formulation (q projected into latent
space through w_kb) so decode attention is computed directly against the
latent cache without materializing per-head K/V.

Queries optionally go through a rank-``q_lora_rank`` bottleneck (236B config).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import (NEG_INF, apply_rope, chunked_attention, dense_init,
                     rmsnorm, rmsnorm_init)


def mla_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p = {
        # kv path: x -> [latent r | k_rope dr]
        "w_kva": dense_init(ks[0], (d, r + dr), dtype),
        "kv_norm": rmsnorm_init(r, dtype),
        # latent -> per-head [k_nope dn | v dv]
        "w_kvb": dense_init(ks[1], (r, H, dn + dv), dtype, fan_in=r),
        "wo": dense_init(ks[2], (H, dv, d), dtype, fan_in=H * dv),
    }
    if qr:
        p["w_qa"] = dense_init(ks[3], (d, qr), dtype)
        p["q_norm"] = rmsnorm_init(qr, dtype)
        p["w_qb"] = dense_init(ks[4], (qr, H, dn + dr), dtype, fan_in=qr)
    else:
        p["w_q"] = dense_init(ks[5], (d, H, dn + dr), dtype)
    return p


def _queries(x, p, cfg, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        qa = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_qa"]), p["q_norm"],
                     cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["w_qb"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["w_q"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_latent(x, p, cfg, positions):
    """x -> (latent [B,S,r], k_rope [B,S,1,dr]); this pair is the KV cache."""
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kva = jnp.einsum("bsd,dr->bsr", x, p["w_kva"])
    latent = rmsnorm(kva[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kva[..., None, r:], positions, cfg.rope_theta)
    return latent, k_rope


def mla_apply(x, p, cfg, positions):
    """Full-sequence MLA (training / prefill): materialize per-head K/V and
    run standard chunked attention with the split-softmax-scale trick."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(x, p, cfg, positions)
    latent, k_rope = mla_latent(x, p, cfg, positions)
    kvb = jnp.einsum("bsr,rhk->bshk", latent, p["w_kvb"])
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    # concat nope|rope into one (dn+dr)-dim attention; scale uses full dim
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))],
                        axis=-1)
    scale = 1.0 / math.sqrt(dn + dr)
    o = chunked_attention(q, k, v, causal=True, scale=scale,
                          chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k)
    return jnp.einsum("bshv,hvd->bsd", o, p["wo"])


def mla_attend(x, p, cfg, latent_cache, krope_cache, valid, position):
    """Absorbed-form single-token decode attention.

    x: [B, 1, d] (pre-normed block input); latent_cache: [B, Smax, r];
    krope_cache: [B, Smax, dr]; valid: [B, Smax] bool.  The caller writes
    the new token's (latent, k_rope) — from ``mla_latent`` — into the cache
    *before* attending, so the token sees itself.  Returns (attn_out [B,d],
    per-slot attention mass [B, Smax] — the DAC hit signal).
    """
    B = x.shape[0]
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    positions = jnp.broadcast_to(position[:, None], (B, 1))

    q_nope, q_rope = _queries(x, p, cfg, positions)       # [B,1,H,*]
    w_kb = p["w_kvb"][..., :dn]                           # [r, H, dn]
    w_vb = p["w_kvb"][..., dn:]                           # [r, H, dv]
    # absorb: q_eff[h] = w_kb[:, h] @ q_nope[h]  -> latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_kb)    # [B,1,H,r]
    scale = 1.0 / math.sqrt(dn + dr)
    with jax.named_scope("decode_attention_jnp"):
        s = jnp.einsum("bshr,btr->bhst", q_lat,
                       latent_cache.astype(q_lat.dtype))
        s = s + jnp.einsum("bshk,btk->bhst", q_rope,
                           krope_cache.astype(q_rope.dtype))
        s = (s.astype(jnp.float32) * scale)[:, :, 0]      # [B,H,Smax]
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)                   # [B,H,Smax]
        o_lat = jnp.einsum("bhs,bsr->bhr", pr,
                           latent_cache.astype(jnp.float32))  # [B,H,r]
    o = jnp.einsum("bhr,rhv->bhv", o_lat.astype(x.dtype), w_vb)
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])
    mass = pr.mean(axis=1)                                # [B,Smax]
    return out, mass
