"""Trainer: checkpoint/auto-resume, straggler watchdog, elastic restarts.

Fault-tolerance model (designed for 1000+ nodes, exercised in tests on the
CPU host):
  * **Checkpoint/restart** — atomic keep-k checkpoints (repro.checkpoint);
    the trainer auto-resumes from the newest complete step; a kill at any
    instant loses at most `ckpt_every` steps (test simulates mid-run kill).
  * **Stateless data** — batches derive from (seed, step); replaying after
    restart consumes the identical stream (no iterator state to lose).
  * **Elastic scaling** — checkpoints are mesh-agnostic (numpy leaves);
    ``Trainer.restore_into_mesh`` device_puts them under the *current*
    mesh's shardings, so a job can restart on half the pods (test covers
    8 -> 4 fake devices).
  * **Straggler mitigation** — a step-time EMA watchdog flags outlier steps
    (on real fleets this feeds the reschedule signal); the data pipeline's
    host-indexed batches make dropping/reassigning a slow host's shard a
    counter bump, not a pipeline rewind.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.tokens import TokenPipeline
from repro.models import init_params, shardings
from repro.optim import adamw
from .train_step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    n_microbatches: int = 1
    remat: str = "full"
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    straggler_factor: float = 3.0
    async_ckpt: bool = True


class StragglerWatchdog:
    """EMA step-time monitor; flags steps slower than factor x EMA."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.flagged = 0

    def record(self, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.factor * self.ema
        if slow:
            self.flagged += 1       # real fleet: emit reschedule signal
        else:                       # stragglers don't poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


class Trainer:
    def __init__(self, arch_cfg, opt_cfg: adamw.AdamWConfig,
                 tcfg: TrainConfig, sctx=None):
        self.cfg = arch_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.sctx = sctx
        self.manager = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.pipeline = TokenPipeline(
            arch_cfg.vocab, tcfg.global_batch, tcfg.seq_len, seed=tcfg.seed,
            embed_dim=arch_cfg.d_model if arch_cfg.embeds_input else 0)
        self.watchdog = StragglerWatchdog(tcfg.straggler_factor)

        step_fn = make_train_step(arch_cfg, opt_cfg, sctx=sctx,
                                  n_microbatches=tcfg.n_microbatches,
                                  remat=tcfg.remat)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.history: list[dict] = []

    # -- state ------------------------------------------------------------
    def init_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        if self.sctx is not None:
            sh = shardings(params, self.cfg, self.sctx)
            params = jax.tree.map(jax.device_put, params, sh)
        opt_state = adamw.init(params, self.opt_cfg)
        return params, opt_state

    def restore_into_mesh(self, state):
        """device_put numpy checkpoint leaves under the *current* mesh —
        the elastic-restart entry point (device count may have changed)."""
        params = state["params"]
        opt = state["opt"]
        if self.sctx is not None:
            sh = shardings(params, self.cfg, self.sctx)
            params = jax.tree.map(jax.device_put, params, sh)
            # moments follow their parameter's sharding; scalars replicate
            opt = jax.device_put(opt)
        else:
            params = jax.device_put(params)
            opt = jax.device_put(opt)
        return params, opt

    # -- loop ---------------------------------------------------------------
    def run(self, steps: Optional[int] = None):
        steps = steps if steps is not None else self.tcfg.steps
        start, restored = self.manager.restore()
        if restored is not None:
            params, opt_state = self.restore_into_mesh(restored)
            start = int(start)
        else:
            params, opt_state = self.init_state()
            start = 0

        step = start
        try:
            for step in range(start, steps):
                batch = {k: jax.numpy.asarray(v) for k, v in
                         self.pipeline.batch(step).items()}
                t0 = time.perf_counter()
                params, opt_state, metrics = self._step(params, opt_state,
                                                        batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                slow = self.watchdog.record(dt)
                metrics.update(step=step, dt=dt, straggler=slow)
                self.history.append(metrics)
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    self.manager.save(
                        step + 1, {"params": params, "opt": opt_state},
                        blocking=not self.tcfg.async_ckpt)
        finally:
            # SIGTERM-ish safety net: always leave a resumable snapshot
            self.manager.save(step + 1 if self.history else step,
                              {"params": params, "opt": opt_state},
                              blocking=True)
        self.manager.wait()
        return params, opt_state
