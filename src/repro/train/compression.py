"""Gradient compression for the slow cross-pod (DCN) links: int8 blockwise
quantization with error feedback, synced with an all-gather of the int8
payload instead of an f32 all-reduce.

Why all-gather: XLA gives no control over collective wire format, so the
only way to actually move fewer bytes is to *communicate the int8 tensors
themselves*.  A ring f32 all-reduce moves ~2x4 bytes/element; gathering the
P pods' int8 shards moves (P-1) bytes/element — an ~8x byte reduction at
P=2 (the production mesh), and the dequantize+mean stays local.

Error feedback (Seide et al. / EF-SGD) keeps the quantization *unbiased
over time*: the residual e = g - deq(quant(g)) is carried and added to the
next step's gradient, so long-run drift vanishes; smoke-training curves
match uncompressed training closely (tests assert this).

Use ``ef_allgather_mean`` inside a shard_map whose manual axis is the pod
axis; ``make_pod_sync`` wraps a whole grad pytree.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 256


def _quantize(x):
    flat = x.reshape(-1)
    pad = -(-flat.size // BLOCK) * BLOCK - flat.size
    fb = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(fb), axis=1) / 127.0
    q = jnp.round(fb / jnp.maximum(scale[:, None], 1e-20)).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, shape):
    flat = q.astype(jnp.float32) * scale[:, None]
    return flat.reshape(-1)[: math.prod(shape)].reshape(shape)


def ef_allgather_mean(g, ef, axis_name: str):
    """Compressed mean over `axis_name` with error feedback.

    g, ef: f32 arrays (per-device view inside shard_map).  Returns
    (g_mean, new_ef).  Wire payload: int8 blocks + f32 block scales.
    """
    x = g.astype(jnp.float32) + ef
    q, scale = _quantize(x)
    new_ef = x - _dequantize(q, scale, x.shape)
    qs = jax.lax.all_gather(q, axis_name)            # [P, blocks, BLOCK] int8
    ss = jax.lax.all_gather(scale, axis_name)        # [P, blocks] f32
    n = qs.shape[0]
    summed = jnp.einsum("pbk,pb->bk", qs.astype(jnp.float32), ss)
    mean = (summed / n).reshape(-1)[: math.prod(x.shape)].reshape(x.shape)
    return mean, new_ef


def init_ef(params, n_pods: int):
    """Per-pod error-feedback state: leading dim = pod (each pod carries its
    own residual; stored pod-sharded in the train state)."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
