from .train_step import (init_ef_state, make_compressed_train_step,
                         make_train_step)
from .trainer import StragglerWatchdog, TrainConfig, Trainer

__all__ = ["make_train_step", "make_compressed_train_step", "init_ef_state",
           "Trainer", "TrainConfig", "StragglerWatchdog"]
