"""Training steps: grad-accumulation scan, donation-friendly signature,
optional cross-pod int8 error-feedback gradient compression.

``make_train_step`` builds the plain (single- or multi-pod) step: XLA
inserts every gradient collective from the sharding constraints.

``make_compressed_train_step`` builds the multi-pod variant where the *pod*
axis gradient sync is manual (shard_map, axis_names={'pod'}) and compressed
to int8+error-feedback — the DCN links between pods are ~10x slower than
ICI, so this is where compression pays (see train/compression.py).  Within a
pod, data/model axes stay with the compiler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.model import lm_loss
from repro.optim import adamw
from . import compression


def _shard_map(f, mesh, in_specs, out_specs, manual_axes):
    """Version-portable shard_map: newer jax exposes ``jax.shard_map`` with
    ``axis_names``/``check_vma``; older releases only have the experimental
    one with ``auto``/``check_rep``.  ``manual_axes`` is the set of mesh
    axes handled manually inside ``f`` (the rest stay with the compiler)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual_axes,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - set(manual_axes)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               auto=auto, check_rep=False)


def _accumulated_grads(loss_fn, params, batch, n_micro):
    """Mean loss/grads over n_micro microbatches via lax.scan."""
    if n_micro == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def split(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    mbs = jax.tree.map(split, batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(acc, mb):
        loss_acc, g_acc = acc
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                             g_acc, g)
        return (loss_acc + loss, g_acc), None

    (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), mbs)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, sctx=None,
                    n_microbatches: int = 1, remat: str = "full",
                    impl: str = "jnp"):
    """Plain train step: (params, opt_state, batch) -> (params, opt_state,
    metrics).  Collectives from sharding constraints only."""

    def loss_fn(p, mb):
        return lm_loss(p, cfg, mb, sctx=sctx, impl=impl, remat=remat)

    def train_step(params, opt_state, batch):
        loss, grads = _accumulated_grads(loss_fn, params, batch,
                                         n_microbatches)
        params, opt_state, stats = adamw.update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def make_compressed_train_step(cfg, opt_cfg: adamw.AdamWConfig, mesh,
                               sctx=None, n_microbatches: int = 1,
                               remat: str = "full", pod_axis: str = "pod"):
    """Multi-pod train step with int8+EF compressed cross-pod grad sync.

    State carries a per-pod error-feedback pytree (leading dim = n_pods,
    sharded over the pod axis).  Inside the shard_map only the pod axis is
    manual; FSDP/TP collectives within each pod stay compiler-inserted.

    Toolchain note: the partial-manual form (manual 'pod' + auto
    data/model) trips an XLA:CPU SPMD-partitioner check on some inner
    collectives (spmd_partitioner_util.cc); the pure-pod-mesh form is
    exercised in tests and carries the identical compression numerics and
    int8 wire format.  Track the Shardy partitioner migration for the
    partial-manual path.
    """
    import dataclasses

    n_pods = mesh.shape[pod_axis]
    # inside the manual-pod region, activation constraints must not mention
    # the (manual) pod axis — each pod shards its slice over 'data' only
    inner_sctx = dataclasses.replace(sctx, pod=None) if sctx else None

    def loss_fn(p, mb):
        return lm_loss(p, cfg, mb, sctx=inner_sctx, remat=remat)

    def per_pod(params, opt_state, ef, batch):
        # batch arrives with the global batch dim pre-split over pods
        loss, grads = _accumulated_grads(loss_fn, params, batch,
                                         n_microbatches)
        loss = jax.lax.pmean(loss, pod_axis)
        synced = jax.tree.map(
            lambda g, e: compression.ef_allgather_mean(g, e[0], pod_axis),
            grads, ef,
            is_leaf=lambda x: isinstance(x, jax.Array) and not isinstance(
                x, dict))
        grads = jax.tree.map(lambda t: t[0], synced,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda t: t[1][None], synced,
                              is_leaf=lambda x: isinstance(x, tuple))
        params, opt_state, stats = adamw.update(grads, opt_state, params,
                                                opt_cfg)
        return params, opt_state, new_ef, {"loss": loss, **stats}

    def train_step(params, opt_state, ef, batch):
        return _shard_map(
            per_pod, mesh=mesh,
            in_specs=(P(), P(), P(pod_axis), P(pod_axis)),
            out_specs=(P(), P(), P(pod_axis), P()),
            manual_axes={pod_axis},
        )(params, opt_state, ef, batch)

    return train_step


def init_ef_state(params, n_pods: int):
    return compression.init_ef(params, n_pods)
