"""The one currency of the static-analysis subsystem: a ``Finding``.

Both analyzer levels — the AST lint pass (``repro.analysis.lint``) and the
jaxpr contract analyzer (``repro.analysis.contracts`` /
``repro.analysis.retrace``) — report problems as a flat list of ``Finding``
records, so ``tools/repolint.py`` can render and gate them uniformly.

>>> f = Finding(rule="wallclock", where="benchmarks/run.py:12",
...             message="time.time() call")
>>> print(f)
benchmarks/run.py:12: [wallclock] time.time() call
"""
from __future__ import annotations

from typing import NamedTuple

__all__ = ["Finding"]


class Finding(NamedTuple):
    """One static-analysis violation.

    ``rule`` is the machine-readable rule id (used in waiver comments),
    ``where`` locates it (``path:line`` for lint, a contract-target label
    for jaxpr checks), ``message`` explains it to a human.

    >>> Finding("carry-aval", "dac@pallas=False", "dtype drift").rule
    'carry-aval'
    """

    rule: str
    where: str
    message: str

    def __str__(self):
        return f"{self.where}: [{self.rule}] {self.message}"
