"""Static analysis for the reproduction: jaxpr contracts + repo lint.

Two levels, one ``Finding`` currency, gated in CI by
``tools/repolint.py``:

* :mod:`repro.analysis.contracts` — abstractly traces every registry
  policy (and the tier/fleet budgeted paths) to its jaxpr and verifies
  the scan-carry law, lane-padded ``int32`` rows, ``ADAPT_KEYS``
  presence, and the absence of 64-bit widening and host-callback
  primitives.
* :mod:`repro.analysis.retrace` — counts compiled programs across the
  nine canonical engine shapes and fails on silent retraces.
* :mod:`repro.analysis.lint` — AST rules over ``src/``, ``benchmarks/``
  and ``tools/`` (wallclock, unseeded RNG, schema literals, inline
  ``-1`` sentinels, non-atomic JSON writes, traced-value branching),
  with an audited per-line waiver syntax.

>>> from repro.analysis import Finding, lint_source, verify_contracts
>>> lint_source("x = 1\\n", path="ok.py")
[]
"""
from .contracts import (check_fleet, check_policy, check_tier,
                        registry_specs, verify_contracts)
from .findings import Finding
from .lint import RULES, lint_file, lint_source, lint_tree
from .retrace import audit_engine, audit_jit, cache_entries

__all__ = [
    "Finding", "RULES", "lint_source", "lint_file", "lint_tree",
    "registry_specs", "check_policy", "check_tier", "check_fleet",
    "verify_contracts", "audit_jit", "audit_engine", "cache_entries",
]
