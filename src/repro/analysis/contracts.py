"""Jaxpr-level contract analyzer (level 1 of the static-analysis
subsystem).

Every registry policy — and the tier/fleet ``step_budgeted`` paths built
on them — must satisfy a set of structural contracts that no amount of
replaying a few example traces can prove.  This module traces each
``step`` to its jaxpr (abstractly: no trace is replayed, no kernel run)
and verifies:

``carry-aval`` / ``carry-structure``
    The scan-carry law: the state tree that goes into ``step`` comes out
    with the *identical* avals — same tree structure, shapes, dtypes and
    weak-type flags.  Any drift breaks ``lax.scan`` and silently retraces.
``row-dtype`` / ``row-width`` / ``row-init``
    Rank rows (every ``"cache"`` leaf) are ``int32`` with a lane-padded
    trailing width (``W % LANE == 0``) and start all-``EMPTY``.
``f64-leak``
    No ``float64``/``complex128`` aval anywhere in the traced program
    (under default 32-bit mode, no 64-bit aval at all) — device programs
    must not widen.
``adapt-keys``
    Scalars a policy declares in ``ADAPT_KEYS`` really exist in its state
    tree as ``int32`` leaves (the admission/tier revert-exemption
    contract).
``forbidden-primitive``
    No host-callback / debug primitive (``pure_callback``, ``debug_print``,
    ...) inside the jitted step — they stall the device pipeline.

``verify_contracts`` runs the whole registry (15 policies + their
``admit(...)`` wrappers) under both Pallas settings, the budgeted
DAC/tier/fleet paths, and an x64 sub-pass that re-checks carry stability
when 64-bit mode is ambient.

>>> from repro.analysis import contracts
>>> contracts.check_policy("fifo")
[]
>>> len(contracts.registry_specs())
30
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import tree_util as jtu

try:                                    # the blessed home since jax 0.4.35
    from jax.extend import core as jcore
except ImportError:                     # pragma: no cover
    from jax import core as jcore

from ..core import POLICIES, make_policy
from ..core.policy import EMPTY, LANE, Request, pallas_mode
from .findings import Finding

__all__ = ["FORBIDDEN_PRIMITIVES", "registry_specs", "check_policy",
           "check_tier", "check_fleet", "verify_contracts"]

# host-callback / debug primitives that must never appear inside a jitted
# step program (they stall the device pipeline and break AOT lowering)
FORBIDDEN_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "outside_call", "host_callback_call",
})


def registry_specs():
    """All registry policies plus their ``admit(...)`` wrappers.

    >>> specs = registry_specs()
    >>> "dynamicadaptiveclimb" in specs and "admit(fifo)" in specs
    True
    """
    names = sorted(POLICIES)
    return tuple(names) + tuple(f"admit({n})" for n in names)


# -- jaxpr walking ------------------------------------------------------

def _as_jaxprs(v):
    if isinstance(v, jcore.ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, jcore.Jaxpr):
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _as_jaxprs(x)]
    return []


def _iter_jaxprs(jaxpr):
    """Yield ``jaxpr`` and every jaxpr nested in its equations (scan and
    cond bodies, pallas kernels, custom_vmap rules, ...)."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for sub in _as_jaxprs(v):
                yield from _iter_jaxprs(sub)


def _scan_program(closed, target, findings):
    """Walk every equation of a traced program for forbidden primitives
    and 64-bit aval leaks."""
    x64 = bool(jax.config.jax_enable_x64)
    bad_dtypes = set()
    bad_prims = set()
    for jaxpr in _iter_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in FORBIDDEN_PRIMITIVES:
                bad_prims.add(eqn.primitive.name)
            for var in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(var, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is None:
                    continue
                dt = jnp.dtype(dt)
                if dt in (jnp.dtype("float64"), jnp.dtype("complex128")):
                    bad_dtypes.add(str(dt))
                elif not x64 and dt.itemsize == 8:
                    bad_dtypes.add(str(dt))
    for name in sorted(bad_prims):
        findings.append(Finding(
            "forbidden-primitive", target,
            f"primitive {name!r} inside the jitted step program"))
    for dt in sorted(bad_dtypes):
        findings.append(Finding(
            "f64-leak", target,
            f"{dt} aval inside the device program"
            + ("" if x64 else " (x64 is disabled)")))


# -- per-target checks --------------------------------------------------

def _aval_str(a):
    weak = getattr(a, "weak_type", False)
    return f"{a.str_short()}{'~w' if weak else ''}"


def _check_carry(step_fn, state, req, target, findings):
    """Trace ``step_fn(state, req)`` — which must return *only* the new
    state — and verify the scan-carry law plus program-level invariants;
    returns the traced ClosedJaxpr (or None)."""
    in_leaves, in_tree = jtu.tree_flatten(state)
    try:
        closed = jax.make_jaxpr(step_fn)(state, req)
        new_state = jax.eval_shape(step_fn, state, req)
    except Exception as exc:   # a step that won't even trace is a finding
        findings.append(Finding(
            "trace-error", target,
            f"step failed to trace abstractly: {type(exc).__name__}: "
            f"{exc}"))
        return None

    out_tree = jtu.tree_structure(new_state)
    if out_tree != in_tree:
        findings.append(Finding(
            "carry-structure", target,
            f"state tree structure drifts across step: {in_tree} -> "
            f"{out_tree}"))
        return closed

    n = len(in_leaves)
    paths = [jtu.keystr(p)
             for p, _ in jtu.tree_flatten_with_path(state)[0]]
    in_avals, out_avals = closed.in_avals[:n], closed.out_avals[:n]
    for path, a, b in zip(paths, in_avals, out_avals):
        if a != b:
            findings.append(Finding(
                "carry-aval", target,
                f"state leaf {path} drifts across step: "
                f"{_aval_str(a)} -> {_aval_str(b)} (breaks lax.scan)"))
    _scan_program(closed, target, findings)
    return closed


def _check_rows(state, target, findings):
    """Every ``"cache"`` leaf is an int32, lane-padded, all-EMPTY row."""
    for path, leaf in jtu.tree_flatten_with_path(state)[0]:
        if not (path and isinstance(path[-1], jtu.DictKey)
                and path[-1].key == "cache"):
            continue
        where = f"{target}{jtu.keystr(path)}"
        if jnp.dtype(leaf.dtype) != jnp.dtype(jnp.int32):
            findings.append(Finding(
                "row-dtype", where, f"rank row dtype {leaf.dtype}, "
                "expected int32"))
        if leaf.shape[-1] % LANE != 0:
            findings.append(Finding(
                "row-width", where, f"rank row width {leaf.shape[-1]} is "
                f"not a multiple of LANE={LANE}"))
        if not np.all(np.asarray(leaf) == int(EMPTY)):
            findings.append(Finding(
                "row-init", where, "fresh rank row is not all-EMPTY"))


def _check_adapt_keys(pol, state, target, findings):
    """Declared ``ADAPT_KEYS`` exist in the (base) state as int32
    leaves."""
    base, sub = pol, state
    inner = getattr(pol, "base", None)
    if inner is not None and isinstance(state, dict) and "base" in state:
        base, sub = inner, state["base"]
    for key in getattr(base, "ADAPT_KEYS", ()):
        if not (isinstance(sub, dict) and key in sub):
            findings.append(Finding(
                "adapt-keys", target,
                f"declared ADAPT_KEYS entry {key!r} missing from the "
                "state tree"))
            continue
        leaf = sub[key]
        if jnp.dtype(leaf.dtype) != jnp.dtype(jnp.int32):
            findings.append(Finding(
                "adapt-keys", target,
                f"ADAPT_KEYS leaf {key!r} has dtype {leaf.dtype}, "
                "expected int32"))


def _with_cap(state, K):
    """Insert the tier's capacity cap the way ``repro.tier`` does."""
    if isinstance(state, dict) and "base" in state:
        return dict(state, base=dict(state["base"], cap=jnp.int32(K)))
    return dict(state, cap=jnp.int32(K))


def check_policy(spec, K=8, use_pallas=False, budgeted=False):
    """Verify one policy spec's step contracts; returns findings.

    >>> check_policy("dac", use_pallas="interpret")
    []
    >>> check_policy("admit(dac)", budgeted=True)
    []
    """
    pol = make_policy(spec)
    target = (f"{spec}{':budgeted' if budgeted else ''}"
              f"@pallas={use_pallas}")
    findings = []
    state = pol.init(K)
    _check_rows(state, target, findings)
    _check_adapt_keys(pol, state, target, findings)
    if budgeted:
        state = _with_cap(state, K)
        step_fn = lambda st, r: pol.step_budgeted(st, r)[0]
    else:
        step_fn = lambda st, r: pol.step(st, r)[0]
    req = Request.of(jnp.int32(3))
    with pallas_mode(use_pallas):
        _check_carry(step_fn, state, req, target, findings)
    return findings


def check_tier(use_pallas=False, n_tenants=3, budget=6 * LANE):
    """Contract pass over the multi-tenant tier step.

    >>> check_tier()
    []
    """
    from ..tier.tier import CacheTier
    tier = CacheTier("dac", n_tenants=n_tenants, budget=budget)
    target = f"tier(dac,n={n_tenants})@pallas={use_pallas}"
    findings = []
    state = tier.init()
    _check_rows(state, target, findings)
    _check_adapt_keys(tier.policy, state, target, findings)
    req = Request.of(jnp.zeros((n_tenants,), jnp.int32))
    step_fn = lambda st, r: tier.step(st, r)[0]
    with pallas_mode(use_pallas):
        _check_carry(step_fn, state, req, target, findings)
    return findings


def check_fleet(use_pallas=False, n_lanes=4, budget=8 * LANE):
    """Contract pass over the fleet lane-block step.

    >>> check_fleet()
    []
    """
    from ..fleet.fleet import FleetTier, _fleet_step
    tier = FleetTier("dac", n_lanes=n_lanes, budget=budget)
    target = f"fleet(dac,n={n_lanes})@pallas={use_pallas}"
    findings = []
    state = tier.init()
    _check_rows(state, target, findings)
    req = Request.of(jnp.zeros((n_lanes,), jnp.int32))
    step_fn = lambda st, r: _fleet_step(tier, st, r,
                                        jnp.int32(tier.budget))[0]
    with pallas_mode(use_pallas):
        _check_carry(step_fn, state, req, target, findings)
    return findings


def verify_contracts(specs=None, pallas_modes=(False, "interpret"), K=8,
                     include_budgeted=True, include_tier=True,
                     include_x64=True):
    """The full contract pass: registry x Pallas modes, budgeted paths,
    tier/fleet, and an x64 carry-stability sub-pass.  Returns all
    findings (empty = contract-clean)."""
    if specs is None:
        specs = registry_specs()
    findings = []
    for mode in pallas_modes:
        for spec in specs:
            findings += check_policy(spec, K=K, use_pallas=mode)
        if include_budgeted:
            for spec in ("dynamicadaptiveclimb",
                         "admit(dynamicadaptiveclimb)"):
                findings += check_policy(spec, K=K, use_pallas=mode,
                                         budgeted=True)
        if include_tier:
            findings += check_tier(use_pallas=mode)
            findings += check_fleet(use_pallas=mode)
    if include_x64 and not jax.config.jax_enable_x64:
        from jax.experimental import enable_x64
        with enable_x64():
            for spec in specs:
                findings += check_policy(spec, K=K, use_pallas=False)
    return findings
