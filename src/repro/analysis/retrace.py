"""Retrace auditor: compilation-cache accounting for the jitted engine.

A jitted function retraces when a call's cache key differs — a new aval
(weak-typed Python scalar where an ``int32`` array went before), an
unhashable-or-unequal static argument (a policy without value
``__eq__``/``__hash__``), a drifted donation signature.  Each silent
retrace costs a full compile and, at fleet scale, turns a warm serving
path into a cold one.  This module counts cache entries (via the jit
internals ``fn._cache_size()``) across the **nine canonical engine
program shapes** and fails when either the canonical set compiles to an
unexpected count or an equivalence variant — same request stream spelled
differently — grows any cache.

The nine canonical programs:

====================  =====================================================
``_replay_single``    default / ``collect_info=False`` / ``observe=True`` /
                      ``use_pallas="interpret"``                (4 entries)
``_replay_batched``   default / ``collect_info=False`` /
                      ``use_pallas="interpret"``                (3 entries)
``_replay_chunk``     streaming ``[T]`` / ``[B, T]``            (2 entries)
====================  =====================================================

>>> import jax, jax.numpy as jnp
>>> from repro.analysis.retrace import audit_jit
>>> f = jax.jit(lambda x: x + 1)
>>> audit_jit(f, "toy", prime=[("i32", lambda: f(jnp.int32(0)))],
...           variants=[("same-aval", lambda: f(jnp.int32(5)))])
[]
>>> bad = audit_jit(f, "toy", prime=[("i32", lambda: f(jnp.int32(0)))],
...                 variants=[("weak-python-int", lambda: f(0))])
>>> [b.rule for b in bad]
['retrace']
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .findings import Finding

__all__ = ["cache_entries", "audit_jit", "audit_engine",
            "ENGINE_EXPECTED"]

# canonical compiled-program count per jitted engine entry point
ENGINE_EXPECTED = {"_replay_single": 4, "_replay_batched": 3,
                   "_replay_chunk": 2}


def cache_entries(fn):
    """Number of compiled programs in a ``jax.jit`` function's cache.

    >>> import jax, jax.numpy as jnp
    >>> g = jax.jit(lambda x: x * 2)
    >>> _ = g(jnp.int32(1))
    >>> cache_entries(g)
    1
    """
    return fn._cache_size()


def audit_jit(fn, label, prime, variants, expected=None):
    """Clear ``fn``'s cache, run the ``prime`` calls, then verify that no
    ``variants`` call adds a cache entry (and, when ``expected`` is
    given, that priming compiled exactly that many programs).

    ``prime`` / ``variants`` are ``(name, thunk)`` lists.  Returns
    findings; empty means the cache keys are stable.
    """
    findings = []
    fn._clear_cache()
    for _, thunk in prime:
        thunk()
    n = cache_entries(fn)
    if expected is not None and n != expected:
        findings.append(Finding(
            "retrace-count", label,
            f"priming compiled {n} programs, expected {expected} — a "
            "canonical shape either retraced or collapsed"))
    for name, thunk in variants:
        before = cache_entries(fn)
        thunk()
        grew = cache_entries(fn) - before
        if grew:
            findings.append(Finding(
                "retrace", label,
                f"equivalence variant {name!r} grew the cache by {grew} "
                "(weak-type / static-arg cache-key bug)"))
    return findings


def audit_engine(policy="dac", K=8, T=16):
    """Audit the three jitted engine entry points across the nine
    canonical program shapes plus equivalence variants.

    Returns ``(findings, report)`` where ``report`` maps entry-point name
    to its compiled-program count after priming.
    """
    from ..core import make_policy
    from ..core.simulator import (Engine, _replay_batched, _replay_chunk,
                                  _replay_single)

    eng = Engine()
    keys1 = (np.arange(T) % 5).astype(np.int32)
    keys2 = np.stack([keys1, (keys1 + 3) % 7]).astype(np.int32)

    prime = [
        ("single", lambda: eng.replay(policy, keys1, K)),
        ("single/metrics-only",
         lambda: eng.replay(policy, keys1, K, collect_info=False)),
        ("single/observe",
         lambda: eng.replay(policy, keys1, K, observe=True)),
        ("single/pallas-interpret",
         lambda: eng.replay(policy, keys1, K, use_pallas="interpret")),
        ("batched", lambda: eng.replay(policy, keys2, K)),
        ("batched/metrics-only",
         lambda: eng.replay(policy, keys2, K, collect_info=False)),
        ("batched/pallas-interpret",
         lambda: eng.replay(policy, keys2, K, use_pallas="interpret")),
        ("stream[T]", lambda: eng.replay_stream(policy, keys1, K)),
        ("stream[B,T]", lambda: eng.replay_stream(policy, keys2, K)),
    ]
    # the same nine requests spelled differently — none may recompile
    variants = [
        ("python-list keys",
         lambda: eng.replay(policy, [int(x) for x in keys1], K)),
        ("jnp keys", lambda: eng.replay(policy, jnp.asarray(keys1), K)),
        ("np.int32 capacity",
         lambda: eng.replay(policy, keys1, np.int32(K))),
        ("explicit unit sizes/costs",
         lambda: eng.replay(policy, keys1, K, sizes=1, costs=1.0)),
        ("fresh equal policy instance",
         lambda: eng.replay(make_policy(policy), keys1, K)),
        ("explicit stream chunk",
         lambda: eng.replay_stream(policy, keys1, K, chunk=T)),
    ]

    fns = {"_replay_single": _replay_single,
           "_replay_batched": _replay_batched,
           "_replay_chunk": _replay_chunk}
    for fn in fns.values():
        fn._clear_cache()
    for _, thunk in prime:
        thunk()

    findings = []
    report = {name: cache_entries(fn) for name, fn in fns.items()}
    for name, fn in fns.items():
        if report[name] != ENGINE_EXPECTED[name]:
            findings.append(Finding(
                "retrace-count", f"engine.{name}",
                f"{report[name]} compiled programs after priming the "
                f"canonical shapes, expected {ENGINE_EXPECTED[name]}"))
    for vname, thunk in variants:
        before = {name: cache_entries(fn) for name, fn in fns.items()}
        thunk()
        for name, fn in fns.items():
            grew = cache_entries(fn) - before[name]
            if grew:
                findings.append(Finding(
                    "retrace", f"engine.{name}",
                    f"equivalence variant {vname!r} grew the cache by "
                    f"{grew} (weak-type / static-arg cache-key bug)"))
    return findings, report
