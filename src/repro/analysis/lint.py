"""Repo-specific AST lint (level 2 of the static-analysis subsystem).

Five bug classes that have each bitten (or nearly bitten) this repo are
banned structurally, over ``src/``, ``benchmarks/`` and ``tools/``:

``wallclock``
    ``time.time()`` / ``datetime.now()``-style absolute clocks make runs
    irreproducible and results nondeterministic.  Durations must use
    ``time.perf_counter()``; genuine provenance timestamps carry a waiver.
``unseeded-rng``
    Global/legacy RNG draws (``np.random.rand``, stdlib ``random.random``,
    ``default_rng()`` with no seed) break bit-identical replays.  All
    randomness must flow from an explicitly seeded ``Generator``.
``schema-literal``
    Result-schema version strings must come from the
    ``repro.bench.results.SCHEMA_V1/V2`` constants, not duplicated string
    literals (docstrings are exempt; so is the defining module).
``empty-sentinel``
    Inline ``jnp.int32(-1)`` where ``repro.core.EMPTY`` exists invites the
    two drifting apart.  The Pallas kernel's closure-capture sites are the
    intentional, waived exceptions.
``atomic-json``
    Bare ``json.dump(...)`` tears result files on crash; writes go through
    ``repro.bench.results.atomic_write_json`` (whose own body is exempt).
``traced-branch``
    A Python ``if``/``while`` whose test calls ``jnp.*``/``lax.*`` is the
    classic trace-time concretization error (heuristic).

A finding is waived by a comment on the same line or the line above::

    t = time.time()  # repolint: waive[wallclock] -- journal provenance

Waivers are themselves audited: one that matches nothing is reported as
``unused-waiver``, so the waiver list can only shrink with the code.

>>> from repro.analysis.lint import lint_source
>>> bad = "import time\\ndef f():\\n    return time.time()\\n"
>>> [(f.rule, f.where) for f in lint_source(bad, path="x.py")]
[('wallclock', 'x.py:3')]
>>> ok = ("import time\\ndef f():\\n"
...       "    # repolint: waive[wallclock] -- demo\\n    return time.time()\\n")
>>> lint_source(ok, path="x.py")
[]
>>> stale = "x = 1  # repolint: waive[wallclock] -- nothing here\\n"
>>> [f.rule for f in lint_source(stale, path="x.py")]
['unused-waiver']
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path

from .findings import Finding

__all__ = ["RULES", "lint_source", "lint_file", "lint_tree"]

RULES = {
    "wallclock": "absolute clock call (time.time/datetime.now); use "
                 "perf_counter for durations or waive provenance stamps",
    "unseeded-rng": "legacy/global or unseeded RNG draw; use a seeded "
                    "np.random.Generator or jax.random key",
    "schema-literal": "inline result-schema version string; use "
                      "repro.bench.results.SCHEMA_V1/V2",
    "empty-sentinel": "inline int32(-1); use repro.core.EMPTY",
    "atomic-json": "bare json.dump; use "
                   "repro.bench.results.atomic_write_json",
    "traced-branch": "Python if/while branching on a traced jnp/lax value",
    "unused-waiver": "repolint waiver comment that matches no finding",
}

_WAIVER_RE = re.compile(r"#\s*repolint:\s*waive\[([A-Za-z0-9_,\- ]+)\]")

# absolute-wallclock attribute tails (matched against the end of the chain)
_WALLCLOCK_TAILS = {
    ("time", "time"), ("time", "time_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
}

# draw functions that, reached through a `random` module attribute, imply
# the legacy/global (or stdlib) RNG rather than a seeded Generator
_RNG_DRAWS = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "choices", "shuffle", "permutation", "uniform",
    "normal", "standard_normal", "poisson", "exponential", "gauss",
    "randrange", "betavariate", "vonmisesvariate",
}

# string fragment identifying a result-schema version literal; assembled at
# runtime so this module's own AST never contains the banned needle
_SCHEMA_NEEDLE = "repro.bench.result/" + "v"

# modules where specific rules are definitionally allowed
_SCHEMA_HOME = "repro/bench/results.py"
_ATOMIC_WRITERS = {"atomic_write_json"}

# traced-namespace heads for the traced-branch heuristic, minus the
# metadata accessors that return host values (branching on those is fine)
_TRACED_HEADS = {("jnp",), ("lax",), ("jax", "numpy"), ("jax", "lax")}
_HOST_METADATA = {"dtype", "shape", "ndim", "size", "iinfo", "finfo",
                  "result_type", "issubdtype", "promote_types"}


def _attr_chain(node):
    """``np.random.rand`` -> ``("np", "random", "rand")``; None if the
    chain doesn't bottom out in a plain name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _docstring_ids(tree):
    """ids of Constant nodes that are docstrings (exempt from literal
    rules)."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            body = getattr(node, "body", [])
            if (body and isinstance(body[0], ast.Expr)
                    and isinstance(body[0].value, ast.Constant)
                    and isinstance(body[0].value.value, str)):
                out.add(id(body[0].value))
    return out


class _Visitor(ast.NodeVisitor):
    def __init__(self, path, tree):
        self.path = path
        self.doc_ids = _docstring_ids(tree)
        self.func_stack = []
        self.raw = []   # (rule, line, message)

    def _hit(self, rule, node, message):
        self.raw.append((rule, node.lineno, message))

    # -- scope tracking -------------------------------------------------
    def visit_FunctionDef(self, node):
        self.func_stack.append(node.name)
        self.generic_visit(node)
        self.func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- call rules -----------------------------------------------------
    def visit_Call(self, node):
        chain = _attr_chain(node.func)
        if chain:
            self._check_wallclock(node, chain)
            self._check_rng(node, chain)
            self._check_sentinel(node, chain)
            self._check_json(node, chain)
        self.generic_visit(node)

    def _check_wallclock(self, node, chain):
        if chain[-2:] in _WALLCLOCK_TAILS:
            self._hit("wallclock", node,
                      f"{'.'.join(chain)}() is an absolute clock; use "
                      "time.perf_counter() or waive with a reason")

    def _check_rng(self, node, chain):
        name = ".".join(chain)
        if chain[0] == "jax":
            return   # jax.random draws require an explicit key: seeded
        if chain[-1] in _RNG_DRAWS and "random" in chain[:-1]:
            self._hit("unseeded-rng", node,
                      f"{name}() draws from a global/legacy RNG; use a "
                      "seeded np.random.Generator")
        elif chain[-1] == "default_rng":
            args = list(node.args) + [kw.value for kw in node.keywords]
            seeded = any(not (isinstance(a, ast.Constant)
                              and a.value is None) for a in args)
            if not seeded:
                self._hit("unseeded-rng", node,
                          f"{name}() without a seed is nondeterministic")

    def _check_sentinel(self, node, chain):
        if (chain[-1] == "int32" and chain[0] in ("jnp", "np", "numpy",
                                                  "jax")
                and len(node.args) == 1 and not node.keywords):
            a = node.args[0]
            if (isinstance(a, ast.UnaryOp) and isinstance(a.op, ast.USub)
                    and isinstance(a.operand, ast.Constant)
                    and a.operand.value == 1):
                self._hit("empty-sentinel", node,
                          f"{'.'.join(chain)}(-1): use repro.core.EMPTY")

    def _check_json(self, node, chain):
        if chain == ("json", "dump"):
            if not (set(self.func_stack) & _ATOMIC_WRITERS):
                self._hit("atomic-json", node,
                          "json.dump tears files on crash; use "
                          "atomic_write_json")

    # -- literal rule ---------------------------------------------------
    def visit_Constant(self, node):
        if (isinstance(node.value, str) and _SCHEMA_NEEDLE in node.value
                and id(node) not in self.doc_ids
                and not self.path.replace("\\", "/").endswith(
                    _SCHEMA_HOME)):
            self._hit("schema-literal", node,
                      f"schema literal {node.value!r}; import SCHEMA_V1/V2 "
                      "from repro.bench.results")
        self.generic_visit(node)

    # -- traced-branch heuristic ---------------------------------------
    def _check_branch(self, node):
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.Call):
                chain = _attr_chain(sub.func)
                if chain and (chain[:1] in _TRACED_HEADS
                              or chain[:2] in _TRACED_HEADS) \
                        and chain[-1] not in _HOST_METADATA:
                    self._hit("traced-branch", node,
                              f"Python {type(node).__name__.lower()} "
                              f"branches on {'.'.join(chain)}(...) — "
                              "traced values need lax.cond/jnp.where")
                    return
        # only visit the test's children once via generic_visit below

    def visit_If(self, node):
        self._check_branch(node)
        self.generic_visit(node)

    def visit_While(self, node):
        self._check_branch(node)
        self.generic_visit(node)


def _waiver_map(src):
    """Waiver entries ``[line, rules, used]``; a waiver at line L covers
    findings on L and L+1 (comment-above style).  Only real COMMENT
    tokens count — waiver syntax quoted inside a docstring is inert."""
    waivers = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(src).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except tokenize.TokenError:   # pragma: no cover - ast.parse ran first
        comments = []
    for lineno, text in comments:
        m = _WAIVER_RE.search(text)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            waivers.append([lineno, rules, False])
    return waivers


def lint_source(src, path="<memory>"):
    """Lint one module's source text; returns surviving ``Finding``s.

    >>> lint_source("x = jnp.int32(-1)\\n", path="m.py")[0].rule
    'empty-sentinel'
    """
    tree = ast.parse(src, filename=path)
    visitor = _Visitor(path, tree)
    visitor.visit(tree)
    waivers = _waiver_map(src)

    findings = []
    for rule, line, message in sorted(visitor.raw, key=lambda r: r[1]):
        waived = False
        for w in waivers:
            if w[0] in (line, line - 1) and rule in w[1]:
                w[2] = True
                waived = True
        if not waived:
            findings.append(Finding(rule, f"{path}:{line}", message))
    for wline, rules, used in waivers:
        if not used:
            findings.append(Finding(
                "unused-waiver", f"{path}:{wline}",
                f"waiver for {sorted(rules)} matches no finding; remove "
                "it"))
    return findings


def lint_file(path, root=None):
    """Lint a file on disk; ``where`` paths are relative to ``root``."""
    path = Path(path)
    rel = str(path.relative_to(root)) if root else str(path)
    return lint_source(path.read_text(), path=rel)


def lint_tree(root, subdirs=("src", "benchmarks", "tools")):
    """Lint every ``*.py`` under ``root``'s analysis scope.

    >>> from repro.analysis import lint
    >>> root = Path(lint.__file__).resolve().parents[3]
    >>> isinstance(lint_tree(root), list)
    True
    """
    root = Path(root)
    findings = []
    for sub in subdirs:
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            findings.extend(lint_file(path, root=root))
    return findings
