"""Serving steps: prefill + single-token decode for every architecture.

Two cache regimes, selected by ``budget``:
  * budget == 0  — unbounded contiguous KV buffers of ``max_len`` slots
    (decode_32k cells); slot index == token position.
  * budget > 0   — the paper's bounded slot pool (long_500k cells): each
    attention/MLA layer holds ``budget`` physical slots managed per-sequence
    by DynamicAdaptiveClimb (repro.serving.kv_cache).  Per decoded token the
    attention cost is O(budget), independent of logical context length —
    this is the sub-quadratic mechanism for long-context decode.

Recurrent layers (mamba / mlstm / slstm) carry O(1) state and ignore the
budget.  The decode step scans the period-stacked params with the
period-stacked cache state, exactly mirroring ``model.forward``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.config import ArchConfig, LayerSpec
from repro.models.layers import (attn_qkv, decode_attention, mlp_apply,
                                 rmsnorm)
from repro.models.model import forward
from . import kv_cache as kvc


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------

def _layer_state(cfg: ArchConfig, spec: LayerSpec, B, max_len, budget,
                 dtype, k0=None):
    hd = cfg.head_dim
    Hkv = cfg.n_kv_heads
    L = budget if budget else max_len
    if spec.kind == "attn":
        st = {"k": jnp.zeros((B, L, Hkv, hd), dtype),
              "v": jnp.zeros((B, L, Hkv, hd), dtype)}
        if budget:
            # serving starts at the full pool: DAC *shrinks* when hits
            # concentrate (returning HBM) rather than evicting from a
            # quarter-size start — unless a fleet admission share k0 says
            # this sequence only owns part of a global budget
            st["ctrl"] = kvc.control_init(B, budget,
                                          k0=budget if k0 is None else k0)
        return st
    if spec.kind == "mla":
        st = {"latent": jnp.zeros((B, L, cfg.kv_lora_rank), dtype),
              "krope": jnp.zeros((B, L, cfg.qk_rope_head_dim), dtype)}
        if budget:
            st["ctrl"] = kvc.control_init(B, budget,
                                          k0=budget if k0 is None else k0)
        return st
    if spec.kind == "mamba":
        return ssm.mamba_state_init(cfg, B, dtype)
    if spec.kind == "mlstm":
        return ssm.mlstm_state_init(cfg, B, dtype)
    if spec.kind == "slstm":
        return ssm.slstm_state_init(cfg, B, dtype)
    raise ValueError(spec.kind)


def init_serve_state(cfg: ArchConfig, B: int, max_len: int, budget: int = 0,
                     k0: int | None = None):
    """Fresh serve state (period-stacked).  budget>0 => bounded DAC pool;
    ``k0`` starts each sequence's active budget below the full pool (a
    fleet admission share — see ``examples/fleet_decode.py``)."""
    dtype = cfg.dtype
    period_state = {
        f"l{i}": _layer_state(cfg, spec, B, max_len, budget, dtype, k0)
        for i, spec in enumerate(cfg.period)}
    P = cfg.n_periods
    layers = jax.tree.map(
        lambda x: jnp.tile(x[None], (P,) + (1,) * x.ndim), period_state)
    return {"pos": jnp.zeros((B,), jnp.int32), "layers": layers}


def serve_state_specs(cfg: ArchConfig, B: int, max_len: int,
                      budget: int = 0):
    """ShapeDtypeStructs of the serve state — nothing allocated (dry-run)."""
    return jax.eval_shape(
        partial(init_serve_state, cfg, B, max_len, budget))


def serve_state_shardings(cfg: ArchConfig, sctx, state_tree):
    """PartitionSpec pytree for a serve state (period-stacked leaves).

    Policy: batch over (pod,)data when divisible; KV-heads over model when
    divisible, else slots over model; recurrent inner dims over model; DAC
    control rows [B, Bmax] slot-sharded over model.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    tp_n = sctx.axis_size(sctx.tp)

    def b_axes(B):
        return sctx.batch_axes if B % sctx._bsz() == 0 else None

    def tp_if(n):
        return sctx.tp if n % tp_n == 0 else None

    def visit(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        keys = [k for k in keys if isinstance(k, str)]
        name = keys[-1]
        sh = leaf.shape
        if name == "pos":
            return P(b_axes(sh[0]))
        b = b_axes(sh[1])                      # leading dim = period stack
        if name in ("k", "v"):                  # [P,B,L,Hkv,hd]
            if sh[3] % tp_n == 0:
                return P(None, b, None, sctx.tp, None)
            return P(None, b, tp_if(sh[2]), None, None)
        if name in ("latent", "krope"):         # [P,B,L,r]
            return P(None, b, tp_if(sh[2]), None)
        if name in ("rank2slot", "free", "slot_pos"):   # [P,B,Bmax]
            return P(None, b, tp_if(sh[2]))
        if name in ("length", "k_active", "jump", "jump2"):
            return P(None, b)
        if name == "conv":                      # [P,B,dc-1,di]
            return P(None, b, None, tp_if(sh[3]))
        if name == "h" and len(sh) == 4:        # mamba h [P,B,di,ds]
            return P(None, b, tp_if(sh[2]), None)
        if name == "C":                         # mlstm [P,B,H,dh,dh]
            return P(None, b, tp_if(sh[2]), None, None)
        if name == "n" and len(sh) == 4:        # mlstm n [P,B,H,dh]
            return P(None, b, tp_if(sh[2]), None)
        if name == "m" and len(sh) == 3:        # mlstm m [P,B,H]
            return P(None, b, tp_if(sh[2]))
        if len(sh) == 3:                        # slstm h/c/n/m [P,B,d]
            return P(None, b, tp_if(sh[2]))
        return P(*([None] * len(sh)))

    specs = jax.tree_util.tree_map_with_path(visit, state_tree)
    return jax.tree.map(lambda s: NamedSharding(sctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _sharded_cache(st, sctx):
    """Constrain KV buffers: batch over data, slots over model."""
    if sctx is None:
        return st
    out = dict(st)
    for key in ("k", "v", "latent", "krope"):
        if key in st:
            x = st[key]
            b = sctx.batch_axes if x.shape[0] % sctx._bsz() == 0 else None
            s = sctx.tp if x.shape[1] % sctx.axis_size(sctx.tp) == 0 else None
            out[key] = sctx.cons(x, b, s, *([None] * (x.ndim - 2)))
    return out


def _decode_attn(x, p, st, cfg, spec, pos, sctx, eps, k_min, kv_caps):
    """Attention layer decode (bounded or unbounded).  x: [B, 1, d]."""
    B = x.shape[0]
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = attn_qkv(h, p["attn"], cfg, pos[:, None])   # [B,1,H|Hkv,hd]
    bidx = jnp.arange(B)
    new_st = dict(st)
    if "ctrl" in st:                                       # bounded (DAC)
        ctrl, slot = kvc.insert(st["ctrl"], pos)           # miss event
        k_cache = st["k"].at[bidx, slot].set(k[:, 0])
        v_cache = st["v"].at[bidx, slot].set(v[:, 0])
        valid = kvc.valid_slots(ctrl)
        if spec.window:
            valid &= ctrl["slot_pos"] > (pos[:, None] - spec.window)
        o, mass = decode_attention(q[:, 0], k_cache, v_cache, valid,
                                   softcap=cfg.attn_softcap)
        masked = jnp.where(valid, mass, -jnp.inf)
        top = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        top = jnp.where(jnp.any(valid, axis=-1), top, -1)
        ctrl = kvc.hit(ctrl, top)                          # hit event
        ctrl = kvc.resize(ctrl, eps=eps, k_min=k_min, cap=kv_caps)
        new_st.update(k=k_cache, v=v_cache, ctrl=ctrl)
    else:                                                  # unbounded
        k_cache = st["k"].at[bidx, pos].set(k[:, 0])
        v_cache = st["v"].at[bidx, pos].set(v[:, 0])
        ar = jnp.arange(k_cache.shape[1])[None]
        valid = ar <= pos[:, None]
        if spec.window:
            valid &= ar > pos[:, None] - spec.window
        o, _ = decode_attention(q[:, 0], k_cache, v_cache, valid,
                                softcap=cfg.attn_softcap)
        new_st.update(k=k_cache, v=v_cache)
    att = jnp.einsum("bhk,hkd->bd", o, p["attn"]["wo"])
    return x + att[:, None], _sharded_cache(new_st, sctx)


def _decode_mla(x, p, st, cfg, spec, pos, sctx, eps, k_min, kv_caps):
    B = x.shape[0]
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    latent, krope = mla_mod.mla_latent(h, p["attn"], cfg, pos[:, None])
    bidx = jnp.arange(B)
    new_st = dict(st)
    if "ctrl" in st:
        ctrl, slot = kvc.insert(st["ctrl"], pos)
        lat_cache = st["latent"].at[bidx, slot].set(latent[:, 0])
        kr_cache = st["krope"].at[bidx, slot].set(krope[:, 0, 0])
        valid = kvc.valid_slots(ctrl)
        o, mass = mla_mod.mla_attend(h, p["attn"], cfg, lat_cache, kr_cache,
                                     valid, pos)
        masked = jnp.where(valid, mass, -jnp.inf)
        top = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        top = jnp.where(jnp.any(valid, axis=-1), top, -1)
        ctrl = kvc.hit(ctrl, top)
        ctrl = kvc.resize(ctrl, eps=eps, k_min=k_min, cap=kv_caps)
        new_st.update(latent=lat_cache, krope=kr_cache, ctrl=ctrl)
    else:
        lat_cache = st["latent"].at[bidx, pos].set(latent[:, 0])
        kr_cache = st["krope"].at[bidx, pos].set(krope[:, 0, 0])
        valid = jnp.arange(lat_cache.shape[1])[None] <= pos[:, None]
        o, _ = mla_mod.mla_attend(h, p["attn"], cfg, lat_cache, kr_cache,
                                  valid, pos)
        new_st.update(latent=lat_cache, krope=kr_cache)
    return x + o[:, None], _sharded_cache(new_st, sctx)


def _decode_layer(x, p, st, cfg, spec, pos, sctx, eps, k_min, kv_caps):
    if spec.kind == "attn":
        x, new_st = _decode_attn(x, p, st, cfg, spec, pos, sctx, eps,
                                 k_min, kv_caps)
    elif spec.kind == "mla":
        x, new_st = _decode_mla(x, p, st, cfg, spec, pos, sctx, eps,
                                k_min, kv_caps)
    else:
        h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)[:, 0]
        if spec.kind == "mamba":
            out, new_st = ssm.mamba_decode_step(h, p["mamba"], cfg, st)
        elif spec.kind == "mlstm":
            out, new_st = ssm.mlstm_decode_step(h, p["mlstm"], cfg, st)
        else:
            out, new_st = ssm.slstm_decode_step(h, p["slstm"], cfg, st)
        x = x + out[:, None]

    if "moe" in p:
        h = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        ep = sctx.ep if sctx is not None else None
        x = x + moe_mod.moe_apply(h, p["moe"], cfg, ep_constraint=ep)
    elif "mlp" in p:
        h = rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        x = x + mlp_apply(h, p["mlp"], cfg.act)
    return x, new_st


def decode_step(params, cfg: ArchConfig, state, token=None, embed=None,
                sctx=None, eps: float = 0.5, k_min: int = 16,
                kv_caps=None):
    """One decode step.  token: [B] int32 (or embed: [B, d] for stub-frontend
    archs).  Returns (new_state, logits [B, V] f32).

    ``kv_caps`` ([B] int32, optional) caps each sequence's bounded-pool
    *growth* for this step — a doubling only lands if the grown size stays
    within the cap (see ``kv_cache.resize``).  This is the hook a fleet
    arbiter uses to price one shared HBM budget across the batch
    (``examples/fleet_decode.py``); every attention/MLA layer sees the
    same caps.  ``None`` = uncapped (each layer's own Bmax)."""
    pos = state["pos"]
    if cfg.embeds_input:
        x = embed.astype(cfg.dtype)[:, None]
    else:
        x = params["embed"][token][:, None]                # [B, 1, d]

    def body(x, scanned):
        pp, ss = scanned
        new_ss = {}
        for i, spec in enumerate(cfg.period):
            x, ns = _decode_layer(x, pp[f"l{i}"], ss[f"l{i}"], cfg, spec,
                                  pos, sctx, eps, k_min, kv_caps)
            new_ss[f"l{i}"] = ns
        return x, new_ss

    x, new_layers = jax.lax.scan(body, x, (params["layers"],
                                           state["layers"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0].astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return {"pos": pos + 1, "layers": new_layers}, logits


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _bounded_fill(ctrl, kbuf, vbuf, ks, vs):
    """Replay S insert-only DAC steps to load a prompt into the slot pool.
    ks/vs: [B, S, ...] prompt KV.  Returns (ctrl, kbuf, vbuf)."""
    B, S = ks.shape[:2]

    def body(carry, t):
        ctrl, kbuf, vbuf = carry
        pos = jnp.full((B,), t, jnp.int32)
        ctrl, slot = kvc.insert(ctrl, pos)
        bidx = jnp.arange(B)
        kbuf = kbuf.at[bidx, slot].set(ks[:, t])
        vbuf = vbuf.at[bidx, slot].set(vs[:, t])
        return (ctrl, kbuf, vbuf), None

    (ctrl, kbuf, vbuf), _ = jax.lax.scan(body, (ctrl, kbuf, vbuf),
                                         jnp.arange(S))
    return ctrl, kbuf, vbuf


def prefill(params, cfg: ArchConfig, tokens=None, embeds=None,
            max_len: int = 0, budget: int = 0, sctx=None, impl="jnp",
            remat="full", k0: int | None = None):
    """Run the prompt through the stack and build the serve state.

    Returns (serve_state, last_logits [B, V]).  ``k0`` (bounded regime
    only) admits each sequence at an active budget below the full pool.
    """
    B, S = (tokens.shape if tokens is not None else embeds.shape[:2])
    max_len = max_len or 2 * S
    logits, caches = forward(params, cfg, tokens=tokens, embeds=embeds,
                             sctx=sctx, impl=impl, remat=remat,
                             want_cache=True, last_only=True)
    state = init_serve_state(cfg, B, max_len, budget, k0)
    layers = dict(state["layers"])
    for i, spec in enumerate(cfg.period):
        li = f"l{i}"
        st, ca = dict(layers[li]), caches[li]
        if spec.kind == "attn":
            if budget:
                st["ctrl"], st["k"], st["v"] = jax.vmap(_bounded_fill)(
                    st["ctrl"], st["k"], st["v"], ca["k"], ca["v"])
            else:
                st["k"] = st["k"].at[:, :, :S].set(ca["k"])
                st["v"] = st["v"].at[:, :, :S].set(ca["v"])
        elif spec.kind == "mla":
            if budget:
                st["ctrl"], st["latent"], st["krope"] = jax.vmap(
                    _bounded_fill)(st["ctrl"], st["latent"], st["krope"],
                                   ca["latent"], ca["krope"])
            else:
                st["latent"] = st["latent"].at[:, :, :S].set(ca["latent"])
                st["krope"] = st["krope"].at[:, :, :S].set(ca["krope"])
        else:
            st = ca                                       # recurrent state
        layers[li] = st
    return ({"pos": jnp.full((B,), S, jnp.int32), "layers": layers},
            logits[:, -1])
