"""Serving: bounded (DynamicAdaptiveClimb-managed) and unbounded KV-cache
decode + prefill."""
from . import kv_cache
from .serve_step import (decode_step, init_serve_state, prefill,
                         serve_state_specs)

__all__ = ["kv_cache", "decode_step", "init_serve_state", "prefill",
           "serve_state_specs"]
