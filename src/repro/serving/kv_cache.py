"""Bounded KV-cache slot pool managed by DynamicAdaptiveClimb.

The paper's control law, mapped 1:1 onto KV-cache management (see
``docs/ARCHITECTURE.md``, serving section, and ``docs/PAPER_MAPPING.md``
for the Alg. 2 line mapping):

  * The per-layer slot table is the cache.  ``rank2slot`` is the paper's
    rank-ordered list; its entries are *physical slot ids* into the KV slot
    arrays (rank 0 = top).
  * Every decoded token inserts its KV — a **miss** event (Alg. 2 miss
    path: jump += 1, insert at rank k - actualJump, evict the bottom rank's
    slot when the active budget is full).
  * The top-attended slot of the decode-attention pass is a **hit** event
    (Alg. 2 hit path: jump -= 1, promote by actualJump, jump' tracks
    whether hits concentrate in the top half).
  * DAC resizing drives the *active budget* ``k_active``: jump hitting 2k
    doubles it (attention is diffuse — the cache thrashes); jump and jump'
    both saturating at -k/2 halves it (hits concentrate in the top half —
    the bottom half is dead weight, HBM is returned to the pool).

Everything is fixed-shape: the slot arrays are allocated at ``budget``
(=K_max) and ``k_active <= budget`` masks the live region, exactly like the
``k`` scalar in repro.core.dynamicadaptiveclimb.  All ops are batched over
the request batch B — each sequence runs its own independent DAC instance.

State layout (one attention layer):
  rank2slot [B, Bmax] int32   rank -> physical slot (-1 past ``length``)
  free      [B, Bmax] bool    physical-slot free bitmap
  length    [B] int32         occupied slots
  k_active  [B] int32         DAC active budget (k_min..Bmax, power-of-2 steps)
  jump      [B] int32         Alg. 2 jump  (in [-k/2, 2k])
  jump2     [B] int32         Alg. 2 jump' (in [-k/2, 0])
  slot_pos  [B, Bmax] int32   original token position of each slot (rope'd
                              keys are stored; this drives window masks)
plus the KV payload arrays indexed by physical slot:
  k/v       [B, Bmax, Hkv, hd]        (attention layers)
  latent    [B, Bmax, r], krope [B, Bmax, dr]   (MLA layers)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.control import hit_update, miss_update, resize_update
from ..core.policy import EMPTY


def control_init(B: int, budget: int, k0: int | None = None):
    """DAC control state for a batch of B caches with Bmax=budget slots."""
    k0 = k0 if k0 is not None else max(2, budget // 4)
    return {
        "rank2slot": jnp.full((B, budget), EMPTY, jnp.int32),
        "free": jnp.ones((B, budget), jnp.bool_),
        "length": jnp.zeros((B,), jnp.int32),
        "k_active": jnp.full((B,), k0, jnp.int32),
        "jump": jnp.full((B,), k0, jnp.int32),
        "jump2": jnp.zeros((B,), jnp.int32),
        "slot_pos": jnp.full((B, budget), -1, jnp.int32),
    }


# --- single-cache primitives (vmapped over B) ------------------------------

def _promote(rank2slot, i, t, slot):
    """Move `slot` from rank i to rank t (t <= i), shifting [t, i-1] down."""
    r = jnp.arange(rank2slot.shape[0], dtype=jnp.int32)
    rolled = jnp.roll(rank2slot, 1)
    return jnp.where(r == t, slot,
                     jnp.where((r > t) & (r <= i), rolled, rank2slot))


def _insert_one(rank2slot, free, length, k, jump, jump2, pos, slot_pos):
    """Alg. 2 miss path for one cache; returns new state + chosen slot."""
    jump_m, jump2_m, actual = miss_update(jump, jump2, k)

    full = length >= k
    # victim: bottom-ranked slot (only used when full)
    victim = rank2slot[jnp.maximum(length - 1, 0)]
    # fresh: first free physical slot (only used when not full)
    fresh = jnp.argmax(free).astype(jnp.int32)
    slot = jnp.where(full, victim, fresh)

    t = jnp.maximum(k - actual, 0)
    t = jnp.minimum(t, length)               # no gaps while filling
    bottom = jnp.where(full, length - 1, length)
    rank2slot = _promote(rank2slot, bottom, t, slot)
    free = free.at[slot].set(False)
    slot_pos = slot_pos.at[slot].set(pos)
    length = jnp.where(full, length, length + 1)
    return rank2slot, free, length, jump_m, jump2_m, slot, slot_pos


def _hit_one(rank2slot, length, k, jump, jump2, slot):
    """Alg. 2 hit path: promote `slot` (top-attended) by actualJump."""
    valid = (slot >= 0) & (length > 0)
    eq = rank2slot == slot
    i = jnp.argmax(eq).astype(jnp.int32)
    found = jnp.any(eq) & valid
    jump_h, jump2_h, actual = hit_update(jump, jump2, i, k)
    t = i - actual
    r2s_h = jnp.where(i > 0, _promote(rank2slot, i, t, slot), rank2slot)
    return (jnp.where(found, r2s_h, rank2slot),
            jnp.where(found, jump_h, jump),
            jnp.where(found, jump2_h, jump2))


def _resize_one(rank2slot, free, length, k, jump, jump2, eps, k_min, Bmax,
                cap=None):
    """Alg. 2 lines 2.30-2.38: grow / shrink the active budget.  ``cap``
    (optional, per-sequence) is an external capacity grant — an arbiter
    sharing one global slot pool across the batch — gating the doubling
    at ``min(2k, cap)`` exactly like the tier's budgeted law."""
    k_new, jump, jump2, grow, shrink = resize_update(
        jump, jump2, k, eps=eps, k_min=k_min, kmax=Bmax, cap=cap)

    # shrink: free the physical slots of ranks >= k_new
    r = jnp.arange(rank2slot.shape[0], dtype=jnp.int32)
    evict_mask = shrink & (r >= k_new) & (r < length) & (rank2slot >= 0)
    evicted = jnp.where(evict_mask, rank2slot, 0)
    freed = jnp.zeros_like(free).at[evicted].max(evict_mask)
    free = free | freed
    rank2slot = jnp.where(evict_mask, EMPTY, rank2slot)
    length = jnp.where(shrink, jnp.minimum(length, k_new), length)
    return rank2slot, free, length, k_new, jump, jump2


def insert(ctrl, pos):
    """Batched miss event (new token KV).  pos: [B] logical positions.
    Returns (ctrl, slot [B]) — callers scatter the new KV at `slot`."""
    out = jax.vmap(_insert_one)(
        ctrl["rank2slot"], ctrl["free"], ctrl["length"], ctrl["k_active"],
        ctrl["jump"], ctrl["jump2"], pos, ctrl["slot_pos"])
    r2s, free, length, jump, jump2, slot, slot_pos = out
    new = dict(ctrl, rank2slot=r2s, free=free, length=length, jump=jump,
               jump2=jump2, slot_pos=slot_pos)
    return new, slot


def hit(ctrl, slot):
    """Batched hit event: `slot` [B] = top-attended physical slot (-1 = no
    hit this step)."""
    r2s, jump, jump2 = jax.vmap(_hit_one)(
        ctrl["rank2slot"], ctrl["length"], ctrl["k_active"], ctrl["jump"],
        ctrl["jump2"], slot)
    return dict(ctrl, rank2slot=r2s, jump=jump, jump2=jump2)


def resize(ctrl, eps: float = 0.5, k_min: int = 16, cap=None):
    """Batched DAC resize check (after every request).  ``cap`` ([B] int32,
    optional) threads per-sequence capacity grants from an external
    arbiter — the fleet-serving path where the batch shares one global
    slot budget smaller than ``B * Bmax`` (see ``examples/fleet_decode``);
    ``None`` keeps the paper's un-arbitrated law."""
    Bmax = ctrl["rank2slot"].shape[1]
    if cap is None:
        fn = lambda a, b, c, d, e, f: _resize_one(  # noqa: E731
            a, b, c, d, e, f, eps, k_min, Bmax)
        args = ()
    else:
        fn = lambda a, b, c, d, e, f, g: _resize_one(  # noqa: E731
            a, b, c, d, e, f, eps, k_min, Bmax, cap=g)
        args = (jnp.asarray(cap, jnp.int32),)
    r2s, free, length, k, jump, jump2 = jax.vmap(fn)(
        ctrl["rank2slot"], ctrl["free"], ctrl["length"], ctrl["k_active"],
        ctrl["jump"], ctrl["jump2"], *args)
    return dict(ctrl, rank2slot=r2s, free=free, length=length, k_active=k,
                jump=jump, jump2=jump2)


def valid_slots(ctrl):
    """[B, Bmax] bool — physical slots holding live entries."""
    return ~ctrl["free"]
