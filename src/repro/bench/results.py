"""Canonical versioned result schema for benchmark/sweep outputs.

Two versions coexist (see ``docs/EXPERIMENTS.md`` for the changelog):
``repro.bench.result/v1`` for single-cache sweeps, and
``repro.bench.result/v2`` — a strict superset whose records may carry
tier fields (``arbiter``/``budget``/``n_tenants``) and a ``tenants`` list
of per-tenant sub-records.  Every benchmark emits one JSON payload of
this shape::

    {
      "schema": "repro.bench.result/v1",
      "bench": "<name>",
      "created_unix": <float>,
      "provenance": {"git_sha", "jax", "x64", "backend", "device_count"},
      "config": {...},        # the sweep config (or bench parameters)
      "records": [            # one per grid cell / measurement
        {"metrics": {"miss_ratio": [per-seed floats] | float, ...},
         # standard optional keys, validated when present:
         "policy": str, "scenario": str, "trace": str,
         "T": int, "K": int, "K_label": str, "seeds": [ints],
         "wall_s": float, ...}
      ],
      "extras": {...},        # free-form derived tables (reporting)
      "wall_s": <float>
    }

``validate`` is a hand-rolled structural check (no jsonschema dependency);
``save`` validates before writing so a non-conforming payload never lands
on disk, and ``load`` validates after reading so consumers can trust the
shape.  Provenance stamps every payload with the git SHA, jax version and
the ``jax_enable_x64`` flag — result JSONs are attributable to an exact
code + numerics state.
"""
from __future__ import annotations

import json
import numbers
import os
import subprocess
import time

import jax

__all__ = ["SCHEMA_V1", "SCHEMA_VERSION", "SCHEMA_V2", "SCHEMA_VERSIONS",
           "RESULTS_DIR", "set_results_dir", "atomic_write_json",
           "provenance", "build_payload", "validate", "save", "load"]

# the one home of the schema-version strings: every other module imports
# these constants (``repolint``'s schema-literal rule bans the literals)
SCHEMA_V1 = "repro.bench.result/v1"
# v2 = v1 plus multi-tenant tier cells: records may carry "arbiter" /
# "budget" / "n_tenants" and a "tenants" list of per-tenant sub-records
# ({"tenant": int, "metrics": {...}}, metrics checked like record metrics,
# per-seed lists aligned with the record's seed axis).  Dynamic-fleet
# cells use the same shape with "n_lanes" and a "lanes" list
# ({"lane": int, "metrics": {...}}).  v1 payloads stay valid and are
# still written by the single-cache sweeps.
SCHEMA_V2 = "repro.bench.result/v2"
SCHEMA_VERSION = SCHEMA_V1   # historical alias (pre-v2 name); prefer V1/V2
SCHEMA_VERSIONS = (SCHEMA_V1, SCHEMA_V2)

RESULTS_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


def set_results_dir(path: str) -> str:
    """Redirect the default results directory for this process (what
    ``benchmarks.run --out-dir`` plumbs through): every later
    :func:`save` without an explicit ``results_dir`` writes there, so
    campaign runs and ad-hoc benchmark runs don't interleave JSONs."""
    global RESULTS_DIR
    RESULTS_DIR = str(path)
    return RESULTS_DIR


def atomic_write_json(path: str, payload: dict, *, sort_keys: bool = False,
                      indent: int = 1) -> str:
    """Durably write JSON via temp-file + ``os.replace``: a reader (or a
    crash) never observes a torn file.  Returns ``path``."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=indent, sort_keys=sort_keys)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path

_RECORD_OPTIONAL = {
    "policy": str, "scenario": str, "trace": str, "K_label": str,
    "T": numbers.Integral, "K": numbers.Integral,
    "wall_s": numbers.Real,
}
_RECORD_OPTIONAL_V2 = dict(
    _RECORD_OPTIONAL,
    arbiter=str, budget=numbers.Integral, budget_label=str,
    n_tenants=numbers.Integral, n_lanes=numbers.Integral,
)
_PROVENANCE_KEYS = {"git_sha": str, "jax": str, "x64": bool,
                    "backend": str, "device_count": numbers.Integral}


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def provenance() -> dict:
    """Attribution stamp: exact code + numerics state of this run."""
    return {
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "x64": bool(jax.config.jax_enable_x64),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def build_payload(bench: str, *, config: dict, records: list,
                  extras: dict | None = None,
                  wall_s: float | None = None,
                  schema: str = SCHEMA_V1) -> dict:
    """Assemble (but do not validate) one canonical payload; pass
    ``schema=SCHEMA_V2`` for tier results with per-tenant records.

    >>> p = build_payload("demo", config={}, records=[
    ...     {"metrics": {"miss_ratio": [0.5]}, "seeds": [0]}])
    >>> validate(p)["schema"]
    'repro.bench.result/v1'
    """
    if schema not in SCHEMA_VERSIONS:
        raise ValueError(
            f"unknown schema {schema!r}; known: {list(SCHEMA_VERSIONS)}")
    return {
        "schema": schema,
        "bench": bench,
        # repolint: waive[wallclock] -- provenance stamp, not a timing
        "created_unix": time.time(),
        "provenance": provenance(),
        "config": config,
        "records": records,
        "extras": extras or {},
        "wall_s": 0.0 if wall_s is None else float(wall_s),
    }


def _fail(path: str, msg: str):
    raise ValueError(f"result schema violation at {path}: {msg}")


def _check_metric_value(path, v):
    if isinstance(v, numbers.Real) and not isinstance(v, bool):
        return
    if isinstance(v, list):
        if not v:
            _fail(path, "metric list must be non-empty")
        for i, x in enumerate(v):
            if not isinstance(x, numbers.Real) or isinstance(x, bool):
                _fail(f"{path}[{i}]", f"expected a number, got {type(x).__name__}")
        return
    _fail(path, f"expected a number or list of numbers, got {type(v).__name__}")


def _check_metrics_dict(path: str, metrics, seeds=None):
    if not isinstance(metrics, dict) or not metrics:
        _fail(path, "must be a non-empty dict")
    for k, v in metrics.items():
        if not isinstance(k, str):
            _fail(path, f"metric names must be str, got {k!r}")
        _check_metric_value(f"{path}[{k!r}]", v)
        # per-seed metric lists must line up with the seed axis
        if seeds is not None and isinstance(v, list) and len(v) != len(seeds):
            _fail(f"{path}[{k!r}]",
                  f"length {len(v)} != len(seeds) {len(seeds)}")


def _check_tenants(path: str, tenants, seeds, key: str = "tenant"):
    """v2: per-tenant (or, with ``key="lane"``, per-lane fleet)
    sub-records inside one cell."""
    if not isinstance(tenants, list) or not tenants:
        _fail(path, f"must be a non-empty list of per-{key} records")
    for j, ten in enumerate(tenants):
        tp = f"{path}[{j}]"
        if not isinstance(ten, dict):
            _fail(tp, f"{key} record must be a dict, got {type(ten).__name__}")
        if not isinstance(ten.get(key), numbers.Integral):
            _fail(f"{tp}.{key}", f"missing or non-int {key} index")
        if "metrics" not in ten:
            _fail(tp, f"{key} record missing 'metrics'")
        _check_metrics_dict(f"{tp}.metrics", ten["metrics"], seeds)


def _check_record(path: str, rec, v2: bool = False):
    if not isinstance(rec, dict):
        _fail(path, f"record must be a dict, got {type(rec).__name__}")
    if "metrics" not in rec:
        _fail(path, "record missing 'metrics'")
    seeds = None
    if "seeds" in rec:
        seeds = rec["seeds"]
        if (not isinstance(seeds, list) or
                not all(isinstance(s, numbers.Integral) for s in seeds)):
            _fail(f"{path}.seeds", "must be a list of ints")
    _check_metrics_dict(f"{path}.metrics", rec["metrics"], seeds)
    if "tenants" in rec:
        if not v2:
            _fail(f"{path}.tenants",
                  f"per-tenant records require schema {SCHEMA_V2!r}")
        _check_tenants(f"{path}.tenants", rec["tenants"], seeds)
    if "lanes" in rec:
        if not v2:
            _fail(f"{path}.lanes",
                  f"per-lane fleet records require schema {SCHEMA_V2!r}")
        _check_tenants(f"{path}.lanes", rec["lanes"], seeds, key="lane")
    optional = _RECORD_OPTIONAL_V2 if v2 else _RECORD_OPTIONAL
    for key, typ in optional.items():
        if key in rec and not isinstance(rec[key], typ):
            _fail(f"{path}.{key}",
                  f"expected {typ.__name__}, got {type(rec[key]).__name__}")


def validate(payload: dict) -> dict:
    """Structurally validate a result payload; returns it unchanged.
    Raises ``ValueError`` naming the offending path otherwise."""
    if not isinstance(payload, dict):
        _fail("$", f"payload must be a dict, got {type(payload).__name__}")
    if payload.get("schema") not in SCHEMA_VERSIONS:
        _fail("$.schema",
              f"expected one of {list(SCHEMA_VERSIONS)}, "
              f"got {payload.get('schema')!r}")
    for key, typ in (("bench", str), ("created_unix", numbers.Real),
                     ("provenance", dict), ("config", dict),
                     ("records", list), ("extras", dict),
                     ("wall_s", numbers.Real)):
        if key not in payload:
            _fail(f"$.{key}", "missing")
        if not isinstance(payload[key], typ):
            _fail(f"$.{key}", f"expected {typ.__name__}, "
                              f"got {type(payload[key]).__name__}")
    prov = payload["provenance"]
    for key, typ in _PROVENANCE_KEYS.items():
        if key not in prov:
            _fail(f"$.provenance.{key}", "missing")
        if not isinstance(prov[key], typ):
            _fail(f"$.provenance.{key}", f"expected {typ.__name__}, "
                                         f"got {type(prov[key]).__name__}")
    v2 = payload["schema"] == SCHEMA_V2
    for i, rec in enumerate(payload["records"]):
        _check_record(f"$.records[{i}]", rec, v2=v2)
    return payload


def save(payload: dict, *, results_dir: str | None = None) -> str:
    """Validate and write ``<results_dir>/<bench>.json`` (atomically, via
    :func:`atomic_write_json`); returns the path."""
    validate(payload)
    out_dir = RESULTS_DIR if results_dir is None else results_dir
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{payload['bench']}.json")
    return atomic_write_json(path, payload)


def load(path: str) -> dict:
    """Read and validate one result payload."""
    with open(path) as f:
        return validate(json.load(f))
