"""Canonical versioned result schema for benchmark/sweep outputs.

Every benchmark emits one JSON payload of this shape::

    {
      "schema": "repro.bench.result/v1",
      "bench": "<name>",
      "created_unix": <float>,
      "provenance": {"git_sha", "jax", "x64", "backend", "device_count"},
      "config": {...},        # the sweep config (or bench parameters)
      "records": [            # one per grid cell / measurement
        {"metrics": {"miss_ratio": [per-seed floats] | float, ...},
         # standard optional keys, validated when present:
         "policy": str, "scenario": str, "trace": str,
         "T": int, "K": int, "K_label": str, "seeds": [ints],
         "wall_s": float, ...}
      ],
      "extras": {...},        # free-form derived tables (reporting)
      "wall_s": <float>
    }

``validate`` is a hand-rolled structural check (no jsonschema dependency);
``save`` validates before writing so a non-conforming payload never lands
on disk, and ``load`` validates after reading so consumers can trust the
shape.  Provenance stamps every payload with the git SHA, jax version and
the ``jax_enable_x64`` flag — result JSONs are attributable to an exact
code + numerics state.
"""
from __future__ import annotations

import json
import numbers
import os
import subprocess
import time

import jax

__all__ = ["SCHEMA_VERSION", "RESULTS_DIR", "provenance", "build_payload",
           "validate", "save", "load"]

SCHEMA_VERSION = "repro.bench.result/v1"

RESULTS_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

_RECORD_OPTIONAL = {
    "policy": str, "scenario": str, "trace": str, "K_label": str,
    "T": numbers.Integral, "K": numbers.Integral,
    "wall_s": numbers.Real,
}
_PROVENANCE_KEYS = {"git_sha": str, "jax": str, "x64": bool,
                    "backend": str, "device_count": numbers.Integral}


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def provenance() -> dict:
    """Attribution stamp: exact code + numerics state of this run."""
    return {
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "x64": bool(jax.config.jax_enable_x64),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
    }


def build_payload(bench: str, *, config: dict, records: list,
                  extras: dict | None = None,
                  wall_s: float | None = None) -> dict:
    return {
        "schema": SCHEMA_VERSION,
        "bench": bench,
        "created_unix": time.time(),
        "provenance": provenance(),
        "config": config,
        "records": records,
        "extras": extras or {},
        "wall_s": 0.0 if wall_s is None else float(wall_s),
    }


def _fail(path: str, msg: str):
    raise ValueError(f"result schema violation at {path}: {msg}")


def _check_metric_value(path, v):
    if isinstance(v, numbers.Real) and not isinstance(v, bool):
        return
    if isinstance(v, list):
        if not v:
            _fail(path, "metric list must be non-empty")
        for i, x in enumerate(v):
            if not isinstance(x, numbers.Real) or isinstance(x, bool):
                _fail(f"{path}[{i}]", f"expected a number, got {type(x).__name__}")
        return
    _fail(path, f"expected a number or list of numbers, got {type(v).__name__}")


def _check_record(path: str, rec):
    if not isinstance(rec, dict):
        _fail(path, f"record must be a dict, got {type(rec).__name__}")
    if "metrics" not in rec:
        _fail(path, "record missing 'metrics'")
    metrics = rec["metrics"]
    if not isinstance(metrics, dict) or not metrics:
        _fail(f"{path}.metrics", "must be a non-empty dict")
    for k, v in metrics.items():
        if not isinstance(k, str):
            _fail(f"{path}.metrics", f"metric names must be str, got {k!r}")
        _check_metric_value(f"{path}.metrics[{k!r}]", v)
    if "seeds" in rec:
        seeds = rec["seeds"]
        if (not isinstance(seeds, list) or
                not all(isinstance(s, numbers.Integral) for s in seeds)):
            _fail(f"{path}.seeds", "must be a list of ints")
        # per-seed metric lists must line up with the seed axis
        for k, v in metrics.items():
            if isinstance(v, list) and len(v) != len(seeds):
                _fail(f"{path}.metrics[{k!r}]",
                      f"length {len(v)} != len(seeds) {len(seeds)}")
    for key, typ in _RECORD_OPTIONAL.items():
        if key in rec and not isinstance(rec[key], typ):
            _fail(f"{path}.{key}",
                  f"expected {typ.__name__}, got {type(rec[key]).__name__}")


def validate(payload: dict) -> dict:
    """Structurally validate a result payload; returns it unchanged.
    Raises ``ValueError`` naming the offending path otherwise."""
    if not isinstance(payload, dict):
        _fail("$", f"payload must be a dict, got {type(payload).__name__}")
    if payload.get("schema") != SCHEMA_VERSION:
        _fail("$.schema",
              f"expected {SCHEMA_VERSION!r}, got {payload.get('schema')!r}")
    for key, typ in (("bench", str), ("created_unix", numbers.Real),
                     ("provenance", dict), ("config", dict),
                     ("records", list), ("extras", dict),
                     ("wall_s", numbers.Real)):
        if key not in payload:
            _fail(f"$.{key}", "missing")
        if not isinstance(payload[key], typ):
            _fail(f"$.{key}", f"expected {typ.__name__}, "
                              f"got {type(payload[key]).__name__}")
    prov = payload["provenance"]
    for key, typ in _PROVENANCE_KEYS.items():
        if key not in prov:
            _fail(f"$.provenance.{key}", "missing")
        if not isinstance(prov[key], typ):
            _fail(f"$.provenance.{key}", f"expected {typ.__name__}, "
                                         f"got {type(prov[key]).__name__}")
    for i, rec in enumerate(payload["records"]):
        _check_record(f"$.records[{i}]", rec)
    return payload


def save(payload: dict, *, results_dir: str | None = None) -> str:
    """Validate and write ``<results_dir>/<bench>.json``; returns the path."""
    validate(payload)
    out_dir = RESULTS_DIR if results_dir is None else results_dir
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{payload['bench']}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load(path: str) -> dict:
    """Read and validate one result payload."""
    with open(path) as f:
        return validate(json.load(f))
