"""Grid execution: a Sweep runs through the Engine, seeds vmapped.

For every (policy, scenario, K) cell the runner builds one ``[S, T]``
request batch (S = the sweep's seed axis) and issues a *single*
``Engine.replay`` call — the seeds replay as parallel vmapped cache lanes
inside one jitted program (metrics-only: totals reduce in the scan carry,
no ``[T]`` StepInfo ever materializes), instead of a Python loop over
seeds.  Pass ``mesh=`` (or an Engine built with one) to shard the seed
axis over devices, and ``use_pallas=True`` to route rank policies through
the fused Pallas policy-step kernel — both knobs reach every cell.

The output is a list of flat, JSON-able records (one per cell, per-seed
metric lists) wrapped in a :class:`SweepResult` that renders the canonical
payload of :mod:`repro.bench.results`.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from ..core import Engine
from ..core.policy import Request
from . import report, results
from .scenario import Scenario, Sweep, TierScenario, TierSweep

__all__ = ["materialize", "run_sweep", "SweepResult",
           "run_tier_sweep", "TierSweepResult"]


def materialize(scenario, seeds) -> Request:
    """Build the ``[S, T]`` request batch for one scenario: traces from
    the registry (one lane per seed) with the scenario's size/cost tables
    gathered per request.  A :class:`TierScenario` materializes the same
    way, one ``[T, N]`` interleaved stream per seed (``[S, T, N]``).

    >>> sc = Scenario("z", trace="zipf(N=64,alpha=1.0)", T=50, K=(8,))
    >>> materialize(sc, seeds=(0, 1)).key.shape
    (2, 50)
    """
    spec = scenario.trace_spec()
    keys = spec.generate_batch(scenario.T, seeds)
    sizes = scenario.size_table()
    if sizes is None:
        return Request.of(keys)
    costs = scenario.cost_table(sizes)
    return Request.of(keys, sizes=sizes[keys],
                      costs=None if costs is None else costs[keys])


def _per_seed(x) -> list:
    return [float(v) for v in np.atleast_1d(np.asarray(x))]


def _cell_record(pol, sc, K, k_label, seeds, res, wall_s) -> dict:
    metrics = {
        "miss_ratio": _per_seed(res.miss_ratio),
        "hit_ratio": _per_seed(res.hit_ratio),
        "byte_miss_ratio": _per_seed(res.byte_miss_ratio),
        "penalty_ratio": _per_seed(res.penalty_ratio),
    }
    if res.obs is not None and "k" in res.obs:
        # adaptive policies: time-mean of the adapted cache size per seed
        metrics["avg_k"] = _per_seed(
            np.asarray(res.obs["k"], dtype=np.float64).mean(axis=-1))
    return {
        "policy": pol, "scenario": sc.name, "trace": sc.trace,
        "T": int(sc.T), "K": int(K), "K_label": k_label,
        "seeds": [int(s) for s in seeds],
        "metrics": metrics, "wall_s": float(wall_s),
    }


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Executed sweep: the config that produced it + one record per cell."""

    sweep: Sweep
    records: list
    wall_s: float

    def select(self, **eq) -> list:
        """Records whose fields equal every given keyword (e.g.
        ``select(policy="lru", scenario="wiki", K_label="S")``)."""
        return report.select(self.records, **eq)

    def metric(self, name: str, **eq) -> np.ndarray:
        """Per-seed values of one metric for the single matching record."""
        return report.seed_values(self.records, name, **eq)

    def payload(self, extras: dict | None = None) -> dict:
        return results.build_payload(
            self.sweep.name, config=self.sweep.to_config(),
            records=self.records, extras=extras, wall_s=self.wall_s)

    def save(self, extras: dict | None = None, *,
             results_dir: str | None = None) -> dict:
        """Validate + write the canonical payload; returns it."""
        payload = self.payload(extras)
        results.save(payload, results_dir=results_dir)
        return payload


def _tier_cell_record(pol, arb, sc, B, label, seeds, res, wall_s) -> dict:
    """One v2 record: aggregate (byte-/cost-weighted) tier metrics plus a
    per-tenant sub-record list."""
    n = sc.n_tenants
    agg = {
        "miss_ratio": _per_seed(res.agg_miss_ratio),
        "byte_miss_ratio": _per_seed(res.agg_byte_miss_ratio),
        "penalty_ratio": _per_seed(res.agg_penalty_ratio),
        "avg_k_total": _per_seed(
            np.asarray(res.avg_k, dtype=np.float64).sum(axis=-1)),
    }
    per_tenant = {
        "miss_ratio": np.atleast_2d(np.asarray(res.miss_ratio)),
        "byte_miss_ratio": np.atleast_2d(np.asarray(res.byte_miss_ratio)),
        "avg_k": np.atleast_2d(np.asarray(res.avg_k, dtype=np.float64)),
    }
    tenants = [
        {"tenant": t,
         "metrics": {name: [float(v) for v in vals[:, t]]
                     for name, vals in per_tenant.items()}}
        for t in range(n)]
    return {
        "policy": pol, "arbiter": arb, "scenario": sc.name,
        "trace": sc.trace, "T": int(sc.T), "budget": int(B),
        "budget_label": label, "n_tenants": n,
        "seeds": [int(s) for s in seeds],
        "metrics": agg, "tenants": tenants, "wall_s": float(wall_s),
    }


@dataclasses.dataclass(frozen=True)
class TierSweepResult:
    """Executed tier sweep: config + one v2 record per grid cell."""

    sweep: TierSweep
    records: list
    wall_s: float

    def select(self, **eq) -> list:
        return report.select(self.records, **eq)

    def metric(self, name: str, **eq) -> np.ndarray:
        return report.seed_values(self.records, name, **eq)

    def payload(self, extras: dict | None = None) -> dict:
        return results.build_payload(
            self.sweep.name, config=self.sweep.to_config(),
            records=self.records, extras=extras, wall_s=self.wall_s,
            schema=results.SCHEMA_V2)

    def save(self, extras: dict | None = None, *,
             results_dir: str | None = None) -> dict:
        payload = self.payload(extras)
        results.save(payload, results_dir=results_dir)
        return payload


def run_tier_sweep(sweep: TierSweep, *, engine: Engine | None = None,
                   use_pallas: bool | None = None,
                   progress=None) -> TierSweepResult:
    """Execute every tier cell: one ``[S, T, N]`` batch per scenario
    (shared across entries and budgets), one seed-vmapped
    ``Engine.replay_tier`` call per (policy, arbiter, budget) cell,
    emitting :data:`repro.bench.results.SCHEMA_V2` records.

    >>> sw = TierSweep("doc", entries=(("dac", "greedy"),), seeds=(0,),
    ...                scenarios=(TierScenario(
    ...                    "flux", trace="tenants(N=64,n_tenants=2,lo=8)",
    ...                    T=300, budget=(32,)),))
    >>> rec = run_tier_sweep(sw).records[0]
    >>> rec["n_tenants"], len(rec["tenants"]), rec["budget"]
    (2, 2, 32)
    """
    from ..tier import CacheTier
    engine = engine or Engine()
    t_start = time.perf_counter()
    records = []
    reqs_cache = {}
    for pol, arb, sc, B, label in sweep.cells():
        if sc.name not in reqs_cache:
            reqs_cache[sc.name] = materialize(sc, sweep.seeds)
        reqs = reqs_cache[sc.name]
        tier = CacheTier(pol, n_tenants=sc.n_tenants, budget=B,
                         arbiter=arb, k0=sc.k0)
        t0 = time.perf_counter()
        res = engine.replay_tier(tier, reqs, use_pallas=use_pallas)
        jax.block_until_ready(res.metrics.hits)
        wall = time.perf_counter() - t0
        records.append(_tier_cell_record(pol, arb, sc, B, label,
                                         sweep.seeds, res, wall))
        if progress is not None:
            mr = np.mean(records[-1]["metrics"]["byte_miss_ratio"])
            progress(f"[{sweep.name}] {sc.name} B={B}({label}) "
                     f"{pol}+{arb}: byte_miss={mr:.3f} [{wall:.2f}s]")
    return TierSweepResult(sweep=sweep, records=records,
                           wall_s=time.perf_counter() - t_start)


def run_sweep(sweep: Sweep, *, engine: Engine | None = None,
              mesh=None, use_pallas: bool | None = None,
              progress=None) -> SweepResult:
    """Execute every cell of ``sweep`` through the Engine.

    Each scenario's ``[S, T]`` request batch is materialized once and
    shared across its policies and capacities; each cell is one vmapped
    metrics-only replay.  ``progress`` (e.g. ``print``) receives a line
    per cell.

    >>> sw = Sweep("doc", policies=("lru",), seeds=(0,),
    ...            scenarios=(Scenario("z", trace="zipf(N=64,alpha=1.0)",
    ...                                T=200, K=(8,)),))
    >>> res = run_sweep(sw)
    >>> sorted(res.records[0]["metrics"])
    ['byte_miss_ratio', 'hit_ratio', 'miss_ratio', 'penalty_ratio']
    """
    engine = engine or Engine(mesh=mesh)
    t_start = time.perf_counter()
    records = []
    reqs_cache = {}
    for pol, sc, K, k_label in sweep.cells():
        if sc.name not in reqs_cache:
            reqs_cache[sc.name] = materialize(sc, sweep.seeds)
        reqs = reqs_cache[sc.name]
        t0 = time.perf_counter()
        res = engine.replay(pol, reqs, K, observe=sweep.observe,
                            collect_info=False, mesh=mesh,
                            use_pallas=use_pallas)
        jax.block_until_ready(res.metrics.hits)
        wall = time.perf_counter() - t0
        records.append(_cell_record(pol, sc, K, k_label, sweep.seeds,
                                    res, wall))
        if progress is not None:
            mr = np.mean(records[-1]["metrics"]["miss_ratio"])
            progress(f"[{sweep.name}] {sc.name} K={K}({k_label}) "
                     f"{pol}: miss={mr:.3f} [{wall:.2f}s]")
    return SweepResult(sweep=sweep, records=records,
                       wall_s=time.perf_counter() - t_start)
