"""Grid execution: a Sweep runs through the Engine, seeds vmapped.

For every (policy, scenario, K) cell the runner builds one ``[S, T]``
request batch (S = the sweep's seed axis) and issues a *single*
``Engine.replay`` call — the seeds replay as parallel vmapped cache lanes
inside one jitted program (metrics-only: totals reduce in the scan carry,
no ``[T]`` StepInfo ever materializes), instead of a Python loop over
seeds.  Pass ``mesh=`` (or an Engine built with one) to shard the seed
axis over devices, and ``use_pallas`` (``False`` / ``"interpret"`` /
``"compiled"``, or ``True`` for the per-backend default) to route rank
policies through the fused Pallas policy-step kernel — both knobs reach
every cell.

Two execution paths per cell, producing identical records (bit-for-bit
whenever the float32 byte/cost running sums are exact — always for the
integer count/ratio metrics; see :func:`run_sweep` for the fine print):

* *materialized* — the whole ``[S, T]`` batch lives on device
  (``Engine.replay``);
* *streaming* — the cell replays through ``Engine.replay_stream`` in
  fixed-size ``[S, chunk]`` slices with donated state buffers: device
  memory is O(K + chunk), and file-backed traces
  (``trace="file(path=...)"``) are read straight off disk chunk by chunk
  (``repro.data.ingest.iter_chunks``), never fully resident.

``run_sweep(stream="auto")`` picks streaming when a scenario is
file-backed or its ``T`` exceeds :data:`STREAM_THRESHOLD`
(:func:`should_stream`); ``stream=True`` / ``False`` forces a path.

The output is a list of flat, JSON-able records (one per cell, per-seed
metric lists) wrapped in a :class:`SweepResult` that renders the canonical
payload of :mod:`repro.bench.results`.
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import numpy as np

from ..core import Engine
from ..core.policy import Request
from ..data import ingest
from . import report, results
from .scenario import (FleetScenario, FleetSweep, Scenario, Sweep,
                       TierScenario, TierSweep)

__all__ = ["materialize", "run_sweep", "SweepResult",
           "run_tier_sweep", "TierSweepResult",
           "run_fleet_sweep", "FleetSweepResult",
           "should_stream", "stream_chunks", "STREAM_THRESHOLD"]

# per-lane trace length above which run_sweep(stream="auto") switches a
# synthetic scenario to the streaming path (file-backed scenarios always
# stream): past ~half a million requests the [S, T] device batch and the
# scan's working set start to dominate device memory
STREAM_THRESHOLD = 1 << 19


def _file_parts(spec):
    return spec.kwargs["path"], spec.kwargs.get("format", "auto")


def _tile(x, S):
    """Lay a per-request column out across S identical seed lanes."""
    return None if x is None else np.broadcast_to(x, (S,) + x.shape)


def materialize(scenario, seeds) -> Request:
    """Build the ``[S, T]`` request batch for one scenario: traces from
    the registry (one lane per seed) with the scenario's size/cost tables
    gathered per request.  A :class:`TierScenario` materializes the same
    way, one ``[T, N]`` interleaved stream per seed (``[S, T, N]``); so
    does a :class:`FleetScenario` (its ``-1`` idle-lane keys gather the
    size/cost table's last entry — harmless: the fleet replay masks every
    idle-lane contribution).  File-backed scenarios replicate the real
    trace across the seed lanes, sizes/costs sourced from the file.

    >>> sc = Scenario("z", trace="zipf(N=64,alpha=1.0)", T=50, K=(8,))
    >>> materialize(sc, seeds=(0, 1)).key.shape
    (2, 50)
    """
    spec = scenario.trace_spec()
    if spec.is_file:
        path, fmt = _file_parts(spec)
        tr = ingest.load_trace(path, fmt, limit=scenario.T)
        S = len(tuple(seeds))
        return Request.of(_tile(tr.keys, S), sizes=_tile(tr.sizes, S),
                          costs=_tile(tr.costs, S))
    keys, sizes, costs = _synthetic_host(scenario, seeds)
    if sizes is None:
        return Request.of(keys)
    return Request.of(keys, sizes=sizes[keys],
                      costs=None if costs is None else costs[keys])


def should_stream(scenario, stream="auto", *,
                  threshold: int = STREAM_THRESHOLD) -> bool:
    """Resolve the execution path for one scenario: ``True`` / ``False``
    pass through; ``"auto"`` streams file-backed scenarios (out-of-core
    by construction) and any whose ``T`` exceeds ``threshold``.  Anything
    else (e.g. the string ``"false"``) is an error, not a truthy
    surprise.

    >>> sc = Scenario("z", trace="zipf(N=64,alpha=1.0)", T=50, K=(8,))
    >>> should_stream(sc), should_stream(sc, True)
    (False, True)
    >>> should_stream(sc, threshold=10)
    True
    """
    if isinstance(stream, str) and stream == "auto":
        return scenario.trace_spec().is_file or scenario.T > threshold
    if not isinstance(stream, bool):
        raise ValueError(
            f"stream must be True, False or 'auto', got {stream!r}")
    return stream


def _synthetic_host(scenario, seeds):
    """Host-side ``([S, T] keys, size table, cost table)`` of a synthetic
    scenario — the arrays :func:`stream_chunks` slices."""
    spec = scenario.trace_spec()
    keys = spec.generate_batch(scenario.T, seeds)
    sizes = scenario.size_table()
    costs = None if sizes is None else scenario.cost_table(sizes)
    return keys, sizes, costs


def _slice_host(host, T, chunk):
    keys, sizes, costs = host
    for lo in range(0, T, chunk):
        k = keys[:, lo:lo + chunk]
        yield Request.of(k, sizes=None if sizes is None else sizes[k],
                         costs=None if costs is None else costs[k])


def stream_chunks(scenario, seeds, chunk: int = ingest.DEFAULT_CHUNK,
                  _host=None):
    """Yield the ``[S, c]`` :class:`Request` chunks of one scenario for
    ``Engine.replay_stream`` — the same requests :func:`materialize`
    builds, sliced into ``chunk``-request pieces.  File-backed traces are
    read off disk chunk by chunk (memory-mapped where possible) and
    replicated across the seed lanes; synthetic traces are generated on
    the host and sliced.

    >>> sc = Scenario("z", trace="zipf(N=64,alpha=1.0)", T=50, K=(8,))
    >>> [c.key.shape for c in stream_chunks(sc, seeds=(0, 1), chunk=32)]
    [(2, 32), (2, 18)]
    """
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    spec = scenario.trace_spec()
    S = len(tuple(seeds))
    if spec.is_file:
        path, fmt = _file_parts(spec)
        if spec.n_requests <= STREAM_THRESHOLD:
            # small file: slice the (lru-cached) materialized load so a
            # grid of cells decodes the file once, not once per cell;
            # device memory is still O(chunk)
            tr = ingest.load_trace(path, fmt, limit=scenario.T)
            for lo in range(0, len(tr.keys), chunk):
                cut = lambda x: (None if x is None
                                 else _tile(x[lo:lo + chunk], S))
                yield Request.of(cut(tr.keys), sizes=cut(tr.sizes),
                                 costs=cut(tr.costs))
            return
        # past the threshold the whole point is out-of-core: re-read
        # chunk by chunk, never holding the decoded trace in host memory
        for ch in ingest.iter_chunks(path, fmt, chunk=chunk,
                                     limit=scenario.T):
            yield Request.of(_tile(ch.keys, S), sizes=_tile(ch.sizes, S),
                             costs=_tile(ch.costs, S))
        return
    host = _synthetic_host(scenario, seeds) if _host is None else _host
    yield from _slice_host(host, scenario.T, chunk)


def _per_seed(x) -> list:
    return [float(v) for v in np.atleast_1d(np.asarray(x))]


def _avg_k(res, streamed: bool):
    """Per-seed time-mean adapted size, whichever path produced ``res``:
    the streaming path already carries time means in ``obs``; the
    materialized path stacks per-step observables to average.  Identical
    for integer observables (64-bit sums of exact values either way)."""
    if res.obs is None or "k" not in res.obs:
        return None
    k = np.asarray(res.obs["k"], dtype=np.float64)
    return k if streamed else k.mean(axis=-1)


def _cell_record(pol, sc, K, k_label, seeds, res, wall_s,
                 avg_k=None) -> dict:
    metrics = {
        "miss_ratio": _per_seed(res.miss_ratio),
        "hit_ratio": _per_seed(res.hit_ratio),
        "byte_miss_ratio": _per_seed(res.byte_miss_ratio),
        "penalty_ratio": _per_seed(res.penalty_ratio),
    }
    if avg_k is not None:
        # adaptive policies: time-mean of the adapted cache size per seed
        metrics["avg_k"] = _per_seed(avg_k)
    return {
        "policy": pol, "scenario": sc.name, "trace": sc.trace,
        "T": int(sc.T), "K": int(K), "K_label": k_label,
        "seeds": [int(s) for s in seeds],
        "metrics": metrics, "wall_s": float(wall_s),
    }


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Executed sweep: the config that produced it + one record per cell."""

    sweep: Sweep
    records: list
    wall_s: float

    def select(self, **eq) -> list:
        """Records whose fields equal every given keyword (e.g.
        ``select(policy="lru", scenario="wiki", K_label="S")``)."""
        return report.select(self.records, **eq)

    def metric(self, name: str, **eq) -> np.ndarray:
        """Per-seed values of one metric for the single matching record."""
        return report.seed_values(self.records, name, **eq)

    def payload(self, extras: dict | None = None, *,
                schema: str = results.SCHEMA_V1) -> dict:
        return results.build_payload(
            self.sweep.name, config=self.sweep.to_config(),
            records=self.records, extras=extras, wall_s=self.wall_s,
            schema=schema)

    def save(self, extras: dict | None = None, *,
             results_dir: str | None = None,
             schema: str = results.SCHEMA_V1) -> dict:
        """Validate + write the canonical payload; returns it."""
        payload = self.payload(extras, schema=schema)
        results.save(payload, results_dir=results_dir)
        return payload


def _tier_cell_record(pol, arb, sc, B, label, seeds, res, wall_s) -> dict:
    """One v2 record: aggregate (byte-/cost-weighted) tier metrics plus a
    per-tenant sub-record list."""
    n = sc.n_tenants
    agg = {
        "miss_ratio": _per_seed(res.agg_miss_ratio),
        "byte_miss_ratio": _per_seed(res.agg_byte_miss_ratio),
        "penalty_ratio": _per_seed(res.agg_penalty_ratio),
        "avg_k_total": _per_seed(
            np.asarray(res.avg_k, dtype=np.float64).sum(axis=-1)),
    }
    per_tenant = {
        "miss_ratio": np.atleast_2d(np.asarray(res.miss_ratio)),
        "byte_miss_ratio": np.atleast_2d(np.asarray(res.byte_miss_ratio)),
        "avg_k": np.atleast_2d(np.asarray(res.avg_k, dtype=np.float64)),
    }
    tenants = [
        {"tenant": t,
         "metrics": {name: [float(v) for v in vals[:, t]]
                     for name, vals in per_tenant.items()}}
        for t in range(n)]
    return {
        "policy": pol, "arbiter": arb, "scenario": sc.name,
        "trace": sc.trace, "T": int(sc.T), "budget": int(B),
        "budget_label": label, "n_tenants": n,
        "seeds": [int(s) for s in seeds],
        "metrics": agg, "tenants": tenants, "wall_s": float(wall_s),
    }


@dataclasses.dataclass(frozen=True)
class TierSweepResult:
    """Executed tier sweep: config + one v2 record per grid cell."""

    sweep: TierSweep
    records: list
    wall_s: float

    def select(self, **eq) -> list:
        return report.select(self.records, **eq)

    def metric(self, name: str, **eq) -> np.ndarray:
        return report.seed_values(self.records, name, **eq)

    def payload(self, extras: dict | None = None) -> dict:
        return results.build_payload(
            self.sweep.name, config=self.sweep.to_config(),
            records=self.records, extras=extras, wall_s=self.wall_s,
            schema=results.SCHEMA_V2)

    def save(self, extras: dict | None = None, *,
             results_dir: str | None = None) -> dict:
        payload = self.payload(extras)
        results.save(payload, results_dir=results_dir)
        return payload


def run_tier_sweep(sweep: TierSweep, *, engine: Engine | None = None,
                   use_pallas=None,
                   progress=None) -> TierSweepResult:
    """Execute every tier cell: one ``[S, T, N]`` batch per scenario
    (shared across entries and budgets), one seed-vmapped
    ``Engine.replay_tier`` call per (policy, arbiter, budget) cell,
    emitting :data:`repro.bench.results.SCHEMA_V2` records.

    >>> sw = TierSweep("doc", entries=(("dac", "greedy"),), seeds=(0,),
    ...                scenarios=(TierScenario(
    ...                    "flux", trace="tenants(N=64,n_tenants=2,lo=8)",
    ...                    T=300, budget=(32,)),))
    >>> rec = run_tier_sweep(sw).records[0]
    >>> rec["n_tenants"], len(rec["tenants"]), rec["budget"]
    (2, 2, 32)
    """
    from ..tier import CacheTier
    engine = engine or Engine()
    t_start = time.perf_counter()
    records = []
    reqs_cache = {}
    for pol, arb, sc, B, label in sweep.cells():
        if sc.name not in reqs_cache:
            reqs_cache[sc.name] = materialize(sc, sweep.seeds)
        reqs = reqs_cache[sc.name]
        tier = CacheTier(pol, n_tenants=sc.n_tenants, budget=B,
                         arbiter=arb, k0=sc.k0)
        t0 = time.perf_counter()
        res = engine.replay_tier(tier, reqs, use_pallas=use_pallas)
        jax.block_until_ready(res.metrics.hits)
        wall = time.perf_counter() - t0
        records.append(_tier_cell_record(pol, arb, sc, B, label,
                                         sweep.seeds, res, wall))
        if progress is not None:
            mr = np.mean(records[-1]["metrics"]["byte_miss_ratio"])
            progress(f"[{sweep.name}] {sc.name} B={B}({label}) "
                     f"{pol}+{arb}: byte_miss={mr:.3f} [{wall:.2f}s]")
    return TierSweepResult(sweep=sweep, records=records,
                           wall_s=time.perf_counter() - t_start)


def _fleet_cell_record(pol, arb, sc, B, label, seeds, res, wall_s) -> dict:
    """One v2 record: aggregate fleet metrics + SLO telemetry (penalty
    p50/p99, Jain occupancy fairness) plus a per-lane sub-record list."""
    n = sc.n_lanes
    hist = np.asarray(res.hist, np.float64)
    agg = {
        "miss_ratio": _per_seed(res.agg_miss_ratio),
        "byte_miss_ratio": _per_seed(res.agg_byte_miss_ratio),
        "penalty_ratio": _per_seed(res.agg_penalty_ratio),
        "avg_k_total": _per_seed(
            np.asarray(res.avg_k, dtype=np.float64).sum(axis=-1)),
        "penalty_p50": _per_seed(res.agg_penalty_quantile(0.5)),
        "penalty_p99": _per_seed(res.agg_penalty_quantile(0.99)),
        "jain": _per_seed(res.jain),
    }
    per_lane = {
        "miss_ratio": np.atleast_2d(np.asarray(res.miss_ratio)),
        "byte_miss_ratio": np.atleast_2d(np.asarray(res.byte_miss_ratio)),
        "avg_k": np.atleast_2d(np.asarray(res.avg_k, dtype=np.float64)),
        "alive_frac": np.atleast_2d(
            np.asarray(res.alive_frac, dtype=np.float64)),
        "penalty_p99": np.atleast_2d(res.penalty_quantile(0.99)),
        "requests": np.atleast_2d(
            np.asarray(res.metrics.requests, dtype=np.float64)),
    }
    lanes = [
        {"lane": t,
         "metrics": {name: [float(v) for v in vals[:, t]]
                     for name, vals in per_lane.items()}}
        for t in range(n)]
    return {
        "policy": pol, "arbiter": arb, "scenario": sc.name,
        "trace": sc.trace, "T": int(sc.T), "budget": int(B),
        "budget_label": label, "n_lanes": n,
        "seeds": [int(s) for s in seeds],
        "metrics": agg, "lanes": lanes, "wall_s": float(wall_s),
    }


@dataclasses.dataclass(frozen=True)
class FleetSweepResult:
    """Executed fleet sweep: config + one v2 record per grid cell."""

    sweep: FleetSweep
    records: list
    wall_s: float

    def select(self, **eq) -> list:
        return report.select(self.records, **eq)

    def metric(self, name: str, **eq) -> np.ndarray:
        return report.seed_values(self.records, name, **eq)

    def payload(self, extras: dict | None = None) -> dict:
        return results.build_payload(
            self.sweep.name, config=self.sweep.to_config(),
            records=self.records, extras=extras, wall_s=self.wall_s,
            schema=results.SCHEMA_V2)

    def save(self, extras: dict | None = None, *,
             results_dir: str | None = None) -> dict:
        payload = self.payload(extras)
        results.save(payload, results_dir=results_dir)
        return payload


def run_fleet_sweep(sweep: FleetSweep, *, engine: Engine | None = None,
                    use_pallas=None,
                    progress=None) -> FleetSweepResult:
    """Execute every fleet cell: one ``[S, T, N]`` batch per scenario
    (shared across entries and budgets), one seed-vmapped
    ``Engine.replay_fleet`` call per (policy, arbiter, budget) cell,
    emitting :data:`repro.bench.results.SCHEMA_V2` records with per-lane
    SLO telemetry (penalty p50/p99 from the in-carry histograms, Jain
    occupancy fairness).

    >>> sw = FleetSweep("doc", entries=(("dac(k_min=4)", "auction"),),
    ...                 seeds=(0,), scenarios=(FleetScenario(
    ...                     "pool", trace="fleet(N=64,n_lanes=2,rate=0.05,"
    ...                     "mean_session=100,lo=8)", T=300, budget=(32,)),))
    >>> rec = run_fleet_sweep(sw).records[0]
    >>> rec["n_lanes"], len(rec["lanes"]), rec["budget"]
    (2, 2, 32)
    >>> sorted(rec["metrics"])[:3]
    ['avg_k_total', 'byte_miss_ratio', 'jain']
    """
    from ..fleet import FleetTier
    engine = engine or Engine()
    t_start = time.perf_counter()
    records = []
    reqs_cache = {}
    for pol, arb, sc, B, label in sweep.cells():
        if sc.name not in reqs_cache:
            reqs_cache[sc.name] = materialize(sc, sweep.seeds)
        reqs = reqs_cache[sc.name]
        tier = FleetTier(pol, n_lanes=sc.n_lanes, budget=B, arbiter=arb,
                         k0=sc.k0, util_decay=sc.util_decay)
        t0 = time.perf_counter()
        res = engine.replay_fleet(tier, reqs, use_pallas=use_pallas)
        jax.block_until_ready(res.metrics.hits)
        wall = time.perf_counter() - t0
        records.append(_fleet_cell_record(pol, arb, sc, B, label,
                                          sweep.seeds, res, wall))
        if progress is not None:
            mr = np.mean(records[-1]["metrics"]["byte_miss_ratio"])
            progress(f"[{sweep.name}] {sc.name} B={B}({label}) "
                     f"{pol}+{arb}: byte_miss={mr:.3f} [{wall:.2f}s]")
    return FleetSweepResult(sweep=sweep, records=records,
                            wall_s=time.perf_counter() - t_start)


def run_sweep(sweep: Sweep, *, engine: Engine | None = None,
              mesh=None, use_pallas=None,
              stream="auto", chunk: int = ingest.DEFAULT_CHUNK,
              progress=None) -> SweepResult:
    """Execute every cell of ``sweep`` through the Engine.

    Materialized cells share one ``[S, T]`` request batch per scenario
    across policies and capacities; each cell is one vmapped metrics-only
    replay.  Streaming cells (``stream=True``, or ``"auto"`` for
    file-backed / over-:data:`STREAM_THRESHOLD` scenarios — see
    :func:`should_stream`) replay the same requests through
    ``Engine.replay_stream`` in ``[S, chunk]`` slices instead: device
    memory stays O(K + chunk), synthetic host batches are generated once
    per scenario and sliced, and file-backed traces come straight off
    disk — decoded once per scenario for small files (the cached
    materialized load), re-read chunk by chunk past
    :data:`STREAM_THRESHOLD` requests (the out-of-core contract: a huge
    decoded trace is never held in host memory).  Both paths emit
    identical counts, ratios and
    time-mean observables; the float byte/cost totals agree bit-for-bit
    while their float32 running sums are exact (integer sizes summing
    under 2^24, as in the committed corpus) and to float32 rounding
    beyond that — the streaming path's host-side 64-bit chunk reduction
    is the *more* accurate of the two at scale.  ``mesh`` applies to
    materialized cells only (streamed chunks run unsharded): under
    ``"auto"`` a mesh keeps synthetic cells on the sharded materialized
    path, and any cell that still streams (file-backed, or forced with
    ``stream=True``) warns that the mesh is not consulted.  ``progress``
    (e.g. ``print``) receives a line per cell.

    >>> sw = Sweep("doc", policies=("lru",), seeds=(0,),
    ...            scenarios=(Scenario("z", trace="zipf(N=64,alpha=1.0)",
    ...                                T=200, K=(8,)),))
    >>> res = run_sweep(sw)
    >>> sorted(res.records[0]["metrics"])
    ['byte_miss_ratio', 'hit_ratio', 'miss_ratio', 'penalty_ratio']
    """
    engine = engine or Engine(mesh=mesh)
    have_mesh = mesh is not None or engine.mesh is not None
    t_start = time.perf_counter()
    records = []
    reqs_cache = {}
    # single-entry host cache: cells() iterates scenario-major, so only
    # the current streamed scenario's [S, T] batch is ever held
    host_name, host_val = None, None
    for pol, sc, K, k_label in sweep.cells():
        streamed = should_stream(sc, stream)
        if streamed and have_mesh:
            if stream == "auto" and not sc.trace_spec().is_file:
                streamed = False    # a mesh-sharded materialized cell
                                    # beats an unsharded stream
            else:
                warnings.warn(
                    f"cell ({pol}, {sc.name}, K={K}) streams unsharded: "
                    "replay_stream does not consult mesh=", stacklevel=2)
        # one-time per-scenario host work (trace generation, request
        # materialization) stays outside the per-cell wall timer, as it
        # always has for the materialized path
        if streamed:
            host = None
            if not sc.trace_spec().is_file:
                if host_name != sc.name:
                    host_name = sc.name
                    host_val = _synthetic_host(sc, sweep.seeds)
                host = host_val
            t0 = time.perf_counter()
            res = engine.replay_stream(
                pol, stream_chunks(sc, sweep.seeds, chunk, _host=host), K,
                observe=sweep.observe, use_pallas=use_pallas)
        else:
            if sc.name not in reqs_cache:
                reqs_cache[sc.name] = materialize(sc, sweep.seeds)
            t0 = time.perf_counter()
            res = engine.replay(pol, reqs_cache[sc.name], K,
                                observe=sweep.observe, collect_info=False,
                                mesh=mesh, use_pallas=use_pallas)
            jax.block_until_ready(res.metrics.hits)
        wall = time.perf_counter() - t0
        records.append(_cell_record(pol, sc, K, k_label, sweep.seeds,
                                    res, wall, avg_k=_avg_k(res, streamed)))
        if progress is not None:
            mr = np.mean(records[-1]["metrics"]["miss_ratio"])
            progress(f"[{sweep.name}] {sc.name} K={K}({k_label}) "
                     f"{pol}{' [stream]' if streamed else ''}: "
                     f"miss={mr:.3f} [{wall:.2f}s]")
    return SweepResult(sweep=sweep, records=records,
                       wall_s=time.perf_counter() - t_start)
