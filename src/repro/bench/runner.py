"""Grid execution: a Sweep runs through the Engine, seeds vmapped.

For every (policy, scenario, K) cell the runner builds one ``[S, T]``
request batch (S = the sweep's seed axis) and issues a *single*
``Engine.replay`` call — the seeds replay as parallel vmapped cache lanes
inside one jitted program (metrics-only: totals reduce in the scan carry,
no ``[T]`` StepInfo ever materializes), instead of a Python loop over
seeds.  Pass ``mesh=`` (or an Engine built with one) to shard the seed
axis over devices, and ``use_pallas=True`` to route rank policies through
the fused Pallas policy-step kernel — both knobs reach every cell.

The output is a list of flat, JSON-able records (one per cell, per-seed
metric lists) wrapped in a :class:`SweepResult` that renders the canonical
payload of :mod:`repro.bench.results`.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from ..core import Engine
from ..core.policy import Request
from . import report, results
from .scenario import Scenario, Sweep

__all__ = ["materialize", "run_sweep", "SweepResult"]


def materialize(scenario: Scenario, seeds) -> Request:
    """Build the ``[S, T]`` request batch for one scenario: traces from the
    registry (one lane per seed) with the scenario's size/cost tables
    gathered per request."""
    spec = scenario.trace_spec()
    keys = spec.generate_batch(scenario.T, seeds)
    sizes = scenario.size_table()
    if sizes is None:
        return Request.of(keys)
    costs = scenario.cost_table(sizes)
    return Request.of(keys, sizes=sizes[keys],
                      costs=None if costs is None else costs[keys])


def _per_seed(x) -> list:
    return [float(v) for v in np.atleast_1d(np.asarray(x))]


def _cell_record(pol, sc, K, k_label, seeds, res, wall_s) -> dict:
    metrics = {
        "miss_ratio": _per_seed(res.miss_ratio),
        "hit_ratio": _per_seed(res.hit_ratio),
        "byte_miss_ratio": _per_seed(res.byte_miss_ratio),
        "penalty_ratio": _per_seed(res.penalty_ratio),
    }
    if res.obs is not None and "k" in res.obs:
        # adaptive policies: time-mean of the adapted cache size per seed
        metrics["avg_k"] = _per_seed(
            np.asarray(res.obs["k"], dtype=np.float64).mean(axis=-1))
    return {
        "policy": pol, "scenario": sc.name, "trace": sc.trace,
        "T": int(sc.T), "K": int(K), "K_label": k_label,
        "seeds": [int(s) for s in seeds],
        "metrics": metrics, "wall_s": float(wall_s),
    }


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Executed sweep: the config that produced it + one record per cell."""

    sweep: Sweep
    records: list
    wall_s: float

    def select(self, **eq) -> list:
        """Records whose fields equal every given keyword (e.g.
        ``select(policy="lru", scenario="wiki", K_label="S")``)."""
        return report.select(self.records, **eq)

    def metric(self, name: str, **eq) -> np.ndarray:
        """Per-seed values of one metric for the single matching record."""
        return report.seed_values(self.records, name, **eq)

    def payload(self, extras: dict | None = None) -> dict:
        return results.build_payload(
            self.sweep.name, config=self.sweep.to_config(),
            records=self.records, extras=extras, wall_s=self.wall_s)

    def save(self, extras: dict | None = None, *,
             results_dir: str | None = None) -> dict:
        """Validate + write the canonical payload; returns it."""
        payload = self.payload(extras)
        results.save(payload, results_dir=results_dir)
        return payload


def run_sweep(sweep: Sweep, *, engine: Engine | None = None,
              mesh=None, use_pallas: bool | None = None,
              progress=None) -> SweepResult:
    """Execute every cell of ``sweep`` through the Engine.

    Each scenario's ``[S, T]`` request batch is materialized once and
    shared across its policies and capacities; each cell is one vmapped
    metrics-only replay.  ``progress`` (e.g. ``print``) receives a line
    per cell.
    """
    engine = engine or Engine(mesh=mesh)
    t_start = time.perf_counter()
    records = []
    reqs_cache = {}
    for pol, sc, K, k_label in sweep.cells():
        if sc.name not in reqs_cache:
            reqs_cache[sc.name] = materialize(sc, sweep.seeds)
        reqs = reqs_cache[sc.name]
        t0 = time.perf_counter()
        res = engine.replay(pol, reqs, K, observe=sweep.observe,
                            collect_info=False, mesh=mesh,
                            use_pallas=use_pallas)
        jax.block_until_ready(res.metrics.hits)
        wall = time.perf_counter() - t0
        records.append(_cell_record(pol, sc, K, k_label, sweep.seeds,
                                    res, wall))
        if progress is not None:
            mr = np.mean(records[-1]["metrics"]["miss_ratio"])
            progress(f"[{sweep.name}] {sc.name} K={K}({k_label}) "
                     f"{pol}: miss={mr:.3f} [{wall:.2f}s]")
    return SweepResult(sweep=sweep, records=records,
                       wall_s=time.perf_counter() - t_start)
