"""Declarative experiment API: experiments are data, not code.

The paper's 1067-trace, 6-dataset evaluation grid as three layers::

    scenario = Scenario("wiki", trace="shifting_zipf(N=4096,alpha=0.9,phases=4)",
                        T=60_000, K=("S", "L"),
                        size_model="lognormal", cost_model="fetch")
    sweep = Sweep("fig8", policies=("lru", "arc", "dac"),
                  scenarios=(scenario,), seeds=(0, 1, 2))
    result = run_sweep(sweep)                 # seeds vmapped per cell
    payload = result.save()                   # canonical versioned JSON

Traces come from the registry (``repro.data.make_trace`` spec strings),
the runner batches the seed axis through one jitted ``Engine.replay`` per
grid cell (with optional mesh sharding and the Pallas policy-step kernel),
and :mod:`repro.bench.results` owns the versioned, provenance-stamped,
schema-validated result payloads that :mod:`repro.bench.report` renders
into the paper's tables.

Multi-tenant tier grids use the same shapes one level up:
``TierScenario`` (a ``tenants(...)`` stream + shared budget) x
``TierSweep`` ((policy, arbiter) entries), executed by
:func:`run_tier_sweep` into ``repro.bench.result/v2`` payloads with
per-tenant records — see ``docs/EXPERIMENTS.md``.  Dynamic-lifecycle
fleets (``fleet(...)`` streams with tenant arrivals/departures) follow
the same grammar via ``FleetScenario`` x ``FleetSweep`` and
:func:`run_fleet_sweep`, whose v2 records additionally carry SLO
telemetry: penalty p50/p99 and Jain occupancy fairness.
"""
from . import report, results
from .runner import (STREAM_THRESHOLD, FleetSweepResult, SweepResult,
                     TierSweepResult, materialize, run_fleet_sweep,
                     run_sweep, run_tier_sweep, should_stream,
                     stream_chunks)
from .scenario import (COST_MODELS, LARGE_FRAC, SIZE_MODELS, SMALL_FRAC,
                       FleetScenario, FleetSweep, Scenario, ServeScenario,
                       Sweep, TierScenario, TierSweep, k_for)

__all__ = [
    "Scenario", "Sweep", "SweepResult", "run_sweep", "materialize",
    "should_stream", "stream_chunks", "STREAM_THRESHOLD",
    "TierScenario", "TierSweep", "TierSweepResult", "run_tier_sweep",
    "FleetScenario", "FleetSweep", "FleetSweepResult", "run_fleet_sweep",
    "ServeScenario",
    "results", "report", "k_for",
    "SIZE_MODELS", "COST_MODELS", "SMALL_FRAC", "LARGE_FRAC",
]
