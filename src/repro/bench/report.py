"""Render the paper's tables from canonical sweep records.

Pure functions over the record lists emitted by :mod:`repro.bench.runner`
(or reloaded from result JSONs): the MRR-vs-FIFO matrix (Table III), the
per-cell winner fractions (Fig. 6), and generic metric pivots — so the
table logic lives once, not in every benchmark script.
"""
from __future__ import annotations

import numpy as np

from ..core import mrr

__all__ = ["select", "seed_values", "cell_label", "pivot",
           "mrr_matrix", "winners", "metric_cdf", "robustness_frontier",
           "fmt_row", "print_table",
           "tier_mrr_matrix", "tier_winners", "tenant_occupancy"]


def select(records, **eq):
    """Records whose fields equal every keyword.

    >>> recs = [{"policy": "lru", "K": 8}, {"policy": "dac", "K": 8}]
    >>> select(recs, policy="dac")
    [{'policy': 'dac', 'K': 8}]
    """
    return [r for r in records if all(r.get(k) == v for k, v in eq.items())]


def seed_values(records, metric: str, **eq) -> np.ndarray:
    """Per-seed values of one metric for the single matching record.

    >>> recs = [{"policy": "lru", "metrics": {"miss_ratio": [0.2, 0.3]}}]
    >>> seed_values(recs, "miss_ratio", policy="lru").tolist()
    [0.2, 0.3]
    """
    recs = select(records, **eq)
    if len(recs) != 1:
        raise KeyError(f"{len(recs)} records match {eq} (need exactly 1)")
    return np.atleast_1d(np.asarray(recs[0]["metrics"][metric]))


def cell_label(rec) -> str:
    """Column label for one (scenario, K) cell: ``wiki(S)`` / ``zipf(256)``.

    >>> cell_label({"scenario": "wiki", "K_label": "S"})
    'wiki(S)'
    """
    return f"{rec['scenario']}({rec['K_label']})"


def _cells(records, key_field: str = "K_label"):
    """Distinct (scenario, <key_field>) cells in first-appearance order."""
    seen = []
    for r in records:
        key = (r["scenario"], r[key_field])
        if key not in seen:
            seen.append(key)
    return seen


# The v1 (policy-keyed) and tier (entry-keyed) views share one
# aggregation core, parameterized by the cell key field, the per-row
# seed-value selector, and the row label.

def _mrr_over_cells(records, rows, baseline, metric, key_field, values,
                    label) -> dict:
    out = {}
    for scenario, cell in _cells(records, key_field):
        base = values(records, metric, baseline, scenario, cell)
        col = {}
        for row in rows:
            vals = values(records, metric, row, scenario, cell)
            col[label(row)] = float(np.mean(
                [mrr(float(m), float(f)) for m, f in zip(vals, base)]))
        out[f"{scenario}({cell})"] = col
    return out


def _winners_over_cells(records, rows, metric, key_field, values,
                        label, margin=False) -> dict:
    out = {}
    for scenario, cell in _cells(records, key_field):
        labels = [label(row) for row in rows]
        stack = np.stack([values(records, metric, row, scenario, cell)
                          for row in rows])
        best_val = stack.min(axis=0)
        # ties break deterministically: the lexicographically smallest
        # label among the tied rows wins, independent of caller ordering
        by_label = sorted(range(len(rows)), key=lambda i: labels[i])
        counts: dict = {}
        for s in range(stack.shape[1]):
            w = next(labels[i] for i in by_label
                     if stack[i, s] == best_val[s])
            counts[w] = counts.get(w, 0) + 1
        frac = {w: counts[w] / stack.shape[1] for w in sorted(counts)}
        if not margin:
            out[f"{scenario}({cell})"] = frac
            continue
        # margin: runner-up minus winner metric per seed, averaged — how
        # much the win is worth (0.0 on exact ties or a single row)
        if len(rows) > 1:
            part = np.partition(stack, 1, axis=0)
            marg = float((part[1] - part[0]).mean())
        else:
            marg = 0.0
        out[f"{scenario}({cell})"] = {"winners": frac, "margin": marg}
    return out


def _policy_values(records, metric, pol, scenario, k_label):
    return seed_values(records, metric, policy=pol, scenario=scenario,
                       K_label=k_label)


def pivot(records, metric: str, policies, reduce=np.mean) -> dict:
    """``{cell_label: {policy: reduced metric}}`` over all cells.

    >>> recs = [{"policy": "lru", "scenario": "z", "K_label": "8",
    ...          "metrics": {"miss_ratio": [0.25, 0.75]}}]
    >>> pivot(recs, "miss_ratio", ["lru"])
    {'z(8)': {'lru': 0.5}}
    """
    out = {}
    for scenario, k_label in _cells(records):
        col = {}
        for pol in policies:
            vals = seed_values(records, metric, policy=pol,
                               scenario=scenario, K_label=k_label)
            col[pol] = float(reduce(vals))
        out[f"{scenario}({k_label})"] = col
    return out


def mrr_matrix(records, policies, baseline: str = "fifo",
               metric: str = "miss_ratio") -> dict:
    """Table III: per cell, each policy's mean miss-ratio reduction vs the
    baseline, the reduction computed per seed then averaged (paper's
    signed MRR definition).

    >>> recs = [{"policy": p, "scenario": "z", "K_label": "8",
    ...          "metrics": {"miss_ratio": [m]}}
    ...         for p, m in [("fifo", 0.4), ("dac", 0.2)]]
    >>> mrr_matrix(recs, ["dac"])
    {'z(8)': {'dac': 0.5}}
    """
    return _mrr_over_cells(records, policies, baseline, metric,
                           "K_label", _policy_values, lambda p: p)


def winners(records, policies, metric: str = "miss_ratio", *,
            margin: bool = False) -> dict:
    """Fig. 6: per cell, the fraction of seeds on which each policy attains
    the lowest metric (only winning policies appear).  Exact ties go to
    the lexicographically smallest policy id — winner tables are stable
    across runs and caller orderings — and ``margin=True`` additionally
    reports how far the runner-up trailed (seed-mean metric gap), so a
    "win" by 0.000 is visible as one.

    >>> recs = [{"policy": p, "scenario": "z", "K_label": "8",
    ...          "metrics": {"miss_ratio": [m, m]}}
    ...         for p, m in [("lru", 0.4), ("dac", 0.2)]]
    >>> winners(recs, ["lru", "dac"])
    {'z(8)': {'dac': 1.0}}
    >>> winners(recs, ["lru", "dac"], margin=True)
    {'z(8)': {'winners': {'dac': 1.0}, 'margin': 0.2}}
    >>> tied = [{"policy": p, "scenario": "z", "K_label": "8",
    ...          "metrics": {"miss_ratio": [0.3]}} for p in ("lru", "arc")]
    >>> winners(tied, ["lru", "arc"])     # tie -> lexicographic, not order
    {'z(8)': {'arc': 1.0}}
    """
    return _winners_over_cells(records, policies, metric, "K_label",
                               _policy_values, lambda p: p, margin=margin)


def metric_cdf(records, policies, metric: str = "hit_ratio") -> dict:
    """Per-policy empirical CDF of the seed-mean metric across every
    (scenario, K) cell — the paper's hit-ratio-CDF-across-traces figure
    shape.  ``values`` are sorted ascending; ``cdf[i]`` is the fraction
    of cells at or below ``values[i]``.

    >>> recs = [{"policy": "lru", "scenario": s, "K_label": "8",
    ...          "metrics": {"hit_ratio": [v]}}
    ...         for s, v in [("a", 0.8), ("b", 0.4)]]
    >>> metric_cdf(recs, ["lru"])
    {'lru': {'values': [0.4, 0.8], 'cdf': [0.5, 1.0]}}
    """
    out = {}
    for pol in policies:
        recs = select(records, policy=pol)
        vals = sorted(
            float(np.mean(seed_values(recs, metric, scenario=sc,
                                      K_label=kl)))
            for sc, kl in _cells(recs))
        n = len(vals)
        out[pol] = {"values": vals,
                    "cdf": [(i + 1) / n for i in range(n)]}
    return out


def robustness_frontier(records, policies, baseline: str = "fifo",
                        metric: str = "byte_miss_ratio") -> dict:
    """Worst-case vs mean MRR frontier: per policy, the seed-mean MRR vs
    ``baseline`` in every (scenario, K) cell, reduced to its minimum
    (the adversarial worst case — the number the robustness claim rides
    on) and its mean.  A policy's worst cell is named so the table says
    *where* it breaks; exact worst-case ties resolve to the
    lexicographically smallest cell label, stable across runs.

    Partial grids are first-class: a cell missing either the policy's or
    the baseline's record is skipped and *counted* in ``dropped`` — a
    shrunken table always says how much of the grid it actually covers.
    A policy with no covered cell reports ``worst``/``mean``/
    ``worst_cell`` of ``None`` rather than vanishing silently.

    >>> recs = [{"policy": p, "scenario": s, "K_label": "8",
    ...          "metrics": {"byte_miss_ratio": [m]}}
    ...         for p, s, m in [("fifo", "flood", 0.8), ("fifo", "scan", 0.5),
    ...                         ("dac", "flood", 0.4), ("dac", "scan", 0.5),
    ...                         ("lru", "flood", 0.6)]]
    >>> f = robustness_frontier(recs, ["dac", "lru"])
    >>> f["dac"]["worst"], f["dac"]["worst_cell"], f["dac"]["dropped"]
    (0.0, 'scan(8)', 0)
    >>> f["lru"]["cells"], f["lru"]["dropped"]     # scan cell has no record
    (1, 1)
    """
    cells = _cells(records)
    out = {}
    for pol in policies:
        per_cell, dropped = {}, 0
        for scenario, kl in cells:
            try:
                base = seed_values(records, metric, policy=baseline,
                                   scenario=scenario, K_label=kl)
                vals = seed_values(records, metric, policy=pol,
                                   scenario=scenario, K_label=kl)
            except KeyError:
                dropped += 1
                continue
            per_cell[f"{scenario}({kl})"] = float(np.mean(
                [mrr(float(m), float(f)) for m, f in zip(vals, base)]))
        worst_cell = (min(sorted(per_cell), key=per_cell.get)
                      if per_cell else None)
        out[pol] = {
            "worst": per_cell[worst_cell] if per_cell else None,
            "worst_cell": worst_cell,
            "mean": float(np.mean(list(per_cell.values())))
            if per_cell else None,
            "cells": len(per_cell),
            "dropped": dropped,
            "per_cell": per_cell,
        }
    return out


# --- tier (v2) views -------------------------------------------------------
# Tier records are keyed by (policy, arbiter) entries instead of a bare
# policy; rows are labelled "policy+arbiter" and cells are (scenario,
# budget_label) pairs.

def _tier_label(entry) -> str:
    return "+".join(entry)


def _entry_values(records, metric, entry, scenario, budget_label):
    pol, arb = entry
    return seed_values(records, metric, policy=pol, arbiter=arb,
                       scenario=scenario, budget_label=budget_label)


def tier_mrr_matrix(records, entries, baseline=("fifo", "static"),
                    metric: str = "byte_miss_ratio") -> dict:
    """Aggregate miss-ratio reduction of each (policy, arbiter) entry vs
    the baseline entry, per tier cell — the byte-weighted default makes
    it the tier analogue of the paper's Table III, computed per seed then
    averaged.

    >>> recs = [{"policy": p, "arbiter": a, "scenario": "flux",
    ...          "budget_label": "512", "seeds": [0],
    ...          "metrics": {"byte_miss_ratio": [m]}}
    ...         for p, a, m in [("fifo", "static", 0.5),
    ...                         ("dac", "greedy", 0.25)]]
    >>> tier_mrr_matrix(recs, [("dac", "greedy")])
    {'flux(512)': {'dac+greedy': 0.5}}
    """
    return _mrr_over_cells(records, entries, baseline, metric,
                           "budget_label", _entry_values, _tier_label)


def tier_winners(records, entries, metric: str = "byte_miss_ratio", *,
                 margin: bool = False) -> dict:
    """Per tier cell, the fraction of seeds on which each (policy,
    arbiter) entry attains the lowest aggregate metric — same tie-break
    and ``margin=`` semantics as :func:`winners`."""
    return _winners_over_cells(records, entries, metric, "budget_label",
                               _entry_values, _tier_label, margin=margin)


def occupancy_timeline(ks, windows: int = 8) -> list:
    """Downsample a per-step occupancy trace ``[T, N]`` (from
    ``replay_tier(..., observe=True)``) into ``windows`` rows of
    per-tenant mean active size — the occupancy-over-time table for one
    tier replay.

    >>> import numpy as np
    >>> ks = np.stack([np.arange(4), np.full(4, 2)], axis=1)   # [T=4, N=2]
    >>> occupancy_timeline(ks, windows=2)
    [[0.5, 2.0], [2.5, 2.0]]
    """
    ks = np.asarray(ks, dtype=np.float64)
    bounds = np.linspace(0, ks.shape[0], windows + 1).astype(int)
    return [[float(v) for v in ks[lo:hi].mean(axis=0)]
            for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]


def tenant_occupancy(rec) -> dict:
    """Per-tenant occupancy/miss table for one tier record:
    ``{tenant: {"avg_k": seed-mean occupancy, "share": fraction of the
    budget, "miss_ratio": seed-mean}}``.

    >>> rec = {"budget": 10, "tenants": [
    ...     {"tenant": 0, "metrics": {"avg_k": [4.0], "miss_ratio": [0.5],
    ...                               "byte_miss_ratio": [0.5]}}]}
    >>> tenant_occupancy(rec)[0]["share"]
    0.4
    """
    out = {}
    for ten in rec["tenants"]:
        avg_k = float(np.mean(ten["metrics"]["avg_k"]))
        out[int(ten["tenant"])] = {
            "avg_k": avg_k,
            "share": avg_k / rec["budget"],
            "miss_ratio": float(np.mean(ten["metrics"]["miss_ratio"])),
        }
    return out


def fmt_row(cells, widths) -> str:
    """Left-justify ``cells`` into fixed-width columns.

    >>> fmt_row(["a", 1], [3, 3])
    'a    1  '
    """
    return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths))


def print_table(matrix: dict, policies, *, fmt="{:+.3f}", col_w=14,
                name_w=22, out=print):
    """Print a ``{col: {policy: value}}`` matrix, policies as rows."""
    cols = list(matrix)
    out(fmt_row(["policy"] + cols, [name_w] + [col_w] * len(cols)))
    for pol in policies:
        out(fmt_row([pol] + [fmt.format(matrix[c][pol]) for c in cols],
                    [name_w] + [col_w] * len(cols)))
