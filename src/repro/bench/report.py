"""Render the paper's tables from canonical sweep records.

Pure functions over the record lists emitted by :mod:`repro.bench.runner`
(or reloaded from result JSONs): the MRR-vs-FIFO matrix (Table III), the
per-cell winner fractions (Fig. 6), and generic metric pivots — so the
table logic lives once, not in every benchmark script.
"""
from __future__ import annotations

import numpy as np

from ..core import mrr

__all__ = ["select", "seed_values", "cell_label", "pivot",
           "mrr_matrix", "winners", "fmt_row", "print_table"]


def select(records, **eq):
    return [r for r in records if all(r.get(k) == v for k, v in eq.items())]


def seed_values(records, metric: str, **eq) -> np.ndarray:
    """Per-seed values of one metric for the single matching record."""
    recs = select(records, **eq)
    if len(recs) != 1:
        raise KeyError(f"{len(recs)} records match {eq} (need exactly 1)")
    return np.atleast_1d(np.asarray(recs[0]["metrics"][metric]))


def cell_label(rec) -> str:
    """Column label for one (scenario, K) cell: ``wiki(S)`` / ``zipf(256)``."""
    return f"{rec['scenario']}({rec['K_label']})"


def _cells(records):
    """Distinct (scenario, K_label) cells in first-appearance order."""
    seen = []
    for r in records:
        key = (r["scenario"], r["K_label"])
        if key not in seen:
            seen.append(key)
    return seen


def pivot(records, metric: str, policies, reduce=np.mean) -> dict:
    """``{cell_label: {policy: reduced metric}}`` over all cells."""
    out = {}
    for scenario, k_label in _cells(records):
        col = {}
        for pol in policies:
            vals = seed_values(records, metric, policy=pol,
                               scenario=scenario, K_label=k_label)
            col[pol] = float(reduce(vals))
        out[f"{scenario}({k_label})"] = col
    return out


def mrr_matrix(records, policies, baseline: str = "fifo",
               metric: str = "miss_ratio") -> dict:
    """Table III: per cell, each policy's mean miss-ratio reduction vs the
    baseline, the reduction computed per seed then averaged (paper's
    signed MRR definition)."""
    out = {}
    for scenario, k_label in _cells(records):
        base = seed_values(records, metric, policy=baseline,
                           scenario=scenario, K_label=k_label)
        col = {}
        for pol in policies:
            vals = seed_values(records, metric, policy=pol,
                               scenario=scenario, K_label=k_label)
            col[pol] = float(np.mean([mrr(float(m), float(f))
                                      for m, f in zip(vals, base)]))
        out[f"{scenario}({k_label})"] = col
    return out


def winners(records, policies, metric: str = "miss_ratio") -> dict:
    """Fig. 6: per cell, the fraction of seeds on which each policy attains
    the lowest metric (only winning policies appear)."""
    out = {}
    for scenario, k_label in _cells(records):
        stack = np.stack([seed_values(records, metric, policy=p,
                                      scenario=scenario, K_label=k_label)
                          for p in policies])
        best = np.argmin(stack, axis=0)
        out[f"{scenario}({k_label})"] = {
            policies[i]: float((best == i).mean())
            for i in sorted(set(best.tolist()))}
    return out


def fmt_row(cells, widths) -> str:
    return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths))


def print_table(matrix: dict, policies, *, fmt="{:+.3f}", col_w=14,
                name_w=22, out=print):
    """Print a ``{col: {policy: value}}`` matrix, policies as rows."""
    cols = list(matrix)
    out(fmt_row(["policy"] + cols, [name_w] + [col_w] * len(cols)))
    for pol in policies:
        out(fmt_row([pol] + [fmt.format(matrix[c][pol]) for c in cols],
                    [name_w] + [col_w] * len(cols)))
