"""Declarative experiment descriptions: Scenario and Sweep.

A :class:`Scenario` is one workload cell — a trace spec string, an optional
object-size/fetch-cost model, and the cache-capacity regime.  A
:class:`Sweep` is the full grid the paper evaluates: policies x scenarios x
capacities x seeds.  Both are plain frozen dataclasses that round-trip to
JSON-able config dicts, so an experiment is data: the sweep config rides
inside the result payload and fully determines the run.

Size and cost models are spec strings over small registries (mirroring
policies and traces)::

    Scenario("wiki", trace="shifting_zipf(N=4096,alpha=0.9,phases=4)",
             T=60_000, K=(64, 256),
             size_model="lognormal(median_kb=16,sigma=1.5)",
             cost_model="fetch(base_ms=2.0,per_mb_ms=8.0)")

Capacity entries are either explicit ints or the paper's regime letters
``"S"`` / ``"L"`` (Section V-B: 0.1% / 10% of the trace's id footprint),
resolved against ``make_trace(trace).n_keys``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..data.traces import (TraceSpec, bimodal_sizes, fetch_costs,
                           make_trace, object_sizes)
from ..specs import build_kwargs, parse_spec

__all__ = [
    "Scenario", "Sweep", "TierScenario", "TierSweep",
    "FleetScenario", "FleetSweep", "ServeScenario",
    "SIZE_MODELS", "COST_MODELS", "SMALL_FRAC", "LARGE_FRAC", "k_for",
]

# cache-size regimes, as fractions of the trace id footprint (paper §V-B:
# small = 0.1%, large = 10%)
SMALL_FRAC = 0.001
LARGE_FRAC = 0.10

SIZE_MODELS = {"lognormal": object_sizes, "bimodal": bimodal_sizes}
COST_MODELS = {"fetch": fetch_costs}


def k_for(N: int, regime: str) -> int:
    """Resolve a regime letter to a capacity: S = 0.1%, L = 10% of N
    (paper §V-B), floored at 4 slots.

    >>> k_for(8192, "S"), k_for(8192, "L")
    (8, 819)
    """
    if regime not in ("S", "L"):
        raise ValueError(f"capacity regime must be 'S' or 'L', got {regime!r}")
    frac = SMALL_FRAC if regime == "S" else LARGE_FRAC
    return max(4, int(N * frac))


def _model_fn(registry: dict, kind: str, spec: str, skip: tuple):
    name, argstr = parse_spec(spec)
    if name not in registry:
        raise ValueError(
            f"unknown {kind} model {name!r}; known: {sorted(registry)}")
    fn = registry[name]
    return fn, build_kwargs(f"{kind} model", name, fn, argstr, skip=skip)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One workload: trace spec + size/cost model + capacity regime.

    >>> sc = Scenario("wiki", trace="wiki", T=1000, K=("S", 256))
    >>> sc.trace                        # canonicalized at construction
    'shifting_zipf(N=8192,alpha=0.9,phases=4)'
    >>> sc.capacities()                 # "S" resolved vs the id footprint
    (8, 256)
    >>> Scenario.from_config(sc.to_config()) == sc
    True
    """

    name: str
    trace: str                  # trace spec string (repro.data.make_trace)
    T: int
    K: tuple = (256,)           # ints and/or regime letters "S"/"L"
    size_model: str | None = None   # e.g. "lognormal(median_kb=16)"
    cost_model: str | None = None   # e.g. "fetch(base_ms=2.0)"; needs sizes

    def __post_init__(self):
        # normalize: canonical trace string, K always a tuple
        spec = make_trace(self.trace)
        if spec.is_tier:
            raise ValueError(
                f"scenario {self.name!r}: {spec.family!r} is a multi-tenant "
                "trace family — use TierScenario (repro.tier workloads)")
        if spec.is_fleet:
            raise ValueError(
                f"scenario {self.name!r}: {spec.family!r} is a dynamic-"
                "fleet trace family — use FleetScenario (repro.fleet "
                "workloads)")
        if spec.is_file:
            # real traces carry their own sizes/costs; validate the file
            # (and its length vs T) eagerly, like every other spec error
            if self.size_model is not None or self.cost_model is not None:
                raise ValueError(
                    f"scenario {self.name!r}: file-backed traces source "
                    "sizes/costs from the trace file — size_model/"
                    "cost_model do not apply")
            # the cheap length check (O(1) for uncompressed oracle) —
            # full characterization stats stay lazy until capacities()
            # resolves an "S"/"L" regime against the id footprint
            n = spec.n_requests
            if self.T > n:
                raise ValueError(
                    f"scenario {self.name!r}: T={self.T} exceeds the "
                    f"{n} requests in {spec.kwargs['path']!r}")
        object.__setattr__(self, "trace", str(spec))
        K = self.K if isinstance(self.K, (tuple, list)) else (self.K,)
        object.__setattr__(self, "K", tuple(K))
        if self.cost_model is not None and self.size_model is None:
            raise ValueError(
                f"scenario {self.name!r}: cost_model requires a size_model "
                "(fetch costs are a function of object sizes)")
        # validate both model specs eagerly (parse only — no table is built)
        if self.size_model is not None:
            _model_fn(SIZE_MODELS, "size", self.size_model,
                      skip=("n_objects",))
        if self.cost_model is not None:
            _model_fn(COST_MODELS, "cost", self.cost_model,
                      skip=("sizes_bytes",))

    def trace_spec(self) -> TraceSpec:
        return make_trace(self.trace)

    def capacities(self) -> tuple:
        """K entries with regime letters resolved against the trace's id
        footprint."""
        n = self.trace_spec().n_keys
        return tuple(k_for(n, k) if isinstance(k, str) else int(k)
                     for k in self.K)

    def k_label(self, K) -> str:
        """Display label for one K entry ("S"/"L" or the number)."""
        return K if isinstance(K, str) else str(int(K))

    def size_table(self) -> np.ndarray | None:
        """Per-object-id size table ``[n_keys]`` (bytes), or ``None`` for
        the unit-object model."""
        if self.size_model is None:
            return None
        fn, kw = _model_fn(SIZE_MODELS, "size", self.size_model,
                           skip=("n_objects",))
        return fn(n_objects=self.trace_spec().n_keys, **kw)

    def cost_table(self, sizes: np.ndarray) -> np.ndarray | None:
        """Per-object-id miss-cost table aligned with ``sizes``."""
        if self.cost_model is None:
            return None
        fn, kw = _model_fn(COST_MODELS, "cost", self.cost_model,
                           skip=("sizes_bytes",))
        return fn(sizes, **kw)

    def to_config(self) -> dict:
        return {"name": self.name, "trace": self.trace, "T": self.T,
                "K": list(self.K), "size_model": self.size_model,
                "cost_model": self.cost_model}

    @classmethod
    def from_config(cls, cfg: dict) -> "Scenario":
        return cls(name=cfg["name"], trace=cfg["trace"], T=cfg["T"],
                   K=tuple(cfg["K"]), size_model=cfg.get("size_model"),
                   cost_model=cfg.get("cost_model"))


@dataclasses.dataclass(frozen=True)
class TierScenario:
    """One multi-tenant workload: a tier trace spec (``tenants(...)``)
    plus the shared budget(s) and optional size/cost models.

    ``budget`` entries are explicit ints or the regime letters ``"S"`` /
    ``"L"``, resolved against the *total* id footprint (``n_tenants x
    n_keys``) exactly like :func:`k_for`.  ``k0`` overrides each tenant's
    initial active size (default: the policy's own headroom rule, see
    :class:`repro.tier.CacheTier`).

    >>> sc = TierScenario("flux", trace="tenants(N=256,n_tenants=4)",
    ...                   T=1000, budget=(64, "S"))
    >>> sc.budgets()
    (64, 16)
    >>> sc.n_tenants
    4
    """

    name: str
    trace: str                  # tier trace spec (repro.data.make_trace)
    T: int
    budget: tuple = (256,)      # ints and/or regime letters "S"/"L"
    k0: int | None = None
    size_model: str | None = None
    cost_model: str | None = None

    def __post_init__(self):
        spec = make_trace(self.trace)
        if not spec.is_tier:
            raise ValueError(
                f"tier scenario {self.name!r} needs a multi-tenant trace "
                f"family, got {spec.family!r} — use Scenario for those")
        object.__setattr__(self, "trace", str(spec))
        b = self.budget if isinstance(self.budget, (tuple, list)) \
            else (self.budget,)
        object.__setattr__(self, "budget", tuple(b))
        if self.cost_model is not None and self.size_model is None:
            raise ValueError(
                f"tier scenario {self.name!r}: cost_model requires a "
                "size_model")
        if self.size_model is not None:
            _model_fn(SIZE_MODELS, "size", self.size_model,
                      skip=("n_objects",))
        if self.cost_model is not None:
            _model_fn(COST_MODELS, "cost", self.cost_model,
                      skip=("sizes_bytes",))

    def trace_spec(self) -> TraceSpec:
        return make_trace(self.trace)

    @property
    def n_tenants(self) -> int:
        return self.trace_spec().n_tenants

    def budgets(self) -> tuple:
        """Budget entries with regime letters resolved against the total
        footprint (``n_tenants * n_keys``), floored at four slots per
        tenant (room for every tenant's initial active size — the same
        floor :func:`k_for` applies to a single cache)."""
        spec = self.trace_spec()
        total = spec.n_tenants * spec.n_keys
        return tuple(max(4 * self.n_tenants, k_for(total, b))
                     if isinstance(b, str) else int(b)
                     for b in self.budget)

    def budget_label(self, b) -> str:
        return b if isinstance(b, str) else str(int(b))

    def size_table(self) -> np.ndarray | None:
        """Per-object-id size table ``[n_keys]`` (bytes), shared by every
        tenant (they address the same id space through private hot-set
        permutations)."""
        if self.size_model is None:
            return None
        fn, kw = _model_fn(SIZE_MODELS, "size", self.size_model,
                           skip=("n_objects",))
        return fn(n_objects=self.trace_spec().n_keys, **kw)

    def cost_table(self, sizes: np.ndarray) -> np.ndarray | None:
        if self.cost_model is None:
            return None
        fn, kw = _model_fn(COST_MODELS, "cost", self.cost_model,
                           skip=("sizes_bytes",))
        return fn(sizes, **kw)

    def to_config(self) -> dict:
        return {"name": self.name, "trace": self.trace, "T": self.T,
                "budget": list(self.budget), "k0": self.k0,
                "size_model": self.size_model,
                "cost_model": self.cost_model}

    @classmethod
    def from_config(cls, cfg: dict) -> "TierScenario":
        return cls(name=cfg["name"], trace=cfg["trace"], T=cfg["T"],
                   budget=tuple(cfg["budget"]), k0=cfg.get("k0"),
                   size_model=cfg.get("size_model"),
                   cost_model=cfg.get("cost_model"))


@dataclasses.dataclass(frozen=True)
class TierSweep:
    """The tier evaluation grid: (policy, arbiter) entries x tier
    scenarios x budgets x seeds.

    Each ``entries`` element is a ``(policy_spec, arbiter_spec)`` pair —
    e.g. ``("dac", "greedy")`` for the arbitrated tier,
    ``("lru", "static")`` for a statically-partitioned baseline.

    >>> sw = TierSweep("demo", entries=(("dac", "greedy"),),
    ...                scenarios=(TierScenario(
    ...                    "flux", trace="tenants(N=256,n_tenants=2)",
    ...                    T=500),))
    >>> TierSweep.from_config(sw.to_config()) == sw
    True
    """

    name: str
    entries: tuple              # of (policy_spec, arbiter_spec) pairs
    scenarios: tuple            # of TierScenario
    seeds: tuple = (0,)
    # (no `observe` knob: tier records always carry per-tenant time-mean
    # occupancy `avg_k`; the per-step trace is a replay_tier concern)

    def __post_init__(self):
        object.__setattr__(
            self, "entries",
            tuple((str(p), str(a)) for p, a in self.entries))
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        if not self.entries:
            raise ValueError("tier sweep needs at least one (policy, "
                             "arbiter) entry")
        if not self.scenarios:
            raise ValueError("tier sweep needs at least one scenario")
        if not self.seeds:
            raise ValueError("tier sweep needs at least one seed")
        names = [sc.name for sc in self.scenarios]
        if len(set(names)) != len(names):
            raise ValueError(f"scenario names must be unique, got {names}")

    def cells(self):
        """Iterate the grid: (policy, arbiter, scenario, budget, label)."""
        for sc in self.scenarios:
            for b_spec, B in zip(sc.budget, sc.budgets()):
                for pol, arb in self.entries:
                    yield pol, arb, sc, B, sc.budget_label(b_spec)

    def to_config(self) -> dict:
        return {"name": self.name,
                "entries": [list(e) for e in self.entries],
                "scenarios": [sc.to_config() for sc in self.scenarios],
                "seeds": list(self.seeds)}

    @classmethod
    def from_config(cls, cfg: dict) -> "TierSweep":
        return cls(name=cfg["name"],
                   entries=tuple(tuple(e) for e in cfg["entries"]),
                   scenarios=tuple(TierScenario.from_config(s)
                                   for s in cfg["scenarios"]),
                   seeds=tuple(cfg["seeds"]))


@dataclasses.dataclass(frozen=True)
class FleetScenario:
    """One dynamic-fleet workload: a ``fleet(...)`` trace spec (tenant
    arrivals/departures encoded as ``-1`` lane entries) plus the global
    budget(s) and optional size/cost models.

    ``budget`` entries are explicit ints or the regime letters ``"S"`` /
    ``"L"``, resolved against the total id footprint (``n_lanes x
    n_keys``) and floored at four slots per lane, exactly like
    :class:`TierScenario`.  ``k0`` overrides the admission target;
    ``util_decay`` sets the byte-miss-cost EWMA the auction arbiter
    prices by (see :class:`repro.fleet.FleetTier`).

    >>> sc = FleetScenario("pool", trace="fleet(N=256,n_lanes=4)",
    ...                    T=1000, budget=(64, "S"))
    >>> sc.budgets()
    (64, 16)
    >>> sc.n_lanes
    4
    >>> FleetScenario.from_config(sc.to_config()) == sc
    True
    """

    name: str
    trace: str                  # fleet trace spec (repro.data.make_trace)
    T: int
    budget: tuple = (256,)      # ints and/or regime letters "S"/"L"
    k0: int | None = None
    util_decay: float = 0.98
    size_model: str | None = None
    cost_model: str | None = None

    def __post_init__(self):
        spec = make_trace(self.trace)
        if not spec.is_fleet:
            raise ValueError(
                f"fleet scenario {self.name!r} needs a dynamic-fleet trace "
                f"family, got {spec.family!r} — use TierScenario/Scenario "
                "for fixed-population workloads")
        object.__setattr__(self, "trace", str(spec))
        b = self.budget if isinstance(self.budget, (tuple, list)) \
            else (self.budget,)
        object.__setattr__(self, "budget", tuple(b))
        if self.cost_model is not None and self.size_model is None:
            raise ValueError(
                f"fleet scenario {self.name!r}: cost_model requires a "
                "size_model")
        if self.size_model is not None:
            _model_fn(SIZE_MODELS, "size", self.size_model,
                      skip=("n_objects",))
        if self.cost_model is not None:
            _model_fn(COST_MODELS, "cost", self.cost_model,
                      skip=("sizes_bytes",))

    def trace_spec(self) -> TraceSpec:
        return make_trace(self.trace)

    @property
    def n_lanes(self) -> int:
        return self.trace_spec().n_tenants

    def budgets(self) -> tuple:
        """Budget entries with regime letters resolved against the total
        footprint (``n_lanes * n_keys``), floored at four slots per lane
        (admission needs every lane to fit at the floor)."""
        spec = self.trace_spec()
        total = spec.n_tenants * spec.n_keys
        return tuple(max(4 * self.n_lanes, k_for(total, b))
                     if isinstance(b, str) else int(b)
                     for b in self.budget)

    def budget_label(self, b) -> str:
        return b if isinstance(b, str) else str(int(b))

    def size_table(self) -> np.ndarray | None:
        """Per-object-id size table ``[n_keys]`` (bytes), shared by every
        session (sessions address the same id space through private
        hot-set permutations)."""
        if self.size_model is None:
            return None
        fn, kw = _model_fn(SIZE_MODELS, "size", self.size_model,
                           skip=("n_objects",))
        return fn(n_objects=self.trace_spec().n_keys, **kw)

    def cost_table(self, sizes: np.ndarray) -> np.ndarray | None:
        if self.cost_model is None:
            return None
        fn, kw = _model_fn(COST_MODELS, "cost", self.cost_model,
                           skip=("sizes_bytes",))
        return fn(sizes, **kw)

    def to_config(self) -> dict:
        return {"name": self.name, "trace": self.trace, "T": self.T,
                "budget": list(self.budget), "k0": self.k0,
                "util_decay": self.util_decay,
                "size_model": self.size_model,
                "cost_model": self.cost_model}

    @classmethod
    def from_config(cls, cfg: dict) -> "FleetScenario":
        return cls(name=cfg["name"], trace=cfg["trace"], T=cfg["T"],
                   budget=tuple(cfg["budget"]), k0=cfg.get("k0"),
                   util_decay=cfg.get("util_decay", 0.98),
                   size_model=cfg.get("size_model"),
                   cost_model=cfg.get("cost_model"))


@dataclasses.dataclass(frozen=True)
class FleetSweep:
    """The fleet evaluation grid: (policy, arbiter) entries x fleet
    scenarios x budgets x seeds — the dynamic-lifecycle analogue of
    :class:`TierSweep` (e.g. ``("dac", "auction")`` for the priced pool,
    ``("lru", "static")`` for a fixed-partition baseline).

    >>> sw = FleetSweep("demo", entries=(("dac", "auction"),),
    ...                 scenarios=(FleetScenario(
    ...                     "pool", trace="fleet(N=256,n_lanes=4)",
    ...                     T=500),))
    >>> FleetSweep.from_config(sw.to_config()) == sw
    True
    """

    name: str
    entries: tuple              # of (policy_spec, arbiter_spec) pairs
    scenarios: tuple            # of FleetScenario
    seeds: tuple = (0,)

    def __post_init__(self):
        object.__setattr__(
            self, "entries",
            tuple((str(p), str(a)) for p, a in self.entries))
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        if not self.entries:
            raise ValueError("fleet sweep needs at least one (policy, "
                             "arbiter) entry")
        if not self.scenarios:
            raise ValueError("fleet sweep needs at least one scenario")
        if not self.seeds:
            raise ValueError("fleet sweep needs at least one seed")
        names = [sc.name for sc in self.scenarios]
        if len(set(names)) != len(names):
            raise ValueError(f"scenario names must be unique, got {names}")

    def cells(self):
        """Iterate the grid: (policy, arbiter, scenario, budget, label)."""
        for sc in self.scenarios:
            for b_spec, B in zip(sc.budget, sc.budgets()):
                for pol, arb in self.entries:
                    yield pol, arb, sc, B, sc.budget_label(b_spec)

    def to_config(self) -> dict:
        return {"name": self.name,
                "entries": [list(e) for e in self.entries],
                "scenarios": [sc.to_config() for sc in self.scenarios],
                "seeds": list(self.seeds)}

    @classmethod
    def from_config(cls, cfg: dict) -> "FleetSweep":
        return cls(name=cfg["name"],
                   entries=tuple(tuple(e) for e in cfg["entries"]),
                   scenarios=tuple(FleetScenario.from_config(s)
                                   for s in cfg["scenarios"]),
                   seeds=tuple(cfg["seeds"]))


@dataclasses.dataclass(frozen=True)
class ServeScenario:
    """One serving-path workload: a model architecture greedily decoded
    with the paper's policy as the bounded KV-cache manager
    (``repro.serving``), swept over KV slot budgets.

    There is no trace spec — the "requests" are the attention reads of a
    seeded random prompt plus ``gen`` decoded tokens — but the cell grid
    is declarative like every other scenario: ``budget_frac`` entries
    scale the exact-cache footprint (``prompt + gen`` positions, the
    serving analogue of the id footprint) and ``budgets()`` resolves them
    to slot counts, floored at four slots like :func:`k_for`.

    >>> sc = ServeScenario("kv", arch="deepseek-7b", prompt=96, gen=32)
    >>> sc.budgets()
    (128, 96, 64, 32)
    >>> sc.budget_label(0.75)
    '75%'
    >>> ServeScenario.from_config(sc.to_config()) == sc
    True
    """

    name: str
    arch: str = "deepseek-7b"
    batch: int = 2
    prompt: int = 96
    gen: int = 32
    budget_frac: tuple = (1.0, 0.75, 0.5, 0.25)

    def __post_init__(self):
        # lazy import: the serving path is optional for trace-only users
        from ..configs import SMOKE_ARCHS
        if self.arch not in SMOKE_ARCHS:
            raise ValueError(
                f"serve scenario {self.name!r}: unknown arch "
                f"{self.arch!r}; known: {sorted(SMOKE_ARCHS)}")
        if min(self.batch, self.prompt, self.gen) < 1:
            raise ValueError(
                f"serve scenario {self.name!r}: batch/prompt/gen must be "
                "positive")
        f = self.budget_frac if isinstance(self.budget_frac, (tuple, list)) \
            else (self.budget_frac,)
        fracs = tuple(float(x) for x in f)
        for x in fracs:
            if not 0.0 < x <= 1.0:
                raise ValueError(
                    f"serve scenario {self.name!r}: budget fractions must "
                    f"lie in (0, 1], got {x}")
        object.__setattr__(self, "budget_frac", fracs)

    @property
    def total(self) -> int:
        """Exact-cache footprint: every prompt + decoded position held."""
        return self.prompt + self.gen

    def budgets(self) -> tuple:
        """Budget fractions resolved to slot counts against the exact
        footprint, floored at four slots."""
        return tuple(max(4, int(self.total * f)) for f in self.budget_frac)

    def budget_label(self, f) -> str:
        """Display label for one fraction (percent of the exact cache)."""
        return f"{f:.0%}"

    def to_config(self) -> dict:
        return {"name": self.name, "arch": self.arch, "batch": self.batch,
                "prompt": self.prompt, "gen": self.gen,
                "budget_frac": list(self.budget_frac)}

    @classmethod
    def from_config(cls, cfg: dict) -> "ServeScenario":
        return cls(name=cfg["name"], arch=cfg["arch"],
                   batch=cfg.get("batch", 2), prompt=cfg["prompt"],
                   gen=cfg["gen"],
                   budget_frac=tuple(cfg["budget_frac"]))


@dataclasses.dataclass(frozen=True)
class Sweep:
    """The evaluation grid: policies x scenarios x capacities x seeds.

    ``policies`` are ``make_policy`` spec strings; ``seeds`` is the axis
    the runner vmaps inside one jitted replay per (policy, scenario, K)
    cell; ``observe=True`` additionally collects policy observables (e.g.
    DAC's adapted size) and reports their per-seed time means.

    >>> sw = Sweep("demo", policies=("lru", "dac"),
    ...            scenarios=(Scenario("z", trace="zipf(N=64,alpha=1.0)",
    ...                                T=100, K=(8,)),), seeds=(0, 1))
    >>> [(pol, K) for pol, _, K, _ in sw.cells()]
    [('lru', 8), ('dac', 8)]
    >>> Sweep.from_config(sw.to_config()) == sw
    True
    """

    name: str
    policies: tuple
    scenarios: tuple
    seeds: tuple = (0,)
    observe: bool = False

    def __post_init__(self):
        object.__setattr__(self, "policies", tuple(self.policies))
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        if not self.policies:
            raise ValueError("sweep needs at least one policy")
        if not self.scenarios:
            raise ValueError("sweep needs at least one scenario")
        if not self.seeds:
            raise ValueError("sweep needs at least one seed")
        names = [sc.name for sc in self.scenarios]
        if len(set(names)) != len(names):
            raise ValueError(
                f"scenario names must be unique, got {names}")

    def cells(self):
        """Iterate the grid: (policy_spec, scenario, K_int, K_label)."""
        for sc in self.scenarios:
            for k_spec, K in zip(sc.K, sc.capacities()):
                for pol in self.policies:
                    yield pol, sc, K, sc.k_label(k_spec)

    def to_config(self) -> dict:
        return {"name": self.name, "policies": list(self.policies),
                "scenarios": [sc.to_config() for sc in self.scenarios],
                "seeds": list(self.seeds), "observe": self.observe}

    @classmethod
    def from_config(cls, cfg: dict) -> "Sweep":
        return cls(name=cfg["name"], policies=tuple(cfg["policies"]),
                   scenarios=tuple(Scenario.from_config(s)
                                   for s in cfg["scenarios"]),
                   seeds=tuple(cfg["seeds"]),
                   observe=cfg.get("observe", False))
