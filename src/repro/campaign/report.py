"""Aggregate campaign reports, rendered from the store alone.

Everything here is a pure function over the completed cell payloads in a
:class:`repro.campaign.CampaignStore` — nothing reruns.  The shapes are
the paper's: hit-ratio CDFs across traces
(:func:`repro.bench.report.metric_cdf`), per-dataset winner tables with
deterministic ties and margins (:func:`repro.bench.report.winners`), and
mean miss/byte-miss/penalty reduction vs a baseline policy (the 29%-over-
FIFO headline shape, byte-weighted variants included).

Campaign cells may be *incomplete* — a quarantined trace, a policy added
to the grid mid-campaign — so every cross-policy table first restricts
itself to cells where **all** compared policies have a record
(:func:`complete_cells`); partial coverage shrinks a table instead of
crashing it, and the dropped-cell count is surfaced in the report.

>>> recs = [
...     {"policy": p, "scenario": "d/a.csv", "K_label": "S", "seeds": [0],
...      "dataset": "d", "metrics": {"miss_ratio": [m], "hit_ratio": [1 - m],
...                                  "byte_miss_ratio": [m],
...                                  "penalty_ratio": [m]}}
...     for p, m in [("fifo", 0.5), ("lru", 0.25)]]
>>> dataset_winners(recs)["d"]["winner"]
'lru'
>>> mrr_vs_baseline(recs, baseline="fifo")["d"]["lru"]
0.5
"""
from __future__ import annotations

import numpy as np

from ..bench import report as bench_report

__all__ = ["campaign_records", "complete_cells", "dataset_winners",
           "mrr_vs_baseline", "hit_ratio_cdf", "render_report",
           "format_report", "REPORT_SCHEMA"]

REPORT_SCHEMA = "repro.campaign.report/v1"


def campaign_records(store) -> list:
    """Flatten a store into one record list, each record annotated with
    its ``dataset`` and ``cell_key`` (from the payload's campaign
    extras) so the grouping the manifest declared survives into the
    tables."""
    out = []
    for key, payload in store.payloads():
        camp = payload.get("extras", {}).get("campaign", {})
        ds = camp.get("cell", {}).get("dataset", "?")
        for rec in payload["records"]:
            out.append(dict(rec, dataset=ds, cell_key=key))
    return out


def _policies(records, policies=None) -> list:
    return (list(policies) if policies
            else sorted({r["policy"] for r in records}))


def complete_cells(records, policies) -> tuple:
    """Split records into (kept, n_dropped_cells): only cells — distinct
    ``(scenario, K_label)`` pairs — where every compared policy has a
    record survive into cross-policy tables.

    >>> recs = [{"policy": "lru", "scenario": "t", "K_label": "S",
    ...          "metrics": {"miss_ratio": [0.1]}}]
    >>> complete_cells(recs, ["lru", "fifo"])
    ([], 1)
    """
    have: dict = {}
    for r in records:
        have.setdefault((r["scenario"], r["K_label"]), set()).add(r["policy"])
    ok = {c for c, pols in have.items() if set(policies) <= pols}
    kept = [r for r in records
            if (r["scenario"], r["K_label"]) in ok
            and r["policy"] in policies]
    return kept, len(have) - len(ok)


def dataset_winners(records, policies=None,
                    metric: str = "miss_ratio") -> dict:
    """The per-dataset winner table: for each dataset, every policy's
    fraction of (trace, K) cells won (deterministic lexicographic ties),
    the overall winner, and the mean winning margin.  ``per_cell`` keeps
    the raw cell-level verdicts for drill-down."""
    out = {}
    for ds in sorted({r["dataset"] for r in records}):
        recs = [r for r in records if r["dataset"] == ds]
        pols = _policies(recs, policies)
        kept, dropped = complete_cells(recs, pols)
        if not kept:
            continue
        per_cell = bench_report.winners(kept, pols, metric, margin=True)
        n = len(per_cell)
        wins = {p: 0.0 for p in pols}
        for cell in per_cell.values():
            for p, frac in cell["winners"].items():
                wins[p] += frac / n
        winner = max(sorted(wins), key=lambda p: wins[p])
        out[ds] = {
            "cells": n, "dropped": dropped,
            "wins": {p: round(f, 6) for p, f in sorted(wins.items())},
            "winner": winner,
            "margin": float(np.mean([c["margin"]
                                     for c in per_cell.values()])),
            "per_cell": per_cell,
        }
    return out


def mrr_vs_baseline(records, policies=None, baseline: str = "fifo",
                    metric: str = "miss_ratio") -> dict:
    """Per dataset, each policy's metric reduction vs ``baseline``
    averaged over that dataset's complete cells — the paper's
    "29% hit-ratio gain over FIFO" aggregate, for any ratio metric
    (``byte_miss_ratio`` and ``penalty_ratio`` give the byte- and
    miss-penalty-weighted variants).  Datasets with no baseline cells are
    skipped."""
    out = {}
    for ds in sorted({r["dataset"] for r in records}):
        recs = [r for r in records if r["dataset"] == ds]
        pols = _policies(recs, policies)
        if baseline not in pols:
            pols = pols + [baseline]
        kept, _ = complete_cells(recs, pols)
        if not kept:
            continue
        matrix = bench_report.mrr_matrix(kept, pols, baseline=baseline,
                                         metric=metric)
        col = {}
        for p in pols:
            col[p] = float(np.mean([cell[p] for cell in matrix.values()]))
        out[ds] = col
    return out


def hit_ratio_cdf(records, policies=None) -> dict:
    """Per-policy hit-ratio CDF across every completed campaign cell —
    the across-traces distribution figure."""
    pols = _policies(records, policies)
    kept, _ = complete_cells(records, pols)
    return bench_report.metric_cdf(kept, pols, "hit_ratio") if kept else {}


def render_report(store, *, baseline: str = "fifo",
                  policies=None) -> dict:
    """The full campaign report as one JSON-able dict, from the store
    alone: coverage counts, per-dataset winner tables (request- and
    byte-weighted), the hit-ratio CDF, and miss / byte-miss / miss-
    penalty reduction vs ``baseline``."""
    records = campaign_records(store)
    pols = _policies(records, policies)
    quarantined = store.quarantined()
    report = {
        "schema": REPORT_SCHEMA,
        "campaign": _campaign_name(store),
        "n_cells": len(store.completed()),
        "n_quarantined": len(quarantined),
        "quarantined": quarantined,
        "policies": pols,
        "datasets": sorted({r["dataset"] for r in records}),
        "winners": dataset_winners(records, pols),
        "winners_bytes": dataset_winners(records, pols,
                                         metric="byte_miss_ratio"),
        "hit_ratio_cdf": hit_ratio_cdf(records, pols),
    }
    if baseline in pols:
        report["baseline"] = baseline
        for name, metric in (("mrr", "miss_ratio"),
                             ("byte_mrr", "byte_miss_ratio"),
                             ("penalty_reduction", "penalty_ratio")):
            report[f"{name}_vs_{baseline}"] = mrr_vs_baseline(
                records, pols, baseline=baseline, metric=metric)
    return report


def _campaign_name(store) -> str:
    try:
        return store.manifest_dict().get("name", "?")
    except OSError:
        return "?"


def format_report(report: dict) -> str:
    """Human-readable rendering of :func:`render_report`'s dict — the
    ``benchmarks/campaign.py --report`` console output."""
    lines = [f"campaign {report['campaign']}: "
             f"{report['n_cells']} cells, "
             f"{report['n_quarantined']} quarantined"]
    pols = report["policies"]
    for title, key in (("winners (miss ratio)", "winners"),
                       ("winners (byte-weighted)", "winners_bytes")):
        table = report.get(key) or {}
        if not table:
            continue
        lines.append(f"\n{title}:")
        lines.append(bench_report.fmt_row(
            ["dataset"] + pols + ["winner", "margin"],
            [16] + [10] * len(pols) + [14, 8]))
        for ds, row in table.items():
            lines.append(bench_report.fmt_row(
                [ds] + [f"{row['wins'].get(p, 0.0):.2f}" for p in pols]
                + [row["winner"], f"{row['margin']:.4f}"],
                [16] + [10] * len(pols) + [14, 8]))
    baseline = report.get("baseline")
    if baseline:
        for title, key in (
                ("mean MRR", f"mrr_vs_{baseline}"),
                ("mean byte-MRR", f"byte_mrr_vs_{baseline}"),
                ("mean penalty reduction", f"penalty_reduction_vs_{baseline}")):
            table = report.get(key) or {}
            if not table:
                continue
            lines.append(f"\n{title} vs {baseline}:")
            lines.append(bench_report.fmt_row(
                ["dataset"] + pols, [16] + [12] * len(pols)))
            for ds, col in table.items():
                lines.append(bench_report.fmt_row(
                    [ds] + [f"{col.get(p, float('nan')):+.4f}"
                            for p in pols],
                    [16] + [12] * len(pols)))
    cdf = report.get("hit_ratio_cdf") or {}
    if cdf:
        lines.append("\nhit-ratio across cells (min / median / max):")
        for p in pols:
            vals = cdf.get(p, {}).get("values", [])
            if vals:
                lines.append(f"  {p:24s} {min(vals):.3f} / "
                             f"{float(np.median(vals)):.3f} / "
                             f"{max(vals):.3f}  ({len(vals)} cells)")
    return "\n".join(lines)
