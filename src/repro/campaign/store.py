"""Resumable campaign results store: one file per completed cell.

A campaign directory is the single source of truth for a run::

    <root>/
      manifest.json          # the manifest that defines the campaign
      cells/<key>.json       # one repro.bench.result/v2 payload per cell
      quarantine/<key>.json  # failed cells: the cell + its traceback
      journal.jsonl          # append-only wall-time/event log (volatile)

Cell files are keyed by the content hash of ``(trace, policy, K, seed,
T)`` (:func:`cell_key`) and written atomically (temp file +
``os.replace``), so a killed worker never leaves a torn record and a
restarted campaign resumes by simply skipping keys that already exist.
Payloads are validated by :func:`repro.bench.results.validate` on both
write and read, and **normalized** before writing — volatile fields
(``created_unix``, per-record and payload ``wall_s``) are zeroed, real
timings going to ``journal.jsonl`` instead — so an interrupted-and-
resumed campaign produces a ``cells/`` tree *bit-identical* to an
uninterrupted one (``tests/test_campaign.py`` asserts exactly this).

>>> import tempfile
>>> from repro.bench import results
>>> store = CampaignStore(tempfile.mkdtemp())
>>> p = results.build_payload("cell", config={}, records=[
...     {"metrics": {"miss_ratio": [0.5]}, "seeds": [0]}],
...     schema=results.SCHEMA_V2)
>>> _ = store.put("0123abcd", p)
>>> store.has("0123abcd"), store.get("0123abcd")["created_unix"]
(True, 0.0)
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

from ..bench import results

__all__ = ["Cell", "cell_key", "deterministic_payload", "CampaignStore"]


@dataclasses.dataclass(frozen=True)
class Cell:
    """One campaign grid cell: a trace file under a dataset x one policy
    spec x one capacity (int or regime letter) x one seed, with the
    manifest's optional request cap ``T``.

    >>> c = Cell(dataset="kv", trace="corpus/kv.csv.gz", format="auto",
    ...          policy="lru", K="S", seed=0)
    >>> Cell.from_dict(c.to_dict()) == c
    True
    """

    dataset: str
    trace: str                  # trace file path
    format: str                 # ingest format ("auto" resolves by suffix)
    policy: str                 # make_policy spec string
    K: str | int                # int capacity or "S"/"L" regime letter
    seed: int
    T: int | None = None        # request cap from the manifest grid

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, cfg: dict) -> "Cell":
        return cls(**cfg)


def cell_key(cell: Cell) -> str:
    """Content hash identifying one cell's result: the first 16 hex chars
    of the SHA-256 of its canonical identity tuple.  Depends only on what
    determines the numbers — trace path, policy, K, seed and the request
    cap — not on dataset naming, shard assignment or execution order.

    >>> a = Cell(dataset="x", trace="t.csv", format="auto",
    ...          policy="lru", K=8, seed=0)
    >>> cell_key(a) == cell_key(dataclasses.replace(a, dataset="y"))
    True
    >>> cell_key(a) == cell_key(dataclasses.replace(a, seed=1))
    False
    """
    ident = json.dumps(
        {"trace": cell.trace, "policy": cell.policy, "K": cell.K,
         "seed": cell.seed, "T": cell.T},
        sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(ident.encode("utf-8")).hexdigest()[:16]


def deterministic_payload(payload: dict) -> dict:
    """A copy of ``payload`` with the volatile timing fields zeroed —
    ``created_unix`` and every ``wall_s`` (payload- and record-level) —
    so byte-identical inputs produce byte-identical cell files across
    runs.  Wall times belong in the store journal, not the records.

    >>> p = {"created_unix": 9.0, "wall_s": 1.5,
    ...      "records": [{"wall_s": 0.7, "metrics": {"m": [1]}}]}
    >>> q = deterministic_payload(p)
    >>> (q["created_unix"], q["wall_s"], q["records"][0]["wall_s"])
    (0.0, 0.0, 0.0)
    >>> p["wall_s"]                     # the input is left untouched
    1.5
    """
    out = dict(payload)
    if "created_unix" in out:
        out["created_unix"] = 0.0
    if "wall_s" in out:
        out["wall_s"] = 0.0
    if isinstance(out.get("records"), list):
        out["records"] = [
            dict(r, wall_s=0.0) if isinstance(r, dict) and "wall_s" in r
            else r
            for r in out["records"]]
    return out


def _atomic_write(path: str, data: str) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class CampaignStore:
    """Directory-backed, crash-safe result store for one campaign.

    All writes are validate-then-atomic-rename; all reads re-validate, so
    a consumer can trust every file under ``cells/``.  ``has`` /
    ``completed`` / ``quarantined`` are what the executor resumes from.
    """

    CELLS = "cells"
    QUARANTINE = "quarantine"
    MANIFEST = "manifest.json"
    JOURNAL = "journal.jsonl"

    def __init__(self, root: str):
        self.root = str(root)
        self.cells_dir = os.path.join(self.root, self.CELLS)
        self.quarantine_dir = os.path.join(self.root, self.QUARANTINE)
        os.makedirs(self.cells_dir, exist_ok=True)
        os.makedirs(self.quarantine_dir, exist_ok=True)

    # -- cell records -------------------------------------------------------

    def path_for(self, key: str) -> str:
        return os.path.join(self.cells_dir, f"{key}.json")

    def has(self, key: str) -> bool:
        return os.path.exists(self.path_for(key))

    def completed(self) -> list:
        """Sorted keys of every completed cell."""
        return sorted(fn[:-5] for fn in os.listdir(self.cells_dir)
                      if fn.endswith(".json"))

    def put(self, key: str, payload: dict) -> str:
        """Validate, normalize and atomically write one cell payload;
        returns the cell file path."""
        det = deterministic_payload(results.validate(payload))
        path = self.path_for(key)
        _atomic_write(path, json.dumps(det, indent=1, sort_keys=True) + "\n")
        return path

    def get(self, key: str) -> dict:
        """Read + re-validate one completed cell payload."""
        with open(self.path_for(key)) as f:
            return results.validate(json.load(f))

    def payloads(self):
        """Iterate ``(key, payload)`` over every completed cell, sorted by
        key — the report layer's only input."""
        for key in self.completed():
            yield key, self.get(key)

    # -- quarantine ---------------------------------------------------------

    def quarantine(self, key: str, cell, error: str) -> str:
        """Record a failed cell (its identity + the traceback) without
        touching ``cells/`` — the campaign carries on and the failure is
        inspectable.  Delete the file to retry the cell on a later run."""
        path = os.path.join(self.quarantine_dir, f"{key}.json")
        entry = {"key": key, "cell": cell.to_dict(), "error": str(error),
                 # repolint: waive[wallclock] -- quarantine provenance
                 "quarantined_unix": time.time()}
        _atomic_write(path, json.dumps(entry, indent=1) + "\n")
        return path

    def quarantined(self) -> list:
        """Sorted keys of every quarantined cell."""
        return sorted(fn[:-5] for fn in os.listdir(self.quarantine_dir)
                      if fn.endswith(".json"))

    def get_quarantined(self, key: str) -> dict:
        with open(os.path.join(self.quarantine_dir, f"{key}.json")) as f:
            return json.load(f)

    # -- manifest + journal -------------------------------------------------

    def init_manifest(self, manifest) -> None:
        """Pin the campaign's manifest into the store (first run), or
        verify it matches the pinned one (every resume) — mixing two
        different grids into one store is an error, not a surprise."""
        path = os.path.join(self.root, self.MANIFEST)
        mine = json.dumps(manifest.to_dict(), sort_keys=True)
        if os.path.exists(path):
            with open(path) as f:
                pinned = json.dumps(json.load(f), sort_keys=True)
            if pinned != mine:
                raise ValueError(
                    f"store {self.root!r} was created from a different "
                    "manifest; use a fresh store directory (or delete "
                    f"{path} if the change is intentional)")
            return
        _atomic_write(path, json.dumps(manifest.to_dict(), indent=1,
                                       sort_keys=True) + "\n")

    def manifest_dict(self) -> dict:
        """The pinned manifest, as a dict (for ``--report`` with no
        manifest argument: the store is self-describing)."""
        with open(os.path.join(self.root, self.MANIFEST)) as f:
            return json.load(f)

    def journal(self, **event) -> None:
        """Append one JSON event line (timings live here, keeping the
        cell records deterministic)."""
        # repolint: waive[wallclock] -- journal timing is deliberately
        entry = dict(event, unix=time.time())  # outside the cell records
        with open(os.path.join(self.root, self.JOURNAL), "a") as f:
            f.write(json.dumps(entry, sort_keys=True) + "\n")
