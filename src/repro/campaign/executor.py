"""Campaign execution: shard pending cells across workers, resumably.

The executor turns a :class:`repro.campaign.Manifest` into the flat,
deterministically-ordered cell list (:func:`plan_cells`), drops every
cell whose key already sits in the store (completed *or* quarantined —
that is the whole resume protocol), optionally takes a ``--shard i/n``
slice for multi-host launches, and runs the remainder either inline or
across a ``ProcessPoolExecutor`` (spawn context — safe with jax).

Each cell executes as a single-cell :class:`repro.bench.Sweep` through
``run_sweep(stream="auto")``, so out-of-core traces stream off disk
exactly as they do everywhere else, and the resulting
``repro.bench.result/v2`` payload is validated and atomically written by
the store.  A failing trace **quarantines** the cell with its traceback
instead of killing the campaign; wall-times and a progress/ETA ticker
flow through the store journal and the ``progress`` callback.

>>> m = Manifest(name="d", root="corpus",
...              grid=Grid(policies=("lru", "dac"), K=(8,), seeds=(0,)),
...              datasets=(Dataset(name="a", traces=(("t.csv", "auto"),)),))
>>> [(c.policy, c.K) for c in plan_cells(m)]
[('lru', 8), ('dac', 8)]
"""
from __future__ import annotations

import dataclasses
import os
import time
import traceback

from .manifest import Dataset, Grid, Manifest  # noqa: F401  (doctest surface)
from .store import CampaignStore, Cell, cell_key

__all__ = ["plan_cells", "pending_cells", "shard_cells", "parse_shard",
           "execute_cell", "run_campaign", "CampaignSummary"]


def plan_cells(manifest: Manifest) -> list:
    """The campaign's full cell list — every matched trace x the grid —
    in deterministic (dataset, trace, policy, K, seed) order.  Shard
    slices and resume sets are carved out of this one ordering, so every
    worker and every restart agrees on what cell an index means."""
    grid = manifest.grid
    return [Cell(dataset=ds, trace=path, format=fmt, policy=pol, K=K,
                 seed=seed, T=grid.T)
            for ds, path, fmt in manifest.traces()
            for pol in grid.policies
            for K in grid.K
            for seed in grid.seeds]


def pending_cells(cells, store: CampaignStore) -> list:
    """Cells with no completed *and* no quarantined record — exactly what
    a (re)started campaign still has to run."""
    return [c for c in cells
            if not store.has(cell_key(c))
            and not os.path.exists(os.path.join(
                store.quarantine_dir, f"{cell_key(c)}.json"))]


def parse_shard(shard) -> tuple | None:
    """Normalize a shard designator: ``None``, an ``(i, n)`` pair, or the
    CLI string ``"i/n"`` with ``0 <= i < n``.

    >>> parse_shard("1/4"), parse_shard(None), parse_shard((0, 2))
    ((1, 4), None, (0, 2))
    """
    if shard is None:
        return None
    if isinstance(shard, str):
        try:
            i, n = (int(x) for x in shard.split("/"))
        except ValueError:
            raise ValueError(
                f"shard must look like 'i/n' (e.g. '0/4'), got {shard!r}")
    else:
        i, n = (int(x) for x in shard)
    if not 0 <= i < n:
        raise ValueError(f"shard index must satisfy 0 <= i < n, "
                         f"got {i}/{n}")
    return i, n


def shard_cells(cells, shard) -> list:
    """Deterministic ``i``-th of ``n`` slices of the *full* cell list
    (round-robin by plan index) — stable across restarts even as cells
    complete, so multi-host shards never overlap.

    >>> shard_cells([10, 11, 12, 13, 14], "1/2")
    [11, 13]
    """
    parsed = parse_shard(shard)
    if parsed is None:
        return list(cells)
    i, n = parsed
    return list(cells)[i::n]


def _cell_payload(cell: Cell, *, chunk=None, use_pallas=None) -> dict:
    """Run one cell through the Scenario/Sweep machinery and return its
    v2 payload (the store normalizes the volatile timing fields)."""
    from ..bench import Scenario, Sweep, results, run_sweep
    from ..data import ingest

    n = ingest.count_requests(cell.trace, cell.format)
    T = min(cell.T, n) if cell.T else n
    fmt_arg = "" if cell.format == "auto" else f",format={cell.format}"
    scenario = Scenario(
        f"{cell.dataset}/{os.path.basename(cell.trace)}",
        trace=f"file(path={cell.trace}{fmt_arg})", T=T, K=(cell.K,))
    sweep = Sweep(f"cell-{cell_key(cell)}", policies=(cell.policy,),
                  scenarios=(scenario,), seeds=(cell.seed,), observe=True)
    kw = {} if chunk is None else {"chunk": chunk}
    res = run_sweep(sweep, stream="auto", use_pallas=use_pallas, **kw)
    stats = dataclasses.asdict(ingest.characterize(cell.trace, cell.format))
    return res.payload(
        extras={"campaign": {"key": cell_key(cell),
                             "cell": cell.to_dict(),
                             "trace_stats": stats}},
        schema=results.SCHEMA_V2)


def execute_cell(cell: Cell, store: CampaignStore, *, chunk=None,
                 use_pallas=None) -> tuple:
    """Execute one cell against the store: completed cells land in
    ``cells/``, failures in ``quarantine/`` with their traceback.
    Returns ``(key, status, wall_s, error)`` with status ``"done"`` or
    ``"failed"``."""
    key = cell_key(cell)
    t0 = time.perf_counter()
    try:
        payload = _cell_payload(cell, chunk=chunk, use_pallas=use_pallas)
        store.put(key, payload)
        return key, "done", time.perf_counter() - t0, None
    except Exception:
        tb = traceback.format_exc()
        store.quarantine(key, cell, tb)
        return key, "failed", time.perf_counter() - t0, tb


def _pool_worker(cell_cfg: dict, store_root: str, chunk, use_pallas):
    """Top-level (picklable) worker body for ProcessPoolExecutor."""
    cell = Cell.from_dict(cell_cfg)
    return execute_cell(cell, CampaignStore(store_root), chunk=chunk,
                        use_pallas=use_pallas)


@dataclasses.dataclass(frozen=True)
class CampaignSummary:
    """What one ``run_campaign`` invocation did: the planned/sharded cell
    count, how many were already in the store, and the keys executed or
    quarantined this run."""

    total: int                  # cells in this invocation's (sharded) plan
    skipped: int                # already completed or quarantined on entry
    executed: tuple             # keys completed this run, in finish order
    quarantined: tuple          # keys quarantined this run
    remaining: int              # pending cells left (cell budget exhausted)
    wall_s: float

    @property
    def counts(self) -> dict:
        return {"total": self.total, "skipped": self.skipped,
                "executed": len(self.executed),
                "quarantined": len(self.quarantined),
                "remaining": self.remaining}


def _eta(done: int, todo: int, elapsed: float) -> str:
    if not done:
        return "?"
    return f"{elapsed / done * (todo - done):.0f}s"


def run_campaign(manifest: Manifest, store, *, workers: int = 0,
                 shard=None, max_cells: int | None = None,
                 chunk: int | None = None, use_pallas=None,
                 progress=None) -> CampaignSummary:
    """Run (or resume) a campaign: plan -> shard -> skip stored cells ->
    execute the rest, atomically recording each one.

    ``workers <= 1`` runs inline (one process, jit caches shared across
    cells); ``workers > 1`` fans cells out over a spawn-context process
    pool.  ``shard="i/n"`` takes the i-th round-robin slice of the full
    plan for multi-host launches — every host runs the same command with
    a different ``i``.  ``max_cells`` bounds how many cells *execute*
    this invocation (the crash-simulation / smoke-test budget hook);
    skipped cells are free.  ``progress`` (e.g. ``print``) receives one
    ticker line per cell with a running ETA.
    """
    store = store if isinstance(store, CampaignStore) \
        else CampaignStore(store)
    store.init_manifest(manifest)
    cells = shard_cells(plan_cells(manifest), shard)
    pending = pending_cells(cells, store)
    if max_cells is not None:
        if max_cells < 0:
            raise ValueError(f"max_cells must be >= 0, got {max_cells}")
        budget = pending[:max_cells]
    else:
        budget = pending
    skipped = len(cells) - len(pending)
    store.journal(event="start", name=manifest.name, shard=shard,
                  workers=workers, planned=len(cells), skipped=skipped,
                  pending=len(pending), budget=len(budget))
    t0 = time.perf_counter()
    executed, quarantined = [], []

    def record(key, status, wall, cell):
        elapsed = time.perf_counter() - t0
        (executed if status == "done" else quarantined).append(key)
        done = len(executed) + len(quarantined)
        store.journal(event=status, key=key, wall_s=wall,
                      trace=cell.trace, policy=cell.policy,
                      K=cell.K, seed=cell.seed)
        if progress is not None:
            progress(
                f"[{manifest.name}] {done}/{len(budget)} "
                f"{cell.dataset}/{os.path.basename(cell.trace)} "
                f"{cell.policy} K={cell.K} s{cell.seed}: {status} "
                f"[{wall:.1f}s, ETA {_eta(done, len(budget), elapsed)}]")

    if workers and workers > 1 and len(budget) > 1:
        import concurrent.futures as cf
        import multiprocessing as mp
        ctx = mp.get_context("spawn")
        with cf.ProcessPoolExecutor(max_workers=workers,
                                    mp_context=ctx) as pool:
            futs = {pool.submit(_pool_worker, c.to_dict(), store.root,
                                chunk, use_pallas): c
                    for c in budget}
            for fut in cf.as_completed(futs):
                key, status, wall, _ = fut.result()
                record(key, status, wall, futs[fut])
    else:
        for cell in budget:
            key, status, wall, _ = execute_cell(
                cell, store, chunk=chunk, use_pallas=use_pallas)
            record(key, status, wall, cell)

    wall = time.perf_counter() - t0
    summary = CampaignSummary(
        total=len(cells), skipped=skipped, executed=tuple(executed),
        quarantined=tuple(quarantined),
        remaining=len(pending) - len(budget), wall_s=wall)
    store.journal(event="stop", wall_s=wall, **summary.counts)
    return summary
