"""Campaign orchestration: resumable, sharded, corpus-scale evaluation.

The paper's headline result is a 1067-trace, 6-dataset grid; one
``run_sweep`` call cannot deliver that.  This package runs whole trace
*directories* through the existing Scenario/Sweep machinery, built to
survive the realities of corpus scale::

    from repro.campaign import load_manifest, run_campaign, render_report
    from repro.campaign import CampaignStore

    manifest = load_manifest("campaign.json")    # datasets x grid, as data
    run_campaign(manifest, "runs/corpus",        # resumable: reruns skip
                 workers=4, progress=print)      #   completed cells
    report = render_report(CampaignStore("runs/corpus"))

Four layers (see ``docs/EXPERIMENTS.md`` "Campaigns"):

* :mod:`repro.campaign.manifest` — the versioned
  ``repro.campaign.manifest/v1`` format: datasets as globs (or pinned
  lists with frozen :func:`repro.data.ingest.characterize` stats, what
  ``tools/make_manifest.py`` emits) plus the policy x K x seed grid;
* :mod:`repro.campaign.store` — one atomically-written, schema-validated
  ``repro.bench.result/v2`` file per ``(trace, policy, K, seed)`` cell,
  keyed by content hash and normalized to be bit-reproducible, so a
  killed worker never corrupts anything and a restart skips what's done;
* :mod:`repro.campaign.executor` — shards pending cells across process
  workers (or ``--shard i/n`` across hosts), streams each cell through
  ``run_sweep(stream="auto")``, quarantines failing traces with their
  traceback instead of dying, and tickers progress/ETA;
* :mod:`repro.campaign.report` — hit-ratio CDFs, per-dataset winner
  tables and miss/byte/penalty reduction vs a baseline, rendered from
  the store without rerunning anything.

``benchmarks/campaign.py`` is the CLI over all four.
"""
from .executor import (CampaignSummary, execute_cell, parse_shard,
                       pending_cells, plan_cells, run_campaign, shard_cells)
from .manifest import (MANIFEST_SCHEMA, Dataset, Grid, Manifest,
                       load_manifest, scan_corpus)
from .report import (REPORT_SCHEMA, campaign_records, complete_cells,
                     dataset_winners, format_report, hit_ratio_cdf,
                     mrr_vs_baseline, render_report)
from .store import Cell, CampaignStore, cell_key, deterministic_payload

__all__ = [
    "MANIFEST_SCHEMA", "Manifest", "Dataset", "Grid", "load_manifest",
    "scan_corpus",
    "Cell", "CampaignStore", "cell_key", "deterministic_payload",
    "plan_cells", "pending_cells", "shard_cells", "parse_shard",
    "execute_cell", "run_campaign", "CampaignSummary",
    "REPORT_SCHEMA", "campaign_records", "complete_cells",
    "dataset_winners", "mrr_vs_baseline", "hit_ratio_cdf",
    "render_report", "format_report",
]
