"""Campaign manifests: a versioned, declarative corpus-scale grid.

A manifest (``repro.campaign.manifest/v1``) declares *datasets* — named
globs over libCacheSim-format trace files — plus the policy x capacity x
seed grid to run every matched trace through.  It is plain data (JSON on
disk, TOML accepted where the interpreter ships ``tomllib``), so a
thousand-trace campaign is fully described by one small file::

    {
      "schema": "repro.campaign.manifest/v1",
      "name": "corpus",
      "root": "benchmarks/corpus",
      "datasets": [
        {"name": "oracle", "glob": "*.oracleGeneral.bin.gz"},
        {"name": "kv",     "glob": "*.csv.gz"}
      ],
      "grid": {"policies": ["fifo", "lru", "dac"],
               "K": ["S", "L"], "seeds": [0], "T": null}
    }

``glob`` patterns resolve relative to ``root`` (itself relative to the
manifest file's directory when loaded from disk).  A dataset may instead
pin an explicit ``traces`` list — ``tools/make_manifest.py`` emits that
form, freezing each trace's :func:`repro.data.ingest.characterize` stats
into the manifest so the campaign grid is reproducible even if files are
later added next to it.  ``grid.K`` entries are ints or the paper's
``"S"`` / ``"L"`` regime letters (resolved per trace against its id
footprint, exactly like :class:`repro.bench.Scenario`); ``grid.T`` caps
the requests taken from each trace (``null`` = full trace).

>>> m = Manifest.from_dict({
...     "schema": MANIFEST_SCHEMA, "name": "demo",
...     "root": ".", "datasets": [{"name": "d", "glob": "*.csv"}],
...     "grid": {"policies": ["lru"], "K": ["S"], "seeds": [0]}})
>>> m.name, m.grid.policies, m.grid.K
('demo', ('lru',), ('S',))
"""
from __future__ import annotations

import dataclasses
import glob as _glob
import json
import os

from ..data import ingest

try:                                        # py3.11+ stdlib; optional here
    import tomllib as _toml
except ImportError:                         # pragma: no cover - py<=3.10
    _toml = None

__all__ = ["MANIFEST_SCHEMA", "Grid", "Dataset", "Manifest",
           "load_manifest", "scan_corpus"]

MANIFEST_SCHEMA = "repro.campaign.manifest/v1"


def _fail(path: str, msg: str):
    raise ValueError(f"campaign manifest violation at {path}: {msg}")


@dataclasses.dataclass(frozen=True)
class Grid:
    """The evaluation grid applied to every matched trace: ``make_policy``
    spec strings x capacities (ints or ``"S"``/``"L"`` regime letters) x
    seeds, plus an optional per-trace request cap ``T``.

    >>> g = Grid(policies=("lru", "dac"), K=("S", 64), seeds=(0,))
    >>> Grid.from_dict(g.to_dict()) == g
    True
    """

    policies: tuple
    K: tuple = ("S", "L")
    seeds: tuple = (0,)
    T: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "policies",
                           tuple(str(p) for p in self.policies))
        ks = []
        for k in self.K:
            if isinstance(k, str) and not k.isdigit():
                if k not in ("S", "L"):
                    _fail("$.grid.K", f"capacity entries are ints or "
                          f"'S'/'L' regime letters, got {k!r}")
                ks.append(k)
            else:
                ks.append(int(k))
        object.__setattr__(self, "K", tuple(ks))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        if not self.policies:
            _fail("$.grid.policies", "needs at least one policy")
        if not self.K:
            _fail("$.grid.K", "needs at least one capacity")
        if not self.seeds:
            _fail("$.grid.seeds", "needs at least one seed")
        if self.T is not None:
            if int(self.T) <= 0:
                _fail("$.grid.T", f"must be a positive cap or null, "
                      f"got {self.T}")
            object.__setattr__(self, "T", int(self.T))

    def to_dict(self) -> dict:
        return {"policies": list(self.policies), "K": list(self.K),
                "seeds": list(self.seeds), "T": self.T}

    @classmethod
    def from_dict(cls, cfg: dict) -> "Grid":
        if not isinstance(cfg, dict):
            _fail("$.grid", f"must be a dict, got {type(cfg).__name__}")
        if "policies" not in cfg:
            _fail("$.grid.policies", "missing")
        return cls(policies=tuple(cfg["policies"]),
                   K=tuple(cfg.get("K", ("S", "L"))),
                   seeds=tuple(cfg.get("seeds", (0,))),
                   T=cfg.get("T"))


@dataclasses.dataclass(frozen=True)
class Dataset:
    """One named trace group: a glob over ``root``, or a pinned explicit
    ``traces`` list (``(path, format)`` pairs plus optional frozen stats).

    >>> d = Dataset(name="kv", glob="*.csv.gz")
    >>> Dataset.from_dict(d.to_dict()) == d
    True
    """

    name: str
    glob: str | None = None
    format: str = "auto"
    traces: tuple = ()          # of (relpath, format) pairs, when pinned
    stats: dict | None = None   # relpath -> frozen characterization dict

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            _fail("$.datasets[].name", f"must be a non-empty string, "
                  f"got {self.name!r}")
        if self.glob is None and not self.traces:
            _fail(f"$.datasets[{self.name}]",
                  "needs a 'glob' pattern or a pinned 'traces' list")
        object.__setattr__(self, "traces",
                           tuple((str(p), str(f)) for p, f in self.traces))

    def resolve(self, root: str) -> list:
        """The dataset's ``(path, format)`` pairs: the pinned list when
        present (paths joined onto ``root``), else a sorted glob."""
        if self.traces:
            return [(os.path.join(root, p), f) for p, f in self.traces]
        return [(p, self.format)
                for p in sorted(_glob.glob(os.path.join(root, self.glob)))]

    def to_dict(self) -> dict:
        out = {"name": self.name, "format": self.format}
        if self.glob is not None:
            out["glob"] = self.glob
        if self.traces:
            out["traces"] = [list(t) for t in self.traces]
        if self.stats is not None:
            out["stats"] = self.stats
        return out

    @classmethod
    def from_dict(cls, cfg: dict) -> "Dataset":
        if not isinstance(cfg, dict) or "name" not in cfg:
            _fail("$.datasets[]", f"each dataset is a dict with a 'name', "
                  f"got {cfg!r}")
        return cls(name=cfg["name"], glob=cfg.get("glob"),
                   format=cfg.get("format", "auto"),
                   traces=tuple(tuple(t) for t in cfg.get("traces", ())),
                   stats=cfg.get("stats"))


@dataclasses.dataclass(frozen=True)
class Manifest:
    """A full campaign declaration: datasets x grid, versioned and
    JSON-round-trippable (the store keeps a copy so a campaign directory
    is self-describing).

    >>> m = Manifest(name="demo", root=".", grid=Grid(policies=("lru",)),
    ...              datasets=(Dataset(name="d", glob="*.csv"),))
    >>> Manifest.from_dict(m.to_dict()) == m
    True
    """

    name: str
    root: str
    datasets: tuple
    grid: Grid

    def __post_init__(self):
        object.__setattr__(self, "datasets", tuple(self.datasets))
        if not self.name or not isinstance(self.name, str):
            _fail("$.name", f"must be a non-empty string, got {self.name!r}")
        if not self.datasets:
            _fail("$.datasets", "needs at least one dataset")
        names = [d.name for d in self.datasets]
        if len(set(names)) != len(names):
            _fail("$.datasets", f"dataset names must be unique, got {names}")

    def traces(self) -> list:
        """Every ``(dataset_name, path, format)`` triple the manifest
        matches, in deterministic (dataset-declaration, sorted-path)
        order.  A dataset whose glob matches nothing is an error — a
        typo'd pattern must not silently shrink the campaign."""
        out = []
        for ds in self.datasets:
            matched = ds.resolve(self.root)
            if not matched:
                _fail(f"$.datasets[{ds.name}]",
                      f"matched no trace files under {self.root!r} "
                      f"(glob {ds.glob!r})")
            out.extend((ds.name, path, fmt) for path, fmt in matched)
        return out

    def to_dict(self) -> dict:
        return {"schema": MANIFEST_SCHEMA, "name": self.name,
                "root": self.root,
                "datasets": [d.to_dict() for d in self.datasets],
                "grid": self.grid.to_dict()}

    @classmethod
    def from_dict(cls, cfg: dict) -> "Manifest":
        if not isinstance(cfg, dict):
            _fail("$", f"manifest must be a dict, got {type(cfg).__name__}")
        if cfg.get("schema") != MANIFEST_SCHEMA:
            _fail("$.schema", f"expected {MANIFEST_SCHEMA!r}, "
                  f"got {cfg.get('schema')!r}")
        for key in ("name", "datasets", "grid"):
            if key not in cfg:
                _fail(f"$.{key}", "missing")
        if not isinstance(cfg["datasets"], list):
            _fail("$.datasets", "must be a list")
        return cls(name=cfg["name"], root=cfg.get("root", "."),
                   datasets=tuple(Dataset.from_dict(d)
                                  for d in cfg["datasets"]),
                   grid=Grid.from_dict(cfg["grid"]))

    def save(self, path: str) -> str:
        """Write the manifest as JSON (atomically — a crashed scan must
        not leave a torn manifest behind); returns ``path``."""
        from ..bench.results import atomic_write_json
        atomic_write_json(path, self.to_dict(), sort_keys=True)
        return path


def load_manifest(path: str) -> Manifest:
    """Load + validate a manifest file (``.json``, or ``.toml`` on
    interpreters that ship ``tomllib``).  A relative ``root`` is
    re-anchored at the manifest file's directory, so a campaign directory
    can be launched from anywhere."""
    if str(path).endswith(".toml"):
        if _toml is None:
            raise RuntimeError(
                f"{path}: TOML manifests need the stdlib 'tomllib' "
                "(python >= 3.11); re-emit the manifest as JSON")
        with open(path, "rb") as f:
            cfg = _toml.load(f)
    else:
        with open(path) as f:
            cfg = json.load(f)
    m = Manifest.from_dict(cfg)
    if not os.path.isabs(m.root):
        root = os.path.join(os.path.dirname(os.path.abspath(path)), m.root)
        m = dataclasses.replace(m, root=os.path.normpath(root))
    return m


def _dataset_name_for(root: str, path: str) -> str:
    """Grouping rule for scanned corpora: traces under a subdirectory form
    that subdirectory's dataset; files directly in ``root`` group by
    trace format (oracle / csv / txt)."""
    rel = os.path.relpath(path, root)
    head = rel.split(os.sep, 1)[0]
    if head != os.path.basename(rel):
        return head
    return ingest.detect_format(path)


def scan_corpus(root: str, *, name: str | None = None, grid: Grid,
                dataset: str | None = None,
                characterize: bool = True) -> Manifest:
    """Build a pinned manifest by scanning ``root`` for trace files (any
    suffix :func:`repro.data.ingest.detect_format` understands, one
    directory level deep).  Traces group into datasets by subdirectory —
    format name for flat files — unless ``dataset`` forces a single
    group; ``characterize=True`` freezes each trace's stats into the
    manifest (what ``tools/make_manifest.py`` emits).  A plain
    ``.oracleGeneral.bin`` with a byte-identical ``.gz`` twin is skipped,
    mirroring ``benchmarks/real_traces.py``."""
    paths = []
    for dirpath, _, files in sorted(os.walk(root)):
        if os.path.relpath(dirpath, root).count(os.sep) > 0:
            continue
        for fn in sorted(files):
            p = os.path.join(dirpath, fn)
            try:
                ingest.detect_format(p)
            except ValueError:
                continue
            if fn.endswith(".oracleGeneral.bin") and \
                    os.path.exists(p + ".gz"):
                continue
            paths.append(p)
    if not paths:
        raise FileNotFoundError(f"no trace files under {root!r}")
    groups: dict = {}
    for p in paths:
        ds = dataset or _dataset_name_for(root, p)
        groups.setdefault(ds, []).append(p)
    datasets = []
    for ds in sorted(groups):
        rels = [os.path.relpath(p, root) for p in groups[ds]]
        stats = None
        if characterize:
            stats = {rel: dataclasses.asdict(ingest.characterize(p))
                     for rel, p in zip(rels, groups[ds])}
        datasets.append(Dataset(
            name=ds, traces=tuple((rel, "auto") for rel in rels),
            stats=stats))
    return Manifest(name=name or os.path.basename(os.path.normpath(root)),
                    root=root, datasets=tuple(datasets), grid=grid)
