"""Fleet serving: dynamic multi-tenant DAC with auction arbitration,
mesh sharding, and per-tenant SLO telemetry.

Where :mod:`repro.tier` holds a fixed tenant set, the fleet layer serves
a *population*: tenants arrive (Poisson), hold a cache lane for one
session (exponential), and leave — all inside one scanned, jittable
program over fixed-shape ``[n_lanes]`` pools with an alive mask.  The
``auction`` arbiter prices capacity by each tenant's byte-miss-cost EWMA,
``replay_fleet(..., mesh=...)`` shards the lane axis over a device mesh
with periodic ``psum`` budget rebalancing, and every replay streams SLO
telemetry: per-tenant penalty quantiles (p50/p99 from in-carry
histograms) and Jain's occupancy-fairness index.

>>> from repro.data.traces import fleet_trace
>>> from repro.fleet import FleetTier, replay_fleet
>>> keys = fleet_trace(N=64, T=400, n_lanes=4, rate=0.05,
...                    mean_session=120, seed=1)
>>> fl = FleetTier("dac(k_min=4)", n_lanes=4, budget=64, arbiter="auction")
>>> res = replay_fleet(fl, keys)
>>> 0.0 <= float(res.jain) <= 1.0
True

See ``docs/ARCHITECTURE.md`` (fleet section) and the ``fleet_sweep``
benchmark for auction-vs-static-partition comparisons.
"""
from .fleet import FleetResult, FleetTier, replay_fleet
from .telemetry import (BINS, jain_index, penalty_bucket, penalty_quantile,
                        window_records)

__all__ = [
    "FleetTier", "FleetResult", "replay_fleet",
    "BINS", "penalty_bucket", "penalty_quantile", "jain_index",
    "window_records",
]
