"""Per-tenant SLO telemetry for the fleet replay.

Serving SLOs are quantiles, not means: a fleet can have a fine average
miss penalty while one tenant's p99 blows its latency budget.  The fleet
scan therefore carries a fixed-bucket **penalty histogram** per lane —
O(BINS) state, streaming, jit-friendly — from which any quantile is
recovered host-side to one-bucket resolution.  Buckets are log2-spaced
(bucket 0 is exactly "no penalty": hits and free misses), so the
resolution is relative — fine where SLO thresholds live, coarse in the
tail's far end.

Occupancy *fairness* is Jain's index over the lanes' mean active sizes:
``J = (sum x)^2 / (n * sum x^2)`` — 1.0 when every tenant holds the same
share, ``1/n`` when one tenant holds everything.  An auction arbiter is
*supposed* to be unfair when utilities differ; reporting J alongside the
aggregate byte-MRR keeps that trade visible instead of implicit.

>>> import jax.numpy as jnp
>>> h = jnp.zeros((BINS,), jnp.int32)
>>> for p in [0.0, 0.0, 2.0, 40.0]:
...     b = int(penalty_bucket(jnp.float32(p)))
...     h = h.at[b].add(1)
>>> float(penalty_quantile(h, 0.5))       # median request: no penalty
0.0
>>> float(penalty_quantile(h, 0.99))      # p99 lands in 40ms's bucket
64.0
>>> round(float(jain_index(jnp.array([4., 4., 4., 4.]))), 3)
1.0
>>> round(float(jain_index(jnp.array([16., 0., 0., 0.]))), 3)
0.25
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["BINS", "LOG2_LO", "penalty_bucket", "penalty_quantile",
           "jain_index", "window_records"]

# bucket 0: zero penalty; buckets 1..BINS-1: log2-spaced, bucket j covering
# [2^(LOG2_LO+j-1), 2^(LOG2_LO+j)) — with LOG2_LO=-4 the tracked range is
# [2^-4, 2^27) cost units (ms under the "fetch" cost model), clamped at
# both ends
BINS = 32
LOG2_LO = -4


def penalty_bucket(penalty):
    """Histogram bucket index (jnp, any shape) for a per-request miss
    penalty.  0 for no penalty; otherwise log2-spaced, edge-clamped."""
    safe = jnp.maximum(penalty, jnp.float32(1e-30))
    idx = jnp.floor(jnp.log2(safe)).astype(jnp.int32) - LOG2_LO + 1
    return jnp.where(penalty > 0, jnp.clip(idx, 1, BINS - 1), 0)


def _edges() -> np.ndarray:
    """Upper edge of each bucket (bucket 0's is exactly 0.0)."""
    return np.concatenate(
        [[0.0], 2.0 ** (LOG2_LO + np.arange(1, BINS, dtype=np.float64))])


def penalty_quantile(hist, q: float):
    """The ``q``-quantile's bucket upper edge, from a ``[..., BINS]``
    histogram (host-side).  Conservative to one bucket: the true quantile
    is <= the returned edge.  Empty histograms (a lane that never served)
    report 0.0."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must lie in [0, 1], got {q}")
    h = np.asarray(hist, np.float64)
    total = h.sum(axis=-1)
    cdf = np.cumsum(h, axis=-1)
    # first bucket where the CDF crosses q * total
    target = q * total[..., None]
    idx = np.argmax(cdf >= target - 1e-9, axis=-1)
    out = _edges()[idx]
    return np.where(total > 0, out, 0.0)


def jain_index(x, mask=None):
    """Jain's fairness index over the last axis: ``(sum x)^2 / (n sum
    x^2)``, with ``mask`` selecting the lanes that count (e.g. lanes that
    ever hosted a tenant).  1.0 = perfectly even, ``1/n`` = maximally
    concentrated; an empty or all-zero selection reports 1.0 (nothing to
    be unfair about)."""
    x = np.asarray(x, np.float64)
    if mask is not None:
        x = np.where(np.asarray(mask, bool), x, 0.0)
        n = np.asarray(mask, bool).sum(axis=-1)
    else:
        n = x.shape[-1]
    s1 = x.sum(axis=-1)
    s2 = (x * x).sum(axis=-1)
    den = n * s2
    out = np.divide(s1 * s1, den, out=np.ones_like(s1, np.float64),
                    where=den > 0)
    return float(out) if np.ndim(out) == 0 else out


def window_records(obs, windows: int = 8):
    """Downsample a fleet replay's ``obs`` (``{"k": [T, N], "alive":
    [T, N]}``) into per-window records for the v2 results schema's
    ``extras`` — each window's mean occupancy per lane, alive fraction,
    and the conservation headroom ``max_t sum_i k``.  Host-side."""
    ks = np.asarray(obs["k"], np.float64)
    alive = np.asarray(obs["alive"], bool)
    T = ks.shape[0]
    bounds = np.linspace(0, T, windows + 1).astype(int)
    out = []
    for w in range(windows):
        lo, hi = int(bounds[w]), int(bounds[w + 1])
        if hi <= lo:
            continue
        out.append({
            "t0": lo, "t1": hi,
            "mean_k": [float(v) for v in ks[lo:hi].mean(axis=0)],
            "alive_frac": [float(v) for v in alive[lo:hi].mean(axis=0)],
            "peak_sum_k": float(ks[lo:hi].sum(axis=1).max()),
        })
    return out
