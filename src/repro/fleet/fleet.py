"""Dynamic multi-tenant fleet: tenant lifecycle + auction arbitration +
mesh sharding, one scanned program.

The tier layer (:mod:`repro.tier`) holds N tenants fixed for the whole
replay.  A serving fleet doesn't: tenants arrive, hold a cache for one
session, and leave.  :class:`FleetTier` keeps the tier's fixed-shape
discipline — ``n_lanes`` lane slots allocated up front, every array
``[n_lanes, ...]`` — and moves the lifecycle *inside* the scan via an
``alive`` mask driven by the trace itself: the ``fleet(...)`` trace
family (:func:`repro.data.traces.fleet_trace`) marks an idle lane with
key ``-1``, so an alive-mask edge is an arrival or departure event.

Per scanned step, in order:

1. **departures** — lanes whose key flipped to ``-1``: active size,
   cap and controller scalars zero out, so the departed tenant's slots
   fall back into the free pool by no longer being counted;
2. **admission** — lanes whose key flipped from ``-1``: a fresh tenant
   is granted ``k_min`` plus whatever headroom toward ``k0`` the pool
   covers (cumulative-sum grants, like the greedy arbiter), with
   ``k_min`` *reserved* for every still-idle lane so a full fleet can
   always admit;
3. **policy step** — every lane advances one fused
   ``step_budgeted`` under ``vmap`` (dead lanes run on neutralized
   inputs and their outputs are discarded);
4. **telemetry** — per-lane Metrics, the byte-miss-cost EWMA
   (``utility``), and the SLO penalty histogram update in the carry;
5. **arbitration** — the arbiter prices the next step's capacity caps
   from ``(k, demanding, budget, utility)``; the auction arbiter is the
   one that actually reads ``utility``.

The conservation law generalizes the tier's: at every step
``sum(k) + k_min * n_idle + outstanding_grants <= budget`` — so
``sum(k) <= budget`` holds through any churn pattern (locked by
``tests/test_fleet.py``).

**Mesh sharding**: ``replay_fleet(..., mesh=...)`` splits the lane axis
over a device mesh with ``shard_map``.  Each shard runs the same scanned
program against a per-shard budget split; every ``rebalance`` steps the
shards exchange their committed capacity and utility mass through
``psum`` and the global slack is re-dealt in proportion to utility —
cross-shard capacity trading at O(1) collective cost, scaling the fleet
to thousands of lanes without serializing on one arbiter.

>>> import numpy as np
>>> from repro.data.traces import fleet_trace
>>> keys = fleet_trace(N=64, T=600, n_lanes=4, rate=0.05,
...                    mean_session=150, seed=0)
>>> fl = FleetTier("dac(k_min=4)", n_lanes=4, budget=64, arbiter="auction")
>>> res = replay_fleet(fl, keys, observe=True)
>>> bool(np.asarray(res.obs["k"]).sum(axis=1).max() <= 64)  # conservation
True
>>> res.metrics.hits.shape                                  # per-lane
(4,)
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core import make_policy
from ..core.dynamicadaptiveclimb import DynamicAdaptiveClimb
from ..core.policy import (EMPTY, Request, lane_pad, normalize_pallas_mode,
                           pallas_mode)
from ..core.simulator import Metrics, _count_dtype, _ratio
from ..tier.arbiter import make_arbiter
from ..train.train_step import _shard_map
from . import telemetry

__all__ = ["FleetTier", "FleetResult", "replay_fleet"]


class FleetResult(NamedTuple):
    """Per-lane fleet replay totals plus the SLO telemetry.

    ``metrics`` leaves carry a trailing lane axis (``[N]``, or ``[S, N]``
    seed-batched); idle steps count nothing (``requests`` is each lane's
    *served* request count).  ``avg_k`` is the time-mean active size over
    all T steps (0 while idle), ``alive_frac`` the fraction of steps the
    lane hosted a tenant, ``hist`` the ``[..., N, BINS]`` penalty
    histogram, and ``obs`` is ``{"k": [T, N], "alive": [T, N]}`` under
    ``observe=True`` (else ``None``).
    """

    metrics: Metrics
    avg_k: jax.Array
    alive_frac: jax.Array
    hist: jax.Array
    obs: Any

    # -- per-lane ratios ----------------------------------------------------
    @property
    def hit_ratio(self):
        return _ratio(self.metrics.hits, self.metrics.requests)

    @property
    def miss_ratio(self):
        m = self.metrics
        return _ratio(np.asarray(m.requests) - np.asarray(m.hits),
                      m.requests)

    @property
    def byte_miss_ratio(self):
        return _ratio(self.metrics.bytes_missed, self.metrics.bytes_total)

    @property
    def penalty_ratio(self):
        return _ratio(self.metrics.penalty, self.metrics.cost_total)

    # -- fleet aggregates (sum over the lane axis, then the ratio) ----------
    def _agg(self, num, den):
        return _ratio(np.asarray(num, dtype=np.float64).sum(axis=-1),
                      np.asarray(den, dtype=np.float64).sum(axis=-1))

    @property
    def agg_miss_ratio(self):
        m = self.metrics
        return self._agg(np.asarray(m.requests) - np.asarray(m.hits),
                         m.requests)

    @property
    def agg_byte_miss_ratio(self):
        return self._agg(self.metrics.bytes_missed, self.metrics.bytes_total)

    @property
    def agg_penalty_ratio(self):
        return self._agg(self.metrics.penalty, self.metrics.cost_total)

    # -- SLO telemetry ------------------------------------------------------
    def penalty_quantile(self, q: float):
        """Per-lane penalty quantile (bucket upper edge) — ``[..., N]``."""
        return telemetry.penalty_quantile(self.hist, q)

    def agg_penalty_quantile(self, q: float):
        """Fleet-wide penalty quantile over all served requests."""
        return telemetry.penalty_quantile(
            np.asarray(self.hist, np.float64).sum(axis=-2), q)

    @property
    def jain(self):
        """Jain fairness of mean-occupancy-while-alive across the lanes
        that ever hosted a tenant."""
        af = np.asarray(self.alive_frac, np.float64)
        k = np.asarray(self.avg_k, np.float64)
        occ = np.divide(k, af, out=np.zeros_like(k), where=af > 0)
        return telemetry.jain_index(occ, mask=af > 0)


class FleetTier:
    """Static description of one fleet: policy x n_lanes x budget x
    arbiter.  Hashable (a jit static argument, like ``CacheTier``).

    ``n_lanes`` bounds the *concurrent* tenants (the trace's arrival
    process decides how many are live at once); ``budget`` is the global
    slot pool.  Resizable fleets (DAC) require ``budget >= n_lanes *
    k_min`` so a fully-booked fleet can still hold every tenant at the
    floor — admission reserves that floor for idle lanes.  ``k0`` is the
    admission *target* (granted fully when the pool covers it);
    ``util_decay`` sets the byte-miss-cost EWMA the auction arbiter
    prices by.  Non-resizing policies pair with the static arbiter only,
    exactly like the tier.

    >>> FleetTier("dac(k_min=4)", n_lanes=8, budget=128, arbiter="auction")
    FleetTier(dynamicadaptiveclimb, n_lanes=8, budget=128, arbiter=auction, k0=4, util_decay=0.98)
    """

    def __init__(self, policy="dac", n_lanes: int = 8, budget: int = 256,
                 arbiter="auction", k0: int | None = None,
                 util_decay: float = 0.98):
        self.policy = make_policy(policy)
        self.arbiter = make_arbiter(arbiter)
        self.n_lanes = int(n_lanes)
        self.budget = int(budget)
        self.util_decay = float(util_decay)
        self.resizable = isinstance(self.policy, DynamicAdaptiveClimb)
        if self.n_lanes < 1:
            raise ValueError("n_lanes must be >= 1")
        if self.budget // self.n_lanes < 1:
            raise ValueError(
                f"budget {self.budget} too small for {self.n_lanes} lanes")
        if not self.resizable and self.arbiter.name != "static":
            raise ValueError(
                f"policy {self.policy.name!r} emits no resize signals; only "
                "arbiter('static') is meaningful for it")
        if self.resizable and self.share < self.policy.k_min:
            raise ValueError(
                f"budget {self.budget} cannot float {self.n_lanes} lanes at "
                f"the k_min={self.policy.k_min} floor — admission reserves "
                "k_min per lane so a full fleet never over-commits")
        if k0 is None:
            k0 = (max(self.policy.k_min, self.share // self.policy.growth)
                  if self.resizable else self.share)
        self.k0 = int(k0)
        if self.resizable and not (self.policy.k_min <= self.k0
                                   <= self.budget):
            raise ValueError(
                f"k0 must lie in [k_min={self.policy.k_min}, "
                f"budget={self.budget}], got {self.k0}")

    @property
    def share(self) -> int:
        """The static per-lane partition, ``budget // n_lanes``."""
        return self.budget // self.n_lanes

    @property
    def k_min(self) -> int:
        """Per-lane floor the admission path reserves (0 when the policy
        has no resize floor — non-resizable lanes hold a fixed share)."""
        return self.policy.k_min if self.resizable else 0

    # -- state --------------------------------------------------------------
    def init(self, n_lanes: int | None = None) -> dict:
        """Fresh fleet state for ``n_lanes`` lanes (default: all; the
        sharded path builds one per-shard block).  All lanes start idle:
        ``k = cap = 0``, caches EMPTY, no utility."""
        n = self.n_lanes if n_lanes is None else int(n_lanes)
        if self.resizable:
            p = {
                "cache": jnp.full((n, lane_pad(self.budget)), EMPTY,
                                  jnp.int32),
                "jump": jnp.zeros((n,), jnp.int32),
                "jump2": jnp.zeros((n,), jnp.int32),
                "k": jnp.zeros((n,), jnp.int32),
                "kmax": jnp.full((n,), self.budget, jnp.int32),
                "cap": jnp.zeros((n,), jnp.int32),
            }
        else:
            st = self.policy.init(self.share)
            p = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), st)
        return {"p": p, "alive": jnp.zeros((n,), bool),
                "util": jnp.zeros((n,), jnp.float32)}

    # -- hashability for jit static args ------------------------------------
    def _fields(self):
        return (self.policy, self.arbiter, self.n_lanes, self.budget,
                self.k0, self.util_decay)

    def __hash__(self):
        return hash((type(self).__name__, self._fields()))

    def __eq__(self, other):
        return type(self) is type(other) and self._fields() == other._fields()

    def __repr__(self):
        return (f"FleetTier({self.policy.name}, n_lanes={self.n_lanes}, "
                f"budget={self.budget}, arbiter={self.arbiter.name}, "
                f"k0={self.k0}, util_decay={self.util_decay})")


def _tree_where(mask, a, b):
    """Leaf-wise ``where`` with the [N] mask broadcast over trailing dims."""
    def sel(x, y):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree_util.tree_map(sel, a, b)


def _fleet_step(tier: FleetTier, st: dict, req: Request, budget):
    """One fleet step (lifecycle + policy + arbitration) for the lane
    block in ``st``.  ``budget`` is the block's slot budget — the global
    int unsharded, a traced per-shard scalar under ``shard_map``.
    Returns ``(st, (hit, bytes_missed, penalty, k, alive))`` with every
    output masked to live lanes."""
    p, alive_prev, util = st["p"], st["alive"], st["util"]
    alive = req.key >= 0
    arrive = alive & ~alive_prev
    depart = alive_prev & ~alive
    pooled = tier.arbiter.pooled

    if tier.resizable:
        k_min = tier.policy.k_min
        # 1. departures: zero the lane's claim — its slots are now free
        #    simply by not being counted
        k = jnp.where(depart, 0, p["k"])
        cap = jnp.where(depart, 0, p["cap"])
        util = jnp.where(depart | arrive, 0.0, util)

        # 2. admission: k_min guaranteed (reserved for every idle lane),
        #    plus pool headroom toward k0, granted in lane order
        if pooled:
            outstanding = jnp.sum(jnp.where(alive_prev & alive,
                                            jnp.maximum(cap - k, 0), 0))
            reserve = k_min * (jnp.sum(~alive) + jnp.sum(arrive))
            pool = jnp.maximum(
                budget - jnp.sum(k) - reserve - outstanding, 0)
            want = jnp.where(arrive, tier.k0 - k_min, 0)
            before = jnp.cumsum(want) - want
            k_admit = (k_min + jnp.clip(pool - before, 0, want)
                       ).astype(jnp.int32)
        else:
            k_admit = jnp.full_like(k, min(tier.k0, tier.share))
        cache = jnp.where(arrive[:, None], EMPTY, p["cache"])
        jump = jnp.where(arrive, k_admit, jnp.where(depart, 0, p["jump"]))
        jump2 = jnp.where(arrive | depart, 0, p["jump2"])
        k = jnp.where(arrive, k_admit, k)
        cap = jnp.where(arrive, k_admit, cap)

        # 3. step every lane fused; dead lanes run on neutral inputs
        #    (key 0, k floored at k_min) and their outputs are discarded
        safe = {"cache": cache, "jump": jump, "jump2": jump2,
                "k": jnp.maximum(k, k_min), "kmax": p["kmax"], "cap": cap}
        safe_req = Request(key=jnp.where(alive, req.key, 0),
                           size=req.size, cost=req.cost)
        new_p, info = jax.vmap(tier.policy.step_budgeted)(safe, safe_req)
        cache = jnp.where(alive[:, None], new_p["cache"], cache)
        jump = jnp.where(alive, new_p["jump"], jump)
        jump2 = jnp.where(alive, new_p["jump2"], jump2)
        k = jnp.where(alive, new_p["k"], k)
    else:
        # non-resizable: every lane owns the static share; an arrival
        # resets the lane to a fresh policy state
        fresh = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x, (alive.shape[0],) + x.shape).astype(x.dtype),
            tier.policy.init(tier.share))
        pstate = _tree_where(arrive, fresh, p)
        util = jnp.where(depart | arrive, 0.0, util)
        safe_req = Request(key=jnp.where(alive, req.key, 0),
                           size=req.size, cost=req.cost)
        new_p, info = jax.vmap(tier.policy.step)(pstate, safe_req)
        p = _tree_where(alive, new_p, pstate)
        k = jnp.where(alive, tier.share, 0).astype(jnp.int32)

    # 4. telemetry: masked step outputs + the byte-miss-cost EWMA the
    #    auction arbiter prices capacity by
    hit = info.hit & alive
    bm = jnp.where(alive, info.bytes_missed.astype(jnp.float32), 0.0)
    pen = jnp.where(alive, info.penalty, 0.0)
    d = jnp.float32(tier.util_decay)
    util = jnp.where(alive, d * util + (1.0 - d) * pen, util)

    # 5. next step's capacity caps
    if tier.resizable:
        demanding = (jump >= 2 * k) & alive
        if pooled:
            # idle lanes keep their k_min admission reserve out of the
            # arbitrated pool
            budget_eff = budget - tier.policy.k_min * jnp.sum(~alive)
            caps = tier.arbiter(k, demanding, budget_eff, tier.n_lanes,
                                utility=util)
        else:
            caps = tier.arbiter(k, demanding, tier.budget, tier.n_lanes)
        cap = jnp.where(alive, caps, 0).astype(jnp.int32)
        p = {"cache": cache, "jump": jump, "jump2": jump2, "k": k,
             "kmax": p["kmax"], "cap": cap}

    st = {"p": p, "alive": alive, "util": util}
    return st, (hit, bm, pen, k, alive)


def _zero_acc_fleet(n: int) -> Metrics:
    return Metrics(
        requests=jnp.zeros((n,), _count_dtype()),
        hits=jnp.zeros((n,), _count_dtype()),
        bytes_total=jnp.zeros((n,), jnp.float32),
        bytes_missed=jnp.zeros((n,), jnp.float32),
        cost_total=jnp.zeros((n,), jnp.float32),
        penalty=jnp.zeros((n,), jnp.float32),
    )


def _acc_fleet(acc: Metrics, req: Request, hit, bm, pen, alive) -> Metrics:
    """Like the engine's ``_acc_step`` but idle lanes count nothing —
    ``requests`` advances only where a tenant served a request."""
    cd = _count_dtype()
    af = alive.astype(jnp.float32)
    return Metrics(
        requests=acc.requests + alive.astype(cd),
        hits=acc.hits + hit.astype(cd),
        bytes_total=acc.bytes_total + req.size.astype(jnp.float32) * af,
        bytes_missed=acc.bytes_missed + bm,
        cost_total=acc.cost_total + req.cost.astype(jnp.float32) * af,
        penalty=acc.penalty + pen,
    )


def _scan_fleet(tier: FleetTier, reqs: Request, observe: bool) -> FleetResult:
    """Metrics-in-carry scan of one ``[T, N]`` fleet stream."""
    n = reqs.key.shape[1]
    T = reqs.key.shape[0]

    def body(carry, req):
        st, acc, ksum, asum, hist = carry
        st, (hit, bm, pen, k, alive) = _fleet_step(tier, st, req,
                                                   tier.budget)
        acc = _acc_fleet(acc, req, hit, bm, pen, alive)
        hist = hist.at[jnp.arange(n), telemetry.penalty_bucket(pen)].add(
            alive.astype(hist.dtype))
        carry = (st, acc, ksum + k.astype(jnp.float32),
                 asum + alive.astype(jnp.float32), hist)
        return carry, ({"k": k, "alive": alive} if observe else None)

    carry0 = (tier.init(n), _zero_acc_fleet(n),
              jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32),
              jnp.zeros((n, telemetry.BINS), jnp.int32))
    (_, acc, ksum, asum, hist), obs = jax.lax.scan(body, carry0, reqs)
    return FleetResult(metrics=acc, avg_k=ksum / T, alive_frac=asum / T,
                       hist=hist, obs=obs)


@partial(jax.jit, static_argnames=("tier", "observe", "use_pallas"))
def _replay_fleet_single(tier, reqs, observe, use_pallas):
    with pallas_mode(use_pallas):
        return _scan_fleet(tier, reqs, observe)


@partial(jax.jit, static_argnames=("tier", "observe", "use_pallas"))
def _replay_fleet_batched(tier, reqs, observe, use_pallas):
    with pallas_mode(use_pallas):
        return jax.vmap(lambda r: _scan_fleet(tier, r, observe))(reqs)


def _scan_fleet_sharded(tier: FleetTier, reqs: Request, axis: str,
                        n_shards: int, rebalance: int,
                        observe: bool) -> FleetResult:
    """Per-shard scan body (runs inside ``shard_map``): the lane block's
    budget starts at an even split and is re-dealt every ``rebalance``
    steps — each shard publishes its *committed* capacity (claimed slots
    + admission reserve + uncashed grants) and its utility mass through
    ``psum``, and the global slack is split in proportion to utility.
    The collective runs unconditionally every step (SPMD collectives
    cannot sit under a traced branch); the reassignment applies on the
    rebalance tick."""
    n_local = reqs.key.shape[1]
    T = reqs.key.shape[0]
    base = tier.budget // n_shards
    idx = jax.lax.axis_index(axis)
    sb0 = (base + jnp.where(idx == 0, tier.budget % n_shards, 0)
           ).astype(jnp.int32)
    trade = tier.resizable and tier.arbiter.pooled
    k_min = tier.k_min

    def body(carry, xs):
        req, t = xs
        st, acc, ksum, asum, hist, sb = carry
        st, (hit, bm, pen, k, alive) = _fleet_step(tier, st, req, sb)
        acc = _acc_fleet(acc, req, hit, bm, pen, alive)
        hist = hist.at[jnp.arange(n_local),
                       telemetry.penalty_bucket(pen)].add(
            alive.astype(hist.dtype))
        if trade:
            outstanding = jnp.sum(
                jnp.where(alive, jnp.maximum(st["p"]["cap"] - k, 0), 0))
            committed = (jnp.sum(k) + k_min * jnp.sum(~alive)
                         + outstanding)
            w = jnp.sum(st["util"]) + 1.0      # +1: idle shards keep a bid
            total = jax.lax.psum(committed, axis)
            wsum = jax.lax.psum(w, axis)
            slack = jnp.maximum(tier.budget - total, 0).astype(jnp.float32)
            sb_new = (committed
                      + jnp.floor(slack * w / wsum).astype(jnp.int32))
            sb = jnp.where(t % rebalance == 0, sb_new.astype(jnp.int32), sb)
        carry = (st, acc, ksum + k.astype(jnp.float32),
                 asum + alive.astype(jnp.float32), hist, sb)
        return carry, ({"k": k, "alive": alive} if observe else None)

    carry0 = (tier.init(n_local), _zero_acc_fleet(n_local),
              jnp.zeros((n_local,), jnp.float32),
              jnp.zeros((n_local,), jnp.float32),
              jnp.zeros((n_local, telemetry.BINS), jnp.int32), sb0)
    (_, acc, ksum, asum, hist, _), obs = jax.lax.scan(
        body, carry0, (reqs, jnp.arange(T, dtype=jnp.int32)))
    return FleetResult(metrics=acc, avg_k=ksum / T, alive_frac=asum / T,
                       hist=hist, obs=obs)


def _replay_fleet_sharded(tier, reqs, mesh, axis, rebalance, observe,
                          use_pallas):
    n_shards = int(mesh.shape[axis])
    if tier.n_lanes % n_shards:
        raise ValueError(
            f"n_lanes={tier.n_lanes} must divide evenly over the "
            f"{n_shards}-device {axis!r} mesh axis")
    n_local = tier.n_lanes // n_shards
    if tier.resizable and tier.budget // n_shards < n_local * tier.k_min:
        raise ValueError(
            f"per-shard budget {tier.budget // n_shards} cannot float "
            f"{n_local} lanes at k_min={tier.k_min}; raise the budget or "
            "use fewer shards")

    def shard_fn(r):
        with pallas_mode(use_pallas):
            return _scan_fleet_sharded(tier, r, axis, n_shards, rebalance,
                                       observe)

    lane = P(axis)
    out_specs = FleetResult(
        metrics=Metrics(*([lane] * 6)),
        avg_k=lane, alive_frac=lane, hist=P(axis, None),
        obs={"k": P(None, axis), "alive": P(None, axis)} if observe
        else None)
    fn = _shard_map(shard_fn, mesh, in_specs=(P(None, axis),),
                    out_specs=out_specs, manual_axes=(axis,))
    return jax.jit(fn)(reqs)


def replay_fleet(tier: FleetTier, requests, *, sizes=None, costs=None,
                 observe: bool = False, mesh=None, axis: str = "data",
                 rebalance: int = 256, use_pallas=False) -> FleetResult:
    """Replay a dynamic-fleet request stream through ``tier``.

    ``requests``: a :class:`~repro.core.Request` (or bare keys, with
    ``sizes``/``costs`` broadcast per ``Request.of``) of shape ``[T, N]``
    — key ``-1`` marks a lane with no active tenant that step (the
    ``fleet(...)`` trace family's lifecycle encoding) — or ``[S, T, N]``
    to vmap a seed axis.  Sizes/costs at idle positions are ignored.

    With ``mesh=`` the lane axis is sharded over the mesh's ``axis`` via
    ``shard_map`` (``[T, N]`` input only): per-shard budget splits with a
    ``psum`` utility-weighted re-deal every ``rebalance`` steps.
    ``use_pallas`` routes the fused policy step through the Pallas kernel
    exactly as in ``replay_tier``.
    """
    use_pallas = normalize_pallas_mode(use_pallas)
    reqs = Request.of(requests, sizes, costs)
    if reqs.key.ndim == 2:
        if reqs.key.shape[1] != tier.n_lanes:
            raise ValueError(
                f"requests [T, N] must have N == n_lanes "
                f"({tier.n_lanes}), got {reqs.key.shape}")
        if mesh is not None:
            return _replay_fleet_sharded(tier, reqs, mesh, axis,
                                         int(rebalance), observe,
                                         use_pallas)
        return _replay_fleet_single(tier, reqs, observe, use_pallas)
    if reqs.key.ndim == 3:
        if mesh is not None:
            raise ValueError(
                "mesh sharding takes a single [T, N] stream; vmap the "
                "seed axis on the host instead")
        if reqs.key.shape[2] != tier.n_lanes:
            raise ValueError(
                f"requests [S, T, N] must have N == n_lanes "
                f"({tier.n_lanes}), got {reqs.key.shape}")
        return _replay_fleet_batched(tier, reqs, observe, use_pallas)
    raise ValueError(
        f"requests must be [T, N] or [S, T, N], got shape {reqs.key.shape}")
