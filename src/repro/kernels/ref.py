"""Pure-jnp oracles for the attention Pallas kernels (shape/dtype-sweep
targets).  The policy-step kernel's oracle is
:func:`repro.core.policy.rank_step` itself (``use_pallas=False``), not a
function here."""
from __future__ import annotations

from repro.models.layers import attention_dense
from repro.models.layers import decode_attention as _decode_attention_jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=0.0,
                        scale=None):
    """O(S^2) dense attention (repro.models.layers.attention_dense)."""
    return attention_dense(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale)


def decode_attention_ref(q, k, v, valid, *, softcap=0.0, scale=None):
    """Materialized decode attention + per-slot mass."""
    return _decode_attention_jnp(q, k, v, valid, softcap=softcap,
                                 scale=scale)


__all__ = ["flash_attention_ref", "decode_attention_ref"]
