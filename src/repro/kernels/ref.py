"""Pure-jnp oracles for every Pallas kernel (shape/dtype-sweep targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention_dense
from repro.models.layers import decode_attention as _decode_attention_jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=0.0,
                        scale=None):
    """O(S^2) dense attention (repro.models.layers.attention_dense)."""
    return attention_dense(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale)


def decode_attention_ref(q, k, v, valid, *, softcap=0.0, scale=None):
    """Materialized decode attention + per-slot mass."""
    return _decode_attention_jnp(q, k, v, valid, softcap=softcap,
                                 scale=scale)


def adaptive_climb_ref(cache, jump, key):
    """Batched AdaptiveClimb step — vmap of the repro.core policy."""
    from repro.core import AdaptiveClimb, Request
    pol = AdaptiveClimb()

    def one(c, j, k):
        state, info = pol.step({"cache": c, "jump": j}, Request.of(k))
        return state["cache"], state["jump"], info.hit.astype(jnp.int32)

    return jax.vmap(one)(cache, jump, key)
