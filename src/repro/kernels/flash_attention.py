"""Pallas TPU kernel: causal flash attention (GQA / sliding-window /
softcap).

Grid (B, H, num_q_blocks, num_kv_blocks); the innermost kv axis iterates
sequentially on TPU, so the online-softmax accumulators live in VMEM scratch
and persist across kv blocks (re-initialized at kv==0, flushed to the output
at the last visited kv block).  Blocks of K/V stream HBM->VMEM; scores,
the running max/denominator and the f32 accumulator never leave VMEM.

Causal + window structure is exploited two ways:
  * blocks entirely above the diagonal (or entirely left of the window) are
    skipped with @pl.when — no MXU work, no accumulator update;
  * the partial block on the diagonal masks with a lane iota.

MXU alignment: block_q/block_k default to 512/512 and head_dim should be a
multiple of 128 on real TPU; interpret mode (CPU tests) accepts any shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, softcap, block_q, block_k, nk, seq_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level structure: skip fully-masked kv blocks entirely
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + block_q - 1
    if window:
        needed &= k_start + block_k - 1 > q_start - window

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)           # [bk, Dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                 # [bq]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None, softcap=0.0,
                           scale=None, block_q=512, block_k=512,
                           interpret=False):
    """q: [B, Sq, H, D]; k, v: [B, Sk, Hkv, D/Dv] -> [B, Sq, H, Dv]."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Sk)
    while Sk % bk:
        bk -= 1
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, nk=nk, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, Dv),
                         lambda b, h, iq, ik: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dv),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
        ],
        interpret=interpret,
    )(q, k, v)
