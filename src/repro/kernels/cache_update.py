"""Pallas TPU kernel: batched AdaptiveClimb cache update.

This is the operation the paper itemizes in its instructions-per-request
analysis (Fig. 9) — one policy step (find / jump update / masked shift) —
executed for a *batch* of independent caches per grid cell.  The CPU paper
implementation is a pointer splice; the TPU-native form operates on the
dense rank row held in VMEM:

  * each grid cell owns a [block_b, K] tile of rank rows (int32);
  * find = lane-wise compare + iota-min reduction (VPU);
  * the promote/insert shift is a masked select against a lane-rolled copy —
    no gather/scatter, K <= a few thousand fits a handful of VREG rows.

The jump scalars ride along as a [block_b] vector.  See ops.adaptive_climb
for the jit wrapper and ref.adaptive_climb_ref for the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cache_ref, jump_ref, key_ref, out_cache_ref, out_jump_ref,
            hit_ref, *, K: int):
    cache = cache_ref[...]                     # [bt, K] int32
    jump = jump_ref[...]                       # [bt]
    key = key_ref[...]                         # [bt]

    r = jax.lax.broadcasted_iota(jnp.int32, cache.shape, 1)
    eq = cache == key[:, None]
    hit = jnp.any(eq, axis=1)                  # [bt]
    big = jnp.int32(K + 1)
    i = jnp.min(jnp.where(eq, r, big), axis=1).astype(jnp.int32)  # rank of key

    # --- hit path ---------------------------------------------------------
    jump_h = jnp.maximum(jump - 1, 1)
    t_h = jnp.maximum(i - jump_h, 0)

    # --- miss path --------------------------------------------------------
    jump_m = jnp.minimum(jump + 1, K)
    t_m = K - jump_m
    i_m = jnp.full_like(i, K - 1)

    t = jnp.where(hit, t_h, t_m)[:, None]
    src = jnp.where(hit, i, i_m)[:, None]

    rolled = jnp.concatenate([cache[:, -1:], cache[:, :-1]], axis=1)
    new_cache = jnp.where(
        r == t, key[:, None],
        jnp.where((r > t) & (r <= src), rolled, cache))

    out_cache_ref[...] = new_cache
    out_jump_ref[...] = jnp.where(hit, jump_h, jump_m)
    hit_ref[...] = hit.astype(jnp.int32)


def adaptive_climb_pallas(cache, jump, key, *, block_b: int = 8,
                          interpret: bool = False):
    """One AdaptiveClimb step for a batch of caches.

    cache: [B, K] int32 rank rows; jump: [B] int32; key: [B] int32.
    Returns (new_cache [B,K], new_jump [B], hit [B] int32).
    """
    B, K = cache.shape
    bt = min(block_b, B)
    while B % bt:
        bt -= 1
    grid = (B // bt,)
    kernel = functools.partial(_kernel, K=K)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, K), lambda b: (b, 0)),
            pl.BlockSpec((bt,), lambda b: (b,)),
            pl.BlockSpec((bt,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, K), lambda b: (b, 0)),
            pl.BlockSpec((bt,), lambda b: (b,)),
            pl.BlockSpec((bt,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(cache, jump, key)
