"""Pallas TPU kernels for the perf-critical hot spots:

  * flash_attention  — causal GQA/SWA/softcap flash attention (training &
                       prefill compute hot spot)
  * decode_attention — flash-decode over the KV slot table with fused DAC
                       hit-signal (per-slot attention mass) extraction
  * cache_update     — batched AdaptiveClimb policy step (the op the paper
                       itemizes in its instructions/request analysis)

Each has a pure-jnp oracle in ref.py; ops.py exposes jit'd wrappers that run
under the Pallas interpreter on CPU and Mosaic on TPU.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
