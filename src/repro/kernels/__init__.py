"""Pallas TPU kernels for the perf-critical hot spots:

  * flash_attention  — causal GQA/SWA/softcap flash attention (training &
                       prefill compute hot spot)
  * decode_attention — flash-decode over the KV slot table with fused DAC
                       hit-signal (per-slot attention mass) extraction
  * policy_step      — tiled fused rank-policy step (find + plan + promote
                       + wipe in one segmented pass over the lane-padded
                       rank row); serves every rank policy — Climb,
                       AdaptiveClimb, DAC, the budgeted tier step — via a
                       traced-in control-law callback and backs the
                       engine's three-valued ``use_pallas`` replay path
                       (``False`` / ``"interpret"`` / ``"compiled"``)

Each has a pure-jnp oracle (ref.py for the attention kernels;
core.policy.rank_step *is* the oracle for policy_step); ops.py exposes
jit'd wrappers whose ``interpret=None`` resolves per backend via
``policy_step.resolve_interpret`` (env-overridable with
``REPRO_PALLAS_INTERPRET``).
"""
from . import ops, policy_step, ref

__all__ = ["ops", "policy_step", "ref"]
