"""Pallas TPU kernels for the perf-critical hot spots:

  * flash_attention  — causal GQA/SWA/softcap flash attention (training &
                       prefill compute hot spot)
  * decode_attention — flash-decode over the KV slot table with fused DAC
                       hit-signal (per-slot attention mass) extraction
  * cache_update     — batched AdaptiveClimb policy step (the op the paper
                       itemizes in its instructions/request analysis)
  * policy_step      — fused rank-policy step (find + plan + promote in one
                       pass over the rank row); serves every rank policy via
                       a traced-in control-law callback and backs the
                       engine's ``use_pallas`` replay path

Each has a pure-jnp oracle (ref.py, or core.policy.rank_step for
policy_step); ops.py exposes jit'd wrappers that run under the Pallas
interpreter on CPU and Mosaic on TPU.
"""
from . import ops, policy_step, ref

__all__ = ["ops", "policy_step", "ref"]
