"""Tiled Pallas TPU kernel: fused rank-policy step (find + plan + promote).

Every rank-based policy in this repo (CLIMB, AdaptiveClimb,
DynamicAdaptiveClimb) has the same per-request shape:

  1. ``find``     — locate the requested key in the rank row (``[W]`` int32,
                    index 0 = top of the cache, ``W`` a 128-lane multiple);
  2. ``plan``     — O(1) scalar control arithmetic (jump updates, resize
                    checks) deciding the shift source/target ranks;
  3. ``promote``  — masked-select shift of ranks ``(t, src]`` against a
                    lane-rolled copy, inserting the key at rank ``t``;
  4. ``wipe``     — ranks ``>= wipe_from`` cleared to EMPTY (the
                    DynamicAdaptiveClimb shrink).

The kernel runs a ``(lanes, 2, n_tiles)`` grid: the rank row streams
HBM→VMEM in ``tile``-lane blocks (BlockSpec-pipelined), so W no longer has
to fit one VMEM row.  Cross-tile state rides in an SMEM scratch:

    phase 0 (find)     per tile: compare + iota-min; the running global
                       argmin accumulates in SMEM (min-reduce across tiles).
    phase 1, tile 0:   the plan callback runs ONCE on the find result
                       (traced into the kernel; SMEM scalars in/out), and
                       its (src, t, wipe_from) decisions park in SMEM.
    phase 1, per tile: segmented promote — each tile shifts its lanes
                       right by one against a boundary carry (the previous
                       tile's last element, saved in SMEM at the end of
                       the prior iteration), masked to ``(t, src]``; the
                       requested key lands at rank ``t``; ranks >=
                       ``wipe_from`` wipe to EMPTY; the evicted occupant
                       of rank ``src`` is extracted by the tile owning it.

Tile/carry diagram (W = 3 tiles, promote range (t, src])::

      tile 0              tile 1              tile 2
    [ a b c d ]         [ e f g h ]         [ i j k l ]
          t ^..................... src ^
    carry: -1 ->  d  (last of tile 0) ->  h  (last of tile 1)
    shift:  [ a b key c ]  [ d e f g ]   [ h i j l ]     (h crosses tiles)

Mosaic details: integer vector reductions are unsupported on TPU, so the
iota-min runs in float32 (rank indices < 2^24 are exact) and the
evicted/carry element extraction splits int32 into 16-bit halves, sums each
in float32 (exactly one lane selected, so the sum is exact), and reassembles
with shifts — bit-exact for every int32 including EMPTY.

Contract (see :func:`repro.core.policy.rank_step` for the jnp oracle)::

    plan(hit, i, scalars) -> (src, t, wipe_from, new_scalars)

      hit        scalar bool  — key resident?
      i          scalar int32 — rank of the key (0 when miss, like argmax)
      scalars    tuple of int32 scalars (policy control state)
      src        shift source rank (eviction rank on a miss; t <= src)
      t          insertion rank for the requested key
      wipe_from  ranks >= wipe_from are cleared to EMPTY (>= K for none)

Returns ``(new_cache, new_scalars, hit, evicted)`` where ``evicted`` is the
pre-update occupant of rank ``src`` (the key shifted off the row on a miss).

Batching: a vmapped ``fused_policy_step`` does NOT fall back to the default
pallas batching of the single-lane call — a ``jax.custom_batching.custom_vmap``
rule swaps in the natively batched kernel, whose grid leads with the lane
axis (``(B, 2, n_tiles)``) and whose scalar I/O lives in unblocked SMEM
arrays indexed by ``program_id(0)``.  A second (outer) vmap — the tier's
seeds × tenants nesting — then hits the standard pallas batching rule on
the batched kernel, which prepends one more grid dimension; both layers
are Mosaic-lowerable and bit-identical to the vmapped jnp oracle.

``interpret``: ``True`` runs the kernel body under the Pallas interpreter
(any backend — the CPU CI path); ``False`` compiles for real (Mosaic on
TPU, Triton on GPU); ``None`` resolves per backend via
:func:`resolve_interpret` (memoized, overridable with the
``REPRO_PALLAS_INTERPRET`` env knob).
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.policy import EMPTY, LANE, lane_pad

__all__ = ["fused_policy_step", "resolve_interpret", "DEFAULT_TILE",
           "INTERPRET_ENV"]

# default VMEM tile, in lanes: 64 KiB of int32 per block — small enough to
# double-buffer input + output blocks comfortably, large enough that rows
# up to this width run as a single tile.  The effective tile is
# gcd(W, tile), so it always divides the padded width exactly.
DEFAULT_TILE = 16384

# forced override for CI: "interpret" (or "1"/"true") forces the
# interpreter, "compiled" (or "0"/"false") forces real lowering —
# regardless of what the call site passed.  Empty/"auto" defers to the
# call site, then to the per-backend default.
INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


@functools.lru_cache(maxsize=None)
def _backend_default(backend: str) -> bool:
    # CPU has no compiled Pallas lowering — interpret.  TPU compiles via
    # Mosaic and GPU via Triton: both run the kernel for real.  (The old
    # `backend != "tpu"` test wrongly interpreted on GPU, silently
    # discarding the Triton lowering.)
    return backend not in ("tpu", "gpu")


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret`` argument to a concrete bool.

    Priority: the :data:`INTERPRET_ENV` env knob (a *forced* override, so
    CI can pin one lowering across every call site) > an explicit
    ``True``/``False`` argument > the memoized per-backend default
    (cpu → interpret; tpu/gpu → compiled).

    >>> resolve_interpret(True), resolve_interpret(False)
    (True, False)
    """
    env = os.environ.get(INTERPRET_ENV, "").strip().lower()
    if env in ("1", "true", "interpret"):
        return True
    if env in ("0", "false", "compiled"):
        return False
    if env not in ("", "auto"):
        raise ValueError(
            f"{INTERPRET_ENV} must be interpret/compiled/auto (or a bool "
            f"spelling), got {env!r}")
    if interpret is None:
        return _backend_default(jax.default_backend())
    return bool(interpret)


def _resolve_tile(W: int, tile: int | None) -> int:
    if tile is None:
        tile = DEFAULT_TILE
    tile = int(tile)
    if tile < LANE or tile % LANE:
        raise ValueError(
            f"tile must be a positive multiple of {LANE}, got {tile}")
    return math.gcd(W, tile)


# SMEM scratch slot indices (cross-tile carries)
_S_ARGMIN = 0   # running find argmin (W = "not found")
_S_CARRY = 1    # boundary element carried into the next tile's shift
_S_SRC = 2      # plan outputs, parked at (phase 1, tile 0)
_S_T = 3
_S_WIPE = 4
_N_SCRATCH = 8


def _split16_pick(row, mask):
    """Extract the single int32 element of ``row`` selected by ``mask``
    using float32 sums of 16-bit halves (Mosaic has no integer vector
    reductions); exact because exactly one lane is selected."""
    lo = jnp.sum(jnp.where(mask, row & 0xFFFF, 0).astype(jnp.float32))
    hi = jnp.sum(jnp.where(mask, (row >> 16) & 0xFFFF, 0).astype(jnp.float32))
    return (hi.astype(jnp.int32) << 16) | lo.astype(jnp.int32)


def _tiled_kernel(sc_ref, cache_ref, out_ref, scal_out_ref, s_ref, *,
                  plan, n_sc: int, W: int, tile: int):
    """Grid (B, 2, n_tiles): lane b, phase (0 find / 1 plan+promote), tile j.

    ``sc_ref``/``scal_out_ref`` are whole unblocked SMEM arrays ``[B, 1+n]``
    / ``[B, n+2]`` (key + control scalars in; new scalars + hit + evicted
    out), indexed by the lane id.  ``cache_ref``/``out_ref`` see one
    ``(1, 1, tile)`` VMEM block of the ``[B, 1, W]`` row per grid step.
    ``s_ref`` is the SMEM cross-tile scratch (per lane: grid iterations run
    lane-major, so one lane's phases/tiles complete before the next lane's
    begin and the scratch never interleaves)."""
    b, ph, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    base = j * tile
    r = jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1) + base
    row = cache_ref[0]                       # (1, tile) int32
    key = sc_ref[b, 0]

    @pl.when((ph == 0) & (j == 0))
    def _init_find():
        s_ref[_S_ARGMIN] = jnp.int32(W)

    @pl.when(ph == 0)
    def _find():
        eq = row == key
        # min-reduce in float32: ranks < 2^24 are exact (W <= 2^24 by
        # construction of any realistic row)
        local = jnp.min(
            jnp.where(eq, r, W).astype(jnp.float32)).astype(jnp.int32)
        s_ref[_S_ARGMIN] = jnp.minimum(s_ref[_S_ARGMIN], local)
        # keep every output block defined even if phase 1 aborts a write
        out_ref[0] = row

    @pl.when((ph == 1) & (j == 0))
    def _plan():
        m = s_ref[_S_ARGMIN]
        hit = m < W
        i = jnp.where(hit, m, 0)             # match find()'s argmax-on-miss
        scalars = tuple(sc_ref[b, 1 + q] for q in range(n_sc))
        src, t, wipe_from, new_sc = plan(hit, i, scalars)
        # EMPTY (-1) is created inline: a closure-captured device constant
        # would be rejected by the kernel tracer
        # repolint: waive[empty-sentinel] -- see above
        s_ref[_S_CARRY] = jnp.int32(-1)      # roll wrap value (never used:
        s_ref[_S_SRC] = src                  # t <= src keeps rank 0 out of
        s_ref[_S_T] = t                      # the shifted range)
        s_ref[_S_WIPE] = wipe_from
        for q, v in enumerate(new_sc):
            scal_out_ref[b, q] = v
        scal_out_ref[b, n_sc] = hit.astype(jnp.int32)

    @pl.when(ph == 1)
    def _promote():
        src, t, wipe = s_ref[_S_SRC], s_ref[_S_T], s_ref[_S_WIPE]
        carry = s_ref[_S_CARRY]
        # evicted occupant of rank src: exactly one tile owns it
        @pl.when((src >= base) & (src < base + tile))
        def _evicted():
            scal_out_ref[b, n_sc + 1] = _split16_pick(row, r == src)
        # segmented shift-right-by-one: boundary element comes from the
        # previous tile via the SMEM carry
        rolled = jnp.concatenate(
            [jnp.full((1, 1), carry, jnp.int32), row[:, :-1]], axis=1)
        new = jnp.where(r == t, key,
                        jnp.where((r > t) & (r <= src), rolled, row))
        # repolint: waive[empty-sentinel] -- inline EMPTY, kernel tracer
        new = jnp.where(r >= wipe, jnp.int32(-1), new)
        out_ref[0] = new
        # save this tile's last pre-shift element for the next tile
        s_ref[_S_CARRY] = _split16_pick(row, r == base + tile - 1)


def _batched_call(cache, keys, scalars, *, plan, n_sc: int, interpret: bool,
                  tile: int | None):
    """The natively batched kernel call: ``cache [B, W]``, ``keys [B]``,
    each scalar ``[B]`` — one grid lane per batch element."""
    B, W = cache.shape
    t = _resolve_tile(W, tile)
    kernel = functools.partial(_tiled_kernel, plan=plan, n_sc=n_sc, W=W,
                               tile=t)
    sc = jnp.stack([keys] + list(scalars), axis=-1)      # [B, 1+n] SMEM
    out, scal = pl.pallas_call(
        kernel,
        grid=(B, 2, W // t),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, t), lambda b, ph, j: (b, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, t), lambda b, ph, j: (b, 0, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1, W), jnp.int32),
            jax.ShapeDtypeStruct((B, n_sc + 2), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((_N_SCRATCH,), jnp.int32)],
        interpret=interpret,
    )(sc, cache[:, None, :])
    return (out[:, 0], tuple(scal[:, q] for q in range(n_sc)),
            scal[:, n_sc].astype(bool), scal[:, n_sc + 1])


def fused_policy_step(cache, key, scalars, plan, *, interpret=None,
                      tile=None):
    """One fused rank-policy step.

    ``cache``: ``[K]`` int32 rank row (any K — padded internally to a
    :data:`~repro.core.policy.LANE` multiple and sliced back, so direct
    calls with tight rows stay bit-identical to the jnp oracle);
    ``key``: scalar int32; ``scalars``: tuple of int32 control scalars.
    ``tile`` caps the VMEM block width (default :data:`DEFAULT_TILE`;
    the effective tile is ``gcd(padded_W, tile)``).

    Batches under ``vmap`` through a ``custom_vmap`` rule that swaps in
    the natively batched lane-grid kernel (nested vmaps compose via the
    standard pallas batching rule on top); scans under ``lax.scan``.
    """
    interpret = resolve_interpret(interpret)
    K = cache.shape[0]
    W = lane_pad(K)
    n_sc = len(scalars)
    call = functools.partial(_batched_call, plan=plan, n_sc=n_sc,
                             interpret=interpret, tile=tile)

    @custom_vmap
    def step(cache, key, sc):
        out, new_sc, hit, ev = call(cache[None], key[None],
                                    tuple(s[None] for s in sc))
        return out[0], tuple(s[0] for s in new_sc), hit[0], ev[0]

    @step.def_vmap
    def _step_vmap(axis_size, in_batched, cache, key, sc):
        cache_b, key_b, sc_b = in_batched

        def bc(x, batched):
            return x if batched else jnp.broadcast_to(
                x, (axis_size,) + jnp.shape(x))

        out = call(bc(cache, cache_b), bc(key, key_b),
                   tuple(bc(s, b) for s, b in zip(sc, sc_b)))
        return out, jax.tree_util.tree_map(lambda _: True, out)

    key = jnp.asarray(key, jnp.int32)
    scalars = tuple(jnp.asarray(s, jnp.int32) for s in scalars)
    padded = cache if W == K else jnp.concatenate(
        [cache, jnp.full((W - K,), EMPTY, jnp.int32)])
    new_cache, new_sc, hit, ev = step(padded, key, scalars)
    return (new_cache if W == K else new_cache[:K]), new_sc, hit, ev
