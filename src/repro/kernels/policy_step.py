"""Pallas TPU kernel: fused rank-policy step (find + plan + promote).

Every rank-based policy in this repo (CLIMB, AdaptiveClimb,
DynamicAdaptiveClimb) has the same per-request shape:

  1. ``find``     — locate the requested key in the rank row (``[K]`` int32,
                    index 0 = top of the cache);
  2. ``plan``     — O(1) scalar control arithmetic (jump updates, resize
                    checks) deciding the shift source/target ranks;
  3. ``promote``  — masked-select shift of ranks ``(t, src]`` against a
                    lane-rolled copy, inserting the key at rank ``t``.

The pure-jnp path materializes the rank row once per primitive; this kernel
fuses all three into ONE pass over the row held in VMEM: the compare /
iota-min reduction (find), the plan's scalar updates (SMEM), the rolled
masked select and the deactivation wipe (DynamicAdaptiveClimb's shrink) all
happen before the row is written back.  ``plan`` is an arbitrary traceable
callback, so the same kernel serves every rank policy — the policy's control
law is traced *into* the kernel body.

Contract (see :func:`repro.core.policy.rank_step` for the jnp oracle)::

    plan(hit, i, scalars) -> (src, t, wipe_from, new_scalars)

      hit        scalar bool  — key resident?
      i          scalar int32 — rank of the key (0 when miss, like argmax)
      scalars    tuple of int32 scalars (policy control state)
      src        shift source rank (eviction rank on a miss; t <= src)
      t          insertion rank for the requested key
      wipe_from  ranks >= wipe_from are cleared to EMPTY (pass K for none)

Returns ``(new_cache, new_scalars, hit, evicted)`` where ``evicted`` is the
pre-update occupant of rank ``src`` (the key shifted off the row on a miss).

``interpret=True`` (the default off-TPU) runs the body under the Pallas
interpreter, so CPU CI exercises the exact kernel code path.  On real TPUs
K should be padded to a lane multiple (128) for Mosaic-friendly layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cache_ref, key_ref, sc_ref, out_cache_ref, out_sc_ref, hit_ref,
            ev_ref, *, plan, n_scalars: int, K: int):
    cache = cache_ref[...]                       # [1, K] int32 in VMEM
    key = key_ref[0]
    scalars = tuple(sc_ref[j] for j in range(n_scalars))

    # --- find: one compare + iota-min reduction -------------------------
    r = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)
    eq = cache == key
    hit = jnp.any(eq)
    i = jnp.min(jnp.where(eq, r, K)).astype(jnp.int32)
    i = jnp.where(hit, i, 0)                     # match find()'s argmax=0

    # --- plan: policy control law, traced into the kernel ---------------
    src, t, wipe_from, new_scalars = plan(hit, i, scalars)

    # --- promote + wipe: rolled masked select, still in registers -------
    evicted = jnp.sum(jnp.where(r == src, cache, 0))  # exactly one lane
    rolled = jnp.concatenate([cache[:, -1:], cache[:, :-1]], axis=1)
    new_cache = jnp.where(
        r == t, key, jnp.where((r > t) & (r <= src), rolled, cache))
    # EMPTY (-1) is created inline: a closure-captured device constant
    # would be rejected by the kernel tracer
    new_cache = jnp.where(r >= wipe_from, jnp.int32(-1), new_cache)

    out_cache_ref[...] = new_cache
    for j, s in enumerate(new_scalars):
        out_sc_ref[j] = s
    hit_ref[0] = hit.astype(jnp.int32)
    ev_ref[0] = evicted


def fused_policy_step(cache, key, scalars, plan, *, interpret=None):
    """One fused rank-policy step.

    cache: [K] int32 rank row; key: scalar int32; scalars: tuple of int32
    control scalars.  Batches transparently under ``vmap`` (the pallas_call
    batching rule adds a grid dimension) and scans under ``lax.scan``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    K = cache.shape[0]
    n = len(scalars)
    sc = (jnp.stack([jnp.asarray(s, jnp.int32) for s in scalars])
          if n else jnp.zeros((1,), jnp.int32))
    kernel = functools.partial(_kernel, plan=plan, n_scalars=n, K=K)
    new_cache, new_sc, hit, ev = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((1, K), jnp.int32),
            jax.ShapeDtypeStruct((max(n, 1),), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(cache[None, :], key[None], sc)
    return (new_cache[0], tuple(new_sc[j] for j in range(n)),
            hit[0].astype(bool), ev[0])
