"""Jit'd public wrappers for the attention Pallas kernels.

``interpret=None`` resolves through
:func:`repro.kernels.policy_step.resolve_interpret` — the env knob
``REPRO_PALLAS_INTERPRET`` force-overrides, then the memoized per-backend
default kicks in (compiled Mosaic/Triton on tpu/gpu, the Pallas
interpreter elsewhere) — so the same call sites run interpreted on this
CPU container and compile on real accelerators, and CI can pin either
path fleet-wide with one variable.

The rank-policy step kernel does not live here: call
``core.policy.rank_step`` under ``pallas_mode(...)`` (or
``repro.kernels.policy_step.fused_policy_step`` directly).
"""
from __future__ import annotations

from functools import partial

import jax

from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas
from .policy_step import resolve_interpret


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                    scale=None, block_q=512, block_k=512, interpret=None):
    interpret = resolve_interpret(interpret)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("softcap", "scale", "block_s",
                                   "interpret"))
def decode_attention(q, k, v, valid, *, softcap=0.0, scale=None,
                     block_s=512, interpret=None):
    interpret = resolve_interpret(interpret)
    return decode_attention_pallas(q, k, v, valid, softcap=softcap,
                                   scale=scale, block_s=block_s,
                                   interpret=interpret)
