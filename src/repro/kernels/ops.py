"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True when no TPU is present (this container), so
the same call sites run the kernel body under the Pallas interpreter on CPU
and compile to Mosaic on real TPUs.
"""
from __future__ import annotations

from functools import partial

import jax

from .cache_update import adaptive_climb_pallas
from .decode_attention import decode_attention_pallas
from .flash_attention import flash_attention_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=0.0,
                    scale=None, block_q=512, block_k=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("softcap", "scale", "block_s",
                                   "interpret"))
def decode_attention(q, k, v, valid, *, softcap=0.0, scale=None,
                     block_s=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return decode_attention_pallas(q, k, v, valid, softcap=softcap,
                                   scale=scale, block_s=block_s,
                                   interpret=interpret)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def adaptive_climb(cache, jump, key, *, block_b=8, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return adaptive_climb_pallas(cache, jump, key, block_b=block_b,
                                 interpret=interpret)
