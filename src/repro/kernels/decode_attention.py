"""Pallas TPU kernel: flash-decode over a KV slot table with *fused DAC
hit-signal extraction*.

One new token attends over the (bounded or unbounded) slot table; besides
the attention output, the kernel emits the per-slot attention mass
(head-mean of the softmax weights) — the hit signal that drives the
DynamicAdaptiveClimb controller.  Producing it inside the same pass means
the policy costs zero extra HBM reads of K/V.

Two phases (each a pallas_call):
  phase 1 (stats)  — flash max/denominator per (b, h) row; K streams once.
  phase 2 (output) — normalized weights p = exp(s - m)/l; accumulates
                     o += p @ v across slot blocks (f32 VMEM scratch) and
                     the head-summed mass per slot block.

The two-phase split is what makes the *normalized* per-slot mass exact in a
single block-streamed pass structure (running-max rescaling cannot repair
already-written mass blocks).  Cost: K is read twice (V once); decode is
HBM-bound on K+V, so the fused signal costs ~K/(K+V) extra traffic — still
strictly cheaper than a separate policy pass, and the §Perf log quantifies
it.

Layouts: q [B, Hkv, g, D] (g = H // Hkv query heads per kv head);
k [B, S, Hkv, D]; v [B, S, Hkv, Dv]; valid [B, S] int32 mask.
Grid phase 1: (B, Hkv, ns); grid phase 2: (B, ns, Hkv) — hkv innermost so
the mass accumulator in VMEM scratch sums over heads before flushing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# --------------------------------------------------------------------------
# phase 1: per-row flash stats (m, l)
# --------------------------------------------------------------------------

def _stats_kernel(q_ref, k_ref, valid_ref, m_out, l_out, m_ref, l_ref, *,
                  scale, softcap, ns):
    isl = pl.program_id(2)

    @pl.when(isl == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # [g, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bs, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [g, bs]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    ok = valid_ref[0] != 0                                 # [bs]
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + \
        jnp.sum(jnp.exp(s - m_new[:, None]), axis=1)
    m_ref[...] = m_new

    @pl.when(isl == ns - 1)
    def _flush():
        m_out[0, 0] = m_ref[...]
        l_out[0, 0] = l_ref[...]


# --------------------------------------------------------------------------
# phase 2: normalized output + fused per-slot mass
# --------------------------------------------------------------------------

def _out_kernel(q_ref, k_ref, v_ref, valid_ref, m_ref, l_ref, o_ref,
                mass_ref, acc_ref, mass_acc, *, scale, softcap, ns, nh, H):
    isl = pl.program_id(1)
    ih = pl.program_id(2)

    @pl.when(ih == 0)
    def _init_mass():
        mass_acc[...] = jnp.zeros_like(mass_acc)

    @pl.when(isl == 0)
    def _init_acc():
        # per-kv-head accumulator row (hkv is the innermost grid axis, so
        # the scratch holds all Hkv rows and each (isl, ih) step updates its
        # own row)
        acc_ref[ih] = jnp.zeros_like(acc_ref[ih])

    q = q_ref[0, 0].astype(jnp.float32) * scale            # [g, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bs, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)              # [bs, Dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    ok = valid_ref[0] != 0
    s = jnp.where(ok[None, :], s, NEG_INF)

    m = m_ref[0, 0]                                        # [g]
    l = jnp.maximum(l_ref[0, 0], 1e-30)
    p = jnp.exp(s - m[:, None]) / l[:, None]               # [g, bs] final

    acc_ref[ih] += jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    mass_acc[...] += jnp.sum(p, axis=0) / H

    @pl.when(isl == ns - 1)
    def _flush_o():
        o_ref[0, 0] = acc_ref[ih].astype(o_ref.dtype)

    @pl.when(ih == nh - 1)
    def _flush_mass():
        mass_ref[0] = mass_acc[...].astype(mass_ref.dtype)


def decode_attention_pallas(q, k, v, valid, *, softcap=0.0, scale=None,
                            block_s: int = 512, interpret: bool = False):
    """q: [B, H, D]; k/v: [B, S, Hkv, D/Dv]; valid: [B, S] bool.

    Returns (o [B, H, Dv], mass [B, S] f32).
    """
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    bs = min(block_s, S)
    while S % bs:
        bs -= 1
    ns = S // bs
    qg = q.reshape(B, Hkv, g, D)
    vmask = valid.astype(jnp.int32)

    stats = pl.pallas_call(
        functools.partial(_stats_kernel, scale=scale, softcap=softcap,
                          ns=ns),
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, h, s: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, g), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, vmask)
    m, l = stats

    o, mass = pl.pallas_call(
        functools.partial(_out_kernel, scale=scale, softcap=softcap, ns=ns,
                          nh=Hkv, H=H),
        grid=(B, ns, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, s, h: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D), lambda b, s, h: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, Dv), lambda b, s, h: (b, s, h, 0)),
            pl.BlockSpec((1, bs), lambda b, s, h: (b, s)),
            pl.BlockSpec((1, 1, g), lambda b, s, h: (b, h, 0)),
            pl.BlockSpec((1, 1, g), lambda b, s, h: (b, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, Dv), lambda b, s, h: (b, h, 0, 0)),
            pl.BlockSpec((1, bs), lambda b, s, h: (b, s)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, g, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Hkv, g, Dv), jnp.float32),
            pltpu.VMEM((bs,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, vmask, m, l)
    return o.reshape(B, H, Dv), mass
