"""DynamicAdaptiveClimb — Algorithm 2 of the paper, vectorized, with true
dynamic cache resizing.

XLA needs static shapes, so the cache array is allocated at
``K_max = K * growth`` and the *active* size is a traced scalar ``k``; ranks
>= k are ``EMPTY`` and never hit.  Doubling activates already-empty ranks;
halving wipes ranks >= k/2.  This masked-budget scheme preserves the paper's
policy behaviour exactly while keeping the state a fixed-shape pytree (and
therefore batchable: a vmapped fleet of caches may each sit at a different
active size).

Pseudocode mapping (0-indexed ranks, dynamic k):
  hit at rank i:
    jump  -= 1                     if jump  > -k/2          (line 2.4-2.6)
    jump' -= 1                     if i < k/2 and jump' > -k/2   (2.7-2.10)
    jump' += 1                     if i >= k/2 and jump' < 0     (2.11-2.15)
    actual = max(1, min(jump, i)); promote i -> i - actual  (2.16-2.20)
  miss on j:
    jump += 1 (clamped at 2k)                               (2.22)
    jump' += 1                     if jump' < 0             (2.23-2.25)
    actual = max(1, min(k-1, jump))                         (2.27)
    evict rank k-1; insert j at rank k - actual             (2.26, 2.28-2.29)
  after every request (see note):
    jump' = 0                      if jump == 0             (2.30-2.32)
    k     = 2k                     if jump >= 2k and 2k <= K_max  (2.33-2.35)
    k     = k/2                    if jump <= -k/2 and jump' <= -ceil(eps*k/2)
                                                            (2.36-2.38)

Documented interpretation choices (the paper's listing is ambiguous here):
  * Lines 2.30-2.38 appear inside the miss block, but the halving condition
    (jump == -K/2) is only reachable through hits — we therefore evaluate the
    resize checks after *every* request.
  * ``jump' == -K/2 * eps`` uses exact equality in the paper; for non-integer
    thresholds we use ``<=`` against ``ceil(eps*k/2)``.
  * After any resize, ``jump`` is clamped into the new [-k/2, 2k] range and
    ``jump'`` is reset to 0 (a fresh observation window for the new size).
"""
from __future__ import annotations

import jax.numpy as jnp

from .policy import EMPTY, Policy, Request, rank_step, step_info


class DynamicAdaptiveClimb(Policy):
    name = "dynamicadaptiveclimb"

    def __init__(self, eps: float = 0.5, growth: int = 4, k_min: int = 2):
        self.eps = float(eps)
        self.growth = int(growth)  # K_max = K * growth
        self.k_min = int(k_min)

    def init(self, K: int) -> dict:
        K_max = K * self.growth
        return {
            "cache": jnp.full((K_max,), EMPTY, dtype=jnp.int32),
            "jump": jnp.int32(K),
            "jump2": jnp.int32(0),
            "k": jnp.int32(K),
        }

    def observables(self, state):
        return {"k": state["k"], "jump": state["jump"]}

    def step(self, state, req: Request):
        K_max = state["cache"].shape[0]
        eps, k_min = self.eps, self.k_min

        def plan(hit, i, scalars):
            jump, jump2, k = scalars
            half = k // 2

            # --- hit path ----------------------------------------------
            jump_h = jnp.where(jump > -half, jump - 1, jump)
            top_half = i < half
            jump2_h = jnp.where(
                top_half,
                jnp.where(jump2 > -half, jump2 - 1, jump2),
                jnp.where(jump2 < 0, jump2 + 1, jump2),
            )
            actual_h = jnp.maximum(1, jnp.minimum(jump_h, i))
            # i == 0: no promotion (src = t = 0 is the identity shift)
            t_h = jnp.where(i > 0, i - actual_h, 0)

            # --- miss path: evict rank k-1, insert at k - actual -------
            jump_m = jnp.minimum(jump + 1, 2 * k)
            jump2_m = jnp.where(jump2 < 0, jump2 + 1, jump2)
            actual_m = jnp.maximum(1, jnp.minimum(k - 1, jump_m))
            t_m = k - actual_m

            # replacement victim rank (EMPTY while filling); entries wiped
            # by a shrink below are a resize side-effect, not a per-request
            # eviction event
            src = jnp.where(hit, i, k - 1)
            t = jnp.where(hit, t_h, t_m)
            jump = jnp.where(hit, jump_h, jump_m)
            jump2 = jnp.where(hit, jump2_h, jump2_m)

            # --- resize checks (after every request) -------------------
            jump2 = jnp.where(jump == 0, 0, jump2)
            shrink_thresh = -jnp.ceil(
                eps * half.astype(jnp.float32)).astype(jnp.int32)
            grow = (jump >= 2 * k) & (2 * k <= K_max)
            shrink = ((~grow) & (jump <= -half) & (jump2 <= shrink_thresh)
                      & (half >= k_min))

            k_new = jnp.where(grow, 2 * k, jnp.where(shrink, half, k))
            # deactivated ranks are wiped in the same fused pass
            wipe_from = jnp.where(shrink, k_new, jnp.int32(K_max))
            # Post-resize control state: after a grow, jump == 2k_old ==
            # k_new, which is exactly Alg. 2's init condition (jump = K) —
            # keep it.  After a shrink, jump is reset to 0 (neutral):
            # leaving it pinned at the new -k/2 would instantly re-arm the
            # halving trigger and cascade the cache to k_min.  jump'
            # restarts its observation window on any resize.  (The paper
            # does not specify post-resize state; these are the choices
            # that keep the control law well-posed.)
            resized = grow | shrink
            jump = jnp.where(shrink, 0,
                             jnp.clip(jump, -(k_new // 2), 2 * k_new))
            jump2 = jnp.where(resized, 0, jump2)
            return src, t, wipe_from, (jump, jump2, k_new)

        cache, (jump, jump2, k), hit, evicted = rank_step(
            state["cache"], req.key,
            (state["jump"], state["jump2"], state["k"]), plan)
        new_state = {"cache": cache, "jump": jump, "jump2": jump2, "k": k}
        return new_state, step_info(hit, req, evicted_key=evicted)
