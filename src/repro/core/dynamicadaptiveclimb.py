"""DynamicAdaptiveClimb — Algorithm 2 of the paper, vectorized, with true
dynamic cache resizing.

XLA needs static shapes, so the cache array is allocated at the lane-padded
width ``lane_pad(K * growth)`` and both the *active* size ``k`` and the
logical allocation bound ``kmax = K * growth`` are traced scalars; ranks
>= k are ``EMPTY`` and never hit.  Doubling activates already-empty ranks
(up to ``kmax`` — never into the lane padding); halving wipes ranks >= k/2.
This masked-budget scheme preserves the paper's policy behaviour exactly
while keeping the state a fixed-shape pytree (and therefore batchable: a
vmapped fleet of caches may each sit at a different active size).

Pseudocode mapping (0-indexed ranks, dynamic k):
  hit at rank i:
    jump  -= 1                     if jump  > -k/2          (line 2.4-2.6)
    jump' -= 1                     if i < k/2 and jump' > -k/2   (2.7-2.10)
    jump' += 1                     if i >= k/2 and jump' < 0     (2.11-2.15)
    actual = max(1, min(jump, i)); promote i -> i - actual  (2.16-2.20)
  miss on j:
    jump += 1 (clamped at 2k)                               (2.22)
    jump' += 1                     if jump' < 0             (2.23-2.25)
    actual = max(1, min(k-1, jump))                         (2.27)
    evict rank k-1; insert j at rank k - actual             (2.26, 2.28-2.29)
  after every request (see note):
    jump' = 0                      if jump == 0             (2.30-2.32)
    k     = 2k                     if jump >= 2k and 2k <= K_max  (2.33-2.35)
    k     = k/2                    if jump <= -k/2 and jump' <= -ceil(eps*k/2)
                                                            (2.36-2.38)

Documented interpretation choices (the paper's listing is ambiguous here):
  * Lines 2.30-2.38 appear inside the miss block, but the halving condition
    (jump == -K/2) is only reachable through hits — we therefore evaluate the
    resize checks after *every* request.
  * ``jump' == -K/2 * eps`` uses exact equality in the paper; for non-integer
    thresholds we use ``<=`` against ``ceil(eps*k/2)``.
  * After any resize, ``jump`` is clamped into the new [-k/2, 2k] range and
    ``jump'`` is reset to 0 (a fresh observation window for the new size).
"""
from __future__ import annotations

import jax.numpy as jnp

from .control import hit_update, miss_update, resize_update
from .policy import Policy, Request, padded_row, rank_step, step_info


class DynamicAdaptiveClimb(Policy):
    """Algorithm 2: AdaptiveClimb plus the jump'-driven dynamic resizing.

    ``eps`` scales the halving threshold (line 2.36), ``growth`` sets the
    allocation headroom (``K_max = K * growth``), ``k_min`` floors the
    active size.  See ``docs/PAPER_MAPPING.md`` for the line-by-line
    mapping and the documented post-resize-state choices.

    >>> from repro.core import Engine
    >>> res = Engine().replay("dac(eps=0.5,growth=4)", [0, 1] * 20, K=4,
    ...                       observe=True)
    >>> int(res.metrics.hits)
    38
    >>> int(res.obs["k"][-1])   # hits concentrate -> the cache halved
    2
    """

    name = "dynamicadaptiveclimb"

    # Adaptation scalars an admission wrapper (repro.core.admission) lets
    # advance even when it rejects the insert: the resize controller must
    # observe filtered misses or it starves.  Safe against a reverted
    # cache row: a miss step can only *grow* k (halving needs
    # jump <= -k/2, unreachable right after the miss's jump += 1 — the
    # check runs every step, so the threshold cannot be crossed earlier
    # and linger), and growth only activates ranks that are EMPTY in the
    # old row, so "ranks >= k are EMPTY" survives the merge.
    ADAPT_KEYS = ("jump", "jump2", "k", "kmax")

    def __init__(self, eps: float = 0.5, growth: int = 4, k_min: int = 2):
        self.eps = float(eps)
        self.growth = int(growth)  # K_max = K * growth
        self.k_min = int(k_min)

    def init(self, K: int) -> dict:
        """Fresh state at initial active size ``K``: a lane-padded rank row
        of width ``lane_pad(K * growth)``, with the logical allocation
        bound ``kmax = K * growth`` riding as a control scalar (growth is
        capped by ``kmax``, never by the padded array width).

        >>> st = DynamicAdaptiveClimb(growth=2).init(4)
        >>> st["cache"].shape, int(st["k"]), int(st["jump"]), int(st["kmax"])
        ((128,), 4, 4, 8)
        """
        K_max = K * self.growth
        return {
            "cache": padded_row(K_max),
            "jump": jnp.int32(K),
            "jump2": jnp.int32(0),
            "k": jnp.int32(K),
            "kmax": jnp.int32(K_max),
        }

    def observables(self, state):
        """Per-step signals the engine collects under ``observe=True``:
        the active size ``k`` and the ``jump`` controller."""
        return {"k": state["k"], "jump": state["jump"]}

    def _plan(self, budgeted: bool):
        """Build the Alg. 2 control law for :func:`rank_step`.

        The allocation bound rides as the traced scalar ``kmax`` (the
        padded array width over-allocates, so the bound can no longer be
        read off the shape — and a tier tenant's bound is the shared
        budget, not its own width).

        ``budgeted=False`` is the paper's law: grow iff ``jump`` saturates
        at ``2k`` and ``2k <= kmax``.  ``budgeted=True`` threads one extra
        control scalar — a dynamic capacity cap ``cap`` (granted by an
        external arbiter, e.g. ``repro.tier``) — and the doubling becomes
        ``k -> min(2k, cap)``: denied when ``cap == k``, partially granted
        when ``k < cap < 2k``.  Everything else is byte-for-byte the same
        arithmetic.  The vanilla law is reproduced exactly whenever the
        cap never truncates a doubling it would have allowed — i.e. the
        cap per step is either ``>= 2k`` or ``<= k``.  That is precisely
        what the tier's ``arbiter("static")`` emits (``2k`` while
        ``2k <= share``, else ``k``), which makes the static tier
        bit-identical to independent vanilla caches for *any* share; a
        cap merely pinned at a constant can instead yield one partial
        grow where vanilla denies (e.g. a non-power-of-two ``growth``).

        The scalar arithmetic itself lives in :mod:`repro.core.control`
        (shared with the serving KV pool — see
        ``tests/test_control_parity.py``); this plan owns only the rank
        plumbing (victim rank, insertion target, shrink wipe).
        """
        eps, k_min = self.eps, self.k_min

        def plan(hit, i, scalars):
            if budgeted:
                jump, jump2, k, kmax, cap = scalars
            else:
                jump, jump2, k, kmax = scalars

            # --- hit path ----------------------------------------------
            jump_h, jump2_h, actual_h = hit_update(jump, jump2, i, k)
            # i == 0: no promotion (src = t = 0 is the identity shift)
            t_h = jnp.where(i > 0, i - actual_h, 0)

            # --- miss path: evict rank k-1, insert at k - actual -------
            jump_m, jump2_m, actual_m = miss_update(jump, jump2, k)
            t_m = k - actual_m

            # replacement victim rank (EMPTY while filling); entries wiped
            # by a shrink below are a resize side-effect, not a per-request
            # eviction event
            src = jnp.where(hit, i, k - 1)
            t = jnp.where(hit, t_h, t_m)
            jump = jnp.where(hit, jump_h, jump_m)
            jump2 = jnp.where(hit, jump2_h, jump2_m)

            # --- resize checks (after every request) -------------------
            # the arbiter's cap gates (and may partially grant) the
            # doubling; cap == k denies, k < cap < 2k grants part
            k_new, jump, jump2, grow, shrink = resize_update(
                jump, jump2, k, eps=eps, k_min=k_min, kmax=kmax,
                cap=cap if budgeted else None)
            # deactivated ranks are wiped in the same fused pass (ranks
            # >= k are EMPTY by invariant, so "no wipe" = wipe from kmax)
            wipe_from = jnp.where(shrink, k_new, kmax)
            if budgeted:
                return src, t, wipe_from, (jump, jump2, k_new, kmax, cap)
            return src, t, wipe_from, (jump, jump2, k_new, kmax)

        return plan

    def step(self, state, req: Request):
        """One Alg. 2 request: hit/miss bookkeeping, promotion/insertion,
        and the after-request resize checks — one fused
        :func:`~repro.core.policy.rank_step`.

        >>> import jax.numpy as jnp
        >>> pol = DynamicAdaptiveClimb()
        >>> st, info = pol.step(pol.init(4), Request.of(jnp.int32(7)))
        >>> bool(info.hit), int(st["jump"])
        (False, 5)
        """
        cache, (jump, jump2, k, kmax), hit, evicted = rank_step(
            state["cache"], req.key,
            (state["jump"], state["jump2"], state["k"], state["kmax"]),
            self._plan(budgeted=False))
        new_state = {"cache": cache, "jump": jump, "jump2": jump2, "k": k,
                     "kmax": kmax}
        return new_state, step_info(hit, req, evicted_key=evicted)

    def step_budgeted(self, state, req: Request):
        """Like :meth:`step`, but growth is gated by a dynamic capacity cap
        ``state["cap"]`` on top of the ``kmax`` bound: the doubling
        becomes ``k -> min(2k, cap)`` (denied / granted / partially granted
        by whoever sets the cap — the tier arbiter in ``repro.tier``).
        ``cap`` rides through the fused step as an extra control scalar
        and is returned unchanged.  A cap that never truncates a doubling
        (``>= 2k`` or ``<= k`` at every step — see :meth:`_plan`)
        reproduces :meth:`step` bit-identically; pinning it to
        ``K * growth`` does so for power-of-two ``growth``.

        >>> import jax.numpy as jnp
        >>> pol = DynamicAdaptiveClimb(growth=2)
        >>> st = dict(pol.init(4), cap=jnp.int32(4))   # cap == k: never grow
        >>> for key in [0, 1, 2, 3, 4, 5, 6, 7]:
        ...     st, _ = pol.step_budgeted(st, Request.of(jnp.int32(key)))
        >>> int(st["jump"]), int(st["k"])    # jump saturated at 2k, denied
        (8, 4)
        """
        cache, (jump, jump2, k, kmax, cap), hit, evicted = rank_step(
            state["cache"], req.key,
            (state["jump"], state["jump2"], state["k"], state["kmax"],
             state["cap"]),
            self._plan(budgeted=True))
        new_state = {"cache": cache, "jump": jump, "jump2": jump2, "k": k,
                     "kmax": kmax, "cap": cap}
        return new_state, step_info(hit, req, evicted_key=evicted)
