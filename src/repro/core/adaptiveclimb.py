"""AdaptiveClimb — Algorithm 1 of the paper, vectorized.

State: lane-padded rank-ordered key array ``cache`` (index 0 = top; width
``lane_pad(K)``) + scalars ``jump`` and ``len`` (the logical capacity K).

Paper semantics (translated to 0-indexed ranks):
  * init: jump = K
  * hit at rank i:   jump = max(jump-1, 1); if i > 0, promote the item by
    ``jump`` ranks (clamped at the top): new rank t = max(i - jump, 0).
  * miss on key j:   jump = min(jump+1, K); evict rank K-1; insert j at rank
    K - jump (jump=K → top, jump=1 → bottom), shifting [K-jump, K-2] down.

The only interpretation choice: Alg. 1's hit path writes ``cache[i-jump]``
without clamping; for i-jump < 0 we clamp to the top (rank 0), matching the
geometric intent of Figs. 1–2 and Alg. 2's explicit ``actualJump`` clamp.
"""
from __future__ import annotations

import jax.numpy as jnp

from .policy import Policy, Request, padded_row, rank_step, step_info


class AdaptiveClimb(Policy):
    """Algorithm 1: CLIMB with an adaptive jump distance — hits promote by
    ``jump`` ranks (shrinking toward 1 on a hit streak), misses insert at
    rank ``K - jump`` (growing toward K on a miss streak).  See
    ``docs/PAPER_MAPPING.md`` for the line-by-line mapping.

    >>> from repro.core import Engine
    >>> int(Engine().replay("adaptiveclimb", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    2
    """

    name = "adaptiveclimb"

    # jump is a pure adaptation scalar, decoupled from the rank row — an
    # admission wrapper lets it keep observing rejected misses (see
    # repro.core.admission and DynamicAdaptiveClimb.ADAPT_KEYS)
    ADAPT_KEYS = ("jump",)

    def init(self, K: int) -> dict:
        # lane-padded rank row; the logical capacity K rides as the "len"
        # control scalar (the array width is the padded W)
        return {
            "cache": padded_row(K),
            "jump": jnp.int32(K),
            "len": jnp.int32(K),
        }

    def step(self, state, req: Request):
        def plan(hit, i, scalars):
            jump, n = scalars
            # --- hit path ---------------------------------------------
            jump_h = jnp.maximum(jump - 1, 1)
            t_h = jnp.maximum(i - jump_h, 0)
            # --- miss path: evict rank n-1, insert at n - jump --------
            jump_m = jnp.minimum(jump + 1, n)
            t_m = n - jump_m
            src = jnp.where(hit, i, n - 1)
            t = jnp.where(hit, t_h, t_m)
            return src, t, n, (jnp.where(hit, jump_h, jump_m), n)

        cache, (jump, n), hit, evicted = rank_step(
            state["cache"], req.key, (state["jump"], state["len"]), plan)
        return {"cache": cache, "jump": jump, "len": n}, \
            step_info(hit, req, evicted_key=evicted)
