"""The paper's contribution: AdaptiveClimb / DynamicAdaptiveClimb cache
replacement, 12 baselines, and the unified vectorized trace-replay engine.

The public surface is small::

    policy = make_policy("dac(eps=0.5,growth=4)")   # registry + spec parser
    result = Engine().replay(policy, Request.of(keys, sizes), K)
    result.miss_ratio, result.byte_miss_ratio, result.penalty_ratio
"""
import inspect
import re

from .adaptiveclimb import AdaptiveClimb
from .baselines import (ARC, BLRU, Clock, Climb, FIFO, Hyperbolic, LFU, LRU,
                        Sieve, TinyLFU, TwoQ)
from .dynamicadaptiveclimb import DynamicAdaptiveClimb
from .lirs_lhd import LHD, LIRS
from .policy import EMPTY, Policy, Request, StepInfo, rank_step, step_info
from .simulator import Engine, Metrics, ReplayResult, miss_ratio, mrr

POLICIES = {
    "adaptiveclimb": AdaptiveClimb,
    "dynamicadaptiveclimb": DynamicAdaptiveClimb,
    "fifo": FIFO,
    "lru": LRU,
    "blru": BLRU,
    "climb": Climb,
    "lfu": LFU,
    "clock": Clock,
    "sieve": Sieve,
    "twoq": TwoQ,
    "arc": ARC,
    "lirs": LIRS,
    "lhd": LHD,
    "tinylfu": TinyLFU,
    "hyperbolic": Hyperbolic,
}

ALIASES = {
    "ac": "adaptiveclimb",
    "dac": "dynamicadaptiveclimb",
    "2q": "twoq",
}

_SPEC_RE = re.compile(r"([a-z0-9_]+)\s*(?:\((.*)\))?\s*", re.I | re.S)


def _coerce(text: str):
    low = text.lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    return text.strip("'\"")


def _coerce_to_param(name: str, cls, key: str, value):
    """Coerce a parsed spec value to the declared type of the constructor
    parameter (inferred from its default), so ``dac(growth=4.0)`` and
    ``dac(growth=4)`` build identical policies instead of one smuggling a
    float through an integer knob."""
    param = inspect.signature(cls.__init__).parameters.get(key)
    if param is None:
        raise ValueError(
            f"unknown parameter {key!r} for policy {name!r}; accepts: "
            f"{sorted(p for p in inspect.signature(cls.__init__).parameters if p != 'self')}")
    default = param.default
    if default is inspect.Parameter.empty or isinstance(value, str):
        return value
    if isinstance(default, bool):
        if not isinstance(value, bool):
            raise ValueError(
                f"{name}({key}=...) expects a bool, got {value!r}")
        return value
    if isinstance(default, int):
        if isinstance(value, float):
            if not value.is_integer():
                raise ValueError(
                    f"{name}({key}=...) expects an integer, got {value!r}")
            return int(value)
        return int(value)
    if isinstance(default, float):
        return float(value)
    return value


def make_policy(spec) -> Policy:
    """Build a policy from a spec string: ``"lru"``, ``"dac"``,
    ``"dac(eps=0.5,growth=4)"``, ... — registry name (or alias) plus
    optional constructor kwargs (coerced to the parameter's declared
    type).  Policy instances pass through."""
    if isinstance(spec, Policy):
        return spec
    m = _SPEC_RE.fullmatch(spec.strip())
    if not m:
        raise ValueError(f"unparseable policy spec {spec!r}")
    name, argstr = m.group(1).lower(), m.group(2)
    name = ALIASES.get(name, name)
    if name not in POLICIES:
        raise ValueError(
            f"unknown policy {name!r}; known: {sorted(POLICIES)} "
            f"(aliases: {sorted(ALIASES)})")
    cls = POLICIES[name]
    kwargs = {}
    if argstr and argstr.strip():
        for part in argstr.split(","):
            k, sep, v = part.partition("=")
            if not sep:
                raise ValueError(
                    f"policy spec args must be k=v, got {part!r} in {spec!r}")
            k = k.strip()
            kwargs[k] = _coerce_to_param(name, cls, k, _coerce(v.strip()))
    return cls(**kwargs)


__all__ = [
    "AdaptiveClimb", "DynamicAdaptiveClimb", "ARC", "BLRU", "Clock", "Climb",
    "FIFO", "Hyperbolic", "LFU", "LHD", "LIRS", "LRU", "Sieve", "TinyLFU", "TwoQ",
    "EMPTY", "Policy", "Request", "StepInfo", "step_info", "rank_step",
    "POLICIES", "ALIASES", "make_policy",
    "Engine", "Metrics", "ReplayResult", "miss_ratio", "mrr",
]
