"""The paper's contribution: AdaptiveClimb / DynamicAdaptiveClimb cache
replacement, 12 baselines, and the vectorized trace-replay engine."""
from .adaptiveclimb import AdaptiveClimb
from .baselines import (ARC, BLRU, Clock, Climb, FIFO, Hyperbolic, LFU, LRU,
                        Sieve, TinyLFU, TwoQ)
from .dynamicadaptiveclimb import DynamicAdaptiveClimb
from .lirs_lhd import LHD, LIRS
from .policy import EMPTY, Policy
from .simulator import (miss_ratio, mrr, replay, replay_batch,
                        replay_observed, replay_sharded)

POLICIES = {
    "adaptiveclimb": AdaptiveClimb,
    "dynamicadaptiveclimb": DynamicAdaptiveClimb,
    "fifo": FIFO,
    "lru": LRU,
    "blru": BLRU,
    "climb": Climb,
    "lfu": LFU,
    "clock": Clock,
    "sieve": Sieve,
    "twoq": TwoQ,
    "arc": ARC,
    "lirs": LIRS,
    "lhd": LHD,
    "tinylfu": TinyLFU,
    "hyperbolic": Hyperbolic,
}

__all__ = [
    "AdaptiveClimb", "DynamicAdaptiveClimb", "ARC", "BLRU", "Clock", "Climb",
    "FIFO", "Hyperbolic", "LFU", "LHD", "LIRS", "LRU", "Sieve", "TinyLFU", "TwoQ",
    "EMPTY", "Policy", "POLICIES",
    "miss_ratio", "mrr", "replay", "replay_batch", "replay_observed",
    "replay_sharded",
]
