"""The paper's contribution: AdaptiveClimb / DynamicAdaptiveClimb cache
replacement, 12 baselines, and the unified vectorized trace-replay engine.

The public surface is small::

    policy = make_policy("dac(eps=0.5,growth=4)")   # registry + spec parser
    result = Engine().replay(policy, Request.of(keys, sizes), K)
    result.miss_ratio, result.byte_miss_ratio, result.penalty_ratio
"""
from ..specs import build_kwargs, parse_spec, split_top
from .adaptiveclimb import AdaptiveClimb
from .baselines import (ARC, BLRU, Clock, Climb, FIFO, Hyperbolic, LFU, LRU,
                        Sieve, TinyLFU, TwoQ)
from .dynamicadaptiveclimb import DynamicAdaptiveClimb
from .lirs_lhd import LHD, LIRS
from .policy import (EMPTY, LANE, Policy, Request, StepInfo, lane_pad,
                     padded_row, rank_step, step_info)
from .simulator import Engine, Metrics, ReplayResult, miss_ratio, mrr

POLICIES = {
    "adaptiveclimb": AdaptiveClimb,
    "dynamicadaptiveclimb": DynamicAdaptiveClimb,
    "fifo": FIFO,
    "lru": LRU,
    "blru": BLRU,
    "climb": Climb,
    "lfu": LFU,
    "clock": Clock,
    "sieve": Sieve,
    "twoq": TwoQ,
    "arc": ARC,
    "lirs": LIRS,
    "lhd": LHD,
    "tinylfu": TinyLFU,
    "hyperbolic": Hyperbolic,
}

ALIASES = {
    "ac": "adaptiveclimb",
    "dac": "dynamicadaptiveclimb",
    "2q": "twoq",
}


def _make_admission(argstr):
    """Build the ``admit(<base-spec>, k=v...)`` combinator: the first
    top-level argument is a full policy spec (possibly parenthesized —
    ``admit(dac(eps=0.5,growth=4),filter=tinylfu)``), the rest are
    ``AdmissionPolicy`` knobs coerced like any constructor kwargs."""
    from .admission import AdmissionPolicy
    parts = split_top(argstr)
    if not parts or "=" in parts[0].partition("(")[0]:
        raise ValueError(
            "admit(...) needs a base policy spec as its first argument, "
            "e.g. admit(dac,filter=tinylfu)")
    base = make_policy(parts[0])
    kwargs = build_kwargs("policy", "admit", AdmissionPolicy.__init__,
                          ",".join(parts[1:]), skip=("self", "base"))
    return AdmissionPolicy(base, **kwargs)


def make_policy(spec) -> Policy:
    """Build a policy from a spec string: ``"lru"``, ``"dac"``,
    ``"dac(eps=0.5,growth=4)"``, ... — registry name (or alias) plus
    optional constructor kwargs (coerced to the parameter's declared
    type; see :mod:`repro.specs`).  Policy instances pass through.

    >>> make_policy("dac(eps=0.25,growth=2)")
    DynamicAdaptiveClimb(eps=0.25, growth=2, k_min=2)
    >>> make_policy("2q").name           # aliases resolve
    'twoq'
    >>> make_policy("admit(dac(eps=0.25),filter=tinylfu)").base.eps
    0.25
    >>> make_policy("dac(nope=1)")
    Traceback (most recent call last):
        ...
    ValueError: unknown parameter 'nope' for policy 'dynamicadaptiveclimb'; accepts: ['eps', 'growth', 'k_min']
    """
    if isinstance(spec, Policy):
        return spec
    name, argstr = parse_spec(spec)
    name = ALIASES.get(name, name)
    if name == "admit":
        return _make_admission(argstr)
    if name not in POLICIES:
        raise ValueError(
            f"unknown policy {name!r}; known: {sorted(POLICIES)} "
            f"(aliases: {sorted(ALIASES)}; combinator: admit(<policy>,...))")
    cls = POLICIES[name]
    return cls(**build_kwargs("policy", name, cls.__init__, argstr))


from .admission import AdmissionPolicy  # noqa: E402  (needs make_policy)

__all__ = [
    "AdaptiveClimb", "AdmissionPolicy", "DynamicAdaptiveClimb",
    "ARC", "BLRU", "Clock", "Climb",
    "FIFO", "Hyperbolic", "LFU", "LHD", "LIRS", "LRU", "Sieve", "TinyLFU", "TwoQ",
    "EMPTY", "LANE", "Policy", "Request", "StepInfo", "step_info",
    "rank_step", "lane_pad", "padded_row",
    "POLICIES", "ALIASES", "make_policy",
    "Engine", "Metrics", "ReplayResult", "miss_ratio", "mrr",
]
