"""LIRS and LHD — the two remaining non-learned baselines from the paper's
evaluation (Table I/III), in the same vectorized pure-functional form.

LIRS (Jiang & Zhang 2002), timestamp formulation:
  The recency stack S is represented by per-key last-access times; "in the
  stack" == t_last >= the oldest LIR's t_last (stack pruning keeps an LIR
  block at the bottom, so the LIR minimum defines the stack bottom).  State
  per tracked key: LIR / resident-HIR / non-resident-HIR (ghost, bounded at
  2K entries).  Promotions/demotions follow the original rules; all
  selections are timestamp argmins (timestamps are unique, so behavior is
  deterministic and the oracle matches bit-for-bit).

LHD (Beckmann et al. 2018), binned-age approximation (unsampled):
  Hit density per power-of-2 age bin, HD(b) = hits_b / ((hits_b + evs_b
  + 1) * 2^b) — P(hit | age bin) over the bin's age scale.  Counters decay
  by integer halving every 4K requests; eviction takes the resident slot
  with minimal HD of its current age bin (exact argmin over all slots —
  the paper's 64-candidate sampling is a throughput optimization, not a
  policy difference).  Documented approximation: coarse binning replaces
  LHD's full age distributions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .policy import EMPTY, Policy, Request, find, step_info

INF32 = jnp.int32(2**31 - 1)

# LIRS states
FREE, LIR, HIR, GHOST = 0, 1, 2, 3


class LIRS(Policy):
    """LIRS (Jiang & Zhang 2002): inter-reference recency beats recency —
    LIR blocks own most of the cache, HIR blocks pass through a small
    residency window, ghosts remember evicted HIRs (see the module
    docstring for the timestamp formulation).

    >>> from repro.core import Engine
    >>> int(Engine().replay("lirs", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    3
    """

    name = "lirs"

    def __init__(self, hir_frac: float = 0.01, ghost_factor: int = 2):
        self.hir_frac = float(hir_frac)
        self.ghost_factor = int(ghost_factor)

    def _sizes(self, K):
        k_hir = max(1, int(K * self.hir_frac))
        return K - k_hir, k_hir, self.ghost_factor * K

    def init(self, K: int) -> dict:
        _, _, G = self._sizes(K)
        M = K + G
        return {
            "keys": jnp.full((M,), EMPTY, jnp.int32),
            "t_last": jnp.full((M,), -1, jnp.int32),
            "state": jnp.zeros((M,), jnp.int32),
            "t": jnp.int32(0),
        }

    def step(self, state, req: Request):
        key = req.key
        keys, t_last, st = state["keys"], state["t_last"], state["state"]
        t = state["t"] + 1
        K = (keys.shape[0]) // (1 + self.ghost_factor)
        k_lir, k_hir, G = self._sizes(K)

        idx_found = jnp.argmax(keys == key).astype(jnp.int32)
        tracked = jnp.any(keys == key)
        cur_state = jnp.where(tracked, st[idx_found], FREE)
        hit = tracked & ((cur_state == LIR) | (cur_state == HIR))

        n_lir = jnp.sum(st == LIR)
        lir_ts = jnp.where(st == LIR, t_last, INF32)
        lir_bottom = jnp.argmin(lir_ts).astype(jnp.int32)
        min_lir_t = jnp.where(n_lir > 0, t_last[lir_bottom], -1)

        in_stack = jnp.where(tracked, t_last[idx_found] >= min_lir_t,
                             jnp.bool_(False))

        # selection helpers --------------------------------------------------
        def lru_of(mask):
            ts = jnp.where(mask, t_last, INF32)
            return jnp.argmin(ts).astype(jnp.int32), jnp.any(mask)

        hir_lru, has_hir = lru_of(st == HIR)
        ghost_lru, has_ghost = lru_of(st == GHOST)
        free_slot = jnp.argmax(st == FREE).astype(jnp.int32)
        has_free = jnp.any(st == FREE)

        # --- case 1: LIR hit — refresh recency ------------------------------
        s1 = (keys, t_last.at[idx_found].set(t), st)

        # --- case 2: resident-HIR hit ---------------------------------------
        # in stack: promote to LIR, demote the LIR bottom to resident HIR
        st2a = st.at[idx_found].set(LIR).at[lir_bottom].set(HIR)
        # out of stack: stays HIR (Q MRU)
        promote = in_stack & (n_lir > 0)
        st2 = jnp.where(promote, st2a, st)
        s2 = (keys, t_last.at[idx_found].set(t), st2)

        # --- case 3: miss ---------------------------------------------------
        n_res = jnp.sum((st == LIR) | (st == HIR))
        full = n_res >= K
        # residency eviction: the demoted-to-ghost HIR (or dropped LIR)
        evicted = jnp.where(full,
                            jnp.where(has_hir, keys[hir_lru],
                                      keys[lir_bottom]),
                            EMPTY)

        # 3a. make room when full: evict LRU resident HIR -> ghost
        #     (if no HIR exists — unreachable after warmup, kept safe —
        #     drop the LIR bottom entirely)
        st3 = jnp.where(full,
                        jnp.where(has_hir, st.at[hir_lru].set(GHOST),
                                  st.at[lir_bottom].set(FREE)),
                        st)
        keys3 = jnp.where(full & ~has_hir,
                          keys.at[lir_bottom].set(EMPTY), keys)
        # bound the ghost table: drop its LRU if over capacity
        ghost_ts3 = jnp.where(st3 == GHOST, t_last, INF32)
        ghost_lru3 = jnp.argmin(ghost_ts3).astype(jnp.int32)
        n_ghost3 = jnp.sum(st3 == GHOST)
        drop = n_ghost3 > G
        keys3 = jnp.where(drop, keys3.at[ghost_lru3].set(EMPTY), keys3)
        st3 = jnp.where(drop, st3.at[ghost_lru3].set(FREE), st3)
        t3 = jnp.where(drop, t_last.at[ghost_lru3].set(-1), t_last)

        # 3b. insertion slot: reuse the key's ghost slot, else a free slot
        was_ghost = tracked & (cur_state == GHOST)
        ins = jnp.where(was_ghost, idx_found,
                        jnp.argmax(st3 == FREE).astype(jnp.int32))
        # warmup: while LIR underfull, new blocks become LIR.
        # ghost-in-stack: promote to LIR and demote the LIR bottom.
        ghost_promote = was_ghost & in_stack & (n_lir >= k_lir)
        new_state = jnp.where((n_lir < k_lir) | ghost_promote, LIR, HIR)
        keys3 = keys3.at[ins].set(key)
        st3 = st3.at[ins].set(new_state)
        st3 = jnp.where(ghost_promote, st3.at[lir_bottom].set(HIR), st3)
        t3 = t3.at[ins].set(t)
        s3 = (keys3, t3, st3)

        is_lir_hit = hit & (cur_state == LIR)
        out = tuple(
            jnp.where(is_lir_hit, a, jnp.where(hit, b, c))
            for a, b, c in zip(s1, s2, s3))
        return {"keys": out[0], "t_last": out[1], "state": out[2],
                "t": t}, step_info(hit, req, evicted_key=evicted)


class LHD(Policy):
    """LHD (Beckmann et al. 2018): evict the slot with the lowest hit
    density for its age bin (binned-age approximation, unsampled; see the
    module docstring).

    >>> from repro.core import Engine
    >>> int(Engine().replay("lhd", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    2
    """

    name = "lhd"

    def __init__(self, n_bins: int = 16, decay_every_factor: int = 4):
        self.n_bins = int(n_bins)
        self.decay_every_factor = int(decay_every_factor)

    def init(self, K: int) -> dict:
        return {
            "keys": jnp.full((K,), EMPTY, jnp.int32),
            "t_ins": jnp.full((K,), -1, jnp.int32),
            "hits": jnp.zeros((self.n_bins,), jnp.int32),
            "evs": jnp.zeros((self.n_bins,), jnp.int32),
            "t": jnp.int32(0),
        }

    def _bin(self, age):
        # integer floor(log2(age+1)) — exact, so the numpy oracle matches
        a = jnp.maximum(age, 0) + 1
        b = sum((a >= 2 ** j).astype(jnp.int32)
                for j in range(1, self.n_bins))
        return jnp.clip(b, 0, self.n_bins - 1)

    def _hd(self, hits, evs):
        b = jnp.arange(self.n_bins, dtype=jnp.float32)
        num = hits.astype(jnp.float32)
        den = (hits + evs + 1).astype(jnp.float32) * jnp.exp2(b)
        return num / den

    def step(self, state, req: Request):
        key = req.key
        keys, t_ins = state["keys"], state["t_ins"]
        hits_c, evs_c = state["hits"], state["evs"]
        t = state["t"] + 1
        K = keys.shape[0]
        hit, i = find(keys, key)
        age_i = t - t_ins[i]
        bin_i = self._bin(age_i)

        # hit: record the reuse age, refresh the slot
        hits_h = hits_c.at[bin_i].add(1)
        t_ins_h = t_ins.at[i].set(t)

        # miss: evict min hit-density (empties first), record eviction age
        hd = self._hd(hits_c, evs_c)
        ages = t - t_ins
        slot_hd = hd[self._bin(ages)]
        # float32 literal: a weak Python scalar would trace as f64 under x64
        slot_hd = jnp.where(keys == EMPTY, jnp.float32(-1.0), slot_hd)
        v = jnp.argmin(slot_hd).astype(jnp.int32)
        victim_occupied = keys[v] != EMPTY
        evs_m = jnp.where(victim_occupied,
                          evs_c.at[self._bin(t - t_ins[v])].add(1), evs_c)
        evicted = jnp.where(victim_occupied, keys[v], EMPTY)
        keys_m = keys.at[v].set(key)
        t_ins_m = t_ins.at[v].set(t)

        keys = jnp.where(hit, keys, keys_m)
        t_ins = jnp.where(hit, t_ins_h, t_ins_m)
        hits_c = jnp.where(hit, hits_h, hits_c)
        evs_c = jnp.where(hit, evs_c, evs_m)

        # periodic integer-halving decay
        decay = (t % (self.decay_every_factor * K)) == 0
        hits_c = jnp.where(decay, hits_c // 2, hits_c)
        evs_c = jnp.where(decay, evs_c // 2, evs_c)
        return {"keys": keys, "t_ins": t_ins, "hits": hits_c,
                "evs": evs_c, "t": t}, step_info(hit, req,
                                                 evicted_key=evicted)
