"""Size-aware admission layer: a ghost/count-min utility estimator plus a
wrapper that composes with *any* registry policy.

The byte-weighted metrics (PR 5 onward) had no policy-side counterpart:
every ranked policy admits every miss regardless of size, so a burst of
huge one-hit-wonder objects evicts the hot set and the byte miss ratio
pays for it twice.  Following *Lightweight Robust Size Aware Cache
Management* (Einziger et al.), admission is a separate, O(1)-state layer
in front of the insert path:

* a **frequency sketch** — TinyLFU-style count-min (``rows x W`` int32,
  multiply-shift hashing, periodic halving) counting every request;
* a **bytes sketch** sharing the same hash lanes, accumulating request
  sizes, so a *victim's* mean object size can be estimated without any
  per-item resident statistics;
* a **ghost ring** — a fixed-size FIFO of recently-evicted keys (the
  shadow cache): a key that bounces back shortly after eviction gets a
  frequency boost, recovering the hot set after an adversarial flush.

On a miss the wrapper runs the base policy's step first (which routes
through the fused ``rank_step`` and therefore through every ``use_pallas``
lowering unchanged), reads the victim off ``StepInfo.evicted_key``, and
compares size-normalized utilities::

    u(key, size) = (freq(key) + boost * in_ghost(key)) / max(size, 1)

A rejected candidate *reverts the base step*: the victim stays resident
and the ``StepInfo`` still charges the miss (the object was fetched — it
just wasn't cached) while reporting no eviction.  A base may opt its
adaptation scalars out of the revert by declaring ``ADAPT_KEYS`` (DAC
does: its ``jump``/``k`` resize controller must keep seeing filtered
misses, or a flood of rejected one-hit wonders would silently freeze the
paper's dynamic resizing — the same reason W-TinyLFU's adaptive window
observes accesses its doorkeeper bounced).  Hits always commit, so
admission can never change hit accounting.

State shapes are fixed, every decision is pure arithmetic on the carry —
the wrapper scans, vmaps, jits, and shards exactly like its base.

>>> from repro.core import Engine, make_policy
>>> pol = make_policy("admit(dac(eps=0.5),filter=tinylfu,size_norm=false)")
>>> pol.base.eps, pol.filter, pol.size_norm
(0.5, 'tinylfu', False)
>>> res = Engine().replay(pol, [0, 1, 0, 2, 0, 1, 2, 0], K=2,
...                       collect_info=False)
>>> float(res.miss_ratio) <= 1.0
True
>>> off = make_policy("admit(lru,filter=off)")      # pass-through wrapper
>>> a = Engine().replay(off, [3, 1, 3, 2], K=2).metrics
>>> b = Engine().replay("lru", [3, 1, 3, 2], K=2).metrics
>>> int(a.hits) == int(b.hits)
True
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .policy import EMPTY, Policy, Request, StepInfo, step_info

__all__ = ["AdmissionPolicy", "FILTERS"]

# admission filter variants:
#   off     — always admit; the wrapper is bit-identical to the bare base
#   tinylfu — frequency + bytes sketches only (no ghost ring)
#   ghost   — sketches + recently-evicted ghost ring boost (the default)
FILTERS = ("off", "tinylfu", "ghost")

# multiply-shift hash constants, one odd constant per sketch row (the same
# mix the TinyLFU baseline uses — the two estimators stay comparable)
_HASH_MIX = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F)


class AdmissionPolicy(Policy):
    """``admit(<base>, ...)``: size-aware admission around any policy.

    ``filter`` picks the estimator (:data:`FILTERS`); ``size_norm``
    divides utilities by (estimated) object size, making the decision
    byte-aware; ``rows``/``width_factor``/``window_factor`` shape the
    count-min sketch exactly like the TinyLFU baseline;
    ``ghost_factor`` sizes the ghost ring (``ghost_factor * K`` keys)
    and ``ghost_boost`` is the frequency credit for a ghost hit.

    >>> from repro.core import make_policy
    >>> make_policy("admit(dac,filter=ghost)").name
    'admit'
    >>> make_policy("admit(lru)") == make_policy("admit(lru)")
    True
    >>> make_policy("admit(lru,filter=sometimes)")
    Traceback (most recent call last):
        ...
    ValueError: admit filter must be one of ('off', 'tinylfu', 'ghost'), \
got 'sometimes'
    """

    name = "admit"

    def __init__(self, base, filter: str = "ghost", size_norm: bool = True,
                 rows: int = 4, width_factor: int = 16,
                 window_factor: int = 8, ghost_factor: int = 4,
                 ghost_boost: int = 2):
        from . import make_policy
        self.base = make_policy(base)
        if filter not in FILTERS:
            raise ValueError(
                f"admit filter must be one of {FILTERS}, got {filter!r}")
        self.filter = str(filter)
        self.size_norm = bool(size_norm)
        self.rows = int(rows)
        if not 1 <= self.rows <= len(_HASH_MIX):
            raise ValueError(
                f"rows must lie in [1, {len(_HASH_MIX)}], got {rows}")
        self.width_factor = int(width_factor)
        self.window_factor = int(window_factor)
        self.ghost_factor = int(ghost_factor)
        self.ghost_boost = int(ghost_boost)
        if min(self.width_factor, self.window_factor,
               self.ghost_factor) < 1 or self.ghost_boost < 0:
            raise ValueError(
                "width_factor/window_factor/ghost_factor must be >= 1 and "
                "ghost_boost >= 0")

    # --- estimator state -------------------------------------------------

    def _width(self, K: int) -> int:
        w = 1
        while w < K * self.width_factor:
            w *= 2
        return w

    def init(self, K: int) -> dict:
        """Base state nested under ``"base"``; estimator state (when the
        filter is on) under ``"adm"`` — all fixed shapes, derived from
        ``K`` exactly like the base's own rows.

        >>> pol = AdmissionPolicy("lru", filter="ghost")
        >>> st = pol.init(4)
        >>> sorted(st), sorted(st["adm"])
        (['adm', 'base'], ['adds', 'bytes', 'ghost', 'head', 'sketch', \
'window'])
        >>> AdmissionPolicy("lru", filter="off").init(4).keys()
        dict_keys(['base'])
        """
        state = {"base": self.base.init(K)}
        if self.filter == "off":
            return state
        W = self._width(K)
        adm = {
            "sketch": jnp.zeros((self.rows, W), jnp.int32),
            "bytes": jnp.zeros((self.rows, W), jnp.float32),
            "adds": jnp.int32(0),
            "window": jnp.int32(self.window_factor * K),
        }
        if self.filter == "ghost":
            adm["ghost"] = jnp.full((self.ghost_factor * K,), EMPTY,
                                    jnp.int32)
            adm["head"] = jnp.int32(0)
        state["adm"] = adm
        return state

    # --- estimator arithmetic (pure, fixed-shape) ------------------------

    def _hash(self, key, W):
        a = jnp.array(_HASH_MIX[: self.rows], dtype=jnp.uint32)
        x = (key.astype(jnp.uint32) + 1) * a
        x = x ^ (x >> 15)
        return (x & jnp.uint32(W - 1)).astype(jnp.int32)

    def _observe(self, adm: dict, req: Request) -> dict:
        """Count the request in both sketches; halve when the window
        expires (ages stale frequencies *and* stale byte totals together,
        so the mean-size ratio survives the decay)."""
        W = adm["sketch"].shape[1]
        h = self._hash(req.key, W)
        r = jnp.arange(self.rows)
        sketch = adm["sketch"].at[r, h].add(1)
        byts = adm["bytes"].at[r, h].add(req.size.astype(jnp.float32))
        adds = adm["adds"] + 1
        expire = adds >= adm["window"]
        # floor the byte halving like the integer frequency halving, so
        # the bytes/freq mean-size ratio stays exact on unit-size traces
        # (size_norm then degenerates to the pure frequency comparison)
        return dict(adm,
                    sketch=jnp.where(expire, sketch // 2, sketch),
                    bytes=jnp.where(expire, jnp.floor(byts * 0.5), byts),
                    adds=jnp.where(expire, 0, adds))

    def _freq_bytes(self, adm: dict, key):
        """Count-min point estimates: (frequency, accumulated bytes)."""
        W = adm["sketch"].shape[1]
        h = self._hash(key, W)
        r = jnp.arange(self.rows)
        return (jnp.min(adm["sketch"][r, h]).astype(jnp.float32),
                jnp.min(adm["bytes"][r, h]))

    def _boosted(self, adm: dict, key, freq):
        if self.filter != "ghost":
            return freq
        in_ghost = jnp.any(adm["ghost"] == key)
        return freq + self.ghost_boost * in_ghost.astype(jnp.float32)

    def _utility(self, adm: dict, key, size):
        """Size-normalized estimated utility of caching ``key``."""
        freq, _ = self._freq_bytes(adm, key)
        freq = self._boosted(adm, key, freq)
        if not self.size_norm:
            return freq
        return freq / jnp.maximum(size.astype(jnp.float32), 1.0)

    def _victim_utility(self, adm: dict, victim):
        """Like :meth:`_utility`, but the victim's size is *estimated*
        from the bytes/frequency sketch ratio — no resident metadata."""
        freq, byts = self._freq_bytes(adm, victim)
        boosted = self._boosted(adm, victim, freq)
        if not self.size_norm:
            return boosted
        mean_size = byts / jnp.maximum(freq, 1.0)
        return boosted / jnp.maximum(mean_size, 1.0)

    def _remember(self, adm: dict, victim, push) -> dict:
        """Push an admitted step's victim into the ghost ring."""
        ghost, head = adm["ghost"], adm["head"]
        G = ghost.shape[0]
        ghost = jnp.where(push, ghost.at[head].set(victim), ghost)
        head = jnp.where(push, (head + 1) % G, head)
        return dict(adm, ghost=ghost, head=head)

    # --- the wrapped step ------------------------------------------------

    def _merge(self, admit, new_base, old_base):
        """Commit or revert the base transition.  A rejected miss reverts
        the base state — except any ``ADAPT_KEYS`` the base declares:
        decoupled adaptation scalars (e.g. DAC's ``jump``/``k``
        controller) that must keep observing filtered misses, exactly as
        W-TinyLFU's adaptive window sees accesses its doorkeeper bounced.
        A base that declares none (the default) reverts wholesale."""
        revert = lambda n, o: jax.tree_util.tree_map(
            lambda a, b: jnp.where(admit, a, b), n, o)
        adapt = frozenset(getattr(self.base, "ADAPT_KEYS", ()))
        if not adapt or not isinstance(new_base, dict):
            return revert(new_base, old_base)
        return {k: new_base[k] if k in adapt
                else revert(new_base[k], old_base[k]) for k in new_base}

    def _gate(self, state: dict, req: Request, new_base, info: StepInfo):
        """Shared post-step gating: admit or revert the base transition."""
        adm = self._observe(state["adm"], req)
        victim = info.evicted_key
        # hits and victimless inserts (filling, or the base's own
        # admission already bounced) always commit; contested inserts
        # compare size-normalized utilities.  The classic tinylfu filter
        # breaks ties for the resident (strict >): a one-hit wonder never
        # displaces an established key, but equal-utility churn is locked
        # out too, which starves adaptive bases (DAC's resize controller
        # only observes committed steps).  The ghost filter admits ties
        # (>=): equal-utility traffic flows through untouched and only
        # strictly-worse candidates — the oversized one-hit flood — bounce.
        u_cand = self._utility(adm, req.key, req.size)
        u_vict = self._victim_utility(adm, victim)
        beats = u_cand >= u_vict if self.filter == "ghost" else \
            u_cand > u_vict
        admit = info.hit | (victim == EMPTY) | beats
        base_out = self._merge(admit, new_base, state["base"])
        if self.filter == "ghost":
            adm = self._remember(adm, victim,
                                 push=admit & ~info.hit & (victim != EMPTY))
        # a rejected miss still charges size/cost, but nothing left the
        # cache — mask the eviction exactly like step_info does on hits
        info = info._replace(evicted_key=jnp.where(admit, victim, EMPTY))
        return {"base": base_out, "adm": adm}, info

    def step(self, state: dict, req: Request):
        """Base step first (fused ``rank_step`` path untouched), then the
        admission gate.

        >>> import jax.numpy as jnp
        >>> pol = AdmissionPolicy("lru")
        >>> st, info = pol.step(pol.init(2), Request.of(jnp.int32(7)))
        >>> bool(info.hit), int(info.evicted_key), int(st["adm"]["adds"])
        (False, -1, 1)
        """
        new_base, info = self.base.step(state["base"], req)
        if self.filter == "off":
            return {"base": new_base}, info
        return self._gate(state, req, new_base, info)

    def _step_budgeted(self, fn, state: dict, req: Request):
        """Budgeted variant, delegated to the base's ``step_budgeted``
        (``state["base"]["cap"]`` threads through unchanged) with the
        same gate on top — the tier/fleet contract survives wrapping."""
        new_base, info = fn(state["base"], req)
        if self.filter == "off":
            return {"base": new_base}, info
        return self._gate(state, req, new_base, info)

    # --- conditional delegation -----------------------------------------
    # `observables` / `step_budgeted` must exist on the wrapper exactly
    # when the base has them (the engine and the tier feature-detect with
    # hasattr), so they resolve dynamically instead of living on the class.

    def __getattr__(self, name):
        if name in ("observables", "step_budgeted"):
            base = self.__dict__.get("base")
            fn = getattr(base, name, None)
            if fn is not None:
                if name == "observables":
                    return lambda state: fn(state["base"])
                return functools.partial(self._step_budgeted, fn)
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")
