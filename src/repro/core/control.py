"""Algorithm 2's scalar control law — the single source of truth.

Two subsystems run the paper's jump/jump' arithmetic on very different
substrates: :mod:`repro.core.dynamicadaptiveclimb` drives a rank *row*
(key array indexed by rank) through the fused ``rank_step``, while
:mod:`repro.serving.kv_cache` drives a ``rank2slot`` indirection table
over physical KV slots.  The *control scalars* — ``jump``, ``jump'``,
the active size ``k`` and the promotion/insertion distance ``actual``
— obey identical update rules in both (the paper's lines 2.4-2.38), and
any drift between the two copies silently breaks the serving path's
claim to be "Alg. 2 mapped onto KV management".

This module holds those updates once.  Callers keep their own data-plane
plumbing (where the promoted entry lands, which slot is freed); the
thresholds and saturation arithmetic live here.  A bit-parity regression
test (``tests/test_control_parity.py``) drives both subsystems through
matched event streams and asserts the scalar trajectories are identical.

All functions are shape-polymorphic jnp expressions: they accept traced
scalars (inside ``rank_step`` plans), batched arrays (vmapped KV pools),
or concrete ints (doctests below).

>>> import jax.numpy as jnp
>>> j, j2, actual = miss_update(jnp.int32(4), jnp.int32(0), jnp.int32(4))
>>> int(j), int(j2), int(actual)
(5, 0, 3)
>>> j, j2, actual = hit_update(jnp.int32(5), jnp.int32(0), i=jnp.int32(1),
...                            k=jnp.int32(4))
>>> int(j), int(actual)            # jump decays, promote by min(jump, i)
(4, 1)
>>> out = resize_update(jnp.int32(8), jnp.int32(0), jnp.int32(4),
...                     eps=0.5, k_min=2, kmax=jnp.int32(16))
>>> int(out[0]), bool(out[3])      # jump saturated at 2k -> doubled
(8, True)
>>> out = resize_update(jnp.int32(8), jnp.int32(0), jnp.int32(4),
...                     eps=0.5, k_min=2, kmax=jnp.int32(16),
...                     cap=jnp.int32(6))
>>> int(out[0])                    # arbiter cap 6: partial grant
6
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["hit_update", "miss_update", "resize_update"]


def hit_update(jump, jump2, i, k):
    """Alg. 2 hit path (lines 2.4-2.20) at rank ``i``: decay ``jump``
    toward ``-k/2``, steer ``jump'`` by whether the hit landed in the top
    half, and return the promotion distance ``actual``.

    Returns ``(jump, jump2, actual)``; the caller moves the hit entry
    from rank ``i`` to rank ``i - actual`` (no-op at ``i == 0``).
    """
    half = k // 2
    jump_h = jnp.where(jump > -half, jump - 1, jump)
    top_half = i < half
    jump2_h = jnp.where(
        top_half,
        jnp.where(jump2 > -half, jump2 - 1, jump2),
        jnp.where(jump2 < 0, jump2 + 1, jump2),
    )
    actual = jnp.maximum(1, jnp.minimum(jump_h, i))
    return jump_h, jump2_h, actual


def miss_update(jump, jump2, k):
    """Alg. 2 miss path (lines 2.22-2.27): ``jump`` climbs (saturating at
    ``2k`` — the grow demand signal), ``jump'`` relaxes toward 0, and the
    new entry inserts ``actual`` ranks above the bottom.

    Returns ``(jump, jump2, actual)``; the caller evicts rank ``k - 1``
    (when full) and inserts at rank ``k - actual``.
    """
    jump_m = jnp.minimum(jump + 1, 2 * k)
    jump2_m = jnp.where(jump2 < 0, jump2 + 1, jump2)
    actual = jnp.maximum(1, jnp.minimum(k - 1, jump_m))
    return jump_m, jump2_m, actual


def resize_update(jump, jump2, k, *, eps, k_min, kmax, cap=None):
    """Alg. 2 resize checks (lines 2.30-2.38), evaluated after every
    request, plus the documented post-resize state choices (see
    ``repro.core.dynamicadaptiveclimb``'s module docstring).

    ``cap=None`` is the paper's un-arbitrated law: grow iff ``jump``
    saturates at ``2k`` and ``2k <= kmax``.  With a ``cap`` (a dynamic
    capacity grant from an external arbiter — ``repro.tier`` /
    ``repro.fleet``), the doubling becomes ``k -> min(2k, cap, kmax)``:
    denied when ``cap <= k``, partial when ``k < cap < 2k``.

    Returns ``(k_new, jump, jump2, grow, shrink)``; the caller wipes the
    data-plane entries at ranks ``>= k_new`` on shrink.
    """
    half = k // 2
    jump2 = jnp.where(jump == 0, 0, jump2)
    shrink_thresh = -jnp.ceil(
        eps * jnp.asarray(half).astype(jnp.float32)).astype(jnp.int32)
    if cap is None:
        k_grow = 2 * k
        grow = (jump >= 2 * k) & (2 * k <= kmax)
    else:
        k_grow = jnp.minimum(2 * k, jnp.minimum(cap, kmax))
        grow = (jump >= 2 * k) & (k_grow > k)
    shrink = ((~grow) & (jump <= -half) & (jump2 <= shrink_thresh)
              & (half >= k_min))

    k_new = jnp.where(grow, k_grow, jnp.where(shrink, half, k))
    # Post-resize state: after a grow, jump == 2k_old == k_new is exactly
    # Alg. 2's init condition — keep it.  After a shrink, jump resets to 0
    # (leaving it pinned at the new -k/2 would instantly re-arm the halving
    # trigger); jump' restarts its observation window on any resize.
    resized = grow | shrink
    jump = jnp.where(shrink, 0, jnp.clip(jump, -(k_new // 2), 2 * k_new))
    jump2 = jnp.where(resized, 0, jump2)
    return k_new, jump, jump2, grow, shrink
