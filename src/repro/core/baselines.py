"""Baseline cache-replacement policies, vectorized for scan/vmap.

Every baseline the paper evaluates that is implementable without per-trace
learned infrastructure is implemented here with the same pure-functional
interface as the proposed policies:

  FIFO, LRU, CLIMB, LFU, CLOCK, SIEVE, TwoQ, ARC, B-LRU, TinyLFU, Hyperbolic

Slot-based policies (FIFO/LRU/LFU/CLOCK/SIEVE/Hyperbolic/TinyLFU) keep keys
in fixed slots with per-slot metadata — hit/miss behaviour only depends on
*membership*, so this is observationally identical to the textbook list
formulations while being O(K)-vector per request.  Rank-based policies
(CLIMB and the proposed ones) use the rank-array representation.

Documented approximations (validated against `oracle.py`, which implements
the same semantics step-by-step in plain Python):
  * LFU: in-cache frequency only (history lost on eviction); ties broken by
    lowest slot index.
  * CLOCK: new pages inserted with ref bit clear; hand advances past victim.
  * SIEVE: faithful to Yang et al. 2023 (hand tail->head, survivors stay).
  * TwoQ: full 2Q with A1in FIFO, A1out ghost, Am LRU; Kin=K/4, Kout=K/2.
  * ARC: faithful to Megiddo & Modha 2003 Fig. 4.
  * B-LRU: lazy-promotion LRU (recency update only when the entry's last
    update is older than K/8 requests) — models the promotion-buffer churn
    reduction of Yang et al.'s B-LRU.
  * TinyLFU: LRU eviction + count-min-sketch admission filter with periodic
    halving (window 8K), 4 hash rows.
  * Hyperbolic: exact priority freq/age over all slots (no sampling).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .policy import (EMPTY, Policy, Request, find, padded_row, rank_step,
                     step_info)

INF32 = jnp.int32(2**31 - 1)


def _time_dtype():
    """Dtype for monotonically increasing timestamps.  int32 wraps after
    2^31 requests — a few minutes of a multi-billion-request stream replay
    (``Engine.replay_stream``) — so widen to int64 whenever x64 is enabled;
    CPU CI (x64 off) keeps the compact int32 layout."""
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def _first_empty(keys):
    """Index of first EMPTY slot, else 0 (caller must check has_empty)."""
    empty = keys == EMPTY
    return jnp.any(empty), jnp.argmax(empty).astype(jnp.int32)


# ---------------------------------------------------------------------------


class FIFO(Policy):
    """First-in-first-out ring buffer: misses overwrite the oldest
    insertion; hits touch nothing.

    >>> from repro.core import Engine
    >>> int(Engine().replay("fifo", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    1
    """
    name = "fifo"

    def init(self, K: int) -> dict:
        return {"keys": jnp.full((K,), EMPTY, jnp.int32), "head": jnp.int32(0)}

    def step(self, state, req: Request):
        key = req.key
        keys, head = state["keys"], state["head"]
        K = keys.shape[0]
        hit, _ = find(keys, key)
        keys_m = keys.at[head].set(key)
        head_m = (head + 1) % K
        return {
            "keys": jnp.where(hit, keys, keys_m),
            "head": jnp.where(hit, head, head_m),
        }, step_info(hit, req, evicted_key=keys[head])


class LRU(Policy):
    """Least-recently-used: every hit refreshes a per-slot timestamp,
    misses evict the stalest slot.

    >>> from repro.core import Engine
    >>> int(Engine().replay("lru", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    2
    """
    name = "lru"

    def init(self, K: int) -> dict:
        dt = _time_dtype()
        return {
            "keys": jnp.full((K,), EMPTY, jnp.int32),
            "last": jnp.full((K,), -1, dt),
            "t": jnp.zeros((), dt),
        }

    def step(self, state, req: Request):
        key = req.key
        keys, last, t = state["keys"], state["last"], state["t"]
        hit, i = find(keys, key)
        v = jnp.argmin(last).astype(jnp.int32)  # empties (-1) evicted first
        slot = jnp.where(hit, i, v)
        evicted = keys[v]
        keys = keys.at[slot].set(key)
        last = last.at[slot].set(t)
        return {"keys": keys, "last": last, "t": t + 1}, \
            step_info(hit, req, evicted_key=evicted)


class BLRU(Policy):
    """LRU with buffered (lazy) promotion: a hit refreshes recency only
    if the entry's recorded recency is older than ``K // lag_div``
    requests (Yang et al.'s B-LRU churn reduction).

    >>> from repro.core import Engine
    >>> int(Engine().replay("blru", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    2
    """

    name = "blru"

    def __init__(self, lag_div: int = 8):
        self.lag_div = int(lag_div)

    def init(self, K: int) -> dict:
        return LRU().init(K)

    def step(self, state, req: Request):
        key = req.key
        keys, last, t = state["keys"], state["last"], state["t"]
        K = keys.shape[0]
        lag = max(1, K // self.lag_div)
        hit, i = find(keys, key)
        v = jnp.argmin(last).astype(jnp.int32)
        do_update = (~hit) | (t - last[i] > lag)
        slot = jnp.where(hit, i, v)
        evicted = keys[v]
        keys = keys.at[slot].set(key)
        last = jnp.where(do_update, last.at[slot].set(t), last)
        return {"keys": keys, "last": last, "t": t + 1}, \
            step_info(hit, req, evicted_key=evicted)


class Climb(Policy):
    """Classic CLIMB: a hit swaps the entry one rank up; a miss replaces
    the bottom rank in place.

    >>> from repro.core import Engine
    >>> int(Engine().replay("climb", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    0
    """

    name = "climb"

    def init(self, K: int) -> dict:
        # lane-padded rank row + the logical capacity as a control scalar
        # (the array width is the padded W, so K can no longer be read off
        # the shape — see repro.core.policy's padding invariants)
        return {"cache": padded_row(K), "len": jnp.int32(K)}

    def step(self, state, req: Request):
        def plan(hit, i, scalars):
            (n,) = scalars
            # hit: swap one rank up; miss: replace the bottom in place
            # (src == t == n-1 inserts without shifting anything)
            src = jnp.where(hit, i, n - 1)
            t = jnp.where(hit, jnp.maximum(i - 1, 0), n - 1)
            return src, t, n, (n,)

        cache, (n,), hit, evicted = rank_step(
            state["cache"], req.key, (state["len"],), plan)
        return {"cache": cache, "len": n}, \
            step_info(hit, req, evicted_key=evicted)


class LFU(Policy):
    """Least-frequently-used over in-cache counts (history lost on
    eviction); ties break toward the lowest slot index.

    >>> from repro.core import Engine
    >>> int(Engine().replay("lfu", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    3
    """
    name = "lfu"

    def init(self, K: int) -> dict:
        return {
            "keys": jnp.full((K,), EMPTY, jnp.int32),
            "cnt": jnp.zeros((K,), jnp.int32),
        }

    def step(self, state, req: Request):
        key = req.key
        keys, cnt = state["keys"], state["cnt"]
        hit, i = find(keys, key)
        v = jnp.argmin(cnt).astype(jnp.int32)  # empties (cnt=0) evicted first
        slot = jnp.where(hit, i, v)
        evicted = keys[v]
        keys = keys.at[slot].set(key)
        cnt = jnp.where(hit, cnt.at[slot].add(1), cnt.at[slot].set(1))
        return {"keys": keys, "cnt": cnt}, \
            step_info(hit, req, evicted_key=evicted)


class Clock(Policy):
    """Second-chance CLOCK: the hand sweeps past referenced slots,
    clearing their bits, and evicts the first unreferenced one.

    >>> from repro.core import Engine
    >>> int(Engine().replay("clock", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    2
    """
    name = "clock"

    def init(self, K: int) -> dict:
        return {
            "keys": jnp.full((K,), EMPTY, jnp.int32),
            "ref": jnp.zeros((K,), jnp.bool_),
            "hand": jnp.int32(0),
        }

    def step(self, state, req: Request):
        key = req.key
        keys, ref, hand = state["keys"], state["ref"], state["hand"]
        K = keys.shape[0]
        hit, i = find(keys, key)

        # victim search: first slot at/after hand with ref clear (or empty)
        idx = jnp.arange(K, dtype=jnp.int32)
        offset = (idx - hand) % K
        evictable = (~ref) | (keys == EMPTY)
        cand = jnp.where(evictable, offset, K)
        vo = jnp.min(cand)
        none = vo == K  # all referenced: full sweep clears, victim = hand
        victim = jnp.where(none, hand, (hand + vo) % K)
        passed = offset < jnp.where(none, K, vo)
        ref_m = jnp.where(passed, False, ref)
        keys_m = keys.at[victim].set(key)
        ref_m = ref_m.at[victim].set(False)
        hand_m = (victim + 1) % K

        return {
            "keys": jnp.where(hit, keys, keys_m),
            "ref": jnp.where(hit, ref.at[i].set(True), ref_m),
            "hand": jnp.where(hit, hand, hand_m),
        }, step_info(hit, req, evicted_key=keys[victim])


class Sieve(Policy):
    """SIEVE (Yang et al. 2023): FIFO order, visited bits, hand sweeps
    from tail (oldest) toward head clearing visited bits; survivors do
    not move.

    >>> from repro.core import Engine
    >>> int(Engine().replay("sieve", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    3
    """

    name = "sieve"

    def init(self, K: int) -> dict:
        return {
            "keys": jnp.full((K,), EMPTY, jnp.int32),
            "vis": jnp.zeros((K,), jnp.bool_),
            "seq": jnp.zeros((K,), jnp.int32),
            "hand_seq": jnp.int32(0),
            "ctr": jnp.int32(0),
        }

    def step(self, state, req: Request):
        key = req.key
        keys, vis, seq = state["keys"], state["vis"], state["seq"]
        hand_seq, ctr = state["hand_seq"], state["ctr"]
        hit, i = find(keys, key)
        has_empty, e = _first_empty(keys)

        # ---- eviction scan in closed form (cache full) ----
        unv = ~vis
        ge = seq >= hand_seq
        c1 = unv & ge
        c2 = unv & ~ge
        v1 = jnp.min(jnp.where(c1, seq, INF32))
        v2 = jnp.min(jnp.where(c2, seq, INF32))
        ge_any = jnp.any(ge)
        v3 = jnp.where(ge_any, jnp.min(jnp.where(ge, seq, INF32)),
                       jnp.min(seq))  # all-visited: full sweep, evict start
        case1 = jnp.any(c1)
        case2 = (~case1) & jnp.any(c2)
        victim_seq = jnp.where(case1, v1, jnp.where(case2, v2, v3))
        cleared = jnp.where(
            case1,
            vis & ge & (seq < v1),
            jnp.where(case2, (vis & ge) | (vis & ~ge & (seq < v2)),
                      jnp.ones_like(vis)),
        )
        victim = jnp.argmax(seq == victim_seq).astype(jnp.int32)

        slot = jnp.where(has_empty, e, victim)
        keys_m = keys.at[slot].set(key)
        vis_m = jnp.where(has_empty, vis, vis & ~cleared).at[slot].set(False)
        seq_m = seq.at[slot].set(ctr)
        hand_m = jnp.where(has_empty, hand_seq, victim_seq + 1)

        return {
            "keys": jnp.where(hit, keys, keys_m),
            "vis": jnp.where(hit, vis.at[i].set(True), vis_m),
            "seq": jnp.where(hit, seq, seq_m),
            "hand_seq": jnp.where(hit, hand_seq, hand_m),
            "ctr": jnp.where(hit, ctr, ctr + 1),
        }, step_info(hit, req,
                     evicted_key=jnp.where(has_empty, EMPTY, keys[victim]))


class TwoQ(Policy):
    """Full 2Q: A1in FIFO (``K/4``), A1out ghost keys (``K/2``), Am LRU
    (the rest); a ghost hit promotes straight into Am.

    >>> from repro.core import Engine
    >>> int(Engine().replay("twoq", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    2
    """

    name = "twoq"

    def init(self, K: int) -> dict:
        kin = max(1, K // 4)
        kout = max(1, K // 2)
        km = max(1, K - kin)
        return {
            "in_keys": jnp.full((kin,), EMPTY, jnp.int32),
            "in_seq": jnp.full((kin,), -1, jnp.int32),
            "out_keys": jnp.full((kout,), EMPTY, jnp.int32),
            "out_seq": jnp.full((kout,), -1, jnp.int32),
            "am_keys": jnp.full((km,), EMPTY, jnp.int32),
            "am_last": jnp.full((km,), -1, jnp.int32),
            "t": jnp.int32(0),
        }

    def step(self, state, req: Request):
        key = req.key
        s = dict(state)
        t = s["t"]
        in_am, i_am = find(s["am_keys"], key)
        in_a1, _ = find(s["in_keys"], key)
        in_out, i_out = find(s["out_keys"], key)
        hit = in_am | in_a1

        # hit in Am: refresh recency
        am_last_h = s["am_last"].at[i_am].set(t)

        # miss, reclaimed from A1out: remove ghost, insert into Am (evict LRU)
        out_keys_r = s["out_keys"].at[i_out].set(EMPTY)
        out_seq_r = s["out_seq"].at[i_out].set(-1)
        am_slot = jnp.argmin(s["am_last"]).astype(jnp.int32)
        am_evicted = s["am_keys"][am_slot]       # EMPTY while Am has room
        am_keys_r = s["am_keys"].at[am_slot].set(key)
        am_last_r = s["am_last"].at[am_slot].set(t)

        # cold miss: insert into A1in; displaced A1in LRU goes to A1out ghost
        in_has_empty, in_e = _first_empty(s["in_keys"])
        in_v = jnp.argmin(s["in_seq"]).astype(jnp.int32)
        in_slot = jnp.where(in_has_empty, in_e, in_v)
        displaced = s["in_keys"][in_slot]  # EMPTY if there was room
        in_keys_c = s["in_keys"].at[in_slot].set(key)
        in_seq_c = s["in_seq"].at[in_slot].set(t)
        out_has_empty, out_e = _first_empty(s["out_keys"])
        out_v = jnp.argmin(s["out_seq"]).astype(jnp.int32)
        out_slot = jnp.where(out_has_empty, out_e, out_v)
        push_ghost = displaced != EMPTY
        out_keys_c = jnp.where(push_ghost,
                               s["out_keys"].at[out_slot].set(displaced),
                               s["out_keys"])
        out_seq_c = jnp.where(push_ghost,
                              s["out_seq"].at[out_slot].set(t), s["out_seq"])

        reclaim = (~hit) & in_out
        cold = (~hit) & (~in_out)
        # residency = A1in ∪ Am; a displaced A1in entry becomes a ghost, so
        # it leaves residency and counts as evicted
        evicted = jnp.where(reclaim, am_evicted,
                            jnp.where(cold, displaced, EMPTY))
        return {
            "in_keys": jnp.where(cold, in_keys_c, s["in_keys"]),
            "in_seq": jnp.where(cold, in_seq_c, s["in_seq"]),
            "out_keys": jnp.where(reclaim, out_keys_r,
                                  jnp.where(cold, out_keys_c, s["out_keys"])),
            "out_seq": jnp.where(reclaim, out_seq_r,
                                 jnp.where(cold, out_seq_c, s["out_seq"])),
            "am_keys": jnp.where(reclaim, am_keys_r, s["am_keys"]),
            "am_last": jnp.where(in_am, am_last_h,
                                 jnp.where(reclaim, am_last_r, s["am_last"])),
            "t": t + 1,
        }, step_info(hit, req, evicted_key=evicted)


class ARC(Policy):
    """Adaptive Replacement Cache (Megiddo & Modha 2003, Fig. 4): T1/T2
    with B1/B2 ghost lists and the adaptive target ``p``.

    >>> from repro.core import Engine
    >>> int(Engine().replay("arc", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    3
    """

    name = "arc"

    def init(self, K: int) -> dict:
        def lst():
            return (jnp.full((K,), EMPTY, jnp.int32),
                    jnp.full((K,), -1, jnp.int32))

        t1k, t1t = lst()
        t2k, t2t = lst()
        b1k, b1t = lst()
        b2k, b2t = lst()
        return {
            "t1k": t1k, "t1t": t1t, "t2k": t2k, "t2t": t2t,
            "b1k": b1k, "b1t": b1t, "b2k": b2k, "b2t": b2t,
            "p": jnp.int32(0), "t": jnp.int32(0),
        }

    @staticmethod
    def _size(keys):
        return jnp.sum(keys != EMPTY).astype(jnp.int32)

    @staticmethod
    def _del_lru(keys, ts):
        """Remove LRU entry; returns (keys, ts, removed_key)."""
        masked = jnp.where(keys == EMPTY, INF32, ts)
        v = jnp.argmin(masked).astype(jnp.int32)
        nonempty = jnp.any(keys != EMPTY)
        removed = jnp.where(nonempty, keys[v], EMPTY)
        keys = jnp.where(nonempty, keys.at[v].set(EMPTY), keys)
        ts = jnp.where(nonempty, ts.at[v].set(-1), ts)
        return keys, ts, removed

    @staticmethod
    def _ins_mru(keys, ts, key, t):
        has_empty, e = _first_empty(keys)
        masked = jnp.where(keys == EMPTY, INF32, ts)
        v = jnp.argmin(masked).astype(jnp.int32)  # overwrite LRU if full
        slot = jnp.where(has_empty, e, v)
        return keys.at[slot].set(key), ts.at[slot].set(t)

    @staticmethod
    def _remove(keys, ts, i):
        return keys.at[i].set(EMPTY), ts.at[i].set(-1)

    def _replace(self, s, in_b2, t):
        """ARC's REPLACE: demote from T1 or T2 into its ghost list.
        Returns (state, demoted_key) — the key that left residency
        (EMPTY if both lists were empty)."""
        n1 = self._size(s["t1k"])
        use_t1 = (n1 >= 1) & ((in_b2 & (n1 == s["p"])) | (n1 > s["p"]))
        # guard: if chosen list is empty, fall back to the other
        use_t1 = jnp.where(self._size(s["t2k"]) == 0, True, use_t1)
        use_t1 = jnp.where(n1 == 0, False, use_t1)

        t1k, t1t, mov1 = self._del_lru(s["t1k"], s["t1t"])
        b1k, b1t = self._ins_mru(s["b1k"], s["b1t"], mov1, t)
        t2k, t2t, mov2 = self._del_lru(s["t2k"], s["t2t"])
        b2k, b2t = self._ins_mru(s["b2k"], s["b2t"], mov2, t)

        out = dict(s)
        out["t1k"] = jnp.where(use_t1, t1k, s["t1k"])
        out["t1t"] = jnp.where(use_t1, t1t, s["t1t"])
        out["b1k"] = jnp.where(use_t1 & (mov1 != EMPTY), b1k, s["b1k"])
        out["b1t"] = jnp.where(use_t1 & (mov1 != EMPTY), b1t, s["b1t"])
        out["t2k"] = jnp.where(use_t1, s["t2k"], t2k)
        out["t2t"] = jnp.where(use_t1, s["t2t"], t2t)
        out["b2k"] = jnp.where(use_t1 | (mov2 == EMPTY), s["b2k"], b2k)
        out["b2t"] = jnp.where(use_t1 | (mov2 == EMPTY), s["b2t"], b2t)
        return out, jnp.where(use_t1, mov1, mov2)

    def step(self, state, req: Request):
        key = req.key
        s = dict(state)
        t = s["t"]
        K = s["t1k"].shape[0]
        in_t1, i_t1 = find(s["t1k"], key)
        in_t2, i_t2 = find(s["t2k"], key)
        in_b1, i_b1 = find(s["b1k"], key)
        in_b2, i_b2 = find(s["b2k"], key)
        hit = in_t1 | in_t2

        # ---- Case I: hit in T1 or T2 -> move to MRU of T2 ----
        s1 = dict(s)
        t1k, t1t = self._remove(s["t1k"], s["t1t"], i_t1)
        s1["t1k"] = jnp.where(in_t1, t1k, s["t1k"])
        s1["t1t"] = jnp.where(in_t1, t1t, s["t1t"])
        t2k_h, t2t_h = self._remove(s1["t2k"], s1["t2t"], i_t2)
        t2k_h = jnp.where(in_t2, t2k_h, s1["t2k"])
        t2t_h = jnp.where(in_t2, t2t_h, s1["t2t"])
        s1["t2k"], s1["t2t"] = self._ins_mru(t2k_h, t2t_h, key, t)

        # ---- Case II: ghost hit in B1 ----
        # NOTE: the ghost entry is removed BEFORE calling REPLACE.  REPLACE
        # never inspects ghost membership, so this is semantics-preserving,
        # and it keeps |B1| <= K (Fig. 4's order would transiently need K+1
        # slots when the ghost list is full).  The oracle does the same.
        n_b1 = self._size(s["b1k"])
        n_b2 = self._size(s["b2k"])
        delta1 = jnp.maximum(1, n_b2 // jnp.maximum(n_b1, 1))
        p2 = jnp.minimum(s["p"] + delta1, K)
        s2 = dict(s)
        s2["p"] = p2
        s2["b1k"], s2["b1t"] = self._remove(s2["b1k"], s2["b1t"], i_b1)
        s2, ev2 = self._replace(s2, jnp.bool_(False), t)
        s2["t2k"], s2["t2t"] = self._ins_mru(s2["t2k"], s2["t2t"], key, t)

        # ---- Case III: ghost hit in B2 ----
        delta2 = jnp.maximum(1, n_b1 // jnp.maximum(n_b2, 1))
        p3 = jnp.maximum(s["p"] - delta2, 0)
        s3 = dict(s)
        s3["p"] = p3
        s3["b2k"], s3["b2t"] = self._remove(s3["b2k"], s3["b2t"], i_b2)
        s3, ev3 = self._replace(s3, jnp.bool_(True), t)
        s3["t2k"], s3["t2t"] = self._ins_mru(s3["t2k"], s3["t2t"], key, t)

        # ---- Case IV: true miss ----
        n_t1 = self._size(s["t1k"])
        n_t2 = self._size(s["t2k"])
        L1 = n_t1 + n_b1
        total = n_t1 + n_t2 + n_b1 + n_b2
        s4 = dict(s)
        # branch A: L1 == K
        sA = dict(s4)
        # A1: |T1| < K -> delete LRU of B1, REPLACE
        sA1 = dict(sA)
        sA1["b1k"], sA1["b1t"], _ = self._del_lru(sA["b1k"], sA["b1t"])
        sA1, evA1 = self._replace(sA1, jnp.bool_(False), t)
        # A2: |T1| == K -> delete LRU of T1 outright
        sA2 = dict(sA)
        sA2["t1k"], sA2["t1t"], evA2 = self._del_lru(sA["t1k"], sA["t1t"])
        condA1 = n_t1 < K
        sA = {k: jnp.where(condA1, sA1[k], sA2[k]) for k in sA}
        evA = jnp.where(condA1, evA1, evA2)
        # branch B: L1 < K and total >= K
        sB = dict(s4)
        sB1 = dict(sB)
        sB1["b2k"], sB1["b2t"], _ = self._del_lru(sB["b2k"], sB["b2t"])
        condB1 = total == 2 * K
        sB = {k: jnp.where(condB1, sB1[k], sB[k]) for k in sB}
        sB, evB = self._replace(sB, jnp.bool_(False), t)
        condA = L1 == K
        condB = (L1 < K) & (total >= K)
        s4 = {k: jnp.where(condA, sA[k], jnp.where(condB, sB[k], s4[k]))
              for k in s4}
        ev4 = jnp.where(condA, evA, jnp.where(condB, evB, EMPTY))
        s4["t1k"], s4["t1t"] = self._ins_mru(s4["t1k"], s4["t1t"], key, t)

        out = {}
        for k in s:
            out[k] = jnp.where(
                hit, s1[k],
                jnp.where(in_b1, s2[k], jnp.where(in_b2, s3[k], s4[k])))
        out["t"] = t + 1
        evicted = jnp.where(in_b1, ev2, jnp.where(in_b2, ev3, ev4))
        return out, step_info(hit, req, evicted_key=evicted)


class TinyLFU(Policy):
    """LRU eviction + count-min-sketch admission filter with periodic
    halving (window ``window_factor * K``).

    >>> from repro.core import Engine
    >>> int(Engine().replay("tinylfu", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    4
    """

    name = "tinylfu"

    def __init__(self, rows: int = 4, width_factor: int = 16,
                 window_factor: int = 8):
        self.rows = int(rows)
        self.width_factor = int(width_factor)
        self.window_factor = int(window_factor)

    def _width(self, K):
        w = 1
        while w < K * self.width_factor:
            w *= 2
        return w

    def init(self, K: int) -> dict:
        W = self._width(K)
        return {
            "keys": jnp.full((K,), EMPTY, jnp.int32),
            "last": jnp.full((K,), -1, jnp.int32),
            "sketch": jnp.zeros((self.rows, W), jnp.int32),
            "adds": jnp.int32(0),
            "t": jnp.int32(0),
        }

    def _hash(self, key, W):
        # multiply-shift with fixed odd constants per row
        a = jnp.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F][: self.rows],
                      dtype=jnp.uint32)
        x = (key.astype(jnp.uint32) + 1) * a
        x = x ^ (x >> 15)
        return (x & jnp.uint32(W - 1)).astype(jnp.int32)

    def _estimate(self, sketch, key):
        W = sketch.shape[1]
        h = self._hash(key, W)
        vals = sketch[jnp.arange(self.rows), h]
        return jnp.min(vals)

    def step(self, state, req: Request):
        key = req.key
        keys, last, sketch = state["keys"], state["last"], state["sketch"]
        adds, t = state["adds"], state["t"]
        K = keys.shape[0]
        W = sketch.shape[1]
        hit, i = find(keys, key)

        # count every request in the sketch; halve when window expires
        h = self._hash(key, W)
        sketch = sketch.at[jnp.arange(self.rows), h].add(1)
        adds = adds + 1
        expire = adds >= self.window_factor * K
        sketch = jnp.where(expire, sketch // 2, sketch)
        adds = jnp.where(expire, 0, adds)

        has_empty, e = _first_empty(keys)
        v = jnp.argmin(last).astype(jnp.int32)
        victim_key = keys[v]
        admit = has_empty | (self._estimate(sketch, key) >
                             self._estimate(sketch, victim_key))
        slot = jnp.where(has_empty, e, v)

        keys_m = jnp.where(admit, keys.at[slot].set(key), keys)
        last_m = jnp.where(admit, last.at[slot].set(t), last)
        # a rejected candidate evicts nothing (the admission filter bounces
        # the request, the victim stays resident)
        evicted = jnp.where(admit & ~has_empty, victim_key, EMPTY)
        return {
            "keys": jnp.where(hit, keys, keys_m),
            "last": jnp.where(hit, last.at[i].set(t), last_m),
            "sketch": sketch, "adds": adds, "t": t + 1,
        }, step_info(hit, req, evicted_key=evicted)


class Hyperbolic(Policy):
    """Hyperbolic caching: evict the minimum frequency/age priority
    (exact, unsampled).

    >>> from repro.core import Engine
    >>> int(Engine().replay("hyperbolic", [0, 1, 0, 2, 0, 1, 2, 0], K=2,
    ...                     collect_info=False).metrics.hits)
    2
    """

    name = "hyperbolic"

    def init(self, K: int) -> dict:
        return {
            "keys": jnp.full((K,), EMPTY, jnp.int32),
            "cnt": jnp.zeros((K,), jnp.int32),
            "ins": jnp.zeros((K,), jnp.int32),
            "t": jnp.int32(0),
        }

    def step(self, state, req: Request):
        key = req.key
        keys, cnt, ins, t = state["keys"], state["cnt"], state["ins"], state["t"]
        hit, i = find(keys, key)
        age = (t - ins + 1).astype(jnp.float32)
        # float32 literal: a weak Python scalar would trace as f64 under x64
        prio = jnp.where(keys == EMPTY, jnp.float32(-jnp.inf),
                         cnt.astype(jnp.float32) / age)
        v = jnp.argmin(prio).astype(jnp.int32)
        evicted = keys[v]
        keys_m = keys.at[v].set(key)
        cnt_m = cnt.at[v].set(1)
        ins_m = ins.at[v].set(t)
        return {
            "keys": jnp.where(hit, keys, keys_m),
            "cnt": jnp.where(hit, cnt.at[i].add(1), cnt_m),
            "ins": jnp.where(hit, ins, ins_m),
            "t": t + 1,
        }, step_info(hit, req, evicted_key=evicted)
