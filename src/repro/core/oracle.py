"""Literal step-by-step Python reference implementations of every policy.

These follow the paper pseudocode / original-paper formulations as directly
as possible (lists, while-loops, pointer walks) and serve as the oracle for
the vectorized JAX implementations: for any trace, the per-request hit
sequence must match exactly.  Also provides Belady's OPT for reference
curves.

Conventions shared with the JAX side (so hit sequences are comparable):
  * keys are ints >= 0; -1 is the EMPTY sentinel.
  * tie-breaks: lowest slot index / first minimum.
  * Hyperbolic priorities computed in float32 (matching the TPU arithmetic).

The oracles deliberately keep the scalar ``step(key) -> hit`` shape: they
validate replacement *decisions*, which are size/cost-oblivious for every
policy here.  ``oracle_replay`` lifts an oracle over a trace (optionally
with per-request sizes/costs) into the same (hits, bytes_missed, penalty)
aggregates the JAX engine reports, so engine metrics are checkable
end-to-end against plain Python.
"""
from __future__ import annotations

import math

import numpy as np

EMPTY = -1


def oracle_replay(name: str, trace, K: int, sizes=None, costs=None, **kw):
    """Replay `trace` through oracle `name`; returns a dict with the hit
    mask plus the engine's aggregate metrics computed in plain Python."""
    oracle = ORACLES[name](K, **kw)
    trace = np.asarray(trace)
    hits = np.array([oracle.step(int(k)) for k in trace], dtype=bool)
    sizes = np.ones(len(trace)) if sizes is None else np.asarray(sizes)
    costs = np.ones(len(trace)) if costs is None else np.asarray(costs)
    total = sizes.sum()
    return {
        "hits": hits,
        "miss_ratio": float((~hits).mean()) if len(trace) else 0.0,
        "byte_miss_ratio": (float(((~hits) * sizes).sum() / total)
                            if total > 0 else 0.0),
        "penalty": float(((~hits) * costs).sum()),
    }


class OracleAdaptiveClimb:
    """Algorithm 1, on an actual ordered list (index 0 = top)."""

    def __init__(self, K: int):
        self.K = K
        self.cache = [EMPTY] * K
        self.jump = K

    def step(self, key: int) -> bool:
        K = self.K
        if key in self.cache:
            i = self.cache.index(key)
            self.jump = max(self.jump - 1, 1)
            t = max(i - self.jump, 0)
            self.cache.pop(i)
            self.cache.insert(t, key)
            return True
        self.jump = min(self.jump + 1, K)
        self.cache.pop()  # evict bottom
        self.cache.insert(K - self.jump, key)
        return False


class OracleDynamicAdaptiveClimb:
    """Algorithm 2 with the interpretation choices documented in
    dynamicadaptiveclimb.py (resize checks after every request, <= threshold,
    clamp+reset after resize)."""

    def __init__(self, K: int, eps: float = 0.5, growth: int = 4,
                 k_min: int = 2):
        self.K_max = K * growth
        self.k = K
        self.eps = eps
        self.k_min = k_min
        self.cache = [EMPTY] * K
        self.jump = K
        self.jump2 = 0

    def step(self, key: int) -> bool:
        k = self.k
        half = k // 2
        hit = key in self.cache
        if hit:
            i = self.cache.index(key)
            if self.jump > -half:
                self.jump -= 1
            if i < half:
                if self.jump2 > -half:
                    self.jump2 -= 1
            else:
                if self.jump2 < 0:
                    self.jump2 += 1
            actual = max(1, min(self.jump, i))
            if i > 0:
                t = i - actual
                self.cache.pop(i)
                self.cache.insert(t, key)
        else:
            self.jump = min(self.jump + 1, 2 * k)
            if self.jump2 < 0:
                self.jump2 += 1
            actual = max(1, min(k - 1, self.jump))
            self.cache.pop()  # evict rank k-1
            self.cache.insert(k - actual, key)

        # resize checks
        if self.jump == 0:
            self.jump2 = 0
        half = self.k // 2
        shrink_thresh = -math.ceil(self.eps * half)
        if self.jump >= 2 * self.k and 2 * self.k <= self.K_max:
            self.cache = self.cache + [EMPTY] * self.k
            self.k = 2 * self.k
            self.jump = max(min(self.jump, 2 * self.k), -(self.k // 2))
            self.jump2 = 0
        elif (self.jump <= -half and self.jump2 <= shrink_thresh
              and half >= self.k_min):
            self.cache = self.cache[:half]
            self.k = half
            self.jump = 0  # neutral restart (see dynamicadaptiveclimb.py)
            self.jump2 = 0
        return hit


class OracleFIFO:
    def __init__(self, K: int):
        self.keys = [EMPTY] * K
        self.head = 0
        self.K = K

    def step(self, key: int) -> bool:
        if key in self.keys:
            return True
        self.keys[self.head] = key
        self.head = (self.head + 1) % self.K
        return False


class OracleLRU:
    def __init__(self, K: int):
        self.keys = [EMPTY] * K
        self.last = [-1] * K
        self.t = 0

    def step(self, key: int) -> bool:
        hit = key in self.keys
        if hit:
            i = self.keys.index(key)
        else:
            i = self.last.index(min(self.last))
            self.keys[i] = key
        self.last[i] = self.t
        self.t += 1
        return hit


class OracleBLRU:
    def __init__(self, K: int, lag_div: int = 8):
        self.keys = [EMPTY] * K
        self.last = [-1] * K
        self.t = 0
        self.lag = max(1, K // lag_div)

    def step(self, key: int) -> bool:
        hit = key in self.keys
        if hit:
            i = self.keys.index(key)
            if self.t - self.last[i] > self.lag:
                self.last[i] = self.t
        else:
            i = self.last.index(min(self.last))
            self.keys[i] = key
            self.last[i] = self.t
        self.t += 1
        return hit


class OracleClimb:
    def __init__(self, K: int):
        self.cache = [EMPTY] * K

    def step(self, key: int) -> bool:
        if key in self.cache:
            i = self.cache.index(key)
            if i > 0:
                self.cache[i], self.cache[i - 1] = \
                    self.cache[i - 1], self.cache[i]
            return True
        self.cache[-1] = key
        return False


class OracleLFU:
    def __init__(self, K: int):
        self.keys = [EMPTY] * K
        self.cnt = [0] * K

    def step(self, key: int) -> bool:
        hit = key in self.keys
        if hit:
            i = self.keys.index(key)
            self.cnt[i] += 1
        else:
            i = self.cnt.index(min(self.cnt))
            self.keys[i] = key
            self.cnt[i] = 1
        return hit


class OracleClock:
    def __init__(self, K: int):
        self.keys = [EMPTY] * K
        self.ref = [False] * K
        self.hand = 0
        self.K = K

    def step(self, key: int) -> bool:
        if key in self.keys:
            self.ref[self.keys.index(key)] = True
            return True
        for _ in range(2 * self.K + 1):
            if self.keys[self.hand] == EMPTY or not self.ref[self.hand]:
                break
            self.ref[self.hand] = False
            self.hand = (self.hand + 1) % self.K
        victim = self.hand
        self.keys[victim] = key
        self.ref[victim] = False
        self.hand = (victim + 1) % self.K
        return False


class OracleSieve:
    """SIEVE with an explicit seq-ordered walk (hand: oldest -> newest)."""

    def __init__(self, K: int):
        self.K = K
        self.entries = {}  # key -> [seq, visited]
        self.hand_seq = 0
        self.ctr = 0

    def step(self, key: int) -> bool:
        if key in self.entries:
            self.entries[key][1] = True
            return True
        if len(self.entries) == self.K:
            # walk from the oldest seq >= hand_seq toward newer, wrapping
            ordered = sorted(self.entries.items(), key=lambda kv: kv[1][0])
            seqs = [kv[1][0] for kv in ordered]
            start = 0
            while start < len(seqs) and seqs[start] < self.hand_seq:
                start += 1
            order = list(range(start, len(seqs))) + list(range(0, start))
            victim = None
            for idx in order + order:  # at most two passes
                k2, (s2, v2) = ordered[idx]
                if not self.entries[k2][1]:
                    victim = k2
                    break
                self.entries[k2][1] = False
            assert victim is not None
            victim_seq = self.entries[victim][0]
            del self.entries[victim]
            self.hand_seq = victim_seq + 1
        self.entries[key] = [self.ctr, False]
        self.ctr += 1
        return False


class OracleTwoQ:
    def __init__(self, K: int):
        self.kin = max(1, K // 4)
        self.kout = max(1, K // 2)
        self.km = max(1, K - self.kin)
        self.a1in = []   # FIFO, oldest first
        self.a1out = []  # ghost FIFO, oldest first
        self.am = []     # LRU, oldest first

    def step(self, key: int) -> bool:
        if key in self.am:
            self.am.remove(key)
            self.am.append(key)
            return True
        if key in self.a1in:
            return True
        if key in self.a1out:
            self.a1out.remove(key)
            if len(self.am) == self.km:
                self.am.pop(0)
            self.am.append(key)
            return False
        if len(self.a1in) == self.kin:
            displaced = self.a1in.pop(0)
            if len(self.a1out) == self.kout:
                self.a1out.pop(0)
            self.a1out.append(displaced)
        self.a1in.append(key)
        return False


class OracleARC:
    """Megiddo & Modha 2003 Fig. 4 with integer-valued p."""

    def __init__(self, K: int):
        self.K = K
        self.t1, self.t2, self.b1, self.b2 = [], [], [], []  # oldest first
        self.p = 0

    def _replace(self, in_b2: bool):
        if self.t1 and ((in_b2 and len(self.t1) == self.p)
                        or len(self.t1) > self.p or not self.t2):
            old = self.t1.pop(0)
            self.b1.append(old)
        elif self.t2:
            old = self.t2.pop(0)
            self.b2.append(old)

    def step(self, key: int) -> bool:
        K = self.K
        if key in self.t1:
            self.t1.remove(key)
            self.t2.append(key)
            return True
        if key in self.t2:
            self.t2.remove(key)
            self.t2.append(key)
            return True
        if key in self.b1:
            # ghost removed before REPLACE (see baselines.ARC for rationale)
            self.p = min(self.p + max(1, len(self.b2) // max(len(self.b1), 1)), K)
            self.b1.remove(key)
            self._replace(False)
            self.t2.append(key)
            return False
        if key in self.b2:
            self.p = max(self.p - max(1, len(self.b1) // max(len(self.b2), 1)), 0)
            self.b2.remove(key)
            self._replace(True)
            self.t2.append(key)
            return False
        L1 = len(self.t1) + len(self.b1)
        total = L1 + len(self.t2) + len(self.b2)
        if L1 == K:
            if len(self.t1) < K:
                self.b1.pop(0)
                self._replace(False)
            else:
                self.t1.pop(0)
        elif L1 < K and total >= K:
            if total == 2 * K:
                self.b2.pop(0)
            self._replace(False)
        self.t1.append(key)
        return False


class OracleTinyLFU:
    def __init__(self, K: int, rows: int = 4, width_factor: int = 16,
                 window_factor: int = 8):
        self.K = K
        self.rows = rows
        W = 1
        while W < K * width_factor:
            W *= 2
        self.W = W
        self.window = window_factor * K
        self.sketch = np.zeros((rows, W), dtype=np.int64)
        self.adds = 0
        self.keys = [EMPTY] * K
        self.last = [-1] * K
        self.t = 0

    def _hash(self, key: int):
        consts = [0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F][: self.rows]
        out = []
        for a in consts:
            x = ((key + 1) * a) & 0xFFFFFFFF
            x = (x ^ (x >> 15)) & 0xFFFFFFFF
            out.append(x & (self.W - 1))
        return out

    def _estimate(self, key: int) -> int:
        if key == EMPTY:
            return int(min(self.sketch[r, h]
                           for r, h in enumerate(self._hash(key))))
        return int(min(self.sketch[r, h]
                       for r, h in enumerate(self._hash(key))))

    def step(self, key: int) -> bool:
        hit = key in self.keys
        for r, h in enumerate(self._hash(key)):
            self.sketch[r, h] += 1
        self.adds += 1
        if self.adds >= self.window:
            self.sketch //= 2
            self.adds = 0
        if hit:
            i = self.keys.index(key)
            self.last[i] = self.t
        else:
            if EMPTY in self.keys:
                i = self.keys.index(EMPTY)
                admit = True
            else:
                i = self.last.index(min(self.last))
                admit = self._estimate(key) > self._estimate(self.keys[i])
            if admit:
                self.keys[i] = key
                self.last[i] = self.t
        self.t += 1
        return hit


class OracleHyperbolic:
    def __init__(self, K: int):
        self.keys = [EMPTY] * K
        self.cnt = [0] * K
        self.ins = [0] * K
        self.t = 0

    def step(self, key: int) -> bool:
        hit = key in self.keys
        if hit:
            self.cnt[self.keys.index(key)] += 1
        else:
            prio = [
                -np.inf if k == EMPTY else
                np.float32(np.float32(c) / np.float32(self.t - s + 1))
                for k, c, s in zip(self.keys, self.cnt, self.ins)
            ]
            i = int(np.argmin(np.array(prio, dtype=np.float32)))
            self.keys[i] = key
            self.cnt[i] = 1
            self.ins[i] = self.t
        self.t += 1
        return hit


def belady_opt(trace: np.ndarray, K: int) -> np.ndarray:
    """Belady's optimal offline policy; returns the per-request hit mask."""
    T = len(trace)
    nxt = np.full(T, np.iinfo(np.int64).max, dtype=np.int64)
    last_pos: dict = {}
    for i in range(T - 1, -1, -1):
        k = int(trace[i])
        nxt[i] = last_pos.get(k, np.iinfo(np.int64).max)
        last_pos[k] = i
    cache: dict = {}  # key -> next use position
    hits = np.zeros(T, dtype=bool)
    for i, k in enumerate(trace):
        k = int(k)
        if k in cache:
            hits[i] = True
        elif len(cache) == K:
            victim = max(cache, key=lambda q: cache[q])
            del cache[victim]
        cache[k] = nxt[i]
    return hits


ORACLES = {
    "adaptiveclimb": OracleAdaptiveClimb,
    "dynamicadaptiveclimb": OracleDynamicAdaptiveClimb,
    "fifo": OracleFIFO,
    "lru": OracleLRU,
    "blru": OracleBLRU,
    "climb": OracleClimb,
    "lfu": OracleLFU,
    "clock": OracleClock,
    "sieve": OracleSieve,
    "twoq": OracleTwoQ,
    "arc": OracleARC,
    "tinylfu": OracleTinyLFU,
    "hyperbolic": OracleHyperbolic,
}


class OracleLIRS:
    """Timestamp-formulation LIRS mirroring core.lirs_lhd.LIRS exactly."""

    def __init__(self, K: int, hir_frac: float = 0.01,
                 ghost_factor: int = 2):
        self.K = K
        self.k_hir = max(1, int(K * hir_frac))
        self.k_lir = K - self.k_hir
        self.G = ghost_factor * K
        self.t = 0
        # key -> [t_last, state]  (state in {"LIR","HIR","GHOST"})
        self.tbl: dict = {}

    def _min_lir_t(self):
        ts = [v[0] for v in self.tbl.values() if v[1] == "LIR"]
        return min(ts) if ts else -1

    def _lru(self, state):
        cands = [(v[0], k) for k, v in self.tbl.items() if v[1] == state]
        return min(cands)[1] if cands else None

    def step(self, key: int) -> bool:
        self.t += 1
        t = self.t
        ent = self.tbl.get(key)
        cur = ent[1] if ent else None
        n_lir = sum(1 for v in self.tbl.values() if v[1] == "LIR")
        min_lir = self._min_lir_t()
        in_stack = ent is not None and ent[0] >= min_lir

        if cur == "LIR":
            ent[0] = t
            return True
        if cur == "HIR":
            if in_stack and n_lir > 0:
                bottom = self._lru("LIR")
                self.tbl[bottom][1] = "HIR"
                ent[1] = "LIR"
            ent[0] = t
            return True

        # miss ----------------------------------------------------------
        n_res = sum(1 for v in self.tbl.values() if v[1] in ("LIR", "HIR"))
        if n_res >= self.K:
            hir_lru = self._lru("HIR")
            if hir_lru is not None:
                self.tbl[hir_lru][1] = "GHOST"
            else:                      # unreachable after warmup
                del self.tbl[self._lru("LIR")]
        n_ghost = sum(1 for v in self.tbl.values() if v[1] == "GHOST")
        if n_ghost > self.G:
            dropped = self._lru("GHOST")
            del self.tbl[dropped]
            if dropped == key:
                ent = None   # its ghost entry is gone, but flags captured
        was_ghost = cur == "GHOST"
        promote = was_ghost and in_stack and n_lir >= self.k_lir
        new_state = "LIR" if (n_lir < self.k_lir or promote) else "HIR"
        if promote:
            bottom = self._lru("LIR")
            self.tbl[bottom][1] = "HIR"
        self.tbl[key] = [t, new_state]
        return False


class OracleLHD:
    """Binned-age LHD mirroring core.lirs_lhd.LHD exactly (f32 math)."""

    def __init__(self, K: int, n_bins: int = 16,
                 decay_every_factor: int = 4):
        self.K = K
        self.n_bins = n_bins
        self.decay_every = decay_every_factor * K
        self.keys = np.full(K, EMPTY, np.int64)
        self.t_ins = np.full(K, -1, np.int64)
        self.hits = np.zeros(n_bins, np.int64)
        self.evs = np.zeros(n_bins, np.int64)
        self.t = 0

    def _bin(self, age):
        a = max(int(age), 0) + 1
        b = sum(1 for j in range(1, self.n_bins) if a >= 2 ** j)
        return min(b, self.n_bins - 1)

    def step(self, key: int) -> bool:
        self.t += 1
        t = self.t
        matches = np.nonzero(self.keys == key)[0]
        hit = matches.size > 0
        if hit:
            i = int(matches[0])
            self.hits[self._bin(t - self.t_ins[i])] += 1
            self.t_ins[i] = t
        else:
            num = self.hits.astype(np.float32)
            den = ((self.hits + self.evs + 1).astype(np.float32)
                   * np.exp2(np.arange(self.n_bins, dtype=np.float32)))
            hd = num / den
            slot_hd = np.array(
                [np.float32(-1.0) if self.keys[s] == EMPTY
                 else hd[self._bin(t - self.t_ins[s])]
                 for s in range(self.K)], np.float32)
            v = int(np.argmin(slot_hd))
            if self.keys[v] != EMPTY:
                self.evs[self._bin(t - self.t_ins[v])] += 1
            self.keys[v] = key
            self.t_ins[v] = t
        if t % self.decay_every == 0:
            self.hits //= 2
            self.evs //= 2
        return hit


ORACLES["lirs"] = OracleLIRS
ORACLES["lhd"] = OracleLHD
