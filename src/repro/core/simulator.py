"""Vectorized trace-replay engine.

One policy step is O(K) vector lanes; a trace replays under ``lax.scan``;
independent caches (different traces, seeds, or cache sizes) batch under
``vmap``; fleet-scale studies shard the batch over the device mesh with
``shard_map``.  This replaces the paper's libCacheSim + thread-replay setup
with a single SPMD program.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .policy import Policy


@partial(jax.jit, static_argnames=("policy", "K"))
def replay(policy: Policy, trace: jax.Array, K: int) -> jax.Array:
    """Replay one trace; returns the bool hit mask (shape [T])."""
    state = policy.init(K)

    def body(st, key):
        st, hit = policy.step(st, key)
        return st, hit

    _, hits = jax.lax.scan(body, state, trace)
    return hits


@partial(jax.jit, static_argnames=("policy", "K"))
def replay_batch(policy: Policy, traces: jax.Array, K: int) -> jax.Array:
    """Replay a batch of traces [B, T] -> hit masks [B, T]."""
    return jax.vmap(lambda tr: replay(policy, tr, K))(traces)


@partial(jax.jit, static_argnames=("policy", "K"))
def replay_observed(policy: Policy, trace: jax.Array, K: int):
    """Replay collecting per-step policy observables (e.g. DAC's k, jump)."""
    state = policy.init(K)

    def body(st, key):
        st, hit = policy.step(st, key)
        obs = policy.observables(st) if hasattr(policy, "observables") else {}
        return st, (hit, obs)

    _, (hits, obs) = jax.lax.scan(body, state, trace)
    return hits, obs


def replay_sharded(policy: Policy, traces: np.ndarray, K: int,
                   mesh: Mesh, axis: str = "data") -> jax.Array:
    """Shard a [B, T] trace batch over `axis` of `mesh` and replay SPMD.

    Each device replays B/axis_size independent caches — the TPU-native
    version of the paper's multi-threaded trace replay (Tables IV/V).
    """
    sharding = NamedSharding(mesh, P(axis, None))
    traces = jax.device_put(jnp.asarray(traces), sharding)
    fn = jax.jit(
        lambda tr: jax.vmap(lambda t: replay(policy, t, K))(tr),
        in_shardings=sharding,
        out_shardings=sharding,
    )
    return fn(traces)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def miss_ratio(hits) -> float:
    return float(1.0 - np.asarray(hits, dtype=np.float64).mean())


def mrr(mr_algo: float, mr_fifo: float) -> float:
    """Miss-ratio reduction relative to FIFO (paper's signed definition)."""
    if mr_algo <= mr_fifo:
        return (mr_fifo - mr_algo) / mr_fifo if mr_fifo > 0 else 0.0
    return (mr_fifo - mr_algo) / mr_algo if mr_algo > 0 else 0.0
