"""Unified vectorized trace-replay engine.

One policy step is O(K) vector lanes; a trace replays under ``lax.scan``;
independent caches (different traces, seeds, or cache sizes) batch under
``vmap``; fleet-scale studies shard the batch over the device mesh.  This
replaces the paper's libCacheSim + thread-replay setup with a single SPMD
program, and the former ``replay`` / ``replay_batch`` / ``replay_observed``
/ ``replay_sharded`` quartet with one entrypoint::

    result = Engine().replay(policy, requests, K)

``requests`` is a :class:`~repro.core.policy.Request` pytree (or a bare key
array — coerced with unit size/cost) of shape ``[T]`` or ``[B, T]``; pass
``mesh=`` to spread a ``[B, T]`` batch over a device axis, ``observe=True``
to collect per-step policy observables (e.g. DAC's ``k``/``jump``).  Hit,
byte-miss and penalty totals are reduced *inside* the jitted program (per
lane, under vmap/SPMD) — callers read ratios off the result instead of
recomputing them post-hoc from hit masks.

Two scale paths (the paper's Tables IV/V throughput regime):

* ``replay(..., collect_info=False)`` reduces :class:`Metrics` inside the
  scan carry — the jitted program allocates NO ``[T]``-shaped ``StepInfo``
  output, only O(1) totals per lane (``result.info is None``).
* ``replay_stream(...)`` scans arbitrarily long traces in fixed-size
  chunks, donating the policy-state and accumulator buffers between chunks
  and summing per-chunk totals on the host in 64-bit — multi-billion-
  request streams never materialize on device and never wrap int32.  It
  also accepts an *iterator* of request chunks (the out-of-core path for
  file-backed traces, see ``repro.data.ingest``) and reports time-mean
  policy observables under ``observe=True``.

``use_pallas`` (an ``Engine`` or per-call switch) lowers the rank-policy
hot path (find + promote) through the fused Pallas policy-step kernel
(``repro.kernels.policy_step``) instead of plain jnp.  It is three-valued:
``False`` (plain jnp), ``"interpret"`` (the kernel under the Pallas
interpreter — runs anywhere, bit-identical to jnp), and ``"compiled"``
(the real Mosaic/Triton lowering — TPU/GPU).  ``True`` means "kernel with
the per-backend default" (compiled on tpu/gpu, interpreted elsewhere; see
``repro.kernels.policy_step.resolve_interpret``).
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .policy import (Policy, Request, StepInfo, normalize_pallas_mode,
                     pallas_mode)


def _count_dtype():
    """Dtype for request/hit counters: int32 wraps at 2.1e9 requests, so
    widen to int64 whenever x64 is enabled (CPU CI keeps int32; the
    streaming path additionally accumulates on the host in 64-bit)."""
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


class Metrics(NamedTuple):
    """Per-lane replay totals, reduced inside the jitted replay program.
    ``requests``/``hits`` widen to int64 under x64 (multi-billion-request
    streams wrap int32); byte/cost totals accumulate in float32 (object
    sizes in bytes overflow int32 over long traces).

    >>> from repro.core import Engine
    >>> m = Engine().replay("lru", [0, 0, 1], K=2, collect_info=False).metrics
    >>> int(m.requests), int(m.hits), float(m.bytes_missed)
    (3, 1, 2.0)
    """

    requests: jax.Array      # int32/int64 — trace length
    hits: jax.Array          # int32/int64
    bytes_total: jax.Array   # float32 — sum of request sizes
    bytes_missed: jax.Array  # float32 — sum of sizes over misses
    cost_total: jax.Array    # float32 — sum of request costs
    penalty: jax.Array       # float32 — sum of costs over misses


class ReplayResult(NamedTuple):
    """Engine output: per-step ``StepInfo`` (leading dims match the input;
    ``None`` in metrics-only mode), per-lane ``Metrics``, and optional
    stacked observables.

    >>> from repro.core import Engine
    >>> res = Engine().replay("lru", [0, 0, 0, 1], K=2)
    >>> res.hit_ratio, res.miss_ratio
    (0.5, 0.5)
    >>> [bool(h) for h in res.hits]
    [False, True, True, False]
    """

    info: StepInfo | None
    metrics: Metrics
    obs: Any

    # -- conveniences (host-side; float for one lane, ndarray for a batch) --
    @property
    def hits(self):
        if self.info is None:
            raise ValueError(
                "per-step info was not collected (collect_info=False / "
                "replay_stream); read totals off result.metrics instead")
        return self.info.hit

    @property
    def hit_ratio(self):
        return _ratio(self.metrics.hits, self.metrics.requests)

    @property
    def miss_ratio(self):
        m = self.metrics
        return _ratio(np.asarray(m.requests) - np.asarray(m.hits),
                      m.requests)

    @property
    def byte_miss_ratio(self):
        return _ratio(self.metrics.bytes_missed, self.metrics.bytes_total)

    @property
    def penalty_ratio(self):
        """Cost-weighted miss ratio: sum(cost * miss) / sum(cost)."""
        return _ratio(self.metrics.penalty, self.metrics.cost_total)

    @property
    def total_penalty(self):
        out = np.asarray(self.metrics.penalty, dtype=np.float64)
        return float(out) if out.ndim == 0 else out


def _ratio(num, den):
    num = np.asarray(num, dtype=np.float64)
    den = np.asarray(den, dtype=np.float64)
    out = np.divide(num, den, out=np.zeros_like(num), where=den > 0)
    return float(out) if out.ndim == 0 else out


def _zero_acc():
    return Metrics(
        requests=jnp.zeros((), _count_dtype()),
        hits=jnp.zeros((), _count_dtype()),
        bytes_total=jnp.zeros((), jnp.float32),
        bytes_missed=jnp.zeros((), jnp.float32),
        cost_total=jnp.zeros((), jnp.float32),
        penalty=jnp.zeros((), jnp.float32),
    )


def _acc_step(acc: Metrics, req: Request, info: StepInfo) -> Metrics:
    """Fold one request's StepInfo into the running totals (scan carry)."""
    return Metrics(
        requests=acc.requests + 1,
        hits=acc.hits + info.hit.astype(_count_dtype()),
        bytes_total=acc.bytes_total + req.size.astype(jnp.float32),
        bytes_missed=acc.bytes_missed + info.bytes_missed.astype(jnp.float32),
        cost_total=acc.cost_total + req.cost,
        penalty=acc.penalty + info.penalty,
    )


def _scan_replay(policy: Policy, reqs: Request, K: int, observe: bool,
                 collect_info: bool = True,
                 state: Any = None) -> tuple[ReplayResult, Any]:
    """Scan one lane; returns (result, final_state).  With
    ``collect_info=False`` the metrics ride in the scan carry and no
    ``[T]``-shaped StepInfo is ever stacked."""
    if state is None:
        state = policy.init(K)
    want_obs = observe and hasattr(policy, "observables")

    if collect_info:
        def body(st, req):
            st, info = policy.step(st, req)
            obs = policy.observables(st) if want_obs else None
            return st, (info, obs)

        state, (info, obs) = jax.lax.scan(body, state, reqs)
        metrics = Metrics(
            requests=jnp.asarray(reqs.key.shape[0], _count_dtype()),
            hits=jnp.sum(info.hit, dtype=_count_dtype()),
            bytes_total=jnp.sum(reqs.size.astype(jnp.float32)),
            bytes_missed=jnp.sum(info.bytes_missed.astype(jnp.float32)),
            cost_total=jnp.sum(reqs.cost),
            penalty=jnp.sum(info.penalty),
        )
        return ReplayResult(info=info, metrics=metrics, obs=obs), state

    def body(carry, req):
        st, acc = carry
        st, info = policy.step(st, req)
        obs = policy.observables(st) if want_obs else None
        return (st, _acc_step(acc, req, info)), obs

    (state, acc), obs = jax.lax.scan(body, (state, _zero_acc()), reqs)
    return ReplayResult(info=None, metrics=acc, obs=obs), state


@partial(jax.jit,
         static_argnames=("policy", "K", "observe", "collect_info",
                          "use_pallas"))
def _replay_single(policy, reqs, K, observe, collect_info, use_pallas):
    with pallas_mode(use_pallas):
        return _scan_replay(policy, reqs, K, observe, collect_info)[0]


@partial(jax.jit,
         static_argnames=("policy", "K", "observe", "collect_info",
                          "use_pallas"))
def _replay_batched(policy, reqs, K, observe, collect_info, use_pallas):
    with pallas_mode(use_pallas):
        return jax.vmap(
            lambda r: _scan_replay(policy, r, K, observe, collect_info)[0]
        )(reqs)


@partial(jax.jit, static_argnames=("policy", "use_pallas", "observe"),
         donate_argnums=(1,))
def _replay_chunk(policy, state, reqs, use_pallas, observe):
    """One streaming chunk: advance donated policy state, return per-chunk
    totals (plus the chunk's stacked observables under ``observe`` — only
    ever chunk-shaped, summed into time means on the host).  Handles [T]
    and [B, T] chunks (state batched alike)."""
    with pallas_mode(use_pallas):
        def one(st, r):
            res, st = _scan_replay(policy, r, K=0, observe=observe,
                                   collect_info=False, state=st)
            return st, res.metrics, res.obs

        if reqs.key.ndim == 2:
            return jax.vmap(one)(state, reqs)
        return one(state, reqs)


class Engine:
    """The single replay entrypoint: scans one trace, vmaps a ``[B, T]``
    batch, and — given a mesh — shards the batch axis SPMD (each device
    replays B/axis_size independent caches, the TPU-native version of the
    paper's multi-threaded trace replay, Tables IV/V).

    ``use_pallas`` routes the rank-policy hot path through the fused Pallas
    policy-step kernel (overridable per call): ``False`` / ``"interpret"``
    / ``"compiled"``, or ``True`` for the per-backend default.  Slot-based
    policies are unaffected by the flag.

    >>> import numpy as np
    >>> res = Engine().replay("dac", np.zeros((2, 5), np.int32), K=4)
    >>> res.miss_ratio.tolist()       # [B, T] batch -> per-lane ratios
    [0.2, 0.2]
    """

    def __init__(self, mesh=None, axis: str = "data",
                 use_pallas=False):
        self.mesh = mesh
        self.axis = axis
        self.use_pallas = normalize_pallas_mode(use_pallas)

    def _resolve(self, policy, use_pallas):
        if isinstance(policy, str):
            from . import make_policy
            policy = make_policy(policy)
        use_pallas = (self.use_pallas if use_pallas is None
                      else normalize_pallas_mode(use_pallas))
        return policy, use_pallas

    def replay(self, policy, requests, K: int, *, sizes=None, costs=None,
               mesh=None, axis=None, observe: bool = False,
               collect_info: bool = True,
               use_pallas=None) -> ReplayResult:
        """Replay ``requests`` through ``policy`` at capacity ``K``.

        ``policy`` may be a :class:`Policy` instance or a spec string for
        :func:`repro.core.make_policy` (e.g. ``"dac(eps=0.5)"``).
        ``requests``: a :class:`Request`, or bare keys (``sizes``/``costs``
        then broadcast per :meth:`Request.of`).

        ``collect_info=False`` skips the ``[T]`` ``StepInfo`` stack and
        reduces :class:`Metrics` inside the scan carry — ``result.info`` is
        ``None`` and peak memory is O(K) per lane instead of O(T).
        """
        policy, use_pallas = self._resolve(policy, use_pallas)
        # a np.int32 capacity would be a fresh jit cache key (static args
        # compare with strict type equality) — normalize at the boundary
        K = int(K)
        reqs = Request.of(requests, sizes, costs)
        if reqs.key.ndim == 1:
            return _replay_single(policy, reqs, K, observe, collect_info,
                                  use_pallas)
        if reqs.key.ndim != 2:
            raise ValueError(
                f"requests must be [T] or [B, T], got shape {reqs.key.shape}")
        mesh = self.mesh if mesh is None else mesh
        if mesh is not None:
            sharding = NamedSharding(mesh, P(axis or self.axis, None))
            reqs = jax.device_put(reqs, sharding)
        return _replay_batched(policy, reqs, K, observe, collect_info,
                               use_pallas)

    def replay_tier(self, tier, requests, *, sizes=None, costs=None,
                    observe: bool = False, use_pallas=None):
        """Replay an interleaved multi-tenant stream through a
        :class:`repro.tier.CacheTier` (metrics-only, per-tenant
        :class:`Metrics` + time-mean occupancy in the scan carry).

        ``requests`` is ``[T, N]`` (one request per tenant per global
        step) or ``[S, T, N]`` for a vmapped seed axis; returns a
        :class:`repro.tier.TierResult`.  This is the first experiment
        family the single-cache ``replay`` cannot express — tenants
        compete for one budget, so their lanes are *not* independent.
        """
        from ..tier import CacheTier, replay_tier as _replay_tier
        if not isinstance(tier, CacheTier):
            raise TypeError(f"expected a CacheTier, got {type(tier).__name__}")
        use_pallas = (self.use_pallas if use_pallas is None
                      else normalize_pallas_mode(use_pallas))
        return _replay_tier(tier, requests, sizes=sizes, costs=costs,
                            observe=observe, use_pallas=use_pallas)

    def replay_fleet(self, tier, requests, *, sizes=None, costs=None,
                     observe: bool = False, mesh=None, axis=None,
                     rebalance: int = 256, use_pallas=None):
        """Replay a dynamic-fleet stream (``-1`` keys = idle lane) through
        a :class:`repro.fleet.FleetTier`: tenant arrivals/departures inside
        the scan, arbiter-priced capacity, per-lane SLO telemetry.

        ``requests`` is ``[T, N]`` (or ``[S, T, N]`` for a vmapped seed
        axis; unsharded only); with ``mesh=`` the lane axis is sharded via
        ``shard_map`` with a ``psum`` budget re-deal every ``rebalance``
        steps.  Returns a :class:`repro.fleet.FleetResult`.
        """
        from ..fleet import FleetTier, replay_fleet as _replay_fleet
        if not isinstance(tier, FleetTier):
            raise TypeError(
                f"expected a FleetTier, got {type(tier).__name__}")
        use_pallas = (self.use_pallas if use_pallas is None
                      else normalize_pallas_mode(use_pallas))
        return _replay_fleet(tier, requests, sizes=sizes, costs=costs,
                             observe=observe, mesh=mesh,
                             axis=axis or self.axis, rebalance=rebalance,
                             use_pallas=use_pallas)

    def replay_stream(self, policy, requests, K: int, *, sizes=None,
                      costs=None, chunk: int | None = None,
                      observe: bool = False,
                      use_pallas=None) -> ReplayResult:
        """Metrics-only replay of an arbitrarily long trace in fixed-size
        chunks.

        ``requests`` stays on the host; each chunk is shipped to the
        device, scanned with the metrics-in-carry body, and the policy
        state + accumulator buffers are *donated* between chunks, so device
        memory is O(K + chunk) regardless of trace length.  Per-chunk
        totals are summed on the host in 64-bit, so multi-billion-request
        streams cannot wrap int32 even without x64.  At most two programs
        compile: the full-chunk shape and one remainder shape.

        ``requests`` is either dense — ``[T]`` / ``[B, T]`` keys or a
        :class:`Request`, with per-request ``sizes`` / ``costs`` as scalars
        or same-shape arrays, sliced into ``chunk``-request pieces
        (default 2^18) — or an **iterator of chunks** (each item a
        ``Request``, a key array, or a ``(keys, sizes, costs)`` record
        like ``repro.data.ingest.TraceChunk``, unwrapped with its
        sizes/costs — ``replay_stream(pol,
        ingest.iter_chunks(path), K)`` just works), in which case the
        caller owns the chunking, nothing longer than one chunk is ever
        resident, and ``sizes``/``costs``/``chunk`` must be left unset
        (enforced — this method does not re-chunk an iterator) — the
        out-of-core path for file-backed traces.

        ``observe=True`` accumulates each policy observable's time total
        in 64 bits on the host and returns its **time mean** per lane in
        ``result.obs`` (e.g. DAC's average active size ``obs["k"]``) —
        the streaming equivalent of averaging :meth:`replay`'s stacked
        per-step observables, without ever materializing a ``[T]`` stack.
        For integer observables the two are bit-identical.

        Returns a :class:`ReplayResult` with ``info=None`` and host-side
        metrics.  Unlike :meth:`replay`, streaming does not consult the
        engine's ``mesh`` — chunks run unsharded on the default device;
        for mesh-sharded batch replay use ``replay(..., mesh=...)``.
        """
        policy, use_pallas = self._resolve(policy, use_pallas)
        K = int(K)   # strict-type static-arg key; see replay()

        if hasattr(requests, "__next__"):      # iterator of chunks
            if sizes is not None or costs is not None:
                raise ValueError(
                    "iterator input: sizes/costs travel inside each chunk")
            if chunk is not None:
                raise ValueError(
                    "iterator input owns its chunking — chunk= is not "
                    "applied to an iterator; size the chunks at the source")

            def coerce(item):
                # unwrap (keys, sizes, costs) chunk records —
                # repro.data.ingest.TraceChunk or a plain 3-tuple of
                # array-or-None columns — instead of letting them stack
                # into a bogus [3, T] key batch (lane batches are
                # arrays, never tuples)
                if isinstance(item, (tuple, list)) and len(item) == 3 \
                        and not isinstance(item, Request) \
                        and np.ndim(item[0]) > 0 \
                        and all(x is None or np.ndim(x) > 0
                                for x in item[1:]):
                    keys, sizes, costs = item
                    return Request.of(np.asarray(keys), sizes=sizes,
                                      costs=costs)
                return Request.of(item)

            chunks = (coerce(item) for item in requests)
            lead = None                        # lane shape learned on entry
        else:                                  # dense host array
            chunk = (1 << 18) if chunk is None else chunk
            if chunk <= 0:
                raise ValueError(f"chunk must be positive, got {chunk}")
            if isinstance(requests, Request):
                if sizes is not None or costs is not None:
                    raise ValueError("pass sizes/costs inside the Request")
                keys = np.asarray(requests.key)
                sizes = np.asarray(requests.size)
                costs = np.asarray(requests.cost)
            else:
                keys = np.asarray(requests)
            if keys.ndim not in (1, 2):
                raise ValueError(
                    f"requests must be [T] or [B, T], got shape {keys.shape}")
            lead = keys.shape[:-1]

            def sl(x, lo, hi):
                if x is None or np.ndim(x) == 0:
                    return x
                return np.asarray(x)[..., lo:hi]

            def dense_chunks():
                for lo in range(0, keys.shape[-1], chunk):
                    hi = min(lo + chunk, keys.shape[-1])
                    yield Request.of(keys[..., lo:hi], sl(sizes, lo, hi),
                                     sl(costs, lo, hi))

            chunks = dense_chunks()

        def init_state(lead):
            state = policy.init(K)
            if lead:
                state = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, lead + x.shape).copy(),
                    state)
            return state

        state = None if lead is None else init_state(lead)
        totals = None if lead is None else np.zeros((6,) + lead, np.float64)
        obs_sums, T_total = None, 0
        with warnings.catch_warnings():
            # buffer donation is a no-op on some backends (CPU) — harmless
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onat.*", category=UserWarning)
            for reqs in chunks:
                if reqs.key.ndim not in (1, 2):
                    raise ValueError(
                        f"chunks must be [T] or [B, T], got shape "
                        f"{reqs.key.shape}")
                if state is None:              # first iterator chunk
                    lead = tuple(reqs.key.shape[:-1])
                    state = init_state(lead)
                    totals = np.zeros((6,) + lead, np.float64)
                elif tuple(reqs.key.shape[:-1]) != tuple(lead):
                    raise ValueError(
                        f"chunk lane shape changed mid-stream: "
                        f"{tuple(reqs.key.shape[:-1])} != {tuple(lead)}")
                state, m, obs = _replay_chunk(policy, state, reqs,
                                              use_pallas, observe)
                totals += np.stack(
                    [np.asarray(f, dtype=np.float64) for f in m])
                T_total += reqs.key.shape[-1]
                if obs is not None:
                    part = {k: np.asarray(v, np.float64).sum(axis=-1)
                            for k, v in obs.items()}
                    obs_sums = part if obs_sums is None else {
                        k: obs_sums[k] + part[k] for k in part}
        if totals is None:                     # empty iterator
            totals = np.zeros(6, np.float64)
        metrics = Metrics(
            requests=totals[0].astype(np.int64),
            hits=totals[1].astype(np.int64),
            bytes_total=totals[2], bytes_missed=totals[3],
            cost_total=totals[4], penalty=totals[5],
        )
        obs_out = None
        if obs_sums is not None and T_total:
            obs_out = {k: v / T_total for k, v in obs_sums.items()}
        return ReplayResult(info=None, metrics=metrics, obs=obs_out)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def miss_ratio(hits) -> float:
    """Miss ratio of a boolean hit mask (host-side convenience).

    >>> miss_ratio([True, False, False, False])
    0.75
    """
    return float(1.0 - np.asarray(hits, dtype=np.float64).mean())


def mrr(mr_algo: float, mr_fifo: float) -> float:
    """Miss-ratio reduction relative to FIFO (paper's signed definition).
    Both-zero is explicitly no-reduction (0.0) rather than falling through
    either signed branch.

    >>> mrr(0.2, 0.4)       # halved the misses
    0.5
    >>> mrr(0.4, 0.2)       # doubled them
    -0.5
    >>> mrr(0.0, 0.0)
    0.0
    """
    if mr_algo == 0.0 and mr_fifo == 0.0:
        return 0.0
    if mr_algo <= mr_fifo:
        return (mr_fifo - mr_algo) / mr_fifo if mr_fifo > 0 else 0.0
    return (mr_fifo - mr_algo) / mr_algo if mr_algo > 0 else 0.0
